"""Build-time compile path: JAX/Pallas authoring + AOT lowering to HLO text.

Nothing in this package is imported at runtime; the Rust binary only reads
the `artifacts/` directory this package produces.
"""
