"""L2 JAX graphs: quantized DNN layers built on the L1 Pallas kernels.

Each builder returns a traceable function with *fixed* shapes (AOT contract:
one HLO artifact per layer instance). Weights and biases are graph INPUTS,
not constants — the Rust coordinator owns the parameters, which is what lets
the software-level fault injector flip bits in them between executions.
Scale multipliers are baked in and recorded in the artifact manifest.

The e2e model ("QuickNet") is a small int8 CNN for 3x32x32 inputs / 10
classes; its per-layer graphs are what the Rust PJRT runtime executes on the
software portion of the cross-layer forward pass.
"""

import jax.numpy as jnp

from .kernels import im2col, matmul_int8, requant_int32
from .kernels.ref import softmax_f32_ref


def make_qconv(cin, h, w, cout, kh, kw, stride, pad, m, relu):
    """Quantized conv layer graph (im2col + GEMM + requant).

    Signature: f(x[cin,h,w] i8, wmat[cin*kh*kw, cout] i8, bias[cout] i32)
    -> (y[cout,oh,ow] i8,)
    """
    oh = (h + 2 * pad - kh) // stride + 1
    ow = (w + 2 * pad - kw) // stride + 1
    p = oh * ow

    def fwd(x, wmat, bias):
        patches = im2col(x, kh, kw, stride, pad)  # [P, cin*kh*kw]
        d = jnp.broadcast_to(bias[None, :], (p, cout)).astype(jnp.int32)
        acc = matmul_int8(patches, wmat, d)  # [P, cout]
        y = requant_int32(acc, m, relu=relu)  # [P, cout] i8
        return (y.T.reshape(cout, oh, ow),)

    shapes = dict(
        x=((cin, h, w), jnp.int8),
        wmat=((cin * kh * kw, cout), jnp.int8),
        bias=((cout,), jnp.int32),
    )
    meta = dict(
        kind="conv", cin=cin, h=h, w=w, cout=cout, kh=kh, kw=kw,
        stride=stride, pad=pad, m=m, relu=relu, oh=oh, ow=ow,
    )
    return fwd, shapes, meta


def make_qlinear(in_f, out_f, m, relu):
    """Quantized fully-connected layer graph.

    Signature: f(x[1,in_f] i8, w[in_f,out_f] i8, bias[out_f] i32)
    -> (y[1,out_f] i8,)
    """

    def fwd(x, w, bias):
        d = bias[None, :].astype(jnp.int32)
        acc = matmul_int8(x, w, d)
        return (requant_int32(acc, m, relu=relu),)

    shapes = dict(
        x=((1, in_f), jnp.int8),
        w=((in_f, out_f), jnp.int8),
        bias=((out_f,), jnp.int32),
    )
    meta = dict(kind="linear", in_f=in_f, out_f=out_f, m=m, relu=relu)
    return fwd, shapes, meta


def make_qgemm(mdim, k, n):
    """Raw tile GEMM graph: the unit the cross-layer runner offloads.

    Signature: f(a[m,k] i8, b[k,n] i8, d[m,n] i32) -> (c[m,n] i32,)
    """

    def fwd(a, b, d):
        return (matmul_int8(a, b, d),)

    shapes = dict(
        a=((mdim, k), jnp.int8), b=((k, n), jnp.int8), d=((mdim, n), jnp.int32)
    )
    meta = dict(kind="gemm", m_dim=mdim, k=k, n=n)
    return fwd, shapes, meta


def make_qattention(seq, d_model, mq, mk, mv, ms, mo, mw):
    """Single-head quantized attention block (the ViT matmul hot-spot).

    Integer projections / AV / output matmuls with f32 softmax in between
    (probabilities re-quantized to int8 with scale 127), mirroring the
    I-ViT-style integer pipeline the paper evaluates.

    Signature: f(x[seq,d] i8, wq, wk, wv, wo [d,d] i8) -> (y[seq,d] i8,)
    """
    zero_d = ((seq, d_model), jnp.int32)

    def proj(x, w, m):
        d0 = jnp.zeros(zero_d[0], jnp.int32)
        return requant_int32(matmul_int8(x, w, d0), m)

    def fwd(x, wq, wk, wv, wo):
        q = proj(x, wq, mq)  # [L, D] i8
        k = proj(x, wk, mk)
        v = proj(x, wv, mv)
        zs = jnp.zeros((seq, seq), jnp.int32)
        s = matmul_int8(q, k.T, zs)  # [L, L] i32 logits
        p = softmax_f32_ref(s.astype(jnp.float32) * jnp.float32(ms))
        p_i8 = jnp.clip(
            jnp.floor(p * jnp.float32(127.0) + jnp.float32(0.5)), 0.0, 127.0
        ).astype(jnp.int8)
        o = requant_int32(matmul_int8(p_i8, v, zero_like(zero_d)), mo)  # [L, D]
        y = requant_int32(matmul_int8(o, wo, zero_like(zero_d)), mw)
        return (y,)

    def zero_like(sd):
        return jnp.zeros(sd[0], jnp.int32)

    shapes = dict(
        x=((seq, d_model), jnp.int8),
        wq=((d_model, d_model), jnp.int8),
        wk=((d_model, d_model), jnp.int8),
        wv=((d_model, d_model), jnp.int8),
        wo=((d_model, d_model), jnp.int8),
    )
    meta = dict(
        kind="attention", seq=seq, d_model=d_model,
        mq=mq, mk=mk, mv=mv, ms=ms, mo=mo, mw=mw,
    )
    return fwd, shapes, meta


# ---------------------------------------------------------------------------
# QuickNet: the end-to-end example model. 3x32x32 -> 10 classes, ~70k params.
# Pool + argmax run natively in Rust (integer ops); every GEMM-bearing layer
# is a PJRT artifact. Scales chosen so int8 ranges stay well-exercised.
# ---------------------------------------------------------------------------
QUICKNET_LAYERS = [
    ("quicknet_conv1", "conv", dict(cin=3, h=32, w=32, cout=16, kh=3, kw=3,
                                    stride=1, pad=1, m=0.035, relu=True)),
    ("quicknet_conv2", "conv", dict(cin=16, h=32, w=32, cout=32, kh=3, kw=3,
                                    stride=2, pad=1, m=0.02, relu=True)),
    ("quicknet_conv3", "conv", dict(cin=32, h=16, w=16, cout=32, kh=3, kw=3,
                                    stride=1, pad=1, m=0.02, relu=True)),
    ("quicknet_conv4", "conv", dict(cin=32, h=16, w=16, cout=64, kh=3, kw=3,
                                    stride=2, pad=1, m=0.02, relu=True)),
    # global 8x8 avg-pool happens natively in rust between conv4 and fc
    ("quicknet_fc", "linear", dict(in_f=64, out_f=10, m=0.05, relu=False)),
]

# Generic GEMM tiles for the mesh cross-check and the ViT attention block.
GEMM_TILES = [(8, 8, 8), (16, 16, 16), (64, 64, 64), (128, 128, 128)]
ATTENTION_CFG = dict(
    seq=64, d_model=64, mq=0.01, mk=0.01, mv=0.01, ms=0.05, mo=0.05, mw=0.02
)


def build_all():
    """Yield (name, fwd, shapes, meta) for every artifact to AOT-compile."""
    for name, kind, cfg in QUICKNET_LAYERS:
        if kind == "conv":
            fwd, shapes, meta = make_qconv(**cfg)
        else:
            fwd, shapes, meta = make_qlinear(**cfg)
        yield name, fwd, shapes, meta
    for mdim, k, n in GEMM_TILES:
        fwd, shapes, meta = make_qgemm(mdim, k, n)
        yield f"gemm_{mdim}x{k}x{n}", fwd, shapes, meta
    fwd, shapes, meta = make_qattention(**ATTENTION_CFG)
    yield "attention_64", fwd, shapes, meta
