"""AOT lowering: JAX graphs -> HLO *text* artifacts + manifest.

HLO text (NOT serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/gen_hlo.py.

Usage: cd python && python -m compile.aot --out-dir ../artifacts
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """stablehlo MLIR -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_one(fwd, shapes):
    """jit + lower a graph with ShapeDtypeStruct example args."""
    specs = [jax.ShapeDtypeStruct(s, dt) for s, dt in shapes.values()]
    return jax.jit(fwd).lower(*specs)


def main() -> None:
    ap = argparse.ArgumentParser(description="AOT-lower all L2 graphs")
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="lower a single artifact")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"format": "hlo-text", "artifacts": {}, "models": {}}
    for name, fwd, shapes, meta in model.build_all():
        if args.only and name != args.only:
            continue
        lowered = lower_one(fwd, shapes)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out_dir, fname), "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "file": fname,
            "meta": meta,
            "inputs": [
                {"name": k, "shape": list(s), "dtype": jnp.dtype(dt).name}
                for k, (s, dt) in shapes.items()
            ],
        }
        print(f"lowered {name:24s} -> {fname} ({len(text)} chars)")

    manifest["models"]["quicknet"] = {
        "input": [3, 32, 32],
        "classes": 10,
        "layers": [
            {"name": n, "kind": k, **cfg} for n, k, cfg in model.QUICKNET_LAYERS
        ],
        "pool": {"after": "quicknet_conv4", "kind": "global_avg", "hw": 8},
    }
    manifest["attention"] = model.ATTENTION_CFG
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest with {len(manifest['artifacts'])} artifacts")


if __name__ == "__main__":
    main()
