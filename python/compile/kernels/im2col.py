"""L1 Pallas kernel: im2col unfold for quantized convolution.

Convolutions are lowered onto the systolic array as GEMMs (the paper's
runtime does exactly this for CNN layers); im2col produces the activation
matrix. The grid iterates over output rows; each program extracts the
KH-row slab of the (pre-padded) image it needs and emits the OW patch rows
for that output row. Patch layout is (c, kh, kw), matching ref.py and
`rust/src/dnn/im2col.rs`.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _im2col_kernel(x_ref, o_ref, *, kh, kw, stride, ow):
    """Emit the OW patches of one output row.

    x_ref: full padded image [C, Hp, Wp]; o_ref block: [OW, C*KH*KW].
    """
    i = pl.program_id(0)
    c, _, wp = x_ref.shape
    # KH-row slab for this output row: [C, KH, Wp].
    slab = x_ref[:, pl.ds(i * stride, kh), :]
    # Strided windows along W: idx[ow_, kw_] = ow_ * stride + kw_.
    idx = jnp.arange(ow)[:, None] * stride + jnp.arange(kw)[None, :]
    patches = slab[:, :, idx]  # [C, KH, OW, KW]
    o_ref[...] = patches.transpose(2, 0, 1, 3).reshape(ow, c * kh * kw)


@functools.partial(jax.jit, static_argnames=("kh", "kw", "stride", "pad"))
def im2col(x, kh, kw, stride=1, pad=0):
    """Unfold x[C, H, W] int8 -> [OH*OW, C*KH*KW] int8 patch matrix."""
    c, h, w = x.shape
    oh = (h + 2 * pad - kh) // stride + 1
    ow = (w + 2 * pad - kw) // stride + 1
    xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad)))
    hp, wp = h + 2 * pad, w + 2 * pad
    out = pl.pallas_call(
        functools.partial(_im2col_kernel, kh=kh, kw=kw, stride=stride, ow=ow),
        grid=(oh,),
        in_specs=[pl.BlockSpec((c, hp, wp), lambda i: (0, 0, 0))],
        out_specs=pl.BlockSpec((ow, c * kh * kw), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((oh * ow, c * kh * kw), jnp.int8),
        interpret=True,
    )(xp)
    return out
