"""Pallas kernels (L1) and their pure-jnp oracles."""

from .im2col import im2col
from .matmul_int8 import matmul_int8, requant_int32

__all__ = ["im2col", "matmul_int8", "requant_int32"]
