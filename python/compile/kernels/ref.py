"""Pure-jnp oracles for the Pallas kernels.

These are the CORRECTNESS CONTRACT of layer 1: every Pallas kernel in this
package must agree bit-exactly with its oracle here (pytest enforces it).
The Rust native engine (`rust/src/dnn/gemm.rs`) and the RTL mesh simulator
(`rust/src/mesh/`) implement the same arithmetic, so the whole cross-layer
stack shares one numeric definition.

Quantization scheme (shared by Python, HLO artifacts and Rust):
  * activations / weights: int8, symmetric (zero_point = 0)
  * bias / accumulators:   int32 (exact integer GEMM, no saturation)
  * requantization:        q = clamp(floor(acc_f32 * m + 0.5), -128, 127)
    with `m` a per-layer f32 multiplier; floor(x + 0.5) is round-half-up,
    which is deterministic and identical in IEEE f32 on both XLA-CPU and
    Rust (one f32 multiply, one f32 add, one floor).
"""

import jax.numpy as jnp
import numpy as np


def matmul_int8_ref(a, b, d):
    """C[i32] = A[i8] . B[i8] + D[i32], exact integer arithmetic.

    a: [M, K] int8, b: [K, N] int8, d: [M, N] int32 -> [M, N] int32.
    """
    return (
        jnp.dot(
            a.astype(jnp.int32), b.astype(jnp.int32), preferred_element_type=jnp.int32
        )
        + d
    )


def requant_ref(c, m, relu=False):
    """int32 accumulator -> int8 with round-half-up and saturation."""
    q = jnp.floor(c.astype(jnp.float32) * jnp.float32(m) + jnp.float32(0.5))
    q = jnp.clip(q, -128.0, 127.0).astype(jnp.int8)
    if relu:
        q = jnp.maximum(q, 0)
    return q


def im2col_ref(x, kh, kw, stride, pad):
    """Unfold a single image x[C, H, W] (int8) into patch rows.

    Returns [OH * OW, C * KH * KW] int8, patch layout (c, kh, kw) —
    identical to `rust/src/dnn/im2col.rs`.
    """
    c, h, w = x.shape
    oh = (h + 2 * pad - kh) // stride + 1
    ow = (w + 2 * pad - kw) // stride + 1
    xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad)))
    rows = []
    for i in range(oh):
        for j in range(ow):
            patch = xp[:, i * stride : i * stride + kh, j * stride : j * stride + kw]
            rows.append(patch.reshape(-1))
    return jnp.stack(rows).astype(jnp.int8)


def conv2d_int8_ref(x, w, bias, m, stride, pad, relu):
    """Whole quantized conv layer oracle: im2col + GEMM + requant.

    x: [C, H, W] i8; w: [OC, C, KH, KW] i8; bias: [OC] i32 -> [OC, OH, OW] i8.
    """
    oc, c, kh, kw = w.shape
    patches = im2col_ref(x, kh, kw, stride, pad)  # [P, C*KH*KW]
    wmat = w.reshape(oc, c * kh * kw).T  # [C*KH*KW, OC]
    d = jnp.broadcast_to(bias[None, :], (patches.shape[0], oc)).astype(jnp.int32)
    acc = matmul_int8_ref(patches, wmat, d)  # [P, OC]
    q = requant_ref(acc, m, relu)
    h, wdim = x.shape[1], x.shape[2]
    ohh = (h + 2 * pad - kh) // stride + 1
    oww = (wdim + 2 * pad - kw) // stride + 1
    return q.T.reshape(oc, ohh, oww)


def softmax_f32_ref(s):
    """Numerically stable f32 softmax over the last axis."""
    s = s - jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def np_requant(c, m, relu=False):
    """NumPy twin of requant_ref for host-side golden data generation."""
    q = np.floor(c.astype(np.float32) * np.float32(m) + np.float32(0.5))
    q = np.clip(q, -128.0, 127.0).astype(np.int8)
    if relu:
        q = np.maximum(q, 0)
    return q
