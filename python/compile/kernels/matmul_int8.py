"""L1 Pallas kernel: tiled int8 x int8 -> int32 GEMM with bias.

This is the functional golden model of the Gemmini mesh: the systolic array
computes `C = A . B + D` over int8 operands with exact int32 accumulation,
and so does this kernel. The tile grid (TM, TK, TN) mirrors the DIM x DIM PE
grid the same way the mesh's systolic skewing tiles the operand stream.

TPU mapping (see DESIGN.md §Hardware-Adaptation): each (TM, TK) x (TK, TN)
block pair is staged in VMEM, the K loop is the innermost grid dimension so
the int32 accumulator block stays resident in VMEM across the whole
reduction (no HBM round-trips), and the MAC feeds the MXU via
`preferred_element_type=int32`. interpret=True everywhere — the CPU PJRT
client cannot execute Mosaic custom-calls.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(a_ref, b_ref, d_ref, o_ref):
    """One (i, j, k) grid step: o[i,j] (+)= a[i,k] . b[k,j], init with d."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = d_ref[...]

    a = a_ref[...].astype(jnp.int32)
    b = b_ref[...].astype(jnp.int32)
    o_ref[...] += jnp.dot(a, b, preferred_element_type=jnp.int32)


def _pick_tile(dim, pref):
    """Largest divisor of `dim` that is <= pref (tiles must divide shapes)."""
    t = min(dim, pref)
    while dim % t != 0:
        t -= 1
    return t


@functools.partial(jax.jit, static_argnames=("tm", "tk", "tn"))
def matmul_int8(a, b, d, tm=128, tk=128, tn=128):
    """C[i32] = A[i8] . B[i8] + D[i32] as a tiled Pallas kernel.

    a: [M, K] int8, b: [K, N] int8, d: [M, N] int32 -> [M, N] int32.
    Tile sizes are clamped to divisors of the problem shape.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    assert d.shape == (m, n), f"bias shape {d.shape} != {(m, n)}"
    tm = _pick_tile(m, tm)
    tk = _pick_tile(k, tk)
    tn = _pick_tile(n, tn)
    grid = (m // tm, n // tn, k // tk)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((tk, tn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((tm, tn), lambda i, j, kk: (i, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=True,
    )(a, b, d)


def _requant_kernel(c_ref, m_ref, o_ref, *, relu):
    """Elementwise requantization block: i32 -> i8 (round-half-up, clamp)."""
    c = c_ref[...].astype(jnp.float32)
    q = jnp.floor(c * m_ref[0, 0] + jnp.float32(0.5))
    q = jnp.clip(q, -128.0, 127.0).astype(jnp.int8)
    if relu:
        q = jnp.maximum(q, 0)
    o_ref[...] = q


@functools.partial(jax.jit, static_argnames=("relu", "tm", "tn"))
def requant_int32(c, m, relu=False, tm=256, tn=256):
    """Requantize an int32 accumulator matrix to int8.

    c: [M, N] int32, m: f32 scalar multiplier -> [M, N] int8.
    """
    mm, nn = c.shape
    tm = _pick_tile(mm, tm)
    tn = _pick_tile(nn, tn)
    m_arr = jnp.asarray(m, jnp.float32).reshape(1, 1)
    return pl.pallas_call(
        functools.partial(_requant_kernel, relu=relu),
        grid=(mm // tm, nn // tn),
        in_specs=[
            pl.BlockSpec((tm, tn), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mm, nn), jnp.int8),
        interpret=True,
    )(c, m_arr)
