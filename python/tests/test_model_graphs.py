"""L2 correctness: layer graphs vs whole-layer oracles, and AOT lowering.

These are the graphs the Rust runtime executes via PJRT, so their numeric
behaviour here IS the software path of the cross-layer simulator.
"""

import numpy as np
import pytest

from compile import model
from compile.kernels.ref import (
    conv2d_int8_ref,
    matmul_int8_ref,
    np_requant,
    softmax_f32_ref,
)

RNG = np.random.default_rng(0x90DE1)


def rand_i8(*shape):
    return RNG.integers(-128, 128, shape, dtype=np.int8)


def rand_i32(*shape, span=2**10):
    return RNG.integers(-span, span, shape, dtype=np.int32)


@pytest.mark.parametrize(
    "cfg",
    [
        dict(cin=3, h=8, w=8, cout=4, kh=3, kw=3, stride=1, pad=1, m=0.03, relu=True),
        dict(cin=2, h=9, w=9, cout=3, kh=3, kw=3, stride=2, pad=1, m=0.05, relu=False),
        dict(cin=1, h=6, w=6, cout=2, kh=1, kw=1, stride=1, pad=0, m=0.1, relu=True),
    ],
)
def test_qconv_graph_matches_whole_layer_oracle(cfg):
    fwd, shapes, meta = model.make_qconv(**cfg)
    x = rand_i8(*shapes["x"][0])
    w4 = rand_i8(cfg["cout"], cfg["cin"], cfg["kh"], cfg["kw"])
    wmat = w4.reshape(cfg["cout"], -1).T.copy()
    bias = rand_i32(cfg["cout"])
    (got,) = fwd(x, wmat, bias)
    want = conv2d_int8_ref(
        x, w4, bias, cfg["m"], cfg["stride"], cfg["pad"], cfg["relu"]
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_qlinear_graph_matches_oracle():
    fwd, shapes, meta = model.make_qlinear(in_f=24, out_f=10, m=0.04, relu=False)
    x, w, b = rand_i8(1, 24), rand_i8(24, 10), rand_i32(10)
    (got,) = fwd(x, w, b)
    acc = x.astype(np.int32) @ w.astype(np.int32) + b[None, :]
    want = np_requant(acc, 0.04)
    np.testing.assert_array_equal(np.asarray(got), want)


def test_qgemm_graph_is_exact():
    fwd, shapes, meta = model.make_qgemm(16, 16, 16)
    a, b, d = rand_i8(16, 16), rand_i8(16, 16), rand_i32(16, 16)
    (got,) = fwd(a, b, d)
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(matmul_int8_ref(a, b, d))
    )


def test_qattention_graph_matches_oracle():
    cfg = dict(seq=8, d_model=8, mq=0.02, mk=0.02, mv=0.02, ms=0.05, mo=0.05, mw=0.03)
    fwd, shapes, meta = model.make_qattention(**cfg)
    x = rand_i8(8, 8)
    ws = [rand_i8(8, 8) for _ in range(4)]
    (got,) = fwd(x, *ws)

    def proj(w, m):
        return np_requant(x.astype(np.int32) @ w.astype(np.int32), m)

    q, k, v = proj(ws[0], cfg["mq"]), proj(ws[1], cfg["mk"]), proj(ws[2], cfg["mv"])
    s = q.astype(np.int32) @ k.astype(np.int32).T
    p = np.asarray(softmax_f32_ref(s.astype(np.float32) * np.float32(cfg["ms"])))
    p_i8 = np.clip(np.floor(p * 127.0 + 0.5), 0, 127).astype(np.int8)
    o = np_requant(p_i8.astype(np.int32) @ v.astype(np.int32), cfg["mo"])
    want = np_requant(o.astype(np.int32) @ ws[3].astype(np.int32), cfg["mw"])
    np.testing.assert_array_equal(np.asarray(got), want)


def test_quicknet_layer_shapes_chain():
    """Consecutive QuickNet conv layers must be shape-compatible."""
    convs = [cfg for _, kind, cfg in model.QUICKNET_LAYERS if kind == "conv"]
    for prev, nxt in zip(convs, convs[1:]):
        oh = (prev["h"] + 2 * prev["pad"] - prev["kh"]) // prev["stride"] + 1
        assert nxt["cin"] == prev["cout"]
        assert nxt["h"] == oh and nxt["w"] == oh
    last = convs[-1]
    oh = (last["h"] + 2 * last["pad"] - last["kh"]) // last["stride"] + 1
    fc = model.QUICKNET_LAYERS[-1][2]
    assert fc["in_f"] == last["cout"]  # global avg pool collapses oh x ow
    assert oh == 8  # matches manifest pool.hw


def test_build_all_is_complete_and_unique():
    names = [name for name, *_ in model.build_all()]
    assert len(names) == len(set(names))
    assert "quicknet_conv1" in names and "quicknet_fc" in names
    assert "attention_64" in names
    assert any(n.startswith("gemm_8x") for n in names)


@pytest.mark.parametrize("name", ["quicknet_fc", "gemm_8x8x8"])
def test_aot_lowering_produces_hlo_text(name):
    from compile import aot

    for n, fwd, shapes, meta in model.build_all():
        if n != name:
            continue
        text = aot.to_hlo_text(aot.lower_one(fwd, shapes))
        assert "HloModule" in text
        assert "ENTRY" in text
        return
    pytest.fail(f"artifact {name} not found")
