"""L1 correctness: Pallas int8 GEMM / requant kernels vs pure-jnp oracles.

The GEMM is exact integer arithmetic, so every comparison is bit-exact
(assert_array_equal, not allclose).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul_int8, requant_int32
from compile.kernels.ref import matmul_int8_ref, np_requant, requant_ref

RNG = np.random.default_rng(0xE4F0)


def rand_i8(*shape):
    return RNG.integers(-128, 128, size=shape, dtype=np.int8)


def rand_i32(*shape, lo=-(2**20), hi=2**20):
    return RNG.integers(lo, hi, size=shape, dtype=np.int32)


@pytest.mark.parametrize(
    "m,k,n",
    [
        (1, 1, 1),
        (2, 3, 4),
        (8, 8, 8),
        (16, 27, 16),  # conv1-like K = 3*3*3
        (64, 64, 64),
        (128, 128, 128),
        (256, 144, 32),  # conv2-like
        (100, 70, 30),  # awkward non-power-of-two
    ],
)
def test_matmul_matches_ref(m, k, n):
    a, b, d = rand_i8(m, k), rand_i8(k, n), rand_i32(m, n)
    got = matmul_int8(a, b, d)
    want = matmul_int8_ref(a, b, d)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("tm,tk,tn", [(1, 1, 1), (4, 4, 4), (8, 32, 8), (64, 16, 64)])
def test_matmul_tile_invariance(tm, tk, tn):
    """Result must be independent of the tile decomposition."""
    a, b, d = rand_i8(64, 64), rand_i8(64, 64), rand_i32(64, 64)
    got = matmul_int8(a, b, d, tm=tm, tk=tk, tn=tn)
    want = matmul_int8_ref(a, b, d)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_matmul_extreme_values_no_overflow():
    """Worst case |acc| = 128*128*K must accumulate exactly in int32."""
    k = 96
    a = np.full((8, k), -128, np.int8)
    b = np.full((k, 8), -128, np.int8)
    d = np.zeros((8, 8), np.int32)
    got = np.asarray(matmul_int8(a, b, d))
    assert (got == 128 * 128 * k).all()


def test_matmul_identity():
    n = 16
    eye = np.eye(n, dtype=np.int8)
    x = rand_i8(n, n)
    got = np.asarray(matmul_int8(x, eye, np.zeros((n, n), np.int32)))
    np.testing.assert_array_equal(got, x.astype(np.int32))


def test_matmul_bias_only():
    """Zero operands: output must equal the bias exactly."""
    d = rand_i32(32, 32)
    z = np.zeros((32, 32), np.int8)
    np.testing.assert_array_equal(np.asarray(matmul_int8(z, z, d)), d)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 40),
    k=st.integers(1, 40),
    n=st.integers(1, 40),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_hypothesis_shapes(m, k, n, seed):
    r = np.random.default_rng(seed)
    a = r.integers(-128, 128, (m, k), dtype=np.int8)
    b = r.integers(-128, 128, (k, n), dtype=np.int8)
    d = r.integers(-(2**16), 2**16, (m, n), dtype=np.int32)
    np.testing.assert_array_equal(
        np.asarray(matmul_int8(a, b, d)), np.asarray(matmul_int8_ref(a, b, d))
    )


# ----------------------------- requant ------------------------------------


@pytest.mark.parametrize("relu", [False, True])
@pytest.mark.parametrize("m", [0.001, 0.02, 0.5, 1.0])
def test_requant_matches_ref(m, relu):
    c = rand_i32(32, 48, lo=-(2**24), hi=2**24)
    got = requant_int32(c, m, relu=relu)
    want = requant_ref(c, m, relu=relu)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_requant_saturates():
    c = np.array([[2**30, -(2**30)]], np.int32)
    got = np.asarray(requant_int32(c, 1.0))
    np.testing.assert_array_equal(got, np.array([[127, -128]], np.int8))


def test_requant_round_half_up():
    """floor(x*m + 0.5): 0.5 rounds up, -0.5 rounds to 0 (half-up).

    m = 0.5 is exactly representable in f32, so the halfway cases are exact.
    """
    c = np.array([[1, -1, 3, -3]], np.int32)
    got = np.asarray(requant_int32(c, 0.5))  # 0.5, -0.5, 1.5, -1.5
    np.testing.assert_array_equal(got, np.array([[1, 0, 2, -1]], np.int8))


def test_requant_relu_clamps_negatives():
    c = np.array([[-1000, 1000, 0]], np.int32)
    got = np.asarray(requant_int32(c, 1.0, relu=True))
    np.testing.assert_array_equal(got, np.array([[0, 127, 0]], np.int8))


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), m=st.floats(1e-5, 2.0, allow_nan=False))
def test_requant_hypothesis(seed, m):
    r = np.random.default_rng(seed)
    c = r.integers(-(2**26), 2**26, (17, 9), dtype=np.int32)
    got = np.asarray(requant_int32(c, float(np.float32(m))))
    want = np_requant(c, float(np.float32(m)))
    np.testing.assert_array_equal(got, want)


def test_requant_ref_and_np_twin_agree():
    c = rand_i32(64, 64, lo=-(2**26), hi=2**26)
    np.testing.assert_array_equal(
        np.asarray(requant_ref(c, 0.013)), np_requant(c, 0.013)
    )
