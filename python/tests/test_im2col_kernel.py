"""L1 correctness: Pallas im2col kernel vs pure-jnp oracle (bit-exact)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import im2col
from compile.kernels.ref import im2col_ref

RNG = np.random.default_rng(0x1C01)


def rand_img(c, h, w):
    return RNG.integers(-128, 128, (c, h, w), dtype=np.int8)


@pytest.mark.parametrize(
    "c,h,w,kh,kw,stride,pad",
    [
        (1, 4, 4, 1, 1, 1, 0),  # pointwise
        (3, 8, 8, 3, 3, 1, 1),  # classic 3x3 same
        (3, 32, 32, 3, 3, 1, 1),  # quicknet conv1
        (16, 32, 32, 3, 3, 2, 1),  # strided
        (4, 9, 7, 3, 5, 2, 2),  # asymmetric kernel, odd dims
        (2, 8, 8, 8, 8, 1, 0),  # kernel == image
        (3, 16, 16, 7, 7, 2, 3),  # resnet conv1-like
    ],
)
def test_im2col_matches_ref(c, h, w, kh, kw, stride, pad):
    x = rand_img(c, h, w)
    got = np.asarray(im2col(x, kh, kw, stride, pad))
    want = np.asarray(im2col_ref(x, kh, kw, stride, pad))
    np.testing.assert_array_equal(got, want)


def test_im2col_patch_layout_is_c_kh_kw():
    """Pin the patch element ordering: index = c*KH*KW + kh*KW + kw."""
    c, h, w, kh, kw = 2, 3, 3, 2, 2
    x = np.arange(c * h * w, dtype=np.int8).reshape(c, h, w)
    got = np.asarray(im2col(x, kh, kw, 1, 0))
    # first patch, channel 1, kernel pos (1, 0) => x[1, 1, 0] = 9 + 3 = 12
    assert got[0, 1 * kh * kw + 1 * kw + 0] == x[1, 1, 0]


def test_im2col_zero_padding_is_zero():
    x = np.full((1, 2, 2), 7, np.int8)
    got = np.asarray(im2col(x, 3, 3, 1, 1))
    # top-left patch has its entire first row in the pad region
    assert (got[0, :3] == 0).all()


@settings(max_examples=20, deadline=None)
@given(
    c=st.integers(1, 4),
    h=st.integers(3, 12),
    w=st.integers(3, 12),
    k=st.integers(1, 3),
    stride=st.integers(1, 2),
    pad=st.integers(0, 2),
    seed=st.integers(0, 2**31 - 1),
)
def test_im2col_hypothesis(c, h, w, k, stride, pad, seed):
    if h + 2 * pad < k or w + 2 * pad < k:
        return
    r = np.random.default_rng(seed)
    x = r.integers(-128, 128, (c, h, w), dtype=np.int8)
    got = np.asarray(im2col(x, k, k, stride, pad))
    want = np.asarray(im2col_ref(x, k, k, stride, pad))
    np.testing.assert_array_equal(got, want)
