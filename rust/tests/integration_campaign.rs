//! Campaign-level integration: the paper's experimental pipeline end to
//! end on small budgets — AVF vs PVF gap, backend equivalences, maps.

use enfor_sa::campaign::{run_campaign, weight_exposure_map};
use enfor_sa::config::{Backend, CampaignConfig, MeshConfig, OffloadScope, TrialEngine};
use enfor_sa::dnn::models;

fn cfg(backend: Backend, faults: u64, inputs: u64) -> CampaignConfig {
    CampaignConfig {
        seed: 0x1A7E57,
        faults_per_layer: faults,
        inputs,
        backend,
        offload_scope: OffloadScope::SingleTile,
        engine: TrialEngine::SiteResume,
        tile_engine: Default::default(),
        lanes: 8,
        signals: vec![],
        scenario: Default::default(),
        hardening: Default::default(),
        workers: 1,
    }
}

#[test]
fn avf_and_pvf_campaigns_complete_with_consistent_counts() {
    let model = models::quicknet(21);
    let mesh = MeshConfig::default();
    for backend in [Backend::EnforSa, Backend::SwOnly, Backend::Hdfit] {
        let r = run_campaign(&model, &mesh, &cfg(backend, 5, 2)).unwrap();
        assert_eq!(r.vuln.trials, 5 * 5 * 2, "{backend}");
        assert_eq!(
            r.vuln.trials,
            r.masked_trials + r.exposed_trials + r.vuln.critical
        );
    }
}

#[test]
fn enforsa_and_hdfit_campaigns_agree_exactly() {
    // same seed => same fault list => identical outcome counts (the
    // backends are bit-equivalent, only their cost differs)
    let model = models::quicknet(21);
    let mesh = MeshConfig::default();
    let a = run_campaign(&model, &mesh, &cfg(Backend::EnforSa, 6, 2)).unwrap();
    let b = run_campaign(&model, &mesh, &cfg(Backend::Hdfit, 6, 2)).unwrap();
    assert_eq!(a.vuln.critical, b.vuln.critical);
    assert_eq!(a.exposed_trials, b.exposed_trials);
    assert_eq!(a.masked_trials, b.masked_trials);
}

#[test]
fn pvf_exceeds_avf_on_aggregate() {
    // Table VI's headline observation: SW-only injection (flips in
    // visible tensors, no HW masking) is systematically pessimistic
    // vs RTL-level injection. Use enough trials to see the gap.
    let model = models::quicknet(21);
    let mesh = MeshConfig::default();
    let avf = run_campaign(&model, &mesh, &cfg(Backend::EnforSa, 40, 3)).unwrap();
    let pvf = run_campaign(&model, &mesh, &cfg(Backend::SwOnly, 40, 3)).unwrap();
    assert!(
        pvf.vf() > avf.vf(),
        "PVF {:.4} must exceed AVF {:.4}",
        pvf.vf(),
        avf.vf()
    );
}

#[test]
fn rtl_campaign_has_hw_masked_trials() {
    // a large share of RTL faults must be masked inside the array — the
    // effect SW-only injection cannot see at all
    let model = models::quicknet(21);
    let mesh = MeshConfig::default();
    let r = run_campaign(&model, &mesh, &cfg(Backend::EnforSa, 40, 2)).unwrap();
    assert!(
        r.masked_trials > r.vuln.trials / 10,
        "expected substantial HW masking, got {}/{}",
        r.masked_trials,
        r.vuln.trials
    );
}

#[test]
fn layer_offload_ablation_matches_single_tile() {
    // D3: offloading the whole layer to RTL must give the same
    // *outcomes* as single-tile offload (same fault, same math),
    // it is just slower — which is exactly the paper's argument.
    let model = models::quicknet(21);
    let mesh = MeshConfig::default();
    let mut c1 = cfg(Backend::EnforSa, 4, 1);
    let mut c2 = c1.clone();
    c1.offload_scope = OffloadScope::SingleTile;
    c2.offload_scope = OffloadScope::Layer;
    let a = run_campaign(&model, &mesh, &c1).unwrap();
    let b = run_campaign(&model, &mesh, &c2).unwrap();
    assert_eq!(a.vuln.critical, b.vuln.critical);
    assert_eq!(a.exposed_trials, b.exposed_trials);
    assert!(b.wall >= a.wall, "layer offload should not be faster");
}

#[test]
fn control_signal_restriction_changes_only_sampling() {
    let model = models::quicknet(21);
    let mesh = MeshConfig::default();
    let mut c = cfg(Backend::EnforSa, 10, 1);
    c.signals = vec!["propag".into()];
    let r = run_campaign(&model, &mesh, &c).unwrap();
    assert_eq!(r.vuln.trials, 10 * 5);
}

#[test]
fn ws_dataflow_campaign_runs() {
    let model = models::quicknet(21);
    let mesh = MeshConfig {
        dim: 8,
        dataflow: enfor_sa::config::Dataflow::WeightStationary,
    };
    // WS tiles require K == DIM streams; the runner pads operands, so
    // only DIM-compatible sites offload cleanly. Keep it small.
    let mut c = cfg(Backend::EnforSa, 2, 1);
    c.signals = vec!["acc".into()];
    // the WS driver streams M rows; quicknet sites have k != dim, so the
    // runner's OS tiling is the supported path — assert it still runs by
    // using the OS mesh for WS-marked config only when dims align.
    // (WS end-to-end offload is exercised at the driver level in
    // integration_mesh; here we only require no panic on OS fallback.)
    let r = run_campaign(&model, &MeshConfig::default(), &c).unwrap();
    let _ = mesh;
    assert!(r.vuln.trials > 0);
}

#[test]
fn exposure_map_has_full_coverage() {
    // per-element accounting: 10 trials x 16 output elements per cell
    let map = weight_exposure_map(4, 8, 10, 0xAB);
    for r in 0..4 {
        for c in 0..4 {
            assert_eq!(map.cells[r * 4 + c].trials, 10 * 16);
        }
    }
}
