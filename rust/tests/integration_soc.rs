//! Full-SoC integration: functional equivalence with the mesh-only
//! wrapper (including under faults — the controller reproduces the
//! MatmulDriver schedule exactly) and the cost structure behind Table V.

use enfor_sa::config::Dataflow;
use enfor_sa::mesh::driver::{gold_matmul, os_matmul_cycles, MatmulDriver};
use enfor_sa::mesh::{Fault, Mesh, MeshSim, SignalKind};
use enfor_sa::soc::Soc;
use enfor_sa::util::Rng;

#[test]
fn soc_matmul_fuzz_matches_gold() {
    let mut rng = Rng::new(0x50C1);
    for rep in 0..8 {
        let dim = [2usize, 4][rep % 2];
        let k = 1 + rng.usize_below(12);
        let a = rng.mat_i8(dim, k);
        let b = rng.mat_i8(k, dim);
        let d = rng.mat_i32(dim, dim, 500);
        let mut soc = Soc::new(dim);
        let c = soc.run_matmul(&a, &b, &d, None).unwrap();
        assert_eq!(c, gold_matmul(&a, &b, &d), "dim={dim} k={k}");
    }
}

#[test]
fn soc_and_mesh_agree_on_identical_faults() {
    // The key cross-backend contract: a fault at mesh-relative cycle t
    // produces the same faulty C whether the mesh is driven by the
    // isolated wrapper or by the full SoC's execute FSM.
    let mut rng = Rng::new(0x50C2);
    let dim = 4;
    let k = 6;
    let a = rng.mat_i8(dim, k);
    let b = rng.mat_i8(k, dim);
    let d = rng.mat_i32(dim, dim, 100);
    for kind in SignalKind::ALL {
        for cycle in [1u64, 9, 15, os_matmul_cycles(dim, k) - 2] {
            let fault = Fault::new(1, 2, kind, 0, cycle);
            let mut mesh = Mesh::new(dim, Dataflow::OutputStationary);
            let c_mesh = MatmulDriver::new(&mut mesh).matmul_with_fault(&a, &b, &d, &fault);
            let mut soc = Soc::new(dim);
            let c_soc = soc.run_matmul(&a, &b, &d, Some(fault)).unwrap();
            assert_eq!(c_mesh, c_soc, "{fault} diverged between backends");
        }
    }
}

#[test]
fn soc_reuse_across_matmuls_is_clean() {
    let mut rng = Rng::new(0x50C3);
    let dim = 4;
    let mut soc = Soc::new(dim);
    let a = rng.mat_i8(dim, dim);
    let b = rng.mat_i8(dim, dim);
    let d = rng.mat_i32(dim, dim, 100);
    let c1 = soc.run_matmul(&a, &b, &d, None).unwrap();
    // a faulty run in between must not poison later runs
    let f = Fault::new(0, 0, SignalKind::Acc, 25, 10);
    let _ = soc.run_matmul(&a, &b, &d, Some(f)).unwrap();
    let c2 = soc.run_matmul(&a, &b, &d, None).unwrap();
    assert_eq!(c1, c2);
}

#[test]
fn soc_cycles_scale_beyond_mesh_cycles() {
    let dim = 4;
    let k = 8;
    let mut rng = Rng::new(0x50C4);
    let a = rng.mat_i8(dim, k);
    let b = rng.mat_i8(k, dim);
    let d = rng.mat_i32(dim, dim, 10);
    let mut soc = Soc::new(dim);
    soc.run_matmul(&a, &b, &d, None).unwrap();
    let mesh_cycles = os_matmul_cycles(dim, k);
    assert!(
        soc.cycles > 2 * mesh_cycles,
        "SoC used {} cycles vs mesh-only {}",
        soc.cycles,
        mesh_cycles
    );
    // DMA actually moved both operand matrices
    assert_eq!(soc.dma.rows_moved as usize, 2 * k);
}

#[test]
fn state_ratio_shrinks_with_dim() {
    // Table V's trend: mesh state grows quadratically, the uncore is
    // fixed, so the SoC/mesh ratio must fall monotonically with DIM.
    let mut prev = f64::INFINITY;
    for dim in [4usize, 8, 16, 32, 64] {
        let soc = Soc::new(dim);
        let mesh = Mesh::new(dim, Dataflow::OutputStationary);
        let ratio = soc.state_elements() as f64 / mesh.state_elements() as f64;
        assert!(ratio < prev, "ratio not decreasing at DIM{dim}");
        assert!(ratio > 1.0);
        prev = ratio;
    }
}

#[test]
fn icache_warms_up() {
    let dim = 2;
    let mut rng = Rng::new(0x50C5);
    let a = rng.mat_i8(dim, dim);
    let b = rng.mat_i8(dim, dim);
    let d = rng.mat_i32(dim, dim, 10);
    let mut soc = Soc::new(dim);
    soc.run_matmul(&a, &b, &d, None).unwrap();
    assert!(soc.icache.hits > soc.icache.misses, "icache must mostly hit");
}
