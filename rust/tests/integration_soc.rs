//! Full-SoC integration: functional equivalence with the mesh-only
//! wrapper (including under faults — the controller reproduces the
//! MatmulDriver schedule exactly) and the cost structure behind Table V.

use enfor_sa::config::Dataflow;
use enfor_sa::mesh::driver::{gold_matmul, os_matmul_cycles, ws_matmul_cycles, MatmulDriver};
use enfor_sa::mesh::{Fault, FaultPlan, Mesh, MeshSim, SignalKind};
use enfor_sa::soc::Soc;
use enfor_sa::util::Rng;

#[test]
fn soc_matmul_fuzz_matches_gold() {
    let mut rng = Rng::new(0x50C1);
    for rep in 0..8 {
        let dim = [2usize, 4][rep % 2];
        let k = 1 + rng.usize_below(12);
        let a = rng.mat_i8(dim, k);
        let b = rng.mat_i8(k, dim);
        let d = rng.mat_i32(dim, dim, 500);
        let mut soc = Soc::new(dim);
        let c = soc.run_matmul(a.view(), b.view(), d.view(), &FaultPlan::empty()).unwrap();
        assert_eq!(c, gold_matmul(a.view(), b.view(), d.view()), "dim={dim} k={k}");
    }
}

#[test]
fn soc_and_mesh_agree_on_identical_faults() {
    // The key cross-backend contract: a fault at mesh-relative cycle t
    // produces the same faulty C whether the mesh is driven by the
    // isolated wrapper or by the full SoC's execute FSM.
    let mut rng = Rng::new(0x50C2);
    let dim = 4;
    let k = 6;
    let a = rng.mat_i8(dim, k);
    let b = rng.mat_i8(k, dim);
    let d = rng.mat_i32(dim, dim, 100);
    for kind in SignalKind::ALL {
        for cycle in [1u64, 9, 15, os_matmul_cycles(dim, k) - 2] {
            let fault = Fault::new(1, 2, kind, 0, cycle);
            let mut mesh = Mesh::new(dim, Dataflow::OutputStationary);
            let c_mesh = MatmulDriver::new(&mut mesh)
                .matmul_with_fault(a.view(), b.view(), d.view(), &fault);
            let mut soc = Soc::new(dim);
            let c_soc = soc
                .run_matmul(a.view(), b.view(), d.view(), &FaultPlan::single(fault))
                .unwrap();
            assert_eq!(c_mesh, c_soc, "{fault} diverged between backends");
        }
    }
}

#[test]
fn soc_and_mesh_agree_on_multi_fault_plans() {
    // the scenario seam crosses the SoC boundary too: burst, MBU and
    // stuck-at plans must corrupt identically on both backends
    let mut rng = Rng::new(0x50C7);
    let dim = 4;
    let k = 6;
    let a = rng.mat_i8(dim, k);
    let b = rng.mat_i8(k, dim);
    let d = rng.mat_i32(dim, dim, 100);
    let plans = vec![
        // burst: same-cycle propag flips down one column
        FaultPlan::new(
            (0..dim)
                .map(|r| Fault::new(r, 1, SignalKind::Propag, 0, 9))
                .collect(),
        ),
        // MBU: two adjacent Acc bits of one PE, same cycle
        FaultPlan::new(vec![
            Fault::new(1, 2, SignalKind::Acc, 3, 9),
            Fault::new(1, 2, SignalKind::Acc, 4, 9),
        ]),
        // double SEU: independent space/time draws
        FaultPlan::new(vec![
            Fault::new(0, 0, SignalKind::Weight, 5, 8),
            Fault::new(3, 3, SignalKind::Act, 2, 12),
        ]),
        // stuck-at forcing from mid-preload onward
        FaultPlan::single(Fault::stuck_at(0, 0, SignalKind::Weight, 2, true, 3)),
    ];
    for plan in &plans {
        let mut mesh = Mesh::new(dim, Dataflow::OutputStationary);
        let c_mesh =
            MatmulDriver::new(&mut mesh).matmul_with_plan(a.view(), b.view(), d.view(), plan);
        let mut soc = Soc::new(dim);
        let c_soc = soc.run_matmul(a.view(), b.view(), d.view(), plan).unwrap();
        assert_eq!(c_mesh, c_soc, "plan [{plan}] diverged between backends");
    }
}

#[test]
fn soc_ws_and_mesh_agree_on_identical_faults() {
    // the WS mirror of the cross-backend contract: the controller's WS
    // window replays the MatmulDriver's weight-stationary schedule
    // cycle-for-cycle, so identical faults corrupt identically
    let mut rng = Rng::new(0x50C8);
    let dim = 4;
    let m = 6;
    let a = rng.mat_i8(m, dim);
    let w = rng.mat_i8(dim, dim);
    let d = rng.mat_i32(m, dim, 100);
    for kind in SignalKind::ALL {
        for cycle in [1u64, 7, ws_matmul_cycles(dim, m) - 2] {
            let fault = Fault::new(1, 2, kind, 0, cycle);
            let mut mesh = Mesh::new(dim, Dataflow::WeightStationary);
            let c_mesh = MatmulDriver::new(&mut mesh)
                .matmul_with_fault(a.view(), w.view(), d.view(), &fault);
            let mut soc = Soc::with_dataflow(dim, Dataflow::WeightStationary);
            let c_soc = soc
                .run_matmul(a.view(), w.view(), d.view(), &FaultPlan::single(fault))
                .unwrap();
            assert_eq!(c_mesh, c_soc, "ws {fault} diverged between backends");
        }
    }
}

#[test]
fn soc_ws_and_mesh_agree_on_multi_fault_plans() {
    let mut rng = Rng::new(0x50C9);
    let dim = 4;
    let m = 7;
    let a = rng.mat_i8(m, dim);
    let w = rng.mat_i8(dim, dim);
    let d = rng.mat_i32(m, dim, 100);
    let plans = vec![
        FaultPlan::new(
            (0..dim)
                .map(|r| Fault::new(r, 1, SignalKind::Propag, 0, 6))
                .collect(),
        ),
        FaultPlan::new(vec![
            Fault::new(1, 2, SignalKind::Acc, 3, 6),
            Fault::new(1, 2, SignalKind::Acc, 4, 6),
        ]),
        FaultPlan::new(vec![
            Fault::new(0, 0, SignalKind::Weight, 5, 2),
            Fault::new(3, 3, SignalKind::Act, 2, 10),
        ]),
        FaultPlan::single(Fault::stuck_at(0, 0, SignalKind::Weight, 2, true, 3)),
    ];
    for plan in &plans {
        let mut mesh = Mesh::new(dim, Dataflow::WeightStationary);
        let c_mesh =
            MatmulDriver::new(&mut mesh).matmul_with_plan(a.view(), w.view(), d.view(), plan);
        let mut soc = Soc::with_dataflow(dim, Dataflow::WeightStationary);
        let c_soc = soc.run_matmul(a.view(), w.view(), d.view(), plan).unwrap();
        assert_eq!(c_mesh, c_soc, "ws plan [{plan}] diverged between backends");
    }
}

#[test]
fn soc_reuse_across_matmuls_is_clean() {
    let mut rng = Rng::new(0x50C3);
    let dim = 4;
    let mut soc = Soc::new(dim);
    let a = rng.mat_i8(dim, dim);
    let b = rng.mat_i8(dim, dim);
    let d = rng.mat_i32(dim, dim, 100);
    let c1 = soc.run_matmul(a.view(), b.view(), d.view(), &FaultPlan::empty()).unwrap();
    // a faulty run in between must not poison later runs
    let f = Fault::new(0, 0, SignalKind::Acc, 25, 10);
    let _ = soc.run_matmul(a.view(), b.view(), d.view(), &FaultPlan::single(f)).unwrap();
    let c2 = soc.run_matmul(a.view(), b.view(), d.view(), &FaultPlan::empty()).unwrap();
    assert_eq!(c1, c2);
}

#[test]
fn soc_accepts_zero_padded_window_operands() {
    // the campaign hands the SoC zero-copy padded windows; they must
    // behave exactly like materialized padded tiles
    let mut rng = Rng::new(0x50C6);
    let dim = 4;
    let k = 5;
    let a_small = rng.mat_i8(3, k); // fewer rows than DIM
    let b = rng.mat_i8(k, dim);
    let d_small = rng.mat_i32(3, dim, 100);
    let a_win = a_small.window(0, 0, dim, k);
    let d_win = d_small.window(0, 0, dim, dim);
    let mut soc = Soc::new(dim);
    let c = soc.run_matmul(a_win, b.view(), d_win, &FaultPlan::empty()).unwrap();
    let (am, dm) = (a_win.to_mat(), d_win.to_mat());
    assert_eq!(c, gold_matmul(am.view(), b.view(), dm.view()));
}

#[test]
fn soc_cycles_scale_beyond_mesh_cycles() {
    let dim = 4;
    let k = 8;
    let mut rng = Rng::new(0x50C4);
    let a = rng.mat_i8(dim, k);
    let b = rng.mat_i8(k, dim);
    let d = rng.mat_i32(dim, dim, 10);
    let mut soc = Soc::new(dim);
    soc.run_matmul(a.view(), b.view(), d.view(), &FaultPlan::empty()).unwrap();
    let mesh_cycles = os_matmul_cycles(dim, k);
    assert!(
        soc.cycles > 2 * mesh_cycles,
        "SoC used {} cycles vs mesh-only {}",
        soc.cycles,
        mesh_cycles
    );
    // DMA actually moved both operand matrices
    assert_eq!(soc.dma.rows_moved as usize, 2 * k);
}

#[test]
fn state_ratio_shrinks_with_dim() {
    // Table V's trend: mesh state grows quadratically, the uncore is
    // fixed, so the SoC/mesh ratio must fall monotonically with DIM.
    let mut prev = f64::INFINITY;
    for dim in [4usize, 8, 16, 32, 64] {
        let soc = Soc::new(dim);
        let mesh = Mesh::new(dim, Dataflow::OutputStationary);
        let ratio = soc.state_elements() as f64 / mesh.state_elements() as f64;
        assert!(ratio < prev, "ratio not decreasing at DIM{dim}");
        assert!(ratio > 1.0);
        prev = ratio;
    }
}

#[test]
fn icache_warms_up() {
    let dim = 2;
    let mut rng = Rng::new(0x50C5);
    let a = rng.mat_i8(dim, dim);
    let b = rng.mat_i8(dim, dim);
    let d = rng.mat_i32(dim, dim, 10);
    let mut soc = Soc::new(dim);
    soc.run_matmul(a.view(), b.view(), d.view(), &FaultPlan::empty()).unwrap();
    assert!(soc.icache.hits > soc.icache.misses, "icache must mostly hit");
}
