//! Property tests for the flat-matrix layer (`enfor_sa::mat`): the
//! stride-aware, zero-padded `MatView` must agree exactly with the old
//! nested-matrix (`Vec<Vec<T>>`) tile extraction it replaced, for random
//! shapes, offsets and out-of-bounds overhang.
//!
//! The offline environment has no proptest crate, so properties are
//! checked over seeded random sweeps with the crate's deterministic RNG;
//! each case asserts with enough context to reproduce directly.

use enfor_sa::mat::{Mat, MatView, MatViewMut};
use enfor_sa::util::Rng;

/// The nested-matrix extraction the `mesh`/`campaign` layers used before
/// the flat refactor: window `(r0, c0, rows, cols)` of `src`, zero-padded
/// outside the parent bounds.
fn nested_extract(src: &[Vec<i32>], r0: usize, c0: usize, rows: usize, cols: usize) -> Vec<Vec<i32>> {
    (0..rows)
        .map(|r| {
            (0..cols)
                .map(|c| {
                    src.get(r0 + r)
                        .and_then(|row| row.get(c0 + c))
                        .copied()
                        .unwrap_or(0)
                })
                .collect()
        })
        .collect()
}

fn random_parent(rng: &mut Rng, rows: usize, cols: usize) -> (Mat<i32>, Vec<Vec<i32>>) {
    let flat = rng.mat_i32(rows, cols, 1 << 20);
    let nested: Vec<Vec<i32>> = (0..rows).map(|r| flat.row(r).to_vec()).collect();
    (flat, nested)
}

#[test]
fn prop_window_matches_nested_extraction() {
    let mut rng = Rng::new(0x3A7_001);
    for case in 0..500 {
        let rows = 1 + rng.usize_below(24);
        let cols = 1 + rng.usize_below(24);
        let (flat, nested) = random_parent(&mut rng, rows, cols);
        // offsets beyond the parent and window sizes with overhang
        let r0 = rng.usize_below(rows + 6);
        let c0 = rng.usize_below(cols + 6);
        let wr = 1 + rng.usize_below(16);
        let wc = 1 + rng.usize_below(16);
        let want = nested_extract(&nested, r0, c0, wr, wc);
        let view = flat.window(r0, c0, wr, wc);
        assert_eq!((view.rows(), view.cols()), (wr, wc));
        for r in 0..wr {
            for c in 0..wc {
                assert_eq!(
                    view.at(r, c),
                    want[r][c],
                    "case {case}: parent {rows}x{cols}, window {wr}x{wc} at ({r0},{c0}), cell ({r},{c})"
                );
            }
        }
        // materialization agrees cell-for-cell too
        let mat = view.to_mat();
        for r in 0..wr {
            assert_eq!(mat.row(r), &want[r][..], "case {case} row {r}");
        }
    }
}

#[test]
fn prop_subview_composes_like_double_extraction() {
    // sub() of a window must equal extracting from the already-padded
    // nested extraction — padding composes.
    let mut rng = Rng::new(0x3A7_002);
    for case in 0..300 {
        let rows = 1 + rng.usize_below(16);
        let cols = 1 + rng.usize_below(16);
        let (flat, nested) = random_parent(&mut rng, rows, cols);
        let r0 = rng.usize_below(rows + 3);
        let c0 = rng.usize_below(cols + 3);
        let (wr, wc) = (1 + rng.usize_below(12), 1 + rng.usize_below(12));
        let r1 = rng.usize_below(wr + 2);
        let c1 = rng.usize_below(wc + 2);
        let (sr, sc) = (1 + rng.usize_below(8), 1 + rng.usize_below(8));

        let outer_nested = nested_extract(&nested, r0, c0, wr, wc);
        let want = nested_extract(&outer_nested, r1, c1, sr, sc);

        let sub = flat.window(r0, c0, wr, wc).sub(r1, c1, sr, sc);
        for r in 0..sr {
            for c in 0..sc {
                assert_eq!(
                    sub.at(r, c),
                    want[r][c],
                    "case {case}: sub ({sr}x{sc})@({r1},{c1}) of window ({wr}x{wc})@({r0},{c0})"
                );
            }
        }
    }
}

#[test]
fn prop_full_view_of_flat_slice_matches_mat() {
    let mut rng = Rng::new(0x3A7_003);
    for _ in 0..100 {
        let rows = 1 + rng.usize_below(12);
        let cols = 1 + rng.usize_below(12);
        let m = rng.mat_i32(rows, cols, 1000);
        // viewing the raw flat buffer reproduces the owning matrix
        let v = MatView::full(m.data(), rows, cols);
        for r in 0..rows {
            for c in 0..cols {
                assert_eq!(v.at(r, c), m[(r, c)]);
            }
        }
    }
}

#[test]
fn prop_splice_is_inverse_of_window_read() {
    // writing a tile through MatViewMut then reading it back through a
    // window returns the in-bounds part of the tile unchanged, and
    // leaves every cell outside the window untouched.
    let mut rng = Rng::new(0x3A7_004);
    for case in 0..300 {
        let rows = 1 + rng.usize_below(16);
        let cols = 1 + rng.usize_below(16);
        let mut dst = rng.mat_i32(rows, cols, 1000);
        let before = dst.clone();
        let r0 = rng.usize_below(rows + 3);
        let c0 = rng.usize_below(cols + 3);
        let t = 1 + rng.usize_below(8);
        let tile = rng.mat_i32(t, t, 1000);

        dst.window_mut(r0, c0, t, t).splice_from(&tile);

        for r in 0..rows {
            for c in 0..cols {
                let inside =
                    r >= r0 && r < r0 + t && c >= c0 && c < c0 + t;
                let want = if inside {
                    tile[(r - r0, c - c0)]
                } else {
                    before[(r, c)]
                };
                assert_eq!(dst[(r, c)], want, "case {case}: cell ({r},{c})");
            }
        }
    }
}

#[test]
fn prop_splice_change_flag_detects_exposure() {
    // the campaign runner uses the splice return value as its
    // fault-exposed signal: true iff an in-bounds cell changed
    let mut rng = Rng::new(0x3A7_005);
    for _ in 0..200 {
        let n = 2 + rng.usize_below(10);
        let dst = rng.mat_i32(n, n, 1000);
        let r0 = rng.usize_below(n);
        let c0 = rng.usize_below(n);
        let t = 1 + rng.usize_below(6);

        // splicing back exactly what the window reads: no change
        let same = dst.window(r0, c0, t, t).to_mat();
        let mut d1 = dst.clone();
        assert!(!d1.window_mut(r0, c0, t, t).splice_from(&same));

        // flip one in-bounds cell: change must be reported
        let mut tile = same.clone();
        tile[(0, 0)] ^= 1; // (r0, c0) is always in bounds here
        let mut d2 = dst.clone();
        assert!(d2.window_mut(r0, c0, t, t).splice_from(&tile));
        assert_eq!(d2[(r0, c0)], dst[(r0, c0)] ^ 1);
    }
}

#[test]
fn prop_mutable_window_fully_outside_is_noop() {
    let mut rng = Rng::new(0x3A7_006);
    let mut m = rng.mat_i32(4, 4, 100);
    let before = m.clone();
    let tile = rng.mat_i32(3, 3, 100);
    let changed = MatViewMut::window(m.data_mut(), 4, 4, 4, 9, 9, 3, 3).splice_from(&tile);
    assert!(!changed);
    assert_eq!(m, before);
}
