//! Property tests pinning the lane-lockstep tile engine against the
//! cycle-resume and full oracles.
//!
//! Contracts (ROADMAP "Trial-lockstep lane-batched mesh stepping"):
//! 1. Fixed-seed campaigns are bit-identical across `--tile-engine
//!    full | cycle-resume | lane-lockstep` for ANY lane count, on both
//!    dataflows, under every fault scenario, and across worker
//!    shardings.
//! 2. Lockstep steps strictly fewer total RTL cycles than cycle-resume
//!    once trials pigeonhole onto shared tiles (each lockstep mesh step
//!    counts once per cycle, not per lane), and `lanes = 1` degenerates
//!    to cycle-resume exactly — cycle counts included.
//! 3. Backends without lane support degrade through the gate chain:
//!    HDFIT and the whole-SoC backend both fall back to cycle-resume
//!    (one persistent chip cannot carry N lanes, but its controller is
//!    schedule-indexable) — bit- and cycle-identical to the engine
//!    they fall back to.
//! 4. (ROADMAP "Cross-tile lane packing") `packed-lockstep` is
//!    bit-identical to all three other engines at any lane count,
//!    scenario, dataflow and worker sharding; it never steps MORE
//!    cycles than lane-lockstep, steps STRICTLY fewer once low
//!    faults-per-layer scatter trials across tiles (cross-tile chunks
//!    pay max(span) instead of sum(span)), and degenerates to
//!    cycle-resume cycle-exactly when every chunk is one trial.

use enfor_sa::campaign::{run_campaign, CampaignResult};
use enfor_sa::config::{
    Backend, CampaignConfig, Dataflow, MeshConfig, OffloadScope, Scenario, TileEngine,
    TrialEngine,
};
use enfor_sa::coordinator::run_parallel;
use enfor_sa::dnn::models;

fn cfg(backend: Backend, tile_engine: TileEngine, lanes: usize) -> CampaignConfig {
    CampaignConfig {
        seed: 0x10C_57E9,
        faults_per_layer: 4,
        inputs: 1,
        backend,
        offload_scope: OffloadScope::SingleTile,
        engine: TrialEngine::SiteResume,
        tile_engine,
        lanes,
        signals: vec![],
        scenario: Default::default(),
        hardening: Default::default(),
        workers: 1,
    }
}

fn mesh_cfg(dataflow: Dataflow) -> MeshConfig {
    MeshConfig { dataflow, ..Default::default() }
}

const SCENARIOS: [Scenario; 5] = [
    Scenario::Seu,
    Scenario::Mbu { bits: 2 },
    Scenario::Burst { radius: 1 },
    Scenario::DoubleSeu,
    Scenario::StuckAt { value: true },
];

const DATAFLOWS: [Dataflow; 2] = [Dataflow::OutputStationary, Dataflow::WeightStationary];

fn assert_bit_identical(a: &CampaignResult, b: &CampaignResult, label: &str) {
    assert_eq!(a.vuln.trials, b.vuln.trials, "{label}: trials");
    assert_eq!(a.vuln.critical, b.vuln.critical, "{label}: critical");
    assert_eq!(a.exposed_trials, b.exposed_trials, "{label}: exposed");
    assert_eq!(a.masked_trials, b.masked_trials, "{label}: masked");
    assert_eq!(a.per_layer.len(), b.per_layer.len(), "{label}: layer map size");
    for ((la, va), (lb, vb)) in a.per_layer.iter().zip(b.per_layer.iter()) {
        assert_eq!(la, lb, "{label}: layer ids");
        assert_eq!(va.trials, vb.trials, "{label}: layer {la} trials");
        assert_eq!(va.critical, vb.critical, "{label}: layer {la} critical");
    }
}

/// Contract 1: the engine triple agrees bit-exactly for every scenario,
/// dataflow and lane count — lockstep is an optimization, never a
/// semantic change.
#[test]
fn prop_lockstep_matches_oracles_for_every_scenario_dataflow_and_lane_count() {
    let model = models::quicknet(5);
    for dataflow in DATAFLOWS {
        let mc = mesh_cfg(dataflow);
        for scenario in SCENARIOS {
            let mut full = cfg(Backend::EnforSa, TileEngine::Full, 8);
            full.scenario = scenario;
            let oracle = run_campaign(&model, &mc, &full).unwrap();
            let mut resume = full.clone();
            resume.tile_engine = TileEngine::CycleResume;
            let r = run_campaign(&model, &mc, &resume).unwrap();
            assert_bit_identical(&oracle, &r, &format!("{dataflow}/{scenario}/cycle-resume"));
            for lanes in [1usize, 2, 7, 8] {
                let mut lock = full.clone();
                lock.tile_engine = TileEngine::LaneLockstep;
                lock.lanes = lanes;
                let l = run_campaign(&model, &mc, &lock).unwrap();
                assert_bit_identical(
                    &oracle,
                    &l,
                    &format!("{dataflow}/{scenario}/lockstep lanes={lanes}"),
                );
            }
        }
    }
}

/// Contract 1 (worker axis): lockstep campaigns are worker-count
/// invariant, cycle accounting included — whole-(input, site) claims
/// keep every chunk on one executor.
#[test]
fn prop_lockstep_is_worker_count_invariant() {
    let model = models::quicknet(5);
    for dataflow in DATAFLOWS {
        let mc = mesh_cfg(dataflow);
        let mut base = cfg(Backend::EnforSa, TileEngine::LaneLockstep, 4);
        base.inputs = 2;
        let one = run_parallel(&model, &mc, &base, None).unwrap();
        for workers in [2usize, 3] {
            let mut sharded = base.clone();
            sharded.workers = workers;
            let w = run_parallel(&model, &mc, &sharded, None).unwrap();
            assert_bit_identical(&one, &w, &format!("{dataflow}/workers={workers}"));
            assert_eq!(
                one.rtl_cycles_stepped, w.rtl_cycles_stepped,
                "{dataflow}: cycle accounting must not depend on workers={workers}"
            );
        }
    }
}

/// Contract 2: the pigeonhole pin — with enough faults per layer to
/// share tiles, lockstep steps strictly fewer TOTAL mesh cycles than
/// cycle-resume (suffixes are paid per chunk, not per trial), while
/// lanes=1 reproduces cycle-resume's count exactly.
#[test]
fn prop_lockstep_steps_strictly_fewer_cycles_and_one_lane_degenerates() {
    let model = models::quicknet(5);
    for dataflow in DATAFLOWS {
        let mc = mesh_cfg(dataflow);
        let mut resume = cfg(Backend::EnforSa, TileEngine::CycleResume, 8);
        resume.faults_per_layer = 16;
        let r = run_campaign(&model, &mc, &resume).unwrap();
        let mut lock = resume.clone();
        lock.tile_engine = TileEngine::LaneLockstep;
        let l = run_campaign(&model, &mc, &lock).unwrap();
        assert_bit_identical(&r, &l, &format!("{dataflow}: counts"));
        assert!(r.rtl_cycles_stepped > 0 && l.rtl_cycles_stepped > 0);
        assert!(
            l.rtl_cycles_stepped < r.rtl_cycles_stepped,
            "{dataflow}: lockstep must step fewer RTL cycles: {} vs {}",
            l.rtl_cycles_stepped,
            r.rtl_cycles_stepped
        );
        let mut single = lock.clone();
        single.lanes = 1;
        let s = run_campaign(&model, &mc, &single).unwrap();
        assert_bit_identical(&r, &s, &format!("{dataflow}: lanes=1 counts"));
        assert_eq!(
            s.rtl_cycles_stepped, r.rtl_cycles_stepped,
            "{dataflow}: a single lane must reproduce cycle-resume's cycle count exactly"
        );
    }
}

/// Contract 4: the four-engine bit-identity matrix at sparse fault
/// budgets (`faults_per_layer` 1 and 2), where lane-lockstep's
/// same-tile chunks mostly hold ONE trial and only the cross-tile
/// packer can still batch. Packed never steps more cycles than
/// lockstep; at 2 faults/layer it must step STRICTLY fewer (some batch
/// lands its two trials on different tiles of a multi-tile site and
/// the packer merges them into one chunk); at 1 fault/layer every
/// chunk is a single trial, so all three resumable engines agree on
/// the cycle count exactly.
#[test]
fn prop_packed_batches_cross_tile_trials_at_sparse_fault_budgets() {
    let model = models::quicknet(5);
    for dataflow in DATAFLOWS {
        let mc = mesh_cfg(dataflow);
        for fpl in [1u64, 2] {
            let mut full = cfg(Backend::EnforSa, TileEngine::Full, 8);
            full.faults_per_layer = fpl;
            let oracle = run_campaign(&model, &mc, &full).unwrap();
            let mut resume = full.clone();
            resume.tile_engine = TileEngine::CycleResume;
            let r = run_campaign(&model, &mc, &resume).unwrap();
            let mut lock = full.clone();
            lock.tile_engine = TileEngine::LaneLockstep;
            let l = run_campaign(&model, &mc, &lock).unwrap();
            let mut packed = full.clone();
            packed.tile_engine = TileEngine::PackedLockstep;
            let p = run_campaign(&model, &mc, &packed).unwrap();
            for (x, label) in [(&r, "cycle-resume"), (&l, "lockstep"), (&p, "packed")] {
                assert_bit_identical(&oracle, x, &format!("{dataflow}/fpl={fpl}/{label}"));
            }
            assert!(
                p.rtl_cycles_stepped <= l.rtl_cycles_stepped,
                "{dataflow}/fpl={fpl}: packed must never step more cycles than lockstep"
            );
            if fpl == 1 {
                // single-trial chunks: every resumable engine walks the
                // same per-trial trajectory
                assert_eq!(p.rtl_cycles_stepped, r.rtl_cycles_stepped, "{dataflow}");
                assert_eq!(l.rtl_cycles_stepped, r.rtl_cycles_stepped, "{dataflow}");
            } else {
                assert!(
                    p.rtl_cycles_stepped < l.rtl_cycles_stepped,
                    "{dataflow}/fpl=2: packed must batch cross-tile trials lockstep \
                     cannot: {} vs {}",
                    p.rtl_cycles_stepped,
                    l.rtl_cycles_stepped
                );
            }
        }
    }
}

/// Contract 4 (worker axis): packed campaigns are worker-count
/// invariant, cycle and occupancy accounting included — the packing
/// domain is one (input, site) batch, which is exactly the work unit
/// the coordinator shards.
#[test]
fn prop_packed_is_worker_count_invariant() {
    let model = models::quicknet(5);
    for dataflow in DATAFLOWS {
        let mc = mesh_cfg(dataflow);
        let mut base = cfg(Backend::EnforSa, TileEngine::PackedLockstep, 4);
        base.inputs = 2;
        let one = run_parallel(&model, &mc, &base, None).unwrap();
        for workers in [2usize, 3] {
            let mut sharded = base.clone();
            sharded.workers = workers;
            let w = run_parallel(&model, &mc, &sharded, None).unwrap();
            assert_bit_identical(&one, &w, &format!("{dataflow}/packed workers={workers}"));
            assert_eq!(
                one.rtl_cycles_stepped, w.rtl_cycles_stepped,
                "{dataflow}: packed cycle accounting must not depend on workers={workers}"
            );
            assert_eq!(
                (one.lane_cycles_filled, one.lane_cycles_stepped),
                (w.lane_cycles_filled, w.lane_cycles_stepped),
                "{dataflow}: packed occupancy accounting must not depend on workers={workers}"
            );
        }
    }
}

/// Contract 4 (semantic axis): packed agrees with the full oracle for
/// every scenario, dataflow and lane count — packing is an
/// optimization, never a semantic change.
#[test]
fn prop_packed_matches_oracles_for_every_scenario_dataflow_and_lane_count() {
    let model = models::quicknet(5);
    for dataflow in DATAFLOWS {
        let mc = mesh_cfg(dataflow);
        for scenario in SCENARIOS {
            let mut full = cfg(Backend::EnforSa, TileEngine::Full, 8);
            full.scenario = scenario;
            let oracle = run_campaign(&model, &mc, &full).unwrap();
            for lanes in [1usize, 2, 7, 8] {
                let mut packed = full.clone();
                packed.tile_engine = TileEngine::PackedLockstep;
                packed.lanes = lanes;
                let p = run_campaign(&model, &mc, &packed).unwrap();
                assert_bit_identical(
                    &oracle,
                    &p,
                    &format!("{dataflow}/{scenario}/packed lanes={lanes}"),
                );
            }
        }
    }
}

/// Contract 3: HDFIT rejects lane batching (instrumentation hooks arm
/// one mesh instance) and must degrade to cycle-resume bit- and
/// cycle-identically.
#[test]
fn prop_hdfit_lockstep_degrades_to_cycle_resume() {
    let model = models::quicknet(5);
    for dataflow in DATAFLOWS {
        let mc = mesh_cfg(dataflow);
        for engine in [TileEngine::LaneLockstep, TileEngine::PackedLockstep] {
            let lock = cfg(Backend::Hdfit, engine, 8);
            let a = run_campaign(&model, &mc, &lock).unwrap();
            let resume = cfg(Backend::Hdfit, TileEngine::CycleResume, 8);
            let b = run_campaign(&model, &mc, &resume).unwrap();
            assert_bit_identical(&a, &b, &format!("{dataflow}/{engine}: hdfit fallback"));
            assert_eq!(a.rtl_cycles_stepped, b.rtl_cycles_stepped, "{dataflow}/{engine}");
        }
    }
}

/// Contract 3: the whole-SoC backend rejects lane batching (one
/// persistent chip cannot carry N lanes) and must degrade to
/// cycle-resume bit- and cycle-identically, on both dataflows.
#[test]
fn prop_full_soc_lockstep_degrades_to_cycle_resume() {
    let model = models::quicknet(5);
    for dataflow in DATAFLOWS {
        // the whole-SoC backend steps the entire chip per cycle — keep
        // the mesh small and the budget minimal, like every other SoC pin
        let mc = MeshConfig { dim: 4, dataflow };
        for engine in [TileEngine::LaneLockstep, TileEngine::PackedLockstep] {
            let mut lock = cfg(Backend::FullSoc, engine, 8);
            lock.faults_per_layer = 1;
            let a = run_campaign(&model, &mc, &lock).unwrap();
            let mut resume = cfg(Backend::FullSoc, TileEngine::CycleResume, 8);
            resume.faults_per_layer = 1;
            let b = run_campaign(&model, &mc, &resume).unwrap();
            assert_bit_identical(&a, &b, &format!("{dataflow}/{engine}: full-soc fallback"));
            assert_eq!(a.rtl_cycles_stepped, b.rtl_cycles_stepped, "{dataflow}/{engine}");
        }
    }
}
