//! Property tests pinning the site-resume trial engine against the
//! full-forward oracle.
//!
//! Two invariants:
//! 1. `forward_from(site, checkpoint)` is bit-identical to the full
//!    `forward` for every resume layer of every model topology
//!    (CNNs, residual/grouped/depthwise convs, token/attention stacks).
//! 2. Fixed-seed campaigns produce identical results — trials,
//!    critical, exposed, masked and the per-layer map — on both trial
//!    engines, across backends and offload scopes, and across worker
//!    counts (the site-major loop must preserve the coordinator's
//!    worker-count invariance).

use enfor_sa::campaign::{run_campaign, CampaignResult};
use enfor_sa::config::{Backend, CampaignConfig, MeshConfig, OffloadScope, TrialEngine};
use enfor_sa::coordinator::run_parallel;
use enfor_sa::dnn::engine::synthetic_input;
use enfor_sa::dnn::models;
use enfor_sa::util::Rng;

fn assert_bit_identical(a: &CampaignResult, b: &CampaignResult, label: &str) {
    assert_eq!(a.vuln.trials, b.vuln.trials, "{label}: trials");
    assert_eq!(a.vuln.critical, b.vuln.critical, "{label}: critical");
    assert_eq!(a.exposed_trials, b.exposed_trials, "{label}: exposed");
    assert_eq!(a.masked_trials, b.masked_trials, "{label}: masked");
    assert_eq!(a.per_layer.len(), b.per_layer.len(), "{label}: layer map size");
    for ((la, va), (lb, vb)) in a.per_layer.iter().zip(b.per_layer.iter()) {
        assert_eq!(la, lb, "{label}: layer ids");
        assert_eq!(va.trials, vb.trials, "{label}: layer {la} trials");
        assert_eq!(va.critical, vb.critical, "{label}: layer {la} critical");
    }
}

fn cfg(backend: Backend, engine: TrialEngine, scope: OffloadScope) -> CampaignConfig {
    CampaignConfig {
        seed: 0x5E5A_1E,
        faults_per_layer: 3,
        inputs: 2,
        backend,
        offload_scope: scope,
        engine,
        tile_engine: Default::default(),
        lanes: 8,
        signals: vec![],
        scenario: Default::default(),
        hardening: Default::default(),
        workers: 1,
    }
}

/// Property 1: resumed passes equal full passes for every topology in
/// the zoo's structural families and every resume layer.
#[test]
fn prop_forward_from_matches_forward_oracle() {
    let zoo: Vec<enfor_sa::dnn::Model> = vec![
        models::quicknet(11),
        models::mobilenet_v2(12), // residual + depthwise + pointwise
        models::deit_t(13),       // tokens + attention ordinals
        models::googlenet(14),    // parallel concat branches
    ];
    let mut rng = Rng::new(0xF0);
    for model in &zoo {
        let x = synthetic_input(&model.input_shape, &mut rng);
        let golden = model.forward(&x, None);
        let (logits, ckpt) = model.forward_checkpointed(&x);
        assert_eq!(logits, golden, "{}: checkpointed golden pass", model.name);
        for layer in 0..model.layers.len() {
            let resumed = model.forward_from(layer, &ckpt, None);
            assert_eq!(resumed, golden, "{}: resume at layer {layer}", model.name);
        }
    }
}

/// Property 2a: both trial engines are bit-identical across the
/// mesh-level backends and both offload scopes.
#[test]
fn prop_engines_agree_across_backends_and_scopes() {
    let model = models::quicknet(11);
    let mesh = MeshConfig::default();
    for backend in [Backend::EnforSa, Backend::Hdfit, Backend::SwOnly] {
        for scope in [OffloadScope::SingleTile, OffloadScope::Layer] {
            let resume = run_campaign(
                &model,
                &mesh,
                &cfg(backend, TrialEngine::SiteResume, scope),
            )
            .unwrap();
            let full = run_campaign(
                &model,
                &mesh,
                &cfg(backend, TrialEngine::FullForward, scope),
            )
            .unwrap();
            assert_bit_identical(&resume, &full, &format!("{backend}/{scope:?}"));
        }
    }
}

/// Property 2b: the whole-SoC backend (persistent SoC + reset between
/// trials) agrees with the full-forward oracle too. Small budget: every
/// trial drives the entire SoC model.
#[test]
fn prop_engines_agree_on_full_soc() {
    let model = models::quicknet(11);
    let mesh = MeshConfig {
        dim: 4,
        ..Default::default()
    };
    let mut base = cfg(
        Backend::FullSoc,
        TrialEngine::SiteResume,
        OffloadScope::SingleTile,
    );
    base.faults_per_layer = 1;
    base.inputs = 1;
    let resume = run_campaign(&model, &mesh, &base).unwrap();
    base.engine = TrialEngine::FullForward;
    let full = run_campaign(&model, &mesh, &base).unwrap();
    assert_eq!(resume.vuln.trials, 5);
    assert_bit_identical(&resume, &full, "full-soc");
}

/// Property 2c: the site-major (input, site)-sharded coordinator loop
/// preserves worker-count invariance on both engines, and the engines
/// agree under parallel execution as well.
#[test]
fn prop_site_major_loop_preserves_worker_invariance() {
    let model = models::quicknet(11);
    let mesh = MeshConfig::default();
    for engine in [TrialEngine::SiteResume, TrialEngine::FullForward] {
        let mut c = cfg(Backend::EnforSa, engine, OffloadScope::SingleTile);
        c.workers = 1;
        let one = run_parallel(&model, &mesh, &c, None).unwrap();
        for workers in [2usize, 4, 7] {
            c.workers = workers;
            let many = run_parallel(&model, &mesh, &c, None).unwrap();
            assert_bit_identical(&one, &many, &format!("{engine} workers={workers}"));
        }
    }
    // and across engines under max sharding
    let mut a = cfg(Backend::EnforSa, TrialEngine::SiteResume, OffloadScope::SingleTile);
    let mut b = cfg(Backend::EnforSa, TrialEngine::FullForward, OffloadScope::SingleTile);
    a.workers = 7;
    b.workers = 3;
    let ra = run_parallel(&model, &mesh, &a, None).unwrap();
    let rb = run_parallel(&model, &mesh, &b, None).unwrap();
    assert_bit_identical(&ra, &rb, "engines under parallel execution");
}
