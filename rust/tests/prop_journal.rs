//! End-to-end properties of the durable campaign journal: the ISSUE's
//! acceptance bar is that interrupted+resumed, sharded+merged and
//! straight-through campaigns produce BYTE-identical `report.json`
//! files at any worker count. The offline environment has no proptest
//! crate, so the properties are checked over fixed small campaigns
//! (quicknet: 2 inputs x 5 sites x 3 faults = 10 units, 30 trials)
//! with real campaign directories under the system temp dir.

use enfor_sa::config::{CampaignConfig, MeshConfig, Scenario};
use enfor_sa::coordinator::run_parallel;
use enfor_sa::dnn::models;
use enfor_sa::journal::{merge_dirs, read_journal, run_journaled, Shard};
use enfor_sa::report::campaign_report_json;
use std::path::PathBuf;

fn cfg() -> CampaignConfig {
    CampaignConfig {
        seed: 0x10AD,
        faults_per_layer: 3,
        inputs: 2,
        workers: 1,
        ..Default::default()
    }
}

/// Fresh scratch campaign dir, unique per (process, test-site).
fn tmpdir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "enfor-sa-prop-journal-{}-{name}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn report_bytes(dir: &PathBuf) -> String {
    std::fs::read_to_string(dir.join("report.json")).expect("report.json must exist")
}

/// The canonical report text for a complete straight-through journaled
/// run of `cfg()` — every other execution mode must reproduce it
/// byte-for-byte.
fn straight_report(name: &str) -> String {
    let model = models::quicknet(7);
    let dir = tmpdir(name);
    let cc = cfg();
    let run = run_journaled(
        &model,
        &MeshConfig::default(),
        &cc,
        &dir,
        Shard::default(),
        false,
        None,
        None,
    )
    .unwrap();
    assert!(run.completed);
    assert_eq!(run.batches_total, 10);
    let bytes = report_bytes(&dir);
    let _ = std::fs::remove_dir_all(&dir);
    bytes
}

#[test]
fn journaled_run_matches_in_memory_counts() {
    let model = models::quicknet(7);
    let cc = cfg();
    let mem = run_parallel(&model, &MeshConfig::default(), &cc, None).unwrap();
    let dir = tmpdir("counts");
    let run = run_journaled(
        &model,
        &MeshConfig::default(),
        &cc,
        &dir,
        Shard::default(),
        false,
        None,
        None,
    )
    .unwrap();
    assert!(run.completed);
    let r = &run.result;
    assert_eq!(mem.vuln.trials, r.vuln.trials);
    assert_eq!(mem.vuln.critical, r.vuln.critical);
    assert_eq!(mem.exposed_trials, r.exposed_trials);
    assert_eq!(mem.masked_trials, r.masked_trials);
    assert_eq!(mem.rtl_cycles_stepped, r.rtl_cycles_stepped);
    let keys = |m: &std::collections::BTreeMap<usize, enfor_sa::util::stats::VulnEstimate>| {
        m.iter().map(|(k, v)| (*k, v.trials, v.critical)).collect::<Vec<_>>()
    };
    assert_eq!(keys(&mem.per_layer), keys(&r.per_layer));
    // the journal holds exactly one line per (input, site) unit
    let scan = read_journal(&dir.join("journal.jsonl")).unwrap();
    assert!(!scan.torn);
    assert_eq!(scan.records.len(), 10);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn prop_kill_resume_is_bit_identical() {
    let baseline = straight_report("kill-baseline");
    let model = models::quicknet(7);
    // kill after 0, 1, 4 or 9 of the 10 batches, then resume — at a
    // DIFFERENT worker count than the first leg ran with
    for (cap, resume_workers) in [(0u64, 1usize), (1, 3), (4, 2), (9, 3)] {
        let dir = tmpdir(&format!("kill-{cap}"));
        let cc = cfg();
        let first = run_journaled(
            &model,
            &MeshConfig::default(),
            &cc,
            &dir,
            Shard::default(),
            false,
            Some(cap),
            None,
        )
        .unwrap();
        assert!(!first.completed, "cap {cap} must leave work pending");
        assert_eq!(first.batches_run, cap);
        assert!(!dir.join("report.json").exists(), "no partial reports");
        let mut resumed_cc = cfg();
        resumed_cc.workers = resume_workers;
        let second = run_journaled(
            &model,
            &MeshConfig::default(),
            &resumed_cc,
            &dir,
            Shard::default(),
            true,
            None,
            None,
        )
        .unwrap();
        assert!(second.completed);
        assert_eq!(second.batches_skipped, cap);
        assert_eq!(second.batches_run, 10 - cap);
        assert_eq!(report_bytes(&dir), baseline, "cap {cap} diverged");
        let _ = std::fs::remove_dir_all(&dir);
    }
}

#[test]
fn prop_shard_merge_is_bit_identical() {
    let baseline = straight_report("shard-baseline");
    let model = models::quicknet(7);
    let cc = cfg();
    let dirs: Vec<PathBuf> = (0..2).map(|i| tmpdir(&format!("shard-{i}"))).collect();
    for (i, dir) in dirs.iter().enumerate() {
        let mut shard_cc = cfg();
        shard_cc.workers = i + 1; // shards may run at different widths
        let shard = Shard { index: i as u64, count: 2 };
        let run = run_journaled(
            &model,
            &MeshConfig::default(),
            &shard_cc,
            dir,
            shard,
            false,
            None,
            None,
        )
        .unwrap();
        assert!(run.completed);
        assert_eq!(run.batches_total, 5, "each 1/2 shard owns 5 of 10 units");
    }
    let merged = merge_dirs(&[dirs[0].as_path(), dirs[1].as_path()]).unwrap();
    assert_eq!(merged.batches, 10);
    let text =
        campaign_report_json(&merged.result, cc.tile_engine, cc.lanes).pretty() + "\n";
    assert_eq!(text, baseline, "merged shards diverged from straight run");
    // giving the same shard twice is not a partition
    let e = merge_dirs(&[dirs[0].as_path(), dirs[0].as_path()])
        .unwrap_err()
        .to_string();
    assert!(e.contains("do not partition"), "{e}");
    // a single complete 1/1 dir merges to the same bytes too
    let whole = tmpdir("shard-whole");
    run_journaled(
        &model,
        &MeshConfig::default(),
        &cc,
        &whole,
        Shard::default(),
        false,
        None,
        None,
    )
    .unwrap();
    let solo = merge_dirs(&[whole.as_path()]).unwrap();
    let text = campaign_report_json(&solo.result, cc.tile_engine, cc.lanes).pretty() + "\n";
    assert_eq!(text, baseline);
    for dir in dirs.iter().chain([&whole]) {
        let _ = std::fs::remove_dir_all(dir);
    }
}

#[test]
fn merge_refuses_incomplete_shards() {
    let model = models::quicknet(7);
    let done = tmpdir("inc-done");
    let partial = tmpdir("inc-partial");
    for (dir, shard, cap) in [
        (&done, Shard { index: 0, count: 2 }, None),
        (&partial, Shard { index: 1, count: 2 }, Some(2)),
    ] {
        run_journaled(
            &model,
            &MeshConfig::default(),
            &cfg(),
            dir,
            shard,
            false,
            cap,
            None,
        )
        .unwrap();
    }
    let e = merge_dirs(&[done.as_path(), partial.as_path()])
        .unwrap_err()
        .to_string();
    assert!(e.contains("incomplete"), "{e}");
    assert!(e.contains("resume it first"), "{e}");
    for dir in [&done, &partial] {
        let _ = std::fs::remove_dir_all(dir);
    }
}

#[test]
fn prop_torn_tail_is_repaired_on_resume() {
    let baseline = straight_report("torn-baseline");
    let model = models::quicknet(7);
    let dir = tmpdir("torn");
    run_journaled(
        &model,
        &MeshConfig::default(),
        &cfg(),
        &dir,
        Shard::default(),
        false,
        Some(3),
        None,
    )
    .unwrap();
    // tear the final journal line mid-record, as a crash during the
    // un-synced tail write would
    let journal = dir.join("journal.jsonl");
    let len = std::fs::metadata(&journal).unwrap().len();
    let f = std::fs::OpenOptions::new().write(true).open(&journal).unwrap();
    f.set_len(len - 7).unwrap();
    drop(f);
    let scan = read_journal(&journal).unwrap();
    assert!(scan.torn);
    assert_eq!(scan.records.len(), 2, "only the intact prefix survives");
    let run = run_journaled(
        &model,
        &MeshConfig::default(),
        &cfg(),
        &dir,
        Shard::default(),
        true,
        None,
        None,
    )
    .unwrap();
    assert!(run.torn_repaired, "the torn tail must be detected");
    assert_eq!(run.batches_skipped, 2);
    assert_eq!(run.batches_run, 8, "the torn batch is re-executed");
    assert!(run.completed);
    assert_eq!(report_bytes(&dir), baseline);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn manifest_mismatch_is_refused_with_named_field() {
    let model = models::quicknet(7);
    let dir = tmpdir("mismatch");
    run_journaled(
        &model,
        &MeshConfig::default(),
        &cfg(),
        &dir,
        Shard::default(),
        false,
        Some(1),
        None,
    )
    .unwrap();
    // wrong seed
    let mut other = cfg();
    other.seed += 1;
    let e = run_journaled(
        &model,
        &MeshConfig::default(),
        &other,
        &dir,
        Shard::default(),
        true,
        None,
        None,
    )
    .unwrap_err()
    .to_string();
    assert!(e.contains("manifest mismatch: seed"), "{e}");
    // wrong scenario
    let mut other = cfg();
    other.scenario = Scenario::Mbu { bits: 2 };
    let e = run_journaled(
        &model,
        &MeshConfig::default(),
        &other,
        &dir,
        Shard::default(),
        true,
        None,
        None,
    )
    .unwrap_err()
    .to_string();
    assert!(e.contains("manifest mismatch: scenario"), "{e}");
    // wrong schema version (hand-edited manifest)
    let mpath = dir.join("manifest.json");
    let text = std::fs::read_to_string(&mpath).unwrap();
    assert!(text.contains("enfor-sa/campaign-journal/v1"));
    std::fs::write(&mpath, text.replace("campaign-journal/v1", "campaign-journal/v0"))
        .unwrap();
    let e = run_journaled(
        &model,
        &MeshConfig::default(),
        &cfg(),
        &dir,
        Shard::default(),
        true,
        None,
        None,
    )
    .unwrap_err()
    .to_string();
    assert!(e.contains("manifest mismatch: schema"), "{e}");
    let _ = std::fs::remove_dir_all(&dir);
    // resuming a dir that was never initialized is its own error
    let fresh = tmpdir("mismatch-fresh");
    let e = run_journaled(
        &model,
        &MeshConfig::default(),
        &cfg(),
        &fresh,
        Shard::default(),
        true,
        None,
        None,
    )
    .unwrap_err()
    .to_string();
    assert!(e.contains("nothing to resume"), "{e}");
    // ... and re-initializing an existing dir without --resume refuses
    let dir = tmpdir("mismatch-reinit");
    run_journaled(
        &model,
        &MeshConfig::default(),
        &cfg(),
        &dir,
        Shard::default(),
        false,
        Some(1),
        None,
    )
    .unwrap();
    let e = run_journaled(
        &model,
        &MeshConfig::default(),
        &cfg(),
        &dir,
        Shard::default(),
        false,
        None,
        None,
    )
    .unwrap_err()
    .to_string();
    assert!(e.contains("already initialized"), "{e}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn resume_of_completed_dir_is_noop_and_reemits_report() {
    let model = models::quicknet(7);
    let dir = tmpdir("noop");
    let first = run_journaled(
        &model,
        &MeshConfig::default(),
        &cfg(),
        &dir,
        Shard::default(),
        false,
        None,
        None,
    )
    .unwrap();
    assert!(first.completed);
    let baseline = report_bytes(&dir);
    // even if the report file is lost, resume regenerates it from the
    // journal without re-running anything
    std::fs::remove_file(dir.join("report.json")).unwrap();
    let again = run_journaled(
        &model,
        &MeshConfig::default(),
        &cfg(),
        &dir,
        Shard::default(),
        true,
        None,
        None,
    )
    .unwrap();
    assert!(again.completed);
    assert_eq!(again.batches_run, 0, "no batch may re-execute");
    assert_eq!(again.batches_skipped, 10);
    assert_eq!(report_bytes(&dir), baseline);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn prop_report_bytes_are_worker_count_invariant() {
    let baseline = straight_report("workers-baseline");
    let model = models::quicknet(7);
    for workers in [2usize, 3] {
        let dir = tmpdir(&format!("workers-{workers}"));
        let mut cc = cfg();
        cc.workers = workers;
        let run = run_journaled(
            &model,
            &MeshConfig::default(),
            &cc,
            &dir,
            Shard::default(),
            false,
            None,
            None,
        )
        .unwrap();
        assert!(run.completed);
        assert_eq!(report_bytes(&dir), baseline, "workers={workers} diverged");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
