//! PJRT runtime integration: the AOT artifacts (L1 Pallas kernels inside
//! L2 JAX graphs, lowered to HLO text) must load, compile and agree
//! bit-exactly with the native Rust engine — the contract that makes the
//! cross-layer splice valid.
//!
//! These tests require `artifacts/` (run `make artifacts`); they are
//! skipped gracefully if it is absent so `cargo test` works in a fresh
//! checkout.

use enfor_sa::campaign::TrialFault;
use enfor_sa::config::Dataflow;
use enfor_sa::dnn::engine::synthetic_input;
use enfor_sa::dnn::gemm::gemm_i8_alloc;
use enfor_sa::dnn::GemmSiteId;
use enfor_sa::mesh::{Fault, Mesh, SignalKind};
use enfor_sa::runtime::quicknet::QuicknetPjrt;
use enfor_sa::runtime::PjrtRuntime;
use enfor_sa::util::Rng;

fn runtime() -> Option<PjrtRuntime> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        return None;
    }
    Some(PjrtRuntime::load("artifacts").expect("loading artifacts"))
}

#[test]
fn manifest_covers_expected_artifacts() {
    let Some(rt) = runtime() else { return };
    for name in [
        "quicknet_conv1",
        "quicknet_conv2",
        "quicknet_conv3",
        "quicknet_conv4",
        "quicknet_fc",
        "gemm_8x8x8",
        "gemm_64x64x64",
        "attention_64",
    ] {
        assert!(
            rt.manifest.artifacts.contains_key(name),
            "missing artifact {name}"
        );
    }
}

#[test]
fn pjrt_gemm_matches_native_gemm() {
    let Some(mut rt) = runtime() else { return };
    let mut rng = Rng::new(0x9A);
    for &n in &[8usize, 16, 64] {
        let mut a = vec![0i8; n * n];
        let mut b = vec![0i8; n * n];
        rng.fill_i8(&mut a);
        rng.fill_i8(&mut b);
        let d: Vec<i32> = (0..n * n).map(|i| i as i32 - 100).collect();
        let got = rt.gemm(n, n, n, &a, &b, &d).expect("pjrt gemm");
        let want = gemm_i8_alloc(n, n, n, &a, &b, &d);
        assert_eq!(got, want, "gemm {n}x{n}x{n} diverged");
    }
}

#[test]
fn pjrt_gemm_matches_mesh_rtl() {
    // the three-layer agreement: XLA artifact == native SW == RTL mesh
    let Some(mut rt) = runtime() else { return };
    use enfor_sa::mesh::driver::MatmulDriver;
    let mut rng = Rng::new(0x3141);
    let n = 8;
    let a2 = rng.mat_i8(n, n);
    let b2 = rng.mat_i8(n, n);
    let d2 = rng.mat_i32(n, n, 100);
    let pjrt = rt.gemm(n, n, n, a2.data(), b2.data(), d2.data()).unwrap();
    let mut mesh = Mesh::new(n, Dataflow::OutputStationary);
    let rtl = MatmulDriver::new(&mut mesh).matmul(a2.view(), b2.view(), d2.view());
    assert_eq!(pjrt, rtl.into_vec());
}

#[test]
fn quicknet_pjrt_matches_native_forward() {
    let Some(mut rt) = runtime() else { return };
    let qn = QuicknetPjrt::new(0xDEAD);
    let mut rng = Rng::new(0x51);
    for _ in 0..3 {
        let x = synthetic_input(&[3, 32, 32], &mut rng);
        let pjrt_logits = qn.forward(&mut rt, &x, None).expect("pjrt forward");
        let native_logits = qn.model.forward(&x, None);
        assert_eq!(
            pjrt_logits.data, native_logits.data,
            "PJRT and native QuickNet diverged"
        );
    }
}

#[test]
fn quicknet_cross_layer_trial_through_pjrt() {
    // end-to-end: PJRT software path + RTL mesh tile with a hard fault
    let Some(mut rt) = runtime() else { return };
    let qn = QuicknetPjrt::new(0xDEAD);
    let mut rng = Rng::new(0x52);
    let x = synthetic_input(&[3, 32, 32], &mut rng);
    let golden = qn.forward(&mut rt, &x, None).unwrap();

    let mut mesh = Mesh::new(8, Dataflow::OutputStationary);
    let trial = TrialFault::single(
        GemmSiteId { layer: 1, ordinal: 0 },
        0,
        0,
        Fault::new(0, 0, SignalKind::Acc, 30, 20),
    );
    let faulty = qn.forward(&mut rt, &x, Some((trial, &mut mesh))).unwrap();
    assert_ne!(golden.data, faulty.data, "acc bit-30 fault must be visible");

    // masked fault: identical output
    let trial2 = TrialFault::single(
        GemmSiteId { layer: 1, ordinal: 0 },
        0,
        0,
        Fault::new(7, 7, SignalKind::Valid, 0, 1),
    );
    let masked = qn.forward(&mut rt, &x, Some((trial2, &mut mesh))).unwrap();
    assert_eq!(golden.data, masked.data, "idle-cycle fault must be masked");
}

#[test]
fn attention_artifact_matches_native_attention() {
    let Some(mut rt) = runtime() else { return };
    use enfor_sa::dnn::layers::{ForwardCtx, QAttention};
    use enfor_sa::dnn::TensorI8;
    use enfor_sa::runtime::ArgValue;
    let mut rng = Rng::new(0x53);
    let l = 64;
    let dm = 64;
    // scales must match python/compile/model.py ATTENTION_CFG
    let attn = QAttention {
        d_model: dm,
        wq: TensorI8::random(&[dm * dm], &mut rng).data,
        wk: TensorI8::random(&[dm * dm], &mut rng).data,
        wv: TensorI8::random(&[dm * dm], &mut rng).data,
        wo: TensorI8::random(&[dm * dm], &mut rng).data,
        mq: 0.01,
        mk: 0.01,
        mv: 0.01,
        ms: 0.05,
        mo: 0.05,
        mw: 0.02,
    };
    let x = TensorI8::random(&[l, dm], &mut rng);
    let native = attn.forward(&x, &mut ForwardCtx::plain());
    let pjrt = rt
        .exec_i8(
            "attention_64",
            &[
                ArgValue::I8(&x.data, vec![l, dm]),
                ArgValue::I8(&attn.wq, vec![dm, dm]),
                ArgValue::I8(&attn.wk, vec![dm, dm]),
                ArgValue::I8(&attn.wv, vec![dm, dm]),
                ArgValue::I8(&attn.wo, vec![dm, dm]),
            ],
        )
        .expect("attention artifact");
    // integer path is exact; the f32 softmax may differ by 1 ulp between
    // XLA-CPU and Rust libm, which can move a probability by 1 LSB.
    let mismatches = pjrt
        .iter()
        .zip(&native.data)
        .filter(|(a, b)| a != b)
        .count();
    let tol = l * dm / 100; // <1% of elements may differ by quantization LSB
    assert!(
        mismatches <= tol,
        "attention mismatch on {mismatches}/{} elements",
        l * dm
    );
    for (a, b) in pjrt.iter().zip(&native.data) {
        assert!((*a as i16 - *b as i16).abs() <= 1, "difference beyond 1 LSB");
    }
}

#[test]
fn runtime_rejects_bad_shapes() {
    let Some(mut rt) = runtime() else { return };
    use enfor_sa::runtime::ArgValue;
    let a = vec![0i8; 8];
    let err = rt.exec_i32("gemm_8x8x8", &[ArgValue::I8(&a, vec![2, 4])]);
    assert!(err.is_err(), "arity/shape validation must fire");
}
