//! The paper's §IV-B accuracy validation: ENFOR-SA's source-register
//! injection and HDFIT's per-assignment instrumentation must produce
//! **identical faulty output matrices** for the same input matrices,
//! fault locations and injection cycles.

use enfor_sa::campaign::sample_mesh_fault;
use enfor_sa::config::Dataflow;
use enfor_sa::mat::Mat;
use enfor_sa::mesh::driver::{gold_matmul, os_matmul_cycles, MatmulDriver};
use enfor_sa::mesh::hdfit::InstrumentedMesh;
use enfor_sa::mesh::{Fault, Mesh, SignalKind};
use enfor_sa::util::Rng;

fn both_backends(dim: usize, k: usize, seed: u64, fault: &Fault) -> (Mat<i32>, Mat<i32>) {
    let mut rng = Rng::new(seed);
    let a = rng.mat_i8(dim, k);
    let b = rng.mat_i8(k, dim);
    let d = rng.mat_i32(dim, dim, 1000);
    let mut mesh = Mesh::new(dim, Dataflow::OutputStationary);
    let mut hm = InstrumentedMesh::new(dim);
    let c1 = MatmulDriver::new(&mut mesh).matmul_with_fault(a.view(), b.view(), d.view(), fault);
    let c2 = MatmulDriver::new(&mut hm).matmul_with_fault(a.view(), b.view(), d.view(), fault);
    (c1, c2)
}

#[test]
fn identical_outputs_random_faults() {
    // the paper's validation experiment: same inputs, same fault list
    let mut rng = Rng::new(0xACC1);
    for rep in 0..300 {
        let dim = [4usize, 8][rep % 2];
        let k = 1 + rng.usize_below(20);
        let fault = sample_mesh_fault(dim, k, &mut rng, &[]);
        let (c1, c2) = both_backends(dim, k, 1000 + rep as u64, &fault);
        assert_eq!(c1, c2, "rep {rep}: fault {fault} diverged");
    }
}

#[test]
fn identical_outputs_exhaustive_small_mesh() {
    // every PE x signal kind x a bit x every cycle on a 2x2 mesh
    let dim = 2;
    let k = 3;
    for r in 0..dim {
        for c in 0..dim {
            for kind in SignalKind::ALL {
                for cycle in 0..os_matmul_cycles(dim, k) {
                    for bit in [0u8, kind.width() - 1] {
                        let fault = Fault::new(r, c, kind, bit, cycle);
                        let (c1, c2) = both_backends(dim, k, 7, &fault);
                        assert_eq!(c1, c2, "fault {fault} diverged");
                    }
                }
            }
        }
    }
}

#[test]
fn fault_free_runs_match_software_gold() {
    let mut rng = Rng::new(0xACC2);
    for _ in 0..50 {
        let dim = 8;
        let k = 1 + rng.usize_below(24);
        let a = rng.mat_i8(dim, k);
        let b = rng.mat_i8(k, dim);
        let d = rng.mat_i32(dim, dim, 1000);
        let gold = gold_matmul(a.view(), b.view(), d.view());
        let mut mesh = Mesh::new(dim, Dataflow::OutputStationary);
        let mut hm = InstrumentedMesh::new(dim);
        assert_eq!(
            MatmulDriver::new(&mut mesh).matmul(a.view(), b.view(), d.view()),
            gold
        );
        assert_eq!(
            MatmulDriver::new(&mut hm).matmul(a.view(), b.view(), d.view()),
            gold
        );
    }
}

#[test]
fn injected_faults_do_corrupt_sometimes() {
    // sanity against vacuous equality: a decent fraction of sampled
    // faults must actually corrupt the output on dense operands
    let mut rng = Rng::new(0xACC3);
    let dim = 8;
    let k = 8;
    let a = rng.mat_i8(dim, k);
    let b = Mat::from_fn(k, dim, |_, _| rng.i8() | 1);
    let d = rng.mat_i32(dim, dim, 100);
    let mut mesh = Mesh::new(dim, Dataflow::OutputStationary);
    let golden = MatmulDriver::new(&mut mesh).matmul(a.view(), b.view(), d.view());
    let mut corrupted = 0;
    let reps = 200;
    for _ in 0..reps {
        let fault = sample_mesh_fault(dim, k, &mut rng, &[]);
        let faulty =
            MatmulDriver::new(&mut mesh).matmul_with_fault(a.view(), b.view(), d.view(), &fault);
        if faulty != golden {
            corrupted += 1;
        }
    }
    assert!(
        corrupted > reps / 10,
        "only {corrupted}/{reps} faults corrupted output"
    );
}

#[test]
fn hdfit_pays_per_assignment_bookkeeping() {
    // cost-structure check: hooks fire on every assignment even with no
    // fault armed — the overhead ENFOR-SA eliminates
    let dim = 8;
    let mut hm = InstrumentedMesh::new(dim);
    let mut rng = Rng::new(0xACC4);
    let a = rng.mat_i8(dim, dim);
    let b = rng.mat_i8(dim, dim);
    let d = rng.mat_i32(dim, dim, 10);
    let before = hm.hook_calls;
    MatmulDriver::new(&mut hm).matmul(a.view(), b.view(), d.view());
    let calls = hm.hook_calls - before;
    let cycles = os_matmul_cycles(dim, dim);
    assert_eq!(calls, cycles * (dim * dim) as u64 * 12);
}
