//! Property tests pinning the scenario-first injection API.
//!
//! Three contracts:
//! 1. **SEU compatibility** — `--scenario seu` consumes the campaign
//!    RNG stream in exactly the legacy single-fault order, and a
//!    single-fault `FaultPlan` executes bit-identically to the
//!    pre-redesign single-`Fault` argument, across all four backends,
//!    both trial engines and both offload scopes. Together with
//!    `prop_resume.rs` (which runs the default `seu` scenario) this
//!    pins fixed-seed campaign output to the pre-redesign behaviour.
//! 2. **Plan semantics** — a burst plan fired by the driver's cursor
//!    reproduces N manual single-fault `inject_now` calls on a raw
//!    `Mesh`; an MBU plan equals a manual multi-bit flip.
//! 3. **Scenario campaigns** — every scenario runs end-to-end on every
//!    backend with identical counts across trial engines and worker
//!    shardings.
//! 4. **Dataflow-generic sampling** — the same contracts hold per
//!    dataflow: the OS RNG stream is exactly the legacy one (contract
//!    1a is OS by construction), WS `seu` plans draw the weight-tile
//!    grid and M-stream cycle range in the same draw order, and every
//!    scenario campaign also runs end-to-end on the WS mesh backends
//!    and on the whole SoC under both dataflows (contract 3d).

use enfor_sa::campaign::{
    campaign_sites, derived_input_seed, plan_one, run_campaign, sample_mesh_fault,
    sample_trial, signal_kinds, CampaignResult, PlannedTrial, TrialFault,
};
use enfor_sa::config::{
    Backend, CampaignConfig, Dataflow, MeshConfig, OffloadScope, Scenario, TrialEngine,
};
use enfor_sa::coordinator::run_parallel;
use enfor_sa::dnn::engine::synthetic_input;
use enfor_sa::dnn::models;
use enfor_sa::mesh::driver::MatmulDriver;
use enfor_sa::mesh::{Fault, FaultPlan, Mesh, MeshInputs, MeshSim, PlanCursor, SignalKind};
use enfor_sa::soc::Soc;
use enfor_sa::util::Rng;

fn cfg(backend: Backend, scenario: Scenario) -> CampaignConfig {
    CampaignConfig {
        seed: 0x5CE4A_10,
        faults_per_layer: 3,
        inputs: 2,
        backend,
        offload_scope: OffloadScope::SingleTile,
        engine: TrialEngine::SiteResume,
        tile_engine: Default::default(),
        lanes: 8,
        signals: vec![],
        scenario,
        hardening: Default::default(),
        workers: 1,
    }
}

fn assert_counts_equal(a: &CampaignResult, b: &CampaignResult, label: &str) {
    assert_eq!(a.vuln.trials, b.vuln.trials, "{label}: trials");
    assert_eq!(a.vuln.critical, b.vuln.critical, "{label}: critical");
    assert_eq!(a.exposed_trials, b.exposed_trials, "{label}: exposed");
    assert_eq!(a.masked_trials, b.masked_trials, "{label}: masked");
}

/// Contract 1a: under `seu`, `plan_one` draws every trial exactly as the
/// legacy sampler did — same stream, same order, single-fault plans.
#[test]
fn prop_seu_plans_replay_the_legacy_rng_stream() {
    let model = models::quicknet(11);
    let mesh = MeshConfig::default();
    let c = cfg(Backend::EnforSa, Scenario::Seu);
    let sites = campaign_sites(&model);
    let kinds = signal_kinds(&c);
    for input_idx in 0..c.inputs {
        let seed = derived_input_seed(c.seed, input_idx);
        let mut rng = Rng::new(seed);
        let plan = plan_one(&model, &c, &sites, &kinds, &mesh, &mut rng);
        // legacy replica: input tensor first, then trials site-major in
        // the order (tile_i, tile_j, signal+bit, row, col, cycle)
        let mut legacy = Rng::new(seed);
        let _x = synthetic_input(&model.input_shape, &mut legacy);
        for (batch, info) in plan.batches.iter().zip(&sites) {
            for t in &batch.trials {
                let PlannedTrial::Rtl(t) = t else {
                    panic!("seu RTL campaign must plan RTL trials")
                };
                let tile_i = legacy.usize_below(info.m.div_ceil(mesh.dim));
                let tile_j = legacy.usize_below(info.n.div_ceil(mesh.dim));
                let fault = sample_mesh_fault(mesh.dim, info.k, &mut legacy, &kinds);
                assert_eq!(t, &TrialFault::single(info.site, tile_i, tile_j, fault));
            }
        }
    }
}

/// Contract 1b: a single-fault plan is bit-identical to the legacy
/// single-`Fault` execution on the mesh drivers and the SoC.
#[test]
fn prop_single_fault_plans_match_legacy_execution_everywhere() {
    let mut rng = Rng::new(0x51E6);
    let dim = 4;
    let k = 6;
    let a = rng.mat_i8(dim, k);
    let b = rng.mat_i8(k, dim);
    let d = rng.mat_i32(dim, dim, 100);
    for _ in 0..40 {
        let f = sample_mesh_fault(dim, k, &mut rng, &[]);
        let mut mesh = Mesh::new(dim, Dataflow::OutputStationary);
        let legacy =
            MatmulDriver::new(&mut mesh).matmul_with_fault(a.view(), b.view(), d.view(), &f);
        let via_plan = MatmulDriver::new(&mut mesh).matmul_with_plan(
            a.view(),
            b.view(),
            d.view(),
            &FaultPlan::single(f),
        );
        assert_eq!(legacy, via_plan, "{f}");
        let mut hm = enfor_sa::mesh::hdfit::InstrumentedMesh::new(dim);
        let hdfit = MatmulDriver::new(&mut hm).matmul_with_plan(
            a.view(),
            b.view(),
            d.view(),
            &FaultPlan::single(f),
        );
        assert_eq!(legacy, hdfit, "{f} on hdfit");
    }
    // and through the whole SoC
    let f = Fault::new(1, 2, SignalKind::Acc, 7, 11);
    let mut soc = Soc::new(dim);
    let c_soc = soc
        .run_matmul(a.view(), b.view(), d.view(), &FaultPlan::single(f))
        .unwrap();
    let mut mesh = Mesh::new(dim, Dataflow::OutputStationary);
    let c_mesh =
        MatmulDriver::new(&mut mesh).matmul_with_fault(a.view(), b.view(), d.view(), &f);
    assert_eq!(c_soc, c_mesh);
}

/// Contract 2: a burst plan on one column fired through the cursor
/// reproduces N manual single-fault `inject_now` calls on a raw `Mesh`,
/// at the firing cycle and on every downstream cycle. Both meshes run
/// the identical live MAC stream so the corruption propagates.
#[test]
fn burst_plan_reproduces_manual_inject_now_calls() {
    let dim = 8;
    let col = 2;
    let fire_at: u64 = 3;
    let faults: Vec<Fault> = (0..dim)
        .map(|r| Fault::new(r, col, SignalKind::Propag, 0, fire_at))
        .collect();
    let plan = FaultPlan::new(faults.clone());

    // two raw meshes stepped through the identical input schedule
    let mut m1 = Mesh::new(dim, Dataflow::OutputStationary);
    let mut m2 = Mesh::new(dim, Dataflow::OutputStationary);
    let mut out1 = enfor_sa::mesh::StepOutput::new(dim);
    let mut out2 = enfor_sa::mesh::StepOutput::new(dim);
    let mut cursor = PlanCursor::start(&plan);
    let drive = |inp: &mut MeshInputs, t: u64| {
        inp.clear();
        for lane in 0..dim {
            inp.west_a[lane] = (lane as i8) + 1 + (t as i8);
            inp.north_b[lane] = 2 * (lane as i8) - (t as i8);
            inp.north_valid[lane] = true;
        }
    };
    let mut inp1 = MeshInputs::idle(dim);
    let mut inp2 = MeshInputs::idle(dim);
    for t in 0..12u64 {
        drive(&mut inp1, t);
        drive(&mut inp2, t);
        // mesh 1: the wrapper's one-compare-per-cycle cursor
        if cursor.next_cycle() == t {
            cursor.fire(&plan, t, &mut m1, &mut inp1);
        }
        // mesh 2: manual single-fault injections
        if t == fire_at {
            for f in &faults {
                m2.inject_now(f, &mut inp2);
            }
        }
        m1.step(&inp1, &mut out1);
        m2.step(&inp2, &mut out2);
        for r in 0..dim {
            for c in 0..dim {
                assert_eq!(
                    m1.acc_at(r, c),
                    m2.acc_at(r, c),
                    "cycle {t} PE({r},{c})"
                );
            }
        }
    }
    // sanity: the burst actually disturbed the accumulators vs golden
    let mut golden = Mesh::new(dim, Dataflow::OutputStationary);
    let mut inp = MeshInputs::idle(dim);
    let mut out = enfor_sa::mesh::StepOutput::new(dim);
    for t in 0..12u64 {
        drive(&mut inp, t);
        golden.step(&inp, &mut out);
    }
    let corrupted = (0..dim)
        .flat_map(|r| (0..dim).map(move |c| (r, c)))
        .filter(|&(r, c)| m1.acc_at(r, c) != golden.acc_at(r, c))
        .count();
    assert!(corrupted > 0, "burst must corrupt live accumulators");
}

/// Contract 2b: an MBU plan on an accumulator equals flipping the same
/// bits manually in one shot.
#[test]
fn mbu_plan_equals_manual_multi_bit_flip() {
    let dim = 4;
    let bits = [3u8, 4, 5];
    let plan = FaultPlan::new(
        bits.iter()
            .map(|&b| Fault::new(1, 1, SignalKind::Acc, b, 0))
            .collect(),
    );
    let mut mesh = Mesh::new(dim, Dataflow::OutputStationary);
    let mut inp = MeshInputs::idle(dim);
    let mut cursor = PlanCursor::start(&plan);
    cursor.fire(&plan, 0, &mut mesh, &mut inp);
    let mask: i32 = bits.iter().map(|&b| 1i32 << b).sum();
    assert_eq!(mesh.acc_at(1, 1), mask, "all bits flipped from zero");
    assert_eq!(cursor.next_cycle(), u64::MAX);
}

/// Contract 3a: every scenario × backend campaign completes with the
/// full trial budget and identical counts across trial engines.
#[test]
fn prop_every_scenario_agrees_across_engines_and_backends() {
    let model = models::quicknet(11);
    let mesh = MeshConfig::default();
    let scenarios = [
        Scenario::Seu,
        Scenario::Mbu { bits: 2 },
        Scenario::Burst { radius: 1 },
        Scenario::DoubleSeu,
        Scenario::StuckAt { value: true },
    ];
    for scenario in scenarios {
        for backend in [Backend::EnforSa, Backend::Hdfit, Backend::SwOnly] {
            let mut a_cfg = cfg(backend, scenario);
            a_cfg.engine = TrialEngine::SiteResume;
            let a = run_campaign(&model, &mesh, &a_cfg).unwrap();
            let mut b_cfg = cfg(backend, scenario);
            b_cfg.engine = TrialEngine::FullForward;
            let b = run_campaign(&model, &mesh, &b_cfg).unwrap();
            assert_eq!(a.vuln.trials, 5 * 3 * 2, "{scenario}/{backend}");
            assert_counts_equal(&a, &b, &format!("{scenario}/{backend}"));
        }
    }
}

/// Contract 3b: the ENFOR-SA and HDFIT backends stay bit-equivalent for
/// multi-fault scenarios (the per-assignment hooks must apply every
/// armed fault, including several on one assignment).
#[test]
fn prop_backends_agree_on_multi_fault_scenarios() {
    let model = models::quicknet(11);
    let mesh = MeshConfig::default();
    for scenario in [
        Scenario::Mbu { bits: 3 },
        Scenario::Burst { radius: 1 },
        Scenario::DoubleSeu,
        Scenario::StuckAt { value: false },
    ] {
        let a = run_campaign(&model, &mesh, &cfg(Backend::EnforSa, scenario)).unwrap();
        let b = run_campaign(&model, &mesh, &cfg(Backend::Hdfit, scenario)).unwrap();
        assert_counts_equal(&a, &b, &format!("{scenario}"));
    }
}

/// Contract 3c: worker-count invariance holds for every scenario (the
/// coordinator shards plans, and plans now carry whole scenarios).
#[test]
fn prop_scenarios_are_worker_count_invariant() {
    let model = models::quicknet(11);
    let mesh = MeshConfig::default();
    for scenario in [Scenario::Mbu { bits: 2 }, Scenario::DoubleSeu] {
        let mut c = cfg(Backend::EnforSa, scenario);
        c.workers = 1;
        let one = run_parallel(&model, &mesh, &c, None).unwrap();
        c.workers = 4;
        let many = run_parallel(&model, &mesh, &c, None).unwrap();
        assert_counts_equal(&one, &many, &format!("{scenario} workers=4"));
    }
}

/// Contract 3d: the full-SoC backend executes scenario plans too,
/// under BOTH dataflows since the schedule-indexable controller
/// (small budget — every trial drives the whole chip).
#[test]
fn full_soc_runs_scenario_plans() {
    let model = models::quicknet(11);
    for dataflow in [Dataflow::OutputStationary, Dataflow::WeightStationary] {
        let mesh = MeshConfig { dim: 4, dataflow };
        for scenario in [Scenario::Mbu { bits: 2 }, Scenario::StuckAt { value: true }] {
            let mut c = cfg(Backend::FullSoc, scenario);
            c.faults_per_layer = 1;
            c.inputs = 1;
            let soc = run_campaign(&model, &mesh, &c).unwrap();
            assert_eq!(soc.vuln.trials, 5, "{dataflow}/{scenario}");
            // and it matches the mesh backend on the same plans
            let mut m_cfg = cfg(Backend::EnforSa, scenario);
            m_cfg.faults_per_layer = 1;
            m_cfg.inputs = 1;
            let mesh_r = run_campaign(&model, &mesh, &m_cfg).unwrap();
            assert_counts_equal(&soc, &mesh_r, &format!("{dataflow}/{scenario} soc-vs-mesh"));
        }
    }
}

/// Burst plans restricted to one signal class still respect the
/// campaign's signal filter (sampling draws the base fault from the
/// filtered pool; derived faults share its kind).
#[test]
fn scenario_sampling_respects_signal_filter() {
    let mut rng = Rng::new(0x51F7);
    let site = enfor_sa::dnn::GemmSiteId { layer: 0, ordinal: 0 };
    for _ in 0..100 {
        let t = sample_trial(
            Scenario::Burst { radius: 2 },
            Dataflow::OutputStationary,
            site,
            64,
            27,
            64,
            8,
            &mut rng,
            &[SignalKind::Propag, SignalKind::Valid],
        );
        for f in t.plan.faults() {
            assert!(matches!(
                f.addr.kind,
                SignalKind::Propag | SignalKind::Valid
            ));
        }
    }
}

/// Contract 4a: under WS, `plan_one` draws in the same order with the
/// dataflow's ranges — tile_i over K tiles, tile_j over N tiles, the
/// cycle inside the M-row streaming pass.
#[test]
fn prop_ws_seu_plans_draw_the_weight_tile_grid() {
    use enfor_sa::campaign::sample_fault;
    use enfor_sa::mesh::driver::tile_grid;
    let model = models::quicknet(11);
    let mesh = MeshConfig {
        dataflow: Dataflow::WeightStationary,
        ..Default::default()
    };
    let c = cfg(Backend::EnforSa, Scenario::Seu);
    let sites = campaign_sites(&model);
    let kinds = signal_kinds(&c);
    for input_idx in 0..c.inputs {
        let seed = derived_input_seed(c.seed, input_idx);
        let mut rng = Rng::new(seed);
        let plan = plan_one(&model, &c, &sites, &kinds, &mesh, &mut rng);
        let mut replica = Rng::new(seed);
        let _x = synthetic_input(&model.input_shape, &mut replica);
        for (batch, info) in plan.batches.iter().zip(&sites) {
            for t in &batch.trials {
                let PlannedTrial::Rtl(t) = t else {
                    panic!("WS RTL campaign must plan RTL trials")
                };
                let (tiles_i, tiles_j) =
                    tile_grid(Dataflow::WeightStationary, mesh.dim, info.m, info.k, info.n);
                let tile_i = replica.usize_below(tiles_i);
                let tile_j = replica.usize_below(tiles_j);
                let fault = sample_fault(
                    Dataflow::WeightStationary,
                    mesh.dim,
                    info.m,
                    info.k,
                    &mut replica,
                    &kinds,
                );
                assert_eq!(t, &TrialFault::single(info.site, tile_i, tile_j, fault));
                assert!(t.tile_i < info.k.div_ceil(mesh.dim), "tile_i indexes K");
            }
        }
    }
}

/// Contract 4b: every scenario runs end-to-end on the WS mesh backends
/// with the full trial budget and identical counts across trial
/// engines — the dataflow axis composes with the whole scenario API.
#[test]
fn prop_ws_every_scenario_agrees_across_engines_and_backends() {
    let model = models::quicknet(11);
    let mesh = MeshConfig {
        dataflow: Dataflow::WeightStationary,
        ..Default::default()
    };
    let scenarios = [
        Scenario::Seu,
        Scenario::Mbu { bits: 2 },
        Scenario::Burst { radius: 1 },
        Scenario::DoubleSeu,
        Scenario::StuckAt { value: true },
    ];
    for scenario in scenarios {
        for backend in [Backend::EnforSa, Backend::Hdfit] {
            let mut a_cfg = cfg(backend, scenario);
            a_cfg.engine = TrialEngine::SiteResume;
            let a = run_campaign(&model, &mesh, &a_cfg).unwrap();
            let mut b_cfg = cfg(backend, scenario);
            b_cfg.engine = TrialEngine::FullForward;
            let b = run_campaign(&model, &mesh, &b_cfg).unwrap();
            assert_eq!(a.vuln.trials, 5 * 3 * 2, "ws/{scenario}/{backend}");
            assert_counts_equal(&a, &b, &format!("ws/{scenario}/{backend}"));
        }
    }
}

/// Contract 4c: the ENFOR-SA and HDFIT backends stay bit-equivalent on
/// the WS mesh for multi-fault scenarios (the WS instrumented step must
/// apply every armed hook identically to the wrapper).
#[test]
fn prop_ws_backends_agree_on_multi_fault_scenarios() {
    let model = models::quicknet(11);
    let mesh = MeshConfig {
        dataflow: Dataflow::WeightStationary,
        ..Default::default()
    };
    for scenario in [
        Scenario::Mbu { bits: 3 },
        Scenario::Burst { radius: 1 },
        Scenario::DoubleSeu,
        Scenario::StuckAt { value: false },
    ] {
        let a = run_campaign(&model, &mesh, &cfg(Backend::EnforSa, scenario)).unwrap();
        let b = run_campaign(&model, &mesh, &cfg(Backend::Hdfit, scenario)).unwrap();
        assert_counts_equal(&a, &b, &format!("ws/{scenario}"));
    }
}

/// OS campaigns stay deterministic and correctly labelled under the
/// dataflow-generic engine. (The actual OS bit-identity pin to the
/// pre-dataflow behaviour is contract 1a above — the draw-by-draw
/// legacy-RNG replica — this test only covers the campaign-level
/// determinism and the new `dataflow` result label.)
#[test]
fn prop_os_campaigns_stay_deterministic_and_labelled() {
    let model = models::quicknet(11);
    let os = MeshConfig::default();
    let c = cfg(Backend::EnforSa, Scenario::Seu);
    let a = run_campaign(&model, &os, &c).unwrap();
    let b = run_campaign(&model, &os, &c).unwrap();
    assert_counts_equal(&a, &b, "os determinism");
    assert_eq!(a.dataflow, Dataflow::OutputStationary);
}
