//! Integration tests over the DNN substrate and software fault injector.

use enfor_sa::dnn::engine::synthetic_input;
use enfor_sa::dnn::{argmax, models};
use enfor_sa::swfi::{sample_output_fault, SwInjector, SwPlan, SwTarget};
use enfor_sa::util::Rng;

#[test]
fn all_zoo_models_forward_all_shapes() {
    let mut rng = Rng::new(0xD0D0);
    for model in models::zoo(123) {
        let x = synthetic_input(&model.input_shape, &mut rng);
        let logits = model.forward(&x, None);
        assert_eq!(logits.shape, vec![1, 10], "{}", model.name);
        // logits must carry signal (not all equal)
        let first = logits.data[0];
        assert!(
            logits.data.iter().any(|&v| v != first),
            "{}: flat logits",
            model.name
        );
    }
}

#[test]
fn zoo_models_have_multiple_gemm_sites() {
    let mut rng = Rng::new(0xD0D1);
    for model in models::zoo(123) {
        let x = synthetic_input(&model.input_shape, &mut rng);
        let sites = model.gemm_sites(&x);
        assert!(
            sites.len() >= 3,
            "{} exposes only {} GEMM sites",
            model.name,
            sites.len()
        );
        // shapes must be well-formed
        for s in &sites {
            assert!(s.m > 0 && s.k > 0 && s.n > 0);
        }
    }
}

#[test]
fn vit_models_contain_attention_gemms() {
    let mut rng = Rng::new(0xD0D2);
    for name in ["DeiT-T", "DeiT-S"] {
        let model = models::by_name(name, 5).unwrap();
        let x = synthetic_input(&model.input_shape, &mut rng);
        let sites = model.gemm_sites(&x);
        // attention blocks emit 6 GEMMs at the same layer index
        let max_ordinal = sites.iter().map(|s| s.site.ordinal).max().unwrap();
        assert!(max_ordinal >= 5, "{name}: no attention multi-GEMM layer");
    }
}

#[test]
fn golden_runs_are_stable_across_calls() {
    let mut rng = Rng::new(0xD0D3);
    let model = models::resnet50(9);
    let x = synthetic_input(&model.input_shape, &mut rng);
    let a = model.forward(&x, None);
    for _ in 0..3 {
        assert_eq!(model.forward(&x, None), a);
    }
}

#[test]
fn sw_injection_fuzz_never_panics_and_classifies() {
    let model = models::quicknet(11);
    let mut rng = Rng::new(0xD0D4);
    let x = synthetic_input(&model.input_shape, &mut rng);
    let golden = model.top1(&x, None);
    let mut criticals = 0;
    for _ in 0..300 {
        let target = sample_output_fault(&model, &mut rng);
        let plan = SwPlan::single(target);
        let mut inj = SwInjector::new(&plan);
        let logits = model.forward(&x, Some(&mut inj));
        assert!(inj.applied_all(), "{target:?} did not apply");
        if argmax(&logits.data) != golden {
            criticals += 1;
        }
    }
    // SW-level injection is pessimistic: flipping visible outputs must
    // produce a clearly nonzero critical rate
    assert!(criticals > 0, "no critical SW faults in 300 trials");
}

#[test]
fn weight_faults_affect_only_that_forward_pass() {
    let model = models::quicknet(11);
    let mut rng = Rng::new(0xD0D5);
    let x = synthetic_input(&model.input_shape, &mut rng);
    let golden = model.forward(&x, None);
    let plan = SwPlan::single(SwTarget::Weight {
        layer: 1,
        ordinal: 0,
        elem: 17,
        bit: 6,
    });
    let mut inj = SwInjector::new(&plan);
    let _faulty = model.forward(&x, Some(&mut inj));
    assert!(inj.applied_all());
    // the model itself is unchanged (transient, not permanent)
    assert_eq!(model.forward(&x, None), golden);
}

#[test]
fn param_counts_are_stable() {
    // regression pin on zoo sizes (Table II ordering is tested in-unit;
    // here we pin rough magnitudes so refactors don't silently shrink
    // the models)
    let m = models::quicknet(1);
    let p = m.param_count();
    assert!(p > 30_000 && p < 80_000, "quicknet params {p}");
    let rn50 = models::resnet50(1).param_count();
    let rx32 = models::resnext32(1).param_count();
    assert!(rn50 > 50_000, "resnet50 params {rn50}");
    assert!(rx32 > 200_000, "resnext32 params {rx32}");
}
