//! Property tests pinning the cycle-resume RTL tile engine against the
//! full (from-cycle-0) oracle.
//!
//! The contract (ROADMAP "Cycle-resume"):
//! 1. **Snapshot semantics** — `restore_state ∘ save_state ≡ id`, and a
//!    restored trajectory continues bit-identically (both dataflows).
//! 2. **Resume equivalence** — `advance_golden` + `matmul_resumed`
//!    reproduce a full faulty run bit-exactly for ANY first-fault
//!    cycle, on the plain mesh (both dataflows) and on the
//!    HDFIT-instrumented mesh (whose storage hooks fire one cycle
//!    before the ENFOR-SA onset — the `first_effect_cycle` shift),
//!    including resume points inside the OS flush window.
//! 3. **Campaign equivalence** — fixed-seed campaigns are bit-identical
//!    between `--tile-engine full` and `--tile-engine cycle-resume`
//!    across all five fault scenarios on the Mesh and Hdfit backends,
//!    under worker sharding, and cycle-resume steps strictly fewer RTL
//!    cycles. The whole-SoC backend honours the flag too (ROADMAP
//!    "Schedule-indexable SoC"): its controller snapshots inside the
//!    matmul window, so resumed campaigns are bit-identical to full
//!    ones under both dataflows and step strictly fewer SoC cycles.

use enfor_sa::campaign::{run_campaign, CampaignResult};
use enfor_sa::config::{
    Backend, CampaignConfig, Dataflow, MeshConfig, OffloadScope, Scenario, TileEngine,
    TrialEngine,
};
use enfor_sa::coordinator::run_parallel;
use enfor_sa::dnn::models;
use enfor_sa::mesh::driver::Schedule;
use enfor_sa::mesh::hdfit::InstrumentedMesh;
use enfor_sa::mesh::{
    CycleCursor, DriverScratch, Fault, FaultPlan, Injectable, MatmulDriver, Mesh, MeshSim,
    MeshState, SignalKind,
};
use enfor_sa::util::Rng;

fn cfg(backend: Backend, scenario: Scenario, tile_engine: TileEngine) -> CampaignConfig {
    CampaignConfig {
        seed: 0xC1C1E_7E5,
        faults_per_layer: 3,
        inputs: 2,
        backend,
        offload_scope: OffloadScope::SingleTile,
        engine: TrialEngine::SiteResume,
        tile_engine,
        lanes: 8,
        signals: vec![],
        scenario,
        hardening: Default::default(),
        workers: 1,
    }
}

fn assert_bit_identical(a: &CampaignResult, b: &CampaignResult, label: &str) {
    assert_eq!(a.vuln.trials, b.vuln.trials, "{label}: trials");
    assert_eq!(a.vuln.critical, b.vuln.critical, "{label}: critical");
    assert_eq!(a.exposed_trials, b.exposed_trials, "{label}: exposed");
    assert_eq!(a.masked_trials, b.masked_trials, "{label}: masked");
    assert_eq!(a.per_layer.len(), b.per_layer.len(), "{label}: layer map size");
    for ((la, va), (lb, vb)) in a.per_layer.iter().zip(b.per_layer.iter()) {
        assert_eq!(la, lb, "{label}: layer ids");
        assert_eq!(va.trials, vb.trials, "{label}: layer {la} trials");
        assert_eq!(va.critical, vb.critical, "{label}: layer {la} critical");
    }
}

/// Contract 1: snapshot round-trip, both dataflows, via the public seam.
#[test]
fn prop_restore_after_save_is_identity() {
    let mut rng = Rng::new(0xA0);
    for dataflow in [Dataflow::OutputStationary, Dataflow::WeightStationary] {
        let dim = 4;
        let (a, b, d) = match dataflow {
            Dataflow::OutputStationary => {
                (rng.mat_i8(dim, 9), rng.mat_i8(9, dim), rng.mat_i32(dim, dim, 100))
            }
            Dataflow::WeightStationary => {
                (rng.mat_i8(7, dim), rng.mat_i8(dim, dim), rng.mat_i32(7, dim, 100))
            }
        };
        let mut mesh = Mesh::new(dim, dataflow);
        let mut cur = CycleCursor::new();
        let mut scratch = DriverScratch::new(dim);
        let total = Schedule::new(dataflow, dim, a.view(), b.view(), d.view()).total_cycles();
        // snapshot mid-program...
        MatmulDriver::new(&mut mesh).advance_golden(
            a.view(),
            b.view(),
            d.view(),
            (0, 0),
            total / 2,
            &mut cur,
            &mut scratch,
        );
        let mut snap = MeshState::default();
        mesh.save_state(&mut snap);
        assert_eq!(snap.cycle(), total / 2);
        // ...clobber the mesh with an unrelated golden run, restore, and
        // the state must round-trip bit-exactly
        MatmulDriver::new(&mut mesh).matmul(a.view(), b.view(), d.view());
        mesh.restore_state(&snap);
        let mut snap2 = MeshState::default();
        mesh.save_state(&mut snap2);
        assert_eq!(snap, snap2, "{dataflow}: restore ∘ save ≡ id");
    }
}

/// Contract 2 (Hdfit): the instrumented backend resumes at the hook's
/// firing cycle, one BEFORE the onset for storage faults — exhaustively
/// over every onset cycle and a mix of wire/storage/control faults.
#[test]
fn prop_hdfit_resumed_matches_full_at_every_cycle() {
    let dim = 4;
    let k = 6;
    let mut rng = Rng::new(0xA1);
    let a = rng.mat_i8(dim, k);
    let b = rng.mat_i8(k, dim);
    let d = rng.mat_i32(dim, dim, 200);
    let mut mesh = InstrumentedMesh::new(dim);
    let total = Schedule::new(Dataflow::OutputStationary, dim, a.view(), b.view(), d.view())
        .total_cycles();
    let mut cur = CycleCursor::new();
    let mut scratch = DriverScratch::new(dim);
    let mut out = enfor_sa::mat::Mat::default();
    for tf in 0..total {
        let f = match tf % 3 {
            0 => Fault::new(2, 1, SignalKind::Acc, 29, tf), // hook fires at tf-1
            1 => Fault::new(1, 2, SignalKind::Weight, 5, tf),
            _ => Fault::new(0, 3, SignalKind::Valid, 0, tf),
        };
        let plan = FaultPlan::single(f);
        let resume = mesh.first_effect_cycle(&plan);
        assert!(resume <= tf, "hooks never fire after the onset");
        let full =
            MatmulDriver::new(&mut mesh).matmul_with_plan(a.view(), b.view(), d.view(), &plan);
        let mut drv = MatmulDriver::new(&mut mesh);
        drv.advance_golden(a.view(), b.view(), d.view(), (0, 0), resume, &mut cur, &mut scratch);
        drv.matmul_resumed(a.view(), b.view(), d.view(), &plan, &cur, &mut out, &mut scratch);
        assert_eq!(out, full, "hdfit tf={tf} ({})", f);
    }
}

/// Contract 2 (multi-fault plans): a resumed scenario plan (several
/// cycles, mixed kinds) equals the full run when resumed at the plan's
/// first effect cycle — the exact shape campaign trials replay.
#[test]
fn prop_resumed_scenario_plans_match_full() {
    let dim = 4;
    let mut rng = Rng::new(0xA2);
    for dataflow in [Dataflow::OutputStationary, Dataflow::WeightStationary] {
        let (a, b, d) = match dataflow {
            Dataflow::OutputStationary => {
                (rng.mat_i8(dim, 8), rng.mat_i8(8, dim), rng.mat_i32(dim, dim, 60))
            }
            Dataflow::WeightStationary => {
                (rng.mat_i8(6, dim), rng.mat_i8(dim, dim), rng.mat_i32(6, dim, 60))
            }
        };
        let mut mesh = Mesh::new(dim, dataflow);
        let total = Schedule::new(dataflow, dim, a.view(), b.view(), d.view()).total_cycles();
        let mut cur = CycleCursor::new();
        let mut scratch = DriverScratch::new(dim);
        let mut out = enfor_sa::mat::Mat::default();
        for trial in 0..40u64 {
            let c0 = rng.below(total);
            let plan = FaultPlan::new(vec![
                Fault::new(
                    rng.usize_below(dim),
                    rng.usize_below(dim),
                    SignalKind::Acc,
                    (trial % 32) as u8,
                    c0,
                ),
                Fault::new(
                    rng.usize_below(dim),
                    rng.usize_below(dim),
                    SignalKind::Propag,
                    0,
                    rng.below(total),
                ),
            ]);
            let full = MatmulDriver::new(&mut mesh)
                .matmul_with_plan(a.view(), b.view(), d.view(), &plan);
            cur.invalidate(); // random cycles are not sorted across trials
            let mut drv = MatmulDriver::new(&mut mesh);
            // on the plain mesh the first effect cycle IS the plan onset
            drv.advance_golden(
                a.view(),
                b.view(),
                d.view(),
                (0, 0),
                plan.first_cycle(),
                &mut cur,
                &mut scratch,
            );
            drv.matmul_resumed(a.view(), b.view(), d.view(), &plan, &cur, &mut out, &mut scratch);
            assert_eq!(out, full, "{dataflow} trial={trial} plan=[{plan}]");
        }
    }
}

/// Contract 3: fixed-seed campaigns are bit-identical across tile
/// engines for every scenario on both mesh-level backends.
#[test]
fn prop_tile_engines_agree_across_scenarios_and_backends() {
    let model = models::quicknet(11);
    let mesh = MeshConfig::default();
    for backend in [Backend::EnforSa, Backend::Hdfit] {
        for scenario in [
            Scenario::Seu,
            Scenario::Mbu { bits: 2 },
            Scenario::Burst { radius: 1 },
            Scenario::DoubleSeu,
            Scenario::StuckAt { value: true },
        ] {
            let resume =
                run_campaign(&model, &mesh, &cfg(backend, scenario, TileEngine::CycleResume))
                    .unwrap();
            let full =
                run_campaign(&model, &mesh, &cfg(backend, scenario, TileEngine::Full)).unwrap();
            assert_bit_identical(&resume, &full, &format!("{backend}/{scenario}"));
            assert!(
                resume.rtl_cycles_stepped <= full.rtl_cycles_stepped,
                "{backend}/{scenario}: resume must never step MORE cycles"
            );
        }
    }
}

/// Contract 2 (WS driver seam): one shared cursor, onsets sorted
/// ascending, matmul-shaped operands — the exact replay shape of a WS
/// campaign batch (which, since the dataflow-generic campaign PR, runs
/// end to end; the campaign-level pins are below).
#[test]
fn prop_ws_driver_tile_engines_agree() {
    // batch-shaped driver sweep: sorted onsets, one golden cursor
    let dim = 8;
    let mut rng = Rng::new(0xA3);
    let a = rng.mat_i8(12, dim);
    let w = rng.mat_i8(dim, dim);
    let d = rng.mat_i32(12, dim, 500);
    let mut mesh = Mesh::new(dim, Dataflow::WeightStationary);
    let total = Schedule::new(Dataflow::WeightStationary, dim, a.view(), w.view(), d.view())
        .total_cycles();
    let mut cur = CycleCursor::new();
    let mut scratch = DriverScratch::new(dim);
    let mut out = enfor_sa::mat::Mat::default();
    // ascending onset cycles: the sorted order a campaign batch uses
    let mut onsets: Vec<u64> = (0..12).map(|_| rng.below(total)).collect();
    onsets.sort_unstable();
    for (i, &tf) in onsets.iter().enumerate() {
        let f = Fault::new(
            rng.usize_below(dim),
            rng.usize_below(dim),
            if i % 2 == 0 { SignalKind::Weight } else { SignalKind::Valid },
            0,
            tf,
        );
        let plan = FaultPlan::single(f);
        let full =
            MatmulDriver::new(&mut mesh).matmul_with_plan(a.view(), w.view(), d.view(), &plan);
        let mut drv = MatmulDriver::new(&mut mesh);
        drv.advance_golden(a.view(), w.view(), d.view(), (0, 0), tf, &mut cur, &mut scratch);
        drv.matmul_resumed(a.view(), w.view(), d.view(), &plan, &cur, &mut out, &mut scratch);
        assert_eq!(out, full, "ws tf={tf}");
    }
}

/// Contract 3 (WS campaigns): fixed-seed WS campaigns are bit-identical
/// across tile engines for every scenario on both mesh-level backends —
/// the dataflow-generic mirror of the OS pin above.
#[test]
fn prop_ws_tile_engines_agree_across_scenarios_and_backends() {
    let model = models::quicknet(11);
    let mesh = MeshConfig {
        dataflow: Dataflow::WeightStationary,
        ..Default::default()
    };
    for backend in [Backend::EnforSa, Backend::Hdfit] {
        for scenario in [
            Scenario::Seu,
            Scenario::Mbu { bits: 2 },
            Scenario::Burst { radius: 1 },
            Scenario::DoubleSeu,
            Scenario::StuckAt { value: true },
        ] {
            let resume =
                run_campaign(&model, &mesh, &cfg(backend, scenario, TileEngine::CycleResume))
                    .unwrap();
            let full =
                run_campaign(&model, &mesh, &cfg(backend, scenario, TileEngine::Full)).unwrap();
            assert_bit_identical(&resume, &full, &format!("ws/{backend}/{scenario}"));
            assert!(
                resume.rtl_cycles_stepped <= full.rtl_cycles_stepped,
                "ws/{backend}/{scenario}: resume must never step MORE cycles"
            );
        }
    }
}

/// Contract 3 (WS worker invariance): WS campaigns shard like OS ones —
/// identical counts AND identical deterministic `rtl_cycles_stepped`
/// for any worker count.
#[test]
fn prop_ws_cycle_resume_is_worker_invariant() {
    let model = models::quicknet(11);
    let mesh = MeshConfig {
        dataflow: Dataflow::WeightStationary,
        ..Default::default()
    };
    let mut c = cfg(Backend::EnforSa, Scenario::Seu, TileEngine::CycleResume);
    c.workers = 1;
    let one = run_parallel(&model, &mesh, &c, None).unwrap();
    for workers in [2usize, 5] {
        c.workers = workers;
        let many = run_parallel(&model, &mesh, &c, None).unwrap();
        assert_bit_identical(&one, &many, &format!("ws workers={workers}"));
        assert_eq!(
            one.rtl_cycles_stepped, many.rtl_cycles_stepped,
            "ws workers={workers}: stepped-cycle accounting must be deterministic"
        );
    }
}

/// WS cycle-resume must beat the full tile engine on stepped RTL cycles
/// once trials share weight tiles — faults_per_layer=16 pigeonholes
/// conv1's (K=27, N=16) -> 4x2 = 8-tile weight grid.
#[test]
fn prop_ws_cycle_resume_steps_strictly_fewer_cycles() {
    let model = models::quicknet(11);
    let mesh = MeshConfig {
        dataflow: Dataflow::WeightStationary,
        ..Default::default()
    };
    let mut c = cfg(Backend::EnforSa, Scenario::Seu, TileEngine::CycleResume);
    c.faults_per_layer = 16;
    c.inputs = 1;
    let resume = run_campaign(&model, &mesh, &c).unwrap();
    c.tile_engine = TileEngine::Full;
    let full = run_campaign(&model, &mesh, &c).unwrap();
    assert_bit_identical(&resume, &full, "ws 16-fault campaign");
    assert!(resume.rtl_cycles_stepped > 0);
    assert!(
        resume.rtl_cycles_stepped < full.rtl_cycles_stepped,
        "ws cycle-resume stepped {} cycles, full {}",
        resume.rtl_cycles_stepped,
        full.rtl_cycles_stepped
    );
}

/// Contract 3: the flag round-trips through the parallel coordinator —
/// worker-count invariance holds under cycle-resume, including the
/// deterministic `rtl_cycles_stepped` accounting.
#[test]
fn prop_cycle_resume_is_worker_invariant() {
    let model = models::quicknet(11);
    let mesh = MeshConfig::default();
    let mut c = cfg(Backend::EnforSa, Scenario::Seu, TileEngine::CycleResume);
    c.workers = 1;
    let one = run_parallel(&model, &mesh, &c, None).unwrap();
    for workers in [2usize, 5] {
        c.workers = workers;
        let many = run_parallel(&model, &mesh, &c, None).unwrap();
        assert_bit_identical(&one, &many, &format!("workers={workers}"));
        assert_eq!(
            one.rtl_cycles_stepped, many.rtl_cycles_stepped,
            "workers={workers}: stepped-cycle accounting must be deterministic"
        );
    }
}

/// Contract 3 (FullSoc): the whole-SoC backend honours the tile engine
/// now — fixed-seed campaigns are bit-identical between full and
/// cycle-resume under both dataflows and multi-fault scenarios, and
/// cycle-resume steps STRICTLY fewer SoC cycles: the command-decode
/// prefix is paid once per tile and the fence-drain/halt postfix never,
/// instead of both per trial. Small budget — every trial still drives
/// the whole chip.
#[test]
fn prop_full_soc_tile_engines_agree_and_resume_steps_fewer() {
    let model = models::quicknet(11);
    for dataflow in [Dataflow::OutputStationary, Dataflow::WeightStationary] {
        let mesh = MeshConfig { dim: 4, dataflow };
        for scenario in [Scenario::Seu, Scenario::DoubleSeu, Scenario::Mbu { bits: 2 }] {
            let mut base = cfg(Backend::FullSoc, scenario, TileEngine::CycleResume);
            base.faults_per_layer = 2;
            base.inputs = 1;
            let resume = run_campaign(&model, &mesh, &base).unwrap();
            base.tile_engine = TileEngine::Full;
            let full = run_campaign(&model, &mesh, &base).unwrap();
            assert_eq!(resume.vuln.trials, 10, "full-soc/{dataflow}/{scenario}");
            assert_bit_identical(&resume, &full, &format!("full-soc/{dataflow}/{scenario}"));
            assert!(
                resume.rtl_cycles_stepped < full.rtl_cycles_stepped,
                "full-soc/{dataflow}/{scenario}: resumed SoC stepped {} cycles, full {}",
                resume.rtl_cycles_stepped,
                full.rtl_cycles_stepped
            );
        }
    }
}

/// Contract 3 (FullSoc worker invariance): the SoC resume cursor is
/// per-batch state and batches are the shard unit, so any worker count
/// reproduces the single-worker counts AND the deterministic
/// stepped-cycle accounting, both dataflows.
#[test]
fn prop_full_soc_cycle_resume_is_worker_invariant() {
    let model = models::quicknet(11);
    for dataflow in [Dataflow::OutputStationary, Dataflow::WeightStationary] {
        let mesh = MeshConfig { dim: 4, dataflow };
        let mut c = cfg(Backend::FullSoc, Scenario::Seu, TileEngine::CycleResume);
        c.faults_per_layer = 2;
        c.inputs = 1;
        c.workers = 1;
        let one = run_parallel(&model, &mesh, &c, None).unwrap();
        for workers in [2usize, 5] {
            c.workers = workers;
            let many = run_parallel(&model, &mesh, &c, None).unwrap();
            assert_bit_identical(
                &one,
                &many,
                &format!("full-soc/{dataflow} workers={workers}"),
            );
            assert_eq!(
                one.rtl_cycles_stepped, many.rtl_cycles_stepped,
                "full-soc/{dataflow} workers={workers}: accounting must be deterministic"
            );
        }
    }
}

/// Cycle-resume must beat the full tile engine on stepped RTL cycles
/// once trials share tiles — faults_per_layer=16 pigeonholes the
/// Linear site's 1x2 tile grid, so the saving is structural.
#[test]
fn prop_cycle_resume_steps_strictly_fewer_cycles() {
    let model = models::quicknet(11);
    let mesh = MeshConfig::default();
    let mut c = cfg(Backend::EnforSa, Scenario::Seu, TileEngine::CycleResume);
    c.faults_per_layer = 16;
    c.inputs = 1;
    let resume = run_campaign(&model, &mesh, &c).unwrap();
    c.tile_engine = TileEngine::Full;
    let full = run_campaign(&model, &mesh, &c).unwrap();
    assert_bit_identical(&resume, &full, "16-fault campaign");
    assert!(resume.rtl_cycles_stepped > 0);
    assert!(
        resume.rtl_cycles_stepped < full.rtl_cycles_stepped,
        "cycle-resume stepped {} cycles, full {}",
        resume.rtl_cycles_stepped,
        full.rtl_cycles_stepped
    );
}
