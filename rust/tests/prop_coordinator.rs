//! Property-based tests on coordinator invariants (routing, batching,
//! state management). The offline environment has no proptest crate, so
//! properties are checked over seeded random configuration sweeps with
//! the crate's own deterministic RNG — each case reports its seed on
//! failure for direct reproduction.

use enfor_sa::campaign::campaign::run_input;
use enfor_sa::campaign::{run_campaign, sample_trial};
use enfor_sa::config::{
    Backend, CampaignConfig, Dataflow, MeshConfig, OffloadScope, Scenario, TileEngine,
    TrialEngine,
};
use enfor_sa::coordinator::run_parallel;
use enfor_sa::dnn::models;
use enfor_sa::dnn::GemmSiteId;
use enfor_sa::util::Rng;

fn random_cfg(rng: &mut Rng) -> CampaignConfig {
    CampaignConfig {
        seed: rng.next_u64(),
        faults_per_layer: 1 + rng.below(4),
        inputs: 1 + rng.below(3),
        backend: Backend::EnforSa,
        offload_scope: if rng.chance(0.5) {
            OffloadScope::SingleTile
        } else {
            OffloadScope::Layer
        },
        // both trial engines must satisfy every coordinator property
        engine: if rng.chance(0.5) {
            TrialEngine::SiteResume
        } else {
            TrialEngine::FullForward
        },
        // ... and all three tile engines
        tile_engine: [
            TileEngine::Full,
            TileEngine::CycleResume,
            TileEngine::LaneLockstep,
        ][rng.usize_below(3)],
        // lane counts 1..=8: every one must be outcome-invariant
        lanes: 1 + rng.usize_below(8),
        signals: vec![],
        // every scenario must satisfy every coordinator property
        scenario: [
            Scenario::Seu,
            Scenario::Mbu { bits: 2 },
            Scenario::Burst { radius: 1 },
            Scenario::DoubleSeu,
            Scenario::StuckAt { value: false },
        ][rng.usize_below(5)],
        hardening: Default::default(),
        workers: 1 + rng.usize_below(4),
    }
}

/// Property: campaign outcomes are a pure function of (model, seed,
/// shape parameters) — never of worker count.
#[test]
fn prop_worker_count_never_changes_results() {
    let model = models::quicknet(3);
    let mesh = MeshConfig::default();
    let mut meta_rng = Rng::new(0x9001);
    for case in 0..6 {
        let mut cfg = random_cfg(&mut meta_rng);
        cfg.workers = 1;
        let base = run_parallel(&model, &mesh, &cfg, None).unwrap();
        for workers in [2usize, 3] {
            cfg.workers = workers;
            let got = run_parallel(&model, &mesh, &cfg, None).unwrap();
            assert_eq!(
                (base.vuln.trials, base.vuln.critical, base.exposed_trials),
                (got.vuln.trials, got.vuln.critical, got.exposed_trials),
                "case {case}: seed {} diverged at workers={workers}",
                cfg.seed
            );
        }
    }
}

/// Property: per-input work units partition the campaign exactly: the
/// merge of all run_input results equals the parallel run.
#[test]
fn prop_input_partition_is_exact() {
    let model = models::quicknet(3);
    let mesh = MeshConfig::default();
    let mut meta_rng = Rng::new(0x9A57);
    for _ in 0..4 {
        let mut cfg = random_cfg(&mut meta_rng);
        cfg.workers = 1;
        let whole = run_parallel(&model, &mesh, &cfg, None).unwrap();
        let mut manual_trials = 0;
        let mut manual_crit = 0;
        for i in 0..cfg.inputs {
            let part = run_input(&model, &mesh, &cfg, i).unwrap();
            manual_trials += part.vuln.trials;
            manual_crit += part.vuln.critical;
        }
        assert_eq!(whole.vuln.trials, manual_trials);
        assert_eq!(whole.vuln.critical, manual_crit);
    }
}

/// Property: trial sampling stays in bounds for arbitrary GEMM shapes
/// and mesh dims.
#[test]
fn prop_sampled_trials_always_in_bounds() {
    let mut rng = Rng::new(0xB07);
    for _ in 0..2000 {
        let m = 1 + rng.usize_below(300);
        let k = 1 + rng.usize_below(300);
        let n = 1 + rng.usize_below(300);
        let dim = [2, 4, 8, 16][rng.usize_below(4)];
        let site = GemmSiteId { layer: rng.usize_below(20), ordinal: 0 };
        let scenario = [
            Scenario::Seu,
            Scenario::Mbu { bits: 3 },
            Scenario::Burst { radius: 1 },
            Scenario::DoubleSeu,
            Scenario::StuckAt { value: true },
        ][rng.usize_below(5)];
        for dataflow in [Dataflow::OutputStationary, Dataflow::WeightStationary] {
            let t = sample_trial(scenario, dataflow, site, m, k, n, dim, &mut rng, &[]);
            let (tiles_i, tiles_j) =
                enfor_sa::mesh::driver::tile_grid(dataflow, dim, m, k, n);
            assert!(t.tile_i < tiles_i, "{dataflow}");
            assert!(t.tile_j < tiles_j, "{dataflow}");
            assert!(!t.plan.is_empty());
            for f in t.plan.faults() {
                assert!(f.addr.row < dim && f.addr.col < dim);
                assert!(f.bit < f.addr.kind.width());
                assert!(
                    f.cycle < enfor_sa::mesh::driver::matmul_cycles(dataflow, dim, m, k),
                    "{dataflow}"
                );
            }
        }
    }
}

/// Property: outcome classification is total — every trial lands in
/// exactly one of masked / exposed / critical.
#[test]
fn prop_outcomes_partition_trials() {
    let model = models::quicknet(3);
    let mesh = MeshConfig::default();
    let mut meta_rng = Rng::new(0x707A1);
    for _ in 0..4 {
        let cfg = random_cfg(&mut meta_rng);
        let r = run_campaign(&model, &mesh, &cfg).unwrap();
        assert_eq!(
            r.vuln.trials,
            r.masked_trials + r.exposed_trials + r.vuln.critical
        );
        let per_layer_sum: u64 = r.per_layer.values().map(|v| v.trials).sum();
        assert_eq!(per_layer_sum, r.vuln.trials, "per-layer routing lost trials");
    }
}

/// Property: the same campaign on different backends (mesh vs HDFIT)
/// yields identical outcome counts for any configuration.
#[test]
fn prop_backend_equivalence_random_configs() {
    let model = models::quicknet(3);
    let mesh = MeshConfig::default();
    let mut meta_rng = Rng::new(0xE9);
    for _ in 0..3 {
        let mut cfg = random_cfg(&mut meta_rng);
        cfg.offload_scope = OffloadScope::SingleTile;
        cfg.backend = Backend::EnforSa;
        let a = run_campaign(&model, &mesh, &cfg).unwrap();
        cfg.backend = Backend::Hdfit;
        let b = run_campaign(&model, &mesh, &cfg).unwrap();
        assert_eq!(a.vuln.critical, b.vuln.critical, "seed {}", cfg.seed);
        assert_eq!(a.exposed_trials, b.exposed_trials);
    }
}
