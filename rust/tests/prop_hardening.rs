//! Property tests pinning the hardening-evaluation axis (ROADMAP
//! "Hardening-evaluation axis").
//!
//! Contracts:
//! 1. `--hardening none` is the absence of the feature: fixed-seed
//!    campaigns produce byte-identical `report.json` text to the
//!    unhardened engine — zero verdict counters and no hardening keys
//!    in the report object.
//! 2. Hardened campaigns are bit-identical — verdict counters included
//!    — across all four tile engines, both dataflows and every worker
//!    sharding: mitigation happens at the deterministic splice seam,
//!    never in engine- or scheduling-dependent code.
//! 3. An ABFT `corrected` verdict means the tile region was restored
//!    bit-exactly, so the trial lands in `masked` with golden-equal
//!    logits: `masked(hardened) == masked(none) + corrected` and the
//!    struck set equals the none-baseline's exposed + critical.
//! 4. Control-path fault campaigns (`--signals control`) keep the same
//!    cross-engine and cross-worker bit-identity (lane engines fall
//!    back per batch, and batches are the sharding unit).

use enfor_sa::campaign::{run_campaign, CampaignResult};
use enfor_sa::config::{
    Backend, CampaignConfig, Dataflow, HardeningConfig, MeshConfig, OffloadScope,
    TileEngine, TrialEngine,
};
use enfor_sa::coordinator::run_parallel;
use enfor_sa::dnn::models;
use enfor_sa::report::campaign_report_json;

fn cfg(hardening: HardeningConfig) -> CampaignConfig {
    CampaignConfig {
        seed: 0x4A4D_E4,
        faults_per_layer: 12,
        inputs: 1,
        backend: Backend::EnforSa,
        offload_scope: OffloadScope::SingleTile,
        engine: TrialEngine::SiteResume,
        tile_engine: TileEngine::CycleResume,
        lanes: 8,
        signals: vec![],
        scenario: Default::default(),
        hardening,
        workers: 1,
    }
}

fn mesh_cfg(dataflow: Dataflow) -> MeshConfig {
    MeshConfig { dataflow, ..Default::default() }
}

const DATAFLOWS: [Dataflow; 2] = [Dataflow::OutputStationary, Dataflow::WeightStationary];

const ENGINES: [TileEngine; 4] = [
    TileEngine::Full,
    TileEngine::CycleResume,
    TileEngine::LaneLockstep,
    TileEngine::PackedLockstep,
];

/// Bit-identity including the mitigation-verdict counters.
fn assert_bit_identical(a: &CampaignResult, b: &CampaignResult, label: &str) {
    assert_eq!(a.vuln.trials, b.vuln.trials, "{label}: trials");
    assert_eq!(a.vuln.critical, b.vuln.critical, "{label}: critical");
    assert_eq!(a.exposed_trials, b.exposed_trials, "{label}: exposed");
    assert_eq!(a.masked_trials, b.masked_trials, "{label}: masked");
    assert_eq!(a.detected_trials, b.detected_trials, "{label}: detected");
    assert_eq!(a.corrected_trials, b.corrected_trials, "{label}: corrected");
    assert_eq!(a.escaped_trials, b.escaped_trials, "{label}: escaped");
    assert_eq!(a.per_layer.len(), b.per_layer.len(), "{label}: layer map size");
    for ((la, va), (lb, vb)) in a.per_layer.iter().zip(b.per_layer.iter()) {
        assert_eq!(la, lb, "{label}: layer ids");
        assert_eq!(va.trials, vb.trials, "{label}: layer {la} trials");
        assert_eq!(va.critical, vb.critical, "{label}: layer {la} critical");
    }
}

/// Contract 1: `--hardening none` report.json text is byte-identical to
/// the unhardened engine's — same counters, no hardening fields, stable
/// across repeated runs.
#[test]
fn prop_none_hardening_reports_are_byte_identical_to_unhardened() {
    let model = models::quicknet(5);
    let none = HardeningConfig::default();
    assert_eq!(HardeningConfig::parse("none"), Some(none));
    for dataflow in DATAFLOWS {
        let mc = mesh_cfg(dataflow);
        let c = cfg(none);
        let a = run_campaign(&model, &mc, &c).unwrap();
        let b = run_campaign(&model, &mc, &c).unwrap();
        // zero verdict counters: nothing in the engine consumed the axis
        assert_eq!(a.struck_trials(), 0, "{dataflow}: none must count no verdicts");
        let ta = campaign_report_json(&a, c.tile_engine, c.lanes, c.hardening).pretty();
        let tb = campaign_report_json(&b, c.tile_engine, c.lanes, c.hardening).pretty();
        assert_eq!(ta, tb, "{dataflow}: fixed-seed reports must be byte-identical");
        for key in ["hardening", "detected", "corrected", "escaped", "detection_coverage"] {
            assert!(
                !ta.contains(&format!("\"{key}\"")),
                "{dataflow}: a none report must not carry '{key}'"
            );
        }
    }
}

/// Contract 2: a hardened campaign agrees bit-exactly — verdicts
/// included — across all four tile engines and both dataflows.
#[test]
fn prop_hardened_campaigns_agree_across_engines_and_dataflows() {
    let model = models::quicknet(5);
    let h = HardeningConfig::parse("clip:-65536,65535+abft+detect").unwrap();
    for dataflow in DATAFLOWS {
        let mc = mesh_cfg(dataflow);
        let mut oracle_cfg = cfg(h);
        oracle_cfg.tile_engine = TileEngine::Full;
        let oracle = run_campaign(&model, &mc, &oracle_cfg).unwrap();
        assert!(
            oracle.struck_trials() > 0,
            "{dataflow}: the budget must strike something, or the pin is vacuous"
        );
        for engine in ENGINES {
            let mut c = cfg(h);
            c.tile_engine = engine;
            let r = run_campaign(&model, &mc, &c).unwrap();
            assert_bit_identical(&oracle, &r, &format!("{dataflow}/{engine:?}"));
        }
    }
}

/// Contract 2 (worker axis): hardened campaigns are worker-count
/// invariant, verdict counters included.
#[test]
fn prop_hardened_campaigns_are_worker_count_invariant() {
    let model = models::quicknet(5);
    let h = HardeningConfig::parse("abft+detect").unwrap();
    for dataflow in DATAFLOWS {
        let mc = mesh_cfg(dataflow);
        let mut base = cfg(h);
        base.inputs = 2;
        base.tile_engine = TileEngine::PackedLockstep;
        let one = run_parallel(&model, &mc, &base, None).unwrap();
        for workers in [2usize, 3] {
            let mut sharded = base.clone();
            sharded.workers = workers;
            let w = run_parallel(&model, &mc, &sharded, None).unwrap();
            assert_bit_identical(&one, &w, &format!("{dataflow}/workers={workers}"));
        }
    }
}

/// Contract 3: ABFT corrections restore the tile bit-exactly, so every
/// corrected trial lands in `masked` (golden-equal logits) and the
/// hardened struck set equals the none-baseline's exposed + critical.
#[test]
fn prop_abft_corrected_trials_become_masked_with_golden_logits() {
    let model = models::quicknet(5);
    for dataflow in DATAFLOWS {
        let mc = mesh_cfg(dataflow);
        // 24 faults/layer: enough seu strikes that at least one is a
        // single-element accumulator corruption ABFT can correct, on
        // both dataflows
        let mut none_cfg = cfg(HardeningConfig::default());
        none_cfg.faults_per_layer = 24;
        let none = run_campaign(&model, &mc, &none_cfg).unwrap();
        let mut hard_cfg = cfg(HardeningConfig::parse("abft+detect").unwrap());
        hard_cfg.faults_per_layer = 24;
        let hard = run_campaign(&model, &mc, &hard_cfg).unwrap();
        assert_eq!(hard.vuln.trials, none.vuln.trials, "{dataflow}: same plans");
        assert_eq!(
            hard.struck_trials(),
            none.exposed_trials + none.vuln.critical,
            "{dataflow}: struck set is decided before mitigation"
        );
        assert!(
            hard.corrected_trials > 0,
            "{dataflow}: seu strikes are single-delta corruptions ABFT can correct"
        );
        assert_eq!(
            hard.masked_trials,
            none.masked_trials + hard.corrected_trials,
            "{dataflow}: a corrected region splices nothing, so the trial is masked"
        );
        assert!(
            hard.vuln.critical <= none.vuln.critical,
            "{dataflow}: correction can only remove SDCs, never add them"
        );
        assert!(hard.detection_coverage() > 0.0 && hard.detection_coverage() <= 1.0);
        assert!(hard.correction_coverage() <= hard.detection_coverage());
    }
}

/// Contract 2 + 4: a campaign targeting the control path (tile
/// sequencer / drain-FSM counters) with hardening armed stays
/// bit-identical across every tile engine and worker sharding — lane
/// engines fall back per batch, and batches are the sharding unit.
#[test]
fn prop_control_fault_campaigns_agree_across_engines_and_workers() {
    let model = models::quicknet(5);
    let h = HardeningConfig::parse("abft").unwrap();
    for dataflow in DATAFLOWS {
        let mc = mesh_cfg(dataflow);
        let mut oracle_cfg = cfg(h);
        oracle_cfg.signals = vec!["control".into()];
        oracle_cfg.tile_engine = TileEngine::Full;
        let oracle = run_campaign(&model, &mc, &oracle_cfg).unwrap();
        for engine in ENGINES {
            let mut c = oracle_cfg.clone();
            c.tile_engine = engine;
            let r = run_campaign(&model, &mc, &c).unwrap();
            assert_bit_identical(&oracle, &r, &format!("{dataflow}/control/{engine:?}"));
        }
        let mut base = oracle_cfg.clone();
        base.tile_engine = TileEngine::PackedLockstep;
        base.inputs = 2;
        let one = run_parallel(&model, &mc, &base, None).unwrap();
        for workers in [2usize, 3] {
            let mut sharded = base.clone();
            sharded.workers = workers;
            let w = run_parallel(&model, &mc, &sharded, None).unwrap();
            assert_bit_identical(
                &one,
                &w,
                &format!("{dataflow}/control/workers={workers}"),
            );
        }
    }
}
