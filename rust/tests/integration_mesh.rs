//! Integration tests over the mesh substrate: both dataflows, tiling,
//! masking properties, and the fault model's structural behaviours.

use enfor_sa::config::Dataflow;
use enfor_sa::mat::Mat;
use enfor_sa::mesh::driver::{gold_matmul, os_matmul_cycles, tiled_matmul_os, MatmulDriver};
use enfor_sa::mesh::{Fault, Mesh, MeshSim, SignalKind};
use enfor_sa::util::Rng;

#[test]
fn os_matmul_fuzz_many_shapes() {
    let mut rng = Rng::new(0x0501);
    for trial in 0..60 {
        let dim = [2, 3, 4, 8][trial % 4];
        let k = 1 + rng.usize_below(40);
        let mut mesh = Mesh::new(dim, Dataflow::OutputStationary);
        let a = rng.mat_i8(dim, k);
        let b = rng.mat_i8(k, dim);
        let d = rng.mat_i32(dim, dim, 1 << 14);
        assert_eq!(
            MatmulDriver::new(&mut mesh).matmul(a.view(), b.view(), d.view()),
            gold_matmul(a.view(), b.view(), d.view()),
            "dim={dim} k={k}"
        );
    }
}

#[test]
fn ws_matmul_fuzz_many_shapes() {
    let mut rng = Rng::new(0x0502);
    for trial in 0..40 {
        let dim = [2, 4, 8][trial % 3];
        let m = 1 + rng.usize_below(30);
        let mut mesh = Mesh::new(dim, Dataflow::WeightStationary);
        let a = rng.mat_i8(m, dim);
        let w = rng.mat_i8(dim, dim);
        let d = rng.mat_i32(m, dim, 1 << 14);
        assert_eq!(
            MatmulDriver::new(&mut mesh).matmul(a.view(), w.view(), d.view()),
            gold_matmul(a.view(), w.view(), d.view()),
            "dim={dim} m={m}"
        );
    }
}

#[test]
fn os_and_ws_agree_on_square_problems() {
    let mut rng = Rng::new(0x0503);
    for _ in 0..10 {
        let dim = 4;
        let a = rng.mat_i8(dim, dim);
        let b = rng.mat_i8(dim, dim);
        let d = rng.mat_i32(dim, dim, 100);
        let mut os = Mesh::new(dim, Dataflow::OutputStationary);
        let mut ws = Mesh::new(dim, Dataflow::WeightStationary);
        let c_os = MatmulDriver::new(&mut os).matmul(a.view(), b.view(), d.view());
        let c_ws = MatmulDriver::new(&mut ws).matmul(a.view(), b.view(), d.view());
        assert_eq!(c_os, c_ws);
    }
}

#[test]
fn tiled_matmul_fuzz() {
    let mut rng = Rng::new(0x0504);
    let mut mesh = Mesh::new(8, Dataflow::OutputStationary);
    for _ in 0..12 {
        let m = 1 + rng.usize_below(40);
        let k = 1 + rng.usize_below(40);
        let n = 1 + rng.usize_below(40);
        let a = rng.mat_i8(m, k);
        let b = rng.mat_i8(k, n);
        let d = rng.mat_i32(m, n, 1000);
        assert_eq!(
            tiled_matmul_os(&mut mesh, a.view(), b.view(), d.view()),
            gold_matmul(a.view(), b.view(), d.view()),
            "m={m} k={k} n={n}"
        );
    }
}

#[test]
fn every_signal_kind_can_corrupt_an_output() {
    // For each signal kind there must exist a (cycle, bit) that visibly
    // corrupts some matmul — no signal class is dead in the fault model.
    let dim = 4;
    let mut rng = Rng::new(0x0505);
    let a = rng.mat_i8(dim, dim);
    let b = Mat::from_fn(dim, dim, |_, _| rng.i8().max(1));
    let d = rng.mat_i32(dim, dim, 50);
    let mut mesh = Mesh::new(dim, Dataflow::OutputStationary);
    let golden = MatmulDriver::new(&mut mesh).matmul(a.view(), b.view(), d.view());
    for kind in SignalKind::ALL {
        let mut hit = false;
        'outer: for cycle in 0..os_matmul_cycles(dim, dim) {
            for bit in 0..kind.width().min(8) {
                let f = Fault::new(1, 1, kind, bit, cycle);
                let faulty = MatmulDriver::new(&mut mesh)
                    .matmul_with_fault(a.view(), b.view(), d.view(), &f);
                if faulty != golden {
                    hit = true;
                    break 'outer;
                }
            }
        }
        assert!(hit, "signal kind {kind} never corrupted any output");
    }
}

#[test]
fn fault_free_rerun_after_fault_is_clean() {
    // no state leaks across driver invocations
    let dim = 8;
    let mut rng = Rng::new(0x0506);
    let mut mesh = Mesh::new(dim, Dataflow::OutputStationary);
    let a = rng.mat_i8(dim, dim);
    let b = rng.mat_i8(dim, dim);
    let d = rng.mat_i32(dim, dim, 100);
    let golden = MatmulDriver::new(&mut mesh).matmul(a.view(), b.view(), d.view());
    for kind in SignalKind::ALL {
        let f = Fault::new(2, 3, kind, 0, 10);
        let _ = MatmulDriver::new(&mut mesh).matmul_with_fault(a.view(), b.view(), d.view(), &f);
        assert_eq!(
            MatmulDriver::new(&mut mesh).matmul(a.view(), b.view(), d.view()),
            golden,
            "state leaked after {kind} fault"
        );
    }
}

#[test]
fn weight_fault_row_locality() {
    // A weight-path fault in row r must corrupt only output row r (the
    // corrupted operand travels east within its row in OS dataflow).
    let dim = 4;
    let mut rng = Rng::new(0x0507);
    let a = rng.mat_i8(dim, dim);
    let b = Mat::from_fn(dim, dim, |_, _| rng.i8() | 1);
    let d = rng.mat_i32(dim, dim, 10);
    let mut mesh = Mesh::new(dim, Dataflow::OutputStationary);
    let golden = MatmulDriver::new(&mut mesh).matmul(a.view(), b.view(), d.view());
    let mut corrupted_rows = std::collections::BTreeSet::new();
    for cycle in 0..os_matmul_cycles(dim, dim) {
        let f = Fault::new(2, 1, SignalKind::Weight, 5, cycle);
        let faulty =
            MatmulDriver::new(&mut mesh).matmul_with_fault(a.view(), b.view(), d.view(), &f);
        for (r, (fr, gr)) in faulty.row_iter().zip(golden.row_iter()).enumerate() {
            if fr != gr {
                corrupted_rows.insert(r);
            }
        }
    }
    assert!(!corrupted_rows.is_empty());
    assert_eq!(
        corrupted_rows.into_iter().collect::<Vec<_>>(),
        vec![2],
        "weight fault must stay in its mesh row"
    );
}

#[test]
fn act_fault_column_locality() {
    // Symmetric: an activation-path fault in column c corrupts only
    // output column c.
    let dim = 4;
    let mut rng = Rng::new(0x0508);
    let a = Mat::from_fn(dim, dim, |_, _| rng.i8() | 1);
    let b = rng.mat_i8(dim, dim);
    let d = rng.mat_i32(dim, dim, 10);
    let mut mesh = Mesh::new(dim, Dataflow::OutputStationary);
    let golden = MatmulDriver::new(&mut mesh).matmul(a.view(), b.view(), d.view());
    let mut corrupted_cols = std::collections::BTreeSet::new();
    for cycle in 0..os_matmul_cycles(dim, dim) {
        let f = Fault::new(1, 2, SignalKind::Act, 5, cycle);
        let faulty =
            MatmulDriver::new(&mut mesh).matmul_with_fault(a.view(), b.view(), d.view(), &f);
        for r in 0..dim {
            for c in 0..dim {
                if faulty[(r, c)] != golden[(r, c)] {
                    corrupted_cols.insert(c);
                }
            }
        }
    }
    assert!(!corrupted_cols.is_empty());
    assert_eq!(
        corrupted_cols.into_iter().collect::<Vec<_>>(),
        vec![2],
        "act fault must stay in its mesh column"
    );
}

#[test]
fn single_bit_hw_fault_can_produce_multibit_sw_error() {
    // The paper's core motivation for HW-aware injection: one flipped
    // register bit can corrupt MANY output values/bits.
    let dim = 4;
    let mut rng = Rng::new(0x0509);
    let a = rng.mat_i8(dim, dim);
    let b = Mat::from_fn(dim, dim, |_, _| rng.i8() | 1);
    let d = rng.mat_i32(dim, dim, 10);
    let mut mesh = Mesh::new(dim, Dataflow::OutputStationary);
    let golden = MatmulDriver::new(&mut mesh).matmul(a.view(), b.view(), d.view());
    // a propag fault mid-compute hijacks the whole column below
    let f = Fault::new(0, 1, SignalKind::Propag, 0, (2 * dim) as u64 + 2);
    let faulty = MatmulDriver::new(&mut mesh).matmul_with_fault(a.view(), b.view(), d.view(), &f);
    let diffs = faulty
        .data()
        .iter()
        .zip(golden.data())
        .filter(|(x, y)| x != y)
        .count();
    assert!(
        diffs > 1,
        "a single control-bit flip must corrupt multiple outputs, got {diffs}"
    );
}

#[test]
fn cycle_accounting_matches_formula_across_dims() {
    let mut rng = Rng::new(0x050A);
    for &(dim, k) in &[(2usize, 5usize), (4, 4), (8, 16), (16, 8)] {
        let mut mesh = Mesh::new(dim, Dataflow::OutputStationary);
        let a = rng.mat_i8(dim, k);
        let b = rng.mat_i8(k, dim);
        let d = rng.mat_i32(dim, dim, 10);
        MatmulDriver::new(&mut mesh).matmul(a.view(), b.view(), d.view());
        assert_eq!(mesh.cycle(), os_matmul_cycles(dim, k));
    }
}

#[test]
fn stuck_at_fault_corrupts_persistently() {
    // Extension: a stuck-at-1 weight-path bit corrupts MANY stream
    // elements (vs a transient's single element), and a stuck-at fault
    // re-applied every cycle is strictly at least as damaging.
    use enfor_sa::mesh::inject::Persistence;
    let dim = 4;
    let mut rng = Rng::new(0x57AC);
    let a = rng.mat_i8(dim, 12);
    let b = Mat::from_fn(12, dim, |_, _| rng.i8() | 1);
    let d = rng.mat_i32(dim, dim, 10);
    let mut mesh = Mesh::new(dim, Dataflow::OutputStationary);
    let golden = MatmulDriver::new(&mut mesh).matmul(a.view(), b.view(), d.view());

    let sa = Fault::stuck_at(1, 1, SignalKind::Weight, 6, true, 0);
    assert_eq!(sa.persistence, Persistence::StuckAt(true));
    assert!(sa.fires_at(0) && sa.fires_at(100));
    let faulty = MatmulDriver::new(&mut mesh).matmul_with_fault(a.view(), b.view(), d.view(), &sa);
    // row 1 outputs east of column 0 must be corrupted
    let row_diffs = faulty
        .row(1)
        .iter()
        .zip(golden.row(1))
        .filter(|(x, y)| x != y)
        .count();
    assert!(row_diffs >= 2, "stuck-at weight bit corrupted {row_diffs} outputs");
    // transient at one cycle corrupts no more than the stuck-at does
    let tr = Fault::new(1, 1, SignalKind::Weight, 6, (2 * dim) as u64 + 2);
    let faulty_tr =
        MatmulDriver::new(&mut mesh).matmul_with_fault(a.view(), b.view(), d.view(), &tr);
    let tr_diffs = faulty_tr
        .data()
        .iter()
        .zip(golden.data())
        .filter(|(x, y)| x != y)
        .count();
    let sa_diffs = faulty
        .data()
        .iter()
        .zip(golden.data())
        .filter(|(x, y)| x != y)
        .count();
    assert!(sa_diffs >= tr_diffs);
}

#[test]
fn stuck_at_zero_on_zero_bit_is_masked() {
    // forcing a bit to the value it already has must be invisible
    let dim = 4;
    let a: Mat<i8> = Mat::zeros(dim, dim);
    let b: Mat<i8> = Mat::zeros(dim, dim);
    let d: Mat<i32> = Mat::zeros(dim, dim);
    let mut mesh = Mesh::new(dim, Dataflow::OutputStationary);
    let golden = MatmulDriver::new(&mut mesh).matmul(a.view(), b.view(), d.view());
    let sa = Fault::stuck_at(2, 2, SignalKind::Acc, 5, false, 0);
    let faulty = MatmulDriver::new(&mut mesh).matmul_with_fault(a.view(), b.view(), d.view(), &sa);
    assert_eq!(golden, faulty);
}

#[test]
fn stuck_at_no_state_leak_after_disarm() {
    let dim = 4;
    let mut rng = Rng::new(0x57AD);
    let a = rng.mat_i8(dim, dim);
    let b = rng.mat_i8(dim, dim);
    let d = rng.mat_i32(dim, dim, 10);
    let mut mesh = Mesh::new(dim, Dataflow::OutputStationary);
    let golden = MatmulDriver::new(&mut mesh).matmul(a.view(), b.view(), d.view());
    let sa = Fault::stuck_at(0, 0, SignalKind::Acc, 30, true, 0);
    let _ = MatmulDriver::new(&mut mesh).matmul_with_fault(a.view(), b.view(), d.view(), &sa);
    assert_eq!(
        MatmulDriver::new(&mut mesh).matmul(a.view(), b.view(), d.view()),
        golden
    );
}
