//! Bench: Table V — full forward pass of the (scaled) ResNet50 first
//! convolution layer on three backends: ENFOR-SA mesh-only, the full-SoC
//! simulation, and the HDFIT-instrumented mesh.
//!
//! Run: `cargo bench --bench layer_forward` (env BENCH_DIMS="4,8" to
//! restrict — full-SoC at DIM64 takes a while).

use enfor_sa::benchkit::layer_forward;

fn main() {
    let dims: Vec<usize> = std::env::var("BENCH_DIMS")
        .ok()
        .map(|s| {
            s.split(',')
                .filter_map(|x| x.trim().parse().ok())
                .collect()
        })
        .unwrap_or_else(|| vec![4, 8, 16, 32, 64]);
    println!("TABLE V: ResNet50 conv1 full forward pass (im2col: 256x27x24)");
    println!(
        "{:<8} {:>12} {:>12} {:>12} {:>12} {:>10}",
        "Array", "ENFOR-SA", "Full SoC", "vs SoC", "HDFIT", "vs HDFIT"
    );
    let rows = layer_forward(&dims).expect("layer bench");
    for r in &rows {
        println!(
            "DIM{:<5} {:>11.4}s {:>11.4}s {:>11.1}x {:>11.4}s {:>9.2}x",
            r.dim,
            r.enforsa_s,
            r.full_soc_s,
            r.vs_full_soc(),
            r.hdfit_s,
            r.vs_hdfit()
        );
    }
    for r in &rows {
        println!(
            "CSV,layer_forward,{},{:.6},{:.6},{:.6},{:.3},{:.3}",
            r.dim,
            r.enforsa_s,
            r.full_soc_s,
            r.hdfit_s,
            r.vs_full_soc(),
            r.vs_hdfit()
        );
    }
}
