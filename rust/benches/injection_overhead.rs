//! Bench: Table VI — campaign injection time (SW-only vs ENFOR-SA
//! cross-layer) and the AVF/PVF vulnerability factors, per model.
//!
//! The paper runs 500 faults/layer/input over 640 ImageNet inputs
//! (~42M faults, hours per model); this scaled harness defaults to a
//! few hundred trials per model — override with env:
//!   BENCH_FAULTS=..  BENCH_INPUTS=..  BENCH_MODELS=quicknet,ResNet18
//!   BENCH_SCENARIO=seu|mbu:<k>|burst:<r>|double-seu|stuck:<0|1>
//!   BENCH_DATAFLOW=os|ws|both   (default both: one Table-VI row set
//!                                per dataflow)
//!   BENCH_LANES=<n>             (lane count of the lane-lockstep and
//!                                packed-lockstep campaign arms —
//!                                schema v6/v9; default 8, n=1
//!                                degenerates to cycle-resume)
//!
//! Each row also runs the whole-SoC campaign pair (schema v7):
//! cycle-resume vs full tile engine on the FullSoc backend, reported as
//! `soc_cycle_resume_speedup` plus the wall-clock `soc_vs_sw_slowdown`,
//! and the durable-journal pair (schema v8): the same campaign through
//! the coordinator's in-memory sink vs journaled to a scratch campaign
//! dir (manifest + per-batch fsynced JSONL + report), reported as
//! `journal_overhead` — CI's bench smoke asserts its mean stays < 1.10.
//! Schema v9 adds the cross-tile packer arm: `packed_lockstep_speedup`
//! (RTL cycles lockstep steps over the packer's, deterministic per
//! seed) and the lane-occupancy pair — CI's bench smoke asserts the
//! packed mean speedup > 1 and the occupancy improvement at
//! BENCH_FAULTS=2.
//!
//! Set BENCH_OUT=path.json to also write a machine-readable snapshot
//! (`benchkit::injection_snapshot_json` — the schema stored under
//! `benchmarks/BENCH_injection_overhead.json`) so the RTL-offload
//! overhead trajectory can be diffed across PRs.
//!
//! Run: `cargo bench --bench injection_overhead`

use enfor_sa::benchkit::{injection_snapshot_json, injection_table_dataflows};
use enfor_sa::config::{CampaignConfig, Dataflow, MeshConfig, Scenario};
use enfor_sa::dnn::models;
use enfor_sa::report::human_time;

fn main() {
    let faults: u64 = std::env::var("BENCH_FAULTS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(8);
    let inputs: u64 = std::env::var("BENCH_INPUTS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(2);
    let names: Vec<String> = std::env::var("BENCH_MODELS")
        .ok()
        .map(|s| s.split(',').map(str::to_string).collect())
        .unwrap_or_else(|| {
            models::TABLE_II
                .iter()
                .map(|i| i.name.to_string())
                .collect()
        });
    let scenario = std::env::var("BENCH_SCENARIO")
        .ok()
        .map(|s| Scenario::parse(&s).expect("bad BENCH_SCENARIO"))
        .unwrap_or_default();
    let dataflows: Vec<Dataflow> = match std::env::var("BENCH_DATAFLOW").ok().as_deref() {
        None | Some("both") => {
            vec![Dataflow::OutputStationary, Dataflow::WeightStationary]
        }
        Some(s) => vec![Dataflow::parse(s).expect("bad BENCH_DATAFLOW (os|ws|both)")],
    };
    let lanes: usize = std::env::var("BENCH_LANES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(8);
    let mesh_cfg = MeshConfig::default();
    let cc = CampaignConfig {
        faults_per_layer: faults,
        inputs,
        scenario,
        lanes,
        ..Default::default()
    };
    println!(
        "TABLE VI: injection time + AVF/PVF ({faults} faults/layer/input, {inputs} inputs, \
         scenario {scenario}, DIM8, dataflows {dataflows:?}, {lanes} lanes)"
    );
    println!(
        "{:<16} {:>4} {:>12} {:>14} {:>10} {:>8} {:>8} {:>10} {:>9} {:>12} {:>8} {:>8} {:>8} \
         {:>6} {:>8} {:>8} {:>8}",
        "Model", "DF", "SW", "ENFOR-SA(RTL)", "Slowdown", "PVF", "AVF", "trials/s",
        "resume-x", "rtl-cycles", "tile-x", "lock-x", "pack-x", "occ", "soc-x", "soc/sw", "jrnl-x"
    );
    let rows = injection_table_dataflows(&names, &mesh_cfg, &cc, &dataflows).expect("campaigns");
    for r in &rows {
        println!(
            "{:<16} {:>4} {:>12} {:>14} {:>9.2}% {:>7.2}% {:>7.2}% {:>10.1} {:>8.2}x {:>12} \
             {:>7.2}x {:>7.2}x {:>7.2}x {:>6.2} {:>7.2}x {:>7.2}x {:>7.2}x",
            r.model,
            r.dataflow,
            human_time(r.sw.wall.as_secs_f64()),
            human_time(r.rtl.wall.as_secs_f64()),
            r.slowdown_pct(),
            r.pvf_pct(),
            r.avf_pct(),
            r.trials_per_sec(),
            r.resume_speedup_vs_full_forward(),
            r.rtl_cycles_stepped(),
            r.cycle_resume_speedup(),
            r.lockstep_speedup(),
            r.packed_lockstep_speedup(),
            r.lane_occupancy(),
            r.soc_cycle_resume_speedup(),
            r.soc_vs_sw_slowdown(),
            r.journal_overhead()
        );
    }
    let n = rows.len() as f64;
    println!(
        "Mean: slowdown {:.2}%  PVF {:.2}%  AVF {:.2}%  resume speedup {:.2}x  \
         cycle-resume speedup {:.2}x  lockstep speedup {:.2}x  \
         packed speedup {:.2}x  occupancy {:.2} (lockstep {:.2})  \
         SoC cycle-resume speedup {:.2}x  SoC-vs-SW slowdown {:.2}x  \
         journal overhead {:.3}x",
        rows.iter().map(|r| r.slowdown_pct()).sum::<f64>() / n,
        rows.iter().map(|r| r.pvf_pct()).sum::<f64>() / n,
        rows.iter().map(|r| r.avf_pct()).sum::<f64>() / n,
        rows.iter()
            .map(|r| r.resume_speedup_vs_full_forward())
            .sum::<f64>()
            / n,
        rows.iter().map(|r| r.cycle_resume_speedup()).sum::<f64>() / n,
        rows.iter().map(|r| r.lockstep_speedup()).sum::<f64>() / n,
        rows.iter().map(|r| r.packed_lockstep_speedup()).sum::<f64>() / n,
        rows.iter().map(|r| r.lane_occupancy()).sum::<f64>() / n,
        rows.iter().map(|r| r.lane_occupancy_lockstep()).sum::<f64>() / n,
        rows.iter().map(|r| r.soc_cycle_resume_speedup()).sum::<f64>() / n,
        rows.iter().map(|r| r.soc_vs_sw_slowdown()).sum::<f64>() / n,
        rows.iter().map(|r| r.journal_overhead()).sum::<f64>() / n,
    );
    for r in &rows {
        println!(
            "CSV,injection,{},{},{:.6},{:.6},{:.3},{:.4},{:.4},{:.3},{:.4},{},{:.4},{},{:.4},\
             {:.4},{:.4},{:.4},{:.4},{:.4},{:.4}",
            r.model,
            r.dataflow,
            r.sw.wall.as_secs_f64(),
            r.rtl.wall.as_secs_f64(),
            r.slowdown_pct(),
            r.pvf_pct(),
            r.avf_pct(),
            r.trials_per_sec(),
            r.resume_speedup_vs_full_forward(),
            r.rtl_cycles_stepped(),
            r.cycle_resume_speedup(),
            r.lanes,
            r.lockstep_speedup(),
            r.packed_lockstep_speedup(),
            r.lane_occupancy(),
            r.lane_occupancy_lockstep(),
            r.soc_cycle_resume_speedup(),
            r.soc_vs_sw_slowdown(),
            r.journal_overhead()
        );
    }
    if let Ok(path) = std::env::var("BENCH_OUT") {
        let label = std::env::var("BENCH_LABEL").unwrap_or_else(|_| "local".to_string());
        let snap = injection_snapshot_json(&rows, faults, inputs, scenario, &label);
        std::fs::write(&path, snap.pretty()).expect("writing BENCH_OUT snapshot");
        eprintln!("wrote snapshot {path}");
    }
}
