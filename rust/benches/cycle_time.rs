//! Bench: Table III — mean cycle time of raw `step()` calls,
//! ENFOR-SA mesh vs HDFIT-instrumented mesh, across array sizes.
//!
//! Includes the D1 ablation: a third variant with a *cold* armed-fault
//! check (branch present, never taken) to separate the branch cost from
//! HDFIT's full per-assignment bookkeeping.
//!
//! Run: `cargo bench --bench cycle_time` (env BENCH_CYCLES to override).

use enfor_sa::benchkit::cycle_time;
use enfor_sa::config::Dataflow;
use enfor_sa::mesh::inject::idle_cycles;
use enfor_sa::mesh::{Fault, Mesh, MeshInputs, MeshSim, SignalKind, StepOutput};
use std::time::Instant;

fn main() {
    let cycles: u64 = std::env::var("BENCH_CYCLES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1_000_000);
    let dims = [4usize, 8, 16, 32, 64];
    println!("TABLE III: mean cycle time over {cycles} raw step() calls");
    println!(
        "{:<10} {:>14} {:>14} {:>12} {:>18}",
        "Array", "ENFOR-SA", "HDFIT", "Improvement", "branch-check abl."
    );
    let rows = cycle_time(&dims, cycles);
    for row in &rows {
        // D1 ablation: ENFOR-SA step + one per-cycle fault compare (the
        // wrapper branch) — the entire injection overhead of the method.
        let mut mesh = Mesh::new(row.dim, Dataflow::OutputStationary);
        let inp = MeshInputs::idle(row.dim);
        let mut out = StepOutput::new(row.dim);
        let fault = Fault::new(0, 0, SignalKind::Acc, 0, u64::MAX); // never fires
        let t0 = Instant::now();
        for t in 0..cycles {
            if fault.cycle == t {
                unreachable!();
            }
            mesh.step(&inp, &mut out);
        }
        let branch_us = t0.elapsed().as_secs_f64() * 1e6 / cycles as f64;
        std::hint::black_box(mesh.acc_at(0, 0));
        println!(
            "DIM{:<7} {:>12.3}us {:>12.3}us {:>11.2}x {:>16.3}us",
            row.dim,
            row.enforsa_us,
            row.hdfit_us,
            row.improvement(),
            branch_us
        );
    }
    // quick machine-readable block for EXPERIMENTS.md tooling
    for row in &rows {
        println!(
            "CSV,cycle_time,{},{:.6},{:.6},{:.3}",
            row.dim,
            row.enforsa_us,
            row.hdfit_us,
            row.improvement()
        );
    }
    idle_cycles(&mut Mesh::new(4, Dataflow::OutputStationary), 1); // keep linked
}
