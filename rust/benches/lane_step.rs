//! Bench: lane step kernels — ns per mesh cycle and per lane-cycle of
//! the SoA lane mesh, across lane counts and both dataflows, against
//! the scalar `Mesh::step` baseline.
//!
//! The lane kernels walk each row in fixed-width `LANE_BLOCK` chunks
//! (plus a scalar remainder), so wider lane meshes should amortize
//! toward a flat per-lane-cycle cost; the `eff` column is the scalar
//! baseline's per-cycle time divided by the lane mesh's per-lane-cycle
//! time (> 1 means one lane-mesh lane is cheaper than one scalar mesh).
//!
//! Env knobs: BENCH_CYCLES (default 200k), BENCH_DIM (default 8),
//! BENCH_LANE_COUNTS (default 1,8,16). Set BENCH_OUT=path.json to write
//! a machine-readable snapshot (schema enfor-sa/lane-step/v1) for CI's
//! bench smoke.
//!
//! Run: `cargo bench --bench lane_step`

use enfor_sa::config::Dataflow;
use enfor_sa::mesh::{LaneMesh, Mesh, MeshInputs, MeshSim, MeshState, StepOutput};
use enfor_sa::util::json::Json;
use std::time::Instant;

fn main() {
    let cycles: u64 = std::env::var("BENCH_CYCLES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(200_000);
    let dim: usize = std::env::var("BENCH_DIM")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(8);
    let lane_counts: Vec<usize> = std::env::var("BENCH_LANE_COUNTS")
        .ok()
        .map(|s| {
            s.split(',')
                .map(|v| v.parse().expect("bad BENCH_LANE_COUNTS"))
                .collect()
        })
        .unwrap_or_else(|| vec![1, 8, 16]);
    println!("lane step kernels: DIM{dim}, {cycles} cycles per variant");
    println!(
        "{:<4} {:>6} {:>14} {:>18} {:>8}",
        "DF", "lanes", "ns/cycle", "ns/lane-cycle", "eff"
    );
    let inp = MeshInputs::idle(dim);
    let mut rows = Vec::new();
    for dataflow in [Dataflow::OutputStationary, Dataflow::WeightStationary] {
        // scalar baseline: the single-mesh step the lane kernels replace
        let mut mesh = Mesh::new(dim, dataflow);
        let mut out = StepOutput::new(dim);
        let t0 = Instant::now();
        for _ in 0..cycles {
            mesh.step(&inp, &mut out);
        }
        let scalar_ns = t0.elapsed().as_secs_f64() * 1e9 / cycles as f64;
        std::hint::black_box(mesh.acc_at(0, 0));
        // seed the lane broadcast from a mid-flight scalar snapshot so
        // registers carry real values, matching how chunks start
        let mut state = MeshState::default();
        mesh.save_state(&mut state);
        println!(
            "{:<4} {:>6} {:>12.1}ns {:>16.1}ns {:>7.2}x",
            dataflow, "-", scalar_ns, scalar_ns, 1.0
        );
        rows.push(Json::obj(vec![
            ("dataflow", Json::str(dataflow.to_string())),
            ("lanes", Json::num(0.0)),
            ("ns_per_cycle", Json::num(scalar_ns)),
            ("ns_per_lane_cycle", Json::num(scalar_ns)),
            ("lane_efficiency", Json::num(1.0)),
        ]));
        for &lanes in &lane_counts {
            let mut lm = LaneMesh::new(dim, dataflow);
            lm.reshape(lanes);
            lm.broadcast(&state);
            let t0 = Instant::now();
            for _ in 0..cycles {
                lm.begin_cycle(&inp);
                lm.step();
            }
            let step_ns = t0.elapsed().as_secs_f64() * 1e9 / cycles as f64;
            let lane_ns = step_ns / lanes as f64;
            let eff = scalar_ns / lane_ns;
            std::hint::black_box(lm.acc_at(0, 0, 0));
            println!(
                "{:<4} {:>6} {:>12.1}ns {:>16.1}ns {:>7.2}x",
                dataflow, lanes, step_ns, lane_ns, eff
            );
            rows.push(Json::obj(vec![
                ("dataflow", Json::str(dataflow.to_string())),
                ("lanes", Json::num(lanes as f64)),
                ("ns_per_cycle", Json::num(step_ns)),
                ("ns_per_lane_cycle", Json::num(lane_ns)),
                ("lane_efficiency", Json::num(eff)),
            ]));
        }
    }
    for r in &rows {
        println!(
            "CSV,lane_step,{},{},{:.3},{:.3},{:.4}",
            r.get("dataflow").and_then(Json::as_str).unwrap(),
            r.get("lanes").and_then(Json::as_f64).unwrap() as u64,
            r.get("ns_per_cycle").and_then(Json::as_f64).unwrap(),
            r.get("ns_per_lane_cycle").and_then(Json::as_f64).unwrap(),
            r.get("lane_efficiency").and_then(Json::as_f64).unwrap(),
        );
    }
    if let Ok(path) = std::env::var("BENCH_OUT") {
        let label = std::env::var("BENCH_LABEL").unwrap_or_else(|_| "local".to_string());
        let snap = Json::obj(vec![
            ("schema", Json::str("enfor-sa/lane-step/v1")),
            ("label", Json::str(label)),
            ("dim", Json::num(dim as f64)),
            ("cycles", Json::num(cycles as f64)),
            ("rows", Json::Arr(rows)),
        ]);
        std::fs::write(&path, snap.pretty()).expect("writing BENCH_OUT snapshot");
        eprintln!("wrote snapshot {path}");
    }
}
