//! Bench: Table IV — mean full-matmul time (`C = A.B + D`, preload +
//! compute + flush) across array sizes, ENFOR-SA vs HDFIT.
//!
//! Run: `cargo bench --bench matmul_time` (env BENCH_REPS to override).

use enfor_sa::benchkit::matmul_time;

fn main() {
    let reps: u64 = std::env::var("BENCH_REPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1000);
    let dims = [4usize, 8, 16, 32, 64];
    println!("TABLE IV: mean matmul time over {reps} matmuls");
    println!(
        "{:<10} {:>14} {:>14} {:>12}",
        "Array", "ENFOR-SA", "HDFIT", "Improvement"
    );
    let rows = matmul_time(&dims, reps);
    for r in &rows {
        println!(
            "DIM{:<7} {:>12.3}ms {:>12.3}ms {:>11.2}x",
            r.dim, r.enforsa_ms, r.hdfit_ms, r.improvement()
        );
    }
    for r in &rows {
        println!(
            "CSV,matmul_time,{},{:.6},{:.6},{:.3}",
            r.dim, r.enforsa_ms, r.hdfit_ms, r.improvement()
        );
    }
}
