//! Benchmark kit: the measurement routines behind every table and
//! figure of the paper's evaluation (§IV). Shared by the criterion-style
//! bench binaries (`rust/benches/*`) and the `enfor-sa` CLI so the same
//! code regenerates the paper's artifacts either way.

use crate::campaign::{run_campaign, CampaignResult};
use crate::config::{
    Backend, CampaignConfig, Dataflow, HardeningConfig, MeshConfig, OffloadScope, Scenario,
    TileEngine, TrialEngine,
};
use crate::coordinator::run_parallel;
use crate::dnn::models;
use crate::journal::{run_journaled, Shard};
use crate::mat::Mat;
use crate::mesh::driver::{tiled_matmul_os, MatmulDriver};
use crate::mesh::hdfit::InstrumentedMesh;
use crate::mesh::inject::idle_cycles;
use crate::mesh::{Mesh, MeshSim};
use crate::soc::Soc;
use crate::util::json::Json;
use crate::util::Rng;
use anyhow::Result;
use std::time::Instant;

/// Table III row: mean raw `step()` cycle time.
#[derive(Clone, Debug)]
pub struct CycleTimeRow {
    pub dim: usize,
    pub enforsa_us: f64,
    pub hdfit_us: f64,
}

impl CycleTimeRow {
    pub fn improvement(&self) -> f64 {
        self.hdfit_us / self.enforsa_us
    }
}

/// Table III: mean cycle time over `cycles` raw `dut->step()` calls
/// (paper: 1M), ENFOR-SA mesh vs HDFIT-instrumented mesh.
pub fn cycle_time(dims: &[usize], cycles: u64) -> Vec<CycleTimeRow> {
    dims.iter()
        .map(|&dim| {
            let mut mesh = Mesh::new(dim, Dataflow::OutputStationary);
            let t0 = Instant::now();
            idle_cycles(&mut mesh, cycles);
            let enforsa_us = t0.elapsed().as_secs_f64() * 1e6 / cycles as f64;
            // keep the simulator state observable so the loop cannot be
            // optimized away
            std::hint::black_box(mesh.acc_at(0, 0));

            let mut hm = InstrumentedMesh::new(dim);
            let t0 = Instant::now();
            idle_cycles(&mut hm, cycles);
            let hdfit_us = t0.elapsed().as_secs_f64() * 1e6 / cycles as f64;
            std::hint::black_box(hm.hook_calls);
            CycleTimeRow { dim, enforsa_us, hdfit_us }
        })
        .collect()
}

/// Table IV row: mean full matmul time (`C = A.B + D`, DIMxDIM).
#[derive(Clone, Debug)]
pub struct MatmulTimeRow {
    pub dim: usize,
    pub enforsa_ms: f64,
    pub hdfit_ms: f64,
}

impl MatmulTimeRow {
    pub fn improvement(&self) -> f64 {
        self.hdfit_ms / self.enforsa_ms
    }
}

/// Table IV: mean matmul time over `reps` matmuls (paper: 1k), covering
/// preload + compute + flush.
pub fn matmul_time(dims: &[usize], reps: u64) -> Vec<MatmulTimeRow> {
    let mut rng = Rng::new(0xBE0C);
    dims.iter()
        .map(|&dim| {
            let a = rng.mat_i8(dim, dim);
            let b = rng.mat_i8(dim, dim);
            let d = rng.mat_i32(dim, dim, 100);

            let mut mesh = Mesh::new(dim, Dataflow::OutputStationary);
            let t0 = Instant::now();
            for _ in 0..reps {
                std::hint::black_box(
                    MatmulDriver::new(&mut mesh).matmul(a.view(), b.view(), d.view()),
                );
            }
            let enforsa_ms = t0.elapsed().as_secs_f64() * 1e3 / reps as f64;

            let mut hm = InstrumentedMesh::new(dim);
            let t0 = Instant::now();
            for _ in 0..reps {
                std::hint::black_box(
                    MatmulDriver::new(&mut hm).matmul(a.view(), b.view(), d.view()),
                );
            }
            let hdfit_ms = t0.elapsed().as_secs_f64() * 1e3 / reps as f64;
            MatmulTimeRow { dim, enforsa_ms, hdfit_ms }
        })
        .collect()
}

/// Table V row: full forward pass of the ResNet50-style first conv
/// layer, lowered to tiled matmuls, per backend.
#[derive(Clone, Debug)]
pub struct LayerForwardRow {
    pub dim: usize,
    pub enforsa_s: f64,
    pub full_soc_s: f64,
    pub hdfit_s: f64,
}

impl LayerForwardRow {
    pub fn vs_full_soc(&self) -> f64 {
        self.full_soc_s / self.enforsa_s
    }

    pub fn vs_hdfit(&self) -> f64 {
        self.hdfit_s / self.enforsa_s
    }
}

/// The GEMM operands of our scaled ResNet50's first convolution
/// (im2col-lowered), shared by all three backends.
pub fn resnet50_conv1_operands(rng: &mut Rng) -> (Mat<i8>, Mat<i8>, Mat<i32>) {
    // conv1: cin=3, 32x32 input, cout=24, 3x3, stride 2, pad 1
    // im2col: M = 16*16 = 256 pixels, K = 27, N = 24
    let (m, k, n) = (256usize, 27usize, 24usize);
    (rng.mat_i8(m, k), rng.mat_i8(k, n), rng.mat_i32(m, n, 128))
}

/// Table V: one full conv-layer forward per backend. `soc_reps` lets the
/// caller shrink the (expensive) full-SoC measurement.
pub fn layer_forward(dims: &[usize]) -> Result<Vec<LayerForwardRow>> {
    let mut rng = Rng::new(0x7AB1E5);
    let (a, b, d) = resnet50_conv1_operands(&mut rng);
    let mut rows = Vec::new();
    for &dim in dims {
        let mut mesh = Mesh::new(dim, Dataflow::OutputStationary);
        let t0 = Instant::now();
        std::hint::black_box(tiled_matmul_os(&mut mesh, a.view(), b.view(), d.view()));
        let enforsa_s = t0.elapsed().as_secs_f64();

        let mut hm = InstrumentedMesh::new(dim);
        let t0 = Instant::now();
        std::hint::black_box(tiled_matmul_os(&mut hm, a.view(), b.view(), d.view()));
        let hdfit_s = t0.elapsed().as_secs_f64();

        // full SoC: each output tile through the whole chip; tiles are
        // zero-copy padded windows of the shared flat operands
        let mut soc = Soc::new(dim);
        let t0 = Instant::now();
        let m = a.rows();
        let k = a.cols();
        let n = b.cols();
        let mut ti = 0;
        while ti < m {
            let mut tj = 0;
            while tj < n {
                std::hint::black_box(soc.run_matmul(
                    a.window(ti, 0, dim, k),
                    b.window(0, tj, k, dim),
                    d.window(ti, tj, dim, dim),
                    &crate::mesh::FaultPlan::empty(),
                )?);
                tj += dim;
            }
            ti += dim;
        }
        let full_soc_s = t0.elapsed().as_secs_f64();
        rows.push(LayerForwardRow { dim, enforsa_s, full_soc_s, hdfit_s });
    }
    Ok(rows)
}

/// Table VI row: injection time + vulnerability factors for one model,
/// plus the site-resume vs full-forward timing pair and the
/// cycle-resume vs full tile-engine pair on the RTL backend.
#[derive(Clone, Debug)]
pub struct InjectionRow {
    pub model: String,
    /// Mesh dataflow every campaign of this row executed under (schema
    /// v5: one row per (model, dataflow) pair makes OS-vs-WS
    /// reliability directly comparable per model).
    pub dataflow: Dataflow,
    pub sw: CampaignResult,
    /// ENFOR-SA campaign on the default fast path (site-resume trial
    /// engine, cycle-resume tile engine).
    pub rtl: CampaignResult,
    /// Identical campaign with ONLY the tile engine switched to `full`
    /// — same seed, bit-identical counts; isolates the cycle-resume
    /// effect as a deterministic RTL-cycle ratio.
    pub rtl_tile_full: CampaignResult,
    /// Identical campaign with ONLY the trial engine switched to the
    /// full-forward oracle (tile engine stays cycle-resume) — same
    /// seed, bit-identical counts; isolates the site-resume wall-clock
    /// effect. Each speedup below varies exactly one engine.
    pub rtl_full: CampaignResult,
    /// Identical campaign with ONLY the tile engine switched to
    /// `lane-lockstep` (schema v6) — same seed, bit-identical counts;
    /// isolates the lane-batching effect as a deterministic RTL-cycle
    /// ratio against the cycle-resume baseline.
    pub rtl_lockstep: CampaignResult,
    /// Identical campaign with ONLY the tile engine switched to
    /// `packed-lockstep` (schema v9) — same seed, bit-identical counts;
    /// the cross-tile packer merges lane-lockstep's same-tile chunks,
    /// so the cycle ratio against `rtl_lockstep` isolates the packing
    /// effect and the occupancy pair below shows WHY it wins (fuller
    /// lanes).
    pub rtl_packed: CampaignResult,
    /// Lane count the lockstep and packed campaigns ran with.
    pub lanes: usize,
    /// Whole-SoC campaign on its fast path (cycle-resume tile engine,
    /// schema v7) — the measured counterpart of the paper's "verilated
    /// SoC" baseline, now schedule-indexable.
    pub soc: CampaignResult,
    /// Identical whole-SoC campaign with ONLY the tile engine switched
    /// to `full` — same seed, bit-identical counts; isolates the SoC
    /// cycle-resume effect as a deterministic SoC-cycle ratio.
    pub soc_tile_full: CampaignResult,
    /// Identical campaign through the async coordinator with the
    /// in-memory batch sink (schema v8) — the journal-overhead
    /// baseline: same seed, bit-identical counts, no durability.
    pub rtl_mem: CampaignResult,
    /// The same campaign journaled to a scratch campaign dir —
    /// manifest write, per-batch fsynced JSONL appends, final report
    /// (schema v8). Same seed, bit-identical counts; the wall ratio
    /// against `rtl_mem` prices the durability layer.
    pub rtl_journal: CampaignResult,
    /// Mitigation config of the hardened twin campaign below (schema
    /// v10; ABFT by default, or the caller's `--hardening` when armed).
    pub hardening: HardeningConfig,
    /// Identical campaign with ONLY the hardening axis armed — same
    /// seed, same struck-trial set as `rtl` (mitigation happens at the
    /// splice seam, after sampling); the verdict counters yield the
    /// detection/correction coverage and the wall ratio against `rtl`
    /// prices the mitigation checks.
    pub rtl_hardened: CampaignResult,
}

impl InjectionRow {
    pub fn slowdown_pct(&self) -> f64 {
        (self.rtl.wall.as_secs_f64() / self.sw.wall.as_secs_f64() - 1.0) * 100.0
    }

    pub fn pvf_pct(&self) -> f64 {
        self.sw.vf() * 100.0
    }

    pub fn avf_pct(&self) -> f64 {
        self.rtl.vf() * 100.0
    }

    /// Campaign throughput of the (site-resume) RTL campaign.
    pub fn trials_per_sec(&self) -> f64 {
        self.rtl.vuln.trials as f64 / self.rtl.wall.as_secs_f64()
    }

    /// Wall-clock speedup of site-resume over the full-forward oracle
    /// on the same RTL campaign (> 1 means resume is faster; grows with
    /// layer count). Both sides run the cycle-resume tile engine, so
    /// this ratio isolates the TRIAL engine (schema v4 note: v3
    /// predates cycle-resume, so absolute walls are not comparable
    /// across schema versions, only the per-factor ratios).
    pub fn resume_speedup_vs_full_forward(&self) -> f64 {
        self.rtl_full.wall.as_secs_f64() / self.rtl.wall.as_secs_f64()
    }

    /// RTL mesh cycles the (cycle-resume) campaign stepped.
    pub fn rtl_cycles_stepped(&self) -> u64 {
        self.rtl.rtl_cycles_stepped
    }

    /// Architectural speedup of the cycle-resume tile engine: RTL cycles
    /// the full tile engine steps for the bit-identical campaign,
    /// divided by cycle-resume's. A pure cycle-count ratio — fully
    /// deterministic per seed (no wall-clock noise), so CI asserts it.
    pub fn cycle_resume_speedup(&self) -> f64 {
        self.rtl_tile_full.rtl_cycles_stepped as f64
            / self.rtl.rtl_cycles_stepped.max(1) as f64
    }

    /// Architectural speedup of the lane-lockstep tile engine over the
    /// cycle-resume baseline: RTL cycles cycle-resume steps for the
    /// bit-identical campaign, divided by lockstep's (which counts each
    /// lockstep mesh step once per cycle, not per lane). Deterministic
    /// per seed, so CI asserts it, and > 1 whenever any chunk batches
    /// two or more trials.
    pub fn lockstep_speedup(&self) -> f64 {
        self.rtl.rtl_cycles_stepped as f64 / self.rtl_lockstep.rtl_cycles_stepped.max(1) as f64
    }

    /// Architectural speedup of the packed-lockstep tile engine over
    /// same-tile lane-lockstep: RTL cycles lockstep steps for the
    /// bit-identical campaign, divided by the packer's (schema v9).
    /// Deterministic per seed, so CI asserts it; >= 1 always (packing
    /// whole runs never costs cycles) and > 1 whenever the packer
    /// merges at least two same-tile runs into one cross-tile chunk.
    pub fn packed_lockstep_speedup(&self) -> f64 {
        self.rtl_lockstep.rtl_cycles_stepped as f64
            / self.rtl_packed.rtl_cycles_stepped.max(1) as f64
    }

    /// Lane occupancy of the packed campaign: filled lane-cycles over
    /// stepped lane-cycles (schema v9). 1.0 means every stepped lane
    /// carried a live trial.
    pub fn lane_occupancy(&self) -> f64 {
        self.rtl_packed.lane_occupancy()
    }

    /// Lane occupancy of the same-tile lockstep campaign — the packed
    /// engine's baseline; the gap between the two is the idle-lane
    /// waste the cross-tile packer reclaims.
    pub fn lane_occupancy_lockstep(&self) -> f64 {
        self.rtl_lockstep.lane_occupancy()
    }

    /// Architectural speedup of cycle-resume on the whole-SoC backend:
    /// SoC cycles the full tile engine steps for the bit-identical
    /// campaign, divided by the resumed engine's (schema v7). The
    /// command-decode/DMA prefix is paid once per tile instead of per
    /// trial and the fence/halt postfix never, so the ratio is > 1 for
    /// any non-empty campaign — the measured counterpart of the paper's
    /// 569x isolation claim, deterministic per seed so CI asserts it.
    pub fn soc_cycle_resume_speedup(&self) -> f64 {
        self.soc_tile_full.rtl_cycles_stepped as f64
            / self.soc.rtl_cycles_stepped.max(1) as f64
    }

    /// Wall-clock cost of whole-SoC fidelity: the resumed SoC campaign
    /// over the SW-only campaign (schema v7) — the measured counterpart
    /// of the paper's "6% overhead vs software" framing, on the
    /// slowest-fidelity backend instead of the isolated mesh.
    pub fn soc_vs_sw_slowdown(&self) -> f64 {
        self.soc.wall.as_secs_f64() / self.sw.wall.as_secs_f64()
    }

    /// Wall-clock cost of durability (schema v8): the journaled
    /// campaign (manifest + per-batch fsynced journal + report) over
    /// the identical in-memory-sink campaign through the same
    /// coordinator. CI's bench smoke asserts the mean stays < 1.10 —
    /// fsync at batch (not trial) granularity keeps durability in the
    /// noise floor.
    pub fn journal_overhead(&self) -> f64 {
        self.rtl_journal.wall.as_secs_f64() / self.rtl_mem.wall.as_secs_f64()
    }

    /// Detection coverage of the hardened twin: struck trials whose
    /// mitigation raised an alarm (or corrected), over struck trials
    /// (schema v10). Deterministic per seed, so CI asserts it > 0 for
    /// ABFT on seu campaigns.
    pub fn detection_coverage(&self) -> f64 {
        self.rtl_hardened.detection_coverage()
    }

    /// Correction coverage of the hardened twin: struck trials fully
    /// restored by the mitigation, over struck trials (schema v10).
    pub fn correction_coverage(&self) -> f64 {
        self.rtl_hardened.correction_coverage()
    }

    /// Wall-clock cost of the armed mitigation (schema v10): the
    /// hardened campaign over the identical unhardened one. CI's bench
    /// smoke asserts the mean stays < 1.25 — the checksum/vote passes
    /// are O(tile) like the splice compare they ride on.
    pub fn hardening_overhead(&self) -> f64 {
        self.rtl_hardened.wall.as_secs_f64() / self.rtl.wall.as_secs_f64()
    }
}

/// Table VI: run SW-only and ENFOR-SA campaigns for each named model,
/// plus two single-factor oracle reruns of the RTL campaign: the full
/// tile engine (same trial engine) isolates the cycle-resume RTL-cycle
/// saving, and the full-forward trial engine (same tile engine)
/// isolates the site-resume wall-clock speedup. A fourth campaign per
/// model switches only the tile engine to `lane-lockstep` (schema v6)
/// to measure `lockstep_speedup` against the cycle-resume baseline.
/// The oracle runs are slower by design (they are what the fast path
/// is measured against), so generating the table costs a handful of
/// extra campaigns per model — the price of tracking
/// `resume_speedup_vs_full_forward`, `cycle_resume_speedup`,
/// `lockstep_speedup` and (schema v8) `journal_overhead` — the
/// in-memory-sink vs journaled-campaign wall pair — in every snapshot.
pub fn injection_table(
    model_names: &[String],
    mesh_cfg: &MeshConfig,
    base: &CampaignConfig,
) -> Result<Vec<InjectionRow>> {
    let mut rows = Vec::new();
    for name in model_names {
        let model = models::by_name(name, 42 + rows.len() as u64)
            .ok_or_else(|| anyhow::anyhow!("unknown model '{name}'"))?;
        let mut sw_cfg = base.clone();
        sw_cfg.backend = Backend::SwOnly;
        let sw = run_campaign(&model, mesh_cfg, &sw_cfg)?;
        let mut rtl_cfg = base.clone();
        rtl_cfg.backend = Backend::EnforSa;
        rtl_cfg.engine = TrialEngine::SiteResume;
        rtl_cfg.tile_engine = TileEngine::CycleResume;
        let rtl = run_campaign(&model, mesh_cfg, &rtl_cfg)?;
        let mut tile_full_cfg = rtl_cfg.clone();
        tile_full_cfg.tile_engine = TileEngine::Full;
        let rtl_tile_full = run_campaign(&model, mesh_cfg, &tile_full_cfg)?;
        let mut full_cfg = rtl_cfg.clone();
        full_cfg.engine = TrialEngine::FullForward;
        let rtl_full = run_campaign(&model, mesh_cfg, &full_cfg)?;
        let mut lockstep_cfg = rtl_cfg.clone();
        lockstep_cfg.tile_engine = TileEngine::LaneLockstep;
        let rtl_lockstep = run_campaign(&model, mesh_cfg, &lockstep_cfg)?;
        // schema v9: the cross-tile packer — same seed, same lanes, only
        // the tile engine differs from the lockstep run above
        let mut packed_cfg = rtl_cfg.clone();
        packed_cfg.tile_engine = TileEngine::PackedLockstep;
        let rtl_packed = run_campaign(&model, mesh_cfg, &packed_cfg)?;
        // schema v7: the whole-SoC pair — resumed fast path vs the full
        // tile engine, same seed (SoC campaigns are single-tile scoped)
        let mut soc_cfg = rtl_cfg.clone();
        soc_cfg.backend = Backend::FullSoc;
        soc_cfg.offload_scope = OffloadScope::SingleTile;
        let soc = run_campaign(&model, mesh_cfg, &soc_cfg)?;
        let mut soc_full_cfg = soc_cfg.clone();
        soc_full_cfg.tile_engine = TileEngine::Full;
        let soc_tile_full = run_campaign(&model, mesh_cfg, &soc_full_cfg)?;
        // schema v8: the journal-overhead pair — the same RTL campaign
        // through the coordinator with the in-memory sink, then
        // journaled to a scratch campaign dir (manifest + per-batch
        // fsynced JSONL + report); the wall ratio prices durability
        let rtl_mem = run_parallel(&model, mesh_cfg, &rtl_cfg, None)?;
        // unique per call (pid + process-wide counter): concurrent
        // tests in one test binary must not share a scratch dir
        static SCRATCH_ID: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
        let scratch = std::env::temp_dir().join(format!(
            "enfor-sa-journal-bench-{}-{}",
            std::process::id(),
            SCRATCH_ID.fetch_add(1, std::sync::atomic::Ordering::Relaxed)
        ));
        let _ = std::fs::remove_dir_all(&scratch);
        let journaled = run_journaled(
            &model,
            mesh_cfg,
            &rtl_cfg,
            &scratch,
            Shard::default(),
            false,
            None,
            None,
        )?;
        let _ = std::fs::remove_dir_all(&scratch);
        let rtl_journal = journaled.result;
        // schema v10: the hardened twin — same seed, same struck-trial
        // set (sampling never consumes the hardening config), ABFT by
        // default so the coverage columns are non-trivial even when the
        // caller benches an unhardened base config
        let mut hard_cfg = rtl_cfg.clone();
        hard_cfg.hardening = if base.hardening.is_none() {
            HardeningConfig { abft: true, ..Default::default() }
        } else {
            base.hardening
        };
        let rtl_hardened = run_campaign(&model, mesh_cfg, &hard_cfg)?;
        rows.push(InjectionRow {
            model: model.name.clone(),
            dataflow: mesh_cfg.dataflow,
            sw,
            rtl,
            rtl_tile_full,
            rtl_full,
            rtl_lockstep,
            rtl_packed,
            lanes: lockstep_cfg.lanes,
            soc,
            soc_tile_full,
            rtl_mem,
            rtl_journal,
            hardening: hard_cfg.hardening,
            rtl_hardened,
        });
    }
    Ok(rows)
}

/// Table VI across dataflows: the same campaigns re-run per dataflow
/// (same per-model seeds, so weights match across dataflows and only
/// the mesh configuration varies) — the v5 snapshot's OS-vs-WS
/// comparability surface. The `mesh_cfg.dataflow` field is ignored in
/// favour of the explicit `dataflows` list.
pub fn injection_table_dataflows(
    model_names: &[String],
    mesh_cfg: &MeshConfig,
    base: &CampaignConfig,
    dataflows: &[Dataflow],
) -> Result<Vec<InjectionRow>> {
    let mut rows = Vec::new();
    for &dataflow in dataflows {
        let mc = MeshConfig { dataflow, ..*mesh_cfg };
        rows.extend(injection_table(model_names, &mc, base)?);
    }
    Ok(rows)
}

/// Serialize Table VI rows as the `BENCH_injection_overhead.json`
/// snapshot schema (see `benchmarks/` in the repo root): per-model
/// SW/RTL wall clocks, slowdown and vulnerability factors, the
/// per-scenario outcome counts (masked / exposed / critical), campaign
/// throughput and the site-resume speedup over the full-forward
/// oracle, so future PRs can diff the RTL-offload overhead, the
/// trial-engine trajectory and the scenario mix. Schema v4 added the
/// cycle-resume tile-engine accounting: `rtl_cycles_stepped` (the fast
/// path), `rtl_cycles_stepped_full_tile` (the bit-identical full-tile
/// oracle) and their deterministic ratio `cycle_resume_speedup`.
/// Schema v5 makes the rows dataflow-generic: every model row carries
/// a `dataflow` label (one row per (model, dataflow) when the caller
/// benches both — see [`injection_table_dataflows`]), the top level
/// lists the distinct `dataflows` present, and the per-dataflow
/// masked/exposed/SDC and `cycle_resume_speedup` values make OS-vs-WS
/// reliability directly comparable per model. Schema v6 adds the
/// lane-lockstep accounting: a `lanes` axis (top level and per row),
/// `rtl_cycles_stepped_lockstep` and the deterministic
/// `lockstep_speedup` ratio vs the cycle-resume baseline (plus its
/// top-level mean). Schema v7 adds the whole-SoC pair (ROADMAP
/// "Schedule-indexable SoC"): per-model `soc_wall_s`,
/// `soc_rtl_cycles_stepped`, `soc_rtl_cycles_stepped_full_tile`, the
/// deterministic `soc_cycle_resume_speedup` ratio and the wall-clock
/// `soc_vs_sw_slowdown`, plus top-level means of both — the measured
/// counterparts of the paper's 569x isolation and ~6% overhead claims.
/// Schema v8 prices the durable campaign journal (ROADMAP "Durable
/// campaign journal"): per-model `journal_mem_wall_s` (in-memory
/// sink), `journal_wall_s` (manifest + per-batch fsynced JSONL +
/// report) and their ratio `journal_overhead`, plus the top-level
/// `mean_journal_overhead` that the CI bench smoke asserts < 1.10.
/// Schema v9 adds the cross-tile packer accounting (ROADMAP
/// "Cross-tile lane packing"): per-model `rtl_cycles_stepped_packed`,
/// the deterministic `packed_lockstep_speedup` ratio vs the same-tile
/// lockstep baseline, and the lane-occupancy pair `lane_occupancy`
/// (packed) / `lane_occupancy_lockstep` (filled over stepped
/// lane-cycles — the idle-lane waste the packer reclaims), plus
/// top-level means of all three. Schema v10 adds the hardening axis
/// (ROADMAP "Hardening-evaluation axis"): per-model `hardening` label,
/// `hardened_wall_s`, the deterministic `detection_coverage` /
/// `correction_coverage` of the hardened twin campaign and the
/// wall-clock `hardening_overhead` ratio vs the unhardened run, plus
/// top-level means of all three — the CI bench smoke asserts
/// `mean_hardening_overhead` < 1.25.
pub fn injection_snapshot_json(
    rows: &[InjectionRow],
    faults_per_layer: u64,
    inputs: u64,
    scenario: Scenario,
    label: &str,
) -> Json {
    let models: Vec<Json> = rows
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("model", Json::str(r.model.clone())),
                ("dataflow", Json::str(r.dataflow.to_string())),
                ("scenario", Json::str(r.rtl.scenario.to_string())),
                ("sw_wall_s", Json::num(r.sw.wall.as_secs_f64())),
                ("rtl_wall_s", Json::num(r.rtl.wall.as_secs_f64())),
                ("rtl_full_forward_wall_s", Json::num(r.rtl_full.wall.as_secs_f64())),
                ("slowdown_pct", Json::num(r.slowdown_pct())),
                ("pvf_pct", Json::num(r.pvf_pct())),
                ("avf_pct", Json::num(r.avf_pct())),
                ("trials", Json::num(r.rtl.vuln.trials as f64)),
                ("masked", Json::num(r.rtl.masked_trials as f64)),
                ("exposed", Json::num(r.rtl.exposed_trials as f64)),
                ("critical", Json::num(r.rtl.vuln.critical as f64)),
                ("trials_per_sec", Json::num(r.trials_per_sec())),
                (
                    "resume_speedup_vs_full_forward",
                    Json::num(r.resume_speedup_vs_full_forward()),
                ),
                ("rtl_cycles_stepped", Json::num(r.rtl_cycles_stepped() as f64)),
                (
                    "rtl_cycles_stepped_full_tile",
                    Json::num(r.rtl_tile_full.rtl_cycles_stepped as f64),
                ),
                ("cycle_resume_speedup", Json::num(r.cycle_resume_speedup())),
                ("lanes", Json::num(r.lanes as f64)),
                (
                    "rtl_cycles_stepped_lockstep",
                    Json::num(r.rtl_lockstep.rtl_cycles_stepped as f64),
                ),
                ("lockstep_speedup", Json::num(r.lockstep_speedup())),
                (
                    "rtl_cycles_stepped_packed",
                    Json::num(r.rtl_packed.rtl_cycles_stepped as f64),
                ),
                (
                    "packed_lockstep_speedup",
                    Json::num(r.packed_lockstep_speedup()),
                ),
                ("lane_occupancy", Json::num(r.lane_occupancy())),
                (
                    "lane_occupancy_lockstep",
                    Json::num(r.lane_occupancy_lockstep()),
                ),
                ("soc_wall_s", Json::num(r.soc.wall.as_secs_f64())),
                (
                    "soc_rtl_cycles_stepped",
                    Json::num(r.soc.rtl_cycles_stepped as f64),
                ),
                (
                    "soc_rtl_cycles_stepped_full_tile",
                    Json::num(r.soc_tile_full.rtl_cycles_stepped as f64),
                ),
                (
                    "soc_cycle_resume_speedup",
                    Json::num(r.soc_cycle_resume_speedup()),
                ),
                ("soc_vs_sw_slowdown", Json::num(r.soc_vs_sw_slowdown())),
                (
                    "journal_mem_wall_s",
                    Json::num(r.rtl_mem.wall.as_secs_f64()),
                ),
                (
                    "journal_wall_s",
                    Json::num(r.rtl_journal.wall.as_secs_f64()),
                ),
                ("journal_overhead", Json::num(r.journal_overhead())),
                ("hardening", Json::str(r.hardening.to_string())),
                (
                    "hardened_wall_s",
                    Json::num(r.rtl_hardened.wall.as_secs_f64()),
                ),
                ("detection_coverage", Json::num(r.detection_coverage())),
                ("correction_coverage", Json::num(r.correction_coverage())),
                ("hardening_overhead", Json::num(r.hardening_overhead())),
            ])
        })
        .collect();
    let n = rows.len().max(1) as f64;
    // distinct dataflows in first-appearance order (rows may arrive
    // grouped per dataflow or interleaved per model)
    let mut dataflows: Vec<String> = Vec::new();
    for r in rows {
        let df = r.dataflow.to_string();
        if !dataflows.contains(&df) {
            dataflows.push(df);
        }
    }
    // the lane axis is uniform across rows today (one campaign config),
    // but read per row so mixed-lane tables stay representable
    let lanes = rows.first().map_or(0, |r| r.lanes);
    Json::obj(vec![
        ("schema", Json::str("enfor-sa/injection-overhead/v10")),
        ("label", Json::str(label)),
        ("scenario", Json::str(scenario.to_string())),
        (
            "dataflows",
            Json::Arr(dataflows.into_iter().map(Json::str).collect()),
        ),
        ("faults_per_layer", Json::num(faults_per_layer as f64)),
        ("inputs", Json::num(inputs as f64)),
        ("lanes", Json::num(lanes as f64)),
        (
            "mean_slowdown_pct",
            Json::num(rows.iter().map(|r| r.slowdown_pct()).sum::<f64>() / n),
        ),
        (
            "mean_resume_speedup_vs_full_forward",
            Json::num(
                rows.iter()
                    .map(|r| r.resume_speedup_vs_full_forward())
                    .sum::<f64>()
                    / n,
            ),
        ),
        (
            "mean_cycle_resume_speedup",
            Json::num(rows.iter().map(|r| r.cycle_resume_speedup()).sum::<f64>() / n),
        ),
        (
            "mean_lockstep_speedup",
            Json::num(rows.iter().map(|r| r.lockstep_speedup()).sum::<f64>() / n),
        ),
        (
            "mean_packed_lockstep_speedup",
            Json::num(rows.iter().map(|r| r.packed_lockstep_speedup()).sum::<f64>() / n),
        ),
        (
            "mean_lane_occupancy",
            Json::num(rows.iter().map(|r| r.lane_occupancy()).sum::<f64>() / n),
        ),
        (
            "mean_lane_occupancy_lockstep",
            Json::num(rows.iter().map(|r| r.lane_occupancy_lockstep()).sum::<f64>() / n),
        ),
        (
            "mean_soc_cycle_resume_speedup",
            Json::num(rows.iter().map(|r| r.soc_cycle_resume_speedup()).sum::<f64>() / n),
        ),
        (
            "mean_soc_vs_sw_slowdown",
            Json::num(rows.iter().map(|r| r.soc_vs_sw_slowdown()).sum::<f64>() / n),
        ),
        (
            "mean_journal_overhead",
            Json::num(rows.iter().map(|r| r.journal_overhead()).sum::<f64>() / n),
        ),
        (
            "mean_detection_coverage",
            Json::num(rows.iter().map(|r| r.detection_coverage()).sum::<f64>() / n),
        ),
        (
            "mean_correction_coverage",
            Json::num(rows.iter().map(|r| r.correction_coverage()).sum::<f64>() / n),
        ),
        (
            "mean_hardening_overhead",
            Json::num(rows.iter().map(|r| r.hardening_overhead()).sum::<f64>() / n),
        ),
        ("models", Json::Arr(models)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cycle_time_hdfit_is_slower() {
        let rows = cycle_time(&[8], 20_000);
        assert_eq!(rows.len(), 1);
        assert!(
            rows[0].improvement() > 1.2,
            "HDFIT instrumentation must cost: {:.2}x",
            rows[0].improvement()
        );
    }

    #[test]
    fn matmul_time_scales_with_dim() {
        let rows = matmul_time(&[4, 8], 30);
        assert!(rows[1].enforsa_ms > rows[0].enforsa_ms);
        assert!(rows[0].improvement() > 1.0);
    }

    #[test]
    fn layer_forward_soc_dominates() {
        let rows = layer_forward(&[4]).unwrap();
        assert!(rows[0].vs_full_soc() > 5.0, "{:?}", rows[0]);
        assert!(rows[0].vs_hdfit() > 1.0, "{:?}", rows[0]);
    }

    #[test]
    fn snapshot_schema_v10_carries_dataflow_scenario_and_cycle_accounting() {
        let names = vec!["quicknet".to_string()];
        let cc = CampaignConfig {
            faults_per_layer: 2,
            inputs: 1,
            scenario: Scenario::Mbu { bits: 2 },
            ..Default::default()
        };
        let rows = injection_table_dataflows(
            &names,
            &MeshConfig::default(),
            &cc,
            &[Dataflow::OutputStationary, Dataflow::WeightStationary],
        )
        .unwrap();
        assert_eq!(rows.len(), 2, "one row per (model, dataflow)");
        let j = injection_snapshot_json(&rows, 2, 1, cc.scenario, "test");
        assert_eq!(
            j.get("schema").and_then(Json::as_str),
            Some("enfor-sa/injection-overhead/v10")
        );
        assert_eq!(j.get("scenario").and_then(Json::as_str), Some("mbu:2"));
        assert_eq!(j.get("lanes").and_then(Json::as_f64), Some(8.0));
        let dfs = j.get("dataflows").and_then(Json::as_arr).unwrap();
        let dfs: Vec<_> = dfs.iter().filter_map(|d| d.as_str()).collect();
        assert_eq!(dfs, vec!["OS", "WS"], "both dataflows listed");
        let models = j.get("models").and_then(Json::as_arr).unwrap();
        assert_eq!(
            models[0].get("dataflow").and_then(Json::as_str),
            Some("OS")
        );
        assert_eq!(
            models[1].get("dataflow").and_then(Json::as_str),
            Some("WS")
        );
        // the WS row partitions its trials too
        let ws = &models[1];
        assert_eq!(
            ws.get("trials").and_then(Json::as_f64).unwrap(),
            ws.get("masked").and_then(Json::as_f64).unwrap()
                + ws.get("exposed").and_then(Json::as_f64).unwrap()
                + ws.get("critical").and_then(Json::as_f64).unwrap()
        );
        assert!(
            j.get("mean_cycle_resume_speedup")
                .and_then(Json::as_f64)
                .unwrap()
                >= 1.0
        );
        let models = j.get("models").and_then(Json::as_arr).unwrap();
        let m0 = &models[0];
        assert_eq!(m0.get("scenario").and_then(Json::as_str), Some("mbu:2"));
        let trials = m0.get("trials").and_then(Json::as_f64).unwrap();
        let masked = m0.get("masked").and_then(Json::as_f64).unwrap();
        let exposed = m0.get("exposed").and_then(Json::as_f64).unwrap();
        let critical = m0.get("critical").and_then(Json::as_f64).unwrap();
        assert_eq!(trials, masked + exposed + critical);
        assert!(trials > 0.0);
        let cycles = m0.get("rtl_cycles_stepped").and_then(Json::as_f64).unwrap();
        let cycles_full = m0
            .get("rtl_cycles_stepped_full_tile")
            .and_then(Json::as_f64)
            .unwrap();
        let speedup = m0.get("cycle_resume_speedup").and_then(Json::as_f64).unwrap();
        assert!(cycles > 0.0 && cycles_full > 0.0 && speedup > 0.0);
        assert!(cycles <= cycles_full, "resume never steps MORE cycles");
        // the v6 lockstep axis: per-row lanes + cycle accounting
        assert_eq!(m0.get("lanes").and_then(Json::as_f64), Some(8.0));
        let cycles_lock = m0
            .get("rtl_cycles_stepped_lockstep")
            .and_then(Json::as_f64)
            .unwrap();
        let lock_speedup = m0.get("lockstep_speedup").and_then(Json::as_f64).unwrap();
        assert!(cycles_lock > 0.0 && lock_speedup > 0.0);
        assert!(cycles_lock <= cycles, "lockstep never steps MORE cycles");
        assert!(
            j.get("mean_lockstep_speedup").and_then(Json::as_f64).unwrap() >= 1.0
        );
        // the v9 packed axis: cycle count, speedup ratio, occupancy pair
        let cycles_packed = m0
            .get("rtl_cycles_stepped_packed")
            .and_then(Json::as_f64)
            .unwrap();
        assert!(cycles_packed > 0.0);
        assert!(
            cycles_packed <= cycles_lock,
            "packed never steps MORE cycles than lockstep"
        );
        assert!(
            m0.get("packed_lockstep_speedup").and_then(Json::as_f64).unwrap() >= 1.0
        );
        let occ = m0.get("lane_occupancy").and_then(Json::as_f64).unwrap();
        let occ_lock = m0
            .get("lane_occupancy_lockstep")
            .and_then(Json::as_f64)
            .unwrap();
        assert!(occ > 0.0 && occ <= 1.0, "occupancy is a fraction: {occ}");
        assert!(occ_lock > 0.0 && occ_lock <= 1.0);
        assert!(occ >= occ_lock, "packed lanes are never emptier");
        assert!(
            j.get("mean_packed_lockstep_speedup").and_then(Json::as_f64).unwrap() >= 1.0
        );
        assert!(j.get("mean_lane_occupancy").and_then(Json::as_f64).unwrap() > 0.0);
        assert!(
            j.get("mean_lane_occupancy_lockstep").and_then(Json::as_f64).unwrap() > 0.0
        );
        // the v7 whole-SoC axis: wall, cycle pair, both ratios
        assert!(m0.get("soc_wall_s").and_then(Json::as_f64).unwrap() > 0.0);
        let soc_cycles = m0.get("soc_rtl_cycles_stepped").and_then(Json::as_f64).unwrap();
        let soc_cycles_full = m0
            .get("soc_rtl_cycles_stepped_full_tile")
            .and_then(Json::as_f64)
            .unwrap();
        assert!(soc_cycles > 0.0 && soc_cycles_full > 0.0);
        assert!(soc_cycles < soc_cycles_full, "resumed SoC must step fewer cycles");
        assert!(
            m0.get("soc_cycle_resume_speedup").and_then(Json::as_f64).unwrap() > 1.0
        );
        assert!(m0.get("soc_vs_sw_slowdown").and_then(Json::as_f64).unwrap() > 0.0);
        assert!(
            j.get("mean_soc_cycle_resume_speedup")
                .and_then(Json::as_f64)
                .unwrap()
                > 1.0
        );
        assert!(
            j.get("mean_soc_vs_sw_slowdown").and_then(Json::as_f64).unwrap() > 0.0
        );
        // the v8 journal axis: both walls and the overhead ratio
        assert!(m0.get("journal_mem_wall_s").and_then(Json::as_f64).unwrap() > 0.0);
        assert!(m0.get("journal_wall_s").and_then(Json::as_f64).unwrap() > 0.0);
        assert!(m0.get("journal_overhead").and_then(Json::as_f64).unwrap() > 0.0);
        assert!(
            j.get("mean_journal_overhead").and_then(Json::as_f64).unwrap() > 0.0
        );
        // the v10 hardening axis: label, wall, coverage pair, overhead
        assert_eq!(m0.get("hardening").and_then(Json::as_str), Some("abft"));
        assert!(m0.get("hardened_wall_s").and_then(Json::as_f64).unwrap() > 0.0);
        let det = m0.get("detection_coverage").and_then(Json::as_f64).unwrap();
        let cor = m0.get("correction_coverage").and_then(Json::as_f64).unwrap();
        assert!((0.0..=1.0).contains(&det), "coverage is a fraction: {det}");
        assert!((0.0..=1.0).contains(&cor) && cor <= det, "corrected implies detected");
        assert!(m0.get("hardening_overhead").and_then(Json::as_f64).unwrap() > 0.0);
        assert!(
            j.get("mean_detection_coverage").and_then(Json::as_f64).unwrap() >= 0.0
        );
        assert!(
            j.get("mean_hardening_overhead").and_then(Json::as_f64).unwrap() > 0.0
        );
    }

    #[test]
    fn hardened_twin_keeps_the_unhardened_struck_set() {
        // the v10 acceptance bar at the benchkit layer: the hardened
        // twin samples the SAME trials (sampling never consumes the
        // hardening config), so trials match, its struck set equals the
        // baseline's exposed + critical, and ABFT detects seu strikes.
        let names = vec!["quicknet".to_string()];
        let cc = CampaignConfig {
            faults_per_layer: 8,
            inputs: 2,
            ..Default::default()
        };
        let rows = injection_table(&names, &MeshConfig::default(), &cc).unwrap();
        let r = &rows[0];
        assert_eq!(r.hardening, HardeningConfig { abft: true, ..Default::default() });
        assert_eq!(r.rtl.vuln.trials, r.rtl_hardened.vuln.trials);
        assert_eq!(
            r.rtl_hardened.struck_trials(),
            r.rtl.exposed_trials + r.rtl.vuln.critical,
            "mitigation runs at the splice seam, after the strike is decided"
        );
        if r.rtl_hardened.struck_trials() > 0 {
            assert!(
                r.detection_coverage() > 0.0,
                "ABFT checksums must notice at least one seu strike"
            );
        }
        assert!(r.hardening_overhead() > 0.0);
    }

    #[test]
    fn journaled_campaign_counts_match_in_memory_sink() {
        // the v8 acceptance bar at the benchkit layer: the journaled
        // campaign is count-identical to the in-memory-sink campaign
        // AND to the plain single-threaded campaign — durability is a
        // pure sink concern, never a sampling one.
        let names = vec!["quicknet".to_string()];
        let cc = CampaignConfig {
            faults_per_layer: 4,
            inputs: 2,
            ..Default::default()
        };
        let rows = injection_table(&names, &MeshConfig::default(), &cc).unwrap();
        let r = &rows[0];
        for pair in [&r.rtl_mem, &r.rtl_journal] {
            assert_eq!(r.rtl.vuln.trials, pair.vuln.trials);
            assert_eq!(r.rtl.vuln.critical, pair.vuln.critical);
            assert_eq!(r.rtl.exposed_trials, pair.exposed_trials);
            assert_eq!(r.rtl.masked_trials, pair.masked_trials);
            assert_eq!(r.rtl.rtl_cycles_stepped, pair.rtl_cycles_stepped);
        }
        assert!(r.journal_overhead() > 0.0);
    }

    #[test]
    fn soc_cycle_resume_steps_strictly_fewer_soc_cycles() {
        // the SoC tile-engine acceptance bar: bit-identical counts,
        // strictly fewer SoC cycles — the prefix is paid once per tile
        // and the fence/halt postfix never, so the ratio is structural
        // even without tile sharing.
        let names = vec!["quicknet".to_string()];
        let cc = CampaignConfig {
            faults_per_layer: 4,
            inputs: 1,
            ..Default::default()
        };
        let rows = injection_table(&names, &MeshConfig::default(), &cc).unwrap();
        let r = &rows[0];
        assert_eq!(r.soc.vuln.trials, r.soc_tile_full.vuln.trials);
        assert_eq!(r.soc.vuln.critical, r.soc_tile_full.vuln.critical);
        assert_eq!(r.soc.exposed_trials, r.soc_tile_full.exposed_trials);
        assert_eq!(r.soc.masked_trials, r.soc_tile_full.masked_trials);
        assert!(
            r.soc.rtl_cycles_stepped < r.soc_tile_full.rtl_cycles_stepped,
            "resumed SoC stepped {} cycles, full tile engine {}",
            r.soc.rtl_cycles_stepped,
            r.soc_tile_full.rtl_cycles_stepped
        );
        assert!(r.soc_cycle_resume_speedup() > 1.0);
        assert!(r.soc_vs_sw_slowdown() > 0.0);
    }

    #[test]
    fn lane_lockstep_steps_strictly_fewer_rtl_cycles_than_cycle_resume() {
        // the lockstep acceptance bar at the benchkit layer: bit-identical
        // counts vs the cycle-resume baseline, strictly fewer RTL cycles.
        // 8 faults/layer pigeonhole >= 2 trials onto shared tiles.
        let names = vec!["quicknet".to_string()];
        let cc = CampaignConfig {
            faults_per_layer: 8,
            inputs: 2,
            ..Default::default()
        };
        let rows = injection_table(&names, &MeshConfig::default(), &cc).unwrap();
        let r = &rows[0];
        assert_eq!(r.rtl.vuln.trials, r.rtl_lockstep.vuln.trials);
        assert_eq!(r.rtl.vuln.critical, r.rtl_lockstep.vuln.critical);
        assert_eq!(r.rtl.exposed_trials, r.rtl_lockstep.exposed_trials);
        assert_eq!(r.rtl.masked_trials, r.rtl_lockstep.masked_trials);
        assert!(
            r.rtl_lockstep.rtl_cycles_stepped < r.rtl.rtl_cycles_stepped,
            "lockstep stepped {} RTL cycles, cycle-resume {}",
            r.rtl_lockstep.rtl_cycles_stepped,
            r.rtl.rtl_cycles_stepped
        );
        assert!(r.lockstep_speedup() > 1.0);
        assert_eq!(r.lanes, 8);
    }

    #[test]
    fn packed_lockstep_steps_strictly_fewer_rtl_cycles_than_lane_lockstep() {
        // the packed acceptance bar at the benchkit layer: bit-identical
        // counts vs both baselines, strictly fewer RTL cycles than
        // same-tile lockstep, and strictly better lane occupancy. 8
        // faults/layer on 8 lanes lets the packer merge a batch's
        // same-tile runs into one chunk whenever a batch spans >= 2
        // tiles (the Linear site has a 1x2 grid).
        let names = vec!["quicknet".to_string()];
        let cc = CampaignConfig {
            faults_per_layer: 8,
            inputs: 2,
            ..Default::default()
        };
        let rows = injection_table(&names, &MeshConfig::default(), &cc).unwrap();
        let r = &rows[0];
        assert_eq!(r.rtl.vuln.trials, r.rtl_packed.vuln.trials);
        assert_eq!(r.rtl.vuln.critical, r.rtl_packed.vuln.critical);
        assert_eq!(r.rtl.exposed_trials, r.rtl_packed.exposed_trials);
        assert_eq!(r.rtl.masked_trials, r.rtl_packed.masked_trials);
        assert!(
            r.rtl_packed.rtl_cycles_stepped < r.rtl_lockstep.rtl_cycles_stepped,
            "packed stepped {} RTL cycles, lockstep {}",
            r.rtl_packed.rtl_cycles_stepped,
            r.rtl_lockstep.rtl_cycles_stepped
        );
        assert!(r.packed_lockstep_speedup() > 1.0);
        assert!(
            r.lane_occupancy() > r.lane_occupancy_lockstep(),
            "packed lanes must be fuller: {} vs {}",
            r.lane_occupancy(),
            r.lane_occupancy_lockstep()
        );
    }

    #[test]
    fn cycle_resume_steps_strictly_fewer_rtl_cycles() {
        // the tile-engine acceptance bar: bit-identical counts, strictly
        // fewer RTL cycles stepped. 8 faults/layer pigeonhole trials of
        // the 2-tile Linear site onto shared tiles, so the saving is
        // structural for every model in the zoo.
        let names = vec!["quicknet".to_string()];
        let cc = CampaignConfig {
            faults_per_layer: 8,
            inputs: 2,
            ..Default::default()
        };
        let rows = injection_table(&names, &MeshConfig::default(), &cc).unwrap();
        let r = &rows[0];
        assert_eq!(r.rtl.vuln.trials, r.rtl_tile_full.vuln.trials);
        assert_eq!(r.rtl.vuln.critical, r.rtl_tile_full.vuln.critical);
        assert_eq!(r.rtl.exposed_trials, r.rtl_tile_full.exposed_trials);
        assert_eq!(r.rtl.masked_trials, r.rtl_tile_full.masked_trials);
        assert!(
            r.rtl.rtl_cycles_stepped < r.rtl_tile_full.rtl_cycles_stepped,
            "cycle-resume stepped {} RTL cycles, full tile engine {}",
            r.rtl.rtl_cycles_stepped,
            r.rtl_tile_full.rtl_cycles_stepped
        );
        assert!(r.cycle_resume_speedup() > 1.0);
    }

    #[test]
    fn site_resume_beats_full_forward_on_quicknet() {
        // The acceptance bar of the site-resume engine: strictly faster
        // than the full-forward oracle on the same campaign, with
        // bit-identical counts. The workload is large enough (200
        // trials per engine, structural ~2-3x expected gap) that
        // scheduler jitter cannot plausibly invert the comparison.
        let names = vec!["quicknet".to_string()];
        let cc = CampaignConfig {
            faults_per_layer: 20,
            inputs: 2,
            ..Default::default()
        };
        let rows = injection_table(&names, &MeshConfig::default(), &cc).unwrap();
        let r = &rows[0];
        assert_eq!(r.rtl.vuln.trials, r.rtl_full.vuln.trials);
        assert_eq!(r.rtl.vuln.critical, r.rtl_full.vuln.critical);
        assert_eq!(r.rtl.exposed_trials, r.rtl_full.exposed_trials);
        assert!(r.trials_per_sec() > 0.0);
        assert!(
            r.resume_speedup_vs_full_forward() > 1.0,
            "site-resume must beat the full-forward oracle: {:.3}x",
            r.resume_speedup_vs_full_forward()
        );
    }
}
