//! Addressable signal space of the mesh.
//!
//! Every injectable storage element is identified by `(row, col, kind)`;
//! a transient fault additionally carries a bit index and an injection
//! cycle. The same addressing is used by the ENFOR-SA injector, the
//! HDFIT-style instrumented mesh and the campaign sampler, so fault lists
//! are portable across backends (the paper's accuracy-validation setup).



/// The injectable signal classes inside a PE (paper Fig. 2).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]

pub enum SignalKind {
    /// The DNN *weight* operand. Under the paper's output-stationary
    /// configuration this is the horizontal (west→east) operand
    /// pipeline register (Fig. 5b); under weight-stationary it is the
    /// PE's stationary weight register, where an SEU persists until the
    /// next preload. The kinds address logical operands, so fault lists
    /// stay portable across dataflows (see `mesh::inject`).
    Weight,
    /// The *activation* operand: the vertical (north→south) pipeline
    /// register under OS, the horizontal a-path under WS.
    Act,
    /// The output-stationary accumulator (32-bit).
    Acc,
    /// The vertical accumulator-chain pipeline register used for bias
    /// preload and result flush (32-bit).
    DReg,
    /// Local control: propagate bit (flows north→south).
    Propag,
    /// Local control: valid bit (flows north→south).
    Valid,
    /// Control-path state OUTSIDE the PE grid: the tile sequencer and
    /// drain-FSM counters of the mesh `Schedule` (and the `SocSchedule`
    /// window bookkeeping / DMA descriptors on the whole-SoC backend).
    /// Bits 0..8 address the per-column drain counter of `addr.col`;
    /// bits 8..16 address the sequencer's cycle counter (XOR into the
    /// fill cycle — a misfetched schedule step). Deliberately NOT in
    /// [`SignalKind::ALL`]: the PE-grid fault space and its sampling
    /// streams are pinned byte-identical, so control faults are opt-in
    /// via `--signals control`.
    Ctrl,
}

impl SignalKind {
    /// Number of bits of the underlying storage element.
    pub fn width(self) -> u8 {
        match self {
            SignalKind::Weight | SignalKind::Act => 8,
            SignalKind::Acc | SignalKind::DReg => 32,
            SignalKind::Propag | SignalKind::Valid => 1,
            SignalKind::Ctrl => 16,
        }
    }

    /// All PE-grid kinds, in a stable order (used by samplers and
    /// reports). `Ctrl` is intentionally excluded — the default fault
    /// space (and every pinned legacy sampling stream) is the PE grid;
    /// control-path targets are opt-in via `--signals control`.
    pub const ALL: [SignalKind; 6] = [
        SignalKind::Weight,
        SignalKind::Act,
        SignalKind::Acc,
        SignalKind::DReg,
        SignalKind::Propag,
        SignalKind::Valid,
    ];

    /// Parse from the CLI / config string form.
    pub fn parse(s: &str) -> Option<SignalKind> {
        match s.to_ascii_lowercase().as_str() {
            "weight" | "a" => Some(SignalKind::Weight),
            "act" | "activation" | "b" => Some(SignalKind::Act),
            "acc" | "accumulator" | "c" => Some(SignalKind::Acc),
            "dreg" | "d" => Some(SignalKind::DReg),
            "propag" | "propagate" => Some(SignalKind::Propag),
            "valid" => Some(SignalKind::Valid),
            "control" | "ctrl" => Some(SignalKind::Ctrl),
            _ => None,
        }
    }
}

impl std::fmt::Display for SignalKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            SignalKind::Weight => "weight",
            SignalKind::Act => "act",
            SignalKind::Acc => "acc",
            SignalKind::DReg => "dreg",
            SignalKind::Propag => "propag",
            SignalKind::Valid => "valid",
            SignalKind::Ctrl => "control",
        };
        write!(f, "{s}")
    }
}

/// A fully-qualified signal address inside a DIM x DIM mesh.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct SignalAddr {
    pub row: usize,
    pub col: usize,
    pub kind: SignalKind,
}

impl SignalAddr {
    pub fn new(row: usize, col: usize, kind: SignalKind) -> Self {
        SignalAddr { row, col, kind }
    }

    /// Total number of injectable (signal, bit) targets in a mesh —
    /// the per-cycle fault-space size used for statistical sampling.
    pub fn fault_space_bits(dim: usize) -> u64 {
        let per_pe: u64 = SignalKind::ALL.iter().map(|k| k.width() as u64).sum();
        (dim * dim) as u64 * per_pe
    }

    /// Enumerate every signal address of a mesh in a stable order.
    pub fn enumerate(dim: usize) -> impl Iterator<Item = SignalAddr> {
        (0..dim).flat_map(move |r| {
            (0..dim).flat_map(move |c| {
                SignalKind::ALL
                    .iter()
                    .map(move |&k| SignalAddr::new(r, c, k))
            })
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn widths() {
        assert_eq!(SignalKind::Weight.width(), 8);
        assert_eq!(SignalKind::Acc.width(), 32);
        assert_eq!(SignalKind::Propag.width(), 1);
    }

    #[test]
    fn fault_space_size() {
        // per PE: 8 + 8 + 32 + 32 + 1 + 1 = 82 bits
        assert_eq!(SignalAddr::fault_space_bits(8), 64 * 82);
        assert_eq!(SignalAddr::fault_space_bits(1), 82);
    }

    #[test]
    fn enumerate_covers_all() {
        let v: Vec<_> = SignalAddr::enumerate(4).collect();
        assert_eq!(v.len(), 4 * 4 * 6);
        // unique
        let set: std::collections::HashSet<_> = v.iter().collect();
        assert_eq!(set.len(), v.len());
    }

    #[test]
    fn parse_round_trip() {
        for k in SignalKind::ALL {
            assert_eq!(SignalKind::parse(&k.to_string()), Some(k));
        }
        assert_eq!(SignalKind::parse("bogus"), None);
    }

    #[test]
    fn control_kind_is_opt_in() {
        // the control-path kind parses and round-trips...
        assert_eq!(SignalKind::parse("control"), Some(SignalKind::Ctrl));
        assert_eq!(SignalKind::parse("ctrl"), Some(SignalKind::Ctrl));
        assert_eq!(SignalKind::Ctrl.to_string(), "control");
        assert_eq!(SignalKind::Ctrl.width(), 16);
        // ...but stays OUT of the default fault space: ALL and the
        // per-PE bit budget are pinned so legacy sampling streams stay
        // byte-identical.
        assert!(!SignalKind::ALL.contains(&SignalKind::Ctrl));
        assert_eq!(SignalAddr::fault_space_bits(8), 64 * 82);
    }
}
