//! Fixed-width PE row kernels shared by the scalar [`super::mesh::Mesh`]
//! and the lane-batched [`super::lane::LaneMesh`].
//!
//! Both meshes update one mesh row per call as an **element-wise map**
//! over `n` independent cells: the scalar mesh passes `n = dim` (one
//! element per column), the lane mesh passes `n = dim * lanes` (the
//! lane-contiguous SoA row). All intra-row dependencies are resolved by
//! the caller *before* the call — the a-chain through a pre-edge shifted
//! scratch copy (`a_in[j]` is the west port for the leading element(s)
//! and the western neighbour's pre-edge `reg_a` otherwise), the
//! north-row sources through read-only pre-edge slices (rows are walked
//! bottom-up, so the northern row is unwritten), and the south-edge
//! captures through pre/post-edge snapshots taken around the call. That
//! leaves a straight-line select ladder per element.
//!
//! The hot loop is blocked over a compile-time [`LANE_BLOCK`]: the main
//! loop runs `LANE_BLOCK` elements with a *constant* trip count (plus a
//! scalar remainder), and every slice is pre-narrowed to `n` elements,
//! so the body is bounds-check-free, branch-free and fixed-width — the
//! shape LLVM reliably lifts to SIMD on stable Rust. Bit-identity of the
//! blocked kernels against the pre-blocking scalar walk is pinned by
//! `blocked_rows_match_reference_cells` below and by the golden
//! lockstep/mesh tests.
//!
//! The `EDGE` const parameter folds the north-edge row and the interior
//! rows into one body: the only semantic difference is where the
//! accumulator-chain input `d_in` comes from (the boundary port stream
//! for row 0; the PE's own `reg_d`, latched from the northern `out_c`
//! wire last cycle, for interior rows). `d_next` is what `reg_d` latches
//! this cycle: the boundary `north_d` for row 0, the northern pre-edge
//! accumulator for interior rows.

/// Compile-time width of the main element loop. 8 lanes of i32 fill one
/// AVX2 register (and two NEON registers) — wide enough to saturate the
/// vector units the CI runners have, small enough that the scalar
/// remainder stays cheap at dim 4..16.
pub(crate) const LANE_BLOCK: usize = 8;

/// One output-stationary mesh row, `n` independent elements.
///
/// Element semantics (transliterated from the scalar `step_os`):
///
/// ```text
/// d_in  = EDGE ? d_next[j] : reg_d[j]        // acc-chain input
/// mac   = acc[j] + a_in[j] * b_in[j]          (wrapping)
/// acc'  = p ? d_in : (v ? mac : acc)
/// reg_d'= d_next[j]                           // latch north out_c wire
/// reg_a'/reg_b'/reg_propag'/reg_valid' latch the inputs
/// ```
///
/// South-edge flush capture (`p ⇒ out_c = acc_old`, bottom row only) is
/// the caller's job from a pre-edge `acc` snapshot.
#[allow(clippy::too_many_arguments)]
pub(crate) fn os_row<const EDGE: bool>(
    a_in: &[i8],
    b_in: &[i8],
    p_in: &[bool],
    v_in: &[bool],
    d_next: &[i32],
    acc: &mut [i32],
    reg_a: &mut [i8],
    reg_b: &mut [i8],
    reg_d: &mut [i32],
    reg_propag: &mut [bool],
    reg_valid: &mut [bool],
) {
    let n = acc.len();
    // Pre-narrow every slice to `n`: one bounds check each up front, none
    // inside the blocked loop.
    let (a_in, b_in, p_in, v_in, d_next) =
        (&a_in[..n], &b_in[..n], &p_in[..n], &v_in[..n], &d_next[..n]);
    let (reg_a, reg_b, reg_d) = (&mut reg_a[..n], &mut reg_b[..n], &mut reg_d[..n]);
    let (reg_propag, reg_valid) = (&mut reg_propag[..n], &mut reg_valid[..n]);
    macro_rules! cell {
        ($j:expr) => {{
            let j = $j;
            let a = a_in[j];
            let b = b_in[j];
            let p = p_in[j];
            let v = v_in[j];
            let d_in = if EDGE { d_next[j] } else { reg_d[j] };
            let acc_old = acc[j];
            let mac = acc_old.wrapping_add(a as i32 * b as i32);
            acc[j] = if p {
                d_in
            } else if v {
                mac
            } else {
                acc_old
            };
            reg_d[j] = d_next[j];
            reg_a[j] = a;
            reg_b[j] = b;
            reg_propag[j] = p;
            reg_valid[j] = v;
        }};
    }
    let mut j = 0;
    while j + LANE_BLOCK <= n {
        for k in 0..LANE_BLOCK {
            cell!(j + k);
        }
        j += LANE_BLOCK;
    }
    while j < n {
        cell!(j);
        j += 1;
    }
}

/// One weight-stationary mesh row, `n` independent elements.
///
/// `chain[j]` is the psum/d-chain input from the north: the boundary
/// `north_d` stream for row 0, the northern pre-edge accumulator (the
/// psum pipeline) for interior rows. Element semantics (transliterated
/// from the scalar `step_ws`):
///
/// ```text
/// d_in  = EDGE ? chain[j] : reg_d[j]
/// ps    = chain[j] + reg_w[j] * a_in[j]       (wrapping)
/// reg_w'= p ? low8(d_in) : reg_w
/// acc'  = p ? d_in : (v ? ps : acc)
/// reg_d'= chain[j]
/// ```
///
/// South-edge captures (bottom row only: `p ⇒ out_c = w_old`,
/// `!p ∧ v ⇒ psum = ps`) are the caller's job — `w_old` from a pre-edge
/// `reg_w` snapshot, `ps` from the post-edge `acc` (equal to `ps`
/// exactly when `!p ∧ v`).
#[allow(clippy::too_many_arguments)]
pub(crate) fn ws_row<const EDGE: bool>(
    a_in: &[i8],
    b_in: &[i8],
    p_in: &[bool],
    v_in: &[bool],
    chain: &[i32],
    acc: &mut [i32],
    reg_a: &mut [i8],
    reg_b: &mut [i8],
    reg_d: &mut [i32],
    reg_w: &mut [i8],
    reg_propag: &mut [bool],
    reg_valid: &mut [bool],
) {
    let n = acc.len();
    let (a_in, b_in, p_in, v_in, chain) =
        (&a_in[..n], &b_in[..n], &p_in[..n], &v_in[..n], &chain[..n]);
    let (reg_a, reg_b, reg_d, reg_w) =
        (&mut reg_a[..n], &mut reg_b[..n], &mut reg_d[..n], &mut reg_w[..n]);
    let (reg_propag, reg_valid) = (&mut reg_propag[..n], &mut reg_valid[..n]);
    macro_rules! cell {
        ($j:expr) => {{
            let j = $j;
            let a = a_in[j];
            let b = b_in[j];
            let p = p_in[j];
            let v = v_in[j];
            let ch = chain[j];
            let d_in = if EDGE { ch } else { reg_d[j] };
            let w_old = reg_w[j];
            let ps = ch.wrapping_add(w_old as i32 * a as i32);
            reg_w[j] = if p { (d_in & 0xff) as i8 } else { w_old };
            let acc_old = acc[j];
            acc[j] = if p {
                d_in
            } else if v {
                ps
            } else {
                acc_old
            };
            reg_d[j] = ch;
            reg_a[j] = a;
            reg_b[j] = b;
            reg_propag[j] = p;
            reg_valid[j] = v;
        }};
    }
    let mut j = 0;
    while j + LANE_BLOCK <= n {
        for k in 0..LANE_BLOCK {
            cell!(j + k);
        }
        j += LANE_BLOCK;
    }
    while j < n {
        cell!(j);
        j += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Reference (unblocked, per-cell) transliteration of the original
    /// scalar walk, run against the blocked kernels on sizes straddling
    /// `LANE_BLOCK` boundaries — pins that blocking changed no result.
    #[test]
    fn blocked_rows_match_reference_cells() {
        for n in [1, 3, LANE_BLOCK - 1, LANE_BLOCK, LANE_BLOCK + 5, 4 * LANE_BLOCK + 7] {
            // deterministic pseudo-random fixture
            let v8 = |s: usize, j: usize| ((s * 97 + j * 31 + 13) % 251) as u8 as i8;
            let v32 = |s: usize, j: usize| ((s * 131 + j * 17) % 9973) as i32 - 4000;
            let vb = |s: usize, j: usize| (s + j) % 3 == 0;
            let a_in: Vec<i8> = (0..n).map(|j| v8(1, j)).collect();
            let b_in: Vec<i8> = (0..n).map(|j| v8(2, j)).collect();
            let p_in: Vec<bool> = (0..n).map(|j| vb(1, j)).collect();
            let v_in: Vec<bool> = (0..n).map(|j| vb(2, j)).collect();
            let chain: Vec<i32> = (0..n).map(|j| v32(3, j)).collect();
            let mk = || {
                (
                    (0..n).map(|j| v32(4, j)).collect::<Vec<i32>>(), // acc
                    (0..n).map(|j| v8(5, j)).collect::<Vec<i8>>(),   // reg_a
                    (0..n).map(|j| v8(6, j)).collect::<Vec<i8>>(),   // reg_b
                    (0..n).map(|j| v32(7, j)).collect::<Vec<i32>>(), // reg_d
                    (0..n).map(|j| v8(8, j)).collect::<Vec<i8>>(),   // reg_w
                    (0..n).map(|j| vb(3, j)).collect::<Vec<bool>>(), // propag
                    (0..n).map(|j| vb(4, j)).collect::<Vec<bool>>(), // valid
                )
            };
            for edge in [false, true] {
                // OS
                let (mut acc, mut ra, mut rb, mut rd, _, mut rp, mut rv) = mk();
                let (mut acc2, mut ra2, mut rb2, mut rd2, _, mut rp2, mut rv2) = mk();
                for j in 0..n {
                    let d_in = if edge { chain[j] } else { rd2[j] };
                    let acc_old = acc2[j];
                    let mac = acc_old.wrapping_add(a_in[j] as i32 * b_in[j] as i32);
                    acc2[j] = if p_in[j] {
                        d_in
                    } else if v_in[j] {
                        mac
                    } else {
                        acc_old
                    };
                    rd2[j] = chain[j];
                    ra2[j] = a_in[j];
                    rb2[j] = b_in[j];
                    rp2[j] = p_in[j];
                    rv2[j] = v_in[j];
                }
                if edge {
                    os_row::<true>(
                        &a_in, &b_in, &p_in, &v_in, &chain, &mut acc, &mut ra, &mut rb,
                        &mut rd, &mut rp, &mut rv,
                    );
                } else {
                    os_row::<false>(
                        &a_in, &b_in, &p_in, &v_in, &chain, &mut acc, &mut ra, &mut rb,
                        &mut rd, &mut rp, &mut rv,
                    );
                }
                assert_eq!((acc, ra, rb, rd, rp, rv), (acc2, ra2, rb2, rd2, rp2, rv2),
                    "os n={n} edge={edge}");
                // WS
                let (mut acc, mut ra, mut rb, mut rd, mut rw, mut rp, mut rv) = mk();
                let (mut acc2, mut ra2, mut rb2, mut rd2, mut rw2, mut rp2, mut rv2) = mk();
                for j in 0..n {
                    let d_in = if edge { chain[j] } else { rd2[j] };
                    let w_old = rw2[j];
                    let ps = chain[j].wrapping_add(w_old as i32 * a_in[j] as i32);
                    rw2[j] = if p_in[j] { (d_in & 0xff) as i8 } else { w_old };
                    let acc_old = acc2[j];
                    acc2[j] = if p_in[j] {
                        d_in
                    } else if v_in[j] {
                        ps
                    } else {
                        acc_old
                    };
                    rd2[j] = chain[j];
                    ra2[j] = a_in[j];
                    rb2[j] = b_in[j];
                    rp2[j] = p_in[j];
                    rv2[j] = v_in[j];
                }
                if edge {
                    ws_row::<true>(
                        &a_in, &b_in, &p_in, &v_in, &chain, &mut acc, &mut ra, &mut rb,
                        &mut rd, &mut rw, &mut rp, &mut rv,
                    );
                } else {
                    ws_row::<false>(
                        &a_in, &b_in, &p_in, &v_in, &chain, &mut acc, &mut ra, &mut rb,
                        &mut rd, &mut rw, &mut rp, &mut rv,
                    );
                }
                assert_eq!((acc, ra, rb, rd, rw, rp, rv), (acc2, ra2, rb2, rd2, rw2, rp2, rv2),
                    "ws n={n} edge={edge}");
            }
        }
    }
}
