//! The RTL-level systolic-array substrate: the verilated-equivalent
//! Gemmini Mesh model, the ENFOR-SA non-intrusive injector, the
//! HDFIT-style instrumented baseline, the boundary interface adapters and
//! the matmul drivers.
//!
//! See the module docs of [`mesh`] for the microarchitecture and of
//! [`inject`] for the injection technique.

pub mod adapters;
pub mod driver;
pub mod hdfit;
pub mod inject;
pub(crate) mod kernel;
pub mod lane;
#[allow(clippy::module_inception)]
pub mod mesh;
pub mod signal;

pub use driver::{
    gold_matmul, lockstep_resumed, matmul_cycles, os_matmul_cycles, packed_lockstep_resumed,
    tile_grid, tiled_matmul, tiled_matmul_os, tiled_matmul_ws, tiled_matmul_ws_with,
    ws_matmul_cycles, CycleCursor, CycleIndexed, DriverScratch, LaneGroup, MatmulDriver, Schedule,
};
pub use inject::{Fault, FaultPlan, Injectable, PlanCursor};
pub use lane::{LaneCursor, LaneMesh};
pub use mesh::{Mesh, MeshInputs, MeshSim, MeshState, StepOutput};
pub use signal::{SignalAddr, SignalKind};
