//! ENFOR-SA's non-intrusive transient fault injection.
//!
//! The key observation (paper §III-A): the verilated model updates
//! registers in *inverted assignment order*, so register `R_target` of a
//! PE latches the value of its **source** — the upstream PE's register —
//! before the source itself is refreshed. Injecting into `R_target` at
//! cycle `t` therefore requires no HDL instrumentation at all: flip bits
//! in the *source variable* right before `step()` of cycle `t`. During
//! that step the target (and this PE's MAC, which taps the same wire)
//! consumes the corrupted value; at the end of the same step the source
//! is overwritten with its own clean upstream data. One branch per cycle
//! in the simulation wrapper — zero cost per assignment.
//!
//! Source mapping used here (OS dataflow, mirrors Fig. 2):
//!
//! | target (r, c)      | source flipped pre-step                       |
//! |--------------------|-----------------------------------------------|
//! | `Weight` (a path)  | `reg_a[r][c-1]`, or the west edge wire if c=0 |
//! | `Act` (b path)     | `reg_b[r-1][c]`, or the north edge wire if r=0|
//! | `Propag`           | `reg_propag[r-1][c]` / north edge wire        |
//! | `Valid`            | `reg_valid[r-1][c]` / north edge wire         |
//! | `Acc`              | the accumulator itself (self-sourced: the MAC |
//! |                    | reads-modifies-writes it, so a pre-step flip  |
//! |                    | is exactly an SEU latched the cycle before)   |
//! | `DReg`             | the d-chain register itself (rewritten every  |
//! |                    | cycle, so the flip lives exactly one cycle)   |
//!
//! The signal kinds address **logical operands**, so the weight-
//! stationary dataflow remaps the two operand classes onto the storage
//! that actually holds them (the control/storage rows are unchanged):
//!
//! * `Weight` — the PE's stationary `reg_w`: an SEU there persists
//!   until the next preload rewrites it (operands *held* rather than
//!   streamed — the masking-structure difference WS campaigns measure);
//! * `Act` — the horizontal a-path pipeline (`reg_a[r][c-1]` / west
//!   edge wire), where WS streams its activations.

use super::lane::LaneMesh;
use super::mesh::{Mesh, MeshInputs, MeshSim, StepOutput};
use super::signal::{SignalAddr, SignalKind};
use crate::config::Dataflow;
use crate::util::bits::{flip_bool, flip_i32, flip_i8, set_bit_i32, set_bit_i8};

/// Fault persistence model.
///
/// * `Transient` — classic SEU: one latch event corrupted (the paper's
///   model; `cycle` is the single firing cycle).
/// * `StuckAt(v)` — permanent defect: the target bit is forced to `v`
///   on EVERY cycle from `cycle` onward (extension; cf. the Gemmini
///   stuck-at study [26] the paper discusses). ENFOR-SA's source-flip
///   technique supports this for free — the wrapper re-applies the
///   forcing each cycle, still without HDL instrumentation.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, Default)]
pub enum Persistence {
    #[default]
    Transient,
    StuckAt(bool),
}


/// A single transient (SEU) fault: one bit of one signal at one cycle.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Fault {
    pub addr: SignalAddr,
    /// Bit index within the signal (< addr.kind.width()).
    pub bit: u8,
    /// Injection cycle, relative to the start of the offloaded matmul
    /// (first firing cycle for stuck-at faults).
    pub cycle: u64,
    /// Transient (default) or permanent stuck-at.
    pub persistence: Persistence,
}

impl Fault {
    /// A transient (SEU) fault — the paper's model.
    pub fn new(row: usize, col: usize, kind: SignalKind, bit: u8, cycle: u64) -> Self {
        debug_assert!(bit < kind.width());
        Fault {
            addr: SignalAddr::new(row, col, kind),
            bit,
            cycle,
            persistence: Persistence::Transient,
        }
    }

    /// A permanent stuck-at-`value` fault active from `from_cycle` on.
    pub fn stuck_at(
        row: usize,
        col: usize,
        kind: SignalKind,
        bit: u8,
        value: bool,
        from_cycle: u64,
    ) -> Self {
        debug_assert!(bit < kind.width());
        Fault {
            addr: SignalAddr::new(row, col, kind),
            bit,
            cycle: from_cycle,
            persistence: Persistence::StuckAt(value),
        }
    }

    /// Does this fault act on cycle `t`? (The wrapper's only per-cycle
    /// check.)
    #[inline]
    pub fn fires_at(&self, t: u64) -> bool {
        match self.persistence {
            Persistence::Transient => self.cycle == t,
            Persistence::StuckAt(_) => t >= self.cycle,
        }
    }
}

impl std::fmt::Display for Fault {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "PE({},{}).{}[bit {}] @ cycle {}",
            self.addr.row, self.addr.col, self.addr.kind, self.bit, self.cycle
        )?;
        if let Persistence::StuckAt(v) = self.persistence {
            write!(f, " (stuck-at-{})", v as u8)?;
        }
        Ok(())
    }
}

/// A cycle-sorted set of faults injected during ONE offloaded matmul —
/// the unit every injection seam speaks since the scenario redesign
/// (single SEU, MBU, spatial burst, double SEU, stuck-at... each
/// scenario is just a different sampler producing a plan).
///
/// * An **empty plan is a golden run** — the drivers skip `arm`/`disarm`
///   and the per-cycle check never fires.
/// * [`FaultPlan::single`] expresses every legacy single-`Fault` call
///   site; [`Fault`] stays the atom.
/// * Faults are kept **sorted by cycle** (stable, so same-cycle faults
///   fire in sample order), which is what lets the wrapper's per-cycle
///   check stay a single compare via [`PlanCursor::next_cycle`] — the
///   whole point of the paper's §III-A technique, preserved for
///   multi-fault scenarios.
#[derive(Clone, Debug, PartialEq, Eq, Hash, Default)]
pub struct FaultPlan {
    faults: Vec<Fault>,
}

impl FaultPlan {
    /// Build a plan from arbitrary faults (sorted by cycle; stable).
    pub fn new(mut faults: Vec<Fault>) -> Self {
        faults.sort_by_key(|f| f.cycle);
        FaultPlan { faults }
    }

    /// The legacy shape: exactly one fault.
    pub fn single(fault: Fault) -> Self {
        FaultPlan { faults: vec![fault] }
    }

    /// Golden run (no faults).
    pub fn empty() -> Self {
        FaultPlan::default()
    }

    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }

    pub fn len(&self) -> usize {
        self.faults.len()
    }

    /// The faults, cycle-sorted.
    pub fn faults(&self) -> &[Fault] {
        &self.faults
    }

    /// Onset cycle of the earliest fault (`u64::MAX` when empty).
    pub fn first_cycle(&self) -> u64 {
        self.faults.first().map_or(u64::MAX, |f| f.cycle)
    }

    /// True when the plan targets control-path state (the tile
    /// sequencer / drain FSM, [`SignalKind::Ctrl`]) — the drivers then
    /// route every cycle through [`apply_control`], and the campaign
    /// falls lane-batched engines back to cycle-resume for the batch.
    pub fn has_control(&self) -> bool {
        self.faults.iter().any(|f| f.addr.kind == SignalKind::Ctrl)
    }

    /// Copy `src` into this plan in place, reusing the existing
    /// allocation (the derived `clone` would allocate per call — this is
    /// the per-trial re-arm path of persistent backends like the SoC).
    pub fn clone_from_plan(&mut self, src: &FaultPlan) {
        self.faults.clear();
        self.faults.extend_from_slice(&src.faults);
    }

    /// Empty the plan in place, keeping the allocation (disarm).
    pub fn clear(&mut self) {
        self.faults.clear();
    }
}

impl From<Fault> for FaultPlan {
    fn from(f: Fault) -> Self {
        FaultPlan::single(f)
    }
}

impl std::fmt::Display for FaultPlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.faults.is_empty() {
            return write!(f, "golden (no faults)");
        }
        for (i, fault) in self.faults.iter().enumerate() {
            if i > 0 {
                write!(f, " + ")?;
            }
            write!(f, "{fault}")?;
        }
        Ok(())
    }
}

/// Per-run firing state over a [`FaultPlan`]. The plan itself is shared
/// immutably across trials; the cursor is the tiny mutable part a driver
/// (or the SoC controller) owns for the duration of one matmul.
///
/// Hot-path contract: the wrapper performs exactly **one compare per
/// cycle** — `cursor.next_cycle() == t` — and only on a hit walks the
/// due faults. Stuck-at faults re-arm the cursor for `t + 1` so their
/// forcing is re-applied every cycle from onset, still wrapper-only.
#[derive(Clone, Debug)]
pub struct PlanCursor {
    /// Index of the next not-yet-started fault in the sorted plan.
    next: usize,
    /// Cycle of the next due injection (`u64::MAX` when nothing pends).
    due: u64,
    /// Stuck-at forcings already begun (re-applied every cycle). Empty
    /// for pure-transient plans — `Vec::new` never allocates.
    active: Vec<Fault>,
}

impl Default for PlanCursor {
    fn default() -> Self {
        PlanCursor {
            next: 0,
            due: u64::MAX,
            active: Vec::new(),
        }
    }
}

impl PlanCursor {
    /// Start a cursor at the beginning of `plan`.
    pub fn start(plan: &FaultPlan) -> PlanCursor {
        PlanCursor {
            next: 0,
            due: plan.first_cycle(),
            active: Vec::new(),
        }
    }

    /// The single hot-path compare: cycle of the next due injection.
    #[inline]
    pub fn next_cycle(&self) -> u64 {
        self.due
    }

    /// Fire every fault of `plan` due at cycle `t` (cold path; call only
    /// when `next_cycle() == t`, immediately before the `step()` of `t`).
    /// Active stuck-at forcings are re-applied first, then any fault
    /// whose onset is `t` starts, in plan (cycle-then-sample) order.
    pub fn fire<S: Injectable>(
        &mut self,
        plan: &FaultPlan,
        t: u64,
        mesh: &mut S,
        inp: &mut MeshInputs,
    ) {
        for f in &self.active {
            mesh.inject_now(f, inp);
        }
        let faults = plan.faults();
        while self.next < faults.len() && faults[self.next].cycle == t {
            let f = faults[self.next];
            mesh.inject_now(&f, inp);
            if matches!(f.persistence, Persistence::StuckAt(_)) {
                self.active.push(f);
            }
            self.next += 1;
        }
        self.due = if !self.active.is_empty() {
            t + 1
        } else if self.next < faults.len() {
            faults[self.next].cycle
        } else {
            u64::MAX
        };
    }
}

/// Bit indices `>= CTRL_SEQ_BIT` of a [`SignalKind::Ctrl`] fault target
/// the tile sequencer's cycle counter; lower bits target the per-column
/// drain-FSM counter of `addr.col`.
pub const CTRL_SEQ_BIT: u8 = 8;

/// Apply every control-path ([`SignalKind::Ctrl`]) fault of `plan` due
/// at cycle `t` to the schedule machinery the drivers own:
///
/// * sequencer bits (`bit >= CTRL_SEQ_BIT`) XOR into the cycle index
///   the sequencer fetches operands for — returned as the corrupted
///   fill cycle, wrapped into `0..total` (a misfetched schedule step;
///   on the whole-SoC backend this redirects the scratchpad/accumulator
///   reads of the window, i.e. a corrupted DMA descriptor);
/// * drain bits (`bit < CTRL_SEQ_BIT`) XOR into the per-column
///   drain-FSM counter `taken[addr.col]` (the drain's own bounds guard
///   keeps out-of-range counts from writing outside the result tile —
///   results are silently dropped or re-ordered, the FSM failure mode).
///
/// Transient faults act on their own cycle only; stuck-at faults
/// re-corrupt every cycle from onset ([`Fault::fires_at`]). Callers
/// gate the per-cycle scan on [`FaultPlan::has_control`], so plans
/// without control faults keep the single-compare hot path.
pub fn apply_control(plan: &FaultPlan, t: u64, total: u64, taken: &mut [usize]) -> u64 {
    let mut fill_t = t;
    for f in plan.faults() {
        if f.addr.kind != SignalKind::Ctrl || !f.fires_at(t) {
            continue;
        }
        if f.bit >= CTRL_SEQ_BIT {
            fill_t ^= 1u64 << (f.bit - CTRL_SEQ_BIT);
        } else if !taken.is_empty() {
            taken[f.addr.col % taken.len()] ^= 1usize << f.bit;
        }
    }
    if total > 0 {
        fill_t % total
    } else {
        fill_t
    }
}

/// Apply `fault` to the plain mesh using the source-register technique.
/// Must be called immediately before the `step()` of each firing cycle.
pub fn apply_enforsa(mesh: &mut Mesh, inp: &mut MeshInputs, fault: &Fault) {
    let (r, c) = (fault.addr.row, fault.addr.col);
    let dim = mesh.dim();
    assert!(r < dim && c < dim, "fault target outside mesh");
    let i = r * dim + c;
    // corruption operators for this fault's persistence model
    let f8 = |v: i8| match fault.persistence {
        Persistence::Transient => flip_i8(v, fault.bit),
        Persistence::StuckAt(val) => set_bit_i8(v, fault.bit, val),
    };
    let f32v = |v: i32| match fault.persistence {
        Persistence::Transient => flip_i32(v, fault.bit),
        Persistence::StuckAt(val) => set_bit_i32(v, fault.bit, val),
    };
    let fb = |v: bool| match fault.persistence {
        Persistence::Transient => flip_bool(v),
        Persistence::StuckAt(val) => val,
    };
    match fault.addr.kind {
        SignalKind::Weight => {
            if mesh.dataflow() == Dataflow::WeightStationary {
                // WS: the weight lives in the PE's stationary register —
                // an SEU there persists until the next preload.
                mesh.reg_w[i] = f8(mesh.reg_w[i]);
            } else if c == 0 {
                inp.west_a[r] = f8(inp.west_a[r]);
            } else {
                mesh.reg_a[i - 1] = f8(mesh.reg_a[i - 1]);
            }
        }
        SignalKind::Act => {
            if mesh.dataflow() == Dataflow::WeightStationary {
                // WS: activations stream on the horizontal a path.
                if c == 0 {
                    inp.west_a[r] = f8(inp.west_a[r]);
                } else {
                    mesh.reg_a[i - 1] = f8(mesh.reg_a[i - 1]);
                }
            } else if r == 0 {
                inp.north_b[c] = f8(inp.north_b[c]);
            } else {
                mesh.reg_b[i - dim] = f8(mesh.reg_b[i - dim]);
            }
        }
        SignalKind::Propag => {
            if r == 0 {
                inp.north_propag[c] = fb(inp.north_propag[c]);
            } else {
                mesh.reg_propag[i - dim] = fb(mesh.reg_propag[i - dim]);
            }
        }
        SignalKind::Valid => {
            if r == 0 {
                inp.north_valid[c] = fb(inp.north_valid[c]);
            } else {
                mesh.reg_valid[i - dim] = fb(mesh.reg_valid[i - dim]);
            }
        }
        SignalKind::Acc => {
            mesh.acc[i] = f32v(mesh.acc[i]);
        }
        SignalKind::DReg => {
            mesh.reg_d[i] = f32v(mesh.reg_d[i]);
        }
        // Control-path faults live OUTSIDE the PE grid (tile sequencer /
        // drain FSM): the drivers apply them via `apply_control`, not
        // through the PE source-flip path.
        SignalKind::Ctrl => {}
    }
}

impl Mesh {
    /// ENFOR-SA injection entry point used by the drivers.
    pub fn inject_now(&mut self, fault: &Fault, inp: &mut MeshInputs) {
        apply_enforsa(self, inp, fault);
    }
}

/// Lane-batched twin of [`apply_enforsa`]: corrupt the SAME source
/// register/edge wire, but only in lane `lane` of a [`LaneMesh`]. The
/// lane-contiguous SoA layout maps scalar flat index `x` to
/// `x * lanes + lane`, so every arm below is the scalar arm with that
/// stride substituted; edge wires land in the per-lane stripes that
/// `LaneMesh::begin_cycle` rebuilds each cycle (giving edge faults the
/// same one-cycle lifetime as the scalar path's refilled `MeshInputs`).
/// `north_d` has no arm here for the same reason it has none above: the
/// preload stream is not an injection target.
pub(crate) fn apply_enforsa_lane(mesh: &mut LaneMesh, lane: usize, fault: &Fault) {
    let (r, c) = (fault.addr.row, fault.addr.col);
    let dim = mesh.dim();
    let lanes = mesh.lanes();
    assert!(r < dim && c < dim, "fault target outside mesh");
    assert!(lane < lanes, "fault lane outside the lane batch");
    let i = (r * dim + c) * lanes + lane;
    let f8 = |v: i8| match fault.persistence {
        Persistence::Transient => flip_i8(v, fault.bit),
        Persistence::StuckAt(val) => set_bit_i8(v, fault.bit, val),
    };
    let f32v = |v: i32| match fault.persistence {
        Persistence::Transient => flip_i32(v, fault.bit),
        Persistence::StuckAt(val) => set_bit_i32(v, fault.bit, val),
    };
    let fb = |v: bool| match fault.persistence {
        Persistence::Transient => flip_bool(v),
        Persistence::StuckAt(val) => val,
    };
    match fault.addr.kind {
        SignalKind::Weight => {
            if mesh.dataflow() == Dataflow::WeightStationary {
                mesh.reg_w[i] = f8(mesh.reg_w[i]);
            } else if c == 0 {
                let e = r * lanes + lane;
                mesh.west_a[e] = f8(mesh.west_a[e]);
            } else {
                mesh.reg_a[i - lanes] = f8(mesh.reg_a[i - lanes]);
            }
        }
        SignalKind::Act => {
            if mesh.dataflow() == Dataflow::WeightStationary {
                if c == 0 {
                    let e = r * lanes + lane;
                    mesh.west_a[e] = f8(mesh.west_a[e]);
                } else {
                    mesh.reg_a[i - lanes] = f8(mesh.reg_a[i - lanes]);
                }
            } else if r == 0 {
                let e = c * lanes + lane;
                mesh.north_b[e] = f8(mesh.north_b[e]);
            } else {
                mesh.reg_b[i - dim * lanes] = f8(mesh.reg_b[i - dim * lanes]);
            }
        }
        SignalKind::Propag => {
            if r == 0 {
                let e = c * lanes + lane;
                mesh.north_propag[e] = fb(mesh.north_propag[e]);
            } else {
                mesh.reg_propag[i - dim * lanes] = fb(mesh.reg_propag[i - dim * lanes]);
            }
        }
        SignalKind::Valid => {
            if r == 0 {
                let e = c * lanes + lane;
                mesh.north_valid[e] = fb(mesh.north_valid[e]);
            } else {
                mesh.reg_valid[i - dim * lanes] = fb(mesh.reg_valid[i - dim * lanes]);
            }
        }
        SignalKind::Acc => {
            mesh.acc[i] = f32v(mesh.acc[i]);
        }
        SignalKind::DReg => {
            mesh.reg_d[i] = f32v(mesh.reg_d[i]);
        }
        // Applied by the drivers via `apply_control` (never lane-batched:
        // the campaign falls control plans back to cycle-resume).
        SignalKind::Ctrl => {}
    }
}

/// Backend-polymorphic injection interface for the matmul drivers.
///
/// * `arm` / `disarm` bracket a run and speak whole [`FaultPlan`]s —
///   HDFIT-style backends pre-configure one instrumentation hook per
///   planned fault here (HDFIT faults are part of the elaborated
///   design), while ENFOR-SA's mesh needs nothing.
/// * `inject_now` fires ONE due fault and is called by the wrapper's
///   [`PlanCursor`] right before the `step()` of that fault's firing
///   cycle — the per-cycle overhead stays a single compare+branch
///   (`PlanCursor::next_cycle() == t`), which is the whole point of the
///   technique; [`Fault`] remains the firing atom.
pub trait Injectable: MeshSim {
    fn arm(&mut self, _plan: &FaultPlan) {}
    fn inject_now(&mut self, _fault: &Fault, _inp: &mut MeshInputs) {}
    fn disarm(&mut self) {}

    /// Earliest cycle at which this backend's execution of `plan` can
    /// diverge from the golden (fault-free) trajectory — the cycle a
    /// cycle-resume trial must restore its golden snapshot at (every
    /// earlier cycle is bit-identical to the golden pass and safe to
    /// skip). The ENFOR-SA wrapper first acts at the plan's onset
    /// cycle; HDFIT-style backends override this because their storage
    /// hooks fire on the *assignment* one cycle before the onset.
    fn first_effect_cycle(&self, plan: &FaultPlan) -> u64 {
        plan.first_cycle()
    }
}

impl Injectable for Mesh {
    #[inline]
    fn inject_now(&mut self, fault: &Fault, inp: &mut MeshInputs) {
        Mesh::inject_now(self, fault, inp);
    }
}

/// A no-fault golden run helper: step `n` idle cycles (used by benches).
pub fn idle_cycles<S: MeshSim>(mesh: &mut S, n: u64) {
    let dim = mesh.dim();
    let inp = MeshInputs::idle(dim);
    let mut out = StepOutput::new(dim);
    for _ in 0..n {
        mesh.step(&inp, &mut out);
    }
}

/// Convenience constructor for tests/benches.
pub fn os_mesh(dim: usize) -> Mesh {
    Mesh::new(dim, Dataflow::OutputStationary)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Dataflow;

    fn mesh4() -> (Mesh, MeshInputs, StepOutput) {
        (
            Mesh::new(4, Dataflow::OutputStationary),
            MeshInputs::idle(4),
            StepOutput::new(4),
        )
    }

    #[test]
    fn weight_fault_corrupts_target_mac_not_source() {
        // Fill the a-pipeline of row 1 with a known value, then inject a
        // Weight fault targeting PE(1,2): PE(1,2)'s next latched a must be
        // corrupted, PE(1,1)'s must stay clean.
        let (mut m, mut inp, mut out) = mesh4();
        inp.west_a[1] = 16;
        // march the value into reg_a[1][1]
        m.step(&inp, &mut out); // reg_a[1][0] = 16
        m.step(&inp, &mut out); // reg_a[1][1] = 16
        let f = Fault::new(1, 2, SignalKind::Weight, 0, m.cycle());
        m.inject_now(&f, &mut inp);
        m.step(&inp, &mut out); // PE(1,2) latches flipped source
        assert_eq!(m.reg_a[m.idx(1, 2)], 17, "target latched corrupt value");
        assert_eq!(
            m.reg_a[m.idx(1, 1)],
            16,
            "source restored by its own upstream data"
        );
    }

    #[test]
    fn weight_fault_at_column_zero_flips_edge_wire() {
        let (mut m, mut inp, _out) = mesh4();
        inp.west_a[2] = 1;
        let f = Fault::new(2, 0, SignalKind::Weight, 1, 0);
        m.inject_now(&f, &mut inp);
        assert_eq!(inp.west_a[2], 3);
    }

    #[test]
    fn propag_fault_hijacks_accumulator_from_above() {
        // Give PE(0,0) and PE(1,0) distinct accumulators; flip propag at
        // PE(1,0): its acc must become the d-chain value (acc above,
        // latched the previous cycle).
        let (mut m, mut inp, mut out) = mesh4();
        let i = m.idx(0, 0);

        m.acc[i] = 111;
        let i = m.idx(1, 0);

        m.acc[i] = 222;
        // One idle step so reg_d[1][0] latches acc[0][0] = 111.
        m.step(&inp, &mut out);
        let f = Fault::new(1, 0, SignalKind::Propag, 0, m.cycle());
        m.inject_now(&f, &mut inp);
        m.step(&inp, &mut out);
        assert_eq!(m.acc_at(1, 0), 111, "partial sum destroyed by propag");
        // and the erroneous bit forwards south:
        assert!(m.reg_propag[m.idx(1, 0)]);
    }

    #[test]
    fn propag_corruption_cascades_down_the_column() {
        // After the fault at row 1, the flipped bit reaches row 2 next
        // cycle and destroys its accumulator too (paper: whole column
        // below the target is affected; upper rows more critical).
        let (mut m, mut inp, mut out) = mesh4();
        for r in 0..4 {
            let i = m.idx(r, 0);
            m.acc[i] = (r as i32 + 1) * 100;
        }
        m.step(&inp, &mut out); // settle d-chain
        let f = Fault::new(1, 0, SignalKind::Propag, 0, m.cycle());
        m.inject_now(&f, &mut inp);
        m.step(&inp, &mut out); // row 1 hijacked
        m.step(&inp, &mut out); // row 2 hijacked by forwarded bit
        m.step(&inp, &mut out); // row 3 hijacked
        assert_ne!(m.acc_at(2, 0), 300);
        assert_ne!(m.acc_at(3, 0), 400);
        assert_eq!(m.acc_at(0, 0), 100, "rows above are untouched");
    }

    #[test]
    fn valid_fault_suppresses_one_mac() {
        let (mut m, mut inp, mut out) = mesh4();
        // Continuous MAC stream into PE(0,0): a=2, b=3, valid.
        inp.west_a[0] = 2;
        inp.north_b[0] = 3;
        inp.north_valid[0] = true;
        m.step(&inp, &mut out);
        assert_eq!(m.acc_at(0, 0), 6);
        // Fault: flip valid at PE(0,0) (row 0 -> edge wire).
        let f = Fault::new(0, 0, SignalKind::Valid, 0, m.cycle());
        m.inject_now(&f, &mut inp);
        m.step(&inp, &mut out);
        assert_eq!(m.acc_at(0, 0), 6, "MAC suppressed for one cycle");
        // stream continues (inject_now flipped only the cycle's wire value)
        inp.north_valid[0] = true;
        m.step(&inp, &mut out);
        assert_eq!(m.acc_at(0, 0), 12);
    }

    #[test]
    fn acc_fault_is_persistent_until_overwritten() {
        let (mut m, mut inp, mut out) = mesh4();
        let i = m.idx(2, 2);

        m.acc[i] = 0b100;
        let f = Fault::new(2, 2, SignalKind::Acc, 0, 0);
        m.inject_now(&f, &mut inp);
        assert_eq!(m.acc_at(2, 2), 0b101);
        m.step(&inp, &mut out);
        m.step(&inp, &mut out);
        assert_eq!(m.acc_at(2, 2), 0b101, "SEU persists in storage");
    }

    #[test]
    fn dreg_fault_lives_one_cycle() {
        let (mut m, mut inp, mut out) = mesh4();
        let f = Fault::new(1, 1, SignalKind::DReg, 5, 0);
        m.inject_now(&f, &mut inp);
        assert_eq!(m.reg_d[m.idx(1, 1)], 32);
        m.step(&inp, &mut out); // reg_d rewritten from acc above (0)
        assert_eq!(m.reg_d[m.idx(1, 1)], 0);
    }

    #[test]
    fn act_fault_mirrors_weight_on_vertical_path() {
        let (mut m, mut inp, mut out) = mesh4();
        inp.north_b[2] = 32;
        m.step(&inp, &mut out); // reg_b[0][2] = 32
        inp.clear(); // stop driving the edge so the refresh value is 0
        let f = Fault::new(1, 2, SignalKind::Act, 7, m.cycle());
        m.inject_now(&f, &mut inp);
        m.step(&inp, &mut out);
        assert_eq!(m.reg_b[m.idx(1, 2)], 32 | -128, "target corrupted");
        assert_eq!(m.reg_b[m.idx(0, 2)], 0, "source refreshed clean");
    }

    #[test]
    fn ws_operand_faults_target_the_ws_storage() {
        // WS remap: `Act` rides the horizontal a path (where WS streams
        // activations), `Weight` flips the stationary reg_w in place —
        // and the weight SEU persists until the next preload.
        let mut m = Mesh::new(4, Dataflow::WeightStationary);
        let mut inp = MeshInputs::idle(4);
        let mut out = StepOutput::new(4);
        inp.west_a[1] = 16;
        m.step(&inp, &mut out); // reg_a[1][0] = 16
        m.step(&inp, &mut out); // reg_a[1][1] = 16
        let f = Fault::new(1, 2, SignalKind::Act, 0, m.cycle());
        m.inject_now(&f, &mut inp);
        m.step(&inp, &mut out);
        assert_eq!(m.reg_a[m.idx(1, 2)], 17, "target latched corrupt activation");
        assert_eq!(m.reg_a[m.idx(1, 1)], 16, "source refreshed by upstream data");

        let i = m.idx(2, 3);
        m.reg_w[i] = 0b100;
        let f = Fault::new(2, 3, SignalKind::Weight, 0, m.cycle());
        m.inject_now(&f, &mut inp);
        assert_eq!(m.reg_w[i], 0b101);
        inp.clear();
        m.step(&inp, &mut out);
        m.step(&inp, &mut out);
        assert_eq!(m.reg_w[i], 0b101, "stationary weight SEU persists");
    }

    #[test]
    fn display_formats() {
        let f = Fault::new(3, 4, SignalKind::Propag, 0, 17);
        assert_eq!(f.to_string(), "PE(3,4).propag[bit 0] @ cycle 17");
        let sa = Fault::stuck_at(1, 2, SignalKind::Acc, 5, true, 3);
        assert_eq!(sa.to_string(), "PE(1,2).acc[bit 5] @ cycle 3 (stuck-at-1)");
        assert_eq!(FaultPlan::empty().to_string(), "golden (no faults)");
        let plan = FaultPlan::new(vec![f, Fault::new(0, 0, SignalKind::Acc, 1, 2)]);
        assert_eq!(
            plan.to_string(),
            "PE(0,0).acc[bit 1] @ cycle 2 + PE(3,4).propag[bit 0] @ cycle 17"
        );
    }

    #[test]
    fn plan_is_cycle_sorted_and_stable() {
        let f9 = Fault::new(0, 0, SignalKind::Acc, 0, 9);
        let f2a = Fault::new(1, 1, SignalKind::Acc, 2, 2);
        let f2b = Fault::new(2, 2, SignalKind::Acc, 3, 2);
        let plan = FaultPlan::new(vec![f9, f2a, f2b]);
        assert_eq!(plan.faults(), &[f2a, f2b, f9]);
        assert_eq!(plan.first_cycle(), 2);
        assert_eq!(plan.len(), 3);
        assert!(FaultPlan::empty().is_empty());
        assert_eq!(FaultPlan::empty().first_cycle(), u64::MAX);
        assert_eq!(FaultPlan::from(f9).faults(), &[f9]);
    }

    #[test]
    fn cursor_fires_all_same_cycle_faults_once() {
        // A same-cycle multi-fault plan (burst/MBU shape) fired through
        // the cursor must equal N manual inject_now calls.
        let dim = 4;
        let (mut m1, mut inp1, _o1) = mesh4();
        let (mut m2, mut inp2, _o2) = mesh4();
        for r in 0..dim {
            let i = m1.idx(r, 1);
            m1.acc[i] = (r as i32 + 1) * 7;
            m2.acc[i] = (r as i32 + 1) * 7;
        }
        let faults: Vec<Fault> =
            (0..dim).map(|r| Fault::new(r, 1, SignalKind::Acc, 2, 0)).collect();
        let plan = FaultPlan::new(faults.clone());
        let mut cur = PlanCursor::start(&plan);
        assert_eq!(cur.next_cycle(), 0);
        cur.fire(&plan, 0, &mut m1, &mut inp1);
        assert_eq!(cur.next_cycle(), u64::MAX, "transients fire once");
        for f in &faults {
            m2.inject_now(f, &mut inp2);
        }
        for r in 0..dim {
            assert_eq!(m1.acc_at(r, 1), m2.acc_at(r, 1), "row {r}");
        }
    }

    #[test]
    fn cursor_rearms_every_cycle_for_stuck_at() {
        let plan = FaultPlan::single(Fault::stuck_at(0, 0, SignalKind::Acc, 3, true, 5));
        let (mut m, mut inp, _o) = mesh4();
        let mut cur = PlanCursor::start(&plan);
        assert_eq!(cur.next_cycle(), 5);
        cur.fire(&plan, 5, &mut m, &mut inp);
        assert_eq!(cur.next_cycle(), 6, "stuck-at keeps the cursor armed");
        assert_eq!(m.acc_at(0, 0), 1 << 3);
        m.acc[0] = 0;
        cur.fire(&plan, 6, &mut m, &mut inp);
        assert_eq!(m.acc_at(0, 0), 1 << 3, "forcing re-applied");
        assert_eq!(cur.next_cycle(), 7);
    }
}
