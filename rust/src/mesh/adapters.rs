//! Interface adapters (paper Fig. 3, Step 2).
//!
//! When the Mesh is isolated from the SoC, the surrounding hardware —
//! scratchpad read pipelines that skew operand rows, the transposer, and
//! the accumulator drain logic — is emulated by these cheap adapters.
//! They reproduce the *boundary timing* of the real blocks (one column of
//! skew registers per row/column) without simulating their internals.

/// Emulates the bank of skew shift-registers that staggers operand row
/// `i` by `i` cycles on its way into the array.
///
/// `feed(t)` returns the edge value for lane `i` at cycle `t` given the
/// dense operand matrix: lane `i` sees element `t - i` of its stream, or
/// 0 outside the stream window (matching a zero-padded scratchpad read).
#[derive(Clone, Debug)]
pub struct SkewFeeder<T = i8> {
    /// streams[lane][k] = k-th element of the lane's operand stream.
    streams: Vec<Vec<T>>,
}

impl<T: Copy + Default> SkewFeeder<T> {
    /// Build from row streams: lane i carries `rows[i]`.
    pub fn from_rows(rows: &[Vec<T>]) -> Self {
        SkewFeeder {
            streams: rows.to_vec(),
        }
    }

    /// Build from the columns of a K x N matrix: lane c carries column c
    /// (this is the "transposer" path of the real Gemmini frontend).
    pub fn from_cols(mat: &[Vec<T>]) -> Self {
        let k = mat.len();
        let n = if k == 0 { 0 } else { mat[0].len() };
        let streams = (0..n)
            .map(|c| (0..k).map(|r| mat[r][c]).collect())
            .collect();
        SkewFeeder { streams }
    }

    pub fn lanes(&self) -> usize {
        self.streams.len()
    }

    /// Stream length (all lanes equal by construction).
    pub fn len(&self) -> usize {
        self.streams.first().map_or(0, |s| s.len())
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Edge value for `lane` at cycle `t` (skewed by `lane`).
    #[inline]
    pub fn at(&self, lane: usize, t: usize) -> T {
        let s = &self.streams[lane];
        if t >= lane {
            let k = t - lane;
            if k < s.len() {
                return s[k];
            }
        }
        T::default()
    }

    /// Whether lane `lane` carries live data at cycle `t` (the valid bit
    /// that travels with the stream).
    #[inline]
    pub fn live(&self, lane: usize, t: usize) -> bool {
        t >= lane && t - lane < self.streams[lane].len()
    }

    /// Cycles until every lane has drained.
    pub fn duration(&self) -> usize {
        if self.lanes() == 0 {
            0
        } else {
            self.len() + self.lanes() - 1
        }
    }
}

impl SkewFeeder<i8> {
    /// Mutable access to a stream element (fault injection into the
    /// emulated scratchpad-read pipeline feeding the mesh edge).
    pub fn flip_element(&mut self, lane: usize, k: usize, bit: u8) {
        if let Some(v) = self.streams.get_mut(lane).and_then(|s| s.get_mut(k)) {
            *v = crate::util::bits::flip_i8(*v, bit);
        }
    }
}

/// Collects the result matrix from the south edge during flush: the
/// accumulator chain emits row DIM-1 first, so the collector writes rows
/// in reverse order (the "un-staircasing" the real drain FSM performs).
#[derive(Clone, Debug)]
pub struct FlushCollector {
    dim: usize,
    /// Per column, how many values have been captured so far.
    taken: Vec<usize>,
    /// Collected matrix, row-major dim x dim.
    pub c: Vec<Vec<i32>>,
}

impl FlushCollector {
    pub fn new(dim: usize) -> Self {
        FlushCollector {
            dim,
            taken: vec![0; dim],
            c: vec![vec![0; dim]; dim],
        }
    }

    /// Record this cycle's south-edge flush outputs.
    pub fn absorb(&mut self, south_c: &[Option<i32>]) {
        for (col, v) in south_c.iter().enumerate() {
            if let Some(v) = *v {
                let k = self.taken[col];
                if k < self.dim {
                    self.c[self.dim - 1 - k][col] = v;
                    self.taken[col] += 1;
                }
            }
        }
    }

    /// True once every column produced DIM values.
    pub fn complete(&self) -> bool {
        self.taken.iter().all(|&t| t == self.dim)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn skew_feeder_delays_by_lane() {
        let rows = vec![vec![1i8, 2, 3], vec![4, 5, 6]];
        let f = SkewFeeder::from_rows(&rows);
        assert_eq!(f.at(0, 0), 1);
        assert_eq!(f.at(0, 2), 3);
        assert_eq!(f.at(1, 0), 0); // not arrived yet
        assert_eq!(f.at(1, 1), 4);
        assert_eq!(f.at(1, 3), 6);
        assert_eq!(f.at(1, 4), 0); // drained
        assert_eq!(f.duration(), 4);
    }

    #[test]
    fn skew_feeder_from_cols_transposes() {
        // 2x3 matrix; lane c = column c.
        let m = vec![vec![1i8, 2, 3], vec![4, 5, 6]];
        let f = SkewFeeder::from_cols(&m);
        assert_eq!(f.lanes(), 3);
        assert_eq!(f.at(0, 0), 1);
        assert_eq!(f.at(0, 1), 4);
        assert_eq!(f.at(2, 2), 3);
        assert_eq!(f.at(2, 3), 6);
    }

    #[test]
    fn live_matches_at_window() {
        let f = SkewFeeder::from_rows(&[vec![9i8; 4], vec![9i8; 4]]);
        for lane in 0..2 {
            for t in 0..8 {
                assert_eq!(f.live(lane, t), t >= lane && t - lane < 4);
            }
        }
    }

    #[test]
    fn flush_collector_reverses_rows() {
        let mut fc = FlushCollector::new(2);
        fc.absorb(&[Some(30), Some(40)]); // first out = row 1
        assert!(!fc.complete());
        fc.absorb(&[Some(10), Some(20)]); // then row 0
        assert!(fc.complete());
        assert_eq!(fc.c, vec![vec![10, 20], vec![30, 40]]);
    }

    #[test]
    fn flip_element_targets_stream() {
        let mut f = SkewFeeder::from_rows(&[vec![0i8, 0]]);
        f.flip_element(0, 1, 3);
        assert_eq!(f.at(0, 1), 8);
    }
}
