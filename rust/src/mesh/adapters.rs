//! Interface adapters (paper Fig. 3, Step 2).
//!
//! When the Mesh is isolated from the SoC, the surrounding hardware —
//! scratchpad read pipelines that skew operand rows, the transposer, and
//! the accumulator drain logic — is emulated by these cheap adapters.
//! They reproduce the *boundary timing* of the real blocks (one column of
//! skew registers per row/column) without simulating their internals.
//!
//! Since the flat-matrix refactor the feeders are **zero-copy**: a
//! [`SkewFeeder`] is a [`MatView`] plus an orientation bit, so feeding a
//! DIM-padded operand tile into the mesh allocates nothing — the view's
//! implicit zero padding plays the role of the zero-padded scratchpad
//! read the real frontend performs.

use super::mesh::StepOutput;
use crate::mat::{Mat, MatView};

/// Emulates the bank of skew shift-registers that staggers operand lane
/// `i` by `i` cycles on its way into the array.
///
/// `at(lane, t)` returns the edge value for `lane` at cycle `t`: lane
/// `i` sees element `t - i` of its stream, or 0 outside the stream
/// window (matching a zero-padded scratchpad read). Lanes are either the
/// rows of the backing view (`from_rows`) or its columns (`from_cols`,
/// the "transposer" path of the real Gemmini frontend).
#[derive(Clone, Copy, Debug)]
pub struct SkewFeeder<'a, T = i8> {
    view: MatView<'a, T>,
    /// Lanes are the view's columns (stream index walks down a column).
    by_cols: bool,
}

impl<'a, T: Copy + Default> SkewFeeder<'a, T> {
    /// Lane `i` carries row `i` of `view`.
    pub fn from_rows(view: MatView<'a, T>) -> Self {
        SkewFeeder {
            view,
            by_cols: false,
        }
    }

    /// Lane `c` carries column `c` of `view` (transposer path).
    pub fn from_cols(view: MatView<'a, T>) -> Self {
        SkewFeeder {
            view,
            by_cols: true,
        }
    }

    pub fn lanes(&self) -> usize {
        if self.by_cols {
            self.view.cols()
        } else {
            self.view.rows()
        }
    }

    /// Stream length (all lanes equal by construction).
    pub fn len(&self) -> usize {
        if self.by_cols {
            self.view.rows()
        } else {
            self.view.cols()
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Edge value for `lane` at cycle `t` (skewed by `lane`).
    #[inline]
    pub fn at(&self, lane: usize, t: usize) -> T {
        if t >= lane {
            let k = t - lane;
            if k < self.len() {
                return if self.by_cols {
                    self.view.at(k, lane)
                } else {
                    self.view.at(lane, k)
                };
            }
        }
        T::default()
    }

    /// Whether `lane` carries live data at cycle `t` (the valid bit that
    /// travels with the stream).
    #[inline]
    pub fn live(&self, lane: usize, t: usize) -> bool {
        t >= lane && t - lane < self.len()
    }

    /// Cycles until every lane has drained.
    pub fn duration(&self) -> usize {
        if self.lanes() == 0 {
            0
        } else {
            self.len() + self.lanes() - 1
        }
    }
}

/// Collects the result matrix from the south edge during flush: the
/// accumulator chain emits row DIM-1 first, so the collector writes rows
/// in reverse order (the "un-staircasing" the real drain FSM performs).
/// Used by the SoC controller's drain FSM; the mesh-only drivers inline
/// the same logic in `Schedule::drain` since the cycle-resume refactor
/// (a resumed trial must prime the drain mid-flush, which needs the
/// counters in caller-owned scratch).
#[derive(Clone, Debug)]
pub struct FlushCollector {
    dim: usize,
    /// Per column, how many values have been captured so far.
    taken: Vec<usize>,
    /// Collected matrix, dim x dim.
    pub c: Mat<i32>,
}

impl FlushCollector {
    pub fn new(dim: usize) -> Self {
        let mut c = Mat::default();
        c.reset(dim, dim);
        FlushCollector {
            dim,
            taken: vec![0; dim],
            c,
        }
    }

    /// Consume into the collected matrix.
    pub fn into_mat(self) -> Mat<i32> {
        self.c
    }

    /// Record this cycle's south-edge flush outputs.
    pub fn absorb(&mut self, out: &StepOutput) {
        for col in 0..self.dim {
            if out.has_south_c(col) {
                let k = self.taken[col];
                if k < self.dim {
                    self.c.set(self.dim - 1 - k, col, out.south_c_at(col));
                    self.taken[col] += 1;
                }
            }
        }
    }

    /// True once every column produced DIM values.
    pub fn complete(&self) -> bool {
        self.taken.iter().all(|&t| t == self.dim)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn skew_feeder_delays_by_lane() {
        let rows = Mat::from_vec(2, 3, vec![1i8, 2, 3, 4, 5, 6]);
        let f = SkewFeeder::from_rows(rows.view());
        assert_eq!(f.at(0, 0), 1);
        assert_eq!(f.at(0, 2), 3);
        assert_eq!(f.at(1, 0), 0); // not arrived yet
        assert_eq!(f.at(1, 1), 4);
        assert_eq!(f.at(1, 3), 6);
        assert_eq!(f.at(1, 4), 0); // drained
        assert_eq!(f.duration(), 4);
    }

    #[test]
    fn skew_feeder_from_cols_transposes() {
        // 2x3 matrix; lane c = column c.
        let m = Mat::from_vec(2, 3, vec![1i8, 2, 3, 4, 5, 6]);
        let f = SkewFeeder::from_cols(m.view());
        assert_eq!(f.lanes(), 3);
        assert_eq!(f.at(0, 0), 1);
        assert_eq!(f.at(0, 1), 4);
        assert_eq!(f.at(2, 2), 3);
        assert_eq!(f.at(2, 3), 6);
    }

    #[test]
    fn live_matches_at_window() {
        let m = Mat::filled(2, 4, 9i8);
        let f = SkewFeeder::from_rows(m.view());
        for lane in 0..2 {
            for t in 0..8 {
                assert_eq!(f.live(lane, t), t >= lane && t - lane < 4);
            }
        }
    }

    #[test]
    fn padded_window_feeds_zeros_in_overhang() {
        // a 4x4 window over a 2x2 parent: lanes 2..4 are pure padding,
        // exactly what the nested-matrix extraction used to materialize
        let m = Mat::from_vec(2, 2, vec![1i8, 2, 3, 4]);
        let f = SkewFeeder::from_rows(m.window(0, 0, 4, 4));
        assert_eq!(f.lanes(), 4);
        assert_eq!(f.len(), 4);
        assert_eq!(f.at(0, 0), 1);
        assert_eq!(f.at(0, 2), 0, "col overhang");
        assert_eq!(f.at(2, 2), 0, "row overhang");
        assert!(f.live(3, 3), "padding lanes still carry the valid window");
    }

    #[test]
    fn flush_collector_reverses_rows() {
        let south = |a: i32, b: i32| {
            let mut out = StepOutput::new(2);
            out.set_south_c(0, a);
            out.set_south_c(1, b);
            out
        };
        let mut fc = FlushCollector::new(2);
        fc.absorb(&south(30, 40)); // first out = row 1
        assert!(!fc.complete());
        fc.absorb(&south(10, 20)); // then row 0
        assert!(fc.complete());
        assert_eq!(fc.c, Mat::from_vec(2, 2, vec![10, 20, 30, 40]));
    }
}
