//! The verilated-equivalent Gemmini Mesh model.
//!
//! This is a cycle-accurate register-transfer simulator of the DIM x DIM
//! PE grid (the `Mesh.v` block the paper isolates in its "compilation"
//! step). It reproduces, by construction, the property ENFOR-SA's
//! injection method depends on: Verilator preserves Verilog non-blocking
//! register semantics by *inverting the order of register assignments*
//! (downstream registers are written first), so `step()` updates the grid
//! **in place**, most-downstream PE first (row DIM-1..0, col DIM-1..0),
//! and every read of a neighbour register observes its *pre-edge* value.
//!
//! PE microarchitecture (paper Fig. 2, output-stationary):
//!
//! ```text
//!            b_in  d_in  propag/valid (from north)
//!              │     │     │
//!   a_in ──►[MAC: acc += a_in*b_in]──► reg_a ──► east
//!              │     │     │
//!            reg_b reg_d reg_propag/reg_valid
//!              ▼     ▼     ▼   (to south)
//! ```
//!
//! * `reg_a` — horizontal operand pipeline register (weights, west→east);
//! * `reg_b` — vertical operand pipeline register (activations);
//! * `acc`   — the output-stationary 32-bit accumulator;
//! * `reg_d` — the accumulator-chain pipeline register: it latches the
//!   northern PE's `out_c` wire every cycle, so during `propagate` phases
//!   bias matrices staircase in and results staircase out correctly even
//!   though the propagate *enable* itself is pipelined row by row;
//! * `reg_propag` / `reg_valid` — the local control bits, forwarded south.
//!
//! The MAC consumes the *input wires* (the upstream registers); the PE's
//! own registers forward the operands to its neighbours one cycle later —
//! matching Gemmini, where a transient in a PE's operand register corrupts
//! that PE's MAC and every downstream PE one hop per cycle (Fig. 5b).

use crate::config::Dataflow;

/// Per-cycle boundary inputs, produced by the interface adapters.
#[derive(Clone, Debug)]
pub struct MeshInputs {
    /// West edge: operand entering each row's `a` path (weights).
    pub west_a: Vec<i8>,
    /// North edge: operand entering each column's `b` path (activations).
    pub north_b: Vec<i8>,
    /// North edge: accumulator-chain input (bias rows during preload).
    pub north_d: Vec<i32>,
    /// North edge: propagate control per column.
    pub north_propag: Vec<bool>,
    /// North edge: valid control per column.
    pub north_valid: Vec<bool>,
}

impl MeshInputs {
    pub fn idle(dim: usize) -> Self {
        MeshInputs {
            west_a: vec![0; dim],
            north_b: vec![0; dim],
            north_d: vec![0; dim],
            north_propag: vec![false; dim],
            north_valid: vec![false; dim],
        }
    }

    pub fn clear(&mut self) {
        self.west_a.fill(0);
        self.north_b.fill(0);
        self.north_d.fill(0);
        self.north_propag.fill(false);
        self.north_valid.fill(false);
    }
}

/// Values crossing the south edge during one cycle.
///
/// Dense `i32` buffers plus a validity bitmask (one bit per column,
/// packed into 64-bit words) instead of an `Option` per column: drain
/// collection is a mask-bit test over flat storage, and the lane-batched
/// kernels don't carry an `Option` per lane — mirroring the flat-`Mat`
/// boundary contract.
#[derive(Clone, Debug)]
pub struct StepOutput {
    /// `out_c` wire of each bottom-row PE when its propagate input was
    /// asserted this cycle (flush traffic); valid iff its mask bit set.
    south_c: Vec<i32>,
    /// Completed partial sums leaving the bottom row (WS dataflow).
    south_psum: Vec<i32>,
    south_c_mask: Vec<u64>,
    south_psum_mask: Vec<u64>,
}

impl StepOutput {
    pub fn new(dim: usize) -> Self {
        let words = dim.div_ceil(64);
        StepOutput {
            south_c: vec![0; dim],
            south_psum: vec![0; dim],
            south_c_mask: vec![0; words],
            south_psum_mask: vec![0; words],
        }
    }

    /// Invalidate every column. Values are left in place — only the mask
    /// words are zeroed, so the per-cycle clear is O(dim/64).
    pub fn clear(&mut self) {
        self.south_c_mask.fill(0);
        self.south_psum_mask.fill(0);
    }

    #[inline]
    pub fn set_south_c(&mut self, col: usize, v: i32) {
        self.south_c[col] = v;
        self.south_c_mask[col >> 6] |= 1 << (col & 63);
    }

    #[inline]
    pub fn set_south_psum(&mut self, col: usize, v: i32) {
        self.south_psum[col] = v;
        self.south_psum_mask[col >> 6] |= 1 << (col & 63);
    }

    #[inline]
    pub fn has_south_c(&self, col: usize) -> bool {
        self.south_c_mask[col >> 6] & (1 << (col & 63)) != 0
    }

    #[inline]
    pub fn has_south_psum(&self, col: usize) -> bool {
        self.south_psum_mask[col >> 6] & (1 << (col & 63)) != 0
    }

    /// The column's value; meaningful only when [`Self::has_south_c`].
    #[inline]
    pub fn south_c_at(&self, col: usize) -> i32 {
        self.south_c[col]
    }

    /// The column's value; meaningful only when [`Self::has_south_psum`].
    #[inline]
    pub fn south_psum_at(&self, col: usize) -> i32 {
        self.south_psum[col]
    }
}

/// A reusable architectural snapshot of a mesh simulator: every register
/// file plus the cycle counter. Pure scratch buffers (e.g. the pre-edge
/// row copy) carry no cross-cycle state and are excluded. The buffers
/// are recycled across [`MeshSim::save_state`] calls, so a warm snapshot
/// costs only memcpys — the primitive behind cycle-resume
/// (`restore_state(save_state(m)) ≡ id`, pinned by test).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct MeshState {
    // pub(crate): the lane-batched engine broadcasts a snapshot into
    // every lane of its SoA register files (`mesh::lane`).
    pub(crate) cycle: u64,
    pub(crate) reg_a: Vec<i8>,
    pub(crate) reg_b: Vec<i8>,
    pub(crate) acc: Vec<i32>,
    pub(crate) reg_d: Vec<i32>,
    pub(crate) reg_propag: Vec<bool>,
    pub(crate) reg_valid: Vec<bool>,
    pub(crate) reg_w: Vec<i8>,
}

impl MeshState {
    /// The cycle the snapshot was taken at.
    pub fn cycle(&self) -> u64 {
        self.cycle
    }
}

/// Recycle `dst`'s allocation while copying `src` into it.
fn copy_into<T: Copy>(dst: &mut Vec<T>, src: &[T]) {
    dst.clear();
    dst.extend_from_slice(src);
}

/// Common simulation interface implemented by the plain (ENFOR-SA) mesh
/// and the HDFIT-style instrumented mesh, so drivers and the campaign
/// engine are generic over the backend.
pub trait MeshSim {
    fn dim(&self) -> usize;
    fn dataflow(&self) -> Dataflow;
    fn cycle(&self) -> u64;
    /// Advance one clock edge.
    fn step(&mut self, inp: &MeshInputs, out: &mut StepOutput);
    /// Reset all architectural state (registers, accumulators, cycle).
    fn reset(&mut self);
    /// Read an accumulator (test/debug visibility, as in waveforms).
    fn acc_at(&self, row: usize, col: usize) -> i32;
    /// Snapshot every architectural register (and the cycle counter)
    /// into `state`, reusing its buffers.
    fn save_state(&self, state: &mut MeshState);
    /// Restore a snapshot taken by [`MeshSim::save_state`] on an
    /// identically-dimensioned simulator: afterwards the simulator is
    /// bit-identical to the one the snapshot was taken from
    /// (`restore ∘ save ≡ id`).
    fn restore_state(&mut self, state: &MeshState);
}

/// The plain verilated-equivalent mesh (no instrumentation — ENFOR-SA's
/// fast backend).
pub struct Mesh {
    dim: usize,
    dataflow: Dataflow,
    pub(crate) cycle: u64,
    // Flat SoA register files, index = row * dim + col.
    pub(crate) reg_a: Vec<i8>,
    pub(crate) reg_b: Vec<i8>,
    pub(crate) acc: Vec<i32>,
    pub(crate) reg_d: Vec<i32>,
    pub(crate) reg_propag: Vec<bool>,
    pub(crate) reg_valid: Vec<bool>,
    /// WS only: the stationary weight held in each PE.
    pub(crate) reg_w: Vec<i8>,
    /// Scratch: pre-edge copy of one row of `reg_a`, so rows can be
    /// evaluated left-to-right (vectorizable) while preserving the
    /// inverted-assignment-order semantics (§Perf iteration 2).
    scratch_a: Vec<i8>,
}

impl Mesh {
    pub fn new(dim: usize, dataflow: Dataflow) -> Self {
        assert!(dim > 0, "mesh dim must be positive");
        let n = dim * dim;
        Mesh {
            dim,
            dataflow,
            cycle: 0,
            reg_a: vec![0; n],
            reg_b: vec![0; n],
            acc: vec![0; n],
            reg_d: vec![0; n],
            reg_propag: vec![false; n],
            reg_valid: vec![false; n],
            reg_w: vec![0; n],
            scratch_a: vec![0; dim],
        }
    }

    #[inline]
    pub(crate) fn idx(&self, r: usize, c: usize) -> usize {
        r * self.dim + c
    }

    /// Output-stationary clock edge. In-place, inverted assignment order.
    ///
    /// Hot path of the whole framework (Table III/IV/V all sit on it).
    /// Perf notes (EXPERIMENTS.md §Perf): the north/west edge-PE cases
    /// are peeled out of the inner loop so interior PEs run branch-free,
    /// and the row-local state is accessed through disjoint slices so
    /// the optimizer drops the bounds checks.
    fn step_os(&mut self, inp: &MeshInputs, out: &mut StepOutput) {
        let dim = self.dim;
        for r in (0..dim).rev() {
            let base = r * dim;
            if r == 0 {
                // ---- north-edge row: sources are the boundary ports ----
                for c in (0..dim).rev() {
                    let a_in = if c == 0 {
                        inp.west_a[0]
                    } else {
                        self.reg_a[c - 1]
                    };
                    let b_in = inp.north_b[c];
                    let p_in = inp.north_propag[c];
                    let v_in = inp.north_valid[c];
                    let d_in = inp.north_d[c];
                    if p_in {
                        if dim == 1 {
                            out.set_south_c(c, self.acc[c]);
                        }
                        self.acc[c] = d_in;
                    } else if v_in {
                        self.acc[c] =
                            self.acc[c].wrapping_add(a_in as i32 * b_in as i32);
                    }
                    self.reg_d[c] = d_in;
                    self.reg_a[c] = a_in;
                    self.reg_b[c] = b_in;
                    self.reg_propag[c] = p_in;
                    self.reg_valid[c] = v_in;
                }
                continue;
            }
            // ---- interior rows ----
            // A pre-edge snapshot of this row's `reg_a` lets the row be
            // evaluated LEFT-TO-RIGHT with element-wise-independent
            // operations (the only intra-row dependency is the a-chain):
            // identical semantics to the inverted-order walk, but the
            // loop body becomes straight-line selects the autovectorizer
            // can lift to SIMD (§Perf iteration 2).
            let (north, row) = (base - dim, base);
            let bottom = r == dim - 1;
            self.scratch_a.copy_from_slice(&self.reg_a[row..row + dim]);
            for c in 0..dim {
                let i = row + c;
                let n = north + c;
                let a_in = if c == 0 {
                    inp.west_a[r]
                } else {
                    self.scratch_a[c - 1]
                };
                let b_in = self.reg_b[n];
                let p_in = self.reg_propag[n];
                let v_in = self.reg_valid[n];
                // Inner PEs read the accumulator-chain input from their
                // inter-PE pipeline register (which latched the northern
                // PE's out_c wire last cycle).
                let d_in = self.reg_d[i];
                let out_c_north = self.acc[n]; // pre-edge: updated later
                // ---- sequential assignments (branch-free selects) ----
                let acc_old = self.acc[i];
                if bottom && p_in {
                    out.set_south_c(c, acc_old);
                }
                let mac = acc_old.wrapping_add(a_in as i32 * b_in as i32);
                self.acc[i] = if p_in {
                    d_in
                } else if v_in {
                    mac
                } else {
                    acc_old
                };
                self.reg_d[i] = out_c_north;
                self.reg_a[i] = a_in;
                self.reg_b[i] = b_in;
                self.reg_propag[i] = p_in;
                self.reg_valid[i] = v_in;
            }
        }
        self.cycle += 1;
    }

    /// Weight-stationary clock edge. Weights preload through the d-chain
    /// (propagate phases), partial sums flow north→south through `acc`
    /// (acting as the psum pipeline register), activations west→east.
    ///
    /// Mirrors `step_os`'s shape (§Perf iteration 2, WS side): the
    /// north-edge row is peeled out so the boundary-port selects vanish
    /// from the interior, and interior rows take a pre-edge scratch copy
    /// of their `reg_a` so the walk runs LEFT-TO-RIGHT with
    /// straight-line selects — the a-chain is the only intra-row
    /// dependency, so the semantics equal the inverted-order walk while
    /// the loop body becomes SIMD-liftable.
    fn step_ws(&mut self, inp: &MeshInputs, out: &mut StepOutput) {
        let dim = self.dim;
        for r in (0..dim).rev() {
            let base = r * dim;
            if r == 0 {
                // ---- north-edge row: sources are the boundary ports ----
                let bottom = dim == 1;
                for c in (0..dim).rev() {
                    let a_in = if c == 0 { inp.west_a[0] } else { self.reg_a[c - 1] };
                    let b_in = inp.north_b[c];
                    let p_in = inp.north_propag[c];
                    let v_in = inp.north_valid[c];
                    let d_in = inp.north_d[c];
                    if p_in {
                        // weight preload: the d-chain staircases W in;
                        // the old weight flushes out through the chain.
                        if bottom {
                            out.set_south_c(c, self.reg_w[c] as i32);
                        }
                        self.reg_w[c] = (d_in & 0xff) as i8;
                        self.acc[c] = d_in;
                    } else if v_in {
                        let ps = d_in.wrapping_add(self.reg_w[c] as i32 * a_in as i32);
                        self.acc[c] = ps;
                        if bottom {
                            out.set_south_psum(c, ps);
                        }
                    }
                    self.reg_d[c] = d_in;
                    self.reg_a[c] = a_in;
                    self.reg_b[c] = b_in;
                    self.reg_propag[c] = p_in;
                    self.reg_valid[c] = v_in;
                }
                continue;
            }
            // ---- interior rows: pre-edge scratch a-row, straight-line
            // left-to-right body (see step_os) ----
            let north = base - dim;
            let bottom = r == dim - 1;
            self.scratch_a.copy_from_slice(&self.reg_a[base..base + dim]);
            for c in 0..dim {
                let i = base + c;
                let n = north + c;
                let a_in = if c == 0 {
                    inp.west_a[r]
                } else {
                    self.scratch_a[c - 1]
                };
                let b_in = self.reg_b[n];
                let p_in = self.reg_propag[n];
                let v_in = self.reg_valid[n];
                let d_in = self.reg_d[i];
                // psum + d-chain input: the northern accumulator,
                // pre-edge (rows walk bottom-up, so row r-1 is unwritten)
                let ps_in = self.acc[n];
                let w_old = self.reg_w[i];
                let ps = ps_in.wrapping_add(w_old as i32 * a_in as i32);
                if bottom {
                    if p_in {
                        out.set_south_c(c, w_old as i32);
                    } else if v_in {
                        out.set_south_psum(c, ps);
                    }
                }
                // ---- sequential assignments (branch-free selects) ----
                self.reg_w[i] = if p_in { (d_in & 0xff) as i8 } else { w_old };
                self.acc[i] = if p_in {
                    d_in
                } else if v_in {
                    ps
                } else {
                    self.acc[i]
                };
                self.reg_d[i] = ps_in;
                self.reg_a[i] = a_in;
                self.reg_b[i] = b_in;
                self.reg_propag[i] = p_in;
                self.reg_valid[i] = v_in;
            }
        }
        self.cycle += 1;
    }

    /// Number of architectural state elements evaluated per cycle — the
    /// quantity that governs simulation cost (DESIGN.md D2).
    pub fn state_elements(&self) -> usize {
        let per_pe = 7; // a, b, acc, d, w, propag, valid
        self.dim * self.dim * per_pe
    }
}

impl MeshSim for Mesh {
    fn dim(&self) -> usize {
        self.dim
    }

    fn dataflow(&self) -> Dataflow {
        self.dataflow
    }

    fn cycle(&self) -> u64 {
        self.cycle
    }

    #[inline]
    fn step(&mut self, inp: &MeshInputs, out: &mut StepOutput) {
        debug_assert_eq!(inp.west_a.len(), self.dim);
        match self.dataflow {
            Dataflow::OutputStationary => self.step_os(inp, out),
            Dataflow::WeightStationary => self.step_ws(inp, out),
        }
    }

    fn reset(&mut self) {
        self.cycle = 0;
        self.reg_a.fill(0);
        self.reg_b.fill(0);
        self.acc.fill(0);
        self.reg_d.fill(0);
        self.reg_propag.fill(false);
        self.reg_valid.fill(false);
        self.reg_w.fill(0);
    }

    fn acc_at(&self, row: usize, col: usize) -> i32 {
        self.acc[self.idx(row, col)]
    }

    fn save_state(&self, state: &mut MeshState) {
        state.cycle = self.cycle;
        copy_into(&mut state.reg_a, &self.reg_a);
        copy_into(&mut state.reg_b, &self.reg_b);
        copy_into(&mut state.acc, &self.acc);
        copy_into(&mut state.reg_d, &self.reg_d);
        copy_into(&mut state.reg_propag, &self.reg_propag);
        copy_into(&mut state.reg_valid, &self.reg_valid);
        copy_into(&mut state.reg_w, &self.reg_w);
    }

    fn restore_state(&mut self, state: &MeshState) {
        assert_eq!(
            state.acc.len(),
            self.acc.len(),
            "snapshot taken on a differently-dimensioned mesh"
        );
        self.cycle = state.cycle;
        self.reg_a.copy_from_slice(&state.reg_a);
        self.reg_b.copy_from_slice(&state.reg_b);
        self.acc.copy_from_slice(&state.acc);
        self.reg_d.copy_from_slice(&state.reg_d);
        self.reg_propag.copy_from_slice(&state.reg_propag);
        self.reg_valid.copy_from_slice(&state.reg_valid);
        self.reg_w.copy_from_slice(&state.reg_w);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn idle_steps_do_nothing() {
        let mut m = Mesh::new(4, Dataflow::OutputStationary);
        let inp = MeshInputs::idle(4);
        let mut out = StepOutput::new(4);
        for _ in 0..10 {
            m.step(&inp, &mut out);
        }
        assert_eq!(m.cycle(), 10);
        assert!(m.acc.iter().all(|&v| v == 0));
        assert!((0..4).all(|c| !out.has_south_c(c)));
    }

    #[test]
    fn single_mac_at_origin() {
        // Drive a=3 (row 0), b=5 (col 0), valid for exactly one cycle:
        // PE(0,0) must accumulate 15; nothing else changes.
        let mut m = Mesh::new(4, Dataflow::OutputStationary);
        let mut inp = MeshInputs::idle(4);
        let mut out = StepOutput::new(4);
        inp.west_a[0] = 3;
        inp.north_b[0] = 5;
        inp.north_valid[0] = true;
        m.step(&inp, &mut out);
        assert_eq!(m.acc_at(0, 0), 15);
        // the operands were latched for forwarding east/south:
        assert_eq!(m.reg_a[0], 3);
        assert_eq!(m.reg_b[0], 5);
        inp.clear();
        m.step(&inp, &mut out);
        assert_eq!(m.acc_at(0, 0), 15); // valid deasserted: no further MAC
    }

    #[test]
    fn operands_pipeline_one_hop_per_cycle() {
        let mut m = Mesh::new(4, Dataflow::OutputStationary);
        let mut inp = MeshInputs::idle(4);
        let mut out = StepOutput::new(4);
        inp.west_a[0] = 7;
        m.step(&inp, &mut out);
        inp.clear();
        // After k more cycles the value sits in reg_a of PE(0,k).
        for k in 1..4 {
            m.step(&inp, &mut out);
            assert_eq!(m.reg_a[k], 7, "cycle {k}");
            if k >= 1 {
                assert_eq!(m.reg_a[k - 1], 0);
            }
        }
    }

    #[test]
    fn propag_bit_travels_south() {
        let mut m = Mesh::new(4, Dataflow::OutputStationary);
        let mut inp = MeshInputs::idle(4);
        let mut out = StepOutput::new(4);
        inp.north_propag[2] = true;
        m.step(&inp, &mut out);
        inp.clear();
        assert!(m.reg_propag[m.idx(0, 2)]);
        m.step(&inp, &mut out);
        assert!(!m.reg_propag[m.idx(0, 2)]);
        assert!(m.reg_propag[m.idx(1, 2)]);
    }

    #[test]
    fn d_chain_staircases_preload() {
        // Feed a 3-element column of D values (reversed) with propagate
        // asserted for dim cycles; accumulators must end as D[r].
        let dim = 3;
        let mut m = Mesh::new(dim, Dataflow::OutputStationary);
        let mut inp = MeshInputs::idle(dim);
        let mut out = StepOutput::new(dim);
        let d = [10i32, 20, 30];
        for t in 0..(2 * dim - 1) {
            inp.clear();
            if t < dim {
                inp.north_propag[0] = true;
                inp.north_d[0] = d[dim - 1 - t];
            }
            m.step(&inp, &mut out);
        }
        for r in 0..dim {
            assert_eq!(m.acc_at(r, 0), d[r], "row {r}");
        }
    }

    #[test]
    fn flush_emits_rows_bottom_first() {
        let dim = 3;
        let mut m = Mesh::new(dim, Dataflow::OutputStationary);
        // Pre-set accumulators directly (white-box).
        for r in 0..dim {
            let i = r * dim;
            m.acc[i] = (r as i32 + 1) * 100;
        }
        let mut inp = MeshInputs::idle(dim);
        let mut out = StepOutput::new(dim);
        let mut captured = vec![];
        for t in 0..(2 * dim - 1) {
            inp.clear();
            out.clear();
            if t < dim {
                inp.north_propag[0] = true;
            }
            m.step(&inp, &mut out);
            if out.has_south_c(0) {
                captured.push(out.south_c_at(0));
            }
        }
        assert_eq!(captured, vec![300, 200, 100]);
    }

    #[test]
    fn reset_clears_everything() {
        let mut m = Mesh::new(4, Dataflow::OutputStationary);
        let mut inp = MeshInputs::idle(4);
        let mut out = StepOutput::new(4);
        inp.west_a[0] = 1;
        inp.north_b[0] = 1;
        inp.north_valid[0] = true;
        m.step(&inp, &mut out);
        m.reset();
        assert_eq!(m.cycle(), 0);
        assert!(m.acc.iter().all(|&v| v == 0));
        assert!(m.reg_a.iter().all(|&v| v == 0));
    }

    #[test]
    fn state_elements_scale_quadratically() {
        let m4 = Mesh::new(4, Dataflow::OutputStationary);
        let m8 = Mesh::new(8, Dataflow::OutputStationary);
        assert_eq!(m8.state_elements(), 4 * m4.state_elements());
    }

    /// Drive `n` cycles of deterministic pseudo-random boundary traffic.
    fn churn(m: &mut Mesh, n: u64, salt: u64) {
        let dim = m.dim();
        let mut inp = MeshInputs::idle(dim);
        let mut out = StepOutput::new(dim);
        for t in 0..n {
            inp.clear();
            for r in 0..dim {
                inp.west_a[r] = ((t * 7 + salt + r as u64) % 251) as i8;
            }
            for c in 0..dim {
                inp.north_b[c] = ((t * 13 + salt + c as u64) % 241) as i8;
                inp.north_d[c] = ((t * 31 + c as u64) % 9973) as i32 - 4000;
                inp.north_valid[c] = (t + c as u64) % 3 == 0;
                inp.north_propag[c] = (t + c as u64) % 7 == 0;
            }
            m.step(&inp, &mut out);
        }
    }

    #[test]
    fn restore_after_save_is_identity() {
        for dataflow in [Dataflow::OutputStationary, Dataflow::WeightStationary] {
            let mut m = Mesh::new(4, dataflow);
            churn(&mut m, 23, 5);
            let mut snap = MeshState::default();
            m.save_state(&mut snap);
            assert_eq!(snap.cycle(), 23);
            // diverge, then restore: the snapshot round-trips bit-exactly
            churn(&mut m, 11, 99);
            m.restore_state(&snap);
            let mut snap2 = MeshState::default();
            m.save_state(&mut snap2);
            assert_eq!(snap, snap2, "{dataflow}: restore ∘ save must be id");
            assert_eq!(m.cycle(), 23);
            // and the restored trajectory continues identically
            let mut twin = Mesh::new(4, dataflow);
            churn(&mut twin, 23, 5);
            churn(&mut twin, 9, 1);
            churn(&mut m, 9, 1);
            let mut a = MeshState::default();
            let mut b = MeshState::default();
            m.save_state(&mut a);
            twin.save_state(&mut b);
            assert_eq!(a, b, "{dataflow}: resumed trajectory diverged");
        }
    }

    #[test]
    #[should_panic(expected = "differently-dimensioned")]
    fn restore_rejects_wrong_dim_snapshot() {
        let m4 = Mesh::new(4, Dataflow::OutputStationary);
        let mut snap = MeshState::default();
        m4.save_state(&mut snap);
        let mut m8 = Mesh::new(8, Dataflow::OutputStationary);
        m8.restore_state(&snap);
    }

    #[test]
    fn save_state_reuses_snapshot_buffers() {
        let mut m = Mesh::new(4, Dataflow::OutputStationary);
        let mut snap = MeshState::default();
        m.save_state(&mut snap);
        let ptr = snap.acc.as_ptr();
        churn(&mut m, 5, 0);
        m.save_state(&mut snap);
        assert_eq!(snap.acc.as_ptr(), ptr, "warm snapshots must not allocate");
    }

    #[test]
    fn ws_d_chain_staircases_weight_preload() {
        // Mirror of d_chain_staircases_preload for the WS edge: after the
        // preload window every PE holds its weight in reg_w (and the
        // d-chain value in acc).
        let dim = 3;
        let mut m = Mesh::new(dim, Dataflow::WeightStationary);
        let mut inp = MeshInputs::idle(dim);
        let mut out = StepOutput::new(dim);
        let w = [7i32, 11, 13];
        for t in 0..(2 * dim - 1) {
            inp.clear();
            if t < dim {
                inp.north_propag[0] = true;
                inp.north_d[0] = w[dim - 1 - t];
            }
            m.step(&inp, &mut out);
        }
        for r in 0..dim {
            assert_eq!(m.reg_w[r * dim], w[r] as i8, "row {r}");
        }
    }
}
