//! The verilated-equivalent Gemmini Mesh model.
//!
//! This is a cycle-accurate register-transfer simulator of the DIM x DIM
//! PE grid (the `Mesh.v` block the paper isolates in its "compilation"
//! step). It reproduces, by construction, the property ENFOR-SA's
//! injection method depends on: Verilator preserves Verilog non-blocking
//! register semantics by *inverting the order of register assignments*
//! (downstream registers are written first), so `step()` updates the grid
//! **in place**, most-downstream PE first (row DIM-1..0, col DIM-1..0),
//! and every read of a neighbour register observes its *pre-edge* value.
//!
//! PE microarchitecture (paper Fig. 2, output-stationary):
//!
//! ```text
//!            b_in  d_in  propag/valid (from north)
//!              │     │     │
//!   a_in ──►[MAC: acc += a_in*b_in]──► reg_a ──► east
//!              │     │     │
//!            reg_b reg_d reg_propag/reg_valid
//!              ▼     ▼     ▼   (to south)
//! ```
//!
//! * `reg_a` — horizontal operand pipeline register (weights, west→east);
//! * `reg_b` — vertical operand pipeline register (activations);
//! * `acc`   — the output-stationary 32-bit accumulator;
//! * `reg_d` — the accumulator-chain pipeline register: it latches the
//!   northern PE's `out_c` wire every cycle, so during `propagate` phases
//!   bias matrices staircase in and results staircase out correctly even
//!   though the propagate *enable* itself is pipelined row by row;
//! * `reg_propag` / `reg_valid` — the local control bits, forwarded south.
//!
//! The MAC consumes the *input wires* (the upstream registers); the PE's
//! own registers forward the operands to its neighbours one cycle later —
//! matching Gemmini, where a transient in a PE's operand register corrupts
//! that PE's MAC and every downstream PE one hop per cycle (Fig. 5b).

use super::kernel;
use crate::config::Dataflow;

/// Per-cycle boundary inputs, produced by the interface adapters.
#[derive(Clone, Debug)]
pub struct MeshInputs {
    /// West edge: operand entering each row's `a` path (weights).
    pub west_a: Vec<i8>,
    /// North edge: operand entering each column's `b` path (activations).
    pub north_b: Vec<i8>,
    /// North edge: accumulator-chain input (bias rows during preload).
    pub north_d: Vec<i32>,
    /// North edge: propagate control per column.
    pub north_propag: Vec<bool>,
    /// North edge: valid control per column.
    pub north_valid: Vec<bool>,
}

impl MeshInputs {
    pub fn idle(dim: usize) -> Self {
        MeshInputs {
            west_a: vec![0; dim],
            north_b: vec![0; dim],
            north_d: vec![0; dim],
            north_propag: vec![false; dim],
            north_valid: vec![false; dim],
        }
    }

    pub fn clear(&mut self) {
        self.west_a.fill(0);
        self.north_b.fill(0);
        self.north_d.fill(0);
        self.north_propag.fill(false);
        self.north_valid.fill(false);
    }
}

/// Values crossing the south edge during one cycle.
///
/// Dense `i32` buffers plus a validity bitmask (one bit per column,
/// packed into 64-bit words) instead of an `Option` per column: drain
/// collection is a mask-bit test over flat storage, and the lane-batched
/// kernels don't carry an `Option` per lane — mirroring the flat-`Mat`
/// boundary contract.
#[derive(Clone, Debug)]
pub struct StepOutput {
    /// `out_c` wire of each bottom-row PE when its propagate input was
    /// asserted this cycle (flush traffic); valid iff its mask bit set.
    south_c: Vec<i32>,
    /// Completed partial sums leaving the bottom row (WS dataflow).
    south_psum: Vec<i32>,
    south_c_mask: Vec<u64>,
    south_psum_mask: Vec<u64>,
}

impl StepOutput {
    pub fn new(dim: usize) -> Self {
        let words = dim.div_ceil(64);
        StepOutput {
            south_c: vec![0; dim],
            south_psum: vec![0; dim],
            south_c_mask: vec![0; words],
            south_psum_mask: vec![0; words],
        }
    }

    /// Invalidate every column. Values are left in place — only the mask
    /// words are zeroed, so the per-cycle clear is O(dim/64).
    pub fn clear(&mut self) {
        self.south_c_mask.fill(0);
        self.south_psum_mask.fill(0);
    }

    #[inline]
    pub fn set_south_c(&mut self, col: usize, v: i32) {
        self.south_c[col] = v;
        self.south_c_mask[col >> 6] |= 1 << (col & 63);
    }

    #[inline]
    pub fn set_south_psum(&mut self, col: usize, v: i32) {
        self.south_psum[col] = v;
        self.south_psum_mask[col >> 6] |= 1 << (col & 63);
    }

    #[inline]
    pub fn has_south_c(&self, col: usize) -> bool {
        self.south_c_mask[col >> 6] & (1 << (col & 63)) != 0
    }

    #[inline]
    pub fn has_south_psum(&self, col: usize) -> bool {
        self.south_psum_mask[col >> 6] & (1 << (col & 63)) != 0
    }

    /// The column's value; meaningful only when [`Self::has_south_c`].
    #[inline]
    pub fn south_c_at(&self, col: usize) -> i32 {
        self.south_c[col]
    }

    /// The column's value; meaningful only when [`Self::has_south_psum`].
    #[inline]
    pub fn south_psum_at(&self, col: usize) -> i32 {
        self.south_psum[col]
    }
}

/// A reusable architectural snapshot of a mesh simulator: every register
/// file plus the cycle counter. Pure scratch buffers (e.g. the pre-edge
/// row copy) carry no cross-cycle state and are excluded. The buffers
/// are recycled across [`MeshSim::save_state`] calls, so a warm snapshot
/// costs only memcpys — the primitive behind cycle-resume
/// (`restore_state(save_state(m)) ≡ id`, pinned by test).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct MeshState {
    // pub(crate): the lane-batched engine broadcasts a snapshot into
    // every lane of its SoA register files (`mesh::lane`).
    pub(crate) cycle: u64,
    pub(crate) reg_a: Vec<i8>,
    pub(crate) reg_b: Vec<i8>,
    pub(crate) acc: Vec<i32>,
    pub(crate) reg_d: Vec<i32>,
    pub(crate) reg_propag: Vec<bool>,
    pub(crate) reg_valid: Vec<bool>,
    pub(crate) reg_w: Vec<i8>,
}

impl MeshState {
    /// The cycle the snapshot was taken at.
    pub fn cycle(&self) -> u64 {
        self.cycle
    }
}

/// Recycle `dst`'s allocation while copying `src` into it.
fn copy_into<T: Copy>(dst: &mut Vec<T>, src: &[T]) {
    dst.clear();
    dst.extend_from_slice(src);
}

/// Common simulation interface implemented by the plain (ENFOR-SA) mesh
/// and the HDFIT-style instrumented mesh, so drivers and the campaign
/// engine are generic over the backend.
pub trait MeshSim {
    fn dim(&self) -> usize;
    fn dataflow(&self) -> Dataflow;
    fn cycle(&self) -> u64;
    /// Advance one clock edge.
    fn step(&mut self, inp: &MeshInputs, out: &mut StepOutput);
    /// Reset all architectural state (registers, accumulators, cycle).
    fn reset(&mut self);
    /// Read an accumulator (test/debug visibility, as in waveforms).
    fn acc_at(&self, row: usize, col: usize) -> i32;
    /// Snapshot every architectural register (and the cycle counter)
    /// into `state`, reusing its buffers.
    fn save_state(&self, state: &mut MeshState);
    /// Restore a snapshot taken by [`MeshSim::save_state`] on an
    /// identically-dimensioned simulator: afterwards the simulator is
    /// bit-identical to the one the snapshot was taken from
    /// (`restore ∘ save ≡ id`).
    fn restore_state(&mut self, state: &MeshState);
}

/// The plain verilated-equivalent mesh (no instrumentation — ENFOR-SA's
/// fast backend).
pub struct Mesh {
    dim: usize,
    dataflow: Dataflow,
    pub(crate) cycle: u64,
    // Flat SoA register files, index = row * dim + col.
    pub(crate) reg_a: Vec<i8>,
    pub(crate) reg_b: Vec<i8>,
    pub(crate) acc: Vec<i32>,
    pub(crate) reg_d: Vec<i32>,
    pub(crate) reg_propag: Vec<bool>,
    pub(crate) reg_valid: Vec<bool>,
    /// WS only: the stationary weight held in each PE.
    pub(crate) reg_w: Vec<i8>,
    /// Scratch: the SHIFTED pre-edge a-row (`[west_port, reg_a[0..dim-1]]`),
    /// so each row is one element-wise [`kernel`] call while preserving
    /// the inverted-assignment-order semantics (§Perf iteration 2, then
    /// blocked over [`kernel::LANE_BLOCK`] in the cross-tile packing PR).
    scratch_a: Vec<i8>,
    /// Scratch: pre-edge bottom-row `acc` (OS south_c capture source).
    scratch_c: Vec<i32>,
    /// Scratch: pre-edge bottom-row `reg_w` (WS south_c capture source).
    scratch_w: Vec<i8>,
}

impl Mesh {
    pub fn new(dim: usize, dataflow: Dataflow) -> Self {
        assert!(dim > 0, "mesh dim must be positive");
        let n = dim * dim;
        Mesh {
            dim,
            dataflow,
            cycle: 0,
            reg_a: vec![0; n],
            reg_b: vec![0; n],
            acc: vec![0; n],
            reg_d: vec![0; n],
            reg_propag: vec![false; n],
            reg_valid: vec![false; n],
            reg_w: vec![0; n],
            scratch_a: vec![0; dim],
            scratch_c: vec![0; dim],
            scratch_w: vec![0; dim],
        }
    }

    #[inline]
    pub(crate) fn idx(&self, r: usize, c: usize) -> usize {
        r * self.dim + c
    }

    /// Output-stationary clock edge. In-place, inverted assignment order.
    ///
    /// Hot path of the whole framework (Table III/IV/V all sit on it).
    /// Perf notes (EXPERIMENTS.md §Perf): each row is one element-wise
    /// [`kernel::os_row`] call — the a-chain is resolved through the
    /// shifted pre-edge `scratch_a` copy and the north-row sources are
    /// pre-edge slices (rows walk bottom-up), so the per-column body is
    /// a straight-line select ladder blocked over
    /// [`kernel::LANE_BLOCK`]-wide fixed-trip loops. Bit-identical to
    /// the original inverted-order walk (the a-chain is the only
    /// intra-row dependency), pinned by the fixture tests below.
    fn step_os(&mut self, inp: &MeshInputs, out: &mut StepOutput) {
        let dim = self.dim;
        for r in (0..dim).rev() {
            let base = r * dim;
            // shifted pre-edge a-row: the west port, then reg_a[c-1]
            self.scratch_a[0] = inp.west_a[r];
            self.scratch_a[1..dim].copy_from_slice(&self.reg_a[base..base + dim - 1]);
            let bottom = r == dim - 1;
            if bottom {
                // pre-edge acc: the south-edge out_c source during flush
                self.scratch_c.copy_from_slice(&self.acc[base..base + dim]);
            }
            if r == 0 {
                // ---- north-edge row: sources are the boundary ports ----
                kernel::os_row::<true>(
                    &self.scratch_a,
                    &inp.north_b,
                    &inp.north_propag,
                    &inp.north_valid,
                    &inp.north_d,
                    &mut self.acc[..dim],
                    &mut self.reg_a[..dim],
                    &mut self.reg_b[..dim],
                    &mut self.reg_d[..dim],
                    &mut self.reg_propag[..dim],
                    &mut self.reg_valid[..dim],
                );
                if bottom {
                    for c in 0..dim {
                        if inp.north_propag[c] {
                            out.set_south_c(c, self.scratch_c[c]);
                        }
                    }
                }
                continue;
            }
            // ---- interior rows: north-row sources are pre-edge ----
            let north = base - dim;
            let (acc_head, acc_row) = self.acc.split_at_mut(base);
            let (b_head, b_row) = self.reg_b.split_at_mut(base);
            let (p_head, p_row) = self.reg_propag.split_at_mut(base);
            let (v_head, v_row) = self.reg_valid.split_at_mut(base);
            kernel::os_row::<false>(
                &self.scratch_a,
                &b_head[north..],
                &p_head[north..],
                &v_head[north..],
                &acc_head[north..],
                &mut acc_row[..dim],
                &mut self.reg_a[base..base + dim],
                &mut b_row[..dim],
                &mut self.reg_d[base..base + dim],
                &mut p_row[..dim],
                &mut v_row[..dim],
            );
            if bottom {
                for c in 0..dim {
                    if p_head[north + c] {
                        out.set_south_c(c, self.scratch_c[c]);
                    }
                }
            }
        }
        self.cycle += 1;
    }

    /// Weight-stationary clock edge. Weights preload through the d-chain
    /// (propagate phases), partial sums flow north→south through `acc`
    /// (acting as the psum pipeline register), activations west→east.
    ///
    /// Mirrors `step_os`'s shape (§Perf iteration 2, WS side): each row
    /// is one element-wise [`kernel::ws_row`] call over the shifted
    /// pre-edge a-row and the pre-edge north-row sources; the south-edge
    /// captures read `w_old` from the pre-edge `scratch_w` snapshot and
    /// the completed psum from the post-edge accumulator (equal to `ps`
    /// exactly when `!p ∧ v`). Bit-identical to the inverted-order walk.
    fn step_ws(&mut self, inp: &MeshInputs, out: &mut StepOutput) {
        let dim = self.dim;
        for r in (0..dim).rev() {
            let base = r * dim;
            // shifted pre-edge a-row: the west port, then reg_a[c-1]
            self.scratch_a[0] = inp.west_a[r];
            self.scratch_a[1..dim].copy_from_slice(&self.reg_a[base..base + dim - 1]);
            let bottom = r == dim - 1;
            if bottom {
                // pre-edge weights: the south-edge out_c source during preload
                self.scratch_w.copy_from_slice(&self.reg_w[base..base + dim]);
            }
            if r == 0 {
                // ---- north-edge row: sources are the boundary ports ----
                kernel::ws_row::<true>(
                    &self.scratch_a,
                    &inp.north_b,
                    &inp.north_propag,
                    &inp.north_valid,
                    &inp.north_d,
                    &mut self.acc[..dim],
                    &mut self.reg_a[..dim],
                    &mut self.reg_b[..dim],
                    &mut self.reg_d[..dim],
                    &mut self.reg_w[..dim],
                    &mut self.reg_propag[..dim],
                    &mut self.reg_valid[..dim],
                );
                if bottom {
                    for c in 0..dim {
                        if inp.north_propag[c] {
                            out.set_south_c(c, self.scratch_w[c] as i32);
                        } else if inp.north_valid[c] {
                            out.set_south_psum(c, self.acc[c]);
                        }
                    }
                }
                continue;
            }
            // ---- interior rows: north-row sources are pre-edge ----
            let north = base - dim;
            let (acc_head, acc_row) = self.acc.split_at_mut(base);
            let (b_head, b_row) = self.reg_b.split_at_mut(base);
            let (p_head, p_row) = self.reg_propag.split_at_mut(base);
            let (v_head, v_row) = self.reg_valid.split_at_mut(base);
            kernel::ws_row::<false>(
                &self.scratch_a,
                &b_head[north..],
                &p_head[north..],
                &v_head[north..],
                &acc_head[north..],
                &mut acc_row[..dim],
                &mut self.reg_a[base..base + dim],
                &mut b_row[..dim],
                &mut self.reg_d[base..base + dim],
                &mut self.reg_w[base..base + dim],
                &mut p_row[..dim],
                &mut v_row[..dim],
            );
            if bottom {
                for c in 0..dim {
                    if p_head[north + c] {
                        out.set_south_c(c, self.scratch_w[c] as i32);
                    } else if v_head[north + c] {
                        out.set_south_psum(c, acc_row[c]);
                    }
                }
            }
        }
        self.cycle += 1;
    }

    /// Number of architectural state elements evaluated per cycle — the
    /// quantity that governs simulation cost (DESIGN.md D2).
    pub fn state_elements(&self) -> usize {
        let per_pe = 7; // a, b, acc, d, w, propag, valid
        self.dim * self.dim * per_pe
    }
}

impl MeshSim for Mesh {
    fn dim(&self) -> usize {
        self.dim
    }

    fn dataflow(&self) -> Dataflow {
        self.dataflow
    }

    fn cycle(&self) -> u64 {
        self.cycle
    }

    #[inline]
    fn step(&mut self, inp: &MeshInputs, out: &mut StepOutput) {
        debug_assert_eq!(inp.west_a.len(), self.dim);
        match self.dataflow {
            Dataflow::OutputStationary => self.step_os(inp, out),
            Dataflow::WeightStationary => self.step_ws(inp, out),
        }
    }

    fn reset(&mut self) {
        self.cycle = 0;
        self.reg_a.fill(0);
        self.reg_b.fill(0);
        self.acc.fill(0);
        self.reg_d.fill(0);
        self.reg_propag.fill(false);
        self.reg_valid.fill(false);
        self.reg_w.fill(0);
    }

    fn acc_at(&self, row: usize, col: usize) -> i32 {
        self.acc[self.idx(row, col)]
    }

    fn save_state(&self, state: &mut MeshState) {
        state.cycle = self.cycle;
        copy_into(&mut state.reg_a, &self.reg_a);
        copy_into(&mut state.reg_b, &self.reg_b);
        copy_into(&mut state.acc, &self.acc);
        copy_into(&mut state.reg_d, &self.reg_d);
        copy_into(&mut state.reg_propag, &self.reg_propag);
        copy_into(&mut state.reg_valid, &self.reg_valid);
        copy_into(&mut state.reg_w, &self.reg_w);
    }

    fn restore_state(&mut self, state: &MeshState) {
        assert_eq!(
            state.acc.len(),
            self.acc.len(),
            "snapshot taken on a differently-dimensioned mesh"
        );
        self.cycle = state.cycle;
        self.reg_a.copy_from_slice(&state.reg_a);
        self.reg_b.copy_from_slice(&state.reg_b);
        self.acc.copy_from_slice(&state.acc);
        self.reg_d.copy_from_slice(&state.reg_d);
        self.reg_propag.copy_from_slice(&state.reg_propag);
        self.reg_valid.copy_from_slice(&state.reg_valid);
        self.reg_w.copy_from_slice(&state.reg_w);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn idle_steps_do_nothing() {
        let mut m = Mesh::new(4, Dataflow::OutputStationary);
        let inp = MeshInputs::idle(4);
        let mut out = StepOutput::new(4);
        for _ in 0..10 {
            m.step(&inp, &mut out);
        }
        assert_eq!(m.cycle(), 10);
        assert!(m.acc.iter().all(|&v| v == 0));
        assert!((0..4).all(|c| !out.has_south_c(c)));
    }

    #[test]
    fn single_mac_at_origin() {
        // Drive a=3 (row 0), b=5 (col 0), valid for exactly one cycle:
        // PE(0,0) must accumulate 15; nothing else changes.
        let mut m = Mesh::new(4, Dataflow::OutputStationary);
        let mut inp = MeshInputs::idle(4);
        let mut out = StepOutput::new(4);
        inp.west_a[0] = 3;
        inp.north_b[0] = 5;
        inp.north_valid[0] = true;
        m.step(&inp, &mut out);
        assert_eq!(m.acc_at(0, 0), 15);
        // the operands were latched for forwarding east/south:
        assert_eq!(m.reg_a[0], 3);
        assert_eq!(m.reg_b[0], 5);
        inp.clear();
        m.step(&inp, &mut out);
        assert_eq!(m.acc_at(0, 0), 15); // valid deasserted: no further MAC
    }

    #[test]
    fn operands_pipeline_one_hop_per_cycle() {
        let mut m = Mesh::new(4, Dataflow::OutputStationary);
        let mut inp = MeshInputs::idle(4);
        let mut out = StepOutput::new(4);
        inp.west_a[0] = 7;
        m.step(&inp, &mut out);
        inp.clear();
        // After k more cycles the value sits in reg_a of PE(0,k).
        for k in 1..4 {
            m.step(&inp, &mut out);
            assert_eq!(m.reg_a[k], 7, "cycle {k}");
            if k >= 1 {
                assert_eq!(m.reg_a[k - 1], 0);
            }
        }
    }

    #[test]
    fn propag_bit_travels_south() {
        let mut m = Mesh::new(4, Dataflow::OutputStationary);
        let mut inp = MeshInputs::idle(4);
        let mut out = StepOutput::new(4);
        inp.north_propag[2] = true;
        m.step(&inp, &mut out);
        inp.clear();
        assert!(m.reg_propag[m.idx(0, 2)]);
        m.step(&inp, &mut out);
        assert!(!m.reg_propag[m.idx(0, 2)]);
        assert!(m.reg_propag[m.idx(1, 2)]);
    }

    #[test]
    fn d_chain_staircases_preload() {
        // Feed a 3-element column of D values (reversed) with propagate
        // asserted for dim cycles; accumulators must end as D[r].
        let dim = 3;
        let mut m = Mesh::new(dim, Dataflow::OutputStationary);
        let mut inp = MeshInputs::idle(dim);
        let mut out = StepOutput::new(dim);
        let d = [10i32, 20, 30];
        for t in 0..(2 * dim - 1) {
            inp.clear();
            if t < dim {
                inp.north_propag[0] = true;
                inp.north_d[0] = d[dim - 1 - t];
            }
            m.step(&inp, &mut out);
        }
        for r in 0..dim {
            assert_eq!(m.acc_at(r, 0), d[r], "row {r}");
        }
    }

    #[test]
    fn flush_emits_rows_bottom_first() {
        let dim = 3;
        let mut m = Mesh::new(dim, Dataflow::OutputStationary);
        // Pre-set accumulators directly (white-box).
        for r in 0..dim {
            let i = r * dim;
            m.acc[i] = (r as i32 + 1) * 100;
        }
        let mut inp = MeshInputs::idle(dim);
        let mut out = StepOutput::new(dim);
        let mut captured = vec![];
        for t in 0..(2 * dim - 1) {
            inp.clear();
            out.clear();
            if t < dim {
                inp.north_propag[0] = true;
            }
            m.step(&inp, &mut out);
            if out.has_south_c(0) {
                captured.push(out.south_c_at(0));
            }
        }
        assert_eq!(captured, vec![300, 200, 100]);
    }

    #[test]
    fn reset_clears_everything() {
        let mut m = Mesh::new(4, Dataflow::OutputStationary);
        let mut inp = MeshInputs::idle(4);
        let mut out = StepOutput::new(4);
        inp.west_a[0] = 1;
        inp.north_b[0] = 1;
        inp.north_valid[0] = true;
        m.step(&inp, &mut out);
        m.reset();
        assert_eq!(m.cycle(), 0);
        assert!(m.acc.iter().all(|&v| v == 0));
        assert!(m.reg_a.iter().all(|&v| v == 0));
    }

    #[test]
    fn state_elements_scale_quadratically() {
        let m4 = Mesh::new(4, Dataflow::OutputStationary);
        let m8 = Mesh::new(8, Dataflow::OutputStationary);
        assert_eq!(m8.state_elements(), 4 * m4.state_elements());
    }

    /// Drive `n` cycles of deterministic pseudo-random boundary traffic.
    fn churn(m: &mut Mesh, n: u64, salt: u64) {
        let dim = m.dim();
        let mut inp = MeshInputs::idle(dim);
        let mut out = StepOutput::new(dim);
        for t in 0..n {
            inp.clear();
            for r in 0..dim {
                inp.west_a[r] = ((t * 7 + salt + r as u64) % 251) as i8;
            }
            for c in 0..dim {
                inp.north_b[c] = ((t * 13 + salt + c as u64) % 241) as i8;
                inp.north_d[c] = ((t * 31 + c as u64) % 9973) as i32 - 4000;
                inp.north_valid[c] = (t + c as u64) % 3 == 0;
                inp.north_propag[c] = (t + c as u64) % 7 == 0;
            }
            m.step(&inp, &mut out);
        }
    }

    #[test]
    fn restore_after_save_is_identity() {
        for dataflow in [Dataflow::OutputStationary, Dataflow::WeightStationary] {
            let mut m = Mesh::new(4, dataflow);
            churn(&mut m, 23, 5);
            let mut snap = MeshState::default();
            m.save_state(&mut snap);
            assert_eq!(snap.cycle(), 23);
            // diverge, then restore: the snapshot round-trips bit-exactly
            churn(&mut m, 11, 99);
            m.restore_state(&snap);
            let mut snap2 = MeshState::default();
            m.save_state(&mut snap2);
            assert_eq!(snap, snap2, "{dataflow}: restore ∘ save must be id");
            assert_eq!(m.cycle(), 23);
            // and the restored trajectory continues identically
            let mut twin = Mesh::new(4, dataflow);
            churn(&mut twin, 23, 5);
            churn(&mut twin, 9, 1);
            churn(&mut m, 9, 1);
            let mut a = MeshState::default();
            let mut b = MeshState::default();
            m.save_state(&mut a);
            twin.save_state(&mut b);
            assert_eq!(a, b, "{dataflow}: resumed trajectory diverged");
        }
    }

    #[test]
    #[should_panic(expected = "differently-dimensioned")]
    fn restore_rejects_wrong_dim_snapshot() {
        let m4 = Mesh::new(4, Dataflow::OutputStationary);
        let mut snap = MeshState::default();
        m4.save_state(&mut snap);
        let mut m8 = Mesh::new(8, Dataflow::OutputStationary);
        m8.restore_state(&snap);
    }

    #[test]
    fn save_state_reuses_snapshot_buffers() {
        let mut m = Mesh::new(4, Dataflow::OutputStationary);
        let mut snap = MeshState::default();
        m.save_state(&mut snap);
        let ptr = snap.acc.as_ptr();
        churn(&mut m, 5, 0);
        m.save_state(&mut snap);
        assert_eq!(snap.acc.as_ptr(), ptr, "warm snapshots must not allocate");
    }

    #[test]
    fn ws_d_chain_staircases_weight_preload() {
        // Mirror of d_chain_staircases_preload for the WS edge: after the
        // preload window every PE holds its weight in reg_w (and the
        // d-chain value in acc).
        let dim = 3;
        let mut m = Mesh::new(dim, Dataflow::WeightStationary);
        let mut inp = MeshInputs::idle(dim);
        let mut out = StepOutput::new(dim);
        let w = [7i32, 11, 13];
        for t in 0..(2 * dim - 1) {
            inp.clear();
            if t < dim {
                inp.north_propag[0] = true;
                inp.north_d[0] = w[dim - 1 - t];
            }
            m.step(&inp, &mut out);
        }
        for r in 0..dim {
            assert_eq!(m.reg_w[r * dim], w[r] as i8, "row {r}");
        }
    }
}
