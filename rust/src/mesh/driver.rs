//! Matmul drivers: the "simulation wrapper" that drives operand streams
//! through the mesh, performs `C = A . B + D`, and applies at most ONE
//! compare-and-branch per cycle for fault injection — the ENFOR-SA
//! alternative to per-assignment instrumentation.
//!
//! Operands cross the software↔RTL boundary as flat, stride-aware
//! [`MatView`]s (see [`crate::mat`]): a DIM-padded tile of a layer's
//! flat buffer is a zero-copy window, and the implicit zero padding of
//! the view doubles as the zero-padded scratchpad read of the real
//! frontend. No per-matmul operand allocation happens anywhere in this
//! module; the only allocation is the result [`Mat`] — and callers on
//! the campaign hot path avoid even that by draining into a persistent
//! buffer via [`MatmulDriver::matmul_into`].
//!
//! Output-stationary schedule (the paper's configuration):
//!
//! 1. **Preload** (2*DIM-1 cycles): propagate asserted at the north edge
//!    for DIM cycles while the bias matrix D staircases down the
//!    accumulator chain (rows fed in reverse).
//! 2. **Compute** (K + 2*DIM-2 cycles): weights stream west→east with
//!    row skew, activations north→south with column skew, `valid`
//!    travelling with the activation stream.
//! 3. **Flush** (2*DIM-1 cycles): propagate again; results exit the
//!    south edge bottom-row-first and are un-staircased by the
//!    [`FlushCollector`].
//!
//! Weight-stationary schedule: W staircases in through the d-chain, then
//! activation columns stream west→east while psums (initialised with D
//! rows at the north edge) flow down and exit south every cycle.

use super::adapters::{FlushCollector, SkewFeeder};
use super::inject::{Fault, FaultPlan, Injectable, PlanCursor};
use super::mesh::{MeshInputs, StepOutput};
use crate::config::Dataflow;
use crate::mat::{Mat, MatView};

/// Cycle count of one OS matmul on a DIM mesh with inner dimension K.
pub fn os_matmul_cycles(dim: usize, k: usize) -> u64 {
    ((2 * dim - 1) + (k + 2 * dim - 2) + (2 * dim - 1)) as u64
}

/// Cycle count of one WS matmul streaming M rows through a DIM mesh.
pub fn ws_matmul_cycles(dim: usize, m: usize) -> u64 {
    ((2 * dim - 1) + (m + 2 * dim - 2)) as u64
}

/// Drives one matmul through a mesh backend.
pub struct MatmulDriver<'m, S: Injectable> {
    mesh: &'m mut S,
}

impl<'m, S: Injectable> MatmulDriver<'m, S> {
    pub fn new(mesh: &'m mut S) -> Self {
        MatmulDriver { mesh }
    }

    /// Golden (fault-free) matmul.
    pub fn matmul(&mut self, a: MatView<i8>, b: MatView<i8>, d: MatView<i32>) -> Mat<i32> {
        let mut out = Mat::default();
        self.matmul_into(a, b, d, &FaultPlan::empty(), &mut out);
        out
    }

    /// Matmul with a single transient fault injected at `fault.cycle`
    /// (relative to the start of this matmul) — the legacy single-SEU
    /// convenience over [`MatmulDriver::matmul_with_plan`].
    pub fn matmul_with_fault(
        &mut self,
        a: MatView<i8>,
        b: MatView<i8>,
        d: MatView<i32>,
        fault: &Fault,
    ) -> Mat<i32> {
        self.matmul_with_plan(a, b, d, &FaultPlan::single(*fault))
    }

    /// Matmul with a whole fault scenario (MBU, burst, double SEU,
    /// stuck-at...) injected at the plan's cycles.
    pub fn matmul_with_plan(
        &mut self,
        a: MatView<i8>,
        b: MatView<i8>,
        d: MatView<i32>,
        plan: &FaultPlan,
    ) -> Mat<i32> {
        let mut out = Mat::default();
        self.matmul_into(a, b, d, plan, &mut out);
        out
    }

    /// Matmul into a caller-provided result buffer: `out` is reshaped and
    /// zeroed in place (reusing its allocation), so back-to-back trials
    /// against the same buffer allocate nothing. This is the hot entry of
    /// the site-major campaign batches. An empty plan is a golden run.
    pub fn matmul_into(
        &mut self,
        a: MatView<i8>,
        b: MatView<i8>,
        d: MatView<i32>,
        plan: &FaultPlan,
        out: &mut Mat<i32>,
    ) {
        if !plan.is_empty() {
            self.mesh.arm(plan);
        }
        let cursor = PlanCursor::start(plan);
        match self.mesh.dataflow() {
            Dataflow::OutputStationary => self.run_os(a, b, d, plan, cursor, out),
            Dataflow::WeightStationary => self.run_ws(a, b, d, plan, cursor, out),
        }
        if !plan.is_empty() {
            self.mesh.disarm();
        }
    }

    /// One compare per cycle: the entire injection overhead of ENFOR-SA,
    /// unchanged by the scenario redesign. (Transient faults fire once;
    /// stuck-at faults keep the cursor re-armed so the forcing re-applies
    /// every cycle from onset — still wrapper-only.)
    #[inline]
    fn maybe_inject(
        &mut self,
        plan: &FaultPlan,
        cursor: &mut PlanCursor,
        t: u64,
        inp: &mut MeshInputs,
    ) {
        if cursor.next_cycle() == t {
            cursor.fire(plan, t, self.mesh, inp);
        }
    }

    /// Output-stationary: A is DIM x K (weights), B is K x DIM
    /// (activations), D and C are DIM x DIM.
    fn run_os(
        &mut self,
        a: MatView<i8>,
        b: MatView<i8>,
        d: MatView<i32>,
        plan: &FaultPlan,
        mut cursor: PlanCursor,
        out: &mut Mat<i32>,
    ) {
        let dim = self.mesh.dim();
        let k = a.cols();
        assert_eq!(a.rows(), dim, "A must have DIM rows");
        assert_eq!(b.rows(), k, "B must have K rows");
        assert_eq!(b.cols(), dim, "B must have DIM cols");
        assert_eq!((d.rows(), d.cols()), (dim, dim), "D must be DIM x DIM");

        self.mesh.reset();
        let mut inp = MeshInputs::idle(dim);
        let mut step_out = StepOutput::new(dim);
        let mut t: u64 = 0;

        // Phase 1: preload D (reversed rows down the accumulator chain).
        for p in 0..(2 * dim - 1) {
            inp.clear();
            if p < dim {
                for c in 0..dim {
                    inp.north_propag[c] = true;
                    inp.north_d[c] = d.at(dim - 1 - p, c);
                }
            }
            self.maybe_inject(plan, &mut cursor, t, &mut inp);
            self.mesh.step(&inp, &mut step_out);
            t += 1;
        }

        // Phase 2: compute. Row skew on A, column skew on B; valid rides
        // with the activation stream. The feeders read the operand views
        // in place — zero copies.
        let a_feed = SkewFeeder::from_rows(a);
        let b_feed = SkewFeeder::from_cols(b);
        let compute_len = k + 2 * dim - 2;
        for tau in 0..compute_len {
            inp.clear();
            for r in 0..dim {
                inp.west_a[r] = a_feed.at(r, tau);
            }
            for c in 0..dim {
                inp.north_b[c] = b_feed.at(c, tau);
                inp.north_valid[c] = b_feed.live(c, tau);
            }
            self.maybe_inject(plan, &mut cursor, t, &mut inp);
            self.mesh.step(&inp, &mut step_out);
            t += 1;
        }

        // Phase 3: flush C through the south edge, draining into the
        // caller's result buffer (recycled allocation, zeroed first).
        let mut collector = FlushCollector::reusing(dim, std::mem::take(out));
        for p in 0..(2 * dim - 1) {
            inp.clear();
            step_out.clear();
            if p < dim {
                for c in 0..dim {
                    inp.north_propag[c] = true;
                }
            }
            self.maybe_inject(plan, &mut cursor, t, &mut inp);
            self.mesh.step(&inp, &mut step_out);
            collector.absorb(&step_out.south_c);
            t += 1;
        }
        // A control-signal fault during the flush window can legitimately
        // disturb the drain (extra or missing propagate pulses) — the real
        // drain FSM also just latches whatever arrives in its fixed
        // window. Only fault-free runs must drain exactly DIM rows.
        debug_assert!(
            !plan.is_empty() || collector.complete(),
            "fault-free flush did not drain DIM rows"
        );
        debug_assert_eq!(t, os_matmul_cycles(dim, k));
        *out = collector.into_mat();
    }

    /// Weight-stationary: B here is the stationary DIM x DIM weight tile,
    /// A is M x DIM (activations streaming), D is M x DIM (bias rows).
    /// Returns C = A . B + D (M x DIM).
    fn run_ws(
        &mut self,
        a: MatView<i8>,
        w: MatView<i8>,
        d: MatView<i32>,
        plan: &FaultPlan,
        mut cursor: PlanCursor,
        out: &mut Mat<i32>,
    ) {
        let dim = self.mesh.dim();
        let m = a.rows();
        assert_eq!(a.cols(), dim, "A must have DIM cols");
        assert_eq!((w.rows(), w.cols()), (dim, dim), "W must be DIM x DIM");
        assert_eq!(d.rows(), m, "D must have M rows");
        assert_eq!(d.cols(), dim, "D must have DIM cols");

        self.mesh.reset();
        let mut inp = MeshInputs::idle(dim);
        let mut step_out = StepOutput::new(dim);
        let mut t: u64 = 0;

        // Phase 1: preload W through the d-chain (reversed rows).
        for p in 0..(2 * dim - 1) {
            inp.clear();
            if p < dim {
                for c in 0..dim {
                    inp.north_propag[c] = true;
                    inp.north_d[c] = w.at(dim - 1 - p, c) as i32;
                }
            }
            self.maybe_inject(plan, &mut cursor, t, &mut inp);
            self.mesh.step(&inp, &mut step_out);
            t += 1;
        }

        // Phase 2: stream activations (columns of A with row skew) and
        // psum bias rows (columns of D with column skew at the top).
        let a_feed = SkewFeeder::from_cols(a);
        let d_feed = SkewFeeder::from_cols(d);
        let compute_len = m + 2 * dim - 2;
        out.reset(m, dim);
        let mut taken = vec![0usize; dim];
        for tau in 0..compute_len {
            inp.clear();
            step_out.clear();
            for r in 0..dim {
                inp.west_a[r] = a_feed.at(r, tau);
            }
            for cc in 0..dim {
                inp.north_d[cc] = d_feed.at(cc, tau);
                inp.north_valid[cc] = d_feed.live(cc, tau);
            }
            self.maybe_inject(plan, &mut cursor, t, &mut inp);
            self.mesh.step(&inp, &mut step_out);
            for cc in 0..dim {
                if let Some(ps) = step_out.south_psum[cc] {
                    if taken[cc] < m {
                        out.set(taken[cc], cc, ps);
                        taken[cc] += 1;
                    }
                }
            }
            t += 1;
        }
        debug_assert!(
            !plan.is_empty() || taken.iter().all(|&x| x == m),
            "fault-free WS drain incomplete"
        );
    }
}

/// Reference tiled matmul over the mesh: decomposes an arbitrary
/// (M x K) . (K x N) into DIM x DIM output tiles, each computed by one
/// OS pass with the full K stream. Each tile is a zero-copy, zero-padded
/// window of the operand views; results splice back with one strided
/// copy per tile. Used by tests and by the whole-layer RTL offload
/// ablation (DESIGN.md D3).
pub fn tiled_matmul_os<S: Injectable>(
    mesh: &mut S,
    a: MatView<i8>,
    b: MatView<i8>,
    d: MatView<i32>,
) -> Mat<i32> {
    let dim = mesh.dim();
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    let mut c = Mat::zeros(m, n);
    let mut ti = 0;
    while ti < m {
        let mut tj = 0;
        while tj < n {
            let a_tile = a.sub(ti, 0, dim, k);
            let b_tile = b.sub(0, tj, k, dim);
            let d_tile = d.sub(ti, tj, dim, dim);
            let c_tile = MatmulDriver::new(mesh).matmul(a_tile, b_tile, d_tile);
            c.window_mut(ti, tj, dim, dim).splice_from(&c_tile);
            tj += dim;
        }
        ti += dim;
    }
    c
}

/// Pure-software golden matmul (the oracle for all mesh tests; the same
/// arithmetic as the Pallas kernel's ref.py).
pub fn gold_matmul(a: MatView<i8>, b: MatView<i8>, d: MatView<i32>) -> Mat<i32> {
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    let mut c = Mat::zeros(m, n);
    for i in 0..m {
        for j in 0..n {
            let mut acc = d.at(i, j);
            for kk in 0..k {
                acc = acc.wrapping_add(a.at(i, kk) as i32 * b.at(kk, j) as i32);
            }
            c.set(i, j, acc);
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Dataflow;
    use crate::mesh::mesh::Mesh;
    use crate::util::Rng;

    #[test]
    fn os_identity_matmul() {
        let dim = 4;
        let mut mesh = Mesh::new(dim, Dataflow::OutputStationary);
        let eye = Mat::from_fn(dim, dim, |r, c| (r == c) as i8);
        let b = Mat::from_fn(dim, dim, |r, c| (r * dim + c) as i8);
        let d = Mat::zeros(dim, dim);
        let c = MatmulDriver::new(&mut mesh).matmul(eye.view(), b.view(), d.view());
        let want = gold_matmul(eye.view(), b.view(), d.view());
        assert_eq!(c, want);
    }

    #[test]
    fn os_random_matmuls_match_gold() {
        let mut rng = Rng::new(1);
        for &(dim, k) in &[(2usize, 2usize), (4, 4), (4, 12), (8, 8), (8, 3), (3, 7)] {
            let mut mesh = Mesh::new(dim, Dataflow::OutputStationary);
            let a = rng.mat_i8(dim, k);
            let b = rng.mat_i8(k, dim);
            let d = rng.mat_i32(dim, dim, 1 << 12);
            let c = MatmulDriver::new(&mut mesh).matmul(a.view(), b.view(), d.view());
            assert_eq!(c, gold_matmul(a.view(), b.view(), d.view()), "dim={dim} k={k}");
        }
    }

    #[test]
    fn os_bias_only() {
        let dim = 4;
        let mut mesh = Mesh::new(dim, Dataflow::OutputStationary);
        let mut rng = Rng::new(2);
        let a = Mat::zeros(dim, 4);
        let b = Mat::zeros(4, dim);
        let d = rng.mat_i32(dim, dim, 1000);
        let c = MatmulDriver::new(&mut mesh).matmul(a.view(), b.view(), d.view());
        assert_eq!(c, d);
    }

    #[test]
    fn os_back_to_back_matmuls_are_independent() {
        let dim = 4;
        let mut mesh = Mesh::new(dim, Dataflow::OutputStationary);
        let mut rng = Rng::new(3);
        let a1 = rng.mat_i8(dim, 6);
        let b1 = rng.mat_i8(6, dim);
        let d1 = rng.mat_i32(dim, dim, 100);
        let c1a = MatmulDriver::new(&mut mesh).matmul(a1.view(), b1.view(), d1.view());
        let a2 = rng.mat_i8(dim, 5);
        let b2 = rng.mat_i8(5, dim);
        let _noise = MatmulDriver::new(&mut mesh).matmul(a2.view(), b2.view(), d1.view());
        let c1b = MatmulDriver::new(&mut mesh).matmul(a1.view(), b1.view(), d1.view());
        assert_eq!(c1a, c1b);
    }

    #[test]
    fn os_padded_window_operands_match_materialized() {
        // the zero-copy path: running a DIM-padded *window* of a small
        // operand must equal running the materialized padded tile
        let dim = 4;
        let mut mesh = Mesh::new(dim, Dataflow::OutputStationary);
        let mut rng = Rng::new(12);
        let a_small = rng.mat_i8(3, 5); // fewer rows than DIM
        let b_small = rng.mat_i8(5, 2); // fewer cols than DIM
        let d_small = rng.mat_i32(3, 2, 100);
        let a_win = a_small.window(0, 0, dim, 5);
        let b_win = b_small.window(0, 0, 5, dim);
        let d_win = d_small.window(0, 0, dim, dim);
        let via_window = MatmulDriver::new(&mut mesh).matmul(a_win, b_win, d_win);
        let (am, bm, dm) = (a_win.to_mat(), b_win.to_mat(), d_win.to_mat());
        let via_mat = MatmulDriver::new(&mut mesh).matmul(am.view(), bm.view(), dm.view());
        assert_eq!(via_window, via_mat);
        assert_eq!(via_window, gold_matmul(am.view(), bm.view(), dm.view()));
    }

    #[test]
    fn ws_random_matmuls_match_gold() {
        let mut rng = Rng::new(4);
        for &(dim, m) in &[(2usize, 2usize), (4, 4), (4, 10), (8, 8), (8, 1)] {
            let mut mesh = Mesh::new(dim, Dataflow::WeightStationary);
            let a = rng.mat_i8(m, dim);
            let w = rng.mat_i8(dim, dim);
            let d = rng.mat_i32(m, dim, 1 << 12);
            let c = MatmulDriver::new(&mut mesh).matmul(a.view(), w.view(), d.view());
            assert_eq!(c, gold_matmul(a.view(), w.view(), d.view()), "dim={dim} m={m}");
        }
    }

    #[test]
    fn tiled_matmul_matches_gold_on_awkward_shapes() {
        let mut rng = Rng::new(5);
        let mut mesh = Mesh::new(4, Dataflow::OutputStationary);
        for &(m, k, n) in &[(4usize, 4usize, 4usize), (8, 4, 8), (5, 7, 9), (1, 3, 2)] {
            let a = rng.mat_i8(m, k);
            let b = rng.mat_i8(k, n);
            let d = rng.mat_i32(m, n, 500);
            let c = tiled_matmul_os(&mut mesh, a.view(), b.view(), d.view());
            assert_eq!(c, gold_matmul(a.view(), b.view(), d.view()), "m={m} k={k} n={n}");
        }
    }

    #[test]
    fn injected_fault_changes_output() {
        use crate::mesh::signal::SignalKind;
        let dim = 4;
        let mut mesh = Mesh::new(dim, Dataflow::OutputStationary);
        let mut rng = Rng::new(6);
        let a = rng.mat_i8(dim, dim);
        let b = rng.mat_i8(dim, dim);
        let d = Mat::zeros(dim, dim);
        let golden = MatmulDriver::new(&mut mesh).matmul(a.view(), b.view(), d.view());
        // Propag fault in the middle of the compute phase of PE(0,1).
        let cyc = (2 * dim - 1) as u64 + 3;
        let f = Fault::new(0, 1, SignalKind::Propag, 0, cyc);
        let faulty =
            MatmulDriver::new(&mut mesh).matmul_with_fault(a.view(), b.view(), d.view(), &f);
        assert_ne!(golden, faulty);
    }

    #[test]
    fn fault_outside_active_window_is_masked() {
        use crate::mesh::signal::SignalKind;
        let dim = 4;
        let mut mesh = Mesh::new(dim, Dataflow::OutputStationary);
        let mut rng = Rng::new(7);
        let a = rng.mat_i8(dim, dim);
        let b = rng.mat_i8(dim, dim);
        let d = Mat::zeros(dim, dim);
        let golden = MatmulDriver::new(&mut mesh).matmul(a.view(), b.view(), d.view());
        // A weight-path fault injected in the very first preload cycle:
        // the operand pipelines carry no live data yet, and the corrupted
        // stream element drains before compute => fully masked.
        let f = Fault::new(0, 3, SignalKind::Weight, 6, 0);
        let faulty =
            MatmulDriver::new(&mut mesh).matmul_with_fault(a.view(), b.view(), d.view(), &f);
        assert_eq!(golden, faulty);
    }

    #[test]
    fn zero_activation_masks_weight_fault() {
        use crate::mesh::signal::SignalKind;
        // All-zero activations: any weight-path corruption multiplies by
        // zero and never reaches the accumulators (the paper's Fig. 5b
        // masking mechanism).
        let dim = 4;
        let mut mesh = Mesh::new(dim, Dataflow::OutputStationary);
        let mut rng = Rng::new(8);
        let a = rng.mat_i8(dim, dim);
        let b = Mat::zeros(dim, dim);
        let d = rng.mat_i32(dim, dim, 100);
        let golden = MatmulDriver::new(&mut mesh).matmul(a.view(), b.view(), d.view());
        let cyc = (2 * dim - 1) as u64 + 2;
        let f = Fault::new(1, 1, SignalKind::Weight, 3, cyc);
        let faulty =
            MatmulDriver::new(&mut mesh).matmul_with_fault(a.view(), b.view(), d.view(), &f);
        assert_eq!(golden, faulty);
    }

    #[test]
    fn single_fault_plan_matches_legacy_fault_path() {
        // FaultPlan::single must be bit-identical to the pre-redesign
        // single-`Fault` argument — the compatibility contract of the
        // scenario-first seam.
        use crate::mesh::signal::SignalKind;
        let dim = 4;
        let mut mesh = Mesh::new(dim, Dataflow::OutputStationary);
        let mut rng = Rng::new(40);
        let a = rng.mat_i8(dim, 9);
        let b = rng.mat_i8(9, dim);
        let d = rng.mat_i32(dim, dim, 64);
        for kind in crate::mesh::signal::SignalKind::ALL {
            let f = Fault::new(1, 2, kind, 0, (2 * dim) as u64 + 1);
            let legacy = MatmulDriver::new(&mut mesh)
                .matmul_with_fault(a.view(), b.view(), d.view(), &f);
            let plan = MatmulDriver::new(&mut mesh).matmul_with_plan(
                a.view(),
                b.view(),
                d.view(),
                &FaultPlan::single(f),
            );
            assert_eq!(legacy, plan, "kind={kind}");
        }
        // empty plan == golden
        let golden = MatmulDriver::new(&mut mesh).matmul(a.view(), b.view(), d.view());
        let via_empty = MatmulDriver::new(&mut mesh).matmul_with_plan(
            a.view(),
            b.view(),
            d.view(),
            &FaultPlan::empty(),
        );
        assert_eq!(golden, via_empty);
        let sa = Fault::stuck_at(0, 1, SignalKind::Weight, 3, true, 0);
        assert_eq!(
            MatmulDriver::new(&mut mesh).matmul_with_fault(a.view(), b.view(), d.view(), &sa),
            MatmulDriver::new(&mut mesh).matmul_with_plan(
                a.view(),
                b.view(),
                d.view(),
                &FaultPlan::single(sa)
            ),
            "stuck-at through a plan"
        );
    }

    #[test]
    fn multi_fault_plan_fires_every_fault() {
        // a two-transient plan must differ from either single-fault run
        // when the faults hit disjoint accumulators
        use crate::mesh::signal::SignalKind;
        let dim = 4;
        let mut mesh = Mesh::new(dim, Dataflow::OutputStationary);
        let mut rng = Rng::new(41);
        let a = rng.mat_i8(dim, dim);
        let b = rng.mat_i8(dim, dim);
        let d = Mat::zeros(dim, dim);
        let cyc = (2 * dim) as u64 + 1;
        let f1 = Fault::new(0, 0, SignalKind::Acc, 30, cyc);
        let f2 = Fault::new(3, 3, SignalKind::Acc, 30, cyc + 2);
        let both = MatmulDriver::new(&mut mesh).matmul_with_plan(
            a.view(),
            b.view(),
            d.view(),
            &FaultPlan::new(vec![f1, f2]),
        );
        let only1 =
            MatmulDriver::new(&mut mesh).matmul_with_fault(a.view(), b.view(), d.view(), &f1);
        let only2 =
            MatmulDriver::new(&mut mesh).matmul_with_fault(a.view(), b.view(), d.view(), &f2);
        let golden = MatmulDriver::new(&mut mesh).matmul(a.view(), b.view(), d.view());
        assert_ne!(both, only1);
        assert_ne!(both, only2);
        // disjoint Acc flips compose: both corruptions present
        assert_ne!(both[(0, 0)], golden[(0, 0)]);
        assert_ne!(both[(3, 3)], golden[(3, 3)]);
    }

    #[test]
    fn cycle_counts_match_formula() {
        let dim = 8;
        let k = 16;
        let mut mesh = Mesh::new(dim, Dataflow::OutputStationary);
        let mut rng = Rng::new(9);
        let a = rng.mat_i8(dim, k);
        let b = rng.mat_i8(k, dim);
        let d = rng.mat_i32(dim, dim, 10);
        MatmulDriver::new(&mut mesh).matmul(a.view(), b.view(), d.view());
        assert_eq!(mesh.cycle, os_matmul_cycles(dim, k));
    }
}
