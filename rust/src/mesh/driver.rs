//! Matmul drivers: the "simulation wrapper" that drives operand streams
//! through the mesh, performs `C = A . B + D`, and applies at most ONE
//! compare-and-branch per cycle for fault injection — the ENFOR-SA
//! alternative to per-assignment instrumentation.
//!
//! Operands cross the software↔RTL boundary as flat, stride-aware
//! [`MatView`]s (see [`crate::mat`]): a DIM-padded tile of a layer's
//! flat buffer is a zero-copy window, and the implicit zero padding of
//! the view doubles as the zero-padded scratchpad read of the real
//! frontend. No per-matmul operand allocation happens on the campaign
//! hot path: the boundary input/output buffers and the drain counter
//! live in a reusable [`DriverScratch`], and results drain into a
//! caller-owned [`Mat`] (see [`MatmulDriver::matmul_into_with`]).
//!
//! # The cycle-indexed schedule
//!
//! Both dataflow programs are expressed as a [`Schedule`]: phase
//! boundaries plus the zero-copy [`SkewFeeder`]s, able to produce the
//! [`MeshInputs`] of ANY cycle `t` in O(dim). That indexability is what
//! cycle-resume builds on — a trial whose fault plan first acts at
//! cycle `t` restores a golden snapshot and replays only `t..end`
//! ([`MatmulDriver::matmul_resumed`]); the shared golden prefix is
//! advanced lazily once per tile by a [`CycleCursor`].
//!
//! Output-stationary schedule (the paper's configuration):
//!
//! 1. **Preload** (2*DIM-1 cycles): propagate asserted at the north edge
//!    for DIM cycles while the bias matrix D staircases down the
//!    accumulator chain (rows fed in reverse).
//! 2. **Compute** (K + 2*DIM-2 cycles): weights stream west→east with
//!    row skew, activations north→south with column skew, `valid`
//!    travelling with the activation stream.
//! 3. **Flush** (2*DIM-1 cycles): propagate again; results exit the
//!    south edge bottom-row-first and are un-staircased by the drain
//!    (rows written in reverse — the real drain FSM's behaviour).
//!
//! Weight-stationary schedule: W staircases in through the d-chain, then
//! activation columns stream west→east while psums (initialised with D
//! rows at the north edge) flow down and exit south every compute cycle
//! (no flush phase).

use super::adapters::SkewFeeder;
use super::inject::{Fault, FaultPlan, Injectable, PlanCursor};
use super::lane::{LaneCursor, LaneMesh};
use super::mesh::{MeshInputs, MeshState, StepOutput};
use crate::config::Dataflow;
use crate::mat::{Mat, MatView};

/// Cycle count of one OS matmul on a DIM mesh with inner dimension K.
pub fn os_matmul_cycles(dim: usize, k: usize) -> u64 {
    ((2 * dim - 1) + (k + 2 * dim - 2) + (2 * dim - 1)) as u64
}

/// Cycle count of one WS matmul streaming M rows through a DIM mesh.
pub fn ws_matmul_cycles(dim: usize, m: usize) -> u64 {
    ((2 * dim - 1) + (m + 2 * dim - 2)) as u64
}

/// Cycle count of one tile pass under `dataflow` — the dataflow-generic
/// cycle model every campaign layer samples from (ROADMAP
/// "Dataflow-generic campaigns"). An OS pass streams the K reduction
/// ([`os_matmul_cycles`]); a WS pass streams the M activation rows
/// through a preloaded weight tile ([`ws_matmul_cycles`]), so the two
/// dataflows depend on *different* operand dimensions.
pub fn matmul_cycles(dataflow: Dataflow, dim: usize, m: usize, k: usize) -> u64 {
    match dataflow {
        Dataflow::OutputStationary => os_matmul_cycles(dim, k),
        Dataflow::WeightStationary => ws_matmul_cycles(dim, m),
    }
}

/// The `(tiles_i, tiles_j)` grid an `(M x K) . (K x N)` GEMM decomposes
/// into under `dataflow` — the space the campaign samples an offload
/// tile from.
///
/// * OS: output tiles — `tile_i` indexes M, `tile_j` indexes N; every
///   tile receives the full K stream.
/// * WS: **weight** tiles — `tile_i` indexes K (which DIM x DIM weight
///   tile is preloaded), `tile_j` indexes N; every pass streams the
///   full M-row activation panel.
pub fn tile_grid(dataflow: Dataflow, dim: usize, m: usize, k: usize, n: usize) -> (usize, usize) {
    match dataflow {
        Dataflow::OutputStationary => (m.div_ceil(dim), n.div_ceil(dim)),
        Dataflow::WeightStationary => (k.div_ceil(dim), n.div_ceil(dim)),
    }
}

/// The per-dataflow operand streams of a [`Schedule`] (all zero-copy
/// views/feeders over the caller's flat buffers).
enum Streams<'a> {
    /// OS: D preloads down the accumulator chain; A rows stream west,
    /// B columns (with `valid`) stream north.
    Os {
        d: MatView<'a, i32>,
        a: SkewFeeder<'a, i8>,
        b: SkewFeeder<'a, i8>,
    },
    /// WS: W preloads down the d-chain; A columns stream west, D rows
    /// (psum initialisers, with `valid`) enter north.
    Ws {
        w: MatView<'a, i8>,
        a: SkewFeeder<'a, i8>,
        d: SkewFeeder<'a, i32>,
    },
}

/// The cycle-indexability contract shared by every schedule in the
/// system: a fixed total cycle count, a fixed drain window start, and a
/// fixed result-row count — all knowable up front, independent of any
/// stepping state. [`Schedule`] implements it for the mesh-only driver
/// and [`crate::soc::SocSchedule`] for the full-SoC controller, which is
/// what lets the campaign's cycle-resume machinery treat both backends
/// identically (ROADMAP "Schedule-indexable SoC").
pub trait CycleIndexed {
    /// Mesh cycles in the whole program window.
    fn total_cycles(&self) -> u64;
    /// First cycle south-edge traffic is captured (fixed drain window).
    fn drain_start(&self) -> u64;
    /// Result rows the window produces (OS: DIM; WS: M).
    fn out_rows(&self) -> usize;
}

/// A cycle-indexed description of one tile matmul: phase boundaries plus
/// the operand feeders, able to produce the boundary [`MeshInputs`] of
/// ANY cycle `t` in O(dim) ([`Schedule::fill`]) and to absorb that
/// cycle's south-edge traffic ([`Schedule::drain`]). Construction is
/// O(1) (borrowed views only) — the indexability invariant of the
/// ROADMAP "Cycle-resume" contract.
pub struct Schedule<'a> {
    dim: usize,
    /// Result rows each column drains (OS: DIM; WS: M).
    out_rows: usize,
    preload: u64,
    compute: u64,
    flush: u64,
    streams: Streams<'a>,
}

impl<'a> Schedule<'a> {
    /// Build the schedule for one matmul, validating operand shapes.
    ///
    /// OS: `a` is DIM x K (weights), `b` is K x DIM (activations), `d`
    /// DIM x DIM. WS: `a` is M x DIM (streaming activations), `b` the
    /// stationary DIM x DIM weight tile, `d` M x DIM (bias rows).
    pub fn new(
        dataflow: Dataflow,
        dim: usize,
        a: MatView<'a, i8>,
        b: MatView<'a, i8>,
        d: MatView<'a, i32>,
    ) -> Schedule<'a> {
        match dataflow {
            Dataflow::OutputStationary => {
                let k = a.cols();
                assert_eq!(a.rows(), dim, "A must have DIM rows");
                assert_eq!(b.rows(), k, "B must have K rows");
                assert_eq!(b.cols(), dim, "B must have DIM cols");
                assert_eq!((d.rows(), d.cols()), (dim, dim), "D must be DIM x DIM");
                Schedule {
                    dim,
                    out_rows: dim,
                    preload: (2 * dim - 1) as u64,
                    compute: (k + 2 * dim - 2) as u64,
                    flush: (2 * dim - 1) as u64,
                    streams: Streams::Os {
                        d,
                        a: SkewFeeder::from_rows(a),
                        b: SkewFeeder::from_cols(b),
                    },
                }
            }
            Dataflow::WeightStationary => {
                let m = a.rows();
                assert_eq!(a.cols(), dim, "A must have DIM cols");
                assert_eq!((b.rows(), b.cols()), (dim, dim), "W must be DIM x DIM");
                assert_eq!(d.rows(), m, "D must have M rows");
                assert_eq!(d.cols(), dim, "D must have DIM cols");
                Schedule {
                    dim,
                    out_rows: m,
                    preload: (2 * dim - 1) as u64,
                    compute: (m + 2 * dim - 2) as u64,
                    flush: 0,
                    streams: Streams::Ws {
                        w: b,
                        a: SkewFeeder::from_cols(a),
                        d: SkewFeeder::from_cols(d),
                    },
                }
            }
        }
    }

    /// Total cycles of the program (matches `{os,ws}_matmul_cycles`).
    pub fn total_cycles(&self) -> u64 {
        self.preload + self.compute + self.flush
    }

    /// Result shape: `(out_rows, dim)`.
    pub fn out_shape(&self) -> (usize, usize) {
        (self.out_rows, self.dim)
    }

    /// First cycle on which south-edge traffic is captured: the flush
    /// window for OS, the compute window for WS. (Earlier Some values —
    /// possible under control-signal faults — are discarded, exactly as
    /// the fixed-window drain FSM of the real frontend does.)
    fn drain_start(&self) -> u64 {
        match self.streams {
            Streams::Os { .. } => self.preload + self.compute,
            Streams::Ws { .. } => self.preload,
        }
    }

    /// Produce the boundary inputs of cycle `t` (O(dim)).
    pub fn fill(&self, t: u64, inp: &mut MeshInputs) {
        inp.clear();
        let dim = self.dim;
        if t < self.preload {
            // Phase 1: preload down the d-chain (rows fed in reverse).
            let p = t as usize;
            if p < dim {
                match &self.streams {
                    Streams::Os { d, .. } => {
                        for c in 0..dim {
                            inp.north_propag[c] = true;
                            inp.north_d[c] = d.at(dim - 1 - p, c);
                        }
                    }
                    Streams::Ws { w, .. } => {
                        for c in 0..dim {
                            inp.north_propag[c] = true;
                            inp.north_d[c] = w.at(dim - 1 - p, c) as i32;
                        }
                    }
                }
            }
        } else if t < self.preload + self.compute {
            // Phase 2: stream the skewed operands; `valid` rides with
            // the north stream. The feeders read the views in place.
            let tau = (t - self.preload) as usize;
            match &self.streams {
                Streams::Os { a, b, .. } => {
                    for r in 0..dim {
                        inp.west_a[r] = a.at(r, tau);
                    }
                    for c in 0..dim {
                        inp.north_b[c] = b.at(c, tau);
                        inp.north_valid[c] = b.live(c, tau);
                    }
                }
                Streams::Ws { a, d, .. } => {
                    for r in 0..dim {
                        inp.west_a[r] = a.at(r, tau);
                    }
                    for c in 0..dim {
                        inp.north_d[c] = d.at(c, tau);
                        inp.north_valid[c] = d.live(c, tau);
                    }
                }
            }
        } else {
            // Phase 3 (OS only): flush C through the south edge.
            debug_assert!(t < self.total_cycles(), "cycle beyond the schedule");
            let p = (t - self.preload - self.compute) as usize;
            if p < dim {
                for c in 0..dim {
                    inp.north_propag[c] = true;
                }
            }
        }
    }

    /// Absorb cycle `t`'s south-edge traffic into `(out, taken)`: OS
    /// un-staircases flush rows (bottom row first, so rows are written
    /// in reverse), WS collects completed psums in stream order.
    pub fn drain(&self, t: u64, step_out: &StepOutput, out: &mut Mat<i32>, taken: &mut [usize]) {
        if t < self.drain_start() {
            return;
        }
        match self.streams {
            Streams::Os { .. } => {
                for col in 0..self.dim {
                    if step_out.has_south_c(col) {
                        let k = taken[col];
                        if k < self.out_rows {
                            out.set(self.out_rows - 1 - k, col, step_out.south_c_at(col));
                            taken[col] = k + 1;
                        }
                    }
                }
            }
            Streams::Ws { .. } => {
                for col in 0..self.dim {
                    if step_out.has_south_psum(col) {
                        let k = taken[col];
                        if k < self.out_rows {
                            out.set(k, col, step_out.south_psum_at(col));
                            taken[col] = k + 1;
                        }
                    }
                }
            }
        }
    }
}

impl CycleIndexed for Schedule<'_> {
    fn total_cycles(&self) -> u64 {
        Schedule::total_cycles(self)
    }
    fn drain_start(&self) -> u64 {
        Schedule::drain_start(self)
    }
    fn out_rows(&self) -> usize {
        self.out_rows
    }
}

/// Reusable driver buffers: the per-cycle boundary inputs/outputs plus
/// the drain counter `run_ws` used to allocate per matmul. One scratch
/// per persistent runner/worker keeps the whole trial hot path
/// allocation-free (module-doc contract); buffers are re-shaped lazily
/// when the mesh dimension changes.
#[derive(Clone, Debug)]
pub struct DriverScratch {
    inp: MeshInputs,
    step_out: StepOutput,
    taken: Vec<usize>,
}

impl Default for DriverScratch {
    fn default() -> Self {
        DriverScratch::new(0)
    }
}

impl DriverScratch {
    pub fn new(dim: usize) -> Self {
        DriverScratch {
            inp: MeshInputs::idle(dim),
            step_out: StepOutput::new(dim),
            taken: vec![0; dim],
        }
    }

    /// Shape the buffers for `dim` WITHOUT resetting the drain counter —
    /// the one scratch is reused across `advance_golden`,
    /// `matmul_resumed` and the lockstep span, and the resume paths
    /// overwrite `taken` wholesale from the cursor's golden progress, so
    /// re-zeroing it per call would be wasted work.
    fn ensure_dim(&mut self, dim: usize) {
        if self.inp.west_a.len() != dim {
            self.inp = MeshInputs::idle(dim);
            self.step_out = StepOutput::new(dim);
            self.taken.clear();
            self.taken.resize(dim, 0);
        }
    }

    /// Shape for `dim` lanes and zero the drain counter (reusing the
    /// allocations whenever the dimension is unchanged).
    fn begin(&mut self, dim: usize) {
        self.ensure_dim(dim);
        self.taken.fill(0);
    }
}

/// Golden-cursor state for cycle-resume: the architectural snapshot of a
/// fault-free execution of ONE tile matmul at [`CycleCursor::cycle`],
/// plus the drain progress by then (result values already emitted). The
/// campaign keeps one cursor per site batch and advances it lazily
/// ([`MatmulDriver::advance_golden`]): trials sorted tile-major and by
/// ascending first-effect cycle each pay only the golden cycles nobody
/// stepped yet — the whole batch pays each tile's golden prefix once.
/// One cursor lives as long as its runner (a site batch); within that
/// lifetime the buffers are recycled across tiles.
#[derive(Clone, Debug, Default)]
pub struct CycleCursor {
    /// Which tile trajectory the snapshot belongs to (`None` = invalid).
    key: Option<(usize, usize)>,
    cycle: u64,
    state: MeshState,
    /// Golden result values drained by `cycle` (primes a resumed run's
    /// output so a mid-flush resume starts with the rows already out).
    partial: Mat<i32>,
    taken: Vec<usize>,
}

impl CycleCursor {
    pub fn new() -> Self {
        CycleCursor::default()
    }

    /// Golden cycle reached so far (0 when invalid).
    pub fn cycle(&self) -> u64 {
        self.cycle
    }

    /// Invalidate the trajectory: the next advance restarts from cycle 0
    /// (call when the underlying operands may have changed).
    pub fn invalidate(&mut self) {
        self.key = None;
        self.cycle = 0;
    }

    /// Start a fresh trajectory for `key`.
    fn begin(&mut self, key: (usize, usize), rows: usize, cols: usize) {
        self.key = Some(key);
        self.cycle = 0;
        self.partial.reset(rows, cols);
        self.taken.clear();
        self.taken.resize(cols, 0);
    }
}

/// Drives one matmul through a mesh backend.
pub struct MatmulDriver<'m, S: Injectable> {
    mesh: &'m mut S,
}

impl<'m, S: Injectable> MatmulDriver<'m, S> {
    pub fn new(mesh: &'m mut S) -> Self {
        MatmulDriver { mesh }
    }

    /// Golden (fault-free) matmul.
    pub fn matmul(&mut self, a: MatView<i8>, b: MatView<i8>, d: MatView<i32>) -> Mat<i32> {
        let mut out = Mat::default();
        self.matmul_into(a, b, d, &FaultPlan::empty(), &mut out);
        out
    }

    /// Matmul with a single transient fault injected at `fault.cycle`
    /// (relative to the start of this matmul) — the legacy single-SEU
    /// convenience over [`MatmulDriver::matmul_with_plan`].
    pub fn matmul_with_fault(
        &mut self,
        a: MatView<i8>,
        b: MatView<i8>,
        d: MatView<i32>,
        fault: &Fault,
    ) -> Mat<i32> {
        self.matmul_with_plan(a, b, d, &FaultPlan::single(*fault))
    }

    /// Matmul with a whole fault scenario (MBU, burst, double SEU,
    /// stuck-at...) injected at the plan's cycles.
    pub fn matmul_with_plan(
        &mut self,
        a: MatView<i8>,
        b: MatView<i8>,
        d: MatView<i32>,
        plan: &FaultPlan,
    ) -> Mat<i32> {
        let mut out = Mat::default();
        self.matmul_into(a, b, d, plan, &mut out);
        out
    }

    /// Matmul into a caller-provided result buffer: `out` is reshaped
    /// and zeroed in place (reusing its allocation). Convenience over
    /// [`MatmulDriver::matmul_into_with`] that allocates its own
    /// one-shot [`DriverScratch`]. Returns the cycles stepped.
    pub fn matmul_into(
        &mut self,
        a: MatView<i8>,
        b: MatView<i8>,
        d: MatView<i32>,
        plan: &FaultPlan,
        out: &mut Mat<i32>,
    ) -> u64 {
        let mut scratch = DriverScratch::new(self.mesh.dim());
        self.matmul_into_with(a, b, d, plan, out, &mut scratch)
    }

    /// The full-program hot entry: run every cycle of the schedule from
    /// reset, reusing `out`'s and `scratch`'s allocations, so
    /// back-to-back trials allocate nothing. An empty plan is a golden
    /// run. Returns the cycles stepped (always the schedule length).
    pub fn matmul_into_with(
        &mut self,
        a: MatView<i8>,
        b: MatView<i8>,
        d: MatView<i32>,
        plan: &FaultPlan,
        out: &mut Mat<i32>,
        scratch: &mut DriverScratch,
    ) -> u64 {
        let sched = Schedule::new(self.mesh.dataflow(), self.mesh.dim(), a, b, d);
        if !plan.is_empty() {
            self.mesh.arm(plan);
        }
        self.mesh.reset();
        let (rows, cols) = sched.out_shape();
        out.reset(rows, cols);
        scratch.begin(self.mesh.dim());
        let mut cursor = PlanCursor::start(plan);
        let DriverScratch { inp, step_out, taken } = scratch;
        let stepped =
            self.run_span(&sched, plan, &mut cursor, 0, sched.total_cycles(), out, taken, inp, step_out);
        if !plan.is_empty() {
            self.mesh.disarm();
        }
        // A control-signal fault can legitimately disturb the drain
        // (extra or missing propagate pulses) — the real drain FSM also
        // just latches whatever arrives in its fixed window. Only
        // fault-free runs must drain every result row.
        debug_assert!(
            !plan.is_empty() || taken.iter().all(|&x| x == sched.out_rows),
            "fault-free drain did not produce every result row"
        );
        debug_assert_eq!(stepped, sched.total_cycles());
        stepped
    }

    /// Advance `cur`'s golden trajectory for tile `key` up to `target`
    /// (clamped to the schedule end): restore the snapshot, step only
    /// the missing fault-free cycles, re-snapshot. The cursor is
    /// monotonic per key — a different key restarts from cycle 0, and a
    /// rewound target restarts too (correct but unshared; sorted
    /// batches never rewind). Returns the cycles stepped.
    #[allow(clippy::too_many_arguments)]
    pub fn advance_golden(
        &mut self,
        a: MatView<i8>,
        b: MatView<i8>,
        d: MatView<i32>,
        key: (usize, usize),
        target: u64,
        cur: &mut CycleCursor,
        scratch: &mut DriverScratch,
    ) -> u64 {
        let sched = Schedule::new(self.mesh.dataflow(), self.mesh.dim(), a, b, d);
        let target = target.min(sched.total_cycles());
        if cur.key == Some(key) && cur.cycle == target {
            return 0; // snapshot already at the requested cycle
        }
        // reshape-only: the drain progress lives in `cur.taken` here
        scratch.ensure_dim(self.mesh.dim());
        if cur.key != Some(key) || cur.cycle > target {
            // fresh tile — or a rewound target (possible only when tile
            // clamping merged two sort groups): restart the trajectory
            // from cycle 0. Correct either way; the sorted batch order
            // makes the rewind case vanish (prop tests pin that the
            // cycle accounting actually shrinks).
            let (rows, cols) = sched.out_shape();
            cur.begin(key, rows, cols);
            self.mesh.reset();
        } else {
            self.mesh.restore_state(&cur.state);
        }
        let empty = FaultPlan::empty();
        let mut cursor = PlanCursor::start(&empty);
        let DriverScratch { inp, step_out, .. } = scratch;
        let stepped = self.run_span(
            &sched,
            &empty,
            &mut cursor,
            cur.cycle,
            target,
            &mut cur.partial,
            &mut cur.taken,
            inp,
            step_out,
        );
        self.mesh.save_state(&mut cur.state);
        cur.cycle = target;
        stepped
    }

    /// Cycle-resume trial: restore the golden snapshot `cur` holds for
    /// these operands and replay ONLY cycles `[cur.cycle(), end)` with
    /// `plan` armed; the drain — including a mid-flush resume — is
    /// primed from the cursor's golden progress. Requires `cur` to have
    /// been advanced ([`MatmulDriver::advance_golden`]) for the SAME
    /// operands to a cycle `<=` the plan's first effect cycle on this
    /// backend; the result is then bit-identical to a full
    /// [`MatmulDriver::matmul_into_with`] (pinned by
    /// `rust/tests/prop_cycle_resume.rs`). Returns the cycles stepped.
    #[allow(clippy::too_many_arguments)]
    pub fn matmul_resumed(
        &mut self,
        a: MatView<i8>,
        b: MatView<i8>,
        d: MatView<i32>,
        plan: &FaultPlan,
        cur: &CycleCursor,
        out: &mut Mat<i32>,
        scratch: &mut DriverScratch,
    ) -> u64 {
        let sched = Schedule::new(self.mesh.dataflow(), self.mesh.dim(), a, b, d);
        debug_assert!(cur.key.is_some(), "resume requires an advanced golden cursor");
        debug_assert_eq!(
            (cur.partial.rows(), cur.partial.cols()),
            sched.out_shape(),
            "cursor was advanced for a different schedule"
        );
        debug_assert!(
            cur.cycle <= self.mesh.first_effect_cycle(plan).min(sched.total_cycles()),
            "snapshot taken past the plan's first effect cycle"
        );
        // reshape-only: `taken` is primed from the cursor just below
        scratch.ensure_dim(self.mesh.dim());
        if !plan.is_empty() {
            self.mesh.arm(plan);
        }
        self.mesh.restore_state(&cur.state);
        // prime the result and drain progress with the golden prefix
        out.clone_from(&cur.partial);
        scratch.taken.copy_from_slice(&cur.taken);
        let mut cursor = PlanCursor::start(plan);
        let DriverScratch { inp, step_out, taken } = scratch;
        let stepped = self.run_span(
            &sched,
            plan,
            &mut cursor,
            cur.cycle,
            sched.total_cycles(),
            out,
            taken,
            inp,
            step_out,
        );
        if !plan.is_empty() {
            self.mesh.disarm();
        }
        stepped
    }

    /// Step cycles `[from, to)` of `sched`: produce each cycle's
    /// boundary inputs (O(dim)), apply the single per-cycle injection
    /// compare, step, and drain south-edge traffic into `(out, taken)`.
    /// Returns the number of cycles stepped.
    #[allow(clippy::too_many_arguments)]
    fn run_span(
        &mut self,
        sched: &Schedule<'_>,
        plan: &FaultPlan,
        cursor: &mut PlanCursor,
        from: u64,
        to: u64,
        out: &mut Mat<i32>,
        taken: &mut [usize],
        inp: &mut MeshInputs,
        step_out: &mut StepOutput,
    ) -> u64 {
        // Control-path plans corrupt the schedule machinery itself (the
        // tile sequencer's fetch cycle, the drain-FSM counters); gated
        // here so PE-grid plans keep the single-compare hot path.
        let ctrl = plan.has_control();
        for t in from..to {
            let fill_t = if ctrl {
                super::inject::apply_control(plan, t, sched.total_cycles(), taken)
            } else {
                t
            };
            sched.fill(fill_t, inp);
            step_out.clear();
            // One compare per cycle: the entire injection overhead of
            // ENFOR-SA (stuck-at faults keep the cursor re-armed so the
            // forcing re-applies every cycle — still wrapper-only).
            if cursor.next_cycle() == t {
                cursor.fire(plan, t, self.mesh, inp);
            }
            self.mesh.step(inp, step_out);
            sched.drain(t, step_out, out, taken);
        }
        to.saturating_sub(from)
    }
}

/// Trial-lockstep resume (PR 6 tentpole): replay the suffix of ONE tile
/// matmul for a whole chunk of trials at once — one [`LaneMesh`] lane
/// per trial. Sound because of the site-resume invariant: every trial of
/// a site batch shares operands, so a single `Schedule::fill` per cycle
/// feeds ALL lanes, and each lane's fault-free replay of
/// `[cur.cycle(), fe_l)` reproduces the golden trajectory bit-for-bit
/// before its own plan first acts at `fe_l`.
///
/// Requires `cur` to have been advanced ([`MatmulDriver::advance_golden`])
/// for the SAME operands to a cycle `<=` the minimum first-effect cycle
/// over `plans`. Every lane restores from the one golden snapshot
/// ([`LaneMesh::broadcast`]), primes its result and drain counters from
/// the cursor's golden progress, and the suffix is stepped ONCE in
/// lockstep; each lane fires only its own plan through its
/// [`LaneCursor`]. `outs[l]` is then bit-identical to a per-trial
/// [`MatmulDriver::matmul_resumed`] with `plans[l]` (pinned by
/// `lockstep_resumed_matches_per_trial_resume` below and by
/// `rust/tests/prop_lockstep.rs` end to end).
///
/// Returns the cycles stepped — counted ONCE per lockstep cycle, not
/// per lane, which is what `rtl_cycles_stepped` reports and why a chunk
/// of N>1 trials steps strictly fewer cycles than N cycle-resume runs.
pub fn lockstep_resumed(
    mesh: &mut LaneMesh,
    a: MatView<i8>,
    b: MatView<i8>,
    d: MatView<i32>,
    plans: &[&FaultPlan],
    cur: &CycleCursor,
    outs: &mut Vec<Mat<i32>>,
    scratch: &mut DriverScratch,
) -> u64 {
    let dim = mesh.dim();
    let lanes = plans.len();
    assert!(lanes > 0, "a lockstep chunk needs at least one trial");
    let sched = Schedule::new(mesh.dataflow(), dim, a, b, d);
    debug_assert!(
        cur.key.is_some(),
        "lockstep resume requires an advanced golden cursor"
    );
    debug_assert_eq!(
        (cur.partial.rows(), cur.partial.cols()),
        sched.out_shape(),
        "cursor was advanced for a different schedule"
    );
    debug_assert!(
        cur.cycle
            <= plans
                .iter()
                .map(|p| p.first_cycle())
                .min()
                .unwrap_or(u64::MAX)
                .min(sched.total_cycles()),
        "snapshot taken past the chunk's first effect cycle"
    );
    // reshape-only: per-lane drain counters live in `mesh.takens`
    scratch.ensure_dim(dim);
    mesh.reshape(lanes);
    mesh.broadcast(&cur.state);
    if outs.len() != lanes {
        outs.resize_with(lanes, Mat::default);
    }
    let mut cursors = Vec::with_capacity(lanes);
    for (l, plan) in plans.iter().enumerate() {
        // prime each lane's result and drain progress with the golden
        // prefix, exactly as a per-trial resume would
        outs[l].clone_from(&cur.partial);
        mesh.takens[l].clear();
        mesh.takens[l].extend_from_slice(&cur.taken);
        cursors.push(LaneCursor::start(plan));
    }
    let total = sched.total_cycles();
    for t in cur.cycle..total {
        sched.fill(t, &mut scratch.inp);
        mesh.begin_cycle(&scratch.inp);
        // Still one compare per lane per cycle — ENFOR-SA's whole
        // overhead story, now amortized over the shared fill and step.
        for (l, cursor) in cursors.iter_mut().enumerate() {
            if cursor.next_cycle() == t {
                cursor.fire(plans[l], t, mesh, l);
            }
        }
        mesh.step();
        for (l, out) in outs.iter_mut().enumerate() {
            sched.drain(t, &mesh.step_outs[l], out, &mut mesh.takens[l]);
        }
    }
    total.saturating_sub(cur.cycle)
}

/// One lane group of a packed-lockstep chunk: a maximal same-tile run of
/// trials sharing operands, a golden cursor and a drain window. Groups
/// are packed side by side into one [`LaneMesh`]; each owns the lane
/// range `[lane0, lane0 + plans.len())` assigned by packing order.
pub struct LaneGroup<'a> {
    /// Operand views of this group's tile (the `Schedule::new` triple).
    pub a: MatView<'a, i8>,
    pub b: MatView<'a, i8>,
    pub d: MatView<'a, i32>,
    /// One fault plan per lane of the group.
    pub plans: Vec<&'a FaultPlan>,
    /// The group's advanced golden cursor (per-group snapshot + drain
    /// progress; distinct groups may clamp to the same actual tile, so
    /// each group must own its own cursor).
    pub cur: &'a CycleCursor,
}

/// Cross-tile packed-lockstep resume (the cross-tile packing tentpole):
/// replay the suffixes of SEVERAL tile matmuls side by side in one
/// [`LaneMesh`] pass — each [`LaneGroup`] owns its own `Schedule`,
/// golden snapshot (per-group [`LaneMesh::broadcast_group`] instead of a
/// whole-mesh broadcast), per-group edge fill and drain window.
///
/// Cycle alignment is **start-aligned**: group `g` restored its snapshot
/// at golden cycle `start_g = cur.cycle()`, so at global step `t` its
/// local cycle is `start_g + t`, and the chunk runs for
/// `max_g(total_g - start_g)` global steps. A group whose suffix is
/// shorter retires early: its edge fill, fault fires and drain are
/// simply skipped while its lanes keep stepping on stale edges — the
/// step kernels stay branch-free and the retired lanes' outputs are
/// never read. Requires each group's cursor to have been advanced
/// ([`MatmulDriver::advance_golden`]) for that group's operands to a
/// cycle `<=` the minimum first-effect cycle over its plans; `outs[l]`
/// is then bit-identical to a per-trial
/// [`MatmulDriver::matmul_resumed`] (pinned by
/// `packed_resumed_matches_per_trial_resume` below and by
/// `rust/tests/prop_lockstep.rs` end to end).
///
/// Returns `(stepped, lane_cycles_filled)`: the cycles stepped —
/// `max_g(span_g)`, counted ONCE per global lockstep cycle — and the
/// lane-cycles actually carrying live work, `Σ_g lanes_g · span_g` (each
/// group's lanes are active for exactly its own span under start
/// alignment; the campaign's lane-occupancy accounting divides this by
/// capacity · stepped). A chunk of G>1 groups therefore steps
/// `max_g(span_g)` instead of lane-lockstep's `Σ_g span_g`: never more,
/// and strictly fewer whenever packing merged at least two runs.
pub fn packed_lockstep_resumed(
    mesh: &mut LaneMesh,
    groups: &[LaneGroup<'_>],
    outs: &mut Vec<Mat<i32>>,
    scratch: &mut DriverScratch,
) -> (u64, u64) {
    let dim = mesh.dim();
    assert!(!groups.is_empty(), "a packed chunk needs at least one group");
    let lanes: usize = groups.iter().map(|g| g.plans.len()).sum();
    assert!(lanes > 0, "a packed chunk needs at least one trial");
    scratch.ensure_dim(dim);
    mesh.reshape(lanes);
    if outs.len() != lanes {
        outs.resize_with(lanes, Mat::default);
    }
    let mut scheds = Vec::with_capacity(groups.len());
    let mut starts = Vec::with_capacity(groups.len());
    let mut lane0s = Vec::with_capacity(groups.len());
    let mut cursors: Vec<LaneCursor> = Vec::with_capacity(lanes);
    let mut lane0 = 0usize;
    let mut span_max = 0u64;
    let mut filled = 0u64;
    for g in groups {
        let sched = Schedule::new(mesh.dataflow(), dim, g.a, g.b, g.d);
        let cur = g.cur;
        debug_assert!(
            cur.key.is_some(),
            "packed resume requires an advanced golden cursor per group"
        );
        debug_assert_eq!(
            (cur.partial.rows(), cur.partial.cols()),
            sched.out_shape(),
            "a group's cursor was advanced for a different schedule"
        );
        debug_assert!(
            cur.cycle
                <= g.plans
                    .iter()
                    .map(|p| p.first_cycle())
                    .min()
                    .unwrap_or(u64::MAX)
                    .min(sched.total_cycles()),
            "a group's snapshot was taken past its first effect cycle"
        );
        mesh.broadcast_group(lane0, g.plans.len(), &cur.state);
        for (l, plan) in g.plans.iter().enumerate() {
            outs[lane0 + l].clone_from(&cur.partial);
            mesh.takens[lane0 + l].clear();
            mesh.takens[lane0 + l].extend_from_slice(&cur.taken);
            cursors.push(LaneCursor::start(plan));
        }
        let span = sched.total_cycles().saturating_sub(cur.cycle);
        span_max = span_max.max(span);
        filled += g.plans.len() as u64 * span;
        starts.push(cur.cycle);
        lane0s.push(lane0);
        lane0 += g.plans.len();
        scheds.push(sched);
    }
    for t in 0..span_max {
        mesh.clear_outputs();
        for (gi, g) in groups.iter().enumerate() {
            let local = starts[gi] + t;
            if local >= scheds[gi].total_cycles() {
                continue; // retired: stale edges, outputs unread
            }
            scheds[gi].fill(local, &mut scratch.inp);
            mesh.fill_group(lane0s[gi], g.plans.len(), &scratch.inp);
        }
        for (gi, g) in groups.iter().enumerate() {
            let local = starts[gi] + t;
            if local >= scheds[gi].total_cycles() {
                continue;
            }
            for (l, plan) in g.plans.iter().enumerate() {
                let lane = lane0s[gi] + l;
                if cursors[lane].next_cycle() == local {
                    cursors[lane].fire(plan, local, mesh, lane);
                }
            }
        }
        mesh.step();
        for (gi, g) in groups.iter().enumerate() {
            let local = starts[gi] + t;
            if local >= scheds[gi].total_cycles() {
                continue;
            }
            for l in 0..g.plans.len() {
                let lane = lane0s[gi] + l;
                scheds[gi].drain(
                    local,
                    &mesh.step_outs[lane],
                    &mut outs[lane],
                    &mut mesh.takens[lane],
                );
            }
        }
    }
    (span_max, filled)
}

/// Reference tiled matmul over the mesh: decomposes an arbitrary
/// (M x K) . (K x N) into DIM x DIM output tiles, each computed by one
/// OS pass with the full K stream. Each tile is a zero-copy, zero-padded
/// window of the operand views; results splice back with one strided
/// copy per tile. Used by tests and by the whole-layer RTL offload
/// ablation (DESIGN.md D3).
pub fn tiled_matmul_os<S: Injectable>(
    mesh: &mut S,
    a: MatView<i8>,
    b: MatView<i8>,
    d: MatView<i32>,
) -> Mat<i32> {
    let dim = mesh.dim();
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    let mut c = Mat::zeros(m, n);
    let mut ti = 0;
    while ti < m {
        let mut tj = 0;
        while tj < n {
            let a_tile = a.sub(ti, 0, dim, k);
            let b_tile = b.sub(0, tj, k, dim);
            let d_tile = d.sub(ti, tj, dim, dim);
            let c_tile = MatmulDriver::new(mesh).matmul(a_tile, b_tile, d_tile);
            c.window_mut(ti, tj, dim, dim).splice_from(&c_tile);
            tj += dim;
        }
        ti += dim;
    }
    c
}

/// Reference tiled matmul over a weight-stationary mesh — the WS peer
/// of [`tiled_matmul_os`]: an arbitrary `(M x K) . (K x N)` decomposes
/// into DIM-wide output column blocks, each computed by a **chain** of
/// WS passes — one per DIM x DIM weight tile of the K reduction — with
/// the psum output of pass `ki` feeding the next pass's north-edge D
/// stream (a fault-free WS pass computes exactly `A.W + D` in wrapping
/// i32, so the chain is exact). Every operand is a zero-copy,
/// zero-padded [`MatView`] window; the finished column splices back
/// with one strided copy.
pub fn tiled_matmul_ws<S: Injectable>(
    mesh: &mut S,
    a: MatView<i8>,
    b: MatView<i8>,
    d: MatView<i32>,
) -> Mat<i32> {
    tiled_matmul_ws_with(mesh, a, b, d, &FaultPlan::empty(), (usize::MAX, usize::MAX))
}

/// [`tiled_matmul_ws`] with `plan` armed on exactly ONE pass of the
/// chain — `target = (k_tile, n_tile)` in [`tile_grid`] coordinates —
/// the whole-layer-offload shape of the WS campaign: the corrupted psum
/// column flows through the (fault-free, hence exactly linear) RTL
/// suffix passes, so the corruption reaches the layer output precisely
/// as the chained hardware execution would expose it.
pub fn tiled_matmul_ws_with<S: Injectable>(
    mesh: &mut S,
    a: MatView<i8>,
    b: MatView<i8>,
    d: MatView<i32>,
    plan: &FaultPlan,
    target: (usize, usize),
) -> Mat<i32> {
    let dim = mesh.dim();
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    let empty = FaultPlan::empty();
    let mut c = Mat::zeros(m, n);
    let mut psum: Mat<i32> = Mat::default();
    let mut next: Mat<i32> = Mat::default();
    let mut tj = 0;
    while tj < n {
        // the psum chain of column block tj starts at the bias column
        psum.reset(m, dim);
        let ncols = dim.min(n - tj);
        for r in 0..m {
            let row = psum.row_mut(r);
            for col in 0..ncols {
                row[col] = d.at(r, tj + col);
            }
        }
        let mut ti = 0;
        while ti < k {
            let armed = if (ti / dim, tj / dim) == target { plan } else { &empty };
            let a_panel = a.sub(0, ti, m, dim);
            let w_tile = b.sub(ti, tj, dim, dim);
            MatmulDriver::new(mesh).matmul_into(a_panel, w_tile, psum.view(), armed, &mut next);
            std::mem::swap(&mut psum, &mut next);
            ti += dim;
        }
        c.window_mut(0, tj, m, dim).splice_from(&psum);
        tj += dim;
    }
    c
}

/// Dataflow-generic tiled matmul: dispatches on the mesh's configured
/// dataflow ([`tiled_matmul_os`] / [`tiled_matmul_ws`]).
pub fn tiled_matmul<S: Injectable>(
    mesh: &mut S,
    a: MatView<i8>,
    b: MatView<i8>,
    d: MatView<i32>,
) -> Mat<i32> {
    match mesh.dataflow() {
        Dataflow::OutputStationary => tiled_matmul_os(mesh, a, b, d),
        Dataflow::WeightStationary => tiled_matmul_ws(mesh, a, b, d),
    }
}

/// Pure-software golden matmul (the oracle for all mesh tests; the same
/// arithmetic as the Pallas kernel's ref.py).
pub fn gold_matmul(a: MatView<i8>, b: MatView<i8>, d: MatView<i32>) -> Mat<i32> {
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    let mut c = Mat::zeros(m, n);
    for i in 0..m {
        for j in 0..n {
            let mut acc = d.at(i, j);
            for kk in 0..k {
                acc = acc.wrapping_add(a.at(i, kk) as i32 * b.at(kk, j) as i32);
            }
            c.set(i, j, acc);
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Dataflow;
    use crate::mesh::mesh::{Mesh, MeshSim};
    use crate::util::Rng;

    #[test]
    fn os_identity_matmul() {
        let dim = 4;
        let mut mesh = Mesh::new(dim, Dataflow::OutputStationary);
        let eye = Mat::from_fn(dim, dim, |r, c| (r == c) as i8);
        let b = Mat::from_fn(dim, dim, |r, c| (r * dim + c) as i8);
        let d = Mat::zeros(dim, dim);
        let c = MatmulDriver::new(&mut mesh).matmul(eye.view(), b.view(), d.view());
        let want = gold_matmul(eye.view(), b.view(), d.view());
        assert_eq!(c, want);
    }

    #[test]
    fn os_random_matmuls_match_gold() {
        let mut rng = Rng::new(1);
        for &(dim, k) in &[(2usize, 2usize), (4, 4), (4, 12), (8, 8), (8, 3), (3, 7)] {
            let mut mesh = Mesh::new(dim, Dataflow::OutputStationary);
            let a = rng.mat_i8(dim, k);
            let b = rng.mat_i8(k, dim);
            let d = rng.mat_i32(dim, dim, 1 << 12);
            let c = MatmulDriver::new(&mut mesh).matmul(a.view(), b.view(), d.view());
            assert_eq!(c, gold_matmul(a.view(), b.view(), d.view()), "dim={dim} k={k}");
        }
    }

    #[test]
    fn os_bias_only() {
        let dim = 4;
        let mut mesh = Mesh::new(dim, Dataflow::OutputStationary);
        let mut rng = Rng::new(2);
        let a = Mat::zeros(dim, 4);
        let b = Mat::zeros(4, dim);
        let d = rng.mat_i32(dim, dim, 1000);
        let c = MatmulDriver::new(&mut mesh).matmul(a.view(), b.view(), d.view());
        assert_eq!(c, d);
    }

    #[test]
    fn os_back_to_back_matmuls_are_independent() {
        let dim = 4;
        let mut mesh = Mesh::new(dim, Dataflow::OutputStationary);
        let mut rng = Rng::new(3);
        let a1 = rng.mat_i8(dim, 6);
        let b1 = rng.mat_i8(6, dim);
        let d1 = rng.mat_i32(dim, dim, 100);
        let c1a = MatmulDriver::new(&mut mesh).matmul(a1.view(), b1.view(), d1.view());
        let a2 = rng.mat_i8(dim, 5);
        let b2 = rng.mat_i8(5, dim);
        let _noise = MatmulDriver::new(&mut mesh).matmul(a2.view(), b2.view(), d1.view());
        let c1b = MatmulDriver::new(&mut mesh).matmul(a1.view(), b1.view(), d1.view());
        assert_eq!(c1a, c1b);
    }

    #[test]
    fn os_padded_window_operands_match_materialized() {
        // the zero-copy path: running a DIM-padded *window* of a small
        // operand must equal running the materialized padded tile
        let dim = 4;
        let mut mesh = Mesh::new(dim, Dataflow::OutputStationary);
        let mut rng = Rng::new(12);
        let a_small = rng.mat_i8(3, 5); // fewer rows than DIM
        let b_small = rng.mat_i8(5, 2); // fewer cols than DIM
        let d_small = rng.mat_i32(3, 2, 100);
        let a_win = a_small.window(0, 0, dim, 5);
        let b_win = b_small.window(0, 0, 5, dim);
        let d_win = d_small.window(0, 0, dim, dim);
        let via_window = MatmulDriver::new(&mut mesh).matmul(a_win, b_win, d_win);
        let (am, bm, dm) = (a_win.to_mat(), b_win.to_mat(), d_win.to_mat());
        let via_mat = MatmulDriver::new(&mut mesh).matmul(am.view(), bm.view(), dm.view());
        assert_eq!(via_window, via_mat);
        assert_eq!(via_window, gold_matmul(am.view(), bm.view(), dm.view()));
    }

    #[test]
    fn ws_random_matmuls_match_gold() {
        let mut rng = Rng::new(4);
        for &(dim, m) in &[(2usize, 2usize), (4, 4), (4, 10), (8, 8), (8, 1)] {
            let mut mesh = Mesh::new(dim, Dataflow::WeightStationary);
            let a = rng.mat_i8(m, dim);
            let w = rng.mat_i8(dim, dim);
            let d = rng.mat_i32(m, dim, 1 << 12);
            let c = MatmulDriver::new(&mut mesh).matmul(a.view(), w.view(), d.view());
            assert_eq!(c, gold_matmul(a.view(), w.view(), d.view()), "dim={dim} m={m}");
        }
    }

    #[test]
    fn tiled_matmul_matches_gold_on_awkward_shapes() {
        let mut rng = Rng::new(5);
        let mut mesh = Mesh::new(4, Dataflow::OutputStationary);
        for &(m, k, n) in &[(4usize, 4usize, 4usize), (8, 4, 8), (5, 7, 9), (1, 3, 2)] {
            let a = rng.mat_i8(m, k);
            let b = rng.mat_i8(k, n);
            let d = rng.mat_i32(m, n, 500);
            let c = tiled_matmul_os(&mut mesh, a.view(), b.view(), d.view());
            assert_eq!(c, gold_matmul(a.view(), b.view(), d.view()), "m={m} k={k} n={n}");
        }
    }

    #[test]
    fn tiled_matmul_ws_matches_gold_on_awkward_shapes() {
        // the WS chain (psum of pass ki feeds pass ki+1) must equal the
        // software gold for every padding case: ragged M, K and N
        let mut rng = Rng::new(50);
        let mut mesh = Mesh::new(4, Dataflow::WeightStationary);
        for &(m, k, n) in &[(4usize, 4usize, 4usize), (8, 4, 8), (5, 7, 9), (1, 3, 2), (13, 9, 5)]
        {
            let a = rng.mat_i8(m, k);
            let b = rng.mat_i8(k, n);
            let d = rng.mat_i32(m, n, 500);
            let c = tiled_matmul_ws(&mut mesh, a.view(), b.view(), d.view());
            assert_eq!(c, gold_matmul(a.view(), b.view(), d.view()), "m={m} k={k} n={n}");
        }
    }

    #[test]
    fn tiled_matmul_dispatches_on_mesh_dataflow() {
        let mut rng = Rng::new(51);
        let a = rng.mat_i8(6, 7);
        let b = rng.mat_i8(7, 5);
        let d = rng.mat_i32(6, 5, 100);
        let gold = gold_matmul(a.view(), b.view(), d.view());
        let mut os = Mesh::new(4, Dataflow::OutputStationary);
        assert_eq!(tiled_matmul(&mut os, a.view(), b.view(), d.view()), gold);
        let mut ws = Mesh::new(4, Dataflow::WeightStationary);
        assert_eq!(tiled_matmul(&mut ws, a.view(), b.view(), d.view()), gold);
    }

    #[test]
    fn ws_chain_fault_on_target_pass_corrupts_output() {
        use crate::mesh::signal::SignalKind;
        let dim = 4;
        let mut rng = Rng::new(52);
        let (m, k, n) = (6usize, 8usize, 8usize);
        let a = rng.mat_i8(m, k);
        let b = rng.mat_i8(k, n);
        let d = rng.mat_i32(m, n, 100);
        let mut mesh = Mesh::new(dim, Dataflow::WeightStationary);
        let golden = tiled_matmul_ws(&mut mesh, a.view(), b.view(), d.view());
        assert_eq!(golden, gold_matmul(a.view(), b.view(), d.view()));
        // a high Acc (psum pipeline) bit while the valid wave covers the
        // southern consumer of PE(1,1) — the corrupted psum is consumed
        // and drains; only column block 1 can be corrupted (the chain
        // never crosses column blocks). The wave reaches row 2 of lane 1
        // at preload + 1 (lane skew) + 2 (rows), i.e. preload + 3.
        let cyc = (2 * dim - 1) as u64 + 4;
        let plan = FaultPlan::single(Fault::new(1, 1, SignalKind::Acc, 30, cyc));
        let faulty =
            tiled_matmul_ws_with(&mut mesh, a.view(), b.view(), d.view(), &plan, (1, 1));
        assert_ne!(golden, faulty);
        for r in 0..m {
            for c in 0..dim {
                assert_eq!(faulty.at(r, c), golden.at(r, c), "column block 0 untouched");
            }
        }
    }

    #[test]
    fn cycle_model_and_tile_grid_dispatch_per_dataflow() {
        assert_eq!(
            matmul_cycles(Dataflow::OutputStationary, 8, 999, 16),
            os_matmul_cycles(8, 16),
            "OS streams K; M is irrelevant"
        );
        assert_eq!(
            matmul_cycles(Dataflow::WeightStationary, 8, 24, 999),
            ws_matmul_cycles(8, 24),
            "WS streams M; K is irrelevant"
        );
        assert_eq!(tile_grid(Dataflow::OutputStationary, 8, 100, 27, 16), (13, 2));
        assert_eq!(tile_grid(Dataflow::WeightStationary, 8, 100, 27, 16), (4, 2));
    }

    #[test]
    fn injected_fault_changes_output() {
        use crate::mesh::signal::SignalKind;
        let dim = 4;
        let mut mesh = Mesh::new(dim, Dataflow::OutputStationary);
        let mut rng = Rng::new(6);
        let a = rng.mat_i8(dim, dim);
        let b = rng.mat_i8(dim, dim);
        let d = Mat::zeros(dim, dim);
        let golden = MatmulDriver::new(&mut mesh).matmul(a.view(), b.view(), d.view());
        // Propag fault in the middle of the compute phase of PE(0,1).
        let cyc = (2 * dim - 1) as u64 + 3;
        let f = Fault::new(0, 1, SignalKind::Propag, 0, cyc);
        let faulty =
            MatmulDriver::new(&mut mesh).matmul_with_fault(a.view(), b.view(), d.view(), &f);
        assert_ne!(golden, faulty);
    }

    #[test]
    fn fault_outside_active_window_is_masked() {
        use crate::mesh::signal::SignalKind;
        let dim = 4;
        let mut mesh = Mesh::new(dim, Dataflow::OutputStationary);
        let mut rng = Rng::new(7);
        let a = rng.mat_i8(dim, dim);
        let b = rng.mat_i8(dim, dim);
        let d = Mat::zeros(dim, dim);
        let golden = MatmulDriver::new(&mut mesh).matmul(a.view(), b.view(), d.view());
        // A weight-path fault injected in the very first preload cycle:
        // the operand pipelines carry no live data yet, and the corrupted
        // stream element drains before compute => fully masked.
        let f = Fault::new(0, 3, SignalKind::Weight, 6, 0);
        let faulty =
            MatmulDriver::new(&mut mesh).matmul_with_fault(a.view(), b.view(), d.view(), &f);
        assert_eq!(golden, faulty);
    }

    #[test]
    fn zero_activation_masks_weight_fault() {
        use crate::mesh::signal::SignalKind;
        // All-zero activations: any weight-path corruption multiplies by
        // zero and never reaches the accumulators (the paper's Fig. 5b
        // masking mechanism).
        let dim = 4;
        let mut mesh = Mesh::new(dim, Dataflow::OutputStationary);
        let mut rng = Rng::new(8);
        let a = rng.mat_i8(dim, dim);
        let b = Mat::zeros(dim, dim);
        let d = rng.mat_i32(dim, dim, 100);
        let golden = MatmulDriver::new(&mut mesh).matmul(a.view(), b.view(), d.view());
        let cyc = (2 * dim - 1) as u64 + 2;
        let f = Fault::new(1, 1, SignalKind::Weight, 3, cyc);
        let faulty =
            MatmulDriver::new(&mut mesh).matmul_with_fault(a.view(), b.view(), d.view(), &f);
        assert_eq!(golden, faulty);
    }

    #[test]
    fn single_fault_plan_matches_legacy_fault_path() {
        // FaultPlan::single must be bit-identical to the pre-redesign
        // single-`Fault` argument — the compatibility contract of the
        // scenario-first seam.
        use crate::mesh::signal::SignalKind;
        let dim = 4;
        let mut mesh = Mesh::new(dim, Dataflow::OutputStationary);
        let mut rng = Rng::new(40);
        let a = rng.mat_i8(dim, 9);
        let b = rng.mat_i8(9, dim);
        let d = rng.mat_i32(dim, dim, 64);
        for kind in crate::mesh::signal::SignalKind::ALL {
            let f = Fault::new(1, 2, kind, 0, (2 * dim) as u64 + 1);
            let legacy = MatmulDriver::new(&mut mesh)
                .matmul_with_fault(a.view(), b.view(), d.view(), &f);
            let plan = MatmulDriver::new(&mut mesh).matmul_with_plan(
                a.view(),
                b.view(),
                d.view(),
                &FaultPlan::single(f),
            );
            assert_eq!(legacy, plan, "kind={kind}");
        }
        // empty plan == golden
        let golden = MatmulDriver::new(&mut mesh).matmul(a.view(), b.view(), d.view());
        let via_empty = MatmulDriver::new(&mut mesh).matmul_with_plan(
            a.view(),
            b.view(),
            d.view(),
            &FaultPlan::empty(),
        );
        assert_eq!(golden, via_empty);
        let sa = Fault::stuck_at(0, 1, SignalKind::Weight, 3, true, 0);
        assert_eq!(
            MatmulDriver::new(&mut mesh).matmul_with_fault(a.view(), b.view(), d.view(), &sa),
            MatmulDriver::new(&mut mesh).matmul_with_plan(
                a.view(),
                b.view(),
                d.view(),
                &FaultPlan::single(sa)
            ),
            "stuck-at through a plan"
        );
    }

    #[test]
    fn multi_fault_plan_fires_every_fault() {
        // a two-transient plan must differ from either single-fault run
        // when the faults hit disjoint accumulators
        use crate::mesh::signal::SignalKind;
        let dim = 4;
        let mut mesh = Mesh::new(dim, Dataflow::OutputStationary);
        let mut rng = Rng::new(41);
        let a = rng.mat_i8(dim, dim);
        let b = rng.mat_i8(dim, dim);
        let d = Mat::zeros(dim, dim);
        let cyc = (2 * dim) as u64 + 1;
        let f1 = Fault::new(0, 0, SignalKind::Acc, 30, cyc);
        let f2 = Fault::new(3, 3, SignalKind::Acc, 30, cyc + 2);
        let both = MatmulDriver::new(&mut mesh).matmul_with_plan(
            a.view(),
            b.view(),
            d.view(),
            &FaultPlan::new(vec![f1, f2]),
        );
        let only1 =
            MatmulDriver::new(&mut mesh).matmul_with_fault(a.view(), b.view(), d.view(), &f1);
        let only2 =
            MatmulDriver::new(&mut mesh).matmul_with_fault(a.view(), b.view(), d.view(), &f2);
        let golden = MatmulDriver::new(&mut mesh).matmul(a.view(), b.view(), d.view());
        assert_ne!(both, only1);
        assert_ne!(both, only2);
        // disjoint Acc flips compose: both corruptions present
        assert_ne!(both[(0, 0)], golden[(0, 0)]);
        assert_ne!(both[(3, 3)], golden[(3, 3)]);
    }

    #[test]
    fn cycle_counts_match_formula() {
        let dim = 8;
        let k = 16;
        let mut mesh = Mesh::new(dim, Dataflow::OutputStationary);
        let mut rng = Rng::new(9);
        let a = rng.mat_i8(dim, k);
        let b = rng.mat_i8(k, dim);
        let d = rng.mat_i32(dim, dim, 10);
        let stepped =
            MatmulDriver::new(&mut mesh).matmul_into(a.view(), b.view(), d.view(), &FaultPlan::empty(), &mut Mat::default());
        assert_eq!(stepped, os_matmul_cycles(dim, k));
        assert_eq!(mesh.cycle(), os_matmul_cycles(dim, k));
    }

    #[test]
    fn schedule_matches_cycle_formulas() {
        let mut rng = Rng::new(30);
        let (dim, k, m) = (4usize, 9usize, 6usize);
        let a = rng.mat_i8(dim, k);
        let b = rng.mat_i8(k, dim);
        let d = rng.mat_i32(dim, dim, 10);
        let s = Schedule::new(Dataflow::OutputStationary, dim, a.view(), b.view(), d.view());
        assert_eq!(s.total_cycles(), os_matmul_cycles(dim, k));
        assert_eq!(s.out_shape(), (dim, dim));
        let aw = rng.mat_i8(m, dim);
        let w = rng.mat_i8(dim, dim);
        let dw = rng.mat_i32(m, dim, 10);
        let s = Schedule::new(Dataflow::WeightStationary, dim, aw.view(), w.view(), dw.view());
        assert_eq!(s.total_cycles(), ws_matmul_cycles(dim, m));
        assert_eq!(s.out_shape(), (m, dim));
    }

    /// The scheduler indexability pin: filling inputs for cycles in any
    /// order produces the exact inputs the sequential program feeds.
    #[test]
    fn schedule_fill_is_order_independent() {
        let mut rng = Rng::new(31);
        let dim = 4;
        let k = 7;
        let a = rng.mat_i8(dim, k);
        let b = rng.mat_i8(k, dim);
        let d = rng.mat_i32(dim, dim, 50);
        let s = Schedule::new(Dataflow::OutputStationary, dim, a.view(), b.view(), d.view());
        let total = s.total_cycles();
        // sequential reference
        let mut seq = Vec::new();
        let mut inp = MeshInputs::idle(dim);
        for t in 0..total {
            s.fill(t, &mut inp);
            seq.push(inp.clone());
        }
        // random access, reusing one buffer
        for &t in &[total - 1, 0, total / 2, 3, total - 2, 1] {
            s.fill(t, &mut inp);
            let r = &seq[t as usize];
            assert_eq!(inp.west_a, r.west_a, "t={t}");
            assert_eq!(inp.north_b, r.north_b, "t={t}");
            assert_eq!(inp.north_d, r.north_d, "t={t}");
            assert_eq!(inp.north_propag, r.north_propag, "t={t}");
            assert_eq!(inp.north_valid, r.north_valid, "t={t}");
        }
    }

    /// Resume at EVERY cycle of the program: `advance_golden` +
    /// `matmul_resumed` must reproduce the full faulty run bit-exactly
    /// for any first-fault cycle, both dataflows — including resume
    /// points inside the OS flush window (mid-drain priming).
    #[test]
    fn resumed_matmul_matches_full_at_every_cycle() {
        use crate::mesh::signal::SignalKind;
        let mut rng = Rng::new(32);
        for dataflow in [Dataflow::OutputStationary, Dataflow::WeightStationary] {
            let dim = 4;
            let (a, b, d) = match dataflow {
                Dataflow::OutputStationary => {
                    (rng.mat_i8(dim, 6), rng.mat_i8(6, dim), rng.mat_i32(dim, dim, 100))
                }
                Dataflow::WeightStationary => {
                    (rng.mat_i8(5, dim), rng.mat_i8(dim, dim), rng.mat_i32(5, dim, 100))
                }
            };
            let mut mesh = Mesh::new(dim, dataflow);
            let total = Schedule::new(dataflow, dim, a.view(), b.view(), d.view()).total_cycles();
            let mut cur = CycleCursor::new();
            let mut scratch = DriverScratch::new(dim);
            let mut out = Mat::default();
            for tf in 0..total {
                // a control fault stresses the drain, a storage fault the
                // state prime — alternate between them
                let f = if tf % 2 == 0 {
                    Fault::new(1, 2, SignalKind::Propag, 0, tf)
                } else {
                    Fault::new(2, 1, SignalKind::Acc, 27, tf)
                };
                let plan = FaultPlan::single(f);
                let full =
                    MatmulDriver::new(&mut mesh).matmul_with_plan(a.view(), b.view(), d.view(), &plan);
                let mut drv = MatmulDriver::new(&mut mesh);
                let adv =
                    drv.advance_golden(a.view(), b.view(), d.view(), (0, 0), tf, &mut cur, &mut scratch);
                assert!(adv <= tf, "golden advance re-stepped shared prefix");
                let stepped =
                    drv.matmul_resumed(a.view(), b.view(), d.view(), &plan, &cur, &mut out, &mut scratch);
                assert_eq!(stepped, total - tf, "{dataflow} tf={tf}: replay length");
                assert_eq!(out, full, "{dataflow} tf={tf}: resumed != full");
            }
        }
    }

    /// Lockstep chunk vs per-trial oracle: a lane batch of heterogeneous
    /// plans (control, storage, multi-fault, stuck-at) stepped once in
    /// lockstep must reproduce each trial's full faulty run bit-exactly,
    /// for both dataflows, paying the suffix once. A second, smaller
    /// chunk on the same [`LaneMesh`] pins the reshape path and cursor
    /// reuse at a later resume point.
    #[test]
    fn lockstep_resumed_matches_per_trial_resume() {
        use crate::mesh::lane::LaneMesh;
        use crate::mesh::signal::SignalKind;
        let mut rng = Rng::new(35);
        for dataflow in [Dataflow::OutputStationary, Dataflow::WeightStationary] {
            let dim = 4;
            let (a, b, d) = match dataflow {
                Dataflow::OutputStationary => {
                    (rng.mat_i8(dim, 6), rng.mat_i8(6, dim), rng.mat_i32(dim, dim, 100))
                }
                Dataflow::WeightStationary => {
                    (rng.mat_i8(5, dim), rng.mat_i8(dim, dim), rng.mat_i32(5, dim, 100))
                }
            };
            let mut mesh = Mesh::new(dim, dataflow);
            let total = Schedule::new(dataflow, dim, a.view(), b.view(), d.view()).total_cycles();
            let mut lane_mesh = LaneMesh::new(dim, dataflow);
            let mut cur = CycleCursor::new();
            let mut scratch = DriverScratch::new(dim);
            let mut outs = Vec::new();
            for (chunk_idx, plans) in [
                vec![
                    FaultPlan::single(Fault::new(1, 2, SignalKind::Propag, 0, 2)),
                    FaultPlan::single(Fault::new(2, 1, SignalKind::Acc, 27, 9)),
                    FaultPlan::new(vec![
                        Fault::new(0, 0, SignalKind::Act, 3, 7),
                        Fault::new(3, 3, SignalKind::DReg, 11, 15),
                    ]),
                    FaultPlan::single(Fault::stuck_at(1, 1, SignalKind::Valid, 0, true, 5)),
                ],
                // second chunk: fewer lanes, later first-effect cycles
                vec![
                    FaultPlan::single(Fault::new(0, 1, SignalKind::Weight, 2, 12)),
                    FaultPlan::single(Fault::new(2, 2, SignalKind::Acc, 5, 14)),
                ],
            ]
            .into_iter()
            .enumerate()
            {
                let mut fulls = Vec::new();
                for plan in &plans {
                    fulls.push(MatmulDriver::new(&mut mesh).matmul_with_plan(
                        a.view(),
                        b.view(),
                        d.view(),
                        plan,
                    ));
                }
                let min_fe = plans.iter().map(|p| p.first_cycle()).min().unwrap();
                MatmulDriver::new(&mut mesh).advance_golden(
                    a.view(),
                    b.view(),
                    d.view(),
                    (0, 0),
                    min_fe,
                    &mut cur,
                    &mut scratch,
                );
                let plan_refs: Vec<&FaultPlan> = plans.iter().collect();
                let stepped = lockstep_resumed(
                    &mut lane_mesh,
                    a.view(),
                    b.view(),
                    d.view(),
                    &plan_refs,
                    &cur,
                    &mut outs,
                    &mut scratch,
                );
                assert_eq!(
                    stepped,
                    total - min_fe,
                    "{dataflow} chunk {chunk_idx}: suffix paid once"
                );
                for (l, full) in fulls.iter().enumerate() {
                    assert_eq!(&outs[l], full, "{dataflow} chunk {chunk_idx} lane {l}");
                }
            }
        }
    }

    /// Packed chunk vs per-trial oracle: lane groups on DIFFERENT
    /// operands (tiles), each with its own golden cursor advanced to its
    /// own min-first-effect cycle, stepped once side by side — every
    /// lane must reproduce its trial's full faulty run bit-exactly, both
    /// dataflows, and the chunk pays only the LONGEST group suffix
    /// (strictly fewer cycles than the two lockstep chunks would).
    #[test]
    fn packed_resumed_matches_per_trial_resume() {
        use crate::mesh::lane::LaneMesh;
        use crate::mesh::signal::SignalKind;
        let mut rng = Rng::new(36);
        for dataflow in [Dataflow::OutputStationary, Dataflow::WeightStationary] {
            let dim = 4;
            let mk_ops = |rng: &mut Rng| match dataflow {
                Dataflow::OutputStationary => {
                    (rng.mat_i8(dim, 6), rng.mat_i8(6, dim), rng.mat_i32(dim, dim, 100))
                }
                Dataflow::WeightStationary => {
                    (rng.mat_i8(5, dim), rng.mat_i8(dim, dim), rng.mat_i32(5, dim, 100))
                }
            };
            let (a0, b0, d0) = mk_ops(&mut rng);
            let (a1, b1, d1) = mk_ops(&mut rng);
            let plans0 = vec![
                FaultPlan::single(Fault::new(1, 2, SignalKind::Propag, 0, 2)),
                FaultPlan::single(Fault::new(2, 1, SignalKind::Acc, 27, 9)),
                FaultPlan::single(Fault::stuck_at(1, 1, SignalKind::Valid, 0, true, 5)),
            ];
            let plans1 = vec![
                FaultPlan::single(Fault::new(0, 1, SignalKind::Weight, 2, 12)),
                FaultPlan::new(vec![
                    Fault::new(0, 0, SignalKind::Act, 3, 7),
                    Fault::new(3, 3, SignalKind::DReg, 11, 15),
                ]),
            ];
            let mut mesh = Mesh::new(dim, dataflow);
            // per-trial full-run oracles, group order then lane order
            let mut fulls = Vec::new();
            for plan in &plans0 {
                fulls.push(MatmulDriver::new(&mut mesh).matmul_with_plan(
                    a0.view(),
                    b0.view(),
                    d0.view(),
                    plan,
                ));
            }
            for plan in &plans1 {
                fulls.push(MatmulDriver::new(&mut mesh).matmul_with_plan(
                    a1.view(),
                    b1.view(),
                    d1.view(),
                    plan,
                ));
            }
            let mut scratch = DriverScratch::new(dim);
            let mut cur0 = CycleCursor::new();
            let mut cur1 = CycleCursor::new();
            let fe0 = plans0.iter().map(|p| p.first_cycle()).min().unwrap();
            let fe1 = plans1.iter().map(|p| p.first_cycle()).min().unwrap();
            MatmulDriver::new(&mut mesh)
                .advance_golden(a0.view(), b0.view(), d0.view(), (0, 0), fe0, &mut cur0, &mut scratch);
            MatmulDriver::new(&mut mesh)
                .advance_golden(a1.view(), b1.view(), d1.view(), (0, 1), fe1, &mut cur1, &mut scratch);
            let total = Schedule::new(dataflow, dim, a0.view(), b0.view(), d0.view()).total_cycles();
            let (span0, span1) = (total - fe0, total - fe1);
            let groups = vec![
                LaneGroup {
                    a: a0.view(),
                    b: b0.view(),
                    d: d0.view(),
                    plans: plans0.iter().collect(),
                    cur: &cur0,
                },
                LaneGroup {
                    a: a1.view(),
                    b: b1.view(),
                    d: d1.view(),
                    plans: plans1.iter().collect(),
                    cur: &cur1,
                },
            ];
            let mut lane_mesh = LaneMesh::new(dim, dataflow);
            let mut outs = Vec::new();
            let (stepped, filled) =
                packed_lockstep_resumed(&mut lane_mesh, &groups, &mut outs, &mut scratch);
            assert_eq!(stepped, span0.max(span1), "{dataflow}: longest suffix paid once");
            assert!(
                stepped < span0 + span1,
                "{dataflow}: packing must beat back-to-back lockstep"
            );
            assert_eq!(
                filled,
                3 * span0 + 2 * span1,
                "{dataflow}: each group's lanes are live for exactly its span"
            );
            for (l, full) in fulls.iter().enumerate() {
                assert_eq!(&outs[l], full, "{dataflow} lane {l}");
            }
        }
    }

    /// A resume point in the OS flush window must prime the collector
    /// mid-drain: rows already out come from the golden prefix, the rest
    /// from the replay.
    #[test]
    fn mid_flush_resume_primes_the_drain() {
        use crate::mesh::signal::SignalKind;
        let dim = 4;
        let k = 5;
        let mut rng = Rng::new(33);
        let a = rng.mat_i8(dim, k);
        let b = rng.mat_i8(k, dim);
        let d = rng.mat_i32(dim, dim, 80);
        let total = os_matmul_cycles(dim, k);
        let flush_start = total - (2 * dim - 1) as u64;
        // late-flush propag flip: only the last drain rows can differ
        let tf = flush_start + dim as u64;
        let f = Fault::new(0, 0, SignalKind::Propag, 0, tf);
        let plan = FaultPlan::single(f);
        let mut mesh = Mesh::new(dim, Dataflow::OutputStationary);
        let full =
            MatmulDriver::new(&mut mesh).matmul_with_plan(a.view(), b.view(), d.view(), &plan);
        let mut cur = CycleCursor::new();
        let mut scratch = DriverScratch::new(dim);
        let mut out = Mat::default();
        let mut drv = MatmulDriver::new(&mut mesh);
        drv.advance_golden(a.view(), b.view(), d.view(), (0, 0), tf, &mut cur, &mut scratch);
        assert!(cur.cycle() > flush_start, "resume point must sit mid-flush");
        let stepped =
            drv.matmul_resumed(a.view(), b.view(), d.view(), &plan, &cur, &mut out, &mut scratch);
        assert_eq!(out, full);
        assert!(stepped < (2 * dim) as u64, "only the drain tail replays");
    }

    /// The golden cursor advances monotonically within a tile: a batch
    /// sorted by fault cycle pays each golden cycle exactly once.
    #[test]
    fn golden_cursor_advances_incrementally() {
        let dim = 4;
        let mut rng = Rng::new(34);
        let a = rng.mat_i8(dim, dim);
        let b = rng.mat_i8(dim, dim);
        let d = rng.mat_i32(dim, dim, 10);
        let mut mesh = Mesh::new(dim, Dataflow::OutputStationary);
        let mut cur = CycleCursor::new();
        let mut scratch = DriverScratch::new(dim);
        let mut drv = MatmulDriver::new(&mut mesh);
        let mut golden_cycles = 0;
        for target in [3u64, 3, 10, 20] {
            golden_cycles +=
                drv.advance_golden(a.view(), b.view(), d.view(), (0, 0), target, &mut cur, &mut scratch);
        }
        assert_eq!(golden_cycles, 20, "each golden cycle stepped exactly once");
        assert_eq!(cur.cycle(), 20);
        // targets past the schedule end clamp to it (dim=4, k=4: 24)
        golden_cycles += drv.advance_golden(
            a.view(),
            b.view(),
            d.view(),
            (0, 0),
            u64::MAX,
            &mut cur,
            &mut scratch,
        );
        assert_eq!(golden_cycles, 24);
        assert_eq!(cur.cycle(), os_matmul_cycles(4, 4));
        // a new tile key restarts the trajectory
        golden_cycles +=
            drv.advance_golden(a.view(), b.view(), d.view(), (1, 0), 5, &mut cur, &mut scratch);
        assert_eq!(golden_cycles, 29);
        assert_eq!(cur.cycle(), 5);
    }
}
