//! Matmul drivers: the "simulation wrapper" that drives operand streams
//! through the mesh, performs `C = A . B + D`, and applies at most ONE
//! compare-and-branch per cycle for fault injection — the ENFOR-SA
//! alternative to per-assignment instrumentation.
//!
//! Output-stationary schedule (the paper's configuration):
//!
//! 1. **Preload** (2*DIM-1 cycles): propagate asserted at the north edge
//!    for DIM cycles while the bias matrix D staircases down the
//!    accumulator chain (rows fed in reverse).
//! 2. **Compute** (K + 2*DIM-2 cycles): weights stream west→east with
//!    row skew, activations north→south with column skew, `valid`
//!    travelling with the activation stream.
//! 3. **Flush** (2*DIM-1 cycles): propagate again; results exit the
//!    south edge bottom-row-first and are un-staircased by the
//!    [`FlushCollector`].
//!
//! Weight-stationary schedule: W staircases in through the d-chain, then
//! activation columns stream west→east while psums (initialised with D
//! rows at the north edge) flow down and exit south every cycle.

use super::adapters::{FlushCollector, SkewFeeder};
use super::inject::{Fault, Injectable};
use super::mesh::{MeshInputs, StepOutput};
use crate::config::Dataflow;

/// Matrix aliases used throughout the mesh layer (row-major vec-of-rows).
pub type MatI8 = Vec<Vec<i8>>;
pub type MatI32 = Vec<Vec<i32>>;

/// Cycle count of one OS matmul on a DIM mesh with inner dimension K.
pub fn os_matmul_cycles(dim: usize, k: usize) -> u64 {
    ((2 * dim - 1) + (k + 2 * dim - 2) + (2 * dim - 1)) as u64
}

/// Cycle count of one WS matmul streaming M rows through a DIM mesh.
pub fn ws_matmul_cycles(dim: usize, m: usize) -> u64 {
    ((2 * dim - 1) + (m + 2 * dim - 2)) as u64
}

/// Drives one matmul through a mesh backend.
pub struct MatmulDriver<'m, S: Injectable> {
    mesh: &'m mut S,
}

impl<'m, S: Injectable> MatmulDriver<'m, S> {
    pub fn new(mesh: &'m mut S) -> Self {
        MatmulDriver { mesh }
    }

    /// Golden (fault-free) matmul.
    pub fn matmul(&mut self, a: &MatI8, b: &MatI8, d: &MatI32) -> MatI32 {
        self.run(a, b, d, None)
    }

    /// Matmul with a single transient fault injected at `fault.cycle`
    /// (relative to the start of this matmul).
    pub fn matmul_with_fault(
        &mut self,
        a: &MatI8,
        b: &MatI8,
        d: &MatI32,
        fault: &Fault,
    ) -> MatI32 {
        self.run(a, b, d, Some(fault))
    }

    fn run(&mut self, a: &MatI8, b: &MatI8, d: &MatI32, fault: Option<&Fault>) -> MatI32 {
        if let Some(f) = fault {
            self.mesh.arm(f);
        }
        let c = match self.mesh.dataflow() {
            Dataflow::OutputStationary => self.run_os(a, b, d, fault),
            Dataflow::WeightStationary => self.run_ws(a, b, d, fault),
        };
        if fault.is_some() {
            self.mesh.disarm();
        }
        c
    }

    /// One compare per cycle: the entire injection overhead of ENFOR-SA.
    /// (Transient faults fire once; stuck-at faults re-apply the forcing
    /// every cycle from their onset — still wrapper-only.)
    #[inline]
    fn maybe_inject(&mut self, fault: Option<&Fault>, t: u64, inp: &mut MeshInputs) {
        if let Some(f) = fault {
            if f.fires_at(t) {
                self.mesh.inject_now(f, inp);
            }
        }
    }

    /// Output-stationary: A is DIM x K (weights), B is K x DIM
    /// (activations), D and C are DIM x DIM.
    fn run_os(&mut self, a: &MatI8, b: &MatI8, d: &MatI32, fault: Option<&Fault>) -> MatI32 {
        let dim = self.mesh.dim();
        let k = if a.is_empty() { 0 } else { a[0].len() };
        assert_eq!(a.len(), dim, "A must have DIM rows");
        assert!(a.iter().all(|r| r.len() == k), "ragged A");
        assert_eq!(b.len(), k, "B must have K rows");
        assert!(b.iter().all(|r| r.len() == dim), "B must have DIM cols");
        assert_eq!(d.len(), dim, "D must be DIM x DIM");

        self.mesh.reset();
        let mut inp = MeshInputs::idle(dim);
        let mut out = StepOutput::new(dim);
        let mut t: u64 = 0;

        // Phase 1: preload D (reversed rows down the accumulator chain).
        for p in 0..(2 * dim - 1) {
            inp.clear();
            if p < dim {
                for c in 0..dim {
                    inp.north_propag[c] = true;
                    inp.north_d[c] = d[dim - 1 - p][c];
                }
            }
            self.maybe_inject(fault, t, &mut inp);
            self.mesh.step(&inp, &mut out);
            t += 1;
        }

        // Phase 2: compute. Row skew on A, column skew on B; valid rides
        // with the activation stream.
        let a_feed: SkewFeeder<i8> = SkewFeeder::from_rows(a);
        let b_feed: SkewFeeder<i8> = SkewFeeder::from_cols(b);
        let compute_len = k + 2 * dim - 2;
        for tau in 0..compute_len {
            inp.clear();
            for r in 0..dim {
                inp.west_a[r] = a_feed.at(r, tau);
            }
            for c in 0..dim {
                inp.north_b[c] = b_feed.at(c, tau);
                inp.north_valid[c] = b_feed.live(c, tau);
            }
            self.maybe_inject(fault, t, &mut inp);
            self.mesh.step(&inp, &mut out);
            t += 1;
        }

        // Phase 3: flush C through the south edge.
        let mut collector = FlushCollector::new(dim);
        for p in 0..(2 * dim - 1) {
            inp.clear();
            out.clear();
            if p < dim {
                for c in 0..dim {
                    inp.north_propag[c] = true;
                }
            }
            self.maybe_inject(fault, t, &mut inp);
            self.mesh.step(&inp, &mut out);
            collector.absorb(&out.south_c);
            t += 1;
        }
        // A control-signal fault during the flush window can legitimately
        // disturb the drain (extra or missing propagate pulses) — the real
        // drain FSM also just latches whatever arrives in its fixed
        // window. Only fault-free runs must drain exactly DIM rows.
        debug_assert!(
            fault.is_some() || collector.complete(),
            "fault-free flush did not drain DIM rows"
        );
        debug_assert_eq!(t, os_matmul_cycles(dim, k));
        collector.c
    }

    /// Weight-stationary: B here is the stationary DIM x DIM weight tile,
    /// A is M x DIM (activations streaming), D is M x DIM (bias rows).
    /// Returns C = A . B + D (M x DIM).
    fn run_ws(&mut self, a: &MatI8, w: &MatI8, d: &MatI32, fault: Option<&Fault>) -> MatI32 {
        let dim = self.mesh.dim();
        let m = a.len();
        assert!(a.iter().all(|r| r.len() == dim), "A must have DIM cols");
        assert_eq!(w.len(), dim, "W must be DIM x DIM");
        assert_eq!(d.len(), m, "D must have M rows");

        self.mesh.reset();
        let mut inp = MeshInputs::idle(dim);
        let mut out = StepOutput::new(dim);
        let mut t: u64 = 0;

        // Phase 1: preload W through the d-chain (reversed rows).
        for p in 0..(2 * dim - 1) {
            inp.clear();
            if p < dim {
                for c in 0..dim {
                    inp.north_propag[c] = true;
                    inp.north_d[c] = w[dim - 1 - p][c] as i32;
                }
            }
            self.maybe_inject(fault, t, &mut inp);
            self.mesh.step(&inp, &mut out);
            t += 1;
        }

        // Phase 2: stream activations (columns of A with row skew) and
        // psum bias rows (columns of D with column skew at the top).
        let a_feed: SkewFeeder<i8> = SkewFeeder::from_cols(a);
        let d_feed: SkewFeeder<i32> = SkewFeeder::from_cols(d);
        let compute_len = m + 2 * dim - 2;
        let mut c_out = vec![vec![0i32; dim]; m];
        let mut taken = vec![0usize; dim];
        for tau in 0..compute_len {
            inp.clear();
            out.clear();
            for r in 0..dim {
                inp.west_a[r] = a_feed.at(r, tau);
            }
            for cc in 0..dim {
                inp.north_d[cc] = d_feed.at(cc, tau);
                inp.north_valid[cc] = d_feed.live(cc, tau);
            }
            self.maybe_inject(fault, t, &mut inp);
            self.mesh.step(&inp, &mut out);
            for cc in 0..dim {
                if let Some(ps) = out.south_psum[cc] {
                    if taken[cc] < m {
                        c_out[taken[cc]][cc] = ps;
                        taken[cc] += 1;
                    }
                }
            }
            t += 1;
        }
        debug_assert!(
            fault.is_some() || taken.iter().all(|&x| x == m),
            "fault-free WS drain incomplete"
        );
        c_out
    }
}

/// Reference tiled matmul over the mesh: decomposes an arbitrary
/// (M x K) . (K x N) into DIM x DIM output tiles, each computed by one
/// OS pass with the full K stream. Used by tests and by the whole-layer
/// RTL offload ablation (DESIGN.md D3).
pub fn tiled_matmul_os<S: Injectable>(
    mesh: &mut S,
    a: &MatI8,
    b: &MatI8,
    d: &MatI32,
) -> MatI32 {
    let dim = mesh.dim();
    let m = a.len();
    let k = if m == 0 { 0 } else { a[0].len() };
    let n = if b.is_empty() { 0 } else { b[0].len() };
    let mut c = vec![vec![0i32; n]; m];
    let mut ti = 0;
    while ti < m {
        let mut tj = 0;
        while tj < n {
            // Extract (and zero-pad) the operand tiles.
            let a_tile: MatI8 = (0..dim)
                .map(|r| {
                    if ti + r < m {
                        a[ti + r].clone()
                    } else {
                        vec![0; k]
                    }
                })
                .collect();
            let b_tile: MatI8 = (0..k)
                .map(|r| {
                    (0..dim)
                        .map(|cc| if tj + cc < n { b[r][tj + cc] } else { 0 })
                        .collect()
                })
                .collect();
            let d_tile: MatI32 = (0..dim)
                .map(|r| {
                    (0..dim)
                        .map(|cc| {
                            if ti + r < m && tj + cc < n {
                                d[ti + r][tj + cc]
                            } else {
                                0
                            }
                        })
                        .collect()
                })
                .collect();
            let c_tile = MatmulDriver::new(mesh).matmul(&a_tile, &b_tile, &d_tile);
            for r in 0..dim {
                for cc in 0..dim {
                    if ti + r < m && tj + cc < n {
                        c[ti + r][tj + cc] = c_tile[r][cc];
                    }
                }
            }
            tj += dim;
        }
        ti += dim;
    }
    c
}

/// Pure-software golden matmul (the oracle for all mesh tests; the same
/// arithmetic as the Pallas kernel's ref.py).
pub fn gold_matmul(a: &MatI8, b: &MatI8, d: &MatI32) -> MatI32 {
    let m = a.len();
    let k = if m == 0 { 0 } else { a[0].len() };
    let n = if b.is_empty() { 0 } else { b[0].len() };
    let mut c = vec![vec![0i32; n]; m];
    for i in 0..m {
        for j in 0..n {
            let mut acc = d[i][j];
            for kk in 0..k {
                acc = acc.wrapping_add(a[i][kk] as i32 * b[kk][j] as i32);
            }
            c[i][j] = acc;
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Dataflow;
    use crate::mesh::mesh::Mesh;
    use crate::util::Rng;

    #[test]
    fn os_identity_matmul() {
        let dim = 4;
        let mut mesh = Mesh::new(dim, Dataflow::OutputStationary);
        let eye: MatI8 = (0..dim)
            .map(|r| (0..dim).map(|c| (r == c) as i8).collect())
            .collect();
        let b: MatI8 = (0..dim)
            .map(|r| (0..dim).map(|c| (r * dim + c) as i8).collect())
            .collect();
        let d = vec![vec![0i32; dim]; dim];
        let c = MatmulDriver::new(&mut mesh).matmul(&eye, &b, &d);
        let want = gold_matmul(&eye, &b, &d);
        assert_eq!(c, want);
    }

    #[test]
    fn os_random_matmuls_match_gold() {
        let mut rng = Rng::new(1);
        for &(dim, k) in &[(2usize, 2usize), (4, 4), (4, 12), (8, 8), (8, 3), (3, 7)] {
            let mut mesh = Mesh::new(dim, Dataflow::OutputStationary);
            let a = rng.mat_i8(dim, k);
            let b = rng.mat_i8(k, dim);
            let d = rng.mat_i32(dim, dim, 1 << 12);
            let c = MatmulDriver::new(&mut mesh).matmul(&a, &b, &d);
            assert_eq!(c, gold_matmul(&a, &b, &d), "dim={dim} k={k}");
        }
    }

    #[test]
    fn os_bias_only() {
        let dim = 4;
        let mut mesh = Mesh::new(dim, Dataflow::OutputStationary);
        let mut rng = Rng::new(2);
        let a = vec![vec![0i8; 4]; dim];
        let b = vec![vec![0i8; dim]; 4];
        let d = rng.mat_i32(dim, dim, 1000);
        let c = MatmulDriver::new(&mut mesh).matmul(&a, &b, &d);
        assert_eq!(c, d);
    }

    #[test]
    fn os_back_to_back_matmuls_are_independent() {
        let dim = 4;
        let mut mesh = Mesh::new(dim, Dataflow::OutputStationary);
        let mut rng = Rng::new(3);
        let a1 = rng.mat_i8(dim, 6);
        let b1 = rng.mat_i8(6, dim);
        let d1 = rng.mat_i32(dim, dim, 100);
        let c1a = MatmulDriver::new(&mut mesh).matmul(&a1, &b1, &d1);
        let a2 = rng.mat_i8(dim, 5);
        let b2 = rng.mat_i8(5, dim);
        let _noise = MatmulDriver::new(&mut mesh).matmul(&a2, &b2, &d1);
        let c1b = MatmulDriver::new(&mut mesh).matmul(&a1, &b1, &d1);
        assert_eq!(c1a, c1b);
    }

    #[test]
    fn ws_random_matmuls_match_gold() {
        let mut rng = Rng::new(4);
        for &(dim, m) in &[(2usize, 2usize), (4, 4), (4, 10), (8, 8), (8, 1)] {
            let mut mesh = Mesh::new(dim, Dataflow::WeightStationary);
            let a = rng.mat_i8(m, dim);
            let w = rng.mat_i8(dim, dim);
            let d = rng.mat_i32(m, dim, 1 << 12);
            let c = MatmulDriver::new(&mut mesh).matmul(&a, &w, &d);
            assert_eq!(c, gold_matmul(&a, &w, &d), "dim={dim} m={m}");
        }
    }

    #[test]
    fn tiled_matmul_matches_gold_on_awkward_shapes() {
        let mut rng = Rng::new(5);
        let mut mesh = Mesh::new(4, Dataflow::OutputStationary);
        for &(m, k, n) in &[(4usize, 4usize, 4usize), (8, 4, 8), (5, 7, 9), (1, 3, 2)] {
            let a = rng.mat_i8(m, k);
            let b = rng.mat_i8(k, n);
            let d = rng.mat_i32(m, n, 500);
            let c = tiled_matmul_os(&mut mesh, &a, &b, &d);
            assert_eq!(c, gold_matmul(&a, &b, &d), "m={m} k={k} n={n}");
        }
    }

    #[test]
    fn injected_fault_changes_output() {
        use crate::mesh::signal::SignalKind;
        let dim = 4;
        let mut mesh = Mesh::new(dim, Dataflow::OutputStationary);
        let mut rng = Rng::new(6);
        let a = rng.mat_i8(dim, dim);
        let b = rng.mat_i8(dim, dim);
        let d = vec![vec![0i32; dim]; dim];
        let golden = MatmulDriver::new(&mut mesh).matmul(&a, &b, &d);
        // Propag fault in the middle of the compute phase of PE(0,1).
        let cyc = (2 * dim - 1) as u64 + 3;
        let f = Fault::new(0, 1, SignalKind::Propag, 0, cyc);
        let faulty = MatmulDriver::new(&mut mesh).matmul_with_fault(&a, &b, &d, &f);
        assert_ne!(golden, faulty);
    }

    #[test]
    fn fault_outside_active_window_is_masked() {
        use crate::mesh::signal::SignalKind;
        let dim = 4;
        let mut mesh = Mesh::new(dim, Dataflow::OutputStationary);
        let mut rng = Rng::new(7);
        let a = rng.mat_i8(dim, dim);
        let b = rng.mat_i8(dim, dim);
        let d = vec![vec![0i32; dim]; dim];
        let golden = MatmulDriver::new(&mut mesh).matmul(&a, &b, &d);
        // A weight-path fault injected in the very first preload cycle:
        // the operand pipelines carry no live data yet, and the corrupted
        // stream element drains before compute => fully masked.
        let f = Fault::new(0, 3, SignalKind::Weight, 6, 0);
        let faulty = MatmulDriver::new(&mut mesh).matmul_with_fault(&a, &b, &d, &f);
        assert_eq!(golden, faulty);
    }

    #[test]
    fn zero_activation_masks_weight_fault() {
        use crate::mesh::signal::SignalKind;
        // All-zero activations: any weight-path corruption multiplies by
        // zero and never reaches the accumulators (the paper's Fig. 5b
        // masking mechanism).
        let dim = 4;
        let mut mesh = Mesh::new(dim, Dataflow::OutputStationary);
        let mut rng = Rng::new(8);
        let a = rng.mat_i8(dim, dim);
        let b = vec![vec![0i8; dim]; dim];
        let d = rng.mat_i32(dim, dim, 100);
        let golden = MatmulDriver::new(&mut mesh).matmul(&a, &b, &d);
        let cyc = (2 * dim - 1) as u64 + 2;
        let f = Fault::new(1, 1, SignalKind::Weight, 3, cyc);
        let faulty = MatmulDriver::new(&mut mesh).matmul_with_fault(&a, &b, &d, &f);
        assert_eq!(golden, faulty);
    }

    #[test]
    fn cycle_counts_match_formula() {
        let dim = 8;
        let k = 16;
        let mut mesh = Mesh::new(dim, Dataflow::OutputStationary);
        let mut rng = Rng::new(9);
        let a = rng.mat_i8(dim, k);
        let b = rng.mat_i8(k, dim);
        let d = rng.mat_i32(dim, dim, 10);
        MatmulDriver::new(&mut mesh).matmul(&a, &b, &d);
        assert_eq!(mesh.cycle, os_matmul_cycles(dim, k));
    }
}
