//! HDFIT-style instrumented mesh — the state-of-the-art baseline the
//! paper compares against (Omland et al., "API-based hardware fault
//! simulation for DNN accelerators").
//!
//! HDFIT instruments **every combinational and sequential assignment** in
//! the HDL with a fault hook; the hook executes on every assignment of
//! every cycle whether or not a fault is active (the paper: "an 8x8 mesh
//! has 632 assignments, all instrumented"). This model reproduces that
//! cost structure exactly: the same verilated-equivalent step as
//! [`super::mesh::Mesh`], but each wire evaluation and register write is
//! routed through an inline hook that tests the armed fault (compare +
//! bookkeeping, mirroring HDFIT's generated instrumentation). Our OS PE
//! has 12 instrumented assignments (6 wires + 6 registers), i.e. 768
//! hooks per cycle for an 8x8 mesh — the same order as the paper's 632.
//!
//! Functionally the instrumented mesh is bit-identical to the plain mesh
//! (the accuracy-validation experiment in §IV-B and
//! `rust/tests/validate_vs_hdfit.rs` depend on it); only its *cost per
//! cycle* differs.

use super::inject::{Fault, FaultPlan, Injectable, Persistence};
use super::mesh::{Mesh, MeshInputs, MeshSim, MeshState, StepOutput};
use super::signal::SignalKind;
use crate::config::Dataflow;
use crate::util::bits::{flip_i32, flip_i8};

/// Instrumentation slot within a PE (one per HDL assignment).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u32)]
pub enum Slot {
    WireA = 0,
    WireB = 1,
    WireP = 2,
    WireV = 3,
    WireDIn = 4,
    /// The northern PE's `out_c` wire (OS) / psum wire (WS).
    WireOutCNorth = 5,
    RegAcc = 6,
    RegD = 7,
    RegA = 8,
    RegB = 9,
    RegPropag = 10,
    RegValid = 11,
    /// The stationary weight register — assigned only by the WS step
    /// (the OS PE has no such register, so OS cycles never execute this
    /// hook).
    RegW = 12,
}

/// Distinct instrumentation slot ids per PE — the `sig_id` stride.
pub const SLOTS_PER_PE: u32 = 13;
/// Hooks an OS cycle executes per PE (12 of the 13 slots: no stationary
/// weight register) — the same order as the paper's 632 assignments for
/// an 8x8 mesh.
pub const OS_HOOKS_PER_PE: u32 = 12;
/// Hooks a WS cycle executes per PE (all 13 slots: the WS PE re-latches
/// its stationary weight register every cycle, verilator-style).
pub const WS_HOOKS_PER_PE: u32 = 13;

#[inline]
fn sig_id(dim: usize, r: usize, c: usize, slot: Slot) -> u32 {
    ((r * dim + c) as u32) * SLOTS_PER_PE + slot as u32
}

/// An HDFIT fault: a (signal id, bit, cycle) triple checked by the hooks.
#[derive(Clone, Copy, Debug)]
pub struct HdfitFault {
    pub sig_id: u32,
    pub bit: u8,
    pub cycle: u64,
}

/// The instrumented mesh. Both dataflows are instrumented (the paper
/// benchmarks HDFIT in the OS configuration; the WS step exists so
/// dataflow-generic campaigns can run the same scenario set on the
/// instrumented backend).
pub struct InstrumentedMesh {
    pub base: Mesh,
    /// Armed hook-faults — one per planned fault (HDFIT configures its
    /// injections per run); kept as a flat small list so each hook is a
    /// short compare chain, like HDFIT's generated code. Single-SEU
    /// plans keep the historical one-compare shape.
    armed: Vec<HdfitFault>,
    /// Total hook invocations — the per-assignment bookkeeping HDFIT pays.
    pub hook_calls: u64,
    /// Fallbacks the hooks cannot express: Acc/DReg faults at cycle 0
    /// (no previous assignment exists to instrument) and stuck-at
    /// forcings — applied as direct pre-step flips by the wrapper.
    pending_direct: Vec<Fault>,
}

impl InstrumentedMesh {
    pub fn new(dim: usize) -> Self {
        Self::with_dataflow(dim, Dataflow::OutputStationary)
    }

    /// Instrumented mesh for an explicit dataflow (the campaign
    /// executor's constructor — the dataflow comes from `MeshConfig`).
    pub fn with_dataflow(dim: usize, dataflow: Dataflow) -> Self {
        InstrumentedMesh {
            base: Mesh::new(dim, dataflow),
            armed: Vec::new(),
            hook_calls: 0,
            pending_direct: Vec::new(),
        }
    }

    /// Translate an ENFOR-SA fault into the equivalent HDFIT fault.
    ///
    /// Wire-path faults map to the corresponding wire hook at the same
    /// cycle. Storage faults map to the register's *assignment* in the
    /// previous cycle (an SEU latched at the end of cycle t-1 is first
    /// observed at cycle t): `Acc`/`DReg` on both dataflows, plus the
    /// stationary `Weight` register under WS — where `Act` instead rides
    /// the horizontal a-path wire (the logical-operand remap of
    /// `mesh::inject`).
    pub fn translate(&self, f: &Fault) -> Option<HdfitFault> {
        if f.persistence != super::inject::Persistence::Transient {
            // stuck-at faults are applied through the wrapper path
            // (HDFIT would instrument them statically; for the accuracy
            // comparison only transients matter — the paper's model)
            return None;
        }
        let dim = self.base.dim();
        let ws = self.base.dataflow() == Dataflow::WeightStationary;
        let (r, c) = (f.addr.row, f.addr.col);
        let (slot, cycle) = match f.addr.kind {
            SignalKind::Weight if ws => {
                if f.cycle == 0 {
                    return None; // no previous assignment to instrument
                }
                (Slot::RegW, f.cycle - 1)
            }
            SignalKind::Weight => (Slot::WireA, f.cycle),
            SignalKind::Act if ws => (Slot::WireA, f.cycle),
            SignalKind::Act => (Slot::WireB, f.cycle),
            SignalKind::Propag => (Slot::WireP, f.cycle),
            SignalKind::Valid => (Slot::WireV, f.cycle),
            SignalKind::Acc => {
                if f.cycle == 0 {
                    return None; // handled by pending_direct
                }
                (Slot::RegAcc, f.cycle - 1)
            }
            SignalKind::DReg => {
                if f.cycle == 0 {
                    return None;
                }
                (Slot::RegD, f.cycle - 1)
            }
            // Control-path faults live in the driver's schedule machinery
            // (tile sequencer / drain FSM), not in any PE assignment —
            // HDFIT and ENFOR-SA share the driver, so both backends apply
            // them through `apply_control` at the fault's own cycle.
            SignalKind::Ctrl => return None,
        };
        Some(HdfitFault {
            sig_id: sig_id(dim, r, c, slot),
            bit: f.bit,
            cycle,
        })
    }

    // ---- the HDFIT hooks ----
    //
    // HDFIT's instrumentation compiles to an inline "does this
    // assignment match the armed fault" test plus a counter — cheap per
    // assignment, but executed on EVERY assignment of EVERY cycle. We
    // model exactly that: an inline compare chain (cycle, id) plus the
    // bookkeeping increment. The paper measures the aggregate cost of
    // this pattern at ~2-3x over the uninstrumented model (Tab. III).

    #[inline(always)]
    fn hook8(&mut self, id: u32, v: i8) -> i8 {
        self.hook_calls = self.hook_calls.wrapping_add(1);
        let mut v = v;
        // every armed fault is tested (and every match applied — an MBU
        // arms several hooks on the same assignment), mirroring HDFIT's
        // generated compare chain
        for f in &self.armed {
            if f.cycle == self.base.cycle && f.sig_id == id {
                v = flip_i8(v, f.bit);
            }
        }
        v
    }

    #[inline(always)]
    fn hook32(&mut self, id: u32, v: i32) -> i32 {
        self.hook_calls = self.hook_calls.wrapping_add(1);
        let mut v = v;
        for f in &self.armed {
            if f.cycle == self.base.cycle && f.sig_id == id {
                v = flip_i32(v, f.bit);
            }
        }
        v
    }

    #[inline(always)]
    fn hookb(&mut self, id: u32, v: bool) -> bool {
        self.hook_calls = self.hook_calls.wrapping_add(1);
        let mut v = v;
        for f in &self.armed {
            if f.cycle == self.base.cycle && f.sig_id == id {
                v = !v;
            }
        }
        v
    }

    /// Fully instrumented OS step: identical dataflow to `Mesh::step_os`,
    /// with every assignment routed through a hook.
    fn step_os_instrumented(&mut self, inp: &MeshInputs, out: &mut StepOutput) {
        let dim = self.base.dim();
        for r in (0..dim).rev() {
            for c in (0..dim).rev() {
                let i = r * dim + c;
                let raw_a = if c == 0 {
                    inp.west_a[r]
                } else {
                    self.base.reg_a[i - 1]
                };
                let a_in = self.hook8(sig_id(dim, r, c, Slot::WireA), raw_a);
                let raw_b = if r == 0 {
                    inp.north_b[c]
                } else {
                    self.base.reg_b[i - dim]
                };
                let b_in = self.hook8(sig_id(dim, r, c, Slot::WireB), raw_b);
                let raw_p = if r == 0 {
                    inp.north_propag[c]
                } else {
                    self.base.reg_propag[i - dim]
                };
                let p_in = self.hookb(sig_id(dim, r, c, Slot::WireP), raw_p);
                let raw_v = if r == 0 {
                    inp.north_valid[c]
                } else {
                    self.base.reg_valid[i - dim]
                };
                let v_in = self.hookb(sig_id(dim, r, c, Slot::WireV), raw_v);
                let raw_d = if r == 0 {
                    inp.north_d[c]
                } else {
                    self.base.reg_d[i]
                };
                let d_in = self.hook32(sig_id(dim, r, c, Slot::WireDIn), raw_d);
                let raw_outc_n = if r == 0 {
                    inp.north_d[c]
                } else {
                    self.base.acc[i - dim]
                };
                let outc_n = self.hook32(sig_id(dim, r, c, Slot::WireOutCNorth), raw_outc_n);

                // sequential assignments (each one instrumented, like
                // verilated `reg = hook(expr)` rewrites):
                let acc_next = if p_in {
                    if r == dim - 1 {
                        out.set_south_c(c, self.base.acc[i]);
                    }
                    d_in
                } else if v_in {
                    self.base.acc[i].wrapping_add(a_in as i32 * b_in as i32)
                } else {
                    self.base.acc[i]
                };
                self.base.acc[i] = self.hook32(sig_id(dim, r, c, Slot::RegAcc), acc_next);
                self.base.reg_d[i] = self.hook32(sig_id(dim, r, c, Slot::RegD), outc_n);
                self.base.reg_a[i] = self.hook8(sig_id(dim, r, c, Slot::RegA), a_in);
                self.base.reg_b[i] = self.hook8(sig_id(dim, r, c, Slot::RegB), b_in);
                self.base.reg_propag[i] =
                    self.hookb(sig_id(dim, r, c, Slot::RegPropag), p_in);
                self.base.reg_valid[i] =
                    self.hookb(sig_id(dim, r, c, Slot::RegValid), v_in);
            }
        }
        self.base.cycle += 1;
    }

    /// Fully instrumented WS step: identical dataflow to `Mesh::step_ws`,
    /// with every assignment routed through a hook — including the
    /// stationary weight register, which the verilated model re-latches
    /// every cycle (that per-cycle assignment is what lets a `RegW` hook
    /// at cycle t-1 express a persistent weight SEU observed from t).
    fn step_ws_instrumented(&mut self, inp: &MeshInputs, out: &mut StepOutput) {
        let dim = self.base.dim();
        for r in (0..dim).rev() {
            for c in (0..dim).rev() {
                let i = r * dim + c;
                let raw_a = if c == 0 {
                    inp.west_a[r]
                } else {
                    self.base.reg_a[i - 1]
                };
                let a_in = self.hook8(sig_id(dim, r, c, Slot::WireA), raw_a);
                let raw_b = if r == 0 {
                    inp.north_b[c]
                } else {
                    self.base.reg_b[i - dim]
                };
                let b_in = self.hook8(sig_id(dim, r, c, Slot::WireB), raw_b);
                let raw_p = if r == 0 {
                    inp.north_propag[c]
                } else {
                    self.base.reg_propag[i - dim]
                };
                let p_in = self.hookb(sig_id(dim, r, c, Slot::WireP), raw_p);
                let raw_v = if r == 0 {
                    inp.north_valid[c]
                } else {
                    self.base.reg_valid[i - dim]
                };
                let v_in = self.hookb(sig_id(dim, r, c, Slot::WireV), raw_v);
                // d-chain input: the boundary port on the north row, the
                // PE's own inter-PE register inside (as in Mesh::step_ws)
                let raw_d = if r == 0 {
                    inp.north_d[c]
                } else {
                    self.base.reg_d[i]
                };
                let d_in = self.hook32(sig_id(dim, r, c, Slot::WireDIn), raw_d);
                // psum input: the northern accumulator, pre-edge (rows
                // walk bottom-up, so row r-1 is not yet rewritten)
                let raw_ps = if r == 0 {
                    inp.north_d[c]
                } else {
                    self.base.acc[i - dim]
                };
                let ps_in = self.hook32(sig_id(dim, r, c, Slot::WireOutCNorth), raw_ps);

                let w_old = self.base.reg_w[i];
                let ps = ps_in.wrapping_add(w_old as i32 * a_in as i32);
                if r == dim - 1 {
                    if p_in {
                        out.set_south_c(c, w_old as i32);
                    } else if v_in {
                        out.set_south_psum(c, ps);
                    }
                }

                // sequential assignments (each one instrumented):
                let w_next = if p_in { (d_in & 0xff) as i8 } else { w_old };
                self.base.reg_w[i] = self.hook8(sig_id(dim, r, c, Slot::RegW), w_next);
                let acc_next = if p_in {
                    d_in
                } else if v_in {
                    ps
                } else {
                    self.base.acc[i]
                };
                self.base.acc[i] = self.hook32(sig_id(dim, r, c, Slot::RegAcc), acc_next);
                let d_next = if r == 0 { d_in } else { ps_in };
                self.base.reg_d[i] = self.hook32(sig_id(dim, r, c, Slot::RegD), d_next);
                self.base.reg_a[i] = self.hook8(sig_id(dim, r, c, Slot::RegA), a_in);
                self.base.reg_b[i] = self.hook8(sig_id(dim, r, c, Slot::RegB), b_in);
                self.base.reg_propag[i] =
                    self.hookb(sig_id(dim, r, c, Slot::RegPropag), p_in);
                self.base.reg_valid[i] =
                    self.hookb(sig_id(dim, r, c, Slot::RegValid), v_in);
            }
        }
        self.base.cycle += 1;
    }
}

impl MeshSim for InstrumentedMesh {
    fn dim(&self) -> usize {
        self.base.dim()
    }

    fn dataflow(&self) -> Dataflow {
        self.base.dataflow()
    }

    fn cycle(&self) -> u64 {
        self.base.cycle
    }

    fn step(&mut self, inp: &MeshInputs, out: &mut StepOutput) {
        match self.base.dataflow() {
            Dataflow::OutputStationary => self.step_os_instrumented(inp, out),
            Dataflow::WeightStationary => self.step_ws_instrumented(inp, out),
        }
    }

    fn reset(&mut self) {
        self.base.reset();
    }

    fn acc_at(&self, row: usize, col: usize) -> i32 {
        self.base.acc_at(row, col)
    }

    // The hooks are stateless between cycles (armed faults are run
    // configuration, not architectural state), so snapshotting the
    // instrumented mesh is exactly snapshotting the base mesh.
    fn save_state(&self, state: &mut MeshState) {
        self.base.save_state(state);
    }

    fn restore_state(&mut self, state: &MeshState) {
        self.base.restore_state(state);
    }
}

impl Injectable for InstrumentedMesh {
    fn arm(&mut self, plan: &FaultPlan) {
        self.armed.clear();
        self.pending_direct.clear();
        for f in plan.faults() {
            match self.translate(f) {
                Some(h) => self.armed.push(h),
                None => self.pending_direct.push(*f),
            }
        }
    }

    fn inject_now(&mut self, fault: &Fault, inp: &mut MeshInputs) {
        // HDFIT applies transient faults through the always-on hooks;
        // the wrapper handles the cycle-0 storage fallback and the
        // stuck-at extension (re-applied every firing cycle). The cursor
        // hands us the exact due fault, so matching is by value.
        if let Some(pos) = self.pending_direct.iter().position(|pf| pf == fault) {
            let pf = self.pending_direct[pos];
            if pf.fires_at(self.base.cycle) {
                super::inject::apply_enforsa(&mut self.base, inp, &pf);
                if pf.persistence == Persistence::Transient {
                    self.pending_direct.remove(pos);
                }
            }
        }
    }

    fn disarm(&mut self) {
        self.armed.clear();
        self.pending_direct.clear();
    }

    /// HDFIT's storage hooks instrument the *assignment* of a register,
    /// which happens one cycle before the ENFOR-SA onset (`translate`
    /// maps `Acc`/`DReg` at cycle `t` to the `RegAcc`/`RegD` hook at
    /// `t - 1`), so a cycle-resume trial must restore one cycle earlier
    /// than the plan's onset for such faults. Wrapper-applied faults
    /// (cycle-0 storage, stuck-at) first act at their own onset.
    fn first_effect_cycle(&self, plan: &FaultPlan) -> u64 {
        plan.faults()
            .iter()
            .map(|f| match self.translate(f) {
                Some(h) => h.cycle,
                None => f.cycle,
            })
            .min()
            .unwrap_or(u64::MAX)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::driver::{gold_matmul, MatmulDriver};
    use crate::util::Rng;

    #[test]
    fn instrumented_mesh_matches_gold() {
        let mut rng = Rng::new(21);
        for &(dim, k) in &[(2usize, 2usize), (4, 4), (4, 9), (8, 8)] {
            let mut mesh = InstrumentedMesh::new(dim);
            let a = rng.mat_i8(dim, k);
            let b = rng.mat_i8(k, dim);
            let d = rng.mat_i32(dim, dim, 1 << 10);
            let c = MatmulDriver::new(&mut mesh).matmul(a.view(), b.view(), d.view());
            assert_eq!(c, gold_matmul(a.view(), b.view(), d.view()), "dim={dim} k={k}");
        }
    }

    #[test]
    fn hooks_fire_on_every_assignment() {
        let dim = 4;
        let mut mesh = InstrumentedMesh::new(dim);
        let inp = MeshInputs::idle(dim);
        let mut out = StepOutput::new(dim);
        mesh.step(&inp, &mut out);
        assert_eq!(
            mesh.hook_calls,
            (dim * dim) as u64 * OS_HOOKS_PER_PE as u64,
            "12 hooks per PE per OS cycle"
        );
        let mut ws = InstrumentedMesh::with_dataflow(dim, Dataflow::WeightStationary);
        ws.step(&inp, &mut out);
        assert_eq!(
            ws.hook_calls,
            (dim * dim) as u64 * WS_HOOKS_PER_PE as u64,
            "13 hooks per PE per WS cycle (the stationary weight register)"
        );
    }

    #[test]
    fn assignment_count_matches_paper_order() {
        // Paper: 8x8 mesh => 632 instrumented assignments. Ours: 768 OS.
        let mesh = InstrumentedMesh::new(8);
        let per_cycle = (mesh.dim() * mesh.dim()) as u64 * OS_HOOKS_PER_PE as u64;
        assert_eq!(per_cycle, 768);
    }

    #[test]
    fn translate_maps_wire_and_storage_faults() {
        let mesh = InstrumentedMesh::new(8);
        let f = Fault::new(2, 3, SignalKind::Weight, 1, 40);
        let h = mesh.translate(&f).unwrap();
        assert_eq!(h.cycle, 40);
        assert_eq!(h.sig_id % SLOTS_PER_PE, Slot::WireA as u32);
        let f = Fault::new(2, 3, SignalKind::Acc, 9, 40);
        let h = mesh.translate(&f).unwrap();
        assert_eq!(h.cycle, 39, "storage SEU latched the cycle before");
        assert_eq!(h.sig_id % SLOTS_PER_PE, Slot::RegAcc as u32);
        let f0 = Fault::new(2, 3, SignalKind::Acc, 9, 0);
        assert!(mesh.translate(&f0).is_none());
    }

    #[test]
    fn ws_instrumented_mesh_matches_gold() {
        let mut rng = Rng::new(23);
        for &(dim, m) in &[(2usize, 2usize), (4, 4), (4, 10), (8, 8), (8, 1)] {
            let mut mesh = InstrumentedMesh::with_dataflow(dim, Dataflow::WeightStationary);
            let a = rng.mat_i8(m, dim);
            let w = rng.mat_i8(dim, dim);
            let d = rng.mat_i32(m, dim, 1 << 10);
            let c = MatmulDriver::new(&mut mesh).matmul(a.view(), w.view(), d.view());
            assert_eq!(c, gold_matmul(a.view(), w.view(), d.view()), "dim={dim} m={m}");
        }
    }

    #[test]
    fn ws_translate_maps_weight_to_the_stationary_register() {
        let mesh = InstrumentedMesh::with_dataflow(8, Dataflow::WeightStationary);
        // WS Weight = the stationary register: assignment hook at t-1
        let f = Fault::new(2, 3, SignalKind::Weight, 1, 40);
        let h = mesh.translate(&f).unwrap();
        assert_eq!(h.cycle, 39, "stationary weight SEU latched the cycle before");
        assert_eq!(h.sig_id % SLOTS_PER_PE, Slot::RegW as u32);
        // ... with the cycle-0 fallback to the wrapper path
        assert!(mesh.translate(&Fault::new(2, 3, SignalKind::Weight, 1, 0)).is_none());
        // WS Act rides the horizontal a-path wire at the onset cycle
        let f = Fault::new(2, 3, SignalKind::Act, 5, 40);
        let h = mesh.translate(&f).unwrap();
        assert_eq!(h.cycle, 40);
        assert_eq!(h.sig_id % SLOTS_PER_PE, Slot::WireA as u32);
        // first_effect_cycle follows the shifted hook
        let plan = FaultPlan::single(Fault::new(1, 1, SignalKind::Weight, 0, 17));
        assert_eq!(mesh.first_effect_cycle(&plan), 16);
    }

    /// The accuracy-validation invariant extended to WS: for every
    /// signal kind and a sweep of cycles, the instrumented WS mesh must
    /// reproduce the ENFOR-SA wrapper's faulty outputs bit-exactly.
    #[test]
    fn ws_instrumented_matches_enforsa_under_faults() {
        let dim = 4;
        let m = 6;
        let mut rng = Rng::new(24);
        let a = rng.mat_i8(m, dim);
        let w = rng.mat_i8(dim, dim);
        let d = rng.mat_i32(m, dim, 300);
        let total = crate::mesh::driver::ws_matmul_cycles(dim, m);
        let mut plain = Mesh::new(dim, Dataflow::WeightStationary);
        let mut inst = InstrumentedMesh::with_dataflow(dim, Dataflow::WeightStationary);
        for kind in SignalKind::ALL {
            for cycle in 0..total {
                let f = Fault::new(
                    (cycle as usize) % dim,
                    (cycle as usize / dim) % dim,
                    kind,
                    (cycle % kind.width() as u64) as u8,
                    cycle,
                );
                let c1 = MatmulDriver::new(&mut plain)
                    .matmul_with_fault(a.view(), w.view(), d.view(), &f);
                let c2 = MatmulDriver::new(&mut inst)
                    .matmul_with_fault(a.view(), w.view(), d.view(), &f);
                assert_eq!(c1, c2, "kind={kind} cycle={cycle}");
            }
        }
    }

    #[test]
    fn injected_fault_changes_output_via_hooks() {
        let dim = 4;
        let mut rng = Rng::new(22);
        let a = rng.mat_i8(dim, dim);
        let b = rng.mat_i8(dim, dim);
        let d = crate::mat::Mat::zeros(dim, dim);
        let mut mesh = InstrumentedMesh::new(dim);
        let golden = MatmulDriver::new(&mut mesh).matmul(a.view(), b.view(), d.view());
        let cyc = (2 * dim - 1) as u64 + 2;
        let f = Fault::new(0, 0, SignalKind::Act, 6, cyc);
        let faulty =
            MatmulDriver::new(&mut mesh).matmul_with_fault(a.view(), b.view(), d.view(), &f);
        assert_ne!(golden, faulty);
        // disarm happened: a clean rerun matches golden again
        let clean = MatmulDriver::new(&mut mesh).matmul(a.view(), b.view(), d.view());
        assert_eq!(clean, golden);
    }
}
