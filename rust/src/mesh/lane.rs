//! Trial-lockstep lane-batched mesh (PR 6 tentpole).
//!
//! A [`LaneMesh`] steps LANES independent trials of the SAME tile matmul
//! through one register-accurate mesh pass. The site-resume invariant
//! (PR 2) makes this sound: every trial of a `SiteBatch` shares its
//! operands and checkpoint, so the `Schedule` edge streams are identical
//! across trials — only the injected faults differ, and those touch at
//! most a handful of lane-local registers per firing cycle.
//!
//! Layout: per-PE state is **lane-contiguous** (structure-of-arrays with
//! the lane index innermost) — scalar flat index `x` of [`super::Mesh`]
//! maps to `x * lanes + lane` here. The lockstep kernels transliterate
//! the scalar `step_os`/`step_ws` bodies with an innermost branch-free
//! loop over lanes (select ladders instead of lane-dependent control
//! flow), which is the shape LLVM auto-vectorizes. Only the south-edge
//! drain strip is branchy, and each lane owns its own
//! [`StepOutput`]/drain counters there.
//!
//! Feeding: one `Schedule::fill` per cycle per **lane group** produces
//! shared [`MeshInputs`]; [`LaneMesh::fill_group`] broadcasts the edge
//! wires into that group's lane sub-stripes so a lane's [`LaneCursor`]
//! can corrupt its own copy (edge-wire faults live exactly one cycle,
//! mirroring the scalar path where `fill`'s leading `clear()` rebuilds
//! the shared inputs). Same-tile lockstep is the one-group special case
//! ([`LaneMesh::begin_cycle`]). `north_d` is striped per lane like the
//! other edges — packed groups carry different preload streams — but
//! remains a non-target of injection (see `apply_enforsa`: no arm reads
//! or writes `inp.north_d`).
//!
//! The step kernels are the shared fixed-width row kernels of
//! [`super::kernel`]: one element-wise call per mesh row over the
//! `dim * lanes` SoA stripe, blocked over `kernel::LANE_BLOCK` so the
//! hot loop is fixed-trip-count and branch-free — retired lanes of a
//! packed chunk keep stepping on stale edge stripes (their outputs are
//! never drained) instead of adding per-lane control flow.

use super::inject::{apply_enforsa_lane, Fault, FaultPlan, Persistence};
use super::kernel;
use super::mesh::{MeshInputs, MeshState, StepOutput};
use crate::config::Dataflow;

/// Broadcast one scalar register file into lanes `[lane0, lane0 + n)`
/// of its SoA twin, leaving the other lanes untouched.
fn spread_group<T: Copy>(dst: &mut [T], src: &[T], lanes: usize, lane0: usize, n: usize) {
    debug_assert!(lane0 + n <= lanes && dst.len() == src.len() * lanes);
    for (i, &v) in src.iter().enumerate() {
        dst[i * lanes + lane0..i * lanes + lane0 + n].fill(v);
    }
}

/// Broadcast one scalar register file into every lane of its SoA twin.
fn spread<T: Copy>(dst: &mut [T], src: &[T], lanes: usize) {
    spread_group(dst, src, lanes, 0, lanes);
}

/// Lane-batched systolic mesh: LANES trials' register state side by
/// side, stepped in lockstep by [`LaneMesh::step`].
#[derive(Clone, Debug)]
pub struct LaneMesh {
    dim: usize,
    lanes: usize,
    dataflow: Dataflow,
    cycle: u64,
    // SoA register files, `[dim * dim * lanes]`, lane index innermost.
    pub(crate) reg_a: Vec<i8>,
    pub(crate) reg_b: Vec<i8>,
    pub(crate) acc: Vec<i32>,
    pub(crate) reg_d: Vec<i32>,
    pub(crate) reg_propag: Vec<bool>,
    pub(crate) reg_valid: Vec<bool>,
    pub(crate) reg_w: Vec<i8>,
    // Per-lane edge stripes `[dim * lanes]`, rebuilt every cycle by
    // `begin_cycle` (so an edge-wire fault lives one cycle, like the
    // scalar path's shared `MeshInputs` rebuilt by `Schedule::fill`).
    pub(crate) west_a: Vec<i8>,
    pub(crate) north_b: Vec<i8>,
    pub(crate) north_propag: Vec<bool>,
    pub(crate) north_valid: Vec<bool>,
    /// Per-lane preload stream `[dim * lanes]` — striped so packed lane
    /// groups can carry different operands; never an injection target.
    north_d: Vec<i32>,
    /// SHIFTED pre-edge a-row `[dim * lanes]`: the west stripe, then the
    /// western neighbour's pre-edge `reg_a` lanes (Verilator
    /// inverted-assignment-order semantics, as in the scalar kernels).
    scratch_a: Vec<i8>,
    /// Pre-edge bottom-row `acc` lanes (OS south_c capture source).
    scratch_c: Vec<i32>,
    /// Pre-edge bottom-row `reg_w` lanes (WS south_c capture source).
    scratch_w: Vec<i8>,
    /// Per-lane south-edge drain strip.
    pub(crate) step_outs: Vec<StepOutput>,
    /// Per-lane drain counters, primed from the cursor per chunk.
    pub(crate) takens: Vec<Vec<usize>>,
}

impl LaneMesh {
    /// An empty (zero-lane) mesh; [`LaneMesh::reshape`] sizes it per
    /// chunk.
    pub fn new(dim: usize, dataflow: Dataflow) -> Self {
        LaneMesh {
            dim,
            lanes: 0,
            dataflow,
            cycle: 0,
            reg_a: Vec::new(),
            reg_b: Vec::new(),
            acc: Vec::new(),
            reg_d: Vec::new(),
            reg_propag: Vec::new(),
            reg_valid: Vec::new(),
            reg_w: Vec::new(),
            west_a: Vec::new(),
            north_b: Vec::new(),
            north_propag: Vec::new(),
            north_valid: Vec::new(),
            north_d: Vec::new(),
            scratch_a: Vec::new(),
            scratch_c: Vec::new(),
            scratch_w: Vec::new(),
            step_outs: Vec::new(),
            takens: Vec::new(),
        }
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn lanes(&self) -> usize {
        self.lanes
    }

    pub fn dataflow(&self) -> Dataflow {
        self.dataflow
    }

    pub fn cycle(&self) -> u64 {
        self.cycle
    }

    /// Lane `lane`'s accumulator at PE (r, c) — test/debug peek.
    pub fn acc_at(&self, lane: usize, r: usize, c: usize) -> i32 {
        self.acc[(r * self.dim + c) * self.lanes + lane]
    }

    /// Resize to `lanes` lanes, reusing allocations when unchanged.
    /// Contents are left arbitrary — `broadcast` (registers),
    /// `begin_cycle` (edges) and the caller (drain counters) overwrite
    /// everything a chunk reads.
    pub fn reshape(&mut self, lanes: usize) {
        assert!(lanes > 0, "a lockstep chunk needs at least one lane");
        if self.lanes == lanes {
            return;
        }
        self.lanes = lanes;
        let dim = self.dim;
        let pe = dim * dim * lanes;
        let edge = dim * lanes;
        self.reg_a.resize(pe, 0);
        self.reg_b.resize(pe, 0);
        self.acc.resize(pe, 0);
        self.reg_d.resize(pe, 0);
        self.reg_propag.resize(pe, false);
        self.reg_valid.resize(pe, false);
        self.reg_w.resize(pe, 0);
        self.west_a.resize(edge, 0);
        self.north_b.resize(edge, 0);
        self.north_propag.resize(edge, false);
        self.north_valid.resize(edge, false);
        self.north_d.resize(edge, 0);
        self.scratch_a.resize(edge, 0);
        self.scratch_c.resize(edge, 0);
        self.scratch_w.resize(edge, 0);
        self.step_outs.resize_with(lanes, || StepOutput::new(dim));
        self.takens.resize_with(lanes, Vec::new);
    }

    /// Restore every lane from one golden [`MeshState`] snapshot — the
    /// lockstep analogue of `Mesh::restore_state`, replicating each
    /// scalar register across the lane stripe.
    pub fn broadcast(&mut self, state: &MeshState) {
        self.cycle = state.cycle;
        let lanes = self.lanes;
        self.broadcast_group(0, lanes, state);
    }

    /// Restore lanes `[lane0, lane0 + n)` from one golden snapshot — the
    /// per-group restore of a packed chunk. The mesh cycle counter is
    /// NOT touched: packed groups start at different golden cycles, so
    /// the packed driver tracks each group's local cycle itself.
    pub fn broadcast_group(&mut self, lane0: usize, n: usize, state: &MeshState) {
        assert_eq!(
            state.acc.len(),
            self.dim * self.dim,
            "snapshot taken on a differently-dimensioned mesh"
        );
        assert!(lane0 + n <= self.lanes, "lane group out of range");
        let lanes = self.lanes;
        spread_group(&mut self.reg_a, &state.reg_a, lanes, lane0, n);
        spread_group(&mut self.reg_b, &state.reg_b, lanes, lane0, n);
        spread_group(&mut self.acc, &state.acc, lanes, lane0, n);
        spread_group(&mut self.reg_d, &state.reg_d, lanes, lane0, n);
        spread_group(&mut self.reg_propag, &state.reg_propag, lanes, lane0, n);
        spread_group(&mut self.reg_valid, &state.reg_valid, lanes, lane0, n);
        spread_group(&mut self.reg_w, &state.reg_w, lanes, lane0, n);
    }

    /// Broadcast this cycle's shared edge wires into the per-lane
    /// stripes and clear the drain strips. Called once per cycle with
    /// the single `Schedule::fill` result that feeds ALL lanes (the
    /// one-group special case of a packed cycle).
    pub fn begin_cycle(&mut self, inp: &MeshInputs) {
        self.clear_outputs();
        let lanes = self.lanes;
        self.fill_group(0, lanes, inp);
    }

    /// Clear every lane's drain strip — once per (global) cycle of a
    /// packed chunk, before the per-group edge fills.
    pub fn clear_outputs(&mut self) {
        for out in &mut self.step_outs {
            out.clear();
        }
    }

    /// Broadcast one group's `Schedule::fill` result into the edge
    /// stripes of lanes `[lane0, lane0 + n)`. Retired groups simply skip
    /// their fill: their lanes keep stepping on stale edges (branch-free
    /// in the kernels) and their outputs are never drained.
    pub fn fill_group(&mut self, lane0: usize, n: usize, inp: &MeshInputs) {
        debug_assert_eq!(inp.west_a.len(), self.dim);
        debug_assert!(lane0 + n <= self.lanes, "lane group out of range");
        let lanes = self.lanes;
        spread_group(&mut self.west_a, &inp.west_a, lanes, lane0, n);
        spread_group(&mut self.north_b, &inp.north_b, lanes, lane0, n);
        spread_group(&mut self.north_propag, &inp.north_propag, lanes, lane0, n);
        spread_group(&mut self.north_valid, &inp.north_valid, lanes, lane0, n);
        spread_group(&mut self.north_d, &inp.north_d, lanes, lane0, n);
    }

    /// Advance every lane one cycle in lockstep.
    pub fn step(&mut self) {
        match self.dataflow {
            Dataflow::OutputStationary => self.step_os(),
            Dataflow::WeightStationary => self.step_ws(),
        }
        self.cycle += 1;
    }

    /// Lockstep transliteration of the scalar `Mesh::step_os` through
    /// the shared [`kernel::os_row`]: same most-downstream-first row
    /// order, the a-chain through the shifted pre-edge `scratch_a`, the
    /// whole `dim * lanes` SoA row as one fixed-width element-wise call.
    fn step_os(&mut self) {
        let dim = self.dim;
        let lanes = self.lanes;
        let w = dim * lanes;
        for r in (0..dim).rev() {
            let row = r * dim * lanes;
            // shifted pre-edge a-row: the west stripe, then the western
            // neighbour cell's pre-edge reg_a lanes
            self.scratch_a[..lanes]
                .copy_from_slice(&self.west_a[r * lanes..(r + 1) * lanes]);
            self.scratch_a[lanes..w]
                .copy_from_slice(&self.reg_a[row..row + w - lanes]);
            let bottom = r == dim - 1;
            if bottom {
                self.scratch_c.copy_from_slice(&self.acc[row..row + w]);
            }
            if r == 0 {
                kernel::os_row::<true>(
                    &self.scratch_a[..w],
                    &self.north_b[..w],
                    &self.north_propag[..w],
                    &self.north_valid[..w],
                    &self.north_d[..w],
                    &mut self.acc[row..row + w],
                    &mut self.reg_a[row..row + w],
                    &mut self.reg_b[row..row + w],
                    &mut self.reg_d[row..row + w],
                    &mut self.reg_propag[row..row + w],
                    &mut self.reg_valid[row..row + w],
                );
                if bottom {
                    for c in 0..dim {
                        for l in 0..lanes {
                            if self.north_propag[c * lanes + l] {
                                self.step_outs[l]
                                    .set_south_c(c, self.scratch_c[c * lanes + l]);
                            }
                        }
                    }
                }
                continue;
            }
            let north = row - w;
            let (acc_head, acc_row) = self.acc.split_at_mut(row);
            let (b_head, b_row) = self.reg_b.split_at_mut(row);
            let (p_head, p_row) = self.reg_propag.split_at_mut(row);
            let (v_head, v_row) = self.reg_valid.split_at_mut(row);
            kernel::os_row::<false>(
                &self.scratch_a[..w],
                &b_head[north..],
                &p_head[north..],
                &v_head[north..],
                &acc_head[north..],
                &mut acc_row[..w],
                &mut self.reg_a[row..row + w],
                &mut b_row[..w],
                &mut self.reg_d[row..row + w],
                &mut p_row[..w],
                &mut v_row[..w],
            );
            if bottom {
                for c in 0..dim {
                    for l in 0..lanes {
                        if p_head[north + c * lanes + l] {
                            self.step_outs[l]
                                .set_south_c(c, self.scratch_c[c * lanes + l]);
                        }
                    }
                }
            }
        }
    }

    /// Lockstep transliteration of the scalar `Mesh::step_ws` through
    /// the shared [`kernel::ws_row`], under the same discipline as
    /// [`LaneMesh::step_os`].
    fn step_ws(&mut self) {
        let dim = self.dim;
        let lanes = self.lanes;
        let w = dim * lanes;
        for r in (0..dim).rev() {
            let row = r * dim * lanes;
            self.scratch_a[..lanes]
                .copy_from_slice(&self.west_a[r * lanes..(r + 1) * lanes]);
            self.scratch_a[lanes..w]
                .copy_from_slice(&self.reg_a[row..row + w - lanes]);
            let bottom = r == dim - 1;
            if bottom {
                self.scratch_w.copy_from_slice(&self.reg_w[row..row + w]);
            }
            if r == 0 {
                kernel::ws_row::<true>(
                    &self.scratch_a[..w],
                    &self.north_b[..w],
                    &self.north_propag[..w],
                    &self.north_valid[..w],
                    &self.north_d[..w],
                    &mut self.acc[row..row + w],
                    &mut self.reg_a[row..row + w],
                    &mut self.reg_b[row..row + w],
                    &mut self.reg_d[row..row + w],
                    &mut self.reg_w[row..row + w],
                    &mut self.reg_propag[row..row + w],
                    &mut self.reg_valid[row..row + w],
                );
                if bottom {
                    for c in 0..dim {
                        for l in 0..lanes {
                            let i = c * lanes + l;
                            if self.north_propag[i] {
                                self.step_outs[l]
                                    .set_south_c(c, self.scratch_w[i] as i32);
                            } else if self.north_valid[i] {
                                self.step_outs[l].set_south_psum(c, self.acc[i]);
                            }
                        }
                    }
                }
                continue;
            }
            let north = row - w;
            let (acc_head, acc_row) = self.acc.split_at_mut(row);
            let (b_head, b_row) = self.reg_b.split_at_mut(row);
            let (p_head, p_row) = self.reg_propag.split_at_mut(row);
            let (v_head, v_row) = self.reg_valid.split_at_mut(row);
            kernel::ws_row::<false>(
                &self.scratch_a[..w],
                &b_head[north..],
                &p_head[north..],
                &v_head[north..],
                &acc_head[north..],
                &mut acc_row[..w],
                &mut self.reg_a[row..row + w],
                &mut b_row[..w],
                &mut self.reg_d[row..row + w],
                &mut self.reg_w[row..row + w],
                &mut p_row[..w],
                &mut v_row[..w],
            );
            if bottom {
                for c in 0..dim {
                    for l in 0..lanes {
                        let i = c * lanes + l;
                        if p_head[north + i] {
                            self.step_outs[l].set_south_c(c, self.scratch_w[i] as i32);
                        } else if v_head[north + i] {
                            self.step_outs[l].set_south_psum(c, acc_row[i]);
                        }
                    }
                }
            }
        }
    }
}

/// Per-lane fault cursor: [`super::PlanCursor`]'s start/next_cycle/fire
/// contract verbatim — one compare per lane per cycle, stuck-at faults
/// re-armed every cycle while active — but firing through the
/// lane-strided `apply_enforsa_lane` so only this lane's registers and
/// edge stripe are corrupted.
#[derive(Clone, Debug)]
pub struct LaneCursor {
    next: usize,
    due: u64,
    active: Vec<Fault>,
}

impl LaneCursor {
    pub fn start(plan: &FaultPlan) -> Self {
        LaneCursor {
            next: 0,
            due: plan.first_cycle(),
            active: Vec::new(),
        }
    }

    /// Next cycle at which [`LaneCursor::fire`] must run — the single
    /// per-cycle compare.
    #[inline]
    pub fn next_cycle(&self) -> u64 {
        self.due
    }

    /// Apply this lane's faults due at cycle `t`: active stuck-at
    /// faults replay first, then due-onset faults in plan order.
    pub fn fire(&mut self, plan: &FaultPlan, t: u64, mesh: &mut LaneMesh, lane: usize) {
        for f in &self.active {
            apply_enforsa_lane(mesh, lane, f);
        }
        let faults = plan.faults();
        while self.next < faults.len() && faults[self.next].cycle == t {
            let f = faults[self.next];
            apply_enforsa_lane(mesh, lane, &f);
            if matches!(f.persistence, Persistence::StuckAt(_)) {
                self.active.push(f);
            }
            self.next += 1;
        }
        self.due = if !self.active.is_empty() {
            t + 1
        } else if self.next < faults.len() {
            faults[self.next].cycle
        } else {
            u64::MAX
        };
    }
}

#[cfg(test)]
mod tests {
    use super::super::mesh::{Mesh, MeshSim};
    use super::*;

    /// Every lane of a golden (no-fault) lockstep pass must track the
    /// scalar mesh register for register: step both from reset under
    /// identical inputs and compare accumulators each cycle.
    #[test]
    fn golden_lanes_track_the_scalar_mesh() {
        for dataflow in [Dataflow::OutputStationary, Dataflow::WeightStationary] {
            let dim = 3;
            let mut mesh = Mesh::new(dim, dataflow);
            let mut lane_mesh = LaneMesh::new(dim, dataflow);
            lane_mesh.reshape(4);
            let mut state = MeshState::default();
            mesh.save_state(&mut state);
            lane_mesh.broadcast(&state);
            let mut inp = MeshInputs::idle(dim);
            let mut out = StepOutput::new(dim);
            for t in 0..20u64 {
                inp.clear();
                for c in 0..dim {
                    inp.west_a[c] = (t as i8).wrapping_mul(3).wrapping_add(c as i8);
                    inp.north_b[c] = (c as i8).wrapping_sub(t as i8);
                    inp.north_d[c] = t as i32 * 100 + c as i32;
                    inp.north_propag[c] = t % 7 == c as u64 % 7;
                    inp.north_valid[c] = (t + c as u64) % 3 != 0;
                }
                out.clear();
                lane_mesh.begin_cycle(&inp);
                mesh.step(&inp, &mut out);
                lane_mesh.step();
                for lane in 0..4 {
                    for r in 0..dim {
                        for c in 0..dim {
                            assert_eq!(
                                lane_mesh.acc_at(lane, r, c),
                                mesh.acc_at(r, c),
                                "{dataflow} t={t} lane={lane} PE({r},{c})"
                            );
                        }
                    }
                    for c in 0..dim {
                        assert_eq!(
                            lane_mesh.step_outs[lane].has_south_c(c),
                            out.has_south_c(c),
                            "{dataflow} t={t} lane={lane} south_c mask col {c}"
                        );
                        if out.has_south_c(c) {
                            assert_eq!(
                                lane_mesh.step_outs[lane].south_c_at(c),
                                out.south_c_at(c)
                            );
                        }
                        assert_eq!(
                            lane_mesh.step_outs[lane].has_south_psum(c),
                            out.has_south_psum(c),
                            "{dataflow} t={t} lane={lane} south_psum mask col {c}"
                        );
                        if out.has_south_psum(c) {
                            assert_eq!(
                                lane_mesh.step_outs[lane].south_psum_at(c),
                                out.south_psum_at(c)
                            );
                        }
                    }
                }
            }
            assert_eq!(lane_mesh.cycle(), mesh.cycle());
        }
    }

    /// A fault fired into one lane must leave every other lane golden.
    #[test]
    fn lane_faults_stay_lane_local() {
        use super::super::signal::SignalKind;
        for dataflow in [Dataflow::OutputStationary, Dataflow::WeightStationary] {
            let dim = 2;
            let mut lane_mesh = LaneMesh::new(dim, dataflow);
            lane_mesh.reshape(3);
            let mut state = MeshState::default();
            Mesh::new(dim, dataflow).save_state(&mut state);
            lane_mesh.broadcast(&state);
            let plan = FaultPlan::single(Fault::new(1, 1, SignalKind::Acc, 4, 2));
            let mut cursor = LaneCursor::start(&plan);
            let mut inp = MeshInputs::idle(dim);
            for t in 0..4u64 {
                inp.clear();
                for c in 0..dim {
                    inp.west_a[c] = 1 + c as i8;
                    inp.north_b[c] = 2;
                    inp.north_valid[c] = true;
                }
                lane_mesh.begin_cycle(&inp);
                if cursor.next_cycle() == t {
                    cursor.fire(&plan, t, &mut lane_mesh, 1);
                }
                lane_mesh.step();
            }
            for r in 0..dim {
                for c in 0..dim {
                    assert_eq!(
                        lane_mesh.acc_at(0, r, c),
                        lane_mesh.acc_at(2, r, c),
                        "{dataflow} untouched lanes diverged at PE({r},{c})"
                    );
                }
            }
            assert_ne!(
                lane_mesh.acc_at(1, 1, 1),
                lane_mesh.acc_at(0, 1, 1),
                "{dataflow} lane 1's acc fault did not land"
            );
        }
    }
}
