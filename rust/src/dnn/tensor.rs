//! Flat int8 / int32 tensors (CHW activations, GEMM-layout weights).
//!
//! The numeric contract matches `python/compile/kernels/ref.py`: int8
//! symmetric quantization, int32 accumulation, requantization via
//! `util::quant::requant`.

use crate::util::Rng;

/// A dense int8 tensor with an explicit shape (row-major).
#[derive(Clone, Debug, PartialEq)]
pub struct TensorI8 {
    pub shape: Vec<usize>,
    pub data: Vec<i8>,
}

impl TensorI8 {
    pub fn zeros(shape: &[usize]) -> Self {
        TensorI8 {
            shape: shape.to_vec(),
            data: vec![0; shape.iter().product()],
        }
    }

    pub fn from_vec(shape: &[usize], data: Vec<i8>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        TensorI8 {
            shape: shape.to_vec(),
            data,
        }
    }

    /// Deterministic random tensor (synthetic weights / inputs).
    pub fn random(shape: &[usize], rng: &mut Rng) -> Self {
        let mut t = Self::zeros(shape);
        rng.fill_i8(&mut t.data);
        t
    }

    /// Random tensor with a sparsity fraction of exact zeros — DNN
    /// activations after ReLU are sparse, which is the masking mechanism
    /// behind the paper's Fig. 5b. `p_zero` in [0, 1].
    pub fn random_sparse(shape: &[usize], p_zero: f64, rng: &mut Rng) -> Self {
        let mut t = Self::random(shape, rng);
        for v in t.data.iter_mut() {
            if rng.chance(p_zero) {
                *v = 0;
            }
        }
        t
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// CHW accessor (3-D tensors).
    #[inline]
    pub fn at3(&self, c: usize, h: usize, w: usize) -> i8 {
        debug_assert_eq!(self.shape.len(), 3);
        self.data[(c * self.shape[1] + h) * self.shape[2] + w]
    }
}

/// A dense int32 tensor (accumulators, biases).
#[derive(Clone, Debug, PartialEq)]
pub struct TensorI32 {
    pub shape: Vec<usize>,
    pub data: Vec<i32>,
}

impl TensorI32 {
    pub fn zeros(shape: &[usize]) -> Self {
        TensorI32 {
            shape: shape.to_vec(),
            data: vec![0; shape.iter().product()],
        }
    }

    pub fn from_vec(shape: &[usize], data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        TensorI32 {
            shape: shape.to_vec(),
            data,
        }
    }

    pub fn random(shape: &[usize], span: i32, rng: &mut Rng) -> Self {
        let mut t = Self::zeros(shape);
        for v in t.data.iter_mut() {
            *v = (rng.below(2 * span as u64) as i32) - span;
        }
        t
    }
}

/// Activation flowing between layers: either a CHW image tensor (CNNs)
/// or a token matrix (ViTs).
#[derive(Clone, Debug, PartialEq)]
pub enum Act {
    /// [C, H, W]
    Chw(TensorI8),
    /// [L, D] (sequence of L tokens of width D)
    Tokens(TensorI8),
}

impl Act {
    pub fn tensor(&self) -> &TensorI8 {
        match self {
            Act::Chw(t) | Act::Tokens(t) => t,
        }
    }

    pub fn tensor_mut(&mut self) -> &mut TensorI8 {
        match self {
            Act::Chw(t) | Act::Tokens(t) => t,
        }
    }

    /// Consume into the underlying tensor — the classifier seam uses
    /// this instead of cloning the logits row out of a borrowed `Act`.
    pub fn into_tensor(self) -> TensorI8 {
        match self {
            Act::Chw(t) | Act::Tokens(t) => t,
        }
    }

    /// Size in bytes of the underlying buffer (checkpoint accounting).
    pub fn byte_len(&self) -> usize {
        self.tensor().data.len()
    }

    pub fn chw(&self) -> &TensorI8 {
        match self {
            Act::Chw(t) => t,
            Act::Tokens(_) => panic!("expected CHW activation, got tokens"),
        }
    }

    pub fn tokens(&self) -> &TensorI8 {
        match self {
            Act::Tokens(t) => t,
            Act::Chw(_) => panic!("expected token activation, got CHW"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_shape_product() {
        let t = TensorI8::zeros(&[3, 4, 5]);
        assert_eq!(t.len(), 60);
        assert!(t.data.iter().all(|&v| v == 0));
    }

    #[test]
    fn at3_indexing() {
        let mut t = TensorI8::zeros(&[2, 3, 4]);
        t.data[(1 * 3 + 2) * 4 + 3] = 42;
        assert_eq!(t.at3(1, 2, 3), 42);
    }

    #[test]
    fn random_is_deterministic() {
        let mut r1 = Rng::new(5);
        let mut r2 = Rng::new(5);
        assert_eq!(
            TensorI8::random(&[16], &mut r1),
            TensorI8::random(&[16], &mut r2)
        );
    }

    #[test]
    fn sparse_has_zeros() {
        let mut rng = Rng::new(6);
        let t = TensorI8::random_sparse(&[1000], 0.5, &mut rng);
        let zeros = t.data.iter().filter(|&&v| v == 0).count();
        assert!(zeros > 350 && zeros < 700, "zeros = {zeros}");
    }

    #[test]
    #[should_panic(expected = "expected CHW")]
    fn act_kind_mismatch_panics() {
        let a = Act::Tokens(TensorI8::zeros(&[4, 4]));
        a.chw();
    }
}
