//! The model zoo: scaled-down but structurally faithful versions of the
//! ten quantized models the paper evaluates (Table II), plus QuickNet —
//! the end-to-end example model whose per-layer graphs are AOT-compiled
//! to PJRT artifacts.
//!
//! Substitution note (DESIGN.md §3): pretrained torchvision / I-ViT
//! weights are not available offline, so each topology is instantiated
//! with deterministic synthetic int8 weights and calibrated post-hoc
//! (`Model::calibrate`) exactly like PTQ would. AVF/PVF are defined
//! against the golden output of the same network, so masking behaviour
//! (ReLU sparsity, quantization clipping, saturation) exercises the same
//! code paths as the originals. Channel widths are scaled ~100x down;
//! the *relative ordering* of model sizes from Table II is preserved
//! (pinned by a unit test).

use super::engine::Model;
use super::layers::{Layer, ParallelConcat, QAttention, QConv2d, QLinear, Residual};
use crate::util::Rng;

/// Paper-side metadata of Table II (for report rendering).
#[derive(Clone, Copy, Debug)]
pub struct PaperModelInfo {
    pub name: &'static str,
    pub paper_top1: f64,
    pub paper_params_m: f64,
}

pub const TABLE_II: [PaperModelInfo; 10] = [
    PaperModelInfo { name: "MobileNetV2", paper_top1: 71.60, paper_params_m: 3.50 },
    PaperModelInfo { name: "DeiT-T", paper_top1: 72.24, paper_params_m: 5.00 },
    PaperModelInfo { name: "GoogLeNet", paper_top1: 69.8, paper_params_m: 6.60 },
    PaperModelInfo { name: "ShuffleNetX20", paper_top1: 75.3, paper_params_m: 7.40 },
    PaperModelInfo { name: "ResNet18", paper_top1: 69.4, paper_params_m: 11.7 },
    PaperModelInfo { name: "DeiT-S", paper_top1: 80.1, paper_params_m: 22.0 },
    PaperModelInfo { name: "ResNet50", paper_top1: 80.2, paper_params_m: 25.6 },
    PaperModelInfo { name: "InceptionV3", paper_top1: 77.1, paper_params_m: 27.2 },
    PaperModelInfo { name: "ResNeXt64", paper_top1: 82.8, paper_params_m: 83.5 },
    PaperModelInfo { name: "ResNeXt32", paper_top1: 82.5, paper_params_m: 88.8 },
];

// ---------------------------------------------------------------------
// builders
// ---------------------------------------------------------------------

/// Random weight in a PTQ-like range (|w| <= 16 keeps accumulators sane
/// before calibration).
fn wvec(rng: &mut Rng, n: usize) -> Vec<i8> {
    (0..n).map(|_| rng.i8() >> 3).collect()
}

fn bvec(rng: &mut Rng, n: usize) -> Vec<i32> {
    (0..n).map(|_| (rng.below(256) as i32) - 128).collect()
}

#[allow(clippy::too_many_arguments)]
fn conv(
    rng: &mut Rng,
    cin: usize,
    cout: usize,
    k: usize,
    stride: usize,
    pad: usize,
    groups: usize,
    relu: bool,
) -> Layer {
    let kelems = (cin / groups) * k * k;
    Layer::Conv(QConv2d {
        cin,
        cout,
        kh: k,
        kw: k,
        stride,
        pad,
        groups,
        m: 0.02,
        relu,
        wmat: wvec(rng, groups * kelems * (cout / groups)),
        bias: bvec(rng, cout),
    })
}

fn linear(rng: &mut Rng, in_f: usize, out_f: usize, relu: bool) -> Layer {
    Layer::Linear(QLinear {
        in_f,
        out_f,
        m: 0.02,
        relu,
        w: wvec(rng, in_f * out_f),
        bias: bvec(rng, out_f),
    })
}

fn attention(rng: &mut Rng, d: usize) -> Layer {
    Layer::Attention(QAttention {
        d_model: d,
        wq: wvec(rng, d * d),
        wk: wvec(rng, d * d),
        wv: wvec(rng, d * d),
        wo: wvec(rng, d * d),
        mq: 0.01,
        mk: 0.01,
        mv: 0.01,
        ms: 0.05,
        mo: 0.02,
        mw: 0.02,
    })
}

fn residual(body: Vec<Layer>) -> Layer {
    Layer::Residual(Residual { body })
}

fn transformer_block(rng: &mut Rng, d: usize) -> Vec<Layer> {
    vec![
        residual(vec![attention(rng, d)]),
        residual(vec![linear(rng, d, 2 * d, true), linear(rng, 2 * d, d, false)]),
    ]
}

fn finish(name: &str, layers: Vec<Layer>, seed: u64) -> Model {
    let mut model = Model {
        name: name.to_string(),
        layers,
        classes: 10,
        input_shape: vec![3, 32, 32],
    };
    let mut rng = Rng::new(seed ^ 0xCA11B7A7E);
    model.calibrate(&mut rng, 2, 100.0);
    model
}

// ---------------------------------------------------------------------
// QuickNet: the e2e model matching artifacts/manifest.json
// ---------------------------------------------------------------------

/// QuickNet — scales are FIXED (baked into the AOT HLO artifacts), so no
/// calibration here; the weight distribution is tuned to the scales.
pub fn quicknet(seed: u64) -> Model {
    let mut rng = Rng::new(seed);
    // |w| <= 8: tuned to the fixed manifest scales so activations use the
    // int8 range without saturating (pinned by an engine test).
    let qw = |rng: &mut Rng, n: usize| -> Vec<i8> { (0..n).map(|_| rng.i8() >> 4).collect() };
    let mk = |rng: &mut Rng, cin: usize, cout: usize, stride: usize, m: f32| {
        Layer::Conv(QConv2d {
            cin,
            cout,
            kh: 3,
            kw: 3,
            stride,
            pad: 1,
            groups: 1,
            m,
            relu: true,
            wmat: qw(rng, cin * 9 * cout),
            bias: bvec(rng, cout),
        })
    };
    let layers = vec![
        mk(&mut rng, 3, 16, 1, 0.035),
        mk(&mut rng, 16, 32, 2, 0.02),
        mk(&mut rng, 32, 32, 1, 0.02),
        mk(&mut rng, 32, 64, 2, 0.02),
        Layer::GlobalAvgPool,
        Layer::Linear(QLinear {
            in_f: 64,
            out_f: 10,
            m: 0.05,
            relu: false,
            w: qw(&mut rng, 640),
            bias: bvec(&mut rng, 10),
        }),
    ];
    Model {
        name: "quicknet".into(),
        layers,
        classes: 10,
        input_shape: vec![3, 32, 32],
    }
}

// ---------------------------------------------------------------------
// Table II topologies
// ---------------------------------------------------------------------

pub fn mobilenet_v2(seed: u64) -> Model {
    let mut rng = Rng::new(seed);
    let r = &mut rng;
    let inv_res = |r: &mut Rng, c: usize, exp: usize| {
        residual(vec![
            conv(r, c, exp, 1, 1, 0, 1, true),      // expand
            conv(r, exp, exp, 3, 1, 1, exp, true),  // depthwise
            conv(r, exp, c, 1, 1, 0, 1, false),     // project
        ])
    };
    let layers = vec![
        conv(r, 3, 16, 3, 2, 1, 1, true), // stem -> 16x16
        inv_res(r, 16, 32),
        inv_res(r, 16, 32),
        conv(r, 16, 24, 3, 2, 1, 1, true), // -> 8x8
        inv_res(r, 24, 48),
        conv(r, 24, 48, 1, 1, 0, 1, true),
        Layer::GlobalAvgPool,
        linear(r, 48, 10, false),
    ];
    finish("MobileNetV2", layers, seed)
}

pub fn deit_t(seed: u64) -> Model {
    let mut rng = Rng::new(seed);
    let r = &mut rng;
    let d = 32;
    let mut layers = vec![
        conv(r, 3, d, 4, 4, 0, 1, false), // patch embed -> 8x8 patches
        Layer::ToTokens,                  // 64 tokens x 32
    ];
    for _ in 0..2 {
        layers.extend(transformer_block(r, d));
    }
    layers.push(Layer::TokenMean);
    layers.push(linear(r, d, 10, false));
    finish("DeiT-T", layers, seed)
}

pub fn googlenet(seed: u64) -> Model {
    let mut rng = Rng::new(seed);
    let r = &mut rng;
    let inception = |r: &mut Rng, cin: usize, w: usize| {
        Layer::ParallelConcat(ParallelConcat {
            branches: vec![
                vec![conv(r, cin, w, 1, 1, 0, 1, true)],
                vec![
                    conv(r, cin, w / 2, 1, 1, 0, 1, true),
                    conv(r, w / 2, w, 3, 1, 1, 1, true),
                ],
                vec![
                    conv(r, cin, w / 2, 1, 1, 0, 1, true),
                    conv(r, w / 2, w / 2, 3, 1, 1, 1, true),
                    conv(r, w / 2, w, 3, 1, 1, 1, true),
                ],
            ],
        })
    };
    let layers = vec![
        conv(r, 3, 16, 3, 2, 1, 1, true), // -> 16x16
        inception(r, 16, 16),             // -> 48ch
        Layer::MaxPool { k: 2, stride: 2 }, // -> 8x8
        inception(r, 48, 32),             // -> 96ch
        conv(r, 96, 48, 1, 1, 0, 1, true),
        Layer::GlobalAvgPool,
        linear(r, 48, 10, false),
    ];
    finish("GoogLeNet", layers, seed)
}

pub fn shufflenet_x20(seed: u64) -> Model {
    let mut rng = Rng::new(seed);
    let r = &mut rng;
    let shuffle_block = |r: &mut Rng, c: usize, g: usize| {
        residual(vec![
            conv(r, c, c, 1, 1, 0, g, true),
            Layer::ChannelShuffle { groups: g },
            conv(r, c, c, 3, 1, 1, c, false), // depthwise
            conv(r, c, c, 1, 1, 0, g, false),
        ])
    };
    let layers = vec![
        conv(r, 3, 64, 3, 2, 1, 1, true), // -> 16x16
        Layer::MaxPool { k: 2, stride: 2 }, // -> 8x8
        shuffle_block(r, 64, 4),
        shuffle_block(r, 64, 4),
        shuffle_block(r, 64, 4),
        shuffle_block(r, 64, 4),
        conv(r, 64, 160, 1, 1, 0, 1, true),
        Layer::GlobalAvgPool,
        linear(r, 160, 10, false),
    ];
    finish("ShuffleNetX20", layers, seed)
}

pub fn resnet18(seed: u64) -> Model {
    let mut rng = Rng::new(seed);
    let r = &mut rng;
    let basic = |r: &mut Rng, c: usize| {
        residual(vec![
            conv(r, c, c, 3, 1, 1, 1, true),
            conv(r, c, c, 3, 1, 1, 1, false),
        ])
    };
    let layers = vec![
        conv(r, 3, 16, 3, 2, 1, 1, true), // -> 16x16
        basic(r, 16),
        Layer::Relu,
        basic(r, 16),
        Layer::Relu,
        conv(r, 16, 32, 3, 2, 1, 1, true), // -> 8x8
        basic(r, 32),
        Layer::Relu,
        Layer::GlobalAvgPool,
        linear(r, 32, 10, false),
    ];
    finish("ResNet18", layers, seed)
}

pub fn deit_s(seed: u64) -> Model {
    let mut rng = Rng::new(seed);
    let r = &mut rng;
    let d = 48;
    let mut layers = vec![conv(r, 3, d, 4, 4, 0, 1, false), Layer::ToTokens];
    for _ in 0..3 {
        layers.extend(transformer_block(r, d));
    }
    layers.push(Layer::TokenMean);
    layers.push(linear(r, d, 10, false));
    finish("DeiT-S", layers, seed)
}

pub fn resnet50(seed: u64) -> Model {
    let mut rng = Rng::new(seed);
    let r = &mut rng;
    let bottleneck = |r: &mut Rng, c: usize| {
        residual(vec![
            conv(r, c, c / 2, 1, 1, 0, 1, true),
            conv(r, c / 2, c / 2, 3, 1, 1, 1, true),
            conv(r, c / 2, c, 1, 1, 0, 1, false),
        ])
    };
    let mut layers = vec![
        conv(r, 3, 24, 3, 2, 1, 1, true),  // -> 16x16
        conv(r, 24, 56, 3, 2, 1, 1, true), // -> 8x8
    ];
    for _ in 0..6 {
        layers.push(bottleneck(r, 56));
        layers.push(Layer::Relu);
    }
    layers.push(Layer::GlobalAvgPool);
    layers.push(linear(r, 56, 10, false));
    finish("ResNet50", layers, seed)
}

pub fn inception_v3(seed: u64) -> Model {
    let mut rng = Rng::new(seed);
    let r = &mut rng;
    // factorized inception block (1x1 / 1x3+3x1 / 3x3+3x3)
    let block = |r: &mut Rng, cin: usize, w: usize| {
        Layer::ParallelConcat(ParallelConcat {
            branches: vec![
                vec![conv(r, cin, w, 1, 1, 0, 1, true)],
                vec![
                    conv(r, cin, w, 1, 1, 0, 1, true),
                    // stand-in for the factorized 1x3+3x1 pair (our
                    // im2col pads symmetrically, so a same-padded 3x3
                    // with the pair's parameter count is used instead)
                    conv(r, w, w, 3, 1, 1, 1, true),
                ],
                vec![
                    conv(r, cin, w, 1, 1, 0, 1, true),
                    conv(r, w, w, 3, 1, 1, 1, true),
                    conv(r, w, w, 3, 1, 1, 1, true),
                ],
            ],
        })
    };
    let layers = vec![
        conv(r, 3, 24, 3, 2, 1, 1, true),   // -> 16x16
        conv(r, 24, 48, 3, 2, 1, 1, true),  // -> 8x8
        block(r, 48, 24),                   // -> 72
        block(r, 72, 36),                   // -> 108
        conv(r, 108, 64, 1, 1, 0, 1, true),
        Layer::GlobalAvgPool,
        linear(r, 64, 10, false),
    ];
    finish("InceptionV3", layers, seed)
}

fn resnext(seed: u64, name: &str, groups: usize, blocks: usize) -> Model {
    let mut rng = Rng::new(seed);
    let r = &mut rng;
    let c = 96;
    let block = |r: &mut Rng| {
        residual(vec![
            conv(r, c, c, 1, 1, 0, 1, true),
            conv(r, c, c, 3, 1, 1, groups, true),
            conv(r, c, c, 1, 1, 0, 1, false),
        ])
    };
    let mut layers = vec![
        conv(r, 3, 32, 3, 2, 1, 1, true), // -> 16x16
        conv(r, 32, c, 3, 2, 1, 1, true), // -> 8x8
    ];
    for _ in 0..blocks {
        layers.push(block(r));
        layers.push(Layer::Relu);
    }
    layers.push(Layer::GlobalAvgPool);
    layers.push(linear(r, c, 10, false));
    finish(name, layers, seed)
}

pub fn resnext64(seed: u64) -> Model {
    resnext(seed, "ResNeXt64", 8, 8)
}

pub fn resnext32(seed: u64) -> Model {
    // fewer, coarser groups => more parameters (matches Table II's
    // ResNeXt32 > ResNeXt64 ordering)
    resnext(seed, "ResNeXt32", 4, 8)
}

/// The full Table II zoo, in paper order.
pub fn zoo(seed: u64) -> Vec<Model> {
    vec![
        mobilenet_v2(seed),
        deit_t(seed.wrapping_add(1)),
        googlenet(seed.wrapping_add(2)),
        shufflenet_x20(seed.wrapping_add(3)),
        resnet18(seed.wrapping_add(4)),
        deit_s(seed.wrapping_add(5)),
        resnet50(seed.wrapping_add(6)),
        inception_v3(seed.wrapping_add(7)),
        resnext64(seed.wrapping_add(8)),
        resnext32(seed.wrapping_add(9)),
    ]
}

/// Look up a single zoo model (CLI `--model`).
pub fn by_name(name: &str, seed: u64) -> Option<Model> {
    let lower = name.to_ascii_lowercase();
    if lower == "quicknet" {
        return Some(quicknet(seed));
    }
    zoo(seed)
        .into_iter()
        .find(|m| m.name.to_ascii_lowercase() == lower)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::engine::synthetic_input;

    #[test]
    fn zoo_builds_and_classifies() {
        let mut rng = Rng::new(9);
        for model in zoo(42) {
            let x = synthetic_input(&model.input_shape, &mut rng);
            let t = model.top1(&x, None);
            assert!(t < model.classes, "{}", model.name);
        }
    }

    #[test]
    fn zoo_param_ordering_matches_table_ii() {
        let models = zoo(42);
        let params: Vec<(String, usize)> = models
            .iter()
            .map(|m| (m.name.clone(), m.param_count()))
            .collect();
        for w in params.windows(2) {
            assert!(
                w[0].1 < w[1].1,
                "Table II size ordering violated: {:?} >= {:?}",
                w[0],
                w[1]
            );
        }
    }

    #[test]
    fn quicknet_matches_manifest_topology() {
        let m = quicknet(1);
        assert_eq!(m.layers.len(), 6);
        if let Layer::Conv(c) = &m.layers[0] {
            assert_eq!((c.cin, c.cout, c.stride), (3, 16, 1));
            assert!((c.m - 0.035).abs() < 1e-9);
        } else {
            panic!("layer 0 must be conv1");
        }
        if let Layer::Linear(l) = &m.layers[5] {
            assert_eq!((l.in_f, l.out_f), (64, 10));
        } else {
            panic!("layer 5 must be the classifier");
        }
    }

    #[test]
    fn by_name_lookup() {
        assert!(by_name("quicknet", 1).is_some());
        assert!(by_name("ResNet50", 1).is_some());
        assert!(by_name("resnet50", 1).is_some());
        assert!(by_name("nope", 1).is_none());
    }

    #[test]
    fn by_name_resolves_every_zoo_name_case_insensitively() {
        for info in TABLE_II.iter() {
            for variant in [
                info.name.to_string(),
                info.name.to_ascii_lowercase(),
                info.name.to_ascii_uppercase(),
            ] {
                let m = by_name(&variant, 42)
                    .unwrap_or_else(|| panic!("by_name must resolve '{variant}'"));
                assert_eq!(m.name, info.name, "lookup '{variant}'");
            }
        }
        // the example model is reachable too, in any case
        for variant in ["quicknet", "QuickNet", "QUICKNET"] {
            let m = by_name(variant, 7).unwrap();
            assert_eq!(m.name, "quicknet");
        }
        assert!(by_name("bogus-model", 42).is_none());
        assert!(by_name("", 42).is_none());
        assert!(by_name("resnet", 42).is_none(), "no prefix matching");
    }

    /// Same seed => bit-identical weights across two independent zoo
    /// constructions (Layer carries no PartialEq; derived Debug prints
    /// every weight/bias vector, so string equality is weight equality).
    #[test]
    fn zoo_is_bit_deterministic_per_seed() {
        let a = zoo(42);
        let b = zoo(42);
        assert_eq!(a.len(), b.len());
        let mut rng = Rng::new(3);
        for (ma, mb) in a.iter().zip(b.iter()) {
            assert_eq!(ma.name, mb.name);
            assert_eq!(ma.param_count(), mb.param_count(), "{}", ma.name);
            assert_eq!(
                format!("{:?}", ma.layers),
                format!("{:?}", mb.layers),
                "{}: same seed must reproduce weights bit-exactly",
                ma.name
            );
            let x = synthetic_input(&ma.input_shape, &mut rng);
            assert_eq!(
                ma.forward(&x, None),
                mb.forward(&x, None),
                "{}: twin constructions must agree on logits",
                ma.name
            );
        }
        // and a different seed actually changes the weights somewhere
        let c = zoo(43);
        assert!(
            a.iter()
                .zip(c.iter())
                .any(|(ma, mc)| format!("{:?}", ma.layers) != format!("{:?}", mc.layers)),
            "distinct seeds must yield distinct weights"
        );
    }

    #[test]
    fn same_seed_same_model() {
        let mut rng = Rng::new(10);
        let x = synthetic_input(&[3, 32, 32], &mut rng);
        let a = resnet18(7).forward(&x, None);
        let b = resnet18(7).forward(&x, None);
        assert_eq!(a, b);
    }
}
