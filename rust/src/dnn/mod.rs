//! Quantized DNN substrate: tensors, native int8 GEMM, im2col, layers,
//! the inference engine with GEMM-site hooks (the crate's analogue of
//! the paper's PyTorch forward hooks) and the Table II model zoo.

pub mod engine;
pub mod gemm;
pub mod im2col;
pub mod layers;
pub mod models;
pub mod tensor;

pub use engine::{
    argmax, probe_input, synthetic_input, ActivationCheckpoints, GemmSiteInfo, Model,
};
pub use layers::{ForwardCtx, GemmCall, GemmHook, GemmSiteId, Layer};
pub use tensor::{Act, TensorI32, TensorI8};
