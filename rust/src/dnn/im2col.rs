//! im2col unfolding — how convolutions are lowered onto the systolic
//! array (paper §III-B: "convolutions are expressed as matrix
//! multiplications by using the im2col procedure").
//!
//! Patch layout is (c, kh, kw), identical to the Pallas kernel
//! (`python/compile/kernels/im2col.py`) and its ref.py oracle, so the
//! same GEMM operands appear at every level of the stack.

use super::tensor::TensorI8;

/// Output spatial size of a convolution.
#[inline]
pub fn conv_out(h: usize, k: usize, stride: usize, pad: usize) -> usize {
    (h + 2 * pad - k) / stride + 1
}

/// Unfold x[C, H, W] into a [OH*OW, C*KH*KW] patch matrix (flat,
/// row-major). Channel group `(c0, c1)` restricts to channels
/// [c0, c1) — used by grouped / depthwise convolutions.
pub fn im2col_group(
    x: &TensorI8,
    kh: usize,
    kw: usize,
    stride: usize,
    pad: usize,
    c0: usize,
    c1: usize,
) -> (Vec<i8>, usize, usize) {
    let (c, h, w) = (x.shape[0], x.shape[1], x.shape[2]);
    debug_assert!(c1 <= c && c0 < c1);
    let gc = c1 - c0;
    let oh = conv_out(h, kh, stride, pad);
    let ow = conv_out(w, kw, stride, pad);
    let patch = gc * kh * kw;
    let mut out = vec![0i8; oh * ow * patch];
    for oy in 0..oh {
        for ox in 0..ow {
            let row = (oy * ow + ox) * patch;
            for cc in 0..gc {
                for ky in 0..kh {
                    let iy = (oy * stride + ky) as isize - pad as isize;
                    if iy < 0 || iy >= h as isize {
                        continue; // zero padding
                    }
                    for kx in 0..kw {
                        let ix = (ox * stride + kx) as isize - pad as isize;
                        if ix < 0 || ix >= w as isize {
                            continue;
                        }
                        out[row + (cc * kh + ky) * kw + kx] =
                            x.at3(c0 + cc, iy as usize, ix as usize);
                    }
                }
            }
        }
    }
    (out, oh, ow)
}

/// Full-channel im2col.
pub fn im2col(
    x: &TensorI8,
    kh: usize,
    kw: usize,
    stride: usize,
    pad: usize,
) -> (Vec<i8>, usize, usize) {
    im2col_group(x, kh, kw, stride, pad, 0, x.shape[0])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn pointwise_is_channel_transpose() {
        let mut rng = Rng::new(41);
        let x = TensorI8::random(&[3, 2, 2], &mut rng);
        let (p, oh, ow) = im2col(&x, 1, 1, 1, 0);
        assert_eq!((oh, ow), (2, 2));
        for pix in 0..4 {
            for c in 0..3 {
                assert_eq!(p[pix * 3 + c], x.data[c * 4 + pix]);
            }
        }
    }

    #[test]
    fn patch_layout_is_c_kh_kw() {
        // mirror of the pytest pin in python/tests/test_im2col_kernel.py
        let (c, h, w, kh, kw) = (2usize, 3usize, 3usize, 2usize, 2usize);
        let data: Vec<i8> = (0..(c * h * w) as i32).map(|v| v as i8).collect();
        let x = TensorI8::from_vec(&[c, h, w], data);
        let (p, _, _) = im2col(&x, kh, kw, 1, 0);
        // first patch, channel 1, kernel pos (1, 0) => x[1, 1, 0] = 12
        assert_eq!(p[1 * kh * kw + 1 * kw], x.at3(1, 1, 0));
    }

    #[test]
    fn zero_padding_fills_zero() {
        let x = TensorI8::from_vec(&[1, 2, 2], vec![7; 4]);
        let (p, oh, ow) = im2col(&x, 3, 3, 1, 1);
        assert_eq!((oh, ow), (2, 2));
        // top-left patch: entire first kernel row is padding
        assert_eq!(&p[0..3], &[0, 0, 0]);
    }

    #[test]
    fn strided_output_size() {
        let mut rng = Rng::new(42);
        let x = TensorI8::random(&[4, 9, 9], &mut rng);
        let (_p, oh, ow) = im2col(&x, 3, 3, 2, 1);
        assert_eq!((oh, ow), (5, 5));
    }

    #[test]
    fn grouped_extracts_channel_slice() {
        let mut rng = Rng::new(43);
        let x = TensorI8::random(&[4, 3, 3], &mut rng);
        let (pg, _, _) = im2col_group(&x, 1, 1, 1, 0, 2, 4);
        for pix in 0..9 {
            assert_eq!(pg[pix * 2], x.data[2 * 9 + pix]);
            assert_eq!(pg[pix * 2 + 1], x.data[3 * 9 + pix]);
        }
    }
}
