//! Native int8 GEMM — the software inference path (what PyTorch's
//! quantized kernels are to the paper's runtime). Flat row-major arrays,
//! i32 accumulation, identical arithmetic to the Pallas kernel's ref.py
//! and to the mesh.

/// C[i32] = A[i8] . B[i8] + D[i32].
/// a: M x K, b: K x N, d/c: M x N, all row-major flat slices.
pub fn gemm_i8(m: usize, k: usize, n: usize, a: &[i8], b: &[i8], d: &[i32], c: &mut [i32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(d.len(), m * n);
    debug_assert_eq!(c.len(), m * n);
    c.copy_from_slice(d);
    // ikj loop order: streams B rows, keeps C row hot; the autovectorizer
    // turns the inner loop into 8/16-lane integer FMAs.
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (kk, &aik) in arow.iter().enumerate() {
            if aik == 0 {
                continue; // ReLU sparsity: the HW masking analogue in SW
            }
            let av = aik as i32;
            let brow = &b[kk * n..(kk + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv = cv.wrapping_add(av * bv as i32);
            }
        }
    }
}

/// Convenience allocating wrapper.
pub fn gemm_i8_alloc(m: usize, k: usize, n: usize, a: &[i8], b: &[i8], d: &[i32]) -> Vec<i32> {
    let mut c = vec![0; m * n];
    gemm_i8(m, k, n, a, b, d, &mut c);
    c
}

/// Reference (naive ijk) implementation used to pin the optimized one.
pub fn gemm_i8_naive(m: usize, k: usize, n: usize, a: &[i8], b: &[i8], d: &[i32]) -> Vec<i32> {
    let mut c = vec![0; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut acc = d[i * n + j];
            for kk in 0..k {
                acc = acc.wrapping_add(a[i * k + kk] as i32 * b[kk * n + j] as i32);
            }
            c[i * n + j] = acc;
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn optimized_matches_naive() {
        let mut rng = Rng::new(31);
        for &(m, k, n) in &[(1usize, 1usize, 1usize), (4, 4, 4), (7, 13, 5), (32, 27, 16)] {
            let mut a = vec![0i8; m * k];
            let mut b = vec![0i8; k * n];
            rng.fill_i8(&mut a);
            rng.fill_i8(&mut b);
            let d: Vec<i32> = (0..m * n).map(|i| i as i32 - 50).collect();
            assert_eq!(
                gemm_i8_alloc(m, k, n, &a, &b, &d),
                gemm_i8_naive(m, k, n, &a, &b, &d),
                "m={m} k={k} n={n}"
            );
        }
    }

    #[test]
    fn zero_a_rows_short_circuit_correctly() {
        // the aik == 0 skip must not change results
        let m = 4;
        let k = 8;
        let n = 4;
        let a = vec![0i8; m * k];
        let mut rng = Rng::new(32);
        let mut b = vec![0i8; k * n];
        rng.fill_i8(&mut b);
        let d: Vec<i32> = (0..m * n).map(|i| i as i32).collect();
        assert_eq!(gemm_i8_alloc(m, k, n, &a, &b, &d), d);
    }

    #[test]
    fn matches_mesh_gold_matmul() {
        // one arithmetic definition across the whole stack — and one
        // data layout: the GEMM consumes the Mat's flat buffer directly
        use crate::mesh::driver::gold_matmul;
        let mut rng = Rng::new(33);
        let (m, k, n) = (5usize, 6usize, 7usize);
        let a2 = rng.mat_i8(m, k);
        let b2 = rng.mat_i8(k, n);
        let d2 = rng.mat_i32(m, n, 100);
        let flat = gemm_i8_alloc(m, k, n, a2.data(), b2.data(), d2.data());
        let gold = gold_matmul(a2.view(), b2.view(), d2.view());
        assert_eq!(flat, gold.into_vec());
    }

    #[test]
    fn extreme_values_accumulate_exactly() {
        let (m, k, n) = (2usize, 64usize, 2usize);
        let a = vec![-128i8; m * k];
        let b = vec![-128i8; k * n];
        let d = vec![0i32; m * n];
        let c = gemm_i8_alloc(m, k, n, &a, &b, &d);
        assert!(c.iter().all(|&v| v == 128 * 128 * 64));
    }
}
