//! Inference engine: sequential layer stacks, forward hooks, GEMM-site
//! discovery, post-training calibration of requantization scales, and
//! the **checkpoint / resume** machinery of the site-resume trial
//! engine.
//!
//! # Checkpoint / resume contract
//!
//! A fault trial only ever perturbs the network from its injection site
//! onward: everything upstream of the faulty GEMM is bit-identical to
//! the golden pass. [`Model::forward_checkpointed`] therefore snapshots
//! the input activation of every top-level layer once per input, and
//! [`Model::forward_from`] resumes inference at the target layer from
//! that snapshot — each trial then costs one RTL tile plus only the
//! *downstream* software layers instead of the whole network. Nested
//! layers (residual bodies, parallel branches, attention ordinals)
//! share their parent's flat layer index, so one checkpoint per
//! top-level layer covers every GEMM site inside it. Resumed passes are
//! bit-identical to full passes with the same hook (pinned by
//! `rust/tests/prop_resume.rs`).

use super::layers::{Act, ForwardCtx, GemmCall, GemmHook, GemmSiteId, Layer};
use super::tensor::TensorI8;
use crate::util::Rng;
use std::collections::BTreeMap;

/// A quantized model: a named stack of layers ending in a classifier.
#[derive(Clone, Debug)]
pub struct Model {
    pub name: String,
    pub layers: Vec<Layer>,
    pub classes: usize,
    /// Input shape [C, H, W].
    pub input_shape: Vec<usize>,
}

impl Model {
    pub fn param_count(&self) -> usize {
        self.layers.iter().map(Layer::param_count).sum()
    }

    /// Full forward pass; returns the logits row [1, classes].
    pub fn forward(&self, x: &TensorI8, hook: Option<&mut dyn GemmHook>) -> TensorI8 {
        let act = self.forward_layers(0, self.layers.len(), Act::Chw(x.clone()), hook);
        self.into_logits(act)
    }

    /// Run the half-open span of top-level layers `start..end` on `act`
    /// (the input activation of layer `start`), offering every GEMM and
    /// every layer output to `hook`. `forward` is the `0..len` span;
    /// trial resume runs `site..site+1` and then `site+1..len`. Spans
    /// compose: chaining two adjacent spans is bit-identical to the
    /// combined span.
    pub fn forward_layers(
        &self,
        start: usize,
        end: usize,
        mut act: Act,
        hook: Option<&mut dyn GemmHook>,
    ) -> Act {
        let mut ctx = ForwardCtx::new(hook);
        for li in start..end {
            act = self.layers[li].forward(&act, li, &mut ctx);
            if let Some(h) = ctx.hook.as_deref_mut() {
                h.layer_output(li, &mut act);
            }
        }
        act
    }

    /// Run layers `start..` on `act` and return the logits.
    pub fn resume_logits(
        &self,
        start: usize,
        act: Act,
        hook: Option<&mut dyn GemmHook>,
    ) -> TensorI8 {
        let act = self.forward_layers(start, self.layers.len(), act, hook);
        self.into_logits(act)
    }

    /// Golden forward pass that additionally snapshots the input
    /// activation of every top-level layer. Logits are bit-identical to
    /// `forward(x, None)`; the returned checkpoints are the resume
    /// points for this input's fault trials.
    pub fn forward_checkpointed(&self, x: &TensorI8) -> (TensorI8, ActivationCheckpoints) {
        let mut acts = Vec::with_capacity(self.layers.len());
        let mut act = Act::Chw(x.clone());
        let mut ctx = ForwardCtx::plain();
        for (li, layer) in self.layers.iter().enumerate() {
            acts.push(act.clone());
            act = layer.forward(&act, li, &mut ctx);
        }
        (self.into_logits(act), ActivationCheckpoints { acts })
    }

    /// Resume a checkpointed forward pass at top-level layer `layer`:
    /// bit-identical to `forward(x, hook)` whenever the hook leaves
    /// layers `0..layer` untouched (the cross-layer trial case).
    pub fn forward_from(
        &self,
        layer: usize,
        ckpt: &ActivationCheckpoints,
        hook: Option<&mut dyn GemmHook>,
    ) -> TensorI8 {
        self.resume_logits(layer, ckpt.at(layer).clone(), hook)
    }

    /// Check the classifier contract and extract the logits row.
    fn into_logits(&self, act: Act) -> TensorI8 {
        let t = act.into_tensor();
        assert_eq!(
            t.shape,
            vec![1, self.classes],
            "model must end in a [1, classes] classifier"
        );
        t
    }

    /// Top-1 class of an input (the paper's criticality criterion
    /// compares this against the golden run).
    pub fn top1(&self, x: &TensorI8, hook: Option<&mut dyn GemmHook>) -> usize {
        let logits = self.forward(x, hook);
        argmax(&logits.data)
    }

    /// Discover every GEMM call site (layer, ordinal, m, k, n) by running
    /// one recording pass — the fault sampler draws targets from this.
    pub fn gemm_sites(&self, example: &TensorI8) -> Vec<GemmSiteInfo> {
        let mut rec = Recorder::default();
        self.forward(example, Some(&mut rec));
        rec.sites
    }

    /// Post-training calibration: run `n` random inputs, record the peak
    /// |accumulator| per conv/linear layer, and set each layer's
    /// requantization multiplier so peak outputs land near `target`
    /// (|q| ~ 100). This keeps synthetic-weight models in a healthy
    /// dynamic range so quantization masking behaves like a real PTQ
    /// model's.
    pub fn calibrate(&mut self, rng: &mut Rng, n: usize, target: f32) {
        for _ in 0..n {
            let x = synthetic_input(&self.input_shape, rng);
            let mut cal = Calibrator::default();
            self.forward(&x, Some(&mut cal));
            for (li, peak) in cal.peak {
                if peak == 0 {
                    continue;
                }
                let m = target / peak as f32;
                apply_scale(&mut self.layers, li, m);
            }
        }
    }
}

/// Shape record of one GEMM site.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GemmSiteInfo {
    pub site: GemmSiteId,
    pub m: usize,
    pub k: usize,
    pub n: usize,
}

/// Per-layer activation snapshots from one golden forward pass — the
/// resume points of the site-resume trial engine. `at(li)` is the input
/// activation of top-level layer `li`; every GEMM ordinal inside that
/// layer (residual bodies, attention matmuls, conv groups) shares it.
#[derive(Clone, Debug)]
pub struct ActivationCheckpoints {
    acts: Vec<Act>,
}

impl ActivationCheckpoints {
    /// Input activation of top-level layer `layer`.
    pub fn at(&self, layer: usize) -> &Act {
        &self.acts[layer]
    }

    /// Number of checkpointed layers (== the model's layer count).
    pub fn layers(&self) -> usize {
        self.acts.len()
    }

    /// Total checkpoint footprint in bytes (campaign memory accounting).
    pub fn byte_len(&self) -> usize {
        self.acts.iter().map(Act::byte_len).sum()
    }
}

/// Shape-probe input for GEMM-site discovery: the site list (layer,
/// ordinal, m, k, n) depends only on the model topology and the input
/// *shape*, never on input values, so a zero tensor suffices — and no
/// campaign RNG is consumed, which lets campaigns discover sites once
/// up front without perturbing the per-input fault streams.
pub fn probe_input(shape: &[usize]) -> TensorI8 {
    TensorI8::zeros(shape)
}

#[derive(Default)]
struct Recorder {
    sites: Vec<GemmSiteInfo>,
}

impl GemmHook for Recorder {
    fn gemm(&mut self, call: &GemmCall<'_>, _out: &mut Vec<i32>) -> bool {
        self.sites.push(GemmSiteInfo {
            site: call.site,
            m: call.m,
            k: call.k,
            n: call.n,
        });
        false
    }
}

#[derive(Default)]
struct Calibrator {
    peak: BTreeMap<usize, i32>,
}

impl GemmHook for Calibrator {
    fn gemm(&mut self, call: &GemmCall<'_>, out: &mut Vec<i32>) -> bool {
        // run natively into the layer's buffer, observe the range
        out.resize(call.m * call.n, 0);
        super::gemm::gemm_i8(call.m, call.k, call.n, call.a, call.b, call.d, out);
        let peak = out.iter().map(|v| v.saturating_abs()).max().unwrap_or(0);
        let e = self.peak.entry(call.site.layer).or_insert(0);
        *e = (*e).max(peak);
        true
    }
}

/// Set the requant multiplier of conv/linear layers at flat index `li`
/// (first-level only; nested layers inherit the parent index and are
/// scaled together, which matches how they share the site address).
fn apply_scale(layers: &mut [Layer], li: usize, m: f32) {
    fn rec(layer: &mut Layer, m: f32) {
        match layer {
            Layer::Conv(c) => c.m = c.m.min(m),
            Layer::Linear(l) => l.m = l.m.min(m),
            Layer::Residual(r) => r.body.iter_mut().for_each(|l| rec(l, m)),
            Layer::ParallelConcat(p) => p
                .branches
                .iter_mut()
                .for_each(|b| b.iter_mut().for_each(|l| rec(l, m))),
            _ => {}
        }
    }
    if let Some(layer) = layers.get_mut(li) {
        rec(layer, m);
    }
}

pub fn argmax(v: &[i8]) -> usize {
    v.iter()
        .enumerate()
        .max_by_key(|&(i, &x)| (x, std::cmp::Reverse(i)))
        .map(|(i, _)| i)
        .unwrap_or(0)
}

/// Synthetic dataset input: half-range values with ReLU-like sparsity
/// (the zero-masking substrate of the paper's Fig. 5b analysis).
pub fn synthetic_input(shape: &[usize], rng: &mut Rng) -> TensorI8 {
    let mut t = TensorI8::random_sparse(shape, 0.3, rng);
    for v in t.data.iter_mut() {
        *v >>= 1; // keep |x| <= 63
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::models;

    #[test]
    fn argmax_prefers_first_on_tie() {
        assert_eq!(argmax(&[1, 5, 5, 2]), 1);
        assert_eq!(argmax(&[-3]), 0);
    }

    #[test]
    fn quicknet_forward_is_deterministic() {
        let model = models::quicknet(0xDEAD);
        let mut rng = Rng::new(1);
        let x = synthetic_input(&model.input_shape, &mut rng);
        let a = model.forward(&x, None);
        let b = model.forward(&x, None);
        assert_eq!(a, b);
        assert_eq!(a.shape, vec![1, 10]);
    }

    #[test]
    fn quicknet_distinguishes_inputs() {
        let model = models::quicknet(0xDEAD);
        let mut rng = Rng::new(2);
        let mut tops = std::collections::HashSet::new();
        for _ in 0..12 {
            let x = synthetic_input(&model.input_shape, &mut rng);
            tops.insert(model.top1(&x, None));
        }
        assert!(tops.len() > 1, "logits must not be saturated/constant");
    }

    #[test]
    fn gemm_sites_cover_all_gemm_layers() {
        let model = models::quicknet(0xDEAD);
        let mut rng = Rng::new(3);
        let x = synthetic_input(&model.input_shape, &mut rng);
        let sites = model.gemm_sites(&x);
        // 4 convs + 1 fc
        assert_eq!(sites.len(), 5);
        assert_eq!(sites[0].k, 27); // conv1: 3*3*3
        assert_eq!(sites[4].n, 10); // classifier
    }

    #[test]
    fn forward_layers_spans_compose() {
        let model = models::quicknet(0xDEAD);
        let mut rng = Rng::new(5);
        let x = synthetic_input(&model.input_shape, &mut rng);
        let golden = model.forward(&x, None);
        for split in 0..=model.layers.len() {
            let mid = model.forward_layers(0, split, Act::Chw(x.clone()), None);
            let logits = model.resume_logits(split, mid, None);
            assert_eq!(logits, golden, "split at layer {split}");
        }
    }

    #[test]
    fn checkpointed_resume_matches_full_forward() {
        let model = models::quicknet(0xDEAD);
        let mut rng = Rng::new(6);
        let x = synthetic_input(&model.input_shape, &mut rng);
        let golden = model.forward(&x, None);
        let (logits, ckpt) = model.forward_checkpointed(&x);
        assert_eq!(logits, golden);
        assert_eq!(ckpt.layers(), model.layers.len());
        assert!(ckpt.byte_len() > 0);
        for layer in 0..model.layers.len() {
            assert_eq!(
                model.forward_from(layer, &ckpt, None),
                golden,
                "resume at layer {layer}"
            );
        }
    }

    #[test]
    fn probe_input_discovers_identical_sites() {
        let model = models::quicknet(0xDEAD);
        let mut rng = Rng::new(7);
        let x = synthetic_input(&model.input_shape, &mut rng);
        assert_eq!(
            model.gemm_sites(&probe_input(&model.input_shape)),
            model.gemm_sites(&x),
            "site shapes must not depend on input values"
        );
    }

    #[test]
    fn calibration_brings_peaks_into_range() {
        let mut model = models::quicknet(0xBEEF);
        let mut rng = Rng::new(4);
        model.calibrate(&mut rng, 2, 100.0);
        let x = synthetic_input(&model.input_shape, &mut rng);
        let logits = model.forward(&x, None);
        assert!(
            logits.data.iter().any(|&v| v != 127 && v != -128),
            "calibrated logits must not be fully saturated"
        );
    }
}
