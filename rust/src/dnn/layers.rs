//! Quantized DNN layers (int8 symmetric, int32 accumulation).
//!
//! Every GEMM in every layer is routed through [`run_gemm`], which first
//! offers the call to the [`GemmHook`] installed in the [`ForwardCtx`].
//! This is the crate's analogue of the paper's PyTorch forward hooks: the
//! cross-layer runner intercepts exactly one GEMM (or one tile of one
//! GEMM) and executes it on the RTL mesh, while everything else runs on
//! the native software path.

use super::gemm::gemm_i8;
use super::im2col::{conv_out, im2col_group};
pub use super::tensor::Act;
use super::tensor::TensorI8;
use crate::util::quant::{quant_f32, requant_slice};

/// Identifies one GEMM call site during a forward pass.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GemmSiteId {
    /// Index of the layer in the model's layer list.
    pub layer: usize,
    /// Ordinal of the GEMM within the layer (groups, attention matmuls).
    pub ordinal: usize,
}

/// A GEMM call offered to the hook: `C = A . B + D` (flat row-major).
pub struct GemmCall<'s> {
    pub site: GemmSiteId,
    pub m: usize,
    pub k: usize,
    pub n: usize,
    pub a: &'s [i8],
    pub b: &'s [i8],
    pub d: &'s [i32],
}

/// Intercepts GEMMs during a forward pass (cross-layer offload, software
/// fault injection, call tracing...).
pub trait GemmHook {
    /// Return `true` to take over the call, leaving `C = A.B + D` in
    /// `out` (resized by the callee to `m * n`); return `false` — with
    /// `out` untouched — to let the native path run it. `out` is the
    /// layer's reusable accumulator, so a hook that computes into it
    /// allocates nothing per call (the campaign hot path).
    fn gemm(&mut self, call: &GemmCall<'_>, out: &mut Vec<i32>) -> bool;

    /// Offered the requantized int8 output of every layer (SW-level
    /// output injection); may mutate it in place.
    fn layer_output(&mut self, _layer: usize, _out: &mut Act) {}
}

/// Per-forward-pass context.
pub struct ForwardCtx<'h> {
    pub hook: Option<&'h mut dyn GemmHook>,
    /// GEMM ordinal counter within the current layer.
    ordinal: usize,
    layer: usize,
}

impl<'h> ForwardCtx<'h> {
    pub fn new(hook: Option<&'h mut dyn GemmHook>) -> Self {
        ForwardCtx {
            hook,
            ordinal: 0,
            layer: 0,
        }
    }

    pub fn plain() -> ForwardCtx<'static> {
        ForwardCtx {
            hook: None,
            ordinal: 0,
            layer: 0,
        }
    }

    fn begin_layer(&mut self, layer: usize) {
        self.layer = layer;
        self.ordinal = 0;
    }
}

/// All GEMMs funnel through here, draining into `acc` — the layer's
/// reusable accumulator buffer (cleared and resized in place, so
/// back-to-back GEMMs of one layer, and back-to-back trials replaying
/// it, reuse one allocation).
#[allow(clippy::too_many_arguments)]
pub fn run_gemm(
    ctx: &mut ForwardCtx<'_>,
    m: usize,
    k: usize,
    n: usize,
    a: &[i8],
    b: &[i8],
    d: &[i32],
    acc: &mut Vec<i32>,
) {
    let site = GemmSiteId {
        layer: ctx.layer,
        ordinal: ctx.ordinal,
    };
    ctx.ordinal += 1;
    acc.clear();
    if let Some(hook) = ctx.hook.as_deref_mut() {
        let call = GemmCall { site, m, k, n, a, b, d };
        if hook.gemm(&call, acc) {
            debug_assert_eq!(acc.len(), m * n);
            return;
        }
        debug_assert!(acc.is_empty(), "declined hooks must leave `out` untouched");
    }
    acc.resize(m * n, 0);
    gemm_i8(m, k, n, a, b, d, acc);
}

// ---------------------------------------------------------------------
// Layers
// ---------------------------------------------------------------------

/// Quantized 2-D convolution (supports grouped / depthwise via `groups`).
/// Weights are stored GEMM-ready: per group, a [cin_g*kh*kw, cout_g]
/// column-major-by-output matrix, groups concatenated.
#[derive(Clone, Debug)]
pub struct QConv2d {
    pub cin: usize,
    pub cout: usize,
    pub kh: usize,
    pub kw: usize,
    pub stride: usize,
    pub pad: usize,
    pub groups: usize,
    pub m: f32,
    pub relu: bool,
    pub wmat: Vec<i8>,
    pub bias: Vec<i32>,
}

impl QConv2d {
    pub fn param_count(&self) -> usize {
        self.wmat.len() + self.bias.len()
    }

    pub fn out_shape(&self, x: &TensorI8) -> (usize, usize, usize) {
        (
            self.cout,
            conv_out(x.shape[1], self.kh, self.stride, self.pad),
            conv_out(x.shape[2], self.kw, self.stride, self.pad),
        )
    }

    pub fn forward(&self, x: &TensorI8, ctx: &mut ForwardCtx<'_>) -> TensorI8 {
        assert_eq!(x.shape[0], self.cin, "channel mismatch");
        assert_eq!(self.cin % self.groups, 0);
        assert_eq!(self.cout % self.groups, 0);
        let cin_g = self.cin / self.groups;
        let cout_g = self.cout / self.groups;
        let kelems = cin_g * self.kh * self.kw;
        let (_c, oh, ow) = self.out_shape(x);
        let p = oh * ow;
        let mut out = TensorI8::zeros(&[self.cout, oh, ow]);
        let mut q = vec![0i8; p * cout_g];
        // one accumulator buffer shared by every group's GEMM
        let mut acc = Vec::new();
        for g in 0..self.groups {
            let (patches, _, _) = im2col_group(
                x,
                self.kh,
                self.kw,
                self.stride,
                self.pad,
                g * cin_g,
                (g + 1) * cin_g,
            );
            let w_g = &self.wmat[g * kelems * cout_g..(g + 1) * kelems * cout_g];
            let bias_g = &self.bias[g * cout_g..(g + 1) * cout_g];
            // bias broadcast over pixels
            let mut d = vec![0i32; p * cout_g];
            for pix in 0..p {
                d[pix * cout_g..(pix + 1) * cout_g].copy_from_slice(bias_g);
            }
            run_gemm(ctx, p, kelems, cout_g, &patches, w_g, &d, &mut acc);
            requant_slice(&acc, self.m, self.relu, &mut q);
            // [P, cout_g] -> CHW
            for oc in 0..cout_g {
                let ch = g * cout_g + oc;
                for pix in 0..p {
                    out.data[ch * p + pix] = q[pix * cout_g + oc];
                }
            }
        }
        out
    }
}

/// Quantized linear layer applied row-wise to an [L, in_f] matrix.
#[derive(Clone, Debug)]
pub struct QLinear {
    pub in_f: usize,
    pub out_f: usize,
    pub m: f32,
    pub relu: bool,
    /// [in_f, out_f] row-major.
    pub w: Vec<i8>,
    pub bias: Vec<i32>,
}

impl QLinear {
    pub fn param_count(&self) -> usize {
        self.w.len() + self.bias.len()
    }

    pub fn forward(&self, x: &TensorI8, ctx: &mut ForwardCtx<'_>) -> TensorI8 {
        let l = x.shape[0];
        assert_eq!(x.shape[1], self.in_f, "linear input width mismatch");
        let mut d = vec![0i32; l * self.out_f];
        for row in 0..l {
            d[row * self.out_f..(row + 1) * self.out_f].copy_from_slice(&self.bias);
        }
        let mut acc = Vec::new();
        run_gemm(ctx, l, self.in_f, self.out_f, &x.data, &self.w, &d, &mut acc);
        let mut q = vec![0i8; l * self.out_f];
        requant_slice(&acc, self.m, self.relu, &mut q);
        TensorI8::from_vec(&[l, self.out_f], q)
    }
}

/// Single-head quantized attention block (I-ViT style): integer
/// projections and AV/output matmuls, f32 softmax requantized to [0,127].
/// Mirrors `python/compile/model.py::make_qattention` bit-for-bit on the
/// integer path.
#[derive(Clone, Debug)]
pub struct QAttention {
    pub d_model: usize,
    pub wq: Vec<i8>,
    pub wk: Vec<i8>,
    pub wv: Vec<i8>,
    pub wo: Vec<i8>,
    pub mq: f32,
    pub mk: f32,
    pub mv: f32,
    pub ms: f32,
    pub mo: f32,
    pub mw: f32,
}

impl QAttention {
    pub fn param_count(&self) -> usize {
        4 * self.d_model * self.d_model
    }

    pub fn forward(&self, x: &TensorI8, ctx: &mut ForwardCtx<'_>) -> TensorI8 {
        let l = x.shape[0];
        let dm = self.d_model;
        assert_eq!(x.shape[1], dm);
        let zeros_ld = vec![0i32; l * dm];
        // one accumulator buffer shared by all six GEMMs of the block
        let mut acc = Vec::new();
        let proj = |ctx: &mut ForwardCtx<'_>, acc: &mut Vec<i32>, w: &[i8], m: f32| {
            run_gemm(ctx, l, dm, dm, &x.data, w, &zeros_ld, acc);
            let mut q = vec![0i8; l * dm];
            requant_slice(acc, m, false, &mut q);
            q
        };
        let q = proj(ctx, &mut acc, &self.wq, self.mq);
        let k = proj(ctx, &mut acc, &self.wk, self.mk);
        let v = proj(ctx, &mut acc, &self.wv, self.mv);
        // S = Q . K^T  (transpose K into GEMM layout)
        let mut kt = vec![0i8; dm * l];
        for i in 0..l {
            for j in 0..dm {
                kt[j * l + i] = k[i * dm + j];
            }
        }
        let zeros_ll = vec![0i32; l * l];
        run_gemm(ctx, l, dm, l, &q, &kt, &zeros_ll, &mut acc);
        let s = &acc;
        // f32 softmax over rows, probabilities quantized to [0, 127]
        let mut p_i8 = vec![0i8; l * l];
        for row in 0..l {
            let srow = &s[row * l..(row + 1) * l];
            let maxv = srow
                .iter()
                .map(|&x| x as f32 * self.ms)
                .fold(f32::NEG_INFINITY, f32::max);
            let exps: Vec<f32> = srow
                .iter()
                .map(|&x| (x as f32 * self.ms - maxv).exp())
                .collect();
            let sum: f32 = exps.iter().sum();
            for (j, e) in exps.iter().enumerate() {
                p_i8[row * l + j] = quant_f32(e / sum, 127.0).max(0);
            }
        }
        // O = P . V, Y = O . Wo
        run_gemm(ctx, l, l, dm, &p_i8, &v, &zeros_ld, &mut acc);
        let mut o = vec![0i8; l * dm];
        requant_slice(&acc, self.mo, false, &mut o);
        run_gemm(ctx, l, dm, dm, &o, &self.wo, &zeros_ld, &mut acc);
        let mut y = vec![0i8; l * dm];
        requant_slice(&acc, self.mw, false, &mut y);
        TensorI8::from_vec(&[l, dm], y)
    }
}

/// Saturating residual add: `y = sat(x + f(x))` around a sub-stack.
#[derive(Clone, Debug)]
pub struct Residual {
    pub body: Vec<Layer>,
}

/// Parallel branches concatenated along channels (Inception-style).
#[derive(Clone, Debug)]
pub struct ParallelConcat {
    pub branches: Vec<Vec<Layer>>,
}

/// The layer algebra of the model zoo.
#[derive(Clone, Debug)]
pub enum Layer {
    Conv(QConv2d),
    Linear(QLinear),
    Attention(QAttention),
    Residual(Residual),
    ParallelConcat(ParallelConcat),
    /// 2x2 (or kxk) max pooling.
    MaxPool { k: usize, stride: usize },
    /// Global average pool: [C,H,W] -> tokens [1, C].
    GlobalAvgPool,
    /// Channel shuffle (ShuffleNet).
    ChannelShuffle { groups: usize },
    /// [C,H,W] -> tokens [H*W, C] (patch embedding output).
    ToTokens,
    /// Mean over tokens: [L, D] -> [1, D] (ViT classification pooling).
    TokenMean,
    /// ReLU applied in place (for post-residual activation).
    Relu,
}

impl Layer {
    /// Number of parameters (Table II reporting).
    pub fn param_count(&self) -> usize {
        match self {
            Layer::Conv(c) => c.param_count(),
            Layer::Linear(l) => l.param_count(),
            Layer::Attention(a) => a.param_count(),
            Layer::Residual(r) => r.body.iter().map(Layer::param_count).sum(),
            Layer::ParallelConcat(p) => p
                .branches
                .iter()
                .flat_map(|b| b.iter().map(Layer::param_count))
                .sum(),
            _ => 0,
        }
    }

    /// Forward one layer. `li` is the flat layer index used for GEMM-site
    /// addressing (nested layers share their parent's index).
    pub fn forward(&self, x: &Act, li: usize, ctx: &mut ForwardCtx<'_>) -> Act {
        ctx.begin_layer(li);
        match self {
            Layer::Conv(c) => Act::Chw(c.forward(x.chw(), ctx)),
            Layer::Linear(l) => Act::Tokens(l.forward(x.tensor(), ctx)),
            Layer::Attention(a) => Act::Tokens(a.forward(x.tokens(), ctx)),
            Layer::Residual(res) => {
                let mut h = x.clone();
                for layer in &res.body {
                    h = layer.forward(&h, li, ctx);
                    ctx.begin_layer(li); // keep the parent's site addressing
                }
                let xt = x.tensor();
                let ht = h.tensor_mut();
                assert_eq!(xt.shape, ht.shape, "residual shape mismatch");
                for (hv, &xv) in ht.data.iter_mut().zip(&xt.data) {
                    *hv = hv.saturating_add(xv);
                }
                h
            }
            Layer::ParallelConcat(pc) => {
                let mut chans: Vec<TensorI8> = Vec::new();
                for branch in &pc.branches {
                    let mut h = x.clone();
                    for layer in branch {
                        h = layer.forward(&h, li, ctx);
                        ctx.begin_layer(li);
                    }
                    chans.push(h.chw().clone());
                }
                let (hh, ww) = (chans[0].shape[1], chans[0].shape[2]);
                let total_c: usize = chans.iter().map(|t| t.shape[0]).sum();
                let mut out = TensorI8::zeros(&[total_c, hh, ww]);
                let mut off = 0;
                for t in &chans {
                    assert_eq!((t.shape[1], t.shape[2]), (hh, ww));
                    out.data[off..off + t.data.len()].copy_from_slice(&t.data);
                    off += t.data.len();
                }
                Act::Chw(out)
            }
            Layer::MaxPool { k, stride } => {
                let t = x.chw();
                let (c, h, w) = (t.shape[0], t.shape[1], t.shape[2]);
                let oh = (h - k) / stride + 1;
                let ow = (w - k) / stride + 1;
                let mut out = TensorI8::zeros(&[c, oh, ow]);
                for cc in 0..c {
                    for oy in 0..oh {
                        for ox in 0..ow {
                            let mut best = i8::MIN;
                            for dy in 0..*k {
                                for dx in 0..*k {
                                    best =
                                        best.max(t.at3(cc, oy * stride + dy, ox * stride + dx));
                                }
                            }
                            out.data[(cc * oh + oy) * ow + ox] = best;
                        }
                    }
                }
                Act::Chw(out)
            }
            Layer::GlobalAvgPool => {
                let t = x.chw();
                let (c, h, w) = (t.shape[0], t.shape[1], t.shape[2]);
                let n = (h * w) as f32;
                let mut out = TensorI8::zeros(&[1, c]);
                for cc in 0..c {
                    let sum: i32 = t.data[cc * h * w..(cc + 1) * h * w]
                        .iter()
                        .map(|&v| v as i32)
                        .sum();
                    out.data[cc] = (sum as f32 / n + 0.5).floor().clamp(-128.0, 127.0) as i8;
                }
                Act::Tokens(out)
            }
            Layer::ChannelShuffle { groups } => {
                let t = x.chw();
                let (c, h, w) = (t.shape[0], t.shape[1], t.shape[2]);
                assert_eq!(c % groups, 0);
                let per = c / groups;
                let mut out = TensorI8::zeros(&[c, h, w]);
                for cc in 0..c {
                    // (g, i) -> (i, g) transpose of channel groups
                    let (g, i) = (cc / per, cc % per);
                    let dst = i * groups + g;
                    out.data[dst * h * w..(dst + 1) * h * w]
                        .copy_from_slice(&t.data[cc * h * w..(cc + 1) * h * w]);
                }
                Act::Chw(out)
            }
            Layer::ToTokens => {
                let t = x.chw();
                let (c, h, w) = (t.shape[0], t.shape[1], t.shape[2]);
                let l = h * w;
                let mut out = TensorI8::zeros(&[l, c]);
                for cc in 0..c {
                    for pix in 0..l {
                        out.data[pix * c + cc] = t.data[cc * l + pix];
                    }
                }
                Act::Tokens(out)
            }
            Layer::TokenMean => {
                let t = x.tokens();
                let (l, d) = (t.shape[0], t.shape[1]);
                let mut out = TensorI8::zeros(&[1, d]);
                for j in 0..d {
                    let sum: i32 = (0..l).map(|i| t.data[i * d + j] as i32).sum();
                    out.data[j] =
                        (sum as f32 / l as f32 + 0.5).floor().clamp(-128.0, 127.0) as i8;
                }
                Act::Tokens(out)
            }
            Layer::Relu => {
                let mut out = x.clone();
                for v in out.tensor_mut().data.iter_mut() {
                    *v = (*v).max(0);
                }
                out
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn conv_fixture(groups: usize) -> (QConv2d, TensorI8) {
        let mut rng = Rng::new(51);
        let (cin, cout, k) = (4usize, 6usize, 3usize);
        let cin_g = cin / groups;
        let cout_g = cout / groups;
        let conv = QConv2d {
            cin,
            cout,
            kh: k,
            kw: k,
            stride: 1,
            pad: 1,
            groups,
            m: 0.03,
            relu: true,
            wmat: {
                let mut w = vec![0i8; groups * cin_g * k * k * cout_g];
                rng.fill_i8(&mut w);
                w
            },
            bias: (0..cout as i32).map(|v| v * 10).collect(),
        };
        let x = TensorI8::random(&[cin, 6, 6], &mut rng);
        (conv, x)
    }

    /// Direct (definition-level) convolution oracle.
    fn conv_oracle(conv: &QConv2d, x: &TensorI8) -> TensorI8 {
        let (cout, oh, ow) = conv.out_shape(x);
        let cin_g = conv.cin / conv.groups;
        let cout_g = conv.cout / conv.groups;
        let kelems = cin_g * conv.kh * conv.kw;
        let mut out = TensorI8::zeros(&[cout, oh, ow]);
        for oc in 0..cout {
            let g = oc / cout_g;
            let ocg = oc % cout_g;
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut acc = conv.bias[oc];
                    for ic in 0..cin_g {
                        for ky in 0..conv.kh {
                            for kx in 0..conv.kw {
                                let iy =
                                    (oy * conv.stride + ky) as isize - conv.pad as isize;
                                let ix =
                                    (ox * conv.stride + kx) as isize - conv.pad as isize;
                                if iy < 0
                                    || ix < 0
                                    || iy >= x.shape[1] as isize
                                    || ix >= x.shape[2] as isize
                                {
                                    continue;
                                }
                                let xv =
                                    x.at3(g * cin_g + ic, iy as usize, ix as usize) as i32;
                                let widx = ((ic * conv.kh + ky) * conv.kw + kx) * cout_g + ocg;
                                let wv = conv.wmat[g * kelems * cout_g + widx] as i32;
                                acc = acc.wrapping_add(xv * wv);
                            }
                        }
                    }
                    let mut q = crate::util::quant::requant(acc, conv.m);
                    if conv.relu {
                        q = q.max(0);
                    }
                    out.data[(oc * oh + oy) * ow + ox] = q;
                }
            }
        }
        out
    }

    #[test]
    fn conv_matches_definition_oracle() {
        for groups in [1usize, 2] {
            let (conv, x) = conv_fixture(groups);
            let got = conv.forward(&x, &mut ForwardCtx::plain());
            let want = conv_oracle(&conv, &x);
            assert_eq!(got, want, "groups={groups}");
        }
    }

    #[test]
    fn depthwise_conv_runs() {
        let mut rng = Rng::new(52);
        let conv = QConv2d {
            cin: 4,
            cout: 4,
            kh: 3,
            kw: 3,
            stride: 1,
            pad: 1,
            groups: 4,
            m: 0.05,
            relu: false,
            wmat: {
                let mut w = vec![0i8; 4 * 9];
                rng.fill_i8(&mut w);
                w
            },
            bias: vec![0; 4],
        };
        let x = TensorI8::random(&[4, 5, 5], &mut rng);
        let got = conv.forward(&x, &mut ForwardCtx::plain());
        assert_eq!(got.shape, vec![4, 5, 5]);
        assert_eq!(got, conv_oracle(&conv, &x));
    }

    #[test]
    fn linear_matches_manual() {
        let lin = QLinear {
            in_f: 3,
            out_f: 2,
            m: 1.0,
            relu: false,
            w: vec![1, 0, 0, 1, 1, 1], // [3,2]
            bias: vec![5, -5],
        };
        let x = TensorI8::from_vec(&[1, 3], vec![1, 2, 3]);
        let y = lin.forward(&x, &mut ForwardCtx::plain());
        // y0 = 1*1 + 2*0 + 3*1 + 5 = 9 ; y1 = 0 + 2 + 3 - 5 = 0
        assert_eq!(y.data, vec![9, 0]);
    }

    #[test]
    fn global_avg_pool_rounds_half_up() {
        let x = TensorI8::from_vec(&[1, 2, 2], vec![1, 2, 2, 2]); // mean 1.75
        let y = Layer::GlobalAvgPool.forward(&Act::Chw(x), 0, &mut ForwardCtx::plain());
        assert_eq!(y.tokens().data, vec![2]);
    }

    #[test]
    fn channel_shuffle_is_permutation() {
        let mut rng = Rng::new(53);
        let x = TensorI8::random(&[6, 2, 2], &mut rng);
        let y = Layer::ChannelShuffle { groups: 2 }.forward(
            &Act::Chw(x.clone()),
            0,
            &mut ForwardCtx::plain(),
        );
        let yt = y.chw();
        // channel (g, i) moves to i*groups + g
        for g in 0..2 {
            for i in 0..3 {
                let src = g * 3 + i;
                let dst = i * 2 + g;
                assert_eq!(
                    &yt.data[dst * 4..(dst + 1) * 4],
                    &x.data[src * 4..(src + 1) * 4]
                );
            }
        }
    }

    #[test]
    fn residual_adds_saturating() {
        // body = identity (empty) => y = sat(x + x)
        let x = TensorI8::from_vec(&[1, 1, 2], vec![100, -100]);
        let y = Layer::Residual(Residual { body: vec![] }).forward(
            &Act::Chw(x),
            0,
            &mut ForwardCtx::plain(),
        );
        assert_eq!(y.chw().data, vec![127, -128]);
    }

    #[test]
    fn attention_shapes_and_determinism() {
        let mut rng = Rng::new(54);
        let dm = 8;
        let attn = QAttention {
            d_model: dm,
            wq: TensorI8::random(&[dm * dm], &mut rng).data,
            wk: TensorI8::random(&[dm * dm], &mut rng).data,
            wv: TensorI8::random(&[dm * dm], &mut rng).data,
            wo: TensorI8::random(&[dm * dm], &mut rng).data,
            mq: 0.02,
            mk: 0.02,
            mv: 0.02,
            ms: 0.05,
            mo: 0.05,
            mw: 0.03,
        };
        let x = TensorI8::random(&[4, dm], &mut rng);
        let y1 = attn.forward(&x, &mut ForwardCtx::plain());
        let y2 = attn.forward(&x, &mut ForwardCtx::plain());
        assert_eq!(y1.shape, vec![4, dm]);
        assert_eq!(y1, y2);
    }

    #[test]
    fn gemm_hook_sees_all_sites() {
        struct Counter(Vec<GemmSiteId>);
        impl GemmHook for Counter {
            fn gemm(&mut self, call: &GemmCall<'_>, _out: &mut Vec<i32>) -> bool {
                self.0.push(call.site);
                false
            }
        }
        let mut rng = Rng::new(55);
        let dm = 4;
        let attn = QAttention {
            d_model: dm,
            wq: TensorI8::random(&[dm * dm], &mut rng).data,
            wk: TensorI8::random(&[dm * dm], &mut rng).data,
            wv: TensorI8::random(&[dm * dm], &mut rng).data,
            wo: TensorI8::random(&[dm * dm], &mut rng).data,
            mq: 0.02,
            mk: 0.02,
            mv: 0.02,
            ms: 0.05,
            mo: 0.05,
            mw: 0.03,
        };
        let x = TensorI8::random(&[2, dm], &mut rng);
        let mut counter = Counter(vec![]);
        let mut ctx = ForwardCtx::new(Some(&mut counter));
        ctx.begin_layer(7);
        attn.forward(&x, &mut ctx);
        // q, k, v projections + qk^T + pv + out = 6 GEMMs, ordinals 0..6
        assert_eq!(counter.0.len(), 6);
        assert!(counter.0.iter().all(|s| s.layer == 7));
        assert_eq!(
            counter.0.iter().map(|s| s.ordinal).collect::<Vec<_>>(),
            vec![0, 1, 2, 3, 4, 5]
        );
    }

    #[test]
    fn hook_can_override_gemm() {
        struct Zeroer;
        impl GemmHook for Zeroer {
            fn gemm(&mut self, call: &GemmCall<'_>, out: &mut Vec<i32>) -> bool {
                out.resize(call.m * call.n, 0);
                true
            }
        }
        let lin = QLinear {
            in_f: 2,
            out_f: 2,
            m: 1.0,
            relu: false,
            w: vec![1, 1, 1, 1],
            bias: vec![9, 9],
        };
        let x = TensorI8::from_vec(&[1, 2], vec![1, 1]);
        let mut z = Zeroer;
        let mut ctx = ForwardCtx::new(Some(&mut z));
        let y = lin.forward(&x, &mut ctx);
        assert_eq!(y.data, vec![0, 0], "hook result replaced the GEMM");
    }
}
