//! `enfor-sa` — the command-line front end of the framework.
//!
//! Subcommands map one-to-one onto the paper's experiments (see
//! DESIGN.md §5 for the table/figure index):
//!
//! ```text
//! enfor-sa models                          Table II
//! enfor-sa cycle-bench  [--dims 4,8,..]    Table III
//! enfor-sa matmul-bench [--dims ..]        Table IV
//! enfor-sa layer-bench  [--dims ..]        Table V
//! enfor-sa campaign --model <name> ...     Table VI (one model)
//! enfor-sa campaign merge <dir>...         fold sharded campaign dirs
//! enfor-sa suite table6 --models a,b,..    Table VI (many models)
//! enfor-sa maps --signal control|weight    Fig. 5a / 5b
//! enfor-sa validate                        §IV-B accuracy validation
//! enfor-sa report --state-inventory        DESIGN.md D2 ablation data
//! ```
//!
//! Campaign-bearing subcommands (`campaign`, `suite`) take a fault
//! scenario via `--scenario <spec>` (also JSON `campaign.scenario`):
//!
//! ```text
//! --scenario seu          one transient single-bit flip (default; the
//!                         paper's model — bit-identical to the legacy
//!                         single-fault campaigns for a fixed seed)
//! --scenario mbu:<k>      multi-bit upset: k >= 1 adjacent bits of one
//!                         sampled signal flip in the same cycle
//! --scenario burst:<r>    spatially-correlated strike: the sampled SEU
//!                         replicated same-cycle across every PE within
//!                         Chebyshev radius r
//! --scenario double-seu   two independent space/time draws in one tile
//! --scenario stuck:<0|1>  permanent stuck-at-v defect from the sampled
//!                         cycle onward
//! ```
//!
//! ... a mesh dataflow via `--dataflow` (JSON `mesh.dataflow`):
//!
//! ```text
//! --dataflow os           output-stationary (default; the paper's
//!                         configuration): accumulators stay in the
//!                         PEs, weights stream west->east, activations
//!                         north->south; trials offload one output
//!                         tile with the full-K stream
//! --dataflow ws           weight-stationary: DIM x DIM weight tiles
//!                         preloaded, activations stream west->east,
//!                         psums flow north->south; trials offload one
//!                         weight tile with the full M-row activation
//!                         panel. Every scenario / engine / backend
//!                         knob composes with it, the whole-SoC backend
//!                         included (its controller opens a WS
//!                         preload/compute window from the same command
//!                         stream shape)
//! ```
//!
//! ... a trial engine via `--trial-engine site-resume|full-forward`
//! (JSON `campaign.trial_engine`), and an RTL tile engine via
//! `--tile-engine` (JSON `campaign.tile_engine`):
//!
//! ```text
//! --tile-engine cycle-resume   snapshot the golden mesh trajectory per
//!                              offloaded tile and start every trial at
//!                              its first fault cycle; a site batch pays
//!                              each tile's golden prefix once (default).
//!                              On the whole-SoC backend the controller
//!                              snapshot also skips the command-decode/
//!                              DMA prefix and the fence/halt postfix
//! --tile-engine full           step every trial from cycle 0 — the
//!                              bit-exactness oracle for cycle-resume
//! --tile-engine lane-lockstep  cycle-resume plus lane batching: group a
//!                              site batch's same-tile trials into chunks
//!                              of `--lanes` and step each tile suffix
//!                              once through a lane-contiguous mesh, one
//!                              trial per lane. Bit-identical to the
//!                              other engines for a fixed seed at ANY
//!                              lane count (mesh backend only; HDFIT and
//!                              the whole-SoC backend fall back to
//!                              cycle-resume)
//! --tile-engine packed-lockstep
//!                              lane-lockstep plus cross-tile packing:
//!                              whole same-tile chunks whose lane totals
//!                              fit in `--lanes` are packed side by side
//!                              into ONE lane mesh pass — each group owns
//!                              its own operands, schedule and golden
//!                              cursor, shorter schedules retire early,
//!                              and the chunk pays max(span) instead of
//!                              sum(span). Bit-identical to the other
//!                              engines for a fixed seed at ANY lane
//!                              count, never more cycles than
//!                              lane-lockstep (same fallbacks: HDFIT and
//!                              the whole-SoC backend use cycle-resume)
//! --lanes <n>                  lane count for lane-lockstep and
//!                              packed-lockstep (default 8; n >= 1 —
//!                              lanes=1 degenerates to cycle-resume
//!                              exactly, cycle counts included). Ignored
//!                              by the other engines
//! ```
//!
//! ... a hardening configuration via `--hardening <spec>` (JSON
//! `campaign.hardening`; components compose with `+` and display
//! canonically as clip -> abft -> tmr -> detect):
//!
//! ```text
//! --hardening none          no mitigation (default) — campaigns stay
//!                           byte-identical to the unhardened injector
//! --hardening clip:<lo,hi>  range-clip diverged tile outputs to
//!                           [lo, hi] before they propagate
//! --hardening abft          ABFT row/column checksums per GEMM tile:
//!                           detection always, single-error correction
//!                           when the bad row x bad column is unique
//! --hardening tmr:<cols>    triplicate the <cols> most-exposed PE
//!                           columns (ranked by the exposure map of the
//!                           campaign dataflow) and vote their outputs
//! --hardening detect        end-to-end SDC detector on final logit
//!                           divergence (flags, never corrects)
//! --hardening clip:0,64+abft+tmr:2+detect     any '+' composition
//! ```
//!
//! Hardened campaigns classify every struck trial as detected /
//! corrected / escaped; coverage lands in the CLI summary, report.json
//! and the benchkit snapshot (schema v10). `--signals control` adds the
//! control-path fault targets (tile-sequencer / drain-FSM counters) to
//! the sampled signal set; lane engines fall back to cycle-resume for
//! batches that carry a control fault.
//!
//! ... and the durable-journal flags (ROADMAP "Durable campaign
//! journal"), which make campaigns resumable, O(1)-memory and
//! multi-process with byte-identical final reports:
//!
//! ```text
//! --campaign-dir <dir>    journal the run: write <dir>/manifest.json
//!                         once, append one fsynced JSONL line per
//!                         finished (input, site) batch to
//!                         <dir>/journal.jsonl, and emit the
//!                         deterministic <dir>/report.json (no
//!                         wall-clock fields) when the shard completes
//! --resume <dir>          continue an interrupted journaled run:
//!                         journaled batches are skipped, a torn final
//!                         line is truncated and re-executed, and the
//!                         manifest must match (seed/config/schema;
//!                         workers exempt — resume at any parallelism).
//!                         With --campaign-dir, spell it --resume=true
//! --shard i/N             own only the work units with unit % N == i
//!                         (one process + dir per shard, same seed and
//!                         config); `campaign merge` folds the N dirs
//! --max-batches <n>       stop this invocation after n pending batches
//!                         (kill/resume simulation: the journal stays a
//!                         valid prefix; resume finishes the rest)
//!
//! enfor-sa campaign merge <dir>... [--out report.json]
//!                         validate the dirs as the complete, disjoint
//!                         shard set of ONE campaign and fold their
//!                         journals (stable unit order) into the same
//!                         report a single-process run emits
//! ```

#![allow(clippy::needless_range_loop)]

use anyhow::{bail, Result};
use enfor_sa::benchkit;
use enfor_sa::campaign::{
    control_avf_map, exposure_map_for, weight_exposure_map, ws_weight_exposure_map,
};
use enfor_sa::config::{
    Backend, CampaignConfig, Config, Dataflow, HardeningConfig, MeshConfig, OffloadScope,
    Scenario, TileEngine, TrialEngine,
};
use enfor_sa::coordinator::{run_parallel, Args, Progress};
use enfor_sa::dnn::models;
use enfor_sa::journal::{merge_dirs, run_journaled, Shard};
use enfor_sa::mesh::driver::{gold_matmul, MatmulDriver};
use enfor_sa::mesh::hdfit::InstrumentedMesh;
use enfor_sa::mesh::{Mesh, SignalKind};
use enfor_sa::report::{
    campaign_report_json, format_pe_map, format_table, human_time, pe_map_json,
};
use enfor_sa::soc::Soc;
use enfor_sa::util::json::Json;
use enfor_sa::util::Rng;
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn usage() -> &'static str {
    "usage: enfor-sa <models|cycle-bench|matmul-bench|layer-bench|campaign|suite|maps|validate|report> [flags]\n\
     run `enfor-sa <cmd> --help` conceptually via DESIGN.md §5"
}

fn run(argv: Vec<String>) -> Result<()> {
    let args = Args::parse(argv)?;
    let cmd = args
        .positional
        .first()
        .map(String::as_str)
        .unwrap_or("help");
    match cmd {
        "models" => cmd_models(&args),
        "cycle-bench" => cmd_cycle_bench(&args),
        "matmul-bench" => cmd_matmul_bench(&args),
        "layer-bench" => cmd_layer_bench(&args),
        "campaign" => cmd_campaign(&args),
        "suite" => cmd_suite(&args),
        "maps" => cmd_maps(&args),
        "validate" => cmd_validate(&args),
        "report" => cmd_report(&args),
        _ => {
            println!("{}", usage());
            Ok(())
        }
    }
}

/// Common mesh/campaign configuration from flags (+ optional --config).
fn configs(args: &Args) -> Result<(MeshConfig, CampaignConfig)> {
    let mut cfg = match args.get("config") {
        Some(path) => Config::load(std::path::Path::new(path))?,
        None => Config::default(),
    };
    cfg.mesh.dim = args.usize_or("dim", cfg.mesh.dim)?;
    if let Some(df) = args.get("dataflow") {
        cfg.mesh.dataflow = Dataflow::parse(df)
            .ok_or_else(|| anyhow::anyhow!("bad --dataflow {df}"))?;
    }
    cfg.campaign.seed = args.u64_or("seed", cfg.campaign.seed)?;
    cfg.campaign.faults_per_layer = args.u64_or("faults", cfg.campaign.faults_per_layer)?;
    cfg.campaign.inputs = args.u64_or("inputs", cfg.campaign.inputs)?;
    cfg.campaign.workers = args.usize_or("workers", cfg.campaign.workers)?;
    if let Some(b) = args.get("backend") {
        cfg.campaign.backend =
            Backend::parse(b).ok_or_else(|| anyhow::anyhow!("bad --backend {b}"))?;
    }
    if let Some(s) = args.get("offload-scope") {
        cfg.campaign.offload_scope = OffloadScope::parse(s)
            .ok_or_else(|| anyhow::anyhow!("bad --offload-scope {s}"))?;
    }
    if let Some(s) = args.get("trial-engine") {
        cfg.campaign.engine = TrialEngine::parse(s)
            .ok_or_else(|| anyhow::anyhow!("bad --trial-engine {s} (site-resume|full-forward)"))?;
    }
    if let Some(s) = args.get("tile-engine") {
        cfg.campaign.tile_engine = TileEngine::parse(s).ok_or_else(|| {
            anyhow::anyhow!(
                "bad --tile-engine {s} (full|cycle-resume|lane-lockstep|packed-lockstep)"
            )
        })?;
    }
    cfg.campaign.lanes = args.usize_or("lanes", cfg.campaign.lanes)?;
    if let Some(s) = args.get("scenario") {
        cfg.campaign.scenario = Scenario::parse(s).ok_or_else(|| {
            anyhow::anyhow!("bad --scenario {s} (seu|mbu:<k>|burst:<r>|double-seu|stuck:<0|1>)")
        })?;
    }
    if let Some(s) = args.get("signals") {
        cfg.campaign.signals = s.split(',').map(str::to_string).collect();
    }
    if let Some(s) = args.get("hardening") {
        cfg.campaign.hardening = HardeningConfig::parse(s).ok_or_else(|| {
            anyhow::anyhow!(
                "bad --hardening {s} (none|clip:<lo,hi>|abft|tmr:<cols>|detect, '+'-composable)"
            )
        })?;
    }
    cfg.validate()?;
    Ok((cfg.mesh, cfg.campaign))
}

fn cmd_models(args: &Args) -> Result<()> {
    let seed = args.u64_or("seed", 42)?;
    args.finish()?;
    let zoo = models::zoo(seed);
    let rows: Vec<Vec<String>> = zoo
        .iter()
        .zip(models::TABLE_II.iter())
        .map(|(m, info)| {
            vec![
                m.name.clone(),
                format!("{:.2}%", info.paper_top1),
                format!("{:.2}M", info.paper_params_m),
                format!("{}", m.param_count()),
                format!("{}", m.layers.len()),
            ]
        })
        .collect();
    println!(
        "{}",
        format_table(
            "TABLE II: evaluated quantized models (paper metadata + this build)",
            &["Model", "Paper Top-1", "Paper params", "Our params", "Our layers"],
            &rows,
        )
    );
    Ok(())
}

fn cmd_cycle_bench(args: &Args) -> Result<()> {
    let dims = args.usize_list_or("dims", &[4, 8, 16, 32, 64])?;
    let cycles = args.u64_or("cycles", 1_000_000)?;
    args.finish()?;
    let rows = benchkit::cycle_time(&dims, cycles);
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                format!("DIM{}", r.dim),
                format!("{:.3}us", r.enforsa_us),
                format!("{:.3}us", r.hdfit_us),
                format!("{:.2}x", r.improvement()),
            ]
        })
        .collect();
    println!(
        "{}",
        format_table(
            &format!("TABLE III: mean cycle time ({cycles} raw step() calls)"),
            &["Array Size", "ENFOR-SA (mesh only)", "HDFIT (mesh only)", "Improvement"],
            &table,
        )
    );
    Ok(())
}

fn cmd_matmul_bench(args: &Args) -> Result<()> {
    let dims = args.usize_list_or("dims", &[4, 8, 16, 32, 64])?;
    let reps = args.u64_or("reps", 1000)?;
    args.finish()?;
    let rows = benchkit::matmul_time(&dims, reps);
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                format!("DIM{}", r.dim),
                format!("{:.3}ms", r.enforsa_ms),
                format!("{:.3}ms", r.hdfit_ms),
                format!("{:.2}x", r.improvement()),
            ]
        })
        .collect();
    println!(
        "{}",
        format_table(
            &format!("TABLE IV: mean matmul time ({reps} matmuls)"),
            &["Array Size", "ENFOR-SA (mesh only)", "HDFIT (mesh only)", "Improvement"],
            &table,
        )
    );
    Ok(())
}

fn cmd_layer_bench(args: &Args) -> Result<()> {
    let dims = args.usize_list_or("dims", &[4, 8, 16, 32, 64])?;
    args.finish()?;
    let rows = benchkit::layer_forward(&dims)?;
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                format!("DIM{}", r.dim),
                human_time(r.enforsa_s),
                human_time(r.full_soc_s),
                format!("{:.2}x", r.vs_full_soc()),
                human_time(r.hdfit_s),
                format!("{:.2}x", r.vs_hdfit()),
            ]
        })
        .collect();
    println!(
        "{}",
        format_table(
            "TABLE V: full forward pass of ResNet50's 1st conv layer",
            &["Array", "ENFOR-SA", "Full SoC", "vs Full SoC", "HDFIT", "vs HDFIT"],
            &table,
        )
    );
    Ok(())
}

fn cmd_campaign(args: &Args) -> Result<()> {
    if args.positional.get(1).map(String::as_str) == Some("merge") {
        return cmd_campaign_merge(args);
    }
    let (mesh_cfg, cc) = configs(args)?;
    let name = args.str_or("model", "quicknet");
    let out = args.get("out").map(str::to_string);
    // durable-journal flags — see the doc grammar above and ROADMAP
    // "Durable campaign journal"
    let campaign_dir = args.get("campaign-dir").map(str::to_string);
    let resume_arg = args.get("resume").map(str::to_string);
    let (dir, resume) = match (campaign_dir, resume_arg) {
        (Some(d), r) => (Some(d), r.is_some()),
        (None, Some(r)) if !matches!(r.as_str(), "true" | "1" | "yes") => (Some(r), true),
        (None, Some(_)) => bail!("--resume without a directory requires --campaign-dir <dir>"),
        (None, None) => (None, false),
    };
    let shard = match args.get("shard") {
        Some(s) => Shard::parse(s)?,
        None => Shard::default(),
    };
    let max_batches = match args.get("max-batches") {
        Some(v) => Some(v.parse::<u64>().map_err(|_| {
            anyhow::anyhow!("--max-batches expects an integer, got '{v}'")
        })?),
        None => None,
    };
    if dir.is_none() && (shard != Shard::default() || max_batches.is_some()) {
        bail!("--shard / --max-batches need a journaled run (--campaign-dir <dir>)");
    }
    args.finish()?;
    let model = models::by_name(&name, cc.seed)
        .ok_or_else(|| anyhow::anyhow!("unknown model '{name}'"))?;
    eprintln!(
        "campaign: model={name} backend={} engine={} tile-engine={} lanes={} scenario={} dim={} \
         dataflow={} inputs={} faults/layer={} hardening={}",
        cc.backend, cc.engine, cc.tile_engine, cc.lanes, cc.scenario, mesh_cfg.dim,
        mesh_cfg.dataflow, cc.inputs, cc.faults_per_layer, cc.hardening
    );
    let r = match dir {
        Some(dir) => {
            let progress = Arc::new(Progress::default());
            let stop = Arc::new(AtomicBool::new(false));
            let ticker = spawn_progress_ticker(Arc::clone(&progress), Arc::clone(&stop));
            let run = run_journaled(
                &model,
                &mesh_cfg,
                &cc,
                Path::new(&dir),
                shard,
                resume,
                max_batches,
                Some(Arc::clone(&progress)),
            );
            stop.store(true, Ordering::Relaxed);
            let _ = ticker.join();
            let run = run?;
            if run.torn_repaired {
                eprintln!("journal: torn final line truncated, its batch re-executed");
            }
            eprintln!(
                "journal: shard {shard} in {dir}: {} batches skipped, {} run, {}/{} journaled{}",
                run.batches_skipped,
                run.batches_run,
                run.batches_skipped + run.batches_run,
                run.batches_total,
                if run.completed { " (complete)" } else { "" }
            );
            if let Some(report) = &run.report {
                eprintln!("journal: wrote {}", report.display());
            }
            run.result
        }
        None => run_parallel(&model, &mesh_cfg, &cc, None)?,
    };
    let (lo, hi) = r.vuln.ci95();
    println!(
        "{}: trials={} critical={} exposed={} masked={}",
        r.model, r.vuln.trials, r.vuln.critical, r.exposed_trials, r.masked_trials
    );
    // per-scenario outcome row: masked / exposed / SDC (Top-1 flips)
    println!(
        "scenario {}: masked={} exposed={} sdc={}",
        r.scenario, r.masked_trials, r.exposed_trials, r.vuln.critical
    );
    println!(
        "VF = {:.4}% (95% CI [{:.4}%, {:.4}%])  wall = {}",
        r.vf() * 100.0,
        lo * 100.0,
        hi * 100.0,
        human_time(r.wall.as_secs_f64())
    );
    // lane-occupancy accounting: filled vs stepped lane-cycles (1.00
    // means every stepped lane carried a live trial; the cross-tile
    // packer's win shows up here as a higher fraction)
    if r.lane_cycles_stepped > 0 {
        println!(
            "RTL cycles = {}  lane occupancy = {:.2} ({}/{} lane-cycles filled)",
            r.rtl_cycles_stepped,
            r.lane_occupancy(),
            r.lane_cycles_filled,
            r.lane_cycles_stepped
        );
    }
    // hardening coverage row — only for armed campaigns, so `none`
    // output stays byte-identical to the unhardened CLI
    if !cc.hardening.is_none() {
        println!(
            "hardening {}: struck={} detected={} corrected={} escaped={} \
             detection coverage = {:.4}  correction coverage = {:.4}",
            cc.hardening,
            r.struck_trials(),
            r.detected_trials,
            r.corrected_trials,
            r.escaped_trials,
            r.detection_coverage(),
            r.correction_coverage()
        );
    }
    for (layer, v) in &r.per_layer {
        println!("  layer {layer:2}: VF {:.4}% ({} trials)", v.vf() * 100.0, v.trials);
    }
    if let Some(path) = out {
        // the deterministic report object plus this run's wall clock
        // (campaign-dir report.json files stay wall-free for diffing)
        let mut j = campaign_report_json(&r, cc.tile_engine, cc.lanes, cc.hardening);
        if let Json::Obj(m) = &mut j {
            m.insert("wall_s".to_string(), Json::num(r.wall.as_secs_f64()));
        }
        std::fs::write(&path, j.pretty())?;
        eprintln!("wrote {path}");
    }
    Ok(())
}

/// `campaign merge <dir>...` — fold complete shard journals into the
/// byte-identical single-process report.
fn cmd_campaign_merge(args: &Args) -> Result<()> {
    let out = args.get("out").map(str::to_string);
    args.finish()?;
    let dirs: Vec<&Path> = args.positional[2..].iter().map(Path::new).collect();
    if dirs.is_empty() {
        bail!("usage: enfor-sa campaign merge <dir>... [--out report.json]");
    }
    let merged = merge_dirs(&dirs)?;
    let r = &merged.result;
    let cc = &merged.manifest.campaign;
    println!(
        "merged {} shard dir(s): {} batches  trials={} critical={} exposed={} masked={}",
        dirs.len(),
        merged.batches,
        r.vuln.trials,
        r.vuln.critical,
        r.exposed_trials,
        r.masked_trials
    );
    let text = campaign_report_json(r, cc.tile_engine, cc.lanes, cc.hardening).pretty() + "\n";
    match out {
        Some(path) => {
            std::fs::write(&path, text)?;
            eprintln!("wrote {path}");
        }
        None => print!("{text}"),
    }
    Ok(())
}

/// Background stderr ticker for journaled campaigns: one progress line
/// per second (`done/total batches, trials/sec, ETA`).
fn spawn_progress_ticker(
    progress: Arc<Progress>,
    stop: Arc<AtomicBool>,
) -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || {
        let t0 = std::time::Instant::now();
        loop {
            // 100 ms polls so a finished campaign joins promptly; one
            // printed line per second
            for _ in 0..10 {
                std::thread::sleep(std::time::Duration::from_millis(100));
                if stop.load(Ordering::Relaxed) {
                    return;
                }
            }
            eprintln!("progress: {}", progress.line(t0.elapsed().as_secs_f64()));
        }
    })
}

fn cmd_suite(args: &Args) -> Result<()> {
    let which = args
        .positional
        .get(1)
        .map(String::as_str)
        .unwrap_or("table6");
    if which != "table6" {
        bail!("unknown suite '{which}' (available: table6)");
    }
    let (mesh_cfg, cc) = configs(args)?;
    let default_models: Vec<String> = models::TABLE_II
        .iter()
        .map(|i| i.name.to_string())
        .collect();
    let list: Vec<String> = match args.get("models") {
        Some(s) => s.split(',').map(str::to_string).collect(),
        None => default_models,
    };
    args.finish()?;
    let rows = benchkit::injection_table(&list, &mesh_cfg, &cc)?;
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.model.clone(),
                human_time(r.sw.wall.as_secs_f64()),
                human_time(r.rtl.wall.as_secs_f64()),
                format!("{:.2}%", r.slowdown_pct()),
                format!("{:.2}%", r.pvf_pct()),
                format!("{:.2}%", r.avf_pct()),
                format!("{:.2}x", r.resume_speedup_vs_full_forward()),
                format!("{:.2}x", r.soc_cycle_resume_speedup()),
                format!("{:.2}x", r.soc_vs_sw_slowdown()),
            ]
        })
        .collect();
    println!(
        "{}",
        format_table(
            "TABLE VI: injection time and AVF/PVF vulnerability factors",
            &[
                "Model",
                "SW (inputs)",
                "ENFOR-SA (RTL)",
                "Slowdown",
                "PVF*",
                "AVF*",
                "Resume speedup",
                "SoC resume speedup",
                "SoC/SW",
            ],
            &table,
        )
    );
    let mean_slow: f64 =
        rows.iter().map(|r| r.slowdown_pct()).sum::<f64>() / rows.len() as f64;
    let mean_pvf: f64 = rows.iter().map(|r| r.pvf_pct()).sum::<f64>() / rows.len() as f64;
    let mean_avf: f64 = rows.iter().map(|r| r.avf_pct()).sum::<f64>() / rows.len() as f64;
    println!("Mean slowdown {mean_slow:.2}%  mean PVF {mean_pvf:.2}%  mean AVF {mean_avf:.2}%");
    println!("*percentage of critical inferences");
    // per-scenario outcome rows (masked / exposed / SDC) for the RTL arm
    for r in &rows {
        println!(
            "scenario {} [{} {}]: masked={} exposed={} sdc={}",
            r.rtl.scenario,
            r.model,
            r.rtl.dataflow,
            r.rtl.masked_trials,
            r.rtl.exposed_trials,
            r.rtl.vuln.critical
        );
    }
    Ok(())
}

fn cmd_maps(args: &Args) -> Result<()> {
    let (mesh_cfg, cc) = configs(args)?;
    let signal = args.str_or("signal", "control");
    let trials = args.u64_or("trials-per-pe", 30)?;
    let model_name = args.str_or("model", "ResNet50");
    let out = args.get("out").map(str::to_string);
    args.finish()?;
    let mut json_maps = Vec::new();
    match signal.as_str() {
        "control" => {
            let model = models::by_name(&model_name, cc.seed)
                .ok_or_else(|| anyhow::anyhow!("unknown model '{model_name}'"))?;
            for kind in [SignalKind::Valid, SignalKind::Propag] {
                // model-level AVF map (the paper's Fig. 5a metric) on
                // the configured dataflow ...
                let map = control_avf_map(&model, 0, &mesh_cfg, trials, cc.seed, kind);
                println!("{}", format_pe_map(&map));
                json_maps.push(pe_map_json(&map));
                // ... plus the tile-level exposure map, which shows the
                // row gradient even at small trial budgets
                let emap = exposure_map_for(
                    mesh_cfg.dataflow,
                    mesh_cfg.dim,
                    27,
                    kind,
                    trials * 4,
                    cc.seed,
                );
                println!("{}", format_pe_map(&emap));
                json_maps.push(pe_map_json(&emap));
            }
        }
        "weight" => {
            let map = match mesh_cfg.dataflow {
                Dataflow::OutputStationary => {
                    weight_exposure_map(mesh_cfg.dim, 27, trials, cc.seed)
                }
                // WS streams M activation rows; 27 rows keeps the map
                // budget comparable to the OS K=27 stream
                Dataflow::WeightStationary => {
                    ws_weight_exposure_map(mesh_cfg.dim, 27, trials, cc.seed)
                }
            };
            println!("{}", format_pe_map(&map));
            json_maps.push(pe_map_json(&map));
        }
        other => bail!("unknown --signal '{other}' (control|weight)"),
    }
    if let Some(path) = out {
        std::fs::write(&path, Json::Arr(json_maps).pretty())?;
        eprintln!("wrote {path}");
    }
    Ok(())
}

fn cmd_validate(args: &Args) -> Result<()> {
    let dim = args.usize_or("dim", 8)?;
    let reps = args.u64_or("reps", 200)?;
    let seed = args.u64_or("seed", 0x5A11D)?;
    args.finish()?;
    let mut rng = Rng::new(seed);
    let mut mesh = Mesh::new(dim, Dataflow::OutputStationary);
    let mut hm = InstrumentedMesh::new(dim);
    let mut identical = 0u64;
    for i in 0..reps {
        let k = 1 + rng.usize_below(24);
        let a = rng.mat_i8(dim, k);
        let b = rng.mat_i8(k, dim);
        let d = rng.mat_i32(dim, dim, 1000);
        let fault = enfor_sa::campaign::sample_mesh_fault(dim, k, &mut rng, &[]);
        let c1 =
            MatmulDriver::new(&mut mesh).matmul_with_fault(a.view(), b.view(), d.view(), &fault);
        let c2 =
            MatmulDriver::new(&mut hm).matmul_with_fault(a.view(), b.view(), d.view(), &fault);
        if c1 == c2 {
            identical += 1;
        } else {
            eprintln!("MISMATCH at rep {i}: fault {fault}");
        }
        // also confirm fault-free equality with the software gold
        let g1 = MatmulDriver::new(&mut mesh).matmul(a.view(), b.view(), d.view());
        assert_eq!(
            g1,
            gold_matmul(a.view(), b.view(), d.view()),
            "fault-free RTL != SW gold"
        );
    }
    println!(
        "accuracy validation vs HDFIT: {identical}/{reps} identical faulty outputs"
    );
    if identical != reps {
        bail!("ENFOR-SA and HDFIT diverged");
    }
    Ok(())
}

fn cmd_report(args: &Args) -> Result<()> {
    if args.bool("state-inventory") {
        args.finish()?;
        let rows: Vec<Vec<String>> = [4usize, 8, 16, 32, 64]
            .iter()
            .map(|&dim| {
                let soc = Soc::new(dim);
                let mesh = Mesh::new(dim, Dataflow::OutputStationary);
                let ratio = soc.state_elements() as f64 / mesh.state_elements() as f64;
                vec![
                    format!("DIM{dim}"),
                    format!("{}", mesh.state_elements()),
                    format!("{}", soc.state_elements()),
                    format!("{ratio:.1}x"),
                ]
            })
            .collect();
        println!(
            "{}",
            format_table(
                "D2: per-cycle state inventory (why mesh isolation wins, and why\n\
                 the win shrinks with DIM — Table V's trend)",
                &["Array", "Mesh state", "Full-SoC state", "SoC/Mesh"],
                &rows,
            )
        );
        return Ok(());
    }
    args.finish()?;
    println!("available: --state-inventory");
    Ok(())
}
