//! Report rendering: paper-style tables and per-PE heat maps, as
//! monospace text and JSON.

use crate::campaign::{CampaignResult, PeMap};
use crate::config::{HardeningConfig, TileEngine};
use crate::util::json::Json;

/// Render an aligned monospace table (the shape the paper's tables use).
pub fn format_table(title: &str, headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let mut out = String::new();
    out.push_str(title);
    out.push('\n');
    let line = |w: &[usize]| -> String {
        let mut s = String::from("+");
        for width in w {
            s.push_str(&"-".repeat(width + 2));
            s.push('+');
        }
        s.push('\n');
        s
    };
    out.push_str(&line(&widths));
    out.push('|');
    for (h, &w) in headers.iter().zip(&widths) {
        out.push_str(&format!(" {h:w$} |"));
    }
    out.push('\n');
    out.push_str(&line(&widths));
    for row in rows {
        out.push('|');
        for (c, &w) in row.iter().zip(&widths) {
            out.push_str(&format!(" {c:>w$} |"));
        }
        out.push('\n');
    }
    out.push_str(&line(&widths));
    out
}

/// Render a per-PE heat map as ASCII (paper Fig. 5 style).
pub fn format_pe_map(map: &PeMap) -> String {
    let mut out = format!("{} ({}x{})\n", map.title, map.dim, map.dim);
    // column header
    out.push_str("      ");
    for c in 0..map.dim {
        out.push_str(&format!("  c{c:<4}"));
    }
    out.push('\n');
    for r in 0..map.dim {
        out.push_str(&format!("  r{r:<3}"));
        for c in 0..map.dim {
            out.push_str(&format!(" {:>6.3}", map.value(r, c)));
        }
        out.push('\n');
    }
    out
}

/// Per-PE map as JSON (for plotting outside).
pub fn pe_map_json(map: &PeMap) -> Json {
    let rows: Vec<Json> = (0..map.dim)
        .map(|r| {
            Json::Arr(
                (0..map.dim)
                    .map(|c| Json::Num(map.value(r, c)))
                    .collect(),
            )
        })
        .collect();
    Json::obj(vec![
        ("title", Json::str(map.title.clone())),
        ("dim", Json::num(map.dim as f64)),
        ("values", Json::Arr(rows)),
    ])
}

/// The canonical campaign report JSON — every field is a deterministic
/// function of `(seed, config, model)`: counters, labels and per-layer
/// estimates only, NO wall-clock times. This is what makes the
/// journal's bit-identity contract checkable with `diff`: a resumed,
/// sharded+merged or straight-through campaign emits byte-identical
/// report files (`Json::pretty` over `BTreeMap` is key-sorted). The
/// CLI `--out` path layers a `wall_s` field on top of this object;
/// campaign-dir `report.json` files are exactly this object.
///
/// The hardening fields (`hardening`, `detected`, `corrected`,
/// `escaped`, `detection_coverage`, `correction_coverage`) appear ONLY
/// when a mitigation is armed: a `--hardening none` campaign emits
/// byte-identical reports to the pre-hardening engine (the acceptance
/// pin of the hardening axis).
pub fn campaign_report_json(
    r: &CampaignResult,
    tile_engine: TileEngine,
    lanes: usize,
    hardening: HardeningConfig,
) -> Json {
    let per_layer: Vec<Json> = r
        .per_layer
        .iter()
        .map(|(layer, v)| {
            Json::obj(vec![
                ("layer", Json::num(*layer as f64)),
                ("trials", Json::num(v.trials as f64)),
                ("critical", Json::num(v.critical as f64)),
                ("vf", Json::num(v.vf())),
            ])
        })
        .collect();
    let mut fields = vec![
        ("model", Json::str(r.model.clone())),
        ("backend", Json::str(r.backend.to_string())),
        ("dataflow", Json::str(r.dataflow.to_string())),
        ("scenario", Json::str(r.scenario.to_string())),
        ("tile_engine", Json::str(tile_engine.to_string())),
        ("lanes", Json::num(lanes as f64)),
        ("trials", Json::num(r.vuln.trials as f64)),
        ("critical", Json::num(r.vuln.critical as f64)),
        ("exposed", Json::num(r.exposed_trials as f64)),
        ("masked", Json::num(r.masked_trials as f64)),
        ("rtl_cycles_stepped", Json::num(r.rtl_cycles_stepped as f64)),
        (
            "lane_cycles_filled",
            Json::num(r.lane_cycles_filled as f64),
        ),
        (
            "lane_cycles_stepped",
            Json::num(r.lane_cycles_stepped as f64),
        ),
        ("lane_occupancy", Json::num(r.lane_occupancy())),
        ("vf", Json::num(r.vf())),
        ("per_layer", Json::Arr(per_layer)),
    ];
    if !hardening.is_none() {
        fields.push(("hardening", Json::str(hardening.to_string())));
        fields.push(("detected", Json::num(r.detected_trials as f64)));
        fields.push(("corrected", Json::num(r.corrected_trials as f64)));
        fields.push(("escaped", Json::num(r.escaped_trials as f64)));
        fields.push(("detection_coverage", Json::num(r.detection_coverage())));
        fields.push(("correction_coverage", Json::num(r.correction_coverage())));
    }
    Json::obj(fields)
}

/// Format a duration in the paper's style (h / min / s / ms / us).
pub fn human_time(secs: f64) -> String {
    if secs >= 3600.0 {
        format!("{:.0}h{:02.0}min", (secs / 3600.0).floor(), (secs % 3600.0) / 60.0)
    } else if secs >= 60.0 {
        format!("{:.0}min{:02.0}s", (secs / 60.0).floor(), secs % 60.0)
    } else if secs >= 1.0 {
        format!("{secs:.2}s")
    } else if secs >= 1e-3 {
        format!("{:.3}ms", secs * 1e3)
    } else {
        format!("{:.3}us", secs * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::campaign::PeMap;

    #[test]
    fn table_is_aligned() {
        let t = format_table(
            "TABLE X",
            &["Model", "AVF"],
            &[
                vec!["ResNet50".into(), "0.34%".into()],
                vec!["X".into(), "1.00%".into()],
            ],
        );
        assert!(t.contains("TABLE X"));
        assert!(t.contains("| ResNet50 |"));
        let lines: Vec<&str> = t.lines().collect();
        let w = lines[1].len();
        assert!(lines.iter().skip(1).all(|l| l.len() == w));
    }

    #[test]
    fn map_renders_all_cells() {
        let mut m = PeMap::new(2, "t");
        for c in m.cells.iter_mut() {
            c.record(true);
        }
        let s = format_pe_map(&m);
        assert_eq!(s.matches("1.000").count(), 4);
    }

    #[test]
    fn human_time_units() {
        assert_eq!(human_time(7260.0), "2h01min");
        assert_eq!(human_time(61.0), "1min01s");
        assert_eq!(human_time(2.5), "2.50s");
        assert_eq!(human_time(0.0025), "2.500ms");
        assert_eq!(human_time(0.0000025), "2.500us");
    }

    #[test]
    fn campaign_report_json_is_deterministic_and_wall_free() {
        use crate::config::{Backend, Dataflow, Scenario};
        let mut r = CampaignResult::empty(
            "m",
            Backend::EnforSa,
            Scenario::Seu,
            Dataflow::OutputStationary,
        );
        r.vuln.trials = 10;
        r.vuln.critical = 2;
        r.exposed_trials = 3;
        r.masked_trials = 5;
        r.rtl_cycles_stepped = 1234;
        r.lane_cycles_filled = 900;
        r.lane_cycles_stepped = 1200;
        let v = r.vuln;
        r.per_layer.insert(0, v);
        let none = HardeningConfig::default();
        let j = campaign_report_json(&r, TileEngine::CycleResume, 8, none);
        let text = j.pretty();
        assert!(!text.contains("wall"), "report must be wall-clock free");
        assert_eq!(j.get("trials").unwrap().as_usize(), Some(10));
        assert_eq!(j.get("lane_cycles_filled").unwrap().as_usize(), Some(900));
        assert_eq!(j.get("lane_cycles_stepped").unwrap().as_usize(), Some(1200));
        assert_eq!(j.get("lane_occupancy").unwrap().as_f64(), Some(0.75));
        assert_eq!(j.get("per_layer").unwrap().as_arr().unwrap().len(), 1);
        // identical inputs -> identical bytes, the journal's diff contract
        let mut r2 = r.clone();
        r2.wall = std::time::Duration::from_secs(999); // wall differs...
        let text2 = campaign_report_json(&r2, TileEngine::CycleResume, 8, none).pretty();
        assert_eq!(text, text2); // ...bytes don't
    }

    #[test]
    fn hardening_report_fields_are_gated_on_an_armed_config() {
        use crate::config::{Backend, Dataflow, Scenario};
        let mut r = CampaignResult::empty(
            "m",
            Backend::EnforSa,
            Scenario::Seu,
            Dataflow::OutputStationary,
        );
        r.vuln.trials = 10;
        r.detected_trials = 2;
        r.corrected_trials = 1;
        r.escaped_trials = 1;
        // none: no hardening fields at all (byte-identity with pre-axis
        // reports), even if counters were somehow non-zero
        let none = campaign_report_json(&r, TileEngine::CycleResume, 8, HardeningConfig::default());
        assert!(none.get("hardening").is_none());
        assert!(none.get("detection_coverage").is_none());
        // armed: label + counters + coverage
        let h = HardeningConfig::parse("abft+detect").expect("valid hardening");
        let j = campaign_report_json(&r, TileEngine::CycleResume, 8, h);
        assert_eq!(j.get("hardening").unwrap().as_str(), Some("abft+detect"));
        assert_eq!(j.get("detected").unwrap().as_usize(), Some(2));
        assert_eq!(j.get("corrected").unwrap().as_usize(), Some(1));
        assert_eq!(j.get("escaped").unwrap().as_usize(), Some(1));
        assert_eq!(j.get("detection_coverage").unwrap().as_f64(), Some(0.75));
        assert_eq!(j.get("correction_coverage").unwrap().as_f64(), Some(0.25));
    }

    #[test]
    fn pe_map_json_shape() {
        let m = PeMap::new(3, "x");
        let j = pe_map_json(&m);
        assert_eq!(j.get("dim").unwrap().as_usize(), Some(3));
        assert_eq!(j.get("values").unwrap().as_arr().unwrap().len(), 3);
    }
}
