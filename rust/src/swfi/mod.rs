//! Software-level fault injection (the PVF baseline).
//!
//! Flips bits directly in software-visible tensors — layer outputs or
//! weights — exactly like PyTorchFI-class tools (paper §II): no notion
//! of how tensors map to hardware, hence no HW masking, hence the
//! systematically pessimistic PVF of Table VI.
//!
//! Since the scenario redesign the unit of injection is an [`SwPlan`]
//! (one or more targets applied in a single pass), mirroring the RTL
//! seam's `FaultPlan`: `seu` is a single-target plan sampled with the
//! legacy RNG order, `mbu:<k>` flips k adjacent bits of one element,
//! `burst:<r>` flips the same bit of a run of neighbouring elements,
//! `double-seu` draws two independent targets, and `stuck:<v>` forces a
//! bit to `v` instead of flipping it.

use crate::config::Scenario;
use crate::dnn::layers::{Act, GemmCall, GemmHook};
use crate::dnn::Model;
use crate::util::bits::{flip_i8, set_bit_i8};
use crate::util::Rng;

/// Where one software-level flip lands.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SwTarget {
    /// Bit of one element of one layer's int8 output tensor.
    LayerOutput { layer: usize, elem: usize, bit: u8 },
    /// Bit of one element of the weight operand of one GEMM site.
    /// (Transient: applied on one forward pass only.)
    Weight { layer: usize, ordinal: usize, elem: usize, bit: u8 },
    /// Bit of one layer-output element FORCED to `value` — the software
    /// view of a stuck-at defect over one inference.
    LayerOutputSet { layer: usize, elem: usize, bit: u8, value: bool },
}

impl SwTarget {
    /// The top-level layer the flip applies at — the resume point when
    /// the campaign replays only the suffix of the network.
    pub fn layer(&self) -> usize {
        match self {
            SwTarget::LayerOutput { layer, .. }
            | SwTarget::Weight { layer, .. }
            | SwTarget::LayerOutputSet { layer, .. } => *layer,
        }
    }
}

/// One or more software-level targets applied in a single forward pass
/// — the SW twin of the RTL seam's `FaultPlan`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SwPlan {
    pub targets: Vec<SwTarget>,
}

impl SwPlan {
    pub fn single(target: SwTarget) -> Self {
        SwPlan { targets: vec![target] }
    }

    /// Earliest target layer — the checkpoint the site-resume engine
    /// restarts from (every target applies at or after it).
    pub fn resume_layer(&self) -> usize {
        self.targets.iter().map(SwTarget::layer).min().unwrap_or(0)
    }
}

/// A hook that applies one software-level fault plan during a forward
/// pass (each target at most once).
pub struct SwInjector<'p> {
    pub plan: &'p SwPlan,
    applied: Vec<bool>,
}

impl<'p> SwInjector<'p> {
    pub fn new(plan: &'p SwPlan) -> Self {
        SwInjector {
            plan,
            applied: vec![false; plan.targets.len()],
        }
    }

    /// Did every target of the plan apply?
    pub fn applied_all(&self) -> bool {
        self.applied.iter().all(|&a| a)
    }
}

impl GemmHook for SwInjector<'_> {
    fn gemm(&mut self, call: &GemmCall<'_>, out: &mut Vec<i32>) -> bool {
        // collect every pending weight flip aimed at this call (an MBU
        // plan lands several flips on one operand), then run natively.
        // Same set semantics as `layer_output`: targets colliding after
        // the modulo resolution flip once, never cancel.
        let mut b: Option<Vec<i8>> = None;
        let mut flipped: Vec<(usize, u8)> = Vec::new();
        for (i, t) in self.plan.targets.iter().enumerate() {
            if self.applied[i] {
                continue;
            }
            if let SwTarget::Weight { layer, ordinal, elem, bit } = *t {
                if call.site.layer == layer && call.site.ordinal == ordinal {
                    let buf = b.get_or_insert_with(|| call.b.to_vec());
                    let e = elem % buf.len();
                    self.applied[i] = true;
                    if !flipped.contains(&(e, bit)) {
                        flipped.push((e, bit));
                        buf[e] = flip_i8(buf[e], bit);
                    }
                }
            }
        }
        match b {
            Some(buf) => {
                out.resize(call.m * call.n, 0);
                crate::dnn::gemm::gemm_i8(call.m, call.k, call.n, call.a, &buf, call.d, out);
                true
            }
            None => false,
        }
    }

    fn layer_output(&mut self, layer: usize, out: &mut Act) {
        // A plan's output-flip targets are a SET of (element, bit)
        // corruptions: targets are resolved modulo the tensor size, so a
        // burst wider than a small layer wraps onto elements it already
        // hit — without dedup the second flip would silently cancel the
        // first and the "burst" would self-neutralize. Distinct resolved
        // flips apply once each (set-bit targets are idempotent anyway).
        let mut flipped: Vec<(usize, u8)> = Vec::new();
        for (i, t) in self.plan.targets.iter().enumerate() {
            if self.applied[i] {
                continue;
            }
            match *t {
                SwTarget::LayerOutput { layer: tl, elem, bit } if tl == layer => {
                    self.applied[i] = true;
                    let tensor = out.tensor_mut();
                    let e = elem % tensor.data.len();
                    if !flipped.contains(&(e, bit)) {
                        flipped.push((e, bit));
                        tensor.data[e] = flip_i8(tensor.data[e], bit);
                    }
                }
                SwTarget::LayerOutputSet { layer: tl, elem, bit, value } if tl == layer => {
                    self.applied[i] = true;
                    let tensor = out.tensor_mut();
                    let e = elem % tensor.data.len();
                    tensor.data[e] = set_bit_i8(tensor.data[e], bit, value);
                }
                _ => {}
            }
        }
    }
}

/// Sample a uniform software fault target for a model (layer outputs).
pub fn sample_output_fault(model: &Model, rng: &mut Rng) -> SwTarget {
    let layer = rng.usize_below(model.layers.len());
    SwTarget::LayerOutput {
        layer,
        // element resolved modulo the actual tensor size at apply time
        elem: rng.next_u64() as usize,
        bit: rng.below(8) as u8,
    }
}

/// Sample a software fault plan under `scenario`. `seu` consumes the
/// RNG stream exactly like the legacy single-target sampler; the other
/// scenarios derive their plan from the same base draw (`double-seu`
/// adds one extra independent draw), mirroring the RTL samplers.
pub fn sample_sw_plan(model: &Model, scenario: Scenario, rng: &mut Rng) -> SwPlan {
    let base = sample_output_fault(model, rng);
    let SwTarget::LayerOutput { layer, elem, bit } = base else {
        unreachable!("sample_output_fault draws layer-output targets")
    };
    let targets = match scenario {
        Scenario::Seu => vec![base],
        Scenario::Mbu { bits } => {
            let n = bits.min(8);
            let start = bit.min(8 - n);
            (start..start + n)
                .map(|bit| SwTarget::LayerOutput { layer, elem, bit })
                .collect()
        }
        Scenario::Burst { radius } => {
            // spatial burst in tensor space: the same bit of (2r+1)^2
            // consecutive elements (the SW analogue of a Chebyshev ball;
            // wraps modulo the tensor size at apply time)
            let n = (2 * radius + 1) * (2 * radius + 1);
            (0..n)
                .map(|i| SwTarget::LayerOutput {
                    layer,
                    elem: elem.wrapping_add(i),
                    bit,
                })
                .collect()
        }
        Scenario::DoubleSeu => vec![base, sample_output_fault(model, rng)],
        Scenario::StuckAt { value } => {
            vec![SwTarget::LayerOutputSet { layer, elem, bit, value }]
        }
    };
    SwPlan { targets }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::engine::synthetic_input;
    use crate::dnn::models;

    #[test]
    fn output_flip_changes_logits_or_not_but_applies() {
        let model = models::quicknet(3);
        let mut rng = Rng::new(11);
        let x = synthetic_input(&model.input_shape, &mut rng);
        let golden = model.forward(&x, None);
        let plan = SwPlan::single(SwTarget::LayerOutput {
            layer: 5,
            elem: 0,
            bit: 6,
        });
        let mut inj = SwInjector::new(&plan);
        let faulty = model.forward(&x, Some(&mut inj));
        assert!(inj.applied_all());
        // flipping bit 6 of logit 0 changes the logits tensor itself
        assert_ne!(golden, faulty);
    }

    #[test]
    fn weight_flip_applies_once() {
        let model = models::quicknet(3);
        let mut rng = Rng::new(12);
        let x = synthetic_input(&model.input_shape, &mut rng);
        let plan = SwPlan::single(SwTarget::Weight {
            layer: 0,
            ordinal: 0,
            elem: 5,
            bit: 7,
        });
        let mut inj = SwInjector::new(&plan);
        let _ = model.forward(&x, Some(&mut inj));
        assert!(inj.applied_all());
    }

    #[test]
    fn high_bit_logit_flip_changes_top1() {
        // a deterministic critical case: flip the sign bit of the argmax
        let model = models::quicknet(3);
        let mut rng = Rng::new(13);
        let x = synthetic_input(&model.input_shape, &mut rng);
        let golden_logits = model.forward(&x, None);
        let top = crate::dnn::argmax(&golden_logits.data);
        let plan = SwPlan::single(SwTarget::LayerOutput {
            layer: 5,
            elem: top,
            bit: 7,
        });
        let mut inj = SwInjector::new(&plan);
        let faulty = model.forward(&x, Some(&mut inj));
        assert_ne!(crate::dnn::argmax(&faulty.data), top);
    }

    #[test]
    fn stuck_target_forces_the_bit_instead_of_flipping() {
        let model = models::quicknet(3);
        let mut rng = Rng::new(15);
        let x = synthetic_input(&model.input_shape, &mut rng);
        let golden = model.forward(&x, None);
        // force bit 6 of logit 0 to its golden value: fully masked
        let bit6 = (golden.data[0] >> 6) & 1 == 1;
        let plan = SwPlan::single(SwTarget::LayerOutputSet {
            layer: 5,
            elem: 0,
            bit: 6,
            value: bit6,
        });
        let mut inj = SwInjector::new(&plan);
        let same = model.forward(&x, Some(&mut inj));
        assert!(inj.applied_all());
        assert_eq!(same, golden, "stuck-at matching value is invisible");
        // force it to the opposite value: identical to a flip
        let plan2 = SwPlan::single(SwTarget::LayerOutputSet {
            layer: 5,
            elem: 0,
            bit: 6,
            value: !bit6,
        });
        let mut inj2 = SwInjector::new(&plan2);
        let forced = model.forward(&x, Some(&mut inj2));
        assert_ne!(forced, golden);
    }

    #[test]
    fn multi_target_plan_applies_every_target() {
        let model = models::quicknet(3);
        let mut rng = Rng::new(16);
        let x = synthetic_input(&model.input_shape, &mut rng);
        let golden = model.forward(&x, None);
        // MBU-like plan: two adjacent bits of the same logit
        let plan = SwPlan {
            targets: vec![
                SwTarget::LayerOutput { layer: 5, elem: 1, bit: 2 },
                SwTarget::LayerOutput { layer: 5, elem: 1, bit: 3 },
            ],
        };
        let mut inj = SwInjector::new(&plan);
        let faulty = model.forward(&x, Some(&mut inj));
        assert!(inj.applied_all());
        assert_eq!(
            faulty.data[1],
            golden.data[1] ^ 0b1100,
            "both bits flipped in one pass"
        );
        assert_eq!(plan.resume_layer(), 5);
    }

    #[test]
    fn wrapped_burst_targets_do_not_cancel() {
        // a burst wider than the layer wraps modulo the tensor: the
        // duplicate flips must NOT cancel the first ones (set semantics)
        let model = models::quicknet(3);
        let mut rng = Rng::new(20);
        let x = synthetic_input(&model.input_shape, &mut rng);
        let golden = model.forward(&x, None);
        // layer 5 is the 10-logit classifier; 25 consecutive elements
        // wrap 2.5 times around it
        let plan = SwPlan {
            targets: (0..25)
                .map(|i| SwTarget::LayerOutput { layer: 5, elem: i, bit: 2 })
                .collect(),
        };
        let mut inj = SwInjector::new(&plan);
        let faulty = model.forward(&x, Some(&mut inj));
        assert!(inj.applied_all());
        for (i, (fv, gv)) in faulty.data.iter().zip(&golden.data).enumerate() {
            assert_eq!(*fv, gv ^ 0b100, "logit {i}: exactly one net flip");
        }
    }

    #[test]
    fn sampler_is_deterministic() {
        let model = models::quicknet(3);
        let mut r1 = Rng::new(14);
        let mut r2 = Rng::new(14);
        assert_eq!(
            sample_output_fault(&model, &mut r1),
            sample_output_fault(&model, &mut r2)
        );
        for scenario in [
            Scenario::Seu,
            Scenario::Mbu { bits: 3 },
            Scenario::Burst { radius: 1 },
            Scenario::DoubleSeu,
            Scenario::StuckAt { value: false },
        ] {
            let mut r1 = Rng::new(17);
            let mut r2 = Rng::new(17);
            assert_eq!(
                sample_sw_plan(&model, scenario, &mut r1),
                sample_sw_plan(&model, scenario, &mut r2)
            );
        }
    }

    #[test]
    fn sw_seu_plan_matches_legacy_target_draw() {
        let model = models::quicknet(3);
        let mut r1 = Rng::new(18);
        let mut r2 = Rng::new(18);
        for _ in 0..100 {
            let plan = sample_sw_plan(&model, Scenario::Seu, &mut r1);
            let legacy = sample_output_fault(&model, &mut r2);
            assert_eq!(plan, SwPlan::single(legacy));
        }
        assert_eq!(r1.next_u64(), r2.next_u64(), "streams stay in lockstep");
    }

    #[test]
    fn sw_scenario_plan_shapes() {
        let model = models::quicknet(3);
        let mut rng = Rng::new(19);
        let mbu = sample_sw_plan(&model, Scenario::Mbu { bits: 3 }, &mut rng);
        assert_eq!(mbu.targets.len(), 3);
        let burst = sample_sw_plan(&model, Scenario::Burst { radius: 1 }, &mut rng);
        assert_eq!(burst.targets.len(), 9);
        let double = sample_sw_plan(&model, Scenario::DoubleSeu, &mut rng);
        assert_eq!(double.targets.len(), 2);
        let stuck = sample_sw_plan(&model, Scenario::StuckAt { value: true }, &mut rng);
        assert!(matches!(
            stuck.targets[0],
            SwTarget::LayerOutputSet { value: true, .. }
        ));
    }
}
