//! Software-level fault injection (the PVF baseline).
//!
//! Flips bits directly in software-visible tensors — layer outputs or
//! weights — exactly like PyTorchFI-class tools (paper §II): no notion
//! of how tensors map to hardware, hence no HW masking, hence the
//! systematically pessimistic PVF of Table VI.

use crate::dnn::layers::{Act, GemmCall, GemmHook};
use crate::dnn::Model;
use crate::util::bits::flip_i8;
use crate::util::Rng;

/// Where the software-level flip lands.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SwTarget {
    /// Bit of one element of one layer's int8 output tensor.
    LayerOutput { layer: usize, elem: usize, bit: u8 },
    /// Bit of one element of the weight operand of one GEMM site.
    /// (Transient: applied on one forward pass only.)
    Weight { layer: usize, ordinal: usize, elem: usize, bit: u8 },
}

impl SwTarget {
    /// The top-level layer the flip applies at — the resume point when
    /// the campaign replays only the suffix of the network.
    pub fn layer(&self) -> usize {
        match self {
            SwTarget::LayerOutput { layer, .. } | SwTarget::Weight { layer, .. } => *layer,
        }
    }
}

/// A hook that applies one software-level fault during a forward pass.
pub struct SwInjector {
    pub target: SwTarget,
    pub applied: bool,
}

impl SwInjector {
    pub fn new(target: SwTarget) -> Self {
        SwInjector {
            target,
            applied: false,
        }
    }
}

impl GemmHook for SwInjector {
    fn gemm(&mut self, call: &GemmCall<'_>) -> Option<Vec<i32>> {
        if let SwTarget::Weight { layer, ordinal, elem, bit } = self.target {
            if call.site.layer == layer && call.site.ordinal == ordinal && !self.applied {
                self.applied = true;
                // corrupt one weight element for this call only
                let mut b = call.b.to_vec();
                let e = elem % b.len();
                b[e] = flip_i8(b[e], bit);
                let mut c = vec![0i32; call.m * call.n];
                crate::dnn::gemm::gemm_i8(call.m, call.k, call.n, call.a, &b, call.d, &mut c);
                return Some(c);
            }
        }
        None
    }

    fn layer_output(&mut self, layer: usize, out: &mut Act) {
        if let SwTarget::LayerOutput { layer: tl, elem, bit } = self.target {
            if layer == tl && !self.applied {
                self.applied = true;
                let t = out.tensor_mut();
                let e = elem % t.data.len();
                t.data[e] = flip_i8(t.data[e], bit);
            }
        }
    }
}

/// Sample a uniform software fault target for a model (layer outputs).
pub fn sample_output_fault(model: &Model, rng: &mut Rng) -> SwTarget {
    let layer = rng.usize_below(model.layers.len());
    SwTarget::LayerOutput {
        layer,
        // element resolved modulo the actual tensor size at apply time
        elem: rng.next_u64() as usize,
        bit: rng.below(8) as u8,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::engine::synthetic_input;
    use crate::dnn::models;

    #[test]
    fn output_flip_changes_logits_or_not_but_applies() {
        let model = models::quicknet(3);
        let mut rng = Rng::new(11);
        let x = synthetic_input(&model.input_shape, &mut rng);
        let golden = model.forward(&x, None);
        let mut inj = SwInjector::new(SwTarget::LayerOutput {
            layer: 5,
            elem: 0,
            bit: 6,
        });
        let faulty = model.forward(&x, Some(&mut inj));
        assert!(inj.applied);
        // flipping bit 6 of logit 0 changes the logits tensor itself
        assert_ne!(golden, faulty);
    }

    #[test]
    fn weight_flip_applies_once() {
        let model = models::quicknet(3);
        let mut rng = Rng::new(12);
        let x = synthetic_input(&model.input_shape, &mut rng);
        let mut inj = SwInjector::new(SwTarget::Weight {
            layer: 0,
            ordinal: 0,
            elem: 5,
            bit: 7,
        });
        let _ = model.forward(&x, Some(&mut inj));
        assert!(inj.applied);
    }

    #[test]
    fn high_bit_logit_flip_changes_top1() {
        // a deterministic critical case: flip the sign bit of the argmax
        let model = models::quicknet(3);
        let mut rng = Rng::new(13);
        let x = synthetic_input(&model.input_shape, &mut rng);
        let golden_logits = model.forward(&x, None);
        let top = crate::dnn::argmax(&golden_logits.data);
        let mut inj = SwInjector::new(SwTarget::LayerOutput {
            layer: 5,
            elem: top,
            bit: 7,
        });
        let faulty = model.forward(&x, Some(&mut inj));
        assert_ne!(crate::dnn::argmax(&faulty.data), top);
    }

    #[test]
    fn sampler_is_deterministic() {
        let model = models::quicknet(3);
        let mut r1 = Rng::new(14);
        let mut r2 = Rng::new(14);
        assert_eq!(
            sample_output_fault(&model, &mut r1),
            sample_output_fault(&model, &mut r2)
        );
    }
}
