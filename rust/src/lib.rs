//! # ENFOR-SA — End-to-end cross-layer transient fault injector for
//! DNN reliability assessment on systolic arrays.
//!
//! This crate reproduces the ENFOR-SA system (Tonetto et al., 2026) as a
//! three-layer Rust + JAX/Pallas stack:
//!
//! * **L3 (this crate)** — the coordination contribution: the RTL-level
//!   systolic-array mesh simulator with the paper's non-intrusive
//!   *inverted-assignment-order* fault injection ([`mesh`]), the HDFIT-style
//!   instrumented baseline ([`mesh::hdfit`]), the full-SoC baseline
//!   ([`soc`]), the quantized DNN substrate ([`dnn`]), the software-level
//!   injector ([`swfi`]), the statistical campaign engine ([`campaign`]),
//!   the async campaign coordinator ([`coordinator`]) and the durable
//!   campaign journal ([`journal`]) — resumable, shardable,
//!   O(1)-memory campaigns with bit-identical reports.
//! * **L2** — JAX graphs of the quantized layers (`python/compile/model.py`),
//!   AOT-lowered to HLO text and executed from Rust via PJRT ([`runtime`]).
//! * **L1** — Pallas int8 GEMM / im2col kernels
//!   (`python/compile/kernels/`), the functional golden model of the mesh.
//!
//! Python never runs on the request path: once `make artifacts` has produced
//! `artifacts/*.hlo.txt`, the binary is self-contained.
//!
//! Campaigns are **dataflow-generic**: the same scenario set, trial
//! engines, tile engines and worker shardings run end-to-end on the
//! output-stationary mesh (the paper's configuration, default) and on
//! the weight-stationary mesh ([`config::Dataflow`], `--dataflow`).
//! Under OS a trial offloads one output tile with the full-K stream;
//! under WS it offloads one preloaded DIM x DIM weight tile with the
//! full M-row activation panel streamed through it. The whole-SoC
//! backend included: its schedule-indexable controller ([`soc`])
//! opens an OS preload/compute/flush or WS preload/compute window
//! from the same command stream shape, and supports cycle-resume.
//!
//! ## Quick start
//!
//! ```no_run
//! use enfor_sa::mat::Mat;
//! use enfor_sa::mesh::{driver::MatmulDriver, Fault, Mesh, SignalKind};
//!
//! let mut mesh = Mesh::new(8, enfor_sa::config::Dataflow::OutputStationary);
//! let a = Mat::filled(8, 8, 1i8);
//! let b = Mat::filled(8, 8, 2i8);
//! let d: Mat<i32> = Mat::zeros(8, 8);
//! let golden = MatmulDriver::new(&mut mesh).matmul(a.view(), b.view(), d.view());
//! let fault = Fault::new(3, 4, SignalKind::Weight, 2, 10);
//! let faulty =
//!     MatmulDriver::new(&mut mesh).matmul_with_fault(a.view(), b.view(), d.view(), &fault);
//! assert_ne!(golden, faulty);
//!
//! // scenario plans: any cycle-sorted set of faults in ONE run (MBU,
//! // spatial burst, double SEU, stuck-at...) — see `config::Scenario`
//! use enfor_sa::mesh::FaultPlan;
//! let mbu = FaultPlan::new(vec![
//!     Fault::new(3, 4, SignalKind::Weight, 2, 10),
//!     Fault::new(3, 4, SignalKind::Weight, 3, 10),
//! ]);
//! let _ = MatmulDriver::new(&mut mesh).matmul_with_plan(a.view(), b.view(), d.view(), &mbu);
//!
//! // a whole statistical campaign, here on the weight-stationary mesh:
//! // fixed seeds reproduce identical fault lists and outcome counts
//! use enfor_sa::campaign::run_campaign;
//! use enfor_sa::config::{CampaignConfig, Dataflow, MeshConfig};
//! use enfor_sa::dnn::models;
//! let model = models::quicknet(1);
//! let mesh_cfg = MeshConfig { dim: 8, dataflow: Dataflow::WeightStationary };
//! let cfg = CampaignConfig { faults_per_layer: 4, inputs: 1, ..Default::default() };
//! let result = run_campaign(&model, &mesh_cfg, &cfg).unwrap();
//! println!("{}: AVF {:.4}%", result.model, result.vf() * 100.0);
//! ```

// Style lints that fight cycle-accurate, index-addressed simulator code
// (PE grids and edge-port arrays are naturally loop-indexed); correctness
// lints stay on — CI runs `cargo clippy -- -D warnings`.
#![allow(clippy::needless_range_loop)]
#![allow(clippy::manual_memcpy)]
#![allow(clippy::too_many_arguments)]

pub mod benchkit;
pub mod campaign;
pub mod config;
pub mod coordinator;
pub mod dnn;
pub mod journal;
pub mod mat;
pub mod mesh;
pub mod report;
pub mod runtime;
pub mod soc;
pub mod swfi;
pub mod util;

/// Crate version string (matches Cargo.toml).
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
