//! Detailed uncore / core-periphery blocks of the full-SoC baseline.
//!
//! A verilated Chipyard SoC evaluates far more than the mesh each cycle:
//! the Rocket front-end predictors, TLBs and PTW, the FPU pipeline, the
//! TileLink fabric with its MSHRs, and Gemmini's non-mesh machinery
//! (scratchpad scrubbing, the requant/activation pipelines). Each block
//! here owns real architectural state and does genuine (bounded) work in
//! `tick()` — this is the honest stand-in for the "everything else" the
//! paper's mesh isolation strips away (DESIGN.md §3). None of it is a
//! sleep; the Table V ratios come out of this work actually executing.

/// Rocket-style front-end predictors: BTB + gshare + return stack.
pub struct BranchPredictor {
    btb_tags: Vec<u64>,
    btb_targets: Vec<u64>,
    gshare: Vec<u8>,
    ghist: u64,
    ras: [u64; 8],
    ras_top: usize,
}

impl BranchPredictor {
    pub fn new() -> Self {
        BranchPredictor {
            btb_tags: vec![0; 512],
            btb_targets: vec![0; 512],
            gshare: vec![1; 1024],
            ghist: 0,
            ras: [0; 8],
            ras_top: 0,
        }
    }

    /// One fetch-cycle evaluation: BTB lookup + gshare read/update path.
    #[inline]
    pub fn tick(&mut self, pc: u64) -> u64 {
        let b = (pc as usize) & 511;
        let g = ((pc ^ self.ghist) as usize) & 1023;
        let pred = self.gshare[g] >= 2;
        self.ghist = (self.ghist << 1) | pred as u64;
        // BTB refill path (tag compare + potential update)
        if self.btb_tags[b] != pc {
            self.btb_tags[b] = pc;
            self.btb_targets[b] = pc.wrapping_add(4);
        }
        self.ras[self.ras_top] = self.ras[self.ras_top].wrapping_add(pred as u64);
        self.ras_top = (self.ras_top + 1) & 7;
        self.btb_targets[b]
    }

    pub fn state_elements(&self) -> usize {
        512 * 2 + 1024 + 8 + 2
    }
}

/// Instruction/data TLBs + a page-table-walker FSM.
pub struct Tlbs {
    itlb: Vec<u64>,
    dtlb: Vec<u64>,
    ptw_state: u8,
    pub walks: u64,
}

impl Tlbs {
    pub fn new() -> Self {
        Tlbs {
            itlb: vec![u64::MAX; 32],
            dtlb: vec![u64::MAX; 32],
            ptw_state: 0,
            walks: 0,
        }
    }

    #[inline]
    pub fn tick(&mut self, vaddr: u64) {
        let vpn = vaddr >> 12;
        let ii = (vpn as usize) & 31;
        if self.itlb[ii] != vpn {
            self.itlb[ii] = vpn;
            self.ptw_state = self.ptw_state.wrapping_add(1) & 3;
            self.walks += 1;
        }
        let di = ((vpn >> 5) as usize) & 31;
        if self.dtlb[di] != vpn >> 5 {
            self.dtlb[di] = vpn >> 5;
        }
    }

    pub fn state_elements(&self) -> usize {
        32 + 32 + 1
    }
}

/// The FPU pipeline: Rocket clocks it whether or not FP code runs.
pub struct FpuPipeline {
    stages: [u64; 5],
    fcsr: u64,
}

impl FpuPipeline {
    pub fn new() -> Self {
        FpuPipeline { stages: [0; 5], fcsr: 0 }
    }

    #[inline]
    pub fn tick(&mut self, operand: u64) {
        // shift the pipe and fold a cheap op through it (mantissa path)
        for i in (1..5).rev() {
            self.stages[i] = self.stages[i - 1];
        }
        self.stages[0] = operand
            .rotate_left(7)
            .wrapping_mul(0x9E37_79B9)
            ^ self.fcsr;
        self.fcsr = self.fcsr.wrapping_add(self.stages[4] & 0x1f);
    }

    pub fn state_elements(&self) -> usize {
        6
    }
}

/// TileLink fabric state: per-channel beat counters + an MSHR file.
pub struct TileLink {
    mshr_addr: [u64; 8],
    mshr_live: [u8; 8],
    chan_beats: [u32; 5],
    pub grants: u64,
}

impl TileLink {
    pub fn new() -> Self {
        TileLink {
            mshr_addr: [0; 8],
            mshr_live: [0; 8],
            chan_beats: [0; 5],
            grants: 0,
        }
    }

    #[inline]
    pub fn tick(&mut self, active_addr: u64) {
        // age MSHRs, allocate/retire one per cycle at most
        let mut freed = false;
        for i in 0..8 {
            if self.mshr_live[i] > 0 {
                self.mshr_live[i] -= 1;
                if self.mshr_live[i] == 0 && !freed {
                    freed = true;
                    self.grants += 1;
                }
            }
        }
        let slot = (active_addr as usize) & 7;
        if self.mshr_live[slot] == 0 {
            self.mshr_addr[slot] = active_addr;
            self.mshr_live[slot] = 4; // 4-beat refill
        }
        for (i, b) in self.chan_beats.iter_mut().enumerate() {
            *b = b.wrapping_add(1 + i as u32);
        }
    }

    pub fn state_elements(&self) -> usize {
        8 * 2 + 5
    }
}

/// Gemmini's non-mesh pipelines: the scratchpad scrubber walks one row
/// per cycle (ECC), and the requant/activation unit clocks DIM lanes.
pub struct GemminiUncore {
    scrub_row: usize,
    scrub_crc: u32,
    requant_lanes: Vec<i32>,
    dim: usize,
}

impl GemminiUncore {
    pub fn new(dim: usize) -> Self {
        GemminiUncore {
            scrub_row: 0,
            scrub_crc: 0,
            requant_lanes: vec![0; dim],
            dim,
        }
    }

    #[inline]
    pub fn tick(&mut self, spad_rows: usize, row_sample: &[i8]) {
        self.scrub_row = (self.scrub_row + 1) % spad_rows.max(1);
        // one row's worth of ECC work per cycle
        for &b in row_sample {
            self.scrub_crc = self
                .scrub_crc
                .rotate_left(5)
                .wrapping_add(b as u32);
        }
        for (i, lane) in self.requant_lanes.iter_mut().enumerate() {
            *lane = lane.wrapping_add((self.scrub_crc as i32) ^ i as i32);
        }
    }

    pub fn state_elements(&self) -> usize {
        2 + self.dim
    }
}

/// The core + uncore combinational cloud. Verilator re-evaluates the
/// whole active comb logic of the design every `eval()` — decoders,
/// bypass networks, 64-bit datapaths, arbiter trees. A Rocket-class SoC
/// is on the order of 10^5 gates; this sweep models that evaluation cost
/// with `COMB_CLUSTERS` word-level operations per cycle over persistent
/// net state (real work, not a sleep — see DESIGN.md §3).
pub struct CombCloud {
    nets: Vec<u64>,
}

/// Word-level comb clusters evaluated per cycle (each u64 op stands in
/// for a handful of gate evaluations in the verilated core + uncore).
pub const COMB_CLUSTERS: usize = 8192;

impl CombCloud {
    pub fn new() -> Self {
        CombCloud {
            nets: (0..COMB_CLUSTERS as u64).map(|i| i.wrapping_mul(0x2545F491)).collect(),
        }
    }

    #[inline]
    pub fn tick(&mut self, stimulus: u64) {
        let mut carry = stimulus | 1;
        for net in self.nets.iter_mut() {
            // mux + xor + add: a typical LUT cluster's worth of work
            let v = (*net ^ carry).wrapping_add(carry.rotate_left(9));
            carry = v;
            *net = v;
        }
    }

    pub fn state_elements(&self) -> usize {
        self.nets.len()
    }
}

impl Default for CombCloud {
    fn default() -> Self {
        Self::new()
    }
}

/// All detail blocks bundled, ticked once per SoC cycle.
pub struct UncoreDetail {
    pub bp: BranchPredictor,
    pub tlbs: Tlbs,
    pub fpu: FpuPipeline,
    pub tl: TileLink,
    pub gemmini: GemminiUncore,
    pub comb: CombCloud,
    scratch_row: Vec<i8>,
}

impl UncoreDetail {
    pub fn new(dim: usize) -> Self {
        UncoreDetail {
            bp: BranchPredictor::new(),
            tlbs: Tlbs::new(),
            fpu: FpuPipeline::new(),
            tl: TileLink::new(),
            gemmini: GemminiUncore::new(dim),
            comb: CombCloud::new(),
            scratch_row: vec![0; 64],
        }
    }

    #[inline]
    pub fn tick(&mut self, cycle: u64, pc: u64, spad_rows: usize) {
        let t = self.bp.tick(pc);
        self.tlbs.tick(pc ^ cycle);
        self.fpu.tick(t ^ cycle);
        self.tl.tick(pc.wrapping_add(cycle));
        self.comb.tick(t ^ cycle);
        self.scratch_row[(cycle as usize) & 63] = cycle as i8;
        self.gemmini.tick(spad_rows, &self.scratch_row);
    }

    pub fn state_elements(&self) -> usize {
        self.bp.state_elements()
            + self.tlbs.state_elements()
            + self.fpu.state_elements()
            + self.tl.state_elements()
            + self.gemmini.state_elements()
            + self.comb.state_elements()
    }
}

impl Default for BranchPredictor {
    fn default() -> Self {
        Self::new()
    }
}

impl Default for Tlbs {
    fn default() -> Self {
        Self::new()
    }
}

impl Default for FpuPipeline {
    fn default() -> Self {
        Self::new()
    }
}

impl Default for TileLink {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blocks_tick_without_panic_and_mutate_state() {
        let mut d = UncoreDetail::new(8);
        let before = d.fpu.stages;
        for c in 0..1000 {
            d.tick(c, c * 4, 256);
        }
        assert_ne!(d.fpu.stages, before);
        assert!(d.tlbs.walks > 0);
        assert!(d.tl.grants > 0);
    }

    #[test]
    fn state_inventory_is_substantial() {
        let d = UncoreDetail::new(8);
        assert!(d.state_elements() > 2000);
    }

    #[test]
    fn predictor_is_deterministic() {
        let mut a = BranchPredictor::new();
        let mut b = BranchPredictor::new();
        for pc in 0..500u64 {
            assert_eq!(a.tick(pc * 4), b.tick(pc * 4));
        }
    }
}
