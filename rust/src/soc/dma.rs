//! DMA engine: moves operand tiles between the (modelled) main memory and
//! the scratchpad, one row per cycle with a fixed setup latency — the
//! MVIN / MVOUT datapath of Gemmini.

use super::scratchpad::Scratchpad;
use anyhow::Result;

/// Main-memory model: a flat byte array with a fixed access latency that
/// the DMA pays once per burst.
pub struct MainMemory {
    pub bytes: Vec<i8>,
    pub burst_latency: u32,
}

impl MainMemory {
    pub fn new(size: usize, burst_latency: u32) -> Self {
        MainMemory {
            bytes: vec![0; size],
            burst_latency,
        }
    }

    /// Zero the backing store without reallocating (the dominant cost a
    /// per-trial `Soc::new` used to pay).
    pub fn reset(&mut self) {
        self.bytes.fill(0);
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum DmaState {
    Idle,
    Setup { remaining: u32 },
    Busy,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DmaDir {
    MemToSpad,
    SpadToMem,
}

/// One in-flight DMA transfer descriptor.
#[derive(Clone, Copy, Debug)]
struct Xfer {
    dir: DmaDir,
    mem_addr: usize,
    spad_row: usize,
    rows: usize,
    done_rows: usize,
}

/// The DMA engine FSM. `tick` moves at most one row per cycle.
pub struct Dma {
    state: DmaState,
    xfer: Option<Xfer>,
    pub rows_moved: u64,
}

impl Default for Dma {
    fn default() -> Self {
        Self::new()
    }
}

impl Dma {
    pub fn new() -> Self {
        Dma {
            state: DmaState::Idle,
            xfer: None,
            rows_moved: 0,
        }
    }

    /// Abort any in-flight transfer and clear statistics (power-on state).
    pub fn reset(&mut self) {
        self.state = DmaState::Idle;
        self.xfer = None;
        self.rows_moved = 0;
    }

    pub fn busy(&self) -> bool {
        self.state != DmaState::Idle
    }

    /// Enqueue a transfer (controller guarantees the engine is idle).
    pub fn start(
        &mut self,
        dir: DmaDir,
        mem_addr: usize,
        spad_row: usize,
        rows: usize,
        mem: &MainMemory,
    ) {
        debug_assert!(!self.busy(), "DMA start while busy");
        self.xfer = Some(Xfer {
            dir,
            mem_addr,
            spad_row,
            rows,
            done_rows: 0,
        });
        self.state = DmaState::Setup {
            remaining: mem.burst_latency,
        };
    }

    /// One clock edge: progress the FSM, moving up to one row.
    pub fn tick(&mut self, mem: &mut MainMemory, spad: &mut Scratchpad) -> Result<()> {
        match self.state {
            DmaState::Idle => {}
            DmaState::Setup { remaining } => {
                self.state = if remaining <= 1 {
                    DmaState::Busy
                } else {
                    DmaState::Setup {
                        remaining: remaining - 1,
                    }
                };
            }
            DmaState::Busy => {
                let row_bytes = spad.row_bytes();
                let x = self.xfer.as_mut().expect("busy DMA without xfer");
                let mem_off = x.mem_addr + x.done_rows * row_bytes;
                match x.dir {
                    DmaDir::MemToSpad => {
                        let src = mem.bytes[mem_off..mem_off + row_bytes].to_vec();
                        spad.write_row(x.spad_row + x.done_rows, &src)?;
                    }
                    DmaDir::SpadToMem => {
                        let (row, _stall) = spad.read_row(x.spad_row + x.done_rows)?;
                        mem.bytes[mem_off..mem_off + row_bytes].copy_from_slice(&row);
                    }
                }
                x.done_rows += 1;
                self.rows_moved += 1;
                if x.done_rows == x.rows {
                    self.state = DmaState::Idle;
                    self.xfer = None;
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mvin_moves_rows_after_setup() {
        let mut mem = MainMemory::new(1024, 3);
        let mut spad = Scratchpad::new(2, 8, 4);
        for (i, b) in mem.bytes[100..108].iter_mut().enumerate() {
            *b = i as i8;
        }
        let mut dma = Dma::new();
        dma.start(DmaDir::MemToSpad, 100, 2, 2, &mem);
        let mut cycles = 0;
        while dma.busy() {
            spad.tick();
            dma.tick(&mut mem, &mut spad).unwrap();
            cycles += 1;
            assert!(cycles < 100);
        }
        assert_eq!(cycles, 3 + 2, "setup latency + one row per cycle");
        assert_eq!(spad.read_row(2).unwrap().0, vec![0, 1, 2, 3]);
        assert_eq!(spad.read_row(3).unwrap().0, vec![4, 5, 6, 7]);
    }

    #[test]
    fn mvout_round_trips() {
        let mut mem = MainMemory::new(256, 1);
        let mut spad = Scratchpad::new(2, 8, 4);
        spad.write_row(0, &[9, 8, 7, 6]).unwrap();
        spad.tick();
        let mut dma = Dma::new();
        dma.start(DmaDir::SpadToMem, 32, 0, 1, &mem);
        while dma.busy() {
            spad.tick();
            dma.tick(&mut mem, &mut spad).unwrap();
        }
        assert_eq!(&mem.bytes[32..36], &[9, 8, 7, 6]);
        assert_eq!(dma.rows_moved, 1);
    }
}
