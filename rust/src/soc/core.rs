//! The Rocket-like in-order core of the full-SoC baseline.
//!
//! Chipyard couples Gemmini to a RISC-V Rocket core via the RoCC
//! interface; every simulated cycle of the *full SoC* evaluates the whole
//! core pipeline whether or not it matters to the accelerator — which is
//! precisely the cost ENFOR-SA's mesh isolation removes. This model
//! executes a small RoCC-style command program on a 5-stage pipeline with
//! real architectural state (regfile, pipeline latches, CSRs, branch
//! predictor tables) so that per-cycle evaluation cost is honest work,
//! not a sleep.

/// RoCC-style custom instructions the core issues to the accelerator.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Insn {
    /// ALU ops keep the pipeline busy between accelerator commands
    /// (address generation, loop bookkeeping — what real driver code does).
    Addi { rd: u8, rs1: u8, imm: i64 },
    Add { rd: u8, rs1: u8, rs2: u8 },
    /// Branch if rs1 != 0, backwards by `off` instructions (loops).
    Bnez { rs1: u8, off: i32 },
    /// RoCC: enqueue a Gemmini command (opcode + two operand registers).
    Rocc { funct: u8, rs1: u8, rs2: u8 },
    /// Stall until the accelerator's ROB is empty (fence).
    Fence,
    Halt,
}

/// Decoded Gemmini command leaving the core for the controller.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RoccCmd {
    pub funct: u8,
    pub rs1: u64,
    pub rs2: u64,
}

/// 5-stage in-order pipeline: IF -> ID -> EX -> MEM -> WB.
/// Pipeline latches are real state, updated in inverted order like every
/// register in the verilated model.
pub struct Core {
    pub pc: usize,
    pub regs: [u64; 32],
    /// Pipeline latches (the instruction index occupying each stage).
    if_id: Option<usize>,
    id_ex: Option<(usize, Insn)>,
    ex_mem: Option<(usize, Insn)>,
    mem_wb: Option<(usize, Insn)>,
    /// 2-bit saturating counters — a 256-entry branch history table the
    /// verilated core would evaluate on every fetch.
    bht: [u8; 256],
    /// Cycle-accounting CSRs.
    pub csr_cycle: u64,
    pub csr_instret: u64,
    halted: bool,
    stalled: bool,
}

impl Default for Core {
    fn default() -> Self {
        Self::new()
    }
}

impl Core {
    pub fn new() -> Self {
        Core {
            pc: 0,
            regs: [0; 32],
            if_id: None,
            id_ex: None,
            ex_mem: None,
            mem_wb: None,
            bht: [1; 256],
            csr_cycle: 0,
            csr_instret: 0,
            halted: false,
            stalled: false,
        }
    }

    pub fn halted(&self) -> bool {
        self.halted
    }

    /// One clock edge. Returns a RoCC command if one retires this cycle.
    ///
    /// `rob_busy` models the RoCC fence: a `Fence` in EX holds the
    /// pipeline until the accelerator drains.
    pub fn step(&mut self, prog: &[Insn], rob_busy: bool) -> Option<RoccCmd> {
        self.csr_cycle += 1;
        if self.halted {
            return None;
        }

        // WB (retire) — inverted order: downstream stages first.
        // Branches resolve at retire (all older register writes have
        // committed, so no forwarding network is needed); younger
        // wrong-path instructions are flushed from every stage.
        let mut cmd = None;
        let mut redirect = false;
        if let Some((idx, insn)) = self.mem_wb.take() {
            self.csr_instret += 1;
            match insn {
                Insn::Addi { rd, rs1, imm } => {
                    if rd != 0 {
                        self.regs[rd as usize] =
                            self.regs[rs1 as usize].wrapping_add(imm as u64);
                    }
                }
                Insn::Add { rd, rs1, rs2 } => {
                    if rd != 0 {
                        self.regs[rd as usize] = self.regs[rs1 as usize]
                            .wrapping_add(self.regs[rs2 as usize]);
                    }
                }
                Insn::Rocc { funct, rs1, rs2 } => {
                    cmd = Some(RoccCmd {
                        funct,
                        rs1: self.regs[rs1 as usize],
                        rs2: self.regs[rs2 as usize],
                    });
                }
                Insn::Bnez { rs1, off } => {
                    let taken = self.regs[rs1 as usize] != 0;
                    // BHT update: honest per-retire predictor state
                    let ctr = &mut self.bht[idx & 0xff];
                    *ctr = if taken {
                        (*ctr + 1).min(3)
                    } else {
                        ctr.saturating_sub(1)
                    };
                    if taken {
                        self.pc = (idx as i64 + off as i64) as usize;
                        redirect = true;
                    }
                }
                Insn::Halt => self.halted = true,
                _ => {}
            }
        }
        if redirect {
            // flush all younger (wrong-path) instructions
            self.if_id = None;
            self.id_ex = None;
            self.ex_mem = None;
        }

        // MEM
        self.mem_wb = self.ex_mem.take();

        // EX — fences resolve here.
        if let Some((idx, insn)) = self.id_ex {
            match insn {
                Insn::Fence if rob_busy => {
                    // hold the fence in EX; bubble downstream
                    self.stalled = true;
                }
                _ => {
                    self.stalled = false;
                    self.ex_mem = Some((idx, insn));
                    self.id_ex = None;
                }
            }
        }

        if !self.stalled {
            // ID
            if self.id_ex.is_none() {
                if let Some(pc) = self.if_id.take() {
                    self.id_ex = prog.get(pc).map(|&i| (pc, i));
                }
            }
            // IF
            if self.if_id.is_none() && self.pc < prog.len() {
                self.if_id = Some(self.pc);
                self.pc += 1;
            }
        } else if !rob_busy {
            self.stalled = false;
        }

        cmd
    }

    /// Architectural state element count (DESIGN.md D2 inventory).
    pub fn state_elements(&self) -> usize {
        32 + 4 /*latches*/ + 256 /*bht*/ + 2 /*csr*/ + 1 /*pc*/
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn straight_line_alu_program() {
        let prog = vec![
            Insn::Addi { rd: 1, rs1: 0, imm: 5 },
            Insn::Addi { rd: 2, rs1: 0, imm: 7 },
            Insn::Add { rd: 3, rs1: 1, rs2: 2 },
            Insn::Halt,
        ];
        let mut core = Core::new();
        for _ in 0..20 {
            core.step(&prog, false);
        }
        assert!(core.halted());
        assert_eq!(core.regs[3], 12);
        assert_eq!(core.csr_instret, 4);
    }

    #[test]
    fn rocc_command_carries_register_values() {
        let prog = vec![
            Insn::Addi { rd: 1, rs1: 0, imm: 0x100 },
            Insn::Addi { rd: 2, rs1: 0, imm: 0x200 },
            Insn::Rocc { funct: 2, rs1: 1, rs2: 2 },
            Insn::Halt,
        ];
        let mut core = Core::new();
        let mut cmds = vec![];
        for _ in 0..20 {
            if let Some(c) = core.step(&prog, false) {
                cmds.push(c);
            }
        }
        assert_eq!(
            cmds,
            vec![RoccCmd { funct: 2, rs1: 0x100, rs2: 0x200 }]
        );
    }

    #[test]
    fn fence_stalls_until_rob_drains() {
        let prog = vec![
            Insn::Fence,
            Insn::Addi { rd: 1, rs1: 0, imm: 1 },
            Insn::Halt,
        ];
        let mut core = Core::new();
        // ROB busy for 10 cycles: the ADDI must not retire in that window.
        for _ in 0..10 {
            core.step(&prog, true);
        }
        assert_eq!(core.regs[1], 0);
        assert!(!core.halted());
        for _ in 0..10 {
            core.step(&prog, false);
        }
        assert_eq!(core.regs[1], 1);
        assert!(core.halted());
    }

    #[test]
    fn bnez_loops() {
        // r1 = 3; loop: r1 += -1; bnez r1, -1  => r1 ends 0
        let prog = vec![
            Insn::Addi { rd: 1, rs1: 0, imm: 3 },
            Insn::Addi { rd: 1, rs1: 1, imm: -1 },
            Insn::Bnez { rs1: 1, off: -1 },
            Insn::Halt,
        ];
        let mut core = Core::new();
        for _ in 0..100 {
            core.step(&prog, false);
        }
        assert!(core.halted());
        assert_eq!(core.regs[1], 0);
    }

    #[test]
    fn x0_is_hardwired_zero() {
        let prog = vec![Insn::Addi { rd: 0, rs1: 0, imm: 99 }, Insn::Halt];
        let mut core = Core::new();
        for _ in 0..10 {
            core.step(&prog, false);
        }
        assert_eq!(core.regs[0], 0);
    }
}
