//! Gemmini controller: RoCC command queue (ROB), config state and the
//! execute engine that drives operand streams from the scratchpad into
//! the mesh — the `ExecuteController` / `LoadController` /
//! `StoreController` complex of the real design.
//!
//! # The schedule-indexable execute engine
//!
//! The matmul window is expressed as a [`SocSchedule`] — the SoC
//! counterpart of [`crate::mesh::driver::Schedule`]: phase boundaries
//! plus operand base addresses, able to produce any cycle `t`'s
//! [`MeshInputs`] and scratchpad/accmem read addresses in O(dim)
//! ([`Controller::step_window`] reads `mesh_t`, not an imperative FSM
//! state). The command-decode/DMA phases stay a thin prefix outside the
//! window. Because the window is cycle-indexed, the controller supports
//! cycle-resume: [`Controller::save_state`] / [`Controller::restore_state`]
//! snapshot the window-relative architectural state (registers, skew
//! rings, drain accumulator, mesh [`crate::mesh::MeshState`]) in
//! O(dim²) — the scratchpad and accumulator SRAM are *not* mutated
//! mid-window (reads only; C lands at window end), so they are excluded
//! and shared by every replay of a tile.
//!
//! The schedule reproduces *exactly* the mesh-only driver's programs
//! (OS preload/compute/flush and WS preload/compute, same skews), so a
//! fault at mesh-relative cycle `t` produces the same corruption whether
//! injected through the mesh-only wrapper or through the full SoC —
//! pinned by `rust/tests/integration_soc.rs`.

use super::core::RoccCmd;
use super::dma::{Dma, DmaDir, MainMemory};
use super::scratchpad::{AccMem, Scratchpad};
use crate::config::Dataflow;
use crate::mat::Mat;
use crate::mesh::driver::CycleIndexed;
use crate::mesh::inject::{FaultPlan, PlanCursor};
use crate::mesh::mesh::{Mesh, MeshInputs, MeshSim, MeshState, StepOutput};
use anyhow::Result;
use std::collections::VecDeque;

/// Gemmini RoCC functs (subset).
pub mod funct {
    pub const CONFIG: u8 = 0;
    pub const MVIN: u8 = 1;
    pub const PRELOAD: u8 = 2;
    pub const COMPUTE: u8 = 3;
    pub const MVOUT: u8 = 4;
}

/// A cycle-indexed description of one in-flight SoC matmul window: the
/// dataflow's phase arithmetic plus the operand base rows latched by the
/// CONFIG / PRELOAD / COMPUTE commands. Like the mesh-only
/// [`crate::mesh::driver::Schedule`], it maps any window cycle `t` to
/// that cycle's boundary inputs and memory read addresses in O(dim) —
/// the indexability cycle-resume builds on — but reads operands through
/// the scratchpad/accmem ports instead of zero-copy views, preserving
/// the SoC's per-cycle port (and conflict) accounting.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SocSchedule {
    dataflow: Dataflow,
    dim: usize,
    /// Stream length latched by CONFIG: K under OS, M under WS.
    stream: usize,
    /// Scratchpad base rows of the streamed operands (COMPUTE rs1/rs2).
    a_base: usize,
    b_base: usize,
    /// Accmem row holding D (PRELOAD rs1) and landing row for C (rs2).
    d_base: usize,
    c_base: usize,
}

impl SocSchedule {
    fn new(
        dataflow: Dataflow,
        dim: usize,
        stream: usize,
        a_base: usize,
        b_base: usize,
        d_base: usize,
        c_base: usize,
    ) -> SocSchedule {
        SocSchedule { dataflow, dim, stream, a_base, b_base, d_base, c_base }
    }

    pub fn dataflow(&self) -> Dataflow {
        self.dataflow
    }

    /// Accmem row the first result row lands in at window end.
    pub fn c_base(&self) -> usize {
        self.c_base
    }

    /// Preload window: D (OS) or W (WS) staircases down the d-chain.
    pub fn preload_cycles(&self) -> u64 {
        (2 * self.dim - 1) as u64
    }

    /// Compute window: the skewed operand streams.
    pub fn compute_cycles(&self) -> u64 {
        (self.stream + 2 * self.dim - 2) as u64
    }

    /// Flush window: OS drains C through the south edge; WS has none
    /// (psums exit during compute).
    pub fn flush_cycles(&self) -> u64 {
        match self.dataflow {
            Dataflow::OutputStationary => (2 * self.dim - 1) as u64,
            Dataflow::WeightStationary => 0,
        }
    }

    /// Mesh cycles in the whole window (identical to the mesh-only
    /// driver's cycle model for the same operands).
    pub fn total_cycles(&self) -> u64 {
        self.preload_cycles() + self.compute_cycles() + self.flush_cycles()
    }

    /// First cycle south-edge traffic is captured (the fixed drain
    /// window of [`crate::mesh::driver::Schedule::drain`]).
    pub fn drain_start(&self) -> u64 {
        match self.dataflow {
            Dataflow::OutputStationary => self.preload_cycles() + self.compute_cycles(),
            Dataflow::WeightStationary => self.preload_cycles(),
        }
    }

    /// Result rows the window lands in accmem (OS: DIM; WS: M).
    pub fn out_rows(&self) -> usize {
        match self.dataflow {
            Dataflow::OutputStationary => self.dim,
            Dataflow::WeightStationary => self.stream,
        }
    }
}

impl CycleIndexed for SocSchedule {
    fn total_cycles(&self) -> u64 {
        SocSchedule::total_cycles(self)
    }
    fn drain_start(&self) -> u64 {
        SocSchedule::drain_start(self)
    }
    fn out_rows(&self) -> usize {
        SocSchedule::out_rows(self)
    }
}

/// A reusable snapshot of the controller's window-relative architectural
/// state: the in-flight [`SocSchedule`], config/base registers, the skew
/// rings, the drain accumulator and the mesh register file — O(dim²)
/// total. The ROB is excluded (the core fences through the whole window,
/// so it is empty), the fault plan/cursor are excluded (replays re-arm
/// via [`Controller::begin_replay`]), and the scratchpad/accmem are
/// excluded because the window never mutates them before its final
/// cycle. Buffers are recycled across [`Controller::save_state`] calls
/// (`restore ∘ save ≡ id`, pinned by test).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ControllerState {
    pub(crate) window: Option<SocSchedule>,
    pub(crate) cfg_k: usize,
    pub(crate) a_base: usize,
    pub(crate) b_base: usize,
    pub(crate) d_base: usize,
    pub(crate) c_base: usize,
    pub(crate) ring_a: Mat<i8>,
    pub(crate) ring_b: Mat<i8>,
    pub(crate) ring_d: Mat<i32>,
    pub(crate) mesh_t: u64,
    pub(crate) mesh: MeshState,
    pub(crate) cmat: Mat<i32>,
    pub(crate) taken: Vec<usize>,
}

/// The controller + mesh complex.
pub struct Controller {
    pub mesh: Mesh,
    rob: VecDeque<RoccCmd>,
    /// The in-flight matmul window (`None` = idle command decode).
    window: Option<SocSchedule>,
    /// config: stream length (K under OS, M under WS) of the next compute.
    cfg_k: usize,
    /// operand base rows (set by the COMPUTE command).
    a_base: usize,
    b_base: usize,
    /// accmem row holding D (set by PRELOAD) and landing row for C.
    d_base: usize,
    c_base: usize,
    /// ring buffers implementing the skew shift registers at the edges
    /// (flat DIM x DIM matrices; row = ring slot). `ring_d` carries the
    /// WS psum-initialiser stream (unused under OS).
    ring_a: Mat<i8>,
    ring_b: Mat<i8>,
    ring_d: Mat<i32>,
    /// mesh-relative cycle counter for the in-flight matmul.
    mesh_t: u64,
    /// armed fault plan for the next COMPUTE (mesh-relative cycles;
    /// empty = golden) and its per-run firing cursor.
    plan: FaultPlan,
    cursor: PlanCursor,
    /// Drain accumulator: the C tile assembled from south-edge traffic
    /// (OS: rows un-staircased in reverse; WS: stream order) plus the
    /// per-column row counters — the [`crate::mesh::driver::Schedule::drain`]
    /// state, held inline so snapshots capture mid-flush progress.
    cmat: Mat<i32>,
    taken: Vec<usize>,
    /// Persistent scratch row for port reads that feed the north edge
    /// directly (no per-cycle allocation, like `DriverScratch`).
    row_i8: Vec<i8>,
    inp: MeshInputs,
    out: StepOutput,
    /// statistics
    pub matmuls_done: u64,
}

impl Controller {
    /// Build the controller + mesh complex. The dataflow comes from the
    /// campaign's `MeshConfig` (never hardcoded here) and selects which
    /// [`SocSchedule`] the COMPUTE command opens: OS
    /// preload/compute/flush or WS preload/compute.
    pub fn new(dim: usize, dataflow: Dataflow) -> Self {
        Controller {
            mesh: Mesh::new(dim, dataflow),
            rob: VecDeque::new(),
            window: None,
            cfg_k: dim,
            a_base: 0,
            b_base: 0,
            d_base: 0,
            c_base: 0,
            ring_a: Mat::zeros(dim, dim),
            ring_b: Mat::zeros(dim, dim),
            ring_d: Mat::zeros(dim, dim),
            mesh_t: 0,
            plan: FaultPlan::empty(),
            cursor: PlanCursor::default(),
            cmat: Mat::zeros(dim, dim),
            taken: vec![0; dim],
            row_i8: vec![0; dim],
            inp: MeshInputs::idle(dim),
            out: StepOutput::new(dim),
            matmuls_done: 0,
        }
    }

    pub fn dim(&self) -> usize {
        self.mesh.dim()
    }

    /// ROB occupancy (drives the core's fence).
    pub fn busy(&self) -> bool {
        !self.rob.is_empty() || self.window.is_some()
    }

    /// Whether a matmul window is in flight (the cycle-resume region:
    /// between the COMPUTE decode and the window's final cycle).
    pub fn in_window(&self) -> bool {
        self.window.is_some()
    }

    /// The in-flight window's schedule, if any.
    pub fn window_schedule(&self) -> Option<SocSchedule> {
        self.window
    }

    /// Mesh-relative cycle of the in-flight matmul.
    pub fn mesh_cycle(&self) -> u64 {
        self.mesh_t
    }

    pub fn enqueue(&mut self, cmd: RoccCmd) {
        self.rob.push_back(cmd);
    }

    /// Arm a fault plan at mesh-relative cycles of the *next* compute
    /// command (empty plan = golden; the cursor starts when COMPUTE
    /// issues, since that is where the mesh-relative clock resets).
    /// Copies into the controller's persistent plan buffer — no
    /// per-trial allocation on the campaign's re-arm path.
    pub fn arm_plan(&mut self, plan: &FaultPlan) {
        self.plan.clone_from_plan(plan);
    }

    /// Disarm the fault plan and its cursor in place (keeps the plan
    /// buffer for the next re-arm) — the golden-advance state.
    pub fn disarm(&mut self) {
        self.plan.clear();
        self.cursor = PlanCursor::default();
    }

    /// Arm `plan` against an already-open window (a restored snapshot):
    /// the cursor starts fresh, so faults due at or after the snapshot
    /// cycle fire exactly as they would in a from-scratch run. The
    /// cycle-resume replay entry point ([`super::Soc::run_matmul_resumed`]).
    pub fn begin_replay(&mut self, plan: &FaultPlan) {
        self.plan.clone_from_plan(plan);
        self.cursor = PlanCursor::start(&self.plan);
    }

    /// Snapshot the window-relative architectural state into `st`,
    /// reusing its buffers (see [`ControllerState`] for what is and is
    /// not captured).
    pub fn save_state(&self, st: &mut ControllerState) {
        st.window = self.window;
        st.cfg_k = self.cfg_k;
        st.a_base = self.a_base;
        st.b_base = self.b_base;
        st.d_base = self.d_base;
        st.c_base = self.c_base;
        st.ring_a.clone_from(&self.ring_a);
        st.ring_b.clone_from(&self.ring_b);
        st.ring_d.clone_from(&self.ring_d);
        st.mesh_t = self.mesh_t;
        self.mesh.save_state(&mut st.mesh);
        st.cmat.clone_from(&self.cmat);
        st.taken.clear();
        st.taken.extend_from_slice(&self.taken);
    }

    /// Restore a snapshot taken by [`Controller::save_state`] on an
    /// identically-dimensioned controller: the window-relative state is
    /// bit-identical afterwards (`restore ∘ save ≡ id`, pinned by
    /// test). The fault plan/cursor are untouched — follow with
    /// [`Controller::begin_replay`] or [`Controller::disarm`].
    pub fn restore_state(&mut self, st: &ControllerState) {
        self.window = st.window;
        self.cfg_k = st.cfg_k;
        self.a_base = st.a_base;
        self.b_base = st.b_base;
        self.d_base = st.d_base;
        self.c_base = st.c_base;
        self.ring_a.clone_from(&st.ring_a);
        self.ring_b.clone_from(&st.ring_b);
        self.ring_d.clone_from(&st.ring_d);
        self.mesh_t = st.mesh_t;
        self.mesh.restore_state(&st.mesh);
        self.cmat.clone_from(&st.cmat);
        self.taken.clear();
        self.taken.extend_from_slice(&st.taken);
    }

    /// Power-on state: no window, empty ROB, cleared rings, disarmed
    /// fault, zeroed counters. Keeps every allocation.
    pub fn reset(&mut self) {
        let dim = self.dim();
        self.mesh.reset();
        self.rob.clear();
        self.window = None;
        self.cfg_k = dim;
        self.a_base = 0;
        self.b_base = 0;
        self.d_base = 0;
        self.c_base = 0;
        self.ring_a.data_mut().fill(0);
        self.ring_b.data_mut().fill(0);
        self.ring_d.data_mut().fill(0);
        self.mesh_t = 0;
        self.plan.clear();
        self.cursor = PlanCursor::default();
        self.cmat.reset(dim, dim);
        self.taken.clear();
        self.taken.resize(dim, 0);
        self.row_i8.fill(0);
        self.inp.clear();
        self.out.clear();
        self.matmuls_done = 0;
    }

    /// One clock edge of the controller + mesh complex.
    pub fn tick(
        &mut self,
        spad: &mut Scratchpad,
        accmem: &mut AccMem,
        dma: &mut Dma,
        mem: &mut MainMemory,
    ) -> Result<()> {
        if self.window.is_some() {
            return self.step_window(spad, accmem);
        }
        // idle: decode at most one command per cycle (issue stage)
        if let Some(cmd) = self.rob.front().copied() {
            match cmd.funct {
                funct::CONFIG => {
                    self.cfg_k = cmd.rs1 as usize;
                    self.rob.pop_front();
                }
                funct::MVIN => {
                    if !dma.busy() {
                        let rows = (cmd.rs2 >> 32) as usize;
                        let spad_row = (cmd.rs2 & 0xffff_ffff) as usize;
                        dma.start(DmaDir::MemToSpad, cmd.rs1 as usize, spad_row, rows, mem);
                        self.rob.pop_front();
                    }
                }
                funct::MVOUT => {
                    if !dma.busy() {
                        let rows = (cmd.rs2 >> 32) as usize;
                        let spad_row = (cmd.rs2 & 0xffff_ffff) as usize;
                        dma.start(DmaDir::SpadToMem, cmd.rs1 as usize, spad_row, rows, mem);
                        self.rob.pop_front();
                    }
                }
                funct::PRELOAD => {
                    self.d_base = cmd.rs1 as usize;
                    self.c_base = cmd.rs2 as usize;
                    self.rob.pop_front();
                }
                funct::COMPUTE => {
                    self.a_base = cmd.rs1 as usize;
                    self.b_base = cmd.rs2 as usize;
                    self.rob.pop_front();
                    self.begin_window();
                }
                other => anyhow::bail!("unknown RoCC funct {other}"),
            }
        }
        // the full SoC clocks the mesh every cycle, busy or not; on the
        // COMPUTE-decode tick this is the post-reset idle edge the
        // mesh-relative clock starts after
        self.inp.clear();
        self.mesh.step(&self.inp, &mut self.out);
        Ok(())
    }

    /// Open the matmul window: latch the schedule from the decoded
    /// command registers and reset the window-relative state.
    fn begin_window(&mut self) {
        let dim = self.dim();
        let sched = SocSchedule::new(
            self.mesh.dataflow(),
            dim,
            self.cfg_k,
            self.a_base,
            self.b_base,
            self.d_base,
            self.c_base,
        );
        self.mesh.reset();
        self.mesh_t = 0;
        self.cursor = PlanCursor::start(&self.plan);
        self.ring_a.data_mut().fill(0);
        self.ring_b.data_mut().fill(0);
        self.ring_d.data_mut().fill(0);
        self.cmat.reset(sched.out_rows(), dim);
        self.taken.clear();
        self.taken.resize(dim, 0);
        self.window = Some(sched);
    }

    /// One window cycle: fill cycle `mesh_t`'s boundary inputs from the
    /// schedule, fire any due fault, step the mesh, drain the south
    /// edge, and close the window after its final cycle. Callable from
    /// any restored snapshot — the cycle-resume stepping primitive.
    pub fn step_window(&mut self, spad: &mut Scratchpad, accmem: &mut AccMem) -> Result<()> {
        let sched = self.window.expect("step_window outside the matmul window");
        let t = self.mesh_t;
        // Control-path faults corrupt the window bookkeeping itself: a
        // sequencer-bit strike redirects which schedule cycle's operand
        // addresses the scratchpad/accmem reads use (a corrupted DMA
        // descriptor), a drain-bit strike flips the drain-FSM counters.
        let fill_t = if self.plan.has_control() {
            crate::mesh::inject::apply_control(
                &self.plan,
                t,
                sched.total_cycles(),
                &mut self.taken,
            )
        } else {
            t
        };
        self.fill_window(sched, fill_t, spad, accmem)?;
        // one compare per mesh cycle — same wrapper contract as the
        // mesh-only driver (`PlanCursor::next_cycle`)
        if self.cursor.next_cycle() == t {
            self.cursor.fire(&self.plan, t, &mut self.mesh, &mut self.inp);
        }
        self.out.clear();
        self.mesh.step(&self.inp, &mut self.out);
        // drain gating stated once for both dataflows, mirroring
        // `Schedule::drain`'s fixed-window contract: south-edge traffic
        // before the drain window — possible under control-signal
        // faults — is discarded, as the real frontend's drain FSM does.
        if t >= sched.drain_start() {
            let out_rows = sched.out_rows();
            let dim = sched.dim;
            match sched.dataflow {
                Dataflow::OutputStationary => {
                    for col in 0..dim {
                        if self.out.has_south_c(col) {
                            let k = self.taken[col];
                            if k < out_rows {
                                self.cmat.set(out_rows - 1 - k, col, self.out.south_c_at(col));
                                self.taken[col] = k + 1;
                            }
                        }
                    }
                }
                Dataflow::WeightStationary => {
                    for col in 0..dim {
                        if self.out.has_south_psum(col) {
                            let k = self.taken[col];
                            if k < out_rows {
                                self.cmat.set(k, col, self.out.south_psum_at(col));
                                self.taken[col] = k + 1;
                            }
                        }
                    }
                }
            }
        }
        self.mesh_t = t + 1;
        if self.mesh_t == sched.total_cycles() {
            self.finish_window(sched, accmem)?;
        }
        Ok(())
    }

    /// Produce window cycle `t`'s boundary inputs in O(dim), reading
    /// operands through the scratchpad/accmem ports at the same per-cycle
    /// addresses the imperative FSM issued.
    fn fill_window(
        &mut self,
        sched: SocSchedule,
        t: u64,
        spad: &mut Scratchpad,
        accmem: &mut AccMem,
    ) -> Result<()> {
        let dim = sched.dim;
        self.inp.clear();
        if t < sched.preload_cycles() {
            // phase 1: preload down the d-chain (rows fed in reverse)
            let p = t as usize;
            if p < dim {
                match sched.dataflow {
                    Dataflow::OutputStationary => {
                        let d_row = accmem.read_row(sched.d_base + (dim - 1 - p))?;
                        for c in 0..dim {
                            self.inp.north_propag[c] = true;
                            self.inp.north_d[c] = d_row[c];
                        }
                    }
                    Dataflow::WeightStationary => {
                        spad.read_row_into(sched.b_base + (dim - 1 - p), &mut self.row_i8)?;
                        for c in 0..dim {
                            self.inp.north_propag[c] = true;
                            self.inp.north_d[c] = self.row_i8[c] as i32;
                        }
                    }
                }
            }
        } else if t < sched.preload_cycles() + sched.compute_cycles() {
            // phase 2: the skewed operand streams; one operand pair read
            // per cycle while the streams last, pushed into the rings
            let tau = (t - sched.preload_cycles()) as usize;
            let s = sched.stream;
            match sched.dataflow {
                Dataflow::OutputStationary => {
                    if tau < s {
                        spad.read_row_into(sched.a_base + tau, self.ring_a.row_mut(tau % dim))?;
                        spad.read_row_into(sched.b_base + tau, self.ring_b.row_mut(tau % dim))?;
                    }
                    for r in 0..dim {
                        // lane r sees stream element tau - r (skew rings)
                        if tau >= r && tau - r < s {
                            self.inp.west_a[r] = self.ring_a.at((tau - r) % dim, r);
                        }
                    }
                    for c in 0..dim {
                        if tau >= c && tau - c < s {
                            self.inp.north_b[c] = self.ring_b.at((tau - c) % dim, c);
                            self.inp.north_valid[c] = true;
                        }
                    }
                }
                Dataflow::WeightStationary => {
                    if tau < s {
                        spad.read_row_into(sched.a_base + tau, self.ring_a.row_mut(tau % dim))?;
                        let d_row = accmem.read_row(sched.d_base + tau)?;
                        self.ring_d.row_mut(tau % dim).copy_from_slice(d_row);
                    }
                    for r in 0..dim {
                        if tau >= r && tau - r < s {
                            self.inp.west_a[r] = self.ring_a.at((tau - r) % dim, r);
                        }
                    }
                    for c in 0..dim {
                        if tau >= c && tau - c < s {
                            self.inp.north_d[c] = self.ring_d.at((tau - c) % dim, c);
                            self.inp.north_valid[c] = true;
                        }
                    }
                }
            }
        } else {
            // phase 3 (OS only): flush C through the south edge
            debug_assert!(t < sched.total_cycles(), "cycle beyond the schedule");
            let p = (t - sched.preload_cycles() - sched.compute_cycles()) as usize;
            if p < dim {
                for c in 0..dim {
                    self.inp.north_propag[c] = true;
                }
            }
        }
        Ok(())
    }

    /// Close the window: land C into the accumulator memory and disarm.
    fn finish_window(&mut self, sched: SocSchedule, accmem: &mut AccMem) -> Result<()> {
        // the fixed-window drain contract of `Schedule::drain`: only
        // fault-free windows must have produced every result row
        // (control-signal faults can disturb the drain pulses)
        debug_assert!(
            !self.plan.is_empty() || self.taken.iter().all(|&k| k == sched.out_rows()),
            "fault-free drain did not produce every result row"
        );
        for r in 0..sched.out_rows() {
            accmem.write_row(sched.c_base + r, self.cmat.row(r))?;
        }
        // disarm in place (keeps the plan buffer for the next re-arm)
        self.plan.clear();
        self.cursor = PlanCursor::default();
        self.matmuls_done += 1;
        self.window = None;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::driver::gold_matmul;
    use crate::mesh::inject::Fault;
    use crate::mesh::signal::SignalKind;
    use crate::util::Rng;

    /// Stage an OS matmul (spad rows [0..k) = A columns, [k..2k) = B
    /// rows, accmem [0..dim) = D) and enqueue the command sequence;
    /// results land at accmem row 16.
    fn os_setup(
        dim: usize,
        k: usize,
        seed: u64,
    ) -> (Controller, Scratchpad, AccMem, Dma, MainMemory, Mat<i32>) {
        let mut rng = Rng::new(seed);
        let a = rng.mat_i8(dim, k);
        let b = rng.mat_i8(k, dim);
        let d = rng.mat_i32(dim, dim, 1 << 10);

        let mut ctrl = Controller::new(dim, Dataflow::OutputStationary);
        let mut spad = Scratchpad::new(4, 64, dim);
        let mut accmem = AccMem::new(64, dim);
        for kk in 0..k {
            let col: Vec<i8> = (0..dim).map(|r| a.at(r, kk)).collect();
            spad.write_row(kk, &col).unwrap();
            spad.write_row(k + kk, b.row(kk)).unwrap();
            spad.tick();
        }
        for r in 0..dim {
            accmem.write_row(r, d.row(r)).unwrap();
        }
        ctrl.enqueue(RoccCmd { funct: funct::CONFIG, rs1: k as u64, rs2: 0 });
        ctrl.enqueue(RoccCmd { funct: funct::PRELOAD, rs1: 0, rs2: 16 });
        ctrl.enqueue(RoccCmd { funct: funct::COMPUTE, rs1: 0, rs2: k as u64 });
        let gold = gold_matmul(a.view(), b.view(), d.view());
        (ctrl, spad, accmem, Dma::new(), MainMemory::new(1 << 16, 2), gold)
    }

    /// Stage a WS matmul (spad rows [0..m) = A rows, [m..m+dim) = W
    /// rows, accmem [0..m) = D rows); results land at accmem row 32.
    fn ws_setup(
        dim: usize,
        m: usize,
        seed: u64,
    ) -> (Controller, Scratchpad, AccMem, Dma, MainMemory, Mat<i32>) {
        let mut rng = Rng::new(seed);
        let a = rng.mat_i8(m, dim);
        let w = rng.mat_i8(dim, dim);
        let d = rng.mat_i32(m, dim, 1 << 10);

        let mut ctrl = Controller::new(dim, Dataflow::WeightStationary);
        let mut spad = Scratchpad::new(4, 64, dim);
        let mut accmem = AccMem::new(64, dim);
        for r in 0..m {
            spad.write_row(r, a.row(r)).unwrap();
            spad.tick();
        }
        for r in 0..dim {
            spad.write_row(m + r, w.row(r)).unwrap();
            spad.tick();
        }
        for r in 0..m {
            accmem.write_row(r, d.row(r)).unwrap();
        }
        ctrl.enqueue(RoccCmd { funct: funct::CONFIG, rs1: m as u64, rs2: 0 });
        ctrl.enqueue(RoccCmd { funct: funct::PRELOAD, rs1: 0, rs2: 32 });
        ctrl.enqueue(RoccCmd { funct: funct::COMPUTE, rs1: 0, rs2: m as u64 });
        let gold = gold_matmul(a.view(), w.view(), d.view());
        (ctrl, spad, accmem, Dma::new(), MainMemory::new(1 << 16, 2), gold)
    }

    fn run_to_completion(
        ctrl: &mut Controller,
        spad: &mut Scratchpad,
        accmem: &mut AccMem,
        dma: &mut Dma,
        mem: &mut MainMemory,
    ) {
        let mut guard = 0;
        while ctrl.busy() {
            spad.tick();
            ctrl.tick(spad, accmem, dma, mem).unwrap();
            guard += 1;
            assert!(guard < 100_000);
        }
    }

    fn read_c(accmem: &AccMem, base: usize, rows: usize, dim: usize) -> Mat<i32> {
        let mut c = Mat::zeros(rows, dim);
        for r in 0..rows {
            c.row_mut(r).copy_from_slice(accmem.read_row(base + r).unwrap());
        }
        c
    }

    #[test]
    fn controller_matmul_matches_gold() {
        for &(dim, k) in &[(2usize, 2usize), (4, 4), (4, 9), (8, 8)] {
            let (mut ctrl, mut spad, mut accmem, mut dma, mut mem, gold) =
                os_setup(dim, k, dim as u64 * 31 + k as u64);
            run_to_completion(&mut ctrl, &mut spad, &mut accmem, &mut dma, &mut mem);
            assert_eq!(read_c(&accmem, 16, dim, dim), gold, "dim={dim} k={k}");
        }
    }

    #[test]
    fn controller_ws_matmul_matches_gold() {
        for &(dim, m) in &[(2usize, 2usize), (4, 5), (4, 9), (8, 8)] {
            let (mut ctrl, mut spad, mut accmem, mut dma, mut mem, gold) =
                ws_setup(dim, m, dim as u64 * 37 + m as u64);
            run_to_completion(&mut ctrl, &mut spad, &mut accmem, &mut dma, &mut mem);
            assert_eq!(read_c(&accmem, 32, m, dim), gold, "dim={dim} m={m}");
        }
    }

    #[test]
    fn controller_schedule_matches_mesh_driver_cycle_model() {
        use crate::mesh::driver::{os_matmul_cycles, ws_matmul_cycles};
        let os = SocSchedule::new(Dataflow::OutputStationary, 4, 9, 0, 9, 0, 4);
        assert_eq!(os.total_cycles(), os_matmul_cycles(4, 9));
        assert_eq!(os.out_rows(), 4);
        let ws = SocSchedule::new(Dataflow::WeightStationary, 8, 11, 0, 11, 0, 11);
        assert_eq!(ws.total_cycles(), ws_matmul_cycles(8, 11));
        assert_eq!(ws.out_rows(), 11);
        assert_eq!(ws.flush_cycles(), 0);
    }

    #[test]
    fn controller_state_restore_after_save_is_identity() {
        type Setup = fn(usize, usize, u64) -> (Controller, Scratchpad, AccMem, Dma, MainMemory, Mat<i32>);
        for setup in [os_setup as Setup, ws_setup] {
            let (mut ctrl, mut spad, mut accmem, mut dma, mut mem, _gold) = setup(4, 6, 7);
            // advance into the matmul window
            let mut guard = 0;
            while !(ctrl.in_window() && ctrl.mesh_cycle() == 5) {
                spad.tick();
                ctrl.tick(&mut spad, &mut accmem, &mut dma, &mut mem).unwrap();
                guard += 1;
                assert!(guard < 10_000);
            }
            let mut snap = ControllerState::default();
            ctrl.save_state(&mut snap);
            // churn past the snapshot, then restore
            for _ in 0..7 {
                spad.tick();
                ctrl.tick(&mut spad, &mut accmem, &mut dma, &mut mem).unwrap();
            }
            ctrl.restore_state(&snap);
            let mut snap2 = ControllerState::default();
            ctrl.save_state(&mut snap2);
            assert_eq!(snap, snap2, "restore ∘ save must be the identity");
        }
    }

    #[test]
    fn controller_replay_from_snapshot_matches_full_window() {
        // Snapshot the golden window mid-flight, run the rest golden
        // (churn), then restore + begin_replay: the faulty result must be
        // bit-identical to arming the plan before the full run — the
        // controller-level cycle-resume contract, both dataflows.
        type Setup = fn(usize, usize, u64) -> (Controller, Scratchpad, AccMem, Dma, MainMemory, Mat<i32>);
        for (setup, fault_cycle) in [(os_setup as Setup, 9u64), (ws_setup as Setup, 8u64)] {
            let plan =
                FaultPlan::single(Fault::new(1, 2, SignalKind::Acc, 12, fault_cycle));
            // oracle: the plan armed across the whole window
            let (mut ctrl, mut spad, mut accmem, mut dma, mut mem, _gold) = setup(4, 6, 42);
            ctrl.arm_plan(&plan);
            run_to_completion(&mut ctrl, &mut spad, &mut accmem, &mut dma, &mut mem);
            let c_full_os = read_c(&accmem, 16, 4, 4);
            let c_full_ws = read_c(&accmem, 32, 6, 4);

            // golden to the fault cycle, snapshot, churn to the end,
            // restore, replay with the plan
            let (mut ctrl, mut spad, mut accmem, mut dma, mut mem, _gold) = setup(4, 6, 42);
            let mut guard = 0;
            while !(ctrl.in_window() && ctrl.mesh_cycle() == fault_cycle) {
                spad.tick();
                ctrl.tick(&mut spad, &mut accmem, &mut dma, &mut mem).unwrap();
                guard += 1;
                assert!(guard < 10_000);
            }
            let mut snap = ControllerState::default();
            ctrl.save_state(&mut snap);
            run_to_completion(&mut ctrl, &mut spad, &mut accmem, &mut dma, &mut mem);
            ctrl.restore_state(&snap);
            ctrl.begin_replay(&plan);
            run_to_completion(&mut ctrl, &mut spad, &mut accmem, &mut dma, &mut mem);
            assert_eq!(read_c(&accmem, 16, 4, 4), c_full_os, "OS landing rows");
            assert_eq!(read_c(&accmem, 32, 6, 4), c_full_ws, "WS landing rows");
        }
    }

    #[test]
    fn mvin_then_mvout_round_trip() {
        let mut ctrl = Controller::new(4, Dataflow::OutputStationary);
        let mut spad = Scratchpad::new(4, 64, 4);
        let mut accmem = AccMem::new(64, 4);
        let mut dma = Dma::new();
        let mut mem = MainMemory::new(1 << 12, 2);
        for (i, b) in mem.bytes[64..72].iter_mut().enumerate() {
            *b = (i as i8) - 4;
        }
        ctrl.enqueue(RoccCmd { funct: funct::MVIN, rs1: 64, rs2: (2u64 << 32) | 8 });
        ctrl.enqueue(RoccCmd { funct: funct::MVOUT, rs1: 256, rs2: (2u64 << 32) | 8 });
        let mut guard = 0;
        while ctrl.busy() || dma.busy() {
            spad.tick();
            ctrl.tick(&mut spad, &mut accmem, &mut dma, &mut mem).unwrap();
            dma.tick(&mut mem, &mut spad).unwrap();
            guard += 1;
            assert!(guard < 10_000);
        }
        assert_eq!(&mem.bytes[256..264], &mem.bytes[64..72].to_vec()[..]);
    }
}
