//! Gemmini controller: RoCC command queue (ROB), config state and the
//! execute FSM that drives operand streams from the scratchpad into the
//! mesh — the `ExecuteController` / `LoadController` / `StoreController`
//! complex of the real design.
//!
//! The execute FSM reproduces *exactly* the schedule of
//! [`crate::mesh::driver::MatmulDriver`] (preload / compute / flush with
//! the same skews), so a fault at mesh-relative cycle `t` produces the
//! same corruption whether injected through the mesh-only wrapper or
//! through the full SoC — pinned by `rust/tests/integration_soc.rs`.

use super::core::RoccCmd;
use super::dma::{Dma, DmaDir, MainMemory};
use super::scratchpad::{AccMem, Scratchpad};
use crate::mat::Mat;
use crate::mesh::adapters::FlushCollector;
use crate::mesh::inject::{FaultPlan, PlanCursor};
use crate::mesh::mesh::{Mesh, MeshInputs, MeshSim, StepOutput};
use anyhow::Result;
use std::collections::VecDeque;

/// Gemmini RoCC functs (subset).
pub mod funct {
    pub const CONFIG: u8 = 0;
    pub const MVIN: u8 = 1;
    pub const PRELOAD: u8 = 2;
    pub const COMPUTE: u8 = 3;
    pub const MVOUT: u8 = 4;
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum ExecState {
    Idle,
    Preload { p: usize },
    Compute { tau: usize },
    Flush { p: usize },
}

/// The controller + mesh complex.
pub struct Controller {
    pub mesh: Mesh,
    rob: VecDeque<RoccCmd>,
    state: ExecState,
    /// config: inner dimension (stream length K) of the next compute.
    cfg_k: usize,
    /// operand base rows (set by the COMPUTE command).
    a_base: usize,
    b_base: usize,
    /// accmem row holding D (set by PRELOAD) and landing row for C.
    d_base: usize,
    c_base: usize,
    /// ring buffers implementing the skew shift registers at the edges
    /// (flat DIM x DIM matrices; row = ring slot).
    ring_a: Mat<i8>,
    ring_b: Mat<i8>,
    /// mesh-relative cycle counter for the in-flight matmul.
    mesh_t: u64,
    /// armed fault plan for the next COMPUTE (mesh-relative cycles;
    /// empty = golden) and its per-run firing cursor.
    plan: FaultPlan,
    cursor: PlanCursor,
    collector: Option<FlushCollector>,
    inp: MeshInputs,
    out: StepOutput,
    /// statistics
    pub matmuls_done: u64,
}

impl Controller {
    /// Build the controller + mesh complex. The dataflow comes from the
    /// campaign's `MeshConfig` (never hardcoded here), but the execute
    /// FSM implements only the OS preload/compute/flush schedule — a WS
    /// request is a hard error, surfaced as a clear config-level error
    /// by `campaign::validate_dataflow_support` before any SoC is
    /// constructed (ROADMAP "Dataflow-generic campaigns": the SoC
    /// backend stays OS-only for now, with no silent override).
    pub fn new(dim: usize, dataflow: crate::config::Dataflow) -> Self {
        assert_eq!(
            dataflow,
            crate::config::Dataflow::OutputStationary,
            "the SoC execute FSM implements only the output-stationary schedule"
        );
        Controller {
            mesh: Mesh::new(dim, dataflow),
            rob: VecDeque::new(),
            state: ExecState::Idle,
            cfg_k: dim,
            a_base: 0,
            b_base: 0,
            d_base: 0,
            c_base: 0,
            ring_a: Mat::zeros(dim, dim),
            ring_b: Mat::zeros(dim, dim),
            mesh_t: 0,
            plan: FaultPlan::empty(),
            cursor: PlanCursor::default(),
            collector: None,
            inp: MeshInputs::idle(dim),
            out: StepOutput::new(dim),
            matmuls_done: 0,
        }
    }

    pub fn dim(&self) -> usize {
        self.mesh.dim()
    }

    /// ROB occupancy (drives the core's fence).
    pub fn busy(&self) -> bool {
        !self.rob.is_empty() || self.state != ExecState::Idle
    }

    pub fn enqueue(&mut self, cmd: RoccCmd) {
        self.rob.push_back(cmd);
    }

    /// Arm a fault plan at mesh-relative cycles of the *next* compute
    /// command (empty plan = golden; the cursor starts when COMPUTE
    /// issues, since that is where the mesh-relative clock resets).
    /// Copies into the controller's persistent plan buffer — no
    /// per-trial allocation on the campaign's re-arm path.
    pub fn arm_plan(&mut self, plan: &FaultPlan) {
        self.plan.clone_from_plan(plan);
    }

    /// Power-on state: idle FSM, empty ROB, cleared rings, disarmed
    /// fault, zeroed counters. Keeps every allocation.
    pub fn reset(&mut self) {
        let dim = self.dim();
        self.mesh.reset();
        self.rob.clear();
        self.state = ExecState::Idle;
        self.cfg_k = dim;
        self.a_base = 0;
        self.b_base = 0;
        self.d_base = 0;
        self.c_base = 0;
        self.ring_a.data_mut().fill(0);
        self.ring_b.data_mut().fill(0);
        self.mesh_t = 0;
        self.plan.clear();
        self.cursor = PlanCursor::default();
        self.collector = None;
        self.inp.clear();
        self.out.clear();
        self.matmuls_done = 0;
    }

    /// One clock edge of the controller + mesh complex.
    pub fn tick(
        &mut self,
        spad: &mut Scratchpad,
        accmem: &mut AccMem,
        dma: &mut Dma,
        mem: &mut MainMemory,
    ) -> Result<()> {
        let dim = self.dim();
        match self.state {
            ExecState::Idle => {
                // decode at most one command per cycle (issue stage)
                if let Some(cmd) = self.rob.front().copied() {
                    match cmd.funct {
                        funct::CONFIG => {
                            self.cfg_k = cmd.rs1 as usize;
                            self.rob.pop_front();
                        }
                        funct::MVIN => {
                            if !dma.busy() {
                                let rows = (cmd.rs2 >> 32) as usize;
                                let spad_row = (cmd.rs2 & 0xffff_ffff) as usize;
                                dma.start(
                                    DmaDir::MemToSpad,
                                    cmd.rs1 as usize,
                                    spad_row,
                                    rows,
                                    mem,
                                );
                                self.rob.pop_front();
                            }
                        }
                        funct::MVOUT => {
                            if !dma.busy() {
                                let rows = (cmd.rs2 >> 32) as usize;
                                let spad_row = (cmd.rs2 & 0xffff_ffff) as usize;
                                dma.start(
                                    DmaDir::SpadToMem,
                                    cmd.rs1 as usize,
                                    spad_row,
                                    rows,
                                    mem,
                                );
                                self.rob.pop_front();
                            }
                        }
                        funct::PRELOAD => {
                            self.d_base = cmd.rs1 as usize;
                            self.c_base = cmd.rs2 as usize;
                            self.rob.pop_front();
                        }
                        funct::COMPUTE => {
                            self.a_base = cmd.rs1 as usize;
                            self.b_base = cmd.rs2 as usize;
                            self.rob.pop_front();
                            self.mesh.reset();
                            self.mesh_t = 0;
                            self.cursor = PlanCursor::start(&self.plan);
                            self.collector = Some(FlushCollector::new(dim));
                            self.ring_a.data_mut().fill(0);
                            self.ring_b.data_mut().fill(0);
                            self.state = ExecState::Preload { p: 0 };
                        }
                        other => anyhow::bail!("unknown RoCC funct {other}"),
                    }
                }
                // the full SoC clocks the mesh every cycle, busy or not
                self.inp.clear();
                self.mesh.step(&self.inp, &mut self.out);
            }
            ExecState::Preload { p } => {
                self.inp.clear();
                if p < dim {
                    let d_row = accmem.read_row(self.d_base + (dim - 1 - p))?.to_vec();
                    for c in 0..dim {
                        self.inp.north_propag[c] = true;
                        self.inp.north_d[c] = d_row[c];
                    }
                }
                self.step_mesh_with_fault();
                self.state = if p + 1 == 2 * dim - 1 {
                    ExecState::Compute { tau: 0 }
                } else {
                    ExecState::Preload { p: p + 1 }
                };
            }
            ExecState::Compute { tau } => {
                let k = self.cfg_k;
                // scratchpad reads: one operand column/row pair per cycle
                // while the streams last, pushed into the skew registers.
                if tau < k {
                    let (a_col, _s1) = spad.read_row(self.a_base + tau)?;
                    let (b_row, _s2) = spad.read_row(self.b_base + tau)?;
                    self.ring_a.row_mut(tau % dim).copy_from_slice(&a_col);
                    self.ring_b.row_mut(tau % dim).copy_from_slice(&b_row);
                }
                self.inp.clear();
                for r in 0..dim {
                    // lane r sees stream element tau - r (skew registers)
                    if tau >= r && tau - r < k {
                        self.inp.west_a[r] = self.ring_a.at((tau - r) % dim, r);
                    }
                }
                for c in 0..dim {
                    if tau >= c && tau - c < k {
                        self.inp.north_b[c] = self.ring_b.at((tau - c) % dim, c);
                        self.inp.north_valid[c] = true;
                    }
                }
                self.step_mesh_with_fault();
                self.state = if tau + 1 == k + 2 * dim - 2 {
                    ExecState::Flush { p: 0 }
                } else {
                    ExecState::Compute { tau: tau + 1 }
                };
            }
            ExecState::Flush { p } => {
                self.inp.clear();
                self.out.clear();
                if p < dim {
                    for c in 0..dim {
                        self.inp.north_propag[c] = true;
                    }
                }
                self.step_mesh_with_fault();
                if let Some(col) = self.collector.as_mut() {
                    col.absorb(&self.out);
                }
                if p + 1 == 2 * dim - 1 {
                    // land C into the accumulator memory
                    let col = self.collector.take().expect("flush without collector");
                    debug_assert!(col.complete());
                    for (r, row) in col.c.row_iter().enumerate() {
                        accmem.write_row(self.c_base + r, row)?;
                    }
                    // disarm in place (keeps the plan buffer for the
                    // next trial's re-arm)
                    self.plan.clear();
                    self.cursor = PlanCursor::default();
                    self.matmuls_done += 1;
                    self.state = ExecState::Idle;
                } else {
                    self.state = ExecState::Flush { p: p + 1 };
                }
            }
        }
        Ok(())
    }

    fn step_mesh_with_fault(&mut self) {
        // one compare per mesh cycle — same wrapper contract as the
        // mesh-only driver (`PlanCursor::next_cycle`)
        if self.cursor.next_cycle() == self.mesh_t {
            self.cursor
                .fire(&self.plan, self.mesh_t, &mut self.mesh, &mut self.inp);
        }
        self.mesh.step(&self.inp, &mut self.out);
        self.mesh_t += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Drive the controller directly (no core) through one matmul.
    fn run_matmul_direct(dim: usize, k: usize, seed: u64) -> (Mat<i32>, Mat<i32>) {
        use crate::mesh::driver::gold_matmul;
        use crate::util::Rng;
        let mut rng = Rng::new(seed);
        let a = rng.mat_i8(dim, k);
        let b = rng.mat_i8(k, dim);
        let d = rng.mat_i32(dim, dim, 1 << 10);

        let mut ctrl = Controller::new(dim, crate::config::Dataflow::OutputStationary);
        let mut spad = Scratchpad::new(4, 64, dim);
        let mut accmem = AccMem::new(64, dim);
        let mut dma = Dma::new();
        let mut mem = MainMemory::new(1 << 16, 2);

        // stage operands: spad rows [0..k) = A columns, [k..2k) = B rows
        for kk in 0..k {
            let col: Vec<i8> = (0..dim).map(|r| a.at(r, kk)).collect();
            spad.write_row(kk, &col).unwrap();
            spad.write_row(k + kk, b.row(kk)).unwrap();
            spad.tick();
        }
        for r in 0..dim {
            accmem.write_row(r, d.row(r)).unwrap();
        }
        ctrl.enqueue(RoccCmd { funct: funct::CONFIG, rs1: k as u64, rs2: 0 });
        ctrl.enqueue(RoccCmd { funct: funct::PRELOAD, rs1: 0, rs2: 16 });
        ctrl.enqueue(RoccCmd { funct: funct::COMPUTE, rs1: 0, rs2: k as u64 });
        let mut guard = 0;
        while ctrl.busy() {
            spad.tick();
            ctrl.tick(&mut spad, &mut accmem, &mut dma, &mut mem).unwrap();
            guard += 1;
            assert!(guard < 100_000);
        }
        let mut c = Mat::zeros(dim, dim);
        for r in 0..dim {
            c.row_mut(r)
                .copy_from_slice(accmem.read_row(16 + r).unwrap());
        }
        (c, gold_matmul(a.view(), b.view(), d.view()))
    }

    #[test]
    fn controller_matmul_matches_gold() {
        for &(dim, k) in &[(2usize, 2usize), (4, 4), (4, 9), (8, 8)] {
            let (c, gold) = run_matmul_direct(dim, k, dim as u64 * 31 + k as u64);
            assert_eq!(c, gold, "dim={dim} k={k}");
        }
    }

    #[test]
    fn mvin_then_mvout_round_trip() {
        let mut ctrl = Controller::new(4, crate::config::Dataflow::OutputStationary);
        let mut spad = Scratchpad::new(4, 64, 4);
        let mut accmem = AccMem::new(64, 4);
        let mut dma = Dma::new();
        let mut mem = MainMemory::new(1 << 12, 2);
        for (i, b) in mem.bytes[64..72].iter_mut().enumerate() {
            *b = (i as i8) - 4;
        }
        ctrl.enqueue(RoccCmd { funct: funct::MVIN, rs1: 64, rs2: (2u64 << 32) | 8 });
        ctrl.enqueue(RoccCmd { funct: funct::MVOUT, rs1: 256, rs2: (2u64 << 32) | 8 });
        let mut guard = 0;
        while ctrl.busy() || dma.busy() {
            spad.tick();
            ctrl.tick(&mut spad, &mut accmem, &mut dma, &mut mem).unwrap();
            dma.tick(&mut mem, &mut spad).unwrap();
            guard += 1;
            assert!(guard < 10_000);
        }
        assert_eq!(&mem.bytes[256..264], &mem.bytes[64..72].to_vec()[..]);
    }
}
