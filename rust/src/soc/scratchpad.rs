//! Gemmini's banked scratchpad and accumulator SRAM.
//!
//! The real design has N banks of single-ported SRAM with row-wide
//! read/write ports feeding the mesh edge, plus a separate 32-bit
//! accumulator memory. Bank-conflict arbitration is per-cycle logic in
//! the verilated SoC; the model reproduces it (one read + one write port
//! per bank per cycle).

use anyhow::{bail, Result};

/// Banked int8 scratchpad with row-granularity ports (one row = DIM bytes).
pub struct Scratchpad {
    banks: usize,
    rows_per_bank: usize,
    row_bytes: usize,
    data: Vec<i8>,
    /// Per-cycle port occupancy (cleared by `tick`).
    read_busy: Vec<bool>,
    write_busy: Vec<bool>,
    pub conflicts: u64,
}

impl Scratchpad {
    pub fn new(banks: usize, rows_per_bank: usize, row_bytes: usize) -> Self {
        Scratchpad {
            banks,
            rows_per_bank,
            row_bytes,
            data: vec![0; banks * rows_per_bank * row_bytes],
            read_busy: vec![false; banks],
            write_busy: vec![false; banks],
            conflicts: 0,
        }
    }

    /// Zero the SRAM, release the ports and clear statistics (power-on
    /// state) without reallocating.
    pub fn reset(&mut self) {
        self.data.fill(0);
        self.read_busy.fill(false);
        self.write_busy.fill(false);
        self.conflicts = 0;
    }

    pub fn rows(&self) -> usize {
        self.banks * self.rows_per_bank
    }

    pub fn row_bytes(&self) -> usize {
        self.row_bytes
    }

    fn locate(&self, row: usize) -> Result<(usize, usize)> {
        if row >= self.rows() {
            bail!("scratchpad row {row} out of range ({} rows)", self.rows());
        }
        Ok((row % self.banks, row / self.banks))
    }

    /// Read a full row. Returns (data, stall): stall = 1 if the bank's
    /// read port was already claimed this cycle.
    pub fn read_row(&mut self, row: usize) -> Result<(Vec<i8>, u32)> {
        let (bank, local) = self.locate(row)?;
        let stall = if self.read_busy[bank] {
            self.conflicts += 1;
            1
        } else {
            self.read_busy[bank] = true;
            0
        };
        let off = (bank * self.rows_per_bank + local) * self.row_bytes;
        Ok((self.data[off..off + self.row_bytes].to_vec(), stall))
    }

    /// [`Scratchpad::read_row`] into a caller-provided buffer: identical
    /// port arbitration, stall and conflict accounting, but no per-call
    /// allocation — the SoC controller's per-cycle operand reads land
    /// directly in its persistent skew rings through this port.
    pub fn read_row_into(&mut self, row: usize, dst: &mut [i8]) -> Result<u32> {
        let (bank, local) = self.locate(row)?;
        if dst.len() != self.row_bytes {
            bail!("row read of {} bytes from {}-byte rows", dst.len(), self.row_bytes);
        }
        let stall = if self.read_busy[bank] {
            self.conflicts += 1;
            1
        } else {
            self.read_busy[bank] = true;
            0
        };
        let off = (bank * self.rows_per_bank + local) * self.row_bytes;
        dst.copy_from_slice(&self.data[off..off + self.row_bytes]);
        Ok(stall)
    }

    /// Write a full row (port-arbitrated like reads).
    pub fn write_row(&mut self, row: usize, bytes: &[i8]) -> Result<u32> {
        let (bank, local) = self.locate(row)?;
        if bytes.len() != self.row_bytes {
            bail!("row write of {} bytes into {}-byte rows", bytes.len(), self.row_bytes);
        }
        let stall = if self.write_busy[bank] {
            self.conflicts += 1;
            1
        } else {
            self.write_busy[bank] = true;
            0
        };
        let off = (bank * self.rows_per_bank + local) * self.row_bytes;
        self.data[off..off + self.row_bytes].copy_from_slice(bytes);
        Ok(stall)
    }

    /// Release the per-cycle ports (clock edge).
    pub fn tick(&mut self) {
        self.read_busy.fill(false);
        self.write_busy.fill(false);
    }

    pub fn state_elements(&self) -> usize {
        // ports + arbitration per bank; the SRAM macro itself is not
        // swept per cycle by Verilator either.
        self.banks * 4
    }
}

/// The 32-bit accumulator SRAM (bias staging / result landing zone).
pub struct AccMem {
    rows: usize,
    row_elems: usize,
    data: Vec<i32>,
}

impl AccMem {
    pub fn new(rows: usize, row_elems: usize) -> Self {
        AccMem {
            rows,
            row_elems,
            data: vec![0; rows * row_elems],
        }
    }

    /// Zero the accumulator SRAM (power-on state) without reallocating.
    pub fn reset(&mut self) {
        self.data.fill(0);
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn read_row(&self, row: usize) -> Result<&[i32]> {
        if row >= self.rows {
            bail!("accmem row {row} out of range");
        }
        Ok(&self.data[row * self.row_elems..(row + 1) * self.row_elems])
    }

    pub fn write_row(&mut self, row: usize, vals: &[i32]) -> Result<()> {
        if row >= self.rows {
            bail!("accmem row {row} out of range");
        }
        if vals.len() != self.row_elems {
            bail!("accmem row width mismatch");
        }
        self.data[row * self.row_elems..(row + 1) * self.row_elems].copy_from_slice(vals);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn read_write_round_trip() {
        let mut sp = Scratchpad::new(4, 16, 8);
        let row = vec![1i8, -2, 3, -4, 5, -6, 7, -8];
        sp.write_row(5, &row).unwrap();
        sp.tick();
        let (got, stall) = sp.read_row(5).unwrap();
        assert_eq!(got, row);
        assert_eq!(stall, 0);
    }

    #[test]
    fn read_row_into_matches_read_row_ports_included() {
        let mut sp = Scratchpad::new(4, 16, 8);
        let row = vec![9i8, -8, 7, -6, 5, -4, 3, -2];
        sp.write_row(6, &row).unwrap();
        sp.tick();
        let mut buf = vec![0i8; 8];
        assert_eq!(sp.read_row_into(6, &mut buf).unwrap(), 0);
        assert_eq!(buf, row);
        // second same-bank read this cycle stalls, exactly like read_row
        assert_eq!(sp.read_row_into(2, &mut buf).unwrap(), 1);
        assert_eq!(sp.conflicts, 1);
        assert!(sp.read_row_into(0, &mut vec![0i8; 4]).is_err());
    }

    #[test]
    fn same_bank_double_read_conflicts() {
        let mut sp = Scratchpad::new(4, 16, 8);
        // rows 0 and 4 both live in bank 0
        let (_v, s1) = sp.read_row(0).unwrap();
        let (_v, s2) = sp.read_row(4).unwrap();
        assert_eq!(s1, 0);
        assert_eq!(s2, 1);
        assert_eq!(sp.conflicts, 1);
        sp.tick();
        let (_v, s3) = sp.read_row(4).unwrap();
        assert_eq!(s3, 0, "ports released at the clock edge");
    }

    #[test]
    fn different_banks_no_conflict() {
        let mut sp = Scratchpad::new(4, 16, 8);
        assert_eq!(sp.read_row(0).unwrap().1, 0);
        assert_eq!(sp.read_row(1).unwrap().1, 0);
        assert_eq!(sp.conflicts, 0);
    }

    #[test]
    fn out_of_range_errors() {
        let mut sp = Scratchpad::new(2, 4, 8);
        assert!(sp.read_row(8).is_err());
        assert!(sp.write_row(8, &vec![0; 8]).is_err());
        assert!(sp.write_row(0, &vec![0; 4]).is_err());
    }

    #[test]
    fn accmem_round_trip() {
        let mut am = AccMem::new(8, 4);
        am.write_row(3, &[1, 2, 3, 4]).unwrap();
        assert_eq!(am.read_row(3).unwrap(), &[1, 2, 3, 4]);
        assert!(am.read_row(9).is_err());
    }
}
