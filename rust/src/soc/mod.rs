//! Full-SoC RTL-level baseline (paper Fig. 3): Rocket-like core, L1
//! caches, interconnect, DMA, scratchpad, Gemmini controller and the
//! mesh — every block evaluated every cycle, like a verilated Chipyard
//! SoC. This is what ENFOR-SA's mesh isolation is benchmarked against.

pub mod cache;
pub mod controller;
pub mod core;
pub mod detail;
pub mod dma;
pub mod scratchpad;
#[allow(clippy::module_inception)]
pub mod soc;

pub use controller::{Controller, ControllerState, SocSchedule};
pub use soc::Soc;
