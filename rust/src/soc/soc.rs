//! The full-SoC simulation target (paper Fig. 3, Step 1): Rocket-like
//! core + L1 caches + TileLink-style interconnect + DMA + scratchpad +
//! Gemmini controller + the mesh — everything evaluated every cycle, the
//! way Verilator evaluates the whole elaborated design.
//!
//! This is the *baseline* ENFOR-SA's mesh isolation is measured against
//! (Table V): functionally it computes the same matmuls as the mesh-only
//! wrapper, but each simulated cycle pays for the entire SoC.

use super::controller::{funct, Controller, ControllerState, SocSchedule};
use super::core::{Core, Insn};
use super::detail::UncoreDetail;
use super::cache::Cache;
use super::dma::{Dma, MainMemory};
use super::scratchpad::{AccMem, Scratchpad};
use crate::mat::{Mat, MatView};
use crate::mesh::inject::FaultPlan;
use anyhow::Result;

/// TileLink-style crossbar: per-cycle arbitration state between the
/// core, DMA and peripheral ports (round-robin grant counters + request
/// queues the verilated uncore evaluates every cycle).
pub struct Interconnect {
    grant_rr: u32,
    inflight: [u32; 8],
    pub beats: u64,
}

impl Default for Interconnect {
    fn default() -> Self {
        Self::new()
    }
}

impl Interconnect {
    pub fn new() -> Self {
        Interconnect {
            grant_rr: 0,
            inflight: [0; 8],
            beats: 0,
        }
    }

    pub fn tick(&mut self) {
        self.grant_rr = (self.grant_rr + 1) % 8;
        for q in self.inflight.iter_mut() {
            *q = q.saturating_sub(1);
        }
        self.beats += 1;
    }
}

/// Cycle-resume bookkeeping for the FullSoc backend (ROADMAP
/// "Schedule-indexable SoC"): the identity of the currently staged tile,
/// the schedule its COMPUTE opened, the golden controller snapshot and
/// how far along the window it has been advanced. Reused (and its
/// buffers recycled) across every trial of a site batch; invalidated by
/// [`Soc::reset`].
#[derive(Default)]
struct SocResume {
    /// Tile identity of the staged operands (`None` = nothing staged).
    key: Option<(usize, usize)>,
    /// Mesh-relative cycle the golden snapshot `state` sits at.
    cycle: u64,
    /// The staged window's schedule, captured at COMPUTE decode — kept
    /// outside `state` so it survives a golden advance that closes the
    /// window (first effect at/after the window end).
    sched: Option<SocSchedule>,
    state: ControllerState,
}

/// The complete SoC.
pub struct Soc {
    pub core: Core,
    pub icache: Cache,
    pub dcache: Cache,
    pub xbar: Interconnect,
    pub spad: Scratchpad,
    pub accmem: AccMem,
    pub dma: Dma,
    pub mem: MainMemory,
    pub ctrl: Controller,
    pub detail: UncoreDetail,
    pub cycles: u64,
    icache_stall: u32,
    resume: SocResume,
}

impl Soc {
    /// Build a SoC around a DIM x DIM output-stationary mesh with
    /// Chipyard-like defaults (16 KiB L1s, 256 KiB scratchpad, 64 KiB
    /// accumulator).
    pub fn new(dim: usize) -> Self {
        Self::with_dataflow(dim, crate::config::Dataflow::OutputStationary)
    }

    /// [`Soc::new`] with the dataflow taken from `MeshConfig`. Both
    /// dataflows are first-class end-to-end targets: the controller's
    /// [`SocSchedule`] opens the OS preload/compute/flush window or the
    /// WS preload/compute window from the same command stream shape
    /// (ROADMAP "Schedule-indexable SoC").
    pub fn with_dataflow(dim: usize, dataflow: crate::config::Dataflow) -> Self {
        let spad_rows = (256 * 1024 / dim).max(4 * dim * dim);
        Soc {
            core: Core::new(),
            icache: Cache::new(16 * 1024, 4, 64, 20),
            dcache: Cache::new(16 * 1024, 4, 64, 20),
            xbar: Interconnect::new(),
            spad: Scratchpad::new(4, spad_rows / 4, dim),
            accmem: AccMem::new((64 * 1024 / (4 * dim)).max(4 * dim), dim),
            dma: Dma::new(),
            mem: MainMemory::new(1 << 22, 4),
            ctrl: Controller::new(dim, dataflow),
            detail: UncoreDetail::new(dim),
            cycles: 0,
            icache_stall: 0,
            resume: SocResume::default(),
        }
    }

    pub fn dim(&self) -> usize {
        self.ctrl.dim()
    }

    /// The mesh dataflow this SoC executes (see [`Soc::with_dataflow`]).
    pub fn dataflow(&self) -> crate::config::Dataflow {
        use crate::mesh::MeshSim;
        self.ctrl.mesh.dataflow()
    }

    /// Return the SoC to power-on state **without reallocating** its
    /// large memories (4 MiB main memory, 256 KiB scratchpad, cache tag
    /// arrays). Campaigns reuse one SoC across all `FullSoc` trials via
    /// this reset instead of constructing a fresh `Soc::new(dim)` per
    /// trial; `run_matmul` results after a reset are bit-identical to a
    /// freshly built SoC (fault cycles are mesh-relative). Also
    /// invalidates the cycle-resume cursor — the next
    /// [`Soc::run_matmul_resumed`] re-stages its tile from scratch
    /// (snapshot buffers are kept, only the identity is dropped).
    pub fn reset(&mut self) {
        let dim = self.dim();
        self.core = Core::new();
        self.icache.reset();
        self.dcache.reset();
        self.xbar = Interconnect::new();
        self.spad.reset();
        self.accmem.reset();
        self.dma.reset();
        self.mem.reset();
        self.ctrl.reset();
        self.detail = UncoreDetail::new(dim);
        self.cycles = 0;
        self.icache_stall = 0;
        self.resume.key = None;
        self.resume.cycle = 0;
        self.resume.sched = None;
    }

    /// One SoC clock edge: every block evaluates, like the verilated SoC.
    pub fn tick(&mut self, prog: &[Insn]) -> Result<()> {
        self.cycles += 1;
        // uncore always evaluates (predictors, TLBs, FPU, TileLink,
        // Gemmini's non-mesh pipelines — the cost mesh isolation removes)
        self.detail
            .tick(self.cycles, self.core.pc as u64 * 4, self.spad.rows());
        self.xbar.tick();
        self.icache.tick(self.cycles);
        self.dcache.tick(self.cycles);
        self.spad.tick();
        self.dma.tick(&mut self.mem, &mut self.spad)?;
        // core front-end (with icache stalls)
        if self.icache_stall > 0 {
            self.icache_stall -= 1;
        } else if !self.core.halted() {
            let pc = self.core.pc as u64 * 4;
            self.icache_stall = self.icache.access(pc);
            let rob_busy = self.ctrl.busy() || self.dma.busy();
            if let Some(cmd) = self.core.step(prog, rob_busy) {
                self.ctrl.enqueue(cmd);
            }
        }
        // accelerator complex
        self.ctrl
            .tick(&mut self.spad, &mut self.accmem, &mut self.dma, &mut self.mem)?;
        Ok(())
    }

    /// Total architectural state evaluated per cycle (DESIGN.md D2):
    /// the quantity that explains why mesh-only simulation wins, and why
    /// the win shrinks as DIM grows (Table V).
    pub fn state_elements(&self) -> usize {
        self.core.state_elements()
            + self.detail.state_elements()
            + self.icache.state_elements()
            + self.dcache.state_elements()
            + self.spad.state_elements()
            + 16 // xbar
            + self.ctrl.mesh.state_elements()
    }

    /// Run one `C = A . B + D` matmul end-to-end *through the core*:
    /// the driver program stages operands with MVIN commands, issues
    /// PRELOAD + COMPUTE, fences, and halts. Returns C.
    ///
    /// `plan`: fault plan at mesh-relative cycles of the compute (same
    /// addressing as the mesh-only wrapper; empty plan = golden run).
    pub fn run_matmul(
        &mut self,
        a: MatView<i8>,
        b: MatView<i8>,
        d: MatView<i32>,
        plan: &FaultPlan,
    ) -> Result<Mat<i32>> {
        let mut c = Mat::default();
        self.run_matmul_into(a, b, d, plan, &mut c)?;
        Ok(c)
    }

    /// [`Soc::run_matmul`] into a caller-provided buffer (reshaped and
    /// zeroed in place) — the allocation-free seam the site-major trial
    /// batches drive. Returns the SoC cycles this run ticked.
    ///
    /// Executes the FULL driver program every call (command decode, DMA
    /// staging, matmul window, fence drain). The cycle-resume
    /// counterpart is [`Soc::run_matmul_resumed`], which pays the
    /// prefix once per staged tile and replays only window suffixes —
    /// both count SoC cycles through the same `self.cycles` clock, so
    /// the two tile engines' `rtl_cycles_stepped` are directly
    /// comparable (ROADMAP "Schedule-indexable SoC").
    pub fn run_matmul_into(
        &mut self,
        a: MatView<i8>,
        b: MatView<i8>,
        d: MatView<i32>,
        plan: &FaultPlan,
        out: &mut Mat<i32>,
    ) -> Result<u64> {
        let cycles_before = self.cycles;
        let (prog, out_rows, c_base) = self.stage(a, b, d)?;
        if !plan.is_empty() {
            self.ctrl.arm_plan(plan);
        }
        let mut guard = 0u64;
        while !self.core.halted() || self.ctrl.busy() || self.dma.busy() {
            self.tick(&prog)?;
            guard += 1;
            anyhow::ensure!(guard < 10_000_000, "SoC run did not terminate");
        }
        out.reset(out_rows, self.dim());
        for r in 0..out_rows {
            out.row_mut(r).copy_from_slice(self.accmem.read_row(c_base + r)?);
        }
        Ok(self.cycles - cycles_before)
    }

    /// Stage one matmul's operands (main memory + accmem bias rows) and
    /// build the driver program the core executes, per dataflow.
    /// Returns `(program, out_rows, c_base)`: how many result rows land
    /// and at which accmem row.
    fn stage(
        &mut self,
        a: MatView<i8>,
        b: MatView<i8>,
        d: MatView<i32>,
    ) -> Result<(Vec<Insn>, usize, usize)> {
        // the driver program runs from reset on every matmul
        self.core = Core::new();
        match self.dataflow() {
            crate::config::Dataflow::OutputStationary => self.stage_os(a, b, d),
            crate::config::Dataflow::WeightStationary => self.stage_ws(a, b, d),
        }
    }

    /// OS staging: A as K DIM-columns, B as K rows, D as DIM bias rows;
    /// C lands at accmem rows `dim..2*dim`.
    fn stage_os(
        &mut self,
        a: MatView<i8>,
        b: MatView<i8>,
        d: MatView<i32>,
    ) -> Result<(Vec<Insn>, usize, usize)> {
        let dim = self.dim();
        let k = a.cols();
        anyhow::ensure!(a.rows() == dim, "A must have DIM rows");
        anyhow::ensure!(b.rows() == k, "B must have K rows");
        anyhow::ensure!(b.cols() == dim, "B must have DIM cols");
        anyhow::ensure!((d.rows(), d.cols()) == (dim, dim), "D must be DIM x DIM");

        // Stage operands in main memory: A as K columns, then B as K rows.
        // Views may be zero-padded windows, so stage element-wise through
        // `at` (padding reads as zero, like a padded scratchpad line).
        let a_mem = 0x1000usize;
        let b_mem = a_mem + k * dim;
        let mut row_buf = vec![0i8; dim];
        for kk in 0..k {
            for r in 0..dim {
                self.mem.bytes[a_mem + kk * dim + r] = a.at(r, kk);
            }
            b.copy_row_into(kk, &mut row_buf);
            self.mem.bytes[b_mem + kk * dim..b_mem + (kk + 1) * dim]
                .copy_from_slice(&row_buf);
        }
        let mut d_buf = vec![0i32; dim];
        for r in 0..dim {
            d.copy_row_into(r, &mut d_buf);
            self.accmem.write_row(r, &d_buf)?;
        }

        // Driver program the Rocket core executes (rs values via ADDIs —
        // the pointer arithmetic real driver code performs).
        let c_base = dim; // accmem landing row
        let prog = vec![
            Insn::Addi { rd: 1, rs1: 0, imm: a_mem as i64 },
            Insn::Addi { rd: 2, rs1: 0, imm: ((k as i64) << 32) | 0 },
            Insn::Rocc { funct: funct::MVIN, rs1: 1, rs2: 2 }, // A cols -> rows 0..k
            Insn::Fence,
            Insn::Addi { rd: 3, rs1: 0, imm: b_mem as i64 },
            Insn::Addi { rd: 4, rs1: 0, imm: ((k as i64) << 32) | k as i64 },
            Insn::Rocc { funct: funct::MVIN, rs1: 3, rs2: 4 }, // B rows -> rows k..2k
            Insn::Fence,
            Insn::Addi { rd: 5, rs1: 0, imm: k as i64 },
            Insn::Rocc { funct: funct::CONFIG, rs1: 5, rs2: 0 },
            Insn::Addi { rd: 6, rs1: 0, imm: 0 },
            Insn::Addi { rd: 7, rs1: 0, imm: c_base as i64 },
            Insn::Rocc { funct: funct::PRELOAD, rs1: 6, rs2: 7 },
            Insn::Addi { rd: 8, rs1: 0, imm: 0 },
            Insn::Addi { rd: 9, rs1: 0, imm: k as i64 },
            Insn::Rocc { funct: funct::COMPUTE, rs1: 8, rs2: 9 },
            Insn::Fence,
            Insn::Halt,
        ];
        Ok((prog, dim, c_base))
    }

    /// WS staging: A as M activation rows, the stationary DIM x DIM
    /// weight tile W after them, D as M psum-initialiser rows; C lands
    /// at accmem rows `m..2*m`.
    fn stage_ws(
        &mut self,
        a: MatView<i8>,
        w: MatView<i8>,
        d: MatView<i32>,
    ) -> Result<(Vec<Insn>, usize, usize)> {
        let dim = self.dim();
        let m = a.rows();
        anyhow::ensure!(a.cols() == dim, "A must have DIM cols");
        anyhow::ensure!((w.rows(), w.cols()) == (dim, dim), "W must be DIM x DIM");
        anyhow::ensure!(d.rows() == m, "D must have M rows");
        anyhow::ensure!(d.cols() == dim, "D must have DIM cols");
        anyhow::ensure!(
            m + dim <= self.spad.rows(),
            "WS activation panel of {m} rows does not fit the scratchpad"
        );
        anyhow::ensure!(
            2 * m <= self.accmem.rows(),
            "WS activation panel of {m} rows does not fit the accumulator"
        );

        // Stage A rows then W rows in main memory (element-wise through
        // `at` so zero-padded window views read as zero).
        let a_mem = 0x1000usize;
        let w_mem = a_mem + m * dim;
        let mut row_buf = vec![0i8; dim];
        for r in 0..m {
            a.copy_row_into(r, &mut row_buf);
            self.mem.bytes[a_mem + r * dim..a_mem + (r + 1) * dim].copy_from_slice(&row_buf);
        }
        for r in 0..dim {
            w.copy_row_into(r, &mut row_buf);
            self.mem.bytes[w_mem + r * dim..w_mem + (r + 1) * dim].copy_from_slice(&row_buf);
        }
        let mut d_buf = vec![0i32; dim];
        for r in 0..m {
            d.copy_row_into(r, &mut d_buf);
            self.accmem.write_row(r, &d_buf)?;
        }

        // Same program shape as OS — only the stream length (CONFIG = M)
        // and the operand layout differ: A rows at spad 0..m, W rows at
        // spad m..m+dim (COMPUTE rs2), D/C in accmem rows 0..m / m..2m.
        let c_base = m;
        let prog = vec![
            Insn::Addi { rd: 1, rs1: 0, imm: a_mem as i64 },
            Insn::Addi { rd: 2, rs1: 0, imm: ((m as i64) << 32) | 0 },
            Insn::Rocc { funct: funct::MVIN, rs1: 1, rs2: 2 }, // A rows -> rows 0..m
            Insn::Fence,
            Insn::Addi { rd: 3, rs1: 0, imm: w_mem as i64 },
            Insn::Addi { rd: 4, rs1: 0, imm: ((dim as i64) << 32) | m as i64 },
            Insn::Rocc { funct: funct::MVIN, rs1: 3, rs2: 4 }, // W rows -> rows m..m+dim
            Insn::Fence,
            Insn::Addi { rd: 5, rs1: 0, imm: m as i64 },
            Insn::Rocc { funct: funct::CONFIG, rs1: 5, rs2: 0 },
            Insn::Addi { rd: 6, rs1: 0, imm: 0 },
            Insn::Addi { rd: 7, rs1: 0, imm: c_base as i64 },
            Insn::Rocc { funct: funct::PRELOAD, rs1: 6, rs2: 7 },
            Insn::Addi { rd: 8, rs1: 0, imm: 0 },
            Insn::Addi { rd: 9, rs1: 0, imm: m as i64 },
            Insn::Rocc { funct: funct::COMPUTE, rs1: 8, rs2: 9 },
            Insn::Fence,
            Insn::Halt,
        ];
        Ok((prog, m, c_base))
    }

    /// Cold-stage a tile for cycle-resume: full reset, DMA staging and
    /// command decode up to the COMPUTE that opens the matmul window,
    /// then snapshot the controller at mesh-relative cycle 0. Returns
    /// the SoC cycles the prefix ticked (paid once per staged tile).
    fn begin_tile(&mut self, a: MatView<i8>, b: MatView<i8>, d: MatView<i32>) -> Result<u64> {
        self.reset();
        let (prog, _out_rows, _c_base) = self.stage(a, b, d)?;
        let mut guard = 0u64;
        while !self.ctrl.in_window() {
            self.tick(&prog)?;
            guard += 1;
            anyhow::ensure!(guard < 10_000_000, "SoC prefix did not open the matmul window");
        }
        self.resume.sched = self.ctrl.window_schedule();
        self.ctrl.save_state(&mut self.resume.state);
        self.resume.cycle = 0;
        Ok(self.cycles)
    }

    /// Cycle-resume counterpart of [`Soc::run_matmul_into`]: pay the
    /// command-decode/DMA prefix once per tile `key`, keep a golden
    /// controller snapshot, advance it monotonically to each trial's
    /// `resume_at` (the plan's first effect cycle), and replay only the
    /// faulty window suffix. Bit-identical to the full program because
    /// the window trajectory is prefix-independent: the mesh resets at
    /// COMPUTE decode, the scratchpad/accmem operand rows are never
    /// mutated mid-window, and fault cycles are mesh-relative. Returns
    /// the SoC cycles actually ticked (prefix when staging + golden
    /// advance + replay) — the same clock `run_matmul_into` counts, so
    /// `rtl_cycles_stepped` is comparable across tile engines.
    ///
    /// Trials of a batch should arrive sorted by `resume_at` (the
    /// campaign sorts site batches); an earlier cycle re-stages the
    /// tile from scratch rather than failing.
    #[allow(clippy::too_many_arguments)]
    pub fn run_matmul_resumed(
        &mut self,
        a: MatView<i8>,
        b: MatView<i8>,
        d: MatView<i32>,
        plan: &FaultPlan,
        key: (usize, usize),
        resume_at: u64,
        out: &mut Mat<i32>,
    ) -> Result<u64> {
        let mut stepped = 0u64;
        if self.resume.key != Some(key) {
            stepped += self.begin_tile(a, b, d)?;
            self.resume.key = Some(key);
        }
        let sched = self.resume.sched.expect("resumable tile without a schedule");
        let total = sched.total_cycles();
        let target = resume_at.min(total);
        if target < self.resume.cycle {
            // rewind: an unsorted batch — re-stage from scratch
            stepped += self.begin_tile(a, b, d)?;
        }
        self.ctrl.restore_state(&self.resume.state);
        if target > self.resume.cycle {
            // advance the shared golden snapshot (no fault armed)
            self.ctrl.disarm();
            stepped += self.step_ctrl_window_to(target)?;
            self.ctrl.save_state(&mut self.resume.state);
            self.resume.cycle = target;
        }
        // faulty replay of the suffix (a plan entirely at/after the
        // window end degenerates to reading the golden result, exactly
        // as the full program would)
        self.ctrl.begin_replay(plan);
        stepped += self.step_ctrl_window_to(total)?;
        let out_rows = sched.out_rows();
        out.reset(out_rows, self.dim());
        for r in 0..out_rows {
            out.row_mut(r)
                .copy_from_slice(self.accmem.read_row(sched.c_base() + r)?);
        }
        Ok(stepped)
    }

    /// Step the in-flight matmul window up to (not including) mesh
    /// cycle `to`, counting each edge on the SoC clock. Per-edge
    /// discipline matches [`Soc::tick`]: the scratchpad releases its
    /// ports before the controller's operand reads — so port conflicts
    /// and stalls account identically under both tile engines.
    fn step_ctrl_window_to(&mut self, to: u64) -> Result<u64> {
        let mut stepped = 0u64;
        while self.ctrl.in_window() && self.ctrl.mesh_cycle() < to {
            self.cycles += 1;
            self.spad.tick();
            self.ctrl.step_window(&mut self.spad, &mut self.accmem)?;
            stepped += 1;
        }
        Ok(stepped)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::driver::gold_matmul;
    use crate::mesh::inject::Fault;
    use crate::util::Rng;

    #[test]
    fn soc_matmul_matches_gold() {
        let mut rng = Rng::new(77);
        for &(dim, k) in &[(2usize, 2usize), (4, 4), (4, 7)] {
            let mut soc = Soc::new(dim);
            let a = rng.mat_i8(dim, k);
            let b = rng.mat_i8(k, dim);
            let d = rng.mat_i32(dim, dim, 1000);
            let c = soc
                .run_matmul(a.view(), b.view(), d.view(), &FaultPlan::empty())
                .unwrap();
            assert_eq!(c, gold_matmul(a.view(), b.view(), d.view()), "dim={dim} k={k}");
        }
    }

    #[test]
    fn soc_cycle_cost_exceeds_mesh_only() {
        // The point of Table V: the same matmul costs far more cycles
        // (and far more work per cycle) on the full SoC.
        let dim = 4;
        let mut soc = Soc::new(dim);
        let mut rng = Rng::new(78);
        let a = rng.mat_i8(dim, dim);
        let b = rng.mat_i8(dim, dim);
        let d = rng.mat_i32(dim, dim, 10);
        soc.run_matmul(a.view(), b.view(), d.view(), &FaultPlan::empty())
            .unwrap();
        let mesh_only = crate::mesh::driver::os_matmul_cycles(dim, dim);
        assert!(
            soc.cycles > 2 * mesh_only,
            "soc {} vs mesh {}",
            soc.cycles,
            mesh_only
        );
    }

    #[test]
    fn soc_state_dominated_by_uncore_at_small_dim() {
        let soc = Soc::new(4);
        let mesh_state = soc.ctrl.mesh.state_elements();
        assert!(soc.state_elements() > 10 * mesh_state);
    }

    #[test]
    fn reset_soc_matches_fresh_soc_bit_exactly() {
        use crate::mesh::signal::SignalKind;
        // Reusing one SoC via reset() must reproduce the fresh-SoC
        // results bit-exactly, golden and faulty alike — the invariant
        // the campaign's persistent-SoC trial batches rely on.
        let dim = 4;
        let mut rng = Rng::new(80);
        let a1 = rng.mat_i8(dim, 6);
        let b1 = rng.mat_i8(6, dim);
        let d1 = rng.mat_i32(dim, dim, 50);
        let a2 = rng.mat_i8(dim, dim);
        let b2 = rng.mat_i8(dim, dim);
        let d2 = rng.mat_i32(dim, dim, 50);
        let plan = FaultPlan::single(Fault::new(
            1,
            2,
            SignalKind::Acc,
            12,
            (2 * dim - 1) as u64 + 2,
        ));

        let fresh1 = Soc::new(dim)
            .run_matmul(a1.view(), b1.view(), d1.view(), &plan)
            .unwrap();
        let fresh2 = Soc::new(dim)
            .run_matmul(a2.view(), b2.view(), d2.view(), &FaultPlan::empty())
            .unwrap();

        let mut soc = Soc::new(dim);
        let r1 = soc
            .run_matmul(a1.view(), b1.view(), d1.view(), &plan)
            .unwrap();
        let cycles_first = soc.cycles;
        soc.reset();
        assert_eq!(soc.cycles, 0);
        let r2 = soc
            .run_matmul(a2.view(), b2.view(), d2.view(), &FaultPlan::empty())
            .unwrap();
        assert_eq!(r1, fresh1);
        assert_eq!(r2, fresh2);
        // reset also restores the timing state (cold caches), not just
        // the architectural state
        soc.reset();
        let _ = soc
            .run_matmul(a1.view(), b1.view(), d1.view(), &plan)
            .unwrap();
        assert_eq!(soc.cycles, cycles_first);
    }

    #[test]
    fn soc_fault_injection_corrupts_output() {
        use crate::mesh::signal::SignalKind;
        let dim = 4;
        let mut rng = Rng::new(79);
        let a = rng.mat_i8(dim, dim);
        let b = rng.mat_i8(dim, dim);
        let d = rng.mat_i32(dim, dim, 10);
        let golden = Soc::new(dim)
            .run_matmul(a.view(), b.view(), d.view(), &FaultPlan::empty())
            .unwrap();
        let cyc = (2 * dim - 1) as u64 + 3; // mid-compute
        let f = Fault::new(0, 0, SignalKind::Acc, 20, cyc);
        let faulty = Soc::new(dim)
            .run_matmul(a.view(), b.view(), d.view(), &FaultPlan::single(f))
            .unwrap();
        assert_ne!(golden, faulty);
    }

    #[test]
    fn soc_ws_matmul_matches_gold() {
        use crate::config::Dataflow;
        let mut rng = Rng::new(81);
        for &(dim, m) in &[(2usize, 2usize), (4, 4), (4, 7), (8, 11)] {
            let mut soc = Soc::with_dataflow(dim, Dataflow::WeightStationary);
            let a = rng.mat_i8(m, dim);
            let w = rng.mat_i8(dim, dim);
            let d = rng.mat_i32(m, dim, 1000);
            let c = soc
                .run_matmul(a.view(), w.view(), d.view(), &FaultPlan::empty())
                .unwrap();
            assert_eq!(c, gold_matmul(a.view(), w.view(), d.view()), "dim={dim} m={m}");
        }
    }

    #[test]
    fn soc_resumed_matches_full_run_and_steps_fewer_cycles() {
        use crate::config::Dataflow;
        use crate::mesh::signal::SignalKind;
        // The SoC-level cycle-resume contract: per trial, the resumed
        // path is bit-identical to the full driver program, and a batch
        // of same-tile trials steps strictly fewer SoC cycles (prefix
        // and fence-drain postfix paid once, golden window prefixes
        // shared), both dataflows.
        let dim = 4;
        for dataflow in [Dataflow::OutputStationary, Dataflow::WeightStationary] {
            let mut rng = Rng::new(83);
            let (a, b, d) = match dataflow {
                Dataflow::OutputStationary => (
                    rng.mat_i8(dim, 6),
                    rng.mat_i8(6, dim),
                    rng.mat_i32(dim, dim, 100),
                ),
                Dataflow::WeightStationary => (
                    rng.mat_i8(6, dim),
                    rng.mat_i8(dim, dim),
                    rng.mat_i32(6, dim, 100),
                ),
            };
            // trials sorted by first effect cycle, as the campaign sorts
            let plans: Vec<FaultPlan> = [2u64, 9, 14]
                .iter()
                .map(|&cyc| FaultPlan::single(Fault::new(1, 2, SignalKind::Acc, 12, cyc)))
                .collect();

            let mut full = Soc::with_dataflow(dim, dataflow);
            let mut c_full = Vec::new();
            let mut full_cycles = 0u64;
            for plan in &plans {
                full.reset();
                let mut c = Mat::default();
                full_cycles += full
                    .run_matmul_into(a.view(), b.view(), d.view(), plan, &mut c)
                    .unwrap();
                c_full.push(c);
            }

            let mut soc = Soc::with_dataflow(dim, dataflow);
            let mut resumed_cycles = 0u64;
            for (plan, oracle) in plans.iter().zip(&c_full) {
                let mut c = Mat::default();
                resumed_cycles += soc
                    .run_matmul_resumed(
                        a.view(),
                        b.view(),
                        d.view(),
                        plan,
                        (0, 0),
                        plan.first_cycle(),
                        &mut c,
                    )
                    .unwrap();
                assert_eq!(&c, oracle, "{dataflow:?}: resumed trial must be bit-identical");
            }
            assert!(
                resumed_cycles < full_cycles,
                "{dataflow:?}: resumed batch must step fewer SoC cycles: {resumed_cycles} vs {full_cycles}"
            );

            // a fresh key re-stages and still matches (cursor reuse is
            // keyed, never silently carried across tiles)
            let mut c = Mat::default();
            soc.run_matmul_resumed(
                a.view(),
                b.view(),
                d.view(),
                &plans[0],
                (1, 0),
                plans[0].first_cycle(),
                &mut c,
            )
            .unwrap();
            assert_eq!(&c, &c_full[0], "{dataflow:?}: re-staged tile must be bit-identical");
        }
    }
}
