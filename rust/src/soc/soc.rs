//! The full-SoC simulation target (paper Fig. 3, Step 1): Rocket-like
//! core + L1 caches + TileLink-style interconnect + DMA + scratchpad +
//! Gemmini controller + the mesh — everything evaluated every cycle, the
//! way Verilator evaluates the whole elaborated design.
//!
//! This is the *baseline* ENFOR-SA's mesh isolation is measured against
//! (Table V): functionally it computes the same matmuls as the mesh-only
//! wrapper, but each simulated cycle pays for the entire SoC.

use super::controller::{funct, Controller};
use super::core::{Core, Insn};
use super::detail::UncoreDetail;
use super::cache::Cache;
use super::dma::{Dma, MainMemory};
use super::scratchpad::{AccMem, Scratchpad};
use crate::mat::{Mat, MatView};
use crate::mesh::inject::FaultPlan;
use anyhow::Result;

/// TileLink-style crossbar: per-cycle arbitration state between the
/// core, DMA and peripheral ports (round-robin grant counters + request
/// queues the verilated uncore evaluates every cycle).
pub struct Interconnect {
    grant_rr: u32,
    inflight: [u32; 8],
    pub beats: u64,
}

impl Default for Interconnect {
    fn default() -> Self {
        Self::new()
    }
}

impl Interconnect {
    pub fn new() -> Self {
        Interconnect {
            grant_rr: 0,
            inflight: [0; 8],
            beats: 0,
        }
    }

    pub fn tick(&mut self) {
        self.grant_rr = (self.grant_rr + 1) % 8;
        for q in self.inflight.iter_mut() {
            *q = q.saturating_sub(1);
        }
        self.beats += 1;
    }
}

/// The complete SoC.
pub struct Soc {
    pub core: Core,
    pub icache: Cache,
    pub dcache: Cache,
    pub xbar: Interconnect,
    pub spad: Scratchpad,
    pub accmem: AccMem,
    pub dma: Dma,
    pub mem: MainMemory,
    pub ctrl: Controller,
    pub detail: UncoreDetail,
    pub cycles: u64,
    icache_stall: u32,
}

impl Soc {
    /// Build a SoC around a DIM x DIM output-stationary mesh with
    /// Chipyard-like defaults (16 KiB L1s, 256 KiB scratchpad, 64 KiB
    /// accumulator).
    pub fn new(dim: usize) -> Self {
        Self::with_dataflow(dim, crate::config::Dataflow::OutputStationary)
    }

    /// [`Soc::new`] with the dataflow taken from `MeshConfig`. The SoC
    /// backend is OS-only for now (the controller FSM implements the OS
    /// schedule); campaigns reject WS + FullSoc with a config error
    /// before construction, and the controller asserts it here too —
    /// never a silent override to OS.
    pub fn with_dataflow(dim: usize, dataflow: crate::config::Dataflow) -> Self {
        let spad_rows = (256 * 1024 / dim).max(4 * dim * dim);
        Soc {
            core: Core::new(),
            icache: Cache::new(16 * 1024, 4, 64, 20),
            dcache: Cache::new(16 * 1024, 4, 64, 20),
            xbar: Interconnect::new(),
            spad: Scratchpad::new(4, spad_rows / 4, dim),
            accmem: AccMem::new((64 * 1024 / (4 * dim)).max(4 * dim), dim),
            dma: Dma::new(),
            mem: MainMemory::new(1 << 22, 4),
            ctrl: Controller::new(dim, dataflow),
            detail: UncoreDetail::new(dim),
            cycles: 0,
            icache_stall: 0,
        }
    }

    pub fn dim(&self) -> usize {
        self.ctrl.dim()
    }

    /// The mesh dataflow this SoC executes (OS — see [`Soc::with_dataflow`]).
    pub fn dataflow(&self) -> crate::config::Dataflow {
        use crate::mesh::MeshSim;
        self.ctrl.mesh.dataflow()
    }

    /// Return the SoC to power-on state **without reallocating** its
    /// large memories (4 MiB main memory, 256 KiB scratchpad, cache tag
    /// arrays). Campaigns reuse one SoC across all `FullSoc` trials via
    /// this reset instead of constructing a fresh `Soc::new(dim)` per
    /// trial; `run_matmul` results after a reset are bit-identical to a
    /// freshly built SoC (fault cycles are mesh-relative).
    pub fn reset(&mut self) {
        let dim = self.dim();
        self.core = Core::new();
        self.icache.reset();
        self.dcache.reset();
        self.xbar = Interconnect::new();
        self.spad.reset();
        self.accmem.reset();
        self.dma.reset();
        self.mem.reset();
        self.ctrl.reset();
        self.detail = UncoreDetail::new(dim);
        self.cycles = 0;
        self.icache_stall = 0;
    }

    /// One SoC clock edge: every block evaluates, like the verilated SoC.
    pub fn tick(&mut self, prog: &[Insn]) -> Result<()> {
        self.cycles += 1;
        // uncore always evaluates (predictors, TLBs, FPU, TileLink,
        // Gemmini's non-mesh pipelines — the cost mesh isolation removes)
        self.detail
            .tick(self.cycles, self.core.pc as u64 * 4, self.spad.rows());
        self.xbar.tick();
        self.icache.tick(self.cycles);
        self.dcache.tick(self.cycles);
        self.spad.tick();
        self.dma.tick(&mut self.mem, &mut self.spad)?;
        // core front-end (with icache stalls)
        if self.icache_stall > 0 {
            self.icache_stall -= 1;
        } else if !self.core.halted() {
            let pc = self.core.pc as u64 * 4;
            self.icache_stall = self.icache.access(pc);
            let rob_busy = self.ctrl.busy() || self.dma.busy();
            if let Some(cmd) = self.core.step(prog, rob_busy) {
                self.ctrl.enqueue(cmd);
            }
        }
        // accelerator complex
        self.ctrl
            .tick(&mut self.spad, &mut self.accmem, &mut self.dma, &mut self.mem)?;
        Ok(())
    }

    /// Total architectural state evaluated per cycle (DESIGN.md D2):
    /// the quantity that explains why mesh-only simulation wins, and why
    /// the win shrinks as DIM grows (Table V).
    pub fn state_elements(&self) -> usize {
        self.core.state_elements()
            + self.detail.state_elements()
            + self.icache.state_elements()
            + self.dcache.state_elements()
            + self.spad.state_elements()
            + 16 // xbar
            + self.ctrl.mesh.state_elements()
    }

    /// Run one `C = A . B + D` matmul end-to-end *through the core*:
    /// the driver program stages operands with MVIN commands, issues
    /// PRELOAD + COMPUTE, fences, and halts. Returns C.
    ///
    /// `plan`: fault plan at mesh-relative cycles of the compute (same
    /// addressing as the mesh-only wrapper; empty plan = golden run).
    pub fn run_matmul(
        &mut self,
        a: MatView<i8>,
        b: MatView<i8>,
        d: MatView<i32>,
        plan: &FaultPlan,
    ) -> Result<Mat<i32>> {
        let mut c = Mat::default();
        self.run_matmul_into(a, b, d, plan, &mut c)?;
        Ok(c)
    }

    /// [`Soc::run_matmul`] into a caller-provided buffer (reshaped and
    /// zeroed in place) — the allocation-free seam the site-major trial
    /// batches drive. Returns the SoC cycles this run ticked.
    ///
    /// The SoC always executes the FULL program: cycle-resume does not
    /// apply here because the matmul schedule is owned by the
    /// controller's execute FSM (command decode, DMA staging, drain),
    /// not by a wrapper that could index it from an arbitrary cycle —
    /// `TileBackend::supports_cycle_resume` gates on this (ROADMAP
    /// "Cycle-resume" contract).
    pub fn run_matmul_into(
        &mut self,
        a: MatView<i8>,
        b: MatView<i8>,
        d: MatView<i32>,
        plan: &FaultPlan,
        out: &mut Mat<i32>,
    ) -> Result<u64> {
        let cycles_before = self.cycles;
        let dim = self.dim();
        let k = a.cols();
        anyhow::ensure!(a.rows() == dim, "A must have DIM rows");
        anyhow::ensure!(b.rows() == k, "B must have K rows");
        anyhow::ensure!(b.cols() == dim, "B must have DIM cols");
        anyhow::ensure!((d.rows(), d.cols()) == (dim, dim), "D must be DIM x DIM");
        // the driver program runs from reset on every matmul
        self.core = Core::new();

        // Stage operands in main memory: A as K columns, then B as K rows.
        // Views may be zero-padded windows, so stage element-wise through
        // `at` (padding reads as zero, like a padded scratchpad line).
        let a_mem = 0x1000usize;
        let b_mem = a_mem + k * dim;
        let mut row_buf = vec![0i8; dim];
        for kk in 0..k {
            for r in 0..dim {
                self.mem.bytes[a_mem + kk * dim + r] = a.at(r, kk);
            }
            b.copy_row_into(kk, &mut row_buf);
            self.mem.bytes[b_mem + kk * dim..b_mem + (kk + 1) * dim]
                .copy_from_slice(&row_buf);
        }
        let mut d_buf = vec![0i32; dim];
        for r in 0..dim {
            d.copy_row_into(r, &mut d_buf);
            self.accmem.write_row(r, &d_buf)?;
        }
        if !plan.is_empty() {
            self.ctrl.arm_plan(plan);
        }

        // Driver program the Rocket core executes (rs values via ADDIs —
        // the pointer arithmetic real driver code performs).
        let c_base = dim as u64; // accmem landing row
        let prog = vec![
            Insn::Addi { rd: 1, rs1: 0, imm: a_mem as i64 },
            Insn::Addi { rd: 2, rs1: 0, imm: ((k as i64) << 32) | 0 },
            Insn::Rocc { funct: funct::MVIN, rs1: 1, rs2: 2 }, // A cols -> rows 0..k
            Insn::Fence,
            Insn::Addi { rd: 3, rs1: 0, imm: b_mem as i64 },
            Insn::Addi { rd: 4, rs1: 0, imm: ((k as i64) << 32) | k as i64 },
            Insn::Rocc { funct: funct::MVIN, rs1: 3, rs2: 4 }, // B rows -> rows k..2k
            Insn::Fence,
            Insn::Addi { rd: 5, rs1: 0, imm: k as i64 },
            Insn::Rocc { funct: funct::CONFIG, rs1: 5, rs2: 0 },
            Insn::Addi { rd: 6, rs1: 0, imm: 0 },
            Insn::Addi { rd: 7, rs1: 0, imm: c_base as i64 },
            Insn::Rocc { funct: funct::PRELOAD, rs1: 6, rs2: 7 },
            Insn::Addi { rd: 8, rs1: 0, imm: 0 },
            Insn::Addi { rd: 9, rs1: 0, imm: k as i64 },
            Insn::Rocc { funct: funct::COMPUTE, rs1: 8, rs2: 9 },
            Insn::Fence,
            Insn::Halt,
        ];

        let mut guard = 0u64;
        while !self.core.halted() || self.ctrl.busy() || self.dma.busy() {
            self.tick(&prog)?;
            guard += 1;
            anyhow::ensure!(guard < 10_000_000, "SoC run did not terminate");
        }
        out.reset(dim, dim);
        for r in 0..dim {
            out.row_mut(r).copy_from_slice(self.accmem.read_row(dim + r)?);
        }
        Ok(self.cycles - cycles_before)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::driver::gold_matmul;
    use crate::mesh::inject::Fault;
    use crate::util::Rng;

    #[test]
    fn soc_matmul_matches_gold() {
        let mut rng = Rng::new(77);
        for &(dim, k) in &[(2usize, 2usize), (4, 4), (4, 7)] {
            let mut soc = Soc::new(dim);
            let a = rng.mat_i8(dim, k);
            let b = rng.mat_i8(k, dim);
            let d = rng.mat_i32(dim, dim, 1000);
            let c = soc
                .run_matmul(a.view(), b.view(), d.view(), &FaultPlan::empty())
                .unwrap();
            assert_eq!(c, gold_matmul(a.view(), b.view(), d.view()), "dim={dim} k={k}");
        }
    }

    #[test]
    fn soc_cycle_cost_exceeds_mesh_only() {
        // The point of Table V: the same matmul costs far more cycles
        // (and far more work per cycle) on the full SoC.
        let dim = 4;
        let mut soc = Soc::new(dim);
        let mut rng = Rng::new(78);
        let a = rng.mat_i8(dim, dim);
        let b = rng.mat_i8(dim, dim);
        let d = rng.mat_i32(dim, dim, 10);
        soc.run_matmul(a.view(), b.view(), d.view(), &FaultPlan::empty())
            .unwrap();
        let mesh_only = crate::mesh::driver::os_matmul_cycles(dim, dim);
        assert!(
            soc.cycles > 2 * mesh_only,
            "soc {} vs mesh {}",
            soc.cycles,
            mesh_only
        );
    }

    #[test]
    fn soc_state_dominated_by_uncore_at_small_dim() {
        let soc = Soc::new(4);
        let mesh_state = soc.ctrl.mesh.state_elements();
        assert!(soc.state_elements() > 10 * mesh_state);
    }

    #[test]
    fn reset_soc_matches_fresh_soc_bit_exactly() {
        use crate::mesh::signal::SignalKind;
        // Reusing one SoC via reset() must reproduce the fresh-SoC
        // results bit-exactly, golden and faulty alike — the invariant
        // the campaign's persistent-SoC trial batches rely on.
        let dim = 4;
        let mut rng = Rng::new(80);
        let a1 = rng.mat_i8(dim, 6);
        let b1 = rng.mat_i8(6, dim);
        let d1 = rng.mat_i32(dim, dim, 50);
        let a2 = rng.mat_i8(dim, dim);
        let b2 = rng.mat_i8(dim, dim);
        let d2 = rng.mat_i32(dim, dim, 50);
        let plan = FaultPlan::single(Fault::new(
            1,
            2,
            SignalKind::Acc,
            12,
            (2 * dim - 1) as u64 + 2,
        ));

        let fresh1 = Soc::new(dim)
            .run_matmul(a1.view(), b1.view(), d1.view(), &plan)
            .unwrap();
        let fresh2 = Soc::new(dim)
            .run_matmul(a2.view(), b2.view(), d2.view(), &FaultPlan::empty())
            .unwrap();

        let mut soc = Soc::new(dim);
        let r1 = soc
            .run_matmul(a1.view(), b1.view(), d1.view(), &plan)
            .unwrap();
        let cycles_first = soc.cycles;
        soc.reset();
        assert_eq!(soc.cycles, 0);
        let r2 = soc
            .run_matmul(a2.view(), b2.view(), d2.view(), &FaultPlan::empty())
            .unwrap();
        assert_eq!(r1, fresh1);
        assert_eq!(r2, fresh2);
        // reset also restores the timing state (cold caches), not just
        // the architectural state
        soc.reset();
        let _ = soc
            .run_matmul(a1.view(), b1.view(), d1.view(), &plan)
            .unwrap();
        assert_eq!(soc.cycles, cycles_first);
    }

    #[test]
    fn soc_fault_injection_corrupts_output() {
        use crate::mesh::signal::SignalKind;
        let dim = 4;
        let mut rng = Rng::new(79);
        let a = rng.mat_i8(dim, dim);
        let b = rng.mat_i8(dim, dim);
        let d = rng.mat_i32(dim, dim, 10);
        let golden = Soc::new(dim)
            .run_matmul(a.view(), b.view(), d.view(), &FaultPlan::empty())
            .unwrap();
        let cyc = (2 * dim - 1) as u64 + 3; // mid-compute
        let f = Fault::new(0, 0, SignalKind::Acc, 20, cyc);
        let faulty = Soc::new(dim)
            .run_matmul(a.view(), b.view(), d.view(), &FaultPlan::single(f))
            .unwrap();
        assert_ne!(golden, faulty);
    }
}
