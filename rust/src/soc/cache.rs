//! L1 instruction / data caches of the full-SoC baseline.
//!
//! Set-associative tag arrays with pseudo-LRU replacement. The verilated
//! SoC evaluates the tag comparators, replacement state and MSHR logic on
//! every cycle; this model performs the equivalent work on every access
//! and sweeps the replacement state every cycle (the cost the mesh-only
//! isolation strips away).

/// A set-associative cache model (tags + metadata only; data hits are
/// byte-accurate through the backing store in `SocMemory`).
pub struct Cache {
    sets: usize,
    ways: usize,
    line_bytes: usize,
    /// tag per (set, way); u64::MAX = invalid.
    tags: Vec<u64>,
    /// pseudo-LRU: per-set age counters.
    age: Vec<u8>,
    pub hits: u64,
    pub misses: u64,
    /// Miss penalty in cycles (refill from the memory model).
    pub miss_penalty: u32,
}

impl Cache {
    pub fn new(size_bytes: usize, ways: usize, line_bytes: usize, miss_penalty: u32) -> Self {
        let sets = (size_bytes / line_bytes / ways).max(1);
        Cache {
            sets,
            ways,
            line_bytes,
            tags: vec![u64::MAX; sets * ways],
            age: vec![0; sets * ways],
            hits: 0,
            misses: 0,
            miss_penalty,
        }
    }

    /// Invalidate every line and clear statistics (power-on state),
    /// keeping the tag/age allocations.
    pub fn reset(&mut self) {
        self.tags.fill(u64::MAX);
        self.age.fill(0);
        self.hits = 0;
        self.misses = 0;
    }

    /// Look up `addr`; returns the stall cycles this access incurs.
    pub fn access(&mut self, addr: u64) -> u32 {
        let line = addr / self.line_bytes as u64;
        let set = (line as usize) % self.sets;
        let tag = line / self.sets as u64;
        let base = set * self.ways;
        // tag comparators (all ways in parallel in RTL)
        let mut hit_way = None;
        for w in 0..self.ways {
            if self.tags[base + w] == tag {
                hit_way = Some(w);
            }
        }
        match hit_way {
            Some(w) => {
                self.hits += 1;
                // LRU update: aging of all ways in the set
                for ww in 0..self.ways {
                    self.age[base + ww] = self.age[base + ww].saturating_add(1);
                }
                self.age[base + w] = 0;
                0
            }
            None => {
                self.misses += 1;
                // victim: first invalid way, else the oldest
                let mut victim = 0;
                for w in 0..self.ways {
                    if self.tags[base + w] == u64::MAX {
                        victim = w;
                        break;
                    }
                    if self.age[base + w] > self.age[base + victim] {
                        victim = w;
                    }
                }
                for ww in 0..self.ways {
                    self.age[base + ww] = self.age[base + ww].saturating_add(1);
                }
                self.tags[base + victim] = tag;
                self.age[base + victim] = 0;
                self.miss_penalty
            }
        }
    }

    /// Per-cycle idle evaluation: the verilated design clocks the
    /// replacement / MSHR logic whether or not an access occurs. We touch
    /// one set's metadata per cycle (round-robin), mirroring how
    /// Verilator evaluates the (much wider) always-blocks.
    pub fn tick(&mut self, cycle: u64) {
        let set = (cycle as usize) % self.sets;
        let base = set * self.ways;
        for w in 0..self.ways {
            // benign saturating age maintenance
            self.age[base + w] = self.age[base + w].min(200);
        }
    }

    pub fn state_elements(&self) -> usize {
        self.tags.len() * 2 // tag + age per way
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_access_misses_then_hits() {
        let mut c = Cache::new(1024, 2, 64, 20);
        assert_eq!(c.access(0x40), 20);
        assert_eq!(c.access(0x40), 0);
        assert_eq!(c.access(0x44), 0, "same line");
        assert_eq!(c.hits, 2);
        assert_eq!(c.misses, 1);
    }

    #[test]
    fn conflict_evicts_lru() {
        // 2-way, 8 sets of 64B lines: addresses 0, 8*64, 16*64 map to set 0.
        let mut c = Cache::new(1024, 2, 64, 20);
        let s = 8 * 64;
        assert!(c.access(0) > 0);
        assert!(c.access(s as u64) > 0);
        assert_eq!(c.access(0), 0, "both ways resident");
        assert!(c.access(2 * s as u64) > 0, "fills a way, evicting LRU");
        // LRU victim was the less-recently used line (s), so 0 still hits:
        assert_eq!(c.access(0), 0);
        assert!(c.access(s as u64) > 0, "evicted line misses");
    }

    #[test]
    fn tick_is_stable() {
        let mut c = Cache::new(4096, 4, 64, 10);
        for t in 0..10_000 {
            c.tick(t);
        }
        assert_eq!(c.misses, 0);
    }

    #[test]
    fn state_scales_with_size() {
        let small = Cache::new(1024, 2, 64, 1);
        let big = Cache::new(4096, 2, 64, 1);
        assert_eq!(big.state_elements(), 4 * small.state_elements());
    }
}
