//! Fault-injection campaign engine: sampling, the cross-layer trial
//! runner, per-PE vulnerability maps and campaign orchestration.

#[allow(clippy::module_inception)]
pub mod campaign;
pub mod fault;
pub mod maps;
pub mod runner;

pub use campaign::{
    campaign_sites, derived_input_seed, plan_one, run_campaign, run_input, signal_kinds,
    tmr_columns, validate_dataflow_support, CampaignResult, InputPlan, MitVerdict,
    PlannedTrial, SiteBatch, TrialExecutor, TrialOutcome,
};
pub use fault::{sample_fault, sample_mesh_fault, sample_trial, TrialFault};
pub use maps::{
    control_avf_map, exposure_map, exposure_map_for, weight_exposure_map,
    ws_weight_exposure_map, PeMap,
};
pub use runner::{CrossLayerRunner, TileBackend};
