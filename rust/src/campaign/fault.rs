//! Fault sampling for statistical injection campaigns.
//!
//! A *trial fault* picks (uniformly over the bit-weighted fault space):
//! which GEMM tile of which layer is offloaded to RTL, which PE signal
//! bit inside the mesh flips, and at which cycle of the offloaded
//! matmul. This mirrors the paper's setup: one transient fault per
//! inference, injected into the mesh while it computes one tile.

use crate::dnn::GemmSiteId;
use crate::mesh::driver::os_matmul_cycles;
use crate::mesh::{Fault, SignalKind};
use crate::util::Rng;

/// A fully-specified cross-layer fault trial.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TrialFault {
    pub site: GemmSiteId,
    /// Output-tile coordinates (units of DIM).
    pub tile_i: usize,
    pub tile_j: usize,
    /// The mesh-level transient fault (cycle relative to the tile matmul).
    pub fault: Fault,
}

/// Sample a signal kind proportionally to its bit width, optionally
/// restricted to a subset (`kinds`); then a bit within it.
pub fn sample_signal(rng: &mut Rng, kinds: &[SignalKind]) -> (SignalKind, u8) {
    let pool: &[SignalKind] = if kinds.is_empty() {
        &SignalKind::ALL
    } else {
        kinds
    };
    let total: u64 = pool.iter().map(|k| k.width() as u64).sum();
    let mut pick = rng.below(total);
    for &k in pool {
        let w = k.width() as u64;
        if pick < w {
            return (k, pick as u8);
        }
        pick -= w;
    }
    unreachable!("bit-weighted sampling exhausted the pool");
}

/// Sample a mesh fault for a tile matmul with inner dimension `k_inner`.
pub fn sample_mesh_fault(
    dim: usize,
    k_inner: usize,
    rng: &mut Rng,
    kinds: &[SignalKind],
) -> Fault {
    let (kind, bit) = sample_signal(rng, kinds);
    let row = rng.usize_below(dim);
    let col = rng.usize_below(dim);
    let cycle = rng.below(os_matmul_cycles(dim, k_inner));
    Fault::new(row, col, kind, bit, cycle)
}

/// Sample a complete trial for one GEMM site of shape (m, k, n).
#[allow(clippy::too_many_arguments)]
pub fn sample_trial(
    site: GemmSiteId,
    m: usize,
    k: usize,
    n: usize,
    dim: usize,
    rng: &mut Rng,
    kinds: &[SignalKind],
) -> TrialFault {
    let tiles_i = m.div_ceil(dim);
    let tiles_j = n.div_ceil(dim);
    TrialFault {
        site,
        tile_i: rng.usize_below(tiles_i),
        tile_j: rng.usize_below(tiles_j),
        fault: sample_mesh_fault(dim, k, rng, kinds),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn signal_sampling_is_bit_weighted() {
        let mut rng = Rng::new(61);
        let mut acc32 = 0usize;
        let mut ctrl = 0usize;
        let n = 20_000;
        for _ in 0..n {
            let (k, bit) = sample_signal(&mut rng, &[]);
            assert!(bit < k.width());
            match k {
                SignalKind::Acc | SignalKind::DReg => acc32 += 1,
                SignalKind::Propag | SignalKind::Valid => ctrl += 1,
                _ => {}
            }
        }
        // 64 of 82 bits are 32-bit storage; 2 of 82 are control.
        let frac32 = acc32 as f64 / n as f64;
        let fracc = ctrl as f64 / n as f64;
        assert!((frac32 - 64.0 / 82.0).abs() < 0.02, "{frac32}");
        assert!((fracc - 2.0 / 82.0).abs() < 0.01, "{fracc}");
    }

    #[test]
    fn kind_filter_restricts() {
        let mut rng = Rng::new(62);
        for _ in 0..200 {
            let (k, _) = sample_signal(&mut rng, &[SignalKind::Propag, SignalKind::Valid]);
            assert!(matches!(k, SignalKind::Propag | SignalKind::Valid));
        }
    }

    #[test]
    fn trial_bounds_respected() {
        let mut rng = Rng::new(63);
        let site = GemmSiteId { layer: 1, ordinal: 0 };
        for _ in 0..500 {
            let t = sample_trial(site, 100, 27, 16, 8, &mut rng, &[]);
            assert!(t.tile_i < 13);
            assert!(t.tile_j < 2);
            assert!(t.fault.addr.row < 8 && t.fault.addr.col < 8);
            assert!(t.fault.cycle < os_matmul_cycles(8, 27));
        }
    }

    #[test]
    fn sampling_is_deterministic() {
        let site = GemmSiteId { layer: 0, ordinal: 0 };
        let mut r1 = Rng::new(64);
        let mut r2 = Rng::new(64);
        for _ in 0..50 {
            assert_eq!(
                sample_trial(site, 64, 64, 64, 8, &mut r1, &[]),
                sample_trial(site, 64, 64, 64, 8, &mut r2, &[])
            );
        }
    }
}
