//! Fault sampling for statistical injection campaigns.
//!
//! A *trial fault* picks (uniformly over the bit-weighted fault space):
//! which GEMM tile of which layer is offloaded to RTL, and a
//! [`FaultPlan`] of mesh-level faults to inject while it computes —
//! sampled by the campaign's [`Scenario`]. The default `seu` scenario
//! mirrors the paper's setup (one transient fault per inference) and
//! consumes the RNG stream in exactly the legacy order
//! (`tile_i`, `tile_j`, signal+bit, row, col, cycle), so fixed-seed
//! `--scenario seu` campaigns are bit-identical to the pre-redesign
//! single-fault path. Every other scenario derives its plan from the
//! same base draw (plus, for `double-seu`, one extra independent draw),
//! keeping sampling deterministic per `(seed, scenario)`.
//!
//! Sampling is **dataflow-generic**: the tile grid and the fault-cycle
//! range come from the dataflow's tiling ([`tile_grid`]) and cycle
//! model ([`matmul_cycles`]). The output-stationary draws are exactly
//! the legacy ones (the RNG-stream compatibility pin of
//! `prop_scenario.rs`); weight-stationary trials sample a weight tile
//! over the `(K, N)` grid and a cycle inside the M-row streaming pass.

use crate::config::{Dataflow, Scenario};
use crate::dnn::GemmSiteId;
use crate::mesh::driver::{matmul_cycles, tile_grid};
use crate::mesh::inject::Persistence;
use crate::mesh::{Fault, FaultPlan, SignalAddr, SignalKind};
use crate::util::Rng;

/// A fully-specified cross-layer fault trial: one offloaded tile plus
/// the fault plan injected while the RTL computes it.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TrialFault {
    pub site: GemmSiteId,
    /// Tile coordinates in [`tile_grid`] units: the output tile row (OS)
    /// or the K-dimension weight-tile index (WS).
    pub tile_i: usize,
    /// Tile column (units of DIM over N, both dataflows).
    pub tile_j: usize,
    /// The mesh-level fault plan (cycles relative to the tile matmul).
    pub plan: FaultPlan,
}

impl TrialFault {
    /// The legacy shape: a single-SEU trial.
    pub fn single(site: GemmSiteId, tile_i: usize, tile_j: usize, fault: Fault) -> Self {
        TrialFault {
            site,
            tile_i,
            tile_j,
            plan: FaultPlan::single(fault),
        }
    }

    /// The trial's tile identity — the grouping key of the lane-lockstep
    /// executor: only trials of one tile share operands (and hence a
    /// lockstep chunk).
    pub fn tile_key(&self) -> (usize, usize) {
        (self.tile_i, self.tile_j)
    }
}

impl std::fmt::Display for TrialFault {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "site L{}#{} tile({},{}): {}",
            self.site.layer, self.site.ordinal, self.tile_i, self.tile_j, self.plan
        )
    }
}

/// Sample a signal kind proportionally to its bit width, optionally
/// restricted to a subset (`kinds`); then a bit within it.
pub fn sample_signal(rng: &mut Rng, kinds: &[SignalKind]) -> (SignalKind, u8) {
    let pool: &[SignalKind] = if kinds.is_empty() {
        &SignalKind::ALL
    } else {
        kinds
    };
    let total: u64 = pool.iter().map(|k| k.width() as u64).sum();
    let mut pick = rng.below(total);
    for &k in pool {
        let w = k.width() as u64;
        if pick < w {
            return (k, pick as u8);
        }
        pick -= w;
    }
    unreachable!("bit-weighted sampling exhausted the pool");
}

/// Sample a mesh fault for one tile pass of `dataflow`: signal+bit,
/// row, col, then a cycle drawn from the dataflow's cycle model
/// ([`matmul_cycles`] — the K stream for OS, the M stream for WS).
pub fn sample_fault(
    dataflow: Dataflow,
    dim: usize,
    m: usize,
    k: usize,
    rng: &mut Rng,
    kinds: &[SignalKind],
) -> Fault {
    let (kind, bit) = sample_signal(rng, kinds);
    let row = rng.usize_below(dim);
    let col = rng.usize_below(dim);
    let cycle = rng.below(matmul_cycles(dataflow, dim, m, k));
    Fault::new(row, col, kind, bit, cycle)
}

/// Sample a mesh fault for an OS tile matmul with inner dimension
/// `k_inner` — the legacy entry ([`sample_fault`] with
/// [`Dataflow::OutputStationary`]); kept verbatim because it is the
/// RNG-stream compatibility surface of the pre-scenario campaigns.
pub fn sample_mesh_fault(
    dim: usize,
    k_inner: usize,
    rng: &mut Rng,
    kinds: &[SignalKind],
) -> Fault {
    sample_fault(Dataflow::OutputStationary, dim, 0, k_inner, rng, kinds)
}

/// Derive a scenario's fault plan from its base SEU draw. Deterministic:
/// only `double-seu` consumes additional RNG (one more base draw).
#[allow(clippy::too_many_arguments)]
fn scenario_plan(
    scenario: Scenario,
    base: Fault,
    dataflow: Dataflow,
    dim: usize,
    m: usize,
    k_inner: usize,
    rng: &mut Rng,
    kinds: &[SignalKind],
) -> FaultPlan {
    match scenario {
        Scenario::Seu => FaultPlan::single(base),
        Scenario::Mbu { bits } => {
            // k adjacent bits of the SAME signal flip in the same cycle;
            // the run is clamped into the signal's width so mbu:k on a
            // 1-bit control signal degrades to an SEU
            let width = base.addr.kind.width();
            let n = bits.min(width);
            let start = base.bit.min(width - n);
            FaultPlan::new(
                (start..start + n)
                    .map(|bit| Fault { bit, ..base })
                    .collect(),
            )
        }
        Scenario::Burst { radius } => {
            // same-cycle SEUs across every PE within Chebyshev radius r
            // of the struck PE (clipped at the mesh edges), same signal
            // and bit — a spatially-correlated particle strike
            let r0 = base.addr.row.saturating_sub(radius);
            let r1 = (base.addr.row + radius).min(dim - 1);
            let c0 = base.addr.col.saturating_sub(radius);
            let c1 = (base.addr.col + radius).min(dim - 1);
            let mut faults = Vec::with_capacity((r1 - r0 + 1) * (c1 - c0 + 1));
            for row in r0..=r1 {
                for col in c0..=c1 {
                    faults.push(Fault {
                        addr: SignalAddr::new(row, col, base.addr.kind),
                        ..base
                    });
                }
            }
            FaultPlan::new(faults)
        }
        Scenario::DoubleSeu => {
            // two independent space/time draws in one tile
            let second = sample_fault(dataflow, dim, m, k_inner, rng, kinds);
            FaultPlan::new(vec![base, second])
        }
        Scenario::StuckAt { value } => FaultPlan::single(Fault {
            persistence: Persistence::StuckAt(value),
            ..base
        }),
    }
}

/// Sample a complete trial for one GEMM site of shape (m, k, n) under
/// `scenario` and `dataflow`. The draw order is the same for every
/// dataflow (`tile_i`, `tile_j`, signal+bit, row, col, cycle — only the
/// ranges differ), and for [`Dataflow::OutputStationary`] +
/// [`Scenario::Seu`] it consumes the RNG stream in exactly the legacy
/// single-fault order.
#[allow(clippy::too_many_arguments)]
pub fn sample_trial(
    scenario: Scenario,
    dataflow: Dataflow,
    site: GemmSiteId,
    m: usize,
    k: usize,
    n: usize,
    dim: usize,
    rng: &mut Rng,
    kinds: &[SignalKind],
) -> TrialFault {
    let (tiles_i, tiles_j) = tile_grid(dataflow, dim, m, k, n);
    let tile_i = rng.usize_below(tiles_i);
    let tile_j = rng.usize_below(tiles_j);
    let base = sample_fault(dataflow, dim, m, k, rng, kinds);
    TrialFault {
        site,
        tile_i,
        tile_j,
        plan: scenario_plan(scenario, base, dataflow, dim, m, k, rng, kinds),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::driver::{os_matmul_cycles, ws_matmul_cycles};

    const SITE: GemmSiteId = GemmSiteId { layer: 1, ordinal: 0 };
    const OS: Dataflow = Dataflow::OutputStationary;
    const WS: Dataflow = Dataflow::WeightStationary;

    #[test]
    fn signal_sampling_is_bit_weighted() {
        let mut rng = Rng::new(61);
        let mut acc32 = 0usize;
        let mut ctrl = 0usize;
        let n = 20_000;
        for _ in 0..n {
            let (k, bit) = sample_signal(&mut rng, &[]);
            assert!(bit < k.width());
            match k {
                SignalKind::Acc | SignalKind::DReg => acc32 += 1,
                SignalKind::Propag | SignalKind::Valid => ctrl += 1,
                _ => {}
            }
        }
        // 64 of 82 bits are 32-bit storage; 2 of 82 are control.
        let frac32 = acc32 as f64 / n as f64;
        let fracc = ctrl as f64 / n as f64;
        assert!((frac32 - 64.0 / 82.0).abs() < 0.02, "{frac32}");
        assert!((fracc - 2.0 / 82.0).abs() < 0.01, "{fracc}");
    }

    #[test]
    fn kind_filter_restricts() {
        let mut rng = Rng::new(62);
        for _ in 0..200 {
            let (k, _) = sample_signal(&mut rng, &[SignalKind::Propag, SignalKind::Valid]);
            assert!(matches!(k, SignalKind::Propag | SignalKind::Valid));
        }
    }

    #[test]
    fn control_faults_sample_through_the_same_draw_order() {
        // `Ctrl` is outside the default pool (opt-in via `--signals
        // control`) but flows through the unchanged sampler discipline:
        // same draw order, bit inside the 16-bit control space, cycle
        // inside the dataflow's cycle model.
        let mut rng = Rng::new(64);
        for _ in 0..200 {
            let t = sample_trial(
                Scenario::Seu, OS, SITE, 16, 27, 16, 8, &mut rng, &[SignalKind::Ctrl],
            );
            let f = t.plan.faults()[0];
            assert_eq!(f.addr.kind, SignalKind::Ctrl);
            assert!(f.bit < SignalKind::Ctrl.width());
            assert!(f.cycle < os_matmul_cycles(8, 27));
            assert!(t.plan.has_control());
        }
        // mbu over a control signal clamps into its width like any kind
        let mut rng = Rng::new(65);
        let t = sample_trial(
            Scenario::Mbu { bits: 4 }, OS, SITE, 16, 27, 16, 8, &mut rng,
            &[SignalKind::Ctrl],
        );
        assert!(t.plan.len() <= 4);
        assert!(t.plan.faults().iter().all(|f| f.bit < 16));
    }

    #[test]
    fn trial_bounds_respected() {
        let mut rng = Rng::new(63);
        for _ in 0..500 {
            let t = sample_trial(Scenario::Seu, OS, SITE, 100, 27, 16, 8, &mut rng, &[]);
            assert!(t.tile_i < 13);
            assert!(t.tile_j < 2);
            assert_eq!(t.plan.len(), 1);
            let f = t.plan.faults()[0];
            assert!(f.addr.row < 8 && f.addr.col < 8);
            assert!(f.cycle < os_matmul_cycles(8, 27));
        }
    }

    #[test]
    fn sampling_is_deterministic_per_scenario() {
        for scenario in [
            Scenario::Seu,
            Scenario::Mbu { bits: 3 },
            Scenario::Burst { radius: 1 },
            Scenario::DoubleSeu,
            Scenario::StuckAt { value: true },
        ] {
            let mut r1 = Rng::new(64);
            let mut r2 = Rng::new(64);
            for _ in 0..50 {
                assert_eq!(
                    sample_trial(scenario, OS, SITE, 64, 64, 64, 8, &mut r1, &[]),
                    sample_trial(scenario, OS, SITE, 64, 64, 64, 8, &mut r2, &[]),
                    "{scenario}"
                );
            }
        }
    }

    #[test]
    fn seu_scenario_reproduces_the_legacy_rng_order() {
        // the compatibility contract: `seu` consumes exactly the draws
        // of the pre-redesign sampler, in the same order
        let mut s_rng = Rng::new(65);
        let mut l_rng = Rng::new(65);
        for _ in 0..200 {
            let t = sample_trial(Scenario::Seu, OS, SITE, 100, 27, 16, 8, &mut s_rng, &[]);
            // legacy order, drawn manually:
            let tile_i = l_rng.usize_below(100usize.div_ceil(8));
            let tile_j = l_rng.usize_below(16usize.div_ceil(8));
            let fault = sample_mesh_fault(8, 27, &mut l_rng, &[]);
            assert_eq!(t, TrialFault::single(SITE, tile_i, tile_j, fault));
        }
        // and the streams stay in lockstep afterwards
        assert_eq!(s_rng.next_u64(), l_rng.next_u64());
    }

    #[test]
    fn mbu_flips_adjacent_bits_of_one_signal() {
        let mut rng = Rng::new(66);
        for bits in [1u8, 2, 4, 8, 32] {
            for _ in 0..100 {
                let t = sample_trial(
                    Scenario::Mbu { bits },
                    OS,
                    SITE,
                    64,
                    27,
                    64,
                    8,
                    &mut rng,
                    &[],
                );
                let fs = t.plan.faults();
                let kind = fs[0].addr.kind;
                let want = bits.min(kind.width()) as usize;
                assert_eq!(fs.len(), want, "bits={bits} kind={kind}");
                for w in fs.windows(2) {
                    assert_eq!(w[1].bit, w[0].bit + 1, "adjacent bits");
                    assert_eq!(w[0].addr, w[1].addr, "same signal");
                    assert_eq!(w[0].cycle, w[1].cycle, "same cycle");
                }
                assert!(fs.last().unwrap().bit < kind.width());
            }
        }
    }

    #[test]
    fn burst_covers_the_chebyshev_ball_clipped_to_the_mesh() {
        let mut rng = Rng::new(67);
        let dim = 8;
        for radius in [0usize, 1, 2, 7] {
            for _ in 0..100 {
                let t = sample_trial(
                    Scenario::Burst { radius },
                    OS,
                    SITE,
                    64,
                    27,
                    64,
                    dim,
                    &mut rng,
                    &[],
                );
                let fs = t.plan.faults();
                let full = (2 * radius + 1) * (2 * radius + 1);
                assert!(fs.len() <= full && !fs.is_empty());
                let base = fs[0];
                for f in fs {
                    assert!(f.addr.row < dim && f.addr.col < dim);
                    assert_eq!(f.bit, base.bit);
                    assert_eq!(f.cycle, base.cycle);
                    assert_eq!(f.addr.kind, base.addr.kind);
                }
                // pairwise-distinct PEs
                let set: std::collections::HashSet<_> =
                    fs.iter().map(|f| (f.addr.row, f.addr.col)).collect();
                assert_eq!(set.len(), fs.len());
            }
        }
    }

    #[test]
    fn double_seu_draws_two_independent_faults() {
        let mut rng = Rng::new(68);
        let t = sample_trial(Scenario::DoubleSeu, OS, SITE, 64, 27, 64, 8, &mut rng, &[]);
        assert_eq!(t.plan.len(), 2);
    }

    #[test]
    fn stuck_scenario_activates_stuck_at_persistence() {
        let mut rng = Rng::new(69);
        for value in [false, true] {
            let t = sample_trial(
                Scenario::StuckAt { value },
                OS,
                SITE,
                64,
                27,
                64,
                8,
                &mut rng,
                &[],
            );
            assert_eq!(t.plan.len(), 1);
            assert_eq!(
                t.plan.faults()[0].persistence,
                Persistence::StuckAt(value)
            );
        }
    }

    #[test]
    fn ws_trial_samples_the_weight_tile_grid_and_m_stream() {
        // WS: tile_i ranges over K tiles, tile_j over N tiles, and the
        // fault cycle over the M-row streaming pass
        let mut rng = Rng::new(70);
        let (m, k, n, dim) = (100usize, 27usize, 16usize, 8usize);
        for _ in 0..500 {
            let t = sample_trial(Scenario::Seu, WS, SITE, m, k, n, dim, &mut rng, &[]);
            assert!(t.tile_i < k.div_ceil(dim), "tile_i indexes K under WS");
            assert!(t.tile_j < n.div_ceil(dim));
            let f = t.plan.faults()[0];
            assert!(f.cycle < ws_matmul_cycles(dim, m), "cycle from the M stream");
        }
        // os draws are untouched by the dataflow-generic signature
        let mut a = Rng::new(71);
        let mut b = Rng::new(71);
        assert_eq!(
            sample_trial(Scenario::Seu, OS, SITE, m, k, n, dim, &mut a, &[]),
            TrialFault::single(
                SITE,
                b.usize_below(m.div_ceil(dim)),
                b.usize_below(n.div_ceil(dim)),
                sample_mesh_fault(dim, k, &mut b, &[]),
            )
        );
    }

    #[test]
    fn ws_sampling_is_deterministic_per_scenario() {
        for scenario in [
            Scenario::Seu,
            Scenario::Mbu { bits: 3 },
            Scenario::Burst { radius: 1 },
            Scenario::DoubleSeu,
            Scenario::StuckAt { value: true },
        ] {
            let mut r1 = Rng::new(72);
            let mut r2 = Rng::new(72);
            for _ in 0..50 {
                assert_eq!(
                    sample_trial(scenario, WS, SITE, 64, 64, 64, 8, &mut r1, &[]),
                    sample_trial(scenario, WS, SITE, 64, 64, 64, 8, &mut r2, &[]),
                    "{scenario}"
                );
            }
        }
    }

    #[test]
    fn display_includes_site_and_plan() {
        let t = TrialFault::single(SITE, 2, 1, Fault::new(0, 3, SignalKind::Acc, 7, 11));
        assert_eq!(
            t.to_string(),
            "site L1#0 tile(2,1): PE(0,3).acc[bit 7] @ cycle 11"
        );
    }
}
