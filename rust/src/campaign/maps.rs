//! Per-PE vulnerability maps — the paper's Fig. 5.
//!
//! * **Fig. 5a**: AVF per PE when *control signals* (propag / valid) are
//!   targeted during a real cross-layer inference. Propag faults hijack
//!   the accumulator chain and forward down the column, so upper rows
//!   come out more critical.
//! * **Fig. 5b**: probability that a fault in the *weight* pipeline
//!   registers is exposed to the software layer (not masked inside the
//!   array). Western (earlier) columns are more exposed because the
//!   corrupted operand is reused by every PE further east.

use super::fault::TrialFault;
use super::runner::{CrossLayerRunner, TileBackend};
use crate::config::{Dataflow, MeshConfig, OffloadScope};
use crate::dnn::engine::synthetic_input;
use crate::dnn::{argmax, Model};
use crate::mesh::driver::{gold_matmul, matmul_cycles, tile_grid, MatmulDriver};
use crate::mesh::{Fault, Mesh, SignalKind};
use crate::util::stats::VulnEstimate;
use crate::util::Rng;

/// A DIM x DIM heat map of per-PE estimates.
#[derive(Clone, Debug)]
pub struct PeMap {
    pub dim: usize,
    pub title: String,
    /// row-major per-PE estimates
    pub cells: Vec<VulnEstimate>,
}

impl PeMap {
    pub fn new(dim: usize, title: &str) -> Self {
        PeMap {
            dim,
            title: title.to_string(),
            cells: vec![VulnEstimate::default(); dim * dim],
        }
    }

    pub fn value(&self, r: usize, c: usize) -> f64 {
        self.cells[r * self.dim + c].vf()
    }

    /// Mean estimate of one row (Fig. 5a trend check).
    pub fn row_mean(&self, r: usize) -> f64 {
        (0..self.dim).map(|c| self.value(r, c)).sum::<f64>() / self.dim as f64
    }

    /// Mean estimate of one column (Fig. 5b trend check).
    pub fn col_mean(&self, c: usize) -> f64 {
        (0..self.dim).map(|r| self.value(r, c)).sum::<f64>() / self.dim as f64
    }
}

/// Fig. 5a: per-PE AVF for control-signal faults during full cross-layer
/// inference of `model`, injecting into the GEMM of layer-site index
/// `site_idx` (e.g. the first conv of ResNet50 in the paper). The map
/// is dataflow-generic: the tile grid and the fault-cycle range come
/// from `mesh_cfg.dataflow`'s tiling and cycle model, and the trials
/// run on a mesh of that dataflow (the OS draws are exactly the legacy
/// ones).
pub fn control_avf_map(
    model: &Model,
    site_idx: usize,
    mesh_cfg: &MeshConfig,
    trials_per_pe: u64,
    seed: u64,
    kind: SignalKind,
) -> PeMap {
    assert!(matches!(kind, SignalKind::Propag | SignalKind::Valid));
    let (dim, dataflow) = (mesh_cfg.dim, mesh_cfg.dataflow);
    let mut rng = Rng::new(seed);
    let mut map = PeMap::new(
        dim,
        &format!("AVF map ({kind}, {dataflow}) — {}", model.name),
    );
    let x = synthetic_input(&model.input_shape, &mut rng);
    let golden = argmax(&model.forward(&x, None).data);
    let sites = model.gemm_sites(&x);
    let info = sites[site_idx.min(sites.len() - 1)];
    let cycles = matmul_cycles(dataflow, dim, info.m, info.k);
    let (tiles_i, tiles_j) = tile_grid(dataflow, dim, info.m, info.k, info.n);
    let mut mesh = Mesh::new(dim, dataflow);
    for r in 0..dim {
        for c in 0..dim {
            for _ in 0..trials_per_pe {
                let trial = TrialFault::single(
                    info.site,
                    rng.usize_below(tiles_i),
                    rng.usize_below(tiles_j),
                    Fault::new(r, c, kind, 0, rng.below(cycles)),
                );
                let mut runner = CrossLayerRunner::new(
                    &trial,
                    TileBackend::Mesh(&mut mesh),
                    OffloadScope::SingleTile,
                );
                let logits = model.forward(&x, Some(&mut runner));
                let critical = argmax(&logits.data) != golden;
                map.cells[r * dim + c].record(critical);
            }
        }
    }
    map
}

/// Per-PE exposure for faults in `kind`, measured at tile granularity:
/// the probability that an *output element* of the tile is corrupted
/// (golden vs faulty tile, ReLU-sparse activations providing the
/// zero-masking). Per-element accounting captures both the paper's
/// Fig. 5 gradients:
///
/// * `kind = Weight` — Fig. 5b: western columns more exposed (the
///   corrupted operand is reused by every PE further east);
/// * `kind = Propag/Valid` — tile-level companion of Fig. 5a: upper
///   rows more exposed (the flipped control bit forwards south and the
///   accumulator hijack corrupts the whole column below).
pub fn exposure_map(
    dim: usize,
    k_inner: usize,
    kind: SignalKind,
    trials_per_pe: u64,
    seed: u64,
) -> PeMap {
    exposure_map_for(Dataflow::OutputStationary, dim, k_inner, kind, trials_per_pe, seed)
}

/// Dataflow-generic tile-level exposure map. `stream` is the streamed
/// operand extent of one pass: the inner dimension K for OS, the
/// activation row count M for WS. Faults are sampled within the
/// COMPUTE phase — the paper's Fig. 5 analysis concerns faults "during
/// computation" (preload/flush-phase faults have their own, different
/// spatial profile). The OS arm draws exactly what the legacy
/// [`exposure_map`] drew; the WS arm streams ReLU-sparse activation
/// panels against a dense preloaded weight tile, so the map measures
/// the held-operand masking structure of the WS array.
pub fn exposure_map_for(
    dataflow: Dataflow,
    dim: usize,
    stream: usize,
    kind: SignalKind,
    trials_per_pe: u64,
    seed: u64,
) -> PeMap {
    let mut rng = Rng::new(seed);
    let mut map = PeMap::new(dim, &format!("{kind}-register exposure map ({dataflow})"));
    let mut mesh = Mesh::new(dim, dataflow);
    let compute_start = (2 * dim - 1) as u64;
    let compute_len = (stream + 2 * dim - 2) as u64;
    let d = match dataflow {
        Dataflow::OutputStationary => crate::mat::Mat::zeros(dim, dim),
        Dataflow::WeightStationary => crate::mat::Mat::zeros(stream, dim),
    };
    for r in 0..dim {
        for c in 0..dim {
            for _ in 0..trials_per_pe {
                // weights dense, activations ReLU-sparse (half zeros) —
                // under OS the activations stream north (operand B),
                // under WS they stream west (operand A)
                let (a, b) = match dataflow {
                    Dataflow::OutputStationary => {
                        let a = rng.mat_i8(dim, stream);
                        let mut b = rng.mat_i8(stream, dim);
                        sparsify(&mut rng, b.data_mut());
                        (a, b)
                    }
                    Dataflow::WeightStationary => {
                        let mut a = rng.mat_i8(stream, dim);
                        sparsify(&mut rng, a.data_mut());
                        let w = rng.mat_i8(dim, dim);
                        (a, w)
                    }
                };
                let fault = Fault::new(
                    r,
                    c,
                    kind,
                    rng.below(kind.width() as u64) as u8,
                    compute_start + rng.below(compute_len),
                );
                let faulty = MatmulDriver::new(&mut mesh)
                    .matmul_with_fault(a.view(), b.view(), d.view(), &fault);
                let gold = gold_matmul(a.view(), b.view(), d.view());
                let cell = &mut map.cells[r * dim + c];
                for (fv, gv) in faulty.data().iter().zip(gold.data()) {
                    cell.record(fv != gv);
                }
            }
        }
    }
    map
}

/// Half-zero, non-negative values — post-ReLU activation statistics.
fn sparsify(rng: &mut Rng, vals: &mut [i8]) {
    for v in vals {
        if rng.chance(0.5) {
            *v = 0;
        } else {
            *v = (*v).max(0);
        }
    }
}

/// Fig. 5b: weight-register exposure (see [`exposure_map`]).
pub fn weight_exposure_map(
    dim: usize,
    k_inner: usize,
    trials_per_pe: u64,
    seed: u64,
) -> PeMap {
    exposure_map(dim, k_inner, SignalKind::Weight, trials_per_pe, seed)
}

/// WS companion of [`weight_exposure_map`]: exposure of the stationary
/// weight registers while `m_rows` activation rows stream through. A
/// corrupted stationary weight never travels east, so — unlike the OS
/// map's west-to-east gradient — its exposure is confined to the PE's
/// own column (pinned against `inject_now` ground truth by test).
pub fn ws_weight_exposure_map(
    dim: usize,
    m_rows: usize,
    trials_per_pe: u64,
    seed: u64,
) -> PeMap {
    exposure_map_for(
        Dataflow::WeightStationary,
        dim,
        m_rows,
        SignalKind::Weight,
        trials_per_pe,
        seed,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::models;

    fn mesh4(dataflow: Dataflow) -> MeshConfig {
        MeshConfig { dim: 4, dataflow }
    }

    #[test]
    fn propag_map_upper_rows_more_critical() {
        let model = models::quicknet(5);
        let map = control_avf_map(
            &model,
            1,
            &mesh4(Dataflow::OutputStationary),
            12,
            0xF16A,
            SignalKind::Propag,
        );
        // paper: corruption propagates down the whole column, so upper
        // rows affect more PEs => row 0 at least as critical as row dim-1
        let top = map.row_mean(0);
        let bottom = map.row_mean(3);
        assert!(
            top >= bottom,
            "top rows must be >= critical: top={top} bottom={bottom}"
        );
    }

    #[test]
    fn propag_exposure_decreases_southward() {
        let map = exposure_map(4, 16, SignalKind::Propag, 40, 0xF16C);
        let top = map.row_mean(0);
        let bottom = map.row_mean(3);
        assert!(
            top > bottom,
            "upper rows must be more exposed: top={top} bottom={bottom}"
        );
    }

    #[test]
    fn weight_exposure_decreases_eastward() {
        let map = weight_exposure_map(4, 16, 40, 0xF16B);
        let west = map.col_mean(0);
        let east = map.col_mean(3);
        assert!(
            west > east,
            "western columns must be more exposed: west={west} east={east}"
        );
    }

    #[test]
    fn ws_control_avf_map_runs_end_to_end() {
        // the WS AVF map drives real cross-layer inferences through the
        // WS runner path; values stay probabilities
        let model = models::quicknet(5);
        let map = control_avf_map(
            &model,
            1,
            &mesh4(Dataflow::WeightStationary),
            4,
            0xF16D,
            SignalKind::Propag,
        );
        for r in 0..4 {
            for c in 0..4 {
                let v = map.value(r, c);
                assert!((0.0..=1.0).contains(&v), "PE({r},{c}): {v}");
            }
        }
    }

    #[test]
    fn ws_weight_fault_exposure_is_column_local() {
        // inject_now ground truth for the WS map's structure: a
        // corrupted stationary weight multiplies only its own column's
        // psums — it never travels east the way the OS weight stream
        // does (Fig. 5b), so corruption stays in column c.
        let dim = 4;
        let m = 6;
        let mut rng = Rng::new(0xF16E);
        let a = rng.mat_i8(m, dim);
        let w = rng.mat_i8(dim, dim);
        let d = rng.mat_i32(m, dim, 100);
        let mut mesh = Mesh::new(dim, Dataflow::WeightStationary);
        let golden = MatmulDriver::new(&mut mesh).matmul(a.view(), w.view(), d.view());
        let mut exposed_any = false;
        let compute_start = (2 * dim - 1) as u64;
        for r in 0..dim {
            for c in 0..dim {
                let f = Fault::new(r, c, SignalKind::Weight, 6, compute_start + 1);
                let faulty = MatmulDriver::new(&mut mesh)
                    .matmul_with_fault(a.view(), w.view(), d.view(), &f);
                for rr in 0..m {
                    for cc in 0..dim {
                        if cc != c {
                            assert_eq!(
                                faulty.at(rr, cc),
                                golden.at(rr, cc),
                                "PE({r},{c}) corrupted column {cc}"
                            );
                        } else if faulty.at(rr, cc) != golden.at(rr, cc) {
                            exposed_any = true;
                        }
                    }
                }
            }
        }
        assert!(exposed_any, "a bit-6 stationary-weight flip must expose somewhere");
    }

    #[test]
    fn ws_weight_exposure_map_is_deterministic_and_bounded() {
        let a = ws_weight_exposure_map(4, 8, 10, 0xF16F);
        let b = ws_weight_exposure_map(4, 8, 10, 0xF16F);
        for r in 0..4 {
            for c in 0..4 {
                assert_eq!(a.value(r, c), b.value(r, c), "deterministic per seed");
                assert!((0.0..=1.0).contains(&a.value(r, c)));
            }
        }
    }

    #[test]
    fn map_accessors() {
        let mut m = PeMap::new(2, "t");
        m.cells[0].record(true);
        m.cells[0].record(false);
        assert!((m.value(0, 0) - 0.5).abs() < 1e-12);
        assert_eq!(m.value(1, 1), 0.0);
    }
}
