//! Campaign orchestration: the paper's §IV experiments as a library.
//!
//! A campaign runs `faults_per_layer` trials per GEMM layer per input,
//! classifies each trial (masked / exposed / critical) and accumulates
//! AVF (RTL backends) or PVF (software-only backend) with wall-clock
//! accounting for the Table VI timing comparison.
//!
//! # The site-resume trial engine
//!
//! The trial loop is **site-major with per-site batches**: per input,
//! one golden pass records an activation checkpoint per top-level layer
//! ([`Model::forward_checkpointed`]), then all `faults_per_layer`
//! trials of a site run back-to-back against the same checkpoint, the
//! same persistent simulator and the same scratch result tile. Each
//! trial replays only the faulty layer ([`Model::forward_layers`]); if
//! the splice change-flag reports the fault hardware-masked, the
//! downstream recompute is skipped entirely (logits := golden logits —
//! the masked invariant), otherwise only the *downstream* layers run.
//! The legacy whole-network path stays available as
//! [`TrialEngine::FullForward`] and is the bit-exactness oracle: both
//! engines produce identical trials / critical / exposed counts and
//! per-layer maps for a fixed seed (pinned by
//! `rust/tests/prop_resume.rs`).
//!
//! Sampling is split from execution: [`plan_one`] pre-draws every
//! trial of an input in the canonical RNG order (input tensor first,
//! then trials site-major), so execution order no longer touches the
//! RNG and the coordinator can shard work at `(input, site)`
//! granularity while staying bit-identical per `(seed, input_idx)`.
//! Each trial carries a whole fault *plan* sampled by the campaign's
//! [`Scenario`] (`seu` default — bit-identical to the legacy
//! single-fault campaigns; `mbu:<k>`, `burst:<r>`, `double-seu`,
//! `stuck:<0|1>` — see the ROADMAP "Fault scenario API" contract).
//!
//! Campaigns are **dataflow-generic**: `MeshConfig.dataflow` selects
//! the mesh program every RTL tile executes, the tile grid trials are
//! sampled from, and the cycle range fault cycles are drawn from —
//! with the OS draws exactly the legacy ones, so fixed-seed OS
//! campaigns are bit-identical to the pre-dataflow-generic engine.
//! Every backend — the whole SoC included — runs both dataflows
//! (ROADMAP "Schedule-indexable SoC").

use super::fault::{sample_trial, TrialFault};
use super::maps::exposure_map_for;
use super::runner::{CrossLayerRunner, PackedGroup, TileBackend};
use crate::config::{
    Backend, CampaignConfig, Dataflow, HardeningConfig, MeshConfig, OffloadScope, Scenario,
    TileEngine, TrialEngine,
};
use crate::dnn::engine::probe_input;
use crate::dnn::engine::synthetic_input;
use crate::dnn::{argmax, ActivationCheckpoints, GemmSiteInfo, Model, TensorI8};
use crate::mesh::hdfit::InstrumentedMesh;
use crate::mesh::{Mesh, SignalKind};
use crate::soc::Soc;
use crate::swfi::{sample_sw_plan, SwInjector, SwPlan};
use crate::util::stats::VulnEstimate;
use crate::util::Rng;
use anyhow::Result;
use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// Result of one fault-injection trial.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TrialOutcome {
    /// Fault never reached the layer output (HW-masked).
    Masked,
    /// Layer output corrupted but Top-1 unchanged (SW-masked / SDC-safe).
    Exposed,
    /// Top-1 classification flipped vs the golden run.
    Critical,
}

/// Mitigation verdict of one *struck* trial under an armed
/// [`HardeningConfig`] — disjoint, priority corrected > detected >
/// escaped. Unstruck trials (and every trial of a `--hardening none`
/// campaign) carry no verdict; the three verdict counters therefore sum
/// to the campaign's struck-trial count, the coverage denominator.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MitVerdict {
    /// A detector (ABFT checksum or the SDC logit detector) flagged the
    /// corruption, but mitigation could not restore it.
    Detected,
    /// Mitigation restored the struck region to golden bit-exactly
    /// (the trial classifies as masked).
    Corrected,
    /// No armed mechanism caught the corruption.
    Escaped,
}

/// Aggregated campaign result for one model on one backend.
#[derive(Clone, Debug)]
pub struct CampaignResult {
    pub model: String,
    pub backend: Backend,
    /// The mesh dataflow the campaign's RTL tiles executed under.
    pub dataflow: Dataflow,
    /// The fault scenario every trial of this campaign sampled.
    pub scenario: Scenario,
    pub vuln: VulnEstimate,
    pub exposed_trials: u64,
    pub masked_trials: u64,
    /// Total RTL mesh cycles stepped by the campaign's tile runs
    /// (golden-cursor advances included; 0 on the SW-only backend).
    /// Deterministic per seed, so the cycle-resume speedup is
    /// wall-clock-noise-free.
    pub rtl_cycles_stepped: u64,
    /// Lane-cycles that carried a live (unretired) trial, over every RTL
    /// cycle the campaign stepped. Scalar engine paths count one fully
    /// occupied lane per cycle; lockstep/packed passes count their
    /// active lanes per lockstep cycle. Deterministic per seed.
    pub lane_cycles_filled: u64,
    /// Lane-cycles of capacity paid for those same steps: lockstep and
    /// packed passes charge `max(lanes, chunk width)` per lockstep
    /// cycle, scalar paths one. `lane_cycles_filled / lane_cycles_stepped`
    /// is the campaign's lane-occupancy metric — the figure cross-tile
    /// packing exists to raise.
    pub lane_cycles_stepped: u64,
    /// Hardening only: struck trials whose [`MitVerdict`] was
    /// `Detected`. Zero for `--hardening none` campaigns.
    pub detected_trials: u64,
    /// Hardening only: struck trials whose [`MitVerdict`] was
    /// `Corrected` (re-classified as masked by the restored splice).
    pub corrected_trials: u64,
    /// Hardening only: struck trials whose [`MitVerdict`] was
    /// `Escaped`.
    pub escaped_trials: u64,
    pub wall: Duration,
    pub per_layer: BTreeMap<usize, VulnEstimate>,
}

impl CampaignResult {
    /// The vulnerability factor: AVF for RTL backends, PVF for SW-only.
    pub fn vf(&self) -> f64 {
        self.vuln.vf()
    }

    /// Lane occupancy of the campaign's RTL stepping: the fraction of
    /// paid lane-cycles that carried a live trial (1.0 for purely scalar
    /// engines, < 1.0 when lockstep lanes idle, 0.0 when nothing
    /// stepped).
    pub fn lane_occupancy(&self) -> f64 {
        if self.lane_cycles_stepped == 0 {
            0.0
        } else {
            self.lane_cycles_filled as f64 / self.lane_cycles_stepped as f64
        }
    }

    /// Struck trials: the coverage denominator (the trials whose RTL
    /// region differed from golden before mitigation ran — exactly the
    /// exposed trials of the same seed under `--hardening none`).
    pub fn struck_trials(&self) -> u64 {
        self.detected_trials + self.corrected_trials + self.escaped_trials
    }

    /// Detection coverage of the hardening under evaluation: the
    /// fraction of struck trials an armed mechanism caught (detected or
    /// corrected). 0.0 when nothing struck.
    pub fn detection_coverage(&self) -> f64 {
        let struck = self.struck_trials();
        if struck == 0 {
            0.0
        } else {
            (self.detected_trials + self.corrected_trials) as f64 / struck as f64
        }
    }

    /// Correction coverage: the fraction of struck trials mitigation
    /// restored to golden bit-exactly. 0.0 when nothing struck.
    pub fn correction_coverage(&self) -> f64 {
        let struck = self.struck_trials();
        if struck == 0 {
            0.0
        } else {
            self.corrected_trials as f64 / struck as f64
        }
    }
}

impl CampaignResult {
    /// Merge a partial (per-input / per-worker) result into this one.
    pub fn merge(&mut self, other: &CampaignResult) {
        self.vuln.merge(&other.vuln);
        self.exposed_trials += other.exposed_trials;
        self.masked_trials += other.masked_trials;
        self.rtl_cycles_stepped += other.rtl_cycles_stepped;
        self.lane_cycles_filled += other.lane_cycles_filled;
        self.lane_cycles_stepped += other.lane_cycles_stepped;
        self.detected_trials += other.detected_trials;
        self.corrected_trials += other.corrected_trials;
        self.escaped_trials += other.escaped_trials;
        self.wall += other.wall;
        for (layer, v) in &other.per_layer {
            self.per_layer.entry(*layer).or_default().merge(v);
        }
    }

    pub fn empty(
        model: &str,
        backend: Backend,
        scenario: Scenario,
        dataflow: Dataflow,
    ) -> CampaignResult {
        CampaignResult {
            model: model.to_string(),
            backend,
            dataflow,
            scenario,
            vuln: VulnEstimate::default(),
            exposed_trials: 0,
            masked_trials: 0,
            rtl_cycles_stepped: 0,
            lane_cycles_filled: 0,
            lane_cycles_stepped: 0,
            detected_trials: 0,
            corrected_trials: 0,
            escaped_trials: 0,
            wall: Duration::ZERO,
            per_layer: BTreeMap::new(),
        }
    }
}

/// One pre-sampled fault trial (the backend decides which arm is used).
/// Both arms carry a whole scenario plan; executors borrow trials from
/// the shared input plan, so nothing here is cloned on the hot path.
#[derive(Clone, Debug)]
pub enum PlannedTrial {
    /// Cross-layer RTL trial (EnforSa / Hdfit / FullSoc backends).
    Rtl(TrialFault),
    /// Software-level fault plan (SwOnly backend).
    Sw(SwPlan),
}

/// All `faults_per_layer` trials of one GEMM site, run back-to-back
/// against the same checkpoint — the coordinator's shardable work unit.
#[derive(Clone, Debug)]
pub struct SiteBatch {
    pub info: GemmSiteInfo,
    pub trials: Vec<PlannedTrial>,
}

/// Everything needed to execute any site batch of one input: the input
/// tensor, the golden reference, the per-layer activation checkpoints
/// (site-resume engine only) and the pre-sampled trial batches.
#[derive(Clone, Debug)]
pub struct InputPlan {
    pub x: TensorI8,
    pub golden_logits: TensorI8,
    pub golden_top1: usize,
    /// Per-layer resume points; `None` under [`TrialEngine::FullForward`]
    /// (the oracle path never records checkpoints).
    pub ckpt: Option<ActivationCheckpoints>,
    pub batches: Vec<SiteBatch>,
}

/// Parse the campaign's signal-kind restriction once.
pub fn signal_kinds(cfg: &CampaignConfig) -> Vec<SignalKind> {
    cfg.signals
        .iter()
        .filter_map(|s| SignalKind::parse(s))
        .collect()
}

/// Discover the campaign's GEMM sites once per campaign: site shapes
/// depend only on the model topology and input *shape* (never on input
/// values), so a zero probe input suffices and no campaign RNG is
/// consumed.
pub fn campaign_sites(model: &Model) -> Vec<GemmSiteInfo> {
    model.gemm_sites(&probe_input(&model.input_shape))
}

/// The coordinator's per-input seed derivation: results depend only on
/// `(seed, input_idx)`, never on worker count or execution order.
pub fn derived_input_seed(seed: u64, input_idx: u64) -> u64 {
    seed ^ (input_idx + 1).wrapping_mul(0x9E3779B97F4A7C15)
}

/// Build one input's execution plan, drawing from `rng` in the
/// canonical order (input tensor first, then trials site-major) — the
/// exact stream the legacy per-trial loop consumed, so plans are
/// bit-identical across trial engines, worker counts and shardings.
pub fn plan_one(
    model: &Model,
    cfg: &CampaignConfig,
    sites: &[GemmSiteInfo],
    kinds: &[SignalKind],
    mesh_cfg: &MeshConfig,
    rng: &mut Rng,
) -> InputPlan {
    let x = synthetic_input(&model.input_shape, rng);
    let (golden_logits, ckpt) = match cfg.engine {
        TrialEngine::SiteResume => {
            let (logits, ckpt) = model.forward_checkpointed(&x);
            (logits, Some(ckpt))
        }
        TrialEngine::FullForward => (model.forward(&x, None), None),
    };
    let golden_top1 = argmax(&golden_logits.data);
    let batches = sites
        .iter()
        .map(|info| SiteBatch {
            info: *info,
            trials: (0..cfg.faults_per_layer)
                .map(|_| match cfg.backend {
                    Backend::SwOnly => {
                        PlannedTrial::Sw(sample_sw_plan(model, cfg.scenario, rng))
                    }
                    _ => PlannedTrial::Rtl(sample_trial(
                        cfg.scenario,
                        mesh_cfg.dataflow,
                        info.site,
                        info.m,
                        info.k,
                        info.n,
                        mesh_cfg.dim,
                        rng,
                        kinds,
                    )),
                })
                .collect(),
        })
        .collect();
    InputPlan {
        x,
        golden_logits,
        golden_top1,
        ckpt,
        batches,
    }
}

/// The stateful simulator a worker owns for the whole campaign.
enum Sim {
    Mesh(Mesh),
    Hdfit(InstrumentedMesh),
    /// Boxed: the SoC carries MiBs of memory model; persistent across
    /// trials via [`Soc::reset`] instead of per-trial construction.
    Soc(Box<Soc>),
    Sw,
}

/// Executes planned trial batches against a persistent simulator. One
/// executor per worker thread; simulators never cross threads.
pub struct TrialExecutor {
    engine: TrialEngine,
    tile_engine: TileEngine,
    /// Lane count for the lane-lockstep tile engine (ignored otherwise).
    lanes: usize,
    scope: OffloadScope,
    /// The campaign's `--hardening` axis, armed on every RTL runner.
    hardening: HardeningConfig,
    /// Selective-TMR column set (empty unless `tmr:<cols>` is armed),
    /// precomputed once per executor — see [`tmr_columns`].
    tmr_protected: Vec<bool>,
    sim: Sim,
}

/// The selective-TMR column set: rank PE columns by the dataflow's Acc
/// exposure map ([`exposure_map_for`], `col_mean` descending, ties to
/// the lower index) and protect the top `cols`. The map is sampled on
/// its own fresh mesh from fixed literals, so the set depends only on
/// `(dataflow, dim, cols)` — every worker, tile engine and sharding
/// derives the same columns, keeping hardened campaigns bit-identical
/// across all of them.
pub fn tmr_columns(mesh_cfg: &MeshConfig, cols: usize) -> Vec<bool> {
    let dim = mesh_cfg.dim;
    let map = exposure_map_for(mesh_cfg.dataflow, dim, 2 * dim, SignalKind::Acc, 8, 0xC0FFEE);
    let mut rank: Vec<usize> = (0..dim).collect();
    rank.sort_by(|&a, &b| {
        map.col_mean(b)
            .partial_cmp(&map.col_mean(a))
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    let mut protected = vec![false; dim];
    for &c in rank.iter().take(cols.min(dim)) {
        protected[c] = true;
    }
    protected
}

impl TrialExecutor {
    pub fn new(mesh_cfg: &MeshConfig, cfg: &CampaignConfig) -> TrialExecutor {
        let sim = match cfg.backend {
            Backend::EnforSa => Sim::Mesh(Mesh::new(mesh_cfg.dim, mesh_cfg.dataflow)),
            Backend::Hdfit => {
                Sim::Hdfit(InstrumentedMesh::with_dataflow(mesh_cfg.dim, mesh_cfg.dataflow))
            }
            // the SoC takes its dataflow from MeshConfig too: the
            // controller's SocSchedule opens the OS or WS window from
            // the same command stream shape
            Backend::FullSoc => {
                Sim::Soc(Box::new(Soc::with_dataflow(mesh_cfg.dim, mesh_cfg.dataflow)))
            }
            Backend::SwOnly => Sim::Sw,
        };
        let tmr_protected = if cfg.hardening.tmr_cols > 0 && cfg.backend != Backend::SwOnly {
            tmr_columns(mesh_cfg, cfg.hardening.tmr_cols)
        } else {
            Vec::new()
        };
        TrialExecutor {
            engine: cfg.engine,
            tile_engine: cfg.tile_engine,
            lanes: cfg.lanes.max(1),
            scope: cfg.offload_scope,
            hardening: cfg.hardening,
            tmr_protected,
            sim,
        }
    }

    /// Run one site batch of one input's plan, recording every outcome
    /// into `result`.
    pub fn run_batch(
        &mut self,
        model: &Model,
        plan: &InputPlan,
        batch: &SiteBatch,
        result: &mut CampaignResult,
    ) {
        let layer = batch.info.site.layer;
        match &mut self.sim {
            Sim::Sw => {
                // the SW backend has no RTL seam to harden: trials carry
                // no mitigation verdict (coverage is an RTL-axis metric)
                for t in &batch.trials {
                    let PlannedTrial::Sw(sw_plan) = t else {
                        unreachable!("RTL trial routed to the SW backend")
                    };
                    let outcome = run_sw_trial(model, plan, sw_plan, self.engine);
                    record(result, layer, (outcome, None));
                }
            }
            Sim::Mesh(m) => run_rtl_batch(
                model,
                plan,
                batch,
                TileBackend::Mesh(m),
                self.scope,
                self.engine,
                self.tile_engine,
                self.lanes,
                self.hardening,
                &self.tmr_protected,
                result,
            ),
            Sim::Hdfit(m) => run_rtl_batch(
                model,
                plan,
                batch,
                TileBackend::Hdfit(m),
                self.scope,
                self.engine,
                self.tile_engine,
                self.lanes,
                self.hardening,
                &self.tmr_protected,
                result,
            ),
            // the SoC path always offloads a single tile (whole-layer
            // offload through the core is unsupported). Cycle-resume is
            // fully supported — the schedule-indexable controller
            // snapshots mid-window (pinned by prop_cycle_resume.rs);
            // lane-lockstep falls back to cycle-resume (one persistent
            // chip cannot carry N lanes)
            Sim::Soc(s) => run_rtl_batch(
                model,
                plan,
                batch,
                TileBackend::Soc(s.as_mut()),
                OffloadScope::SingleTile,
                self.engine,
                self.tile_engine,
                self.lanes,
                self.hardening,
                &self.tmr_protected,
                result,
            ),
        }
    }
}

/// Run every RTL trial of a batch through one runner: the backend
/// borrow, the scratch buffers and the golden cycle-cursor persist
/// across the whole batch ([`CrossLayerRunner::arm`] re-arms between
/// trials).
///
/// Under [`TileEngine::CycleResume`] the batch executes **tile-major,
/// then by ascending first-effect cycle**, so the golden cursor only
/// ever steps forward within one tile trajectory and the batch pays
/// each tile's golden prefix exactly once. Re-ordering execution is
/// free: sampling order is pinned by [`plan_one`] (the RNG stream is
/// untouched) and every recorded outcome is order-independent.
///
/// Under [`TileEngine::LaneLockstep`] the same sorted order is
/// additionally grouped into consecutive same-tile **chunks of at most
/// `lanes` trials**: each chunk's tile suffix is stepped once through
/// the lane-batched mesh ([`CrossLayerRunner::begin_chunk`]), and every
/// trial of the chunk splices its own lane's result. Backends without
/// [`TileBackend::supports_lane_lockstep`] fall back per trial —
/// HDFIT to cycle-resume, the whole-SoC backend to full.
///
/// Under [`TileEngine::PackedLockstep`] the chunking becomes a
/// **packer**: first form lane-lockstep's exact maximal same-tile runs
/// (each at most `lanes` trials), then pack *whole* consecutive runs
/// into one chunk while their lane total still fits — cross-tile groups
/// stepped side by side by one packed pass
/// ([`CrossLayerRunner::begin_packed_chunk`]). Packing whole runs (never
/// splitting one) keeps the per-chunk cycle cost at
/// `Σ_g adv_g + max_g(span_g)` vs lane-lockstep's
/// `Σ_g (adv_g + span_g)`: never more, strictly fewer whenever at least
/// two runs share a chunk. Fallback on non-mesh backends is identical
/// to lane-lockstep's (per-trial cycle-resume, same trial order).
#[allow(clippy::too_many_arguments)]
fn run_rtl_batch(
    model: &Model,
    plan: &InputPlan,
    batch: &SiteBatch,
    backend: TileBackend<'_>,
    scope: OffloadScope,
    engine: TrialEngine,
    tile_engine: TileEngine,
    lanes: usize,
    hardening: HardeningConfig,
    tmr_protected: &[bool],
    result: &mut CampaignResult,
) {
    let layer = batch.info.site.layer;
    if batch.trials.is_empty() {
        return;
    }
    // control-path plans corrupt the shared schedule bookkeeping (fill
    // redirection + drain counters), which the SoA lane meshes do not
    // model — batches carrying one fall back to per-trial cycle-resume,
    // the same fallback shape as the HDFIT/SoC backends. Per-batch
    // gating keeps the fallback worker-count invariant (batches are the
    // sharding unit).
    let has_ctrl = (0..batch.trials.len()).any(|i| rtl_trial(batch, i).plan.has_control());
    let lockstep = tile_engine == TileEngine::LaneLockstep
        && scope == OffloadScope::SingleTile
        && backend.supports_lane_lockstep()
        && !has_ctrl;
    let packed = tile_engine == TileEngine::PackedLockstep
        && scope == OffloadScope::SingleTile
        && backend.supports_lane_lockstep()
        && !has_ctrl;
    let resumable = matches!(
        tile_engine,
        TileEngine::CycleResume | TileEngine::LaneLockstep | TileEngine::PackedLockstep
    ) && scope == OffloadScope::SingleTile
        && backend.supports_cycle_resume();
    let mut order: Vec<usize> = (0..batch.trials.len()).collect();
    if resumable {
        order.sort_by_key(|&i| {
            let t = rtl_trial(batch, i);
            (t.tile_i, t.tile_j, backend.first_effect_cycle(&t.plan))
        });
    }
    let mut runner =
        CrossLayerRunner::with_engine(rtl_trial(batch, order[0]), backend, scope, tile_engine);
    runner.lane_capacity = lanes;
    runner.hardening = hardening;
    runner.tmr_protected = tmr_protected.to_vec();
    if lockstep || packed {
        // form the maximal same-tile runs of the sorted order, <= lanes
        // trials each — the lockstep chunks, and the packer's atoms
        let mut runs: Vec<(usize, usize)> = Vec::new();
        let mut start = 0;
        while start < order.len() {
            let key = rtl_trial(batch, order[start]).tile_key();
            let mut end = start + 1;
            while end < order.len()
                && end - start < lanes
                && rtl_trial(batch, order[end]).tile_key() == key
            {
                end += 1;
            }
            runs.push((start, end));
            start = end;
        }
        let mut ri = 0;
        while ri < runs.len() {
            // packed: greedily pack whole consecutive runs while the
            // lane total fits; lockstep: exactly one run per chunk
            let mut rj = ri + 1;
            if packed {
                let mut total = runs[ri].1 - runs[ri].0;
                while rj < runs.len() && total + (runs[rj].1 - runs[rj].0) <= lanes {
                    total += runs[rj].1 - runs[rj].0;
                    rj += 1;
                }
                runner.begin_packed_chunk(
                    runs[ri..rj]
                        .iter()
                        .map(|&(s, e)| {
                            let t0 = rtl_trial(batch, order[s]);
                            PackedGroup {
                                tile_i: t0.tile_i,
                                tile_j: t0.tile_j,
                                plans: order[s..e]
                                    .iter()
                                    .map(|&i| &rtl_trial(batch, i).plan)
                                    .collect(),
                            }
                        })
                        .collect(),
                );
            } else {
                runner.begin_chunk(
                    order[runs[ri].0..runs[ri].1]
                        .iter()
                        .map(|&i| &rtl_trial(batch, i).plan)
                        .collect(),
                );
            }
            let (cs, ce) = (runs[ri].0, runs[rj - 1].1);
            for (lane, &i) in order[cs..ce].iter().enumerate() {
                runner.arm_lane(rtl_trial(batch, i), lane);
                runner.backend.reset();
                record(result, layer, run_rtl_trial(model, plan, &mut runner, engine));
            }
            ri = rj;
        }
    } else {
        if resumable {
            // one cold reset per batch: the SoC's resume cursor lives
            // inside the chip, so resetting per trial would re-stage
            // every tile — and a stale cursor from another batch could
            // collide on the (tile_i, tile_j) key. Batches are the
            // sharding unit, so per-batch resets keep cycle accounting
            // worker-count invariant. (No-op for the mesh backends.)
            runner.backend.reset();
        }
        for (idx, &i) in order.iter().enumerate() {
            if idx > 0 {
                runner.arm(rtl_trial(batch, i));
            }
            if !resumable {
                runner.backend.reset();
            }
            record(result, layer, run_rtl_trial(model, plan, &mut runner, engine));
        }
    }
    result.rtl_cycles_stepped += runner.rtl_cycles;
    result.lane_cycles_filled += runner.lane_cycles_filled;
    result.lane_cycles_stepped += runner.lane_cycles_stepped;
}

fn rtl_trial(batch: &SiteBatch, i: usize) -> &TrialFault {
    match &batch.trials[i] {
        PlannedTrial::Rtl(t) => t,
        PlannedTrial::Sw(_) => unreachable!("SW trial routed to an RTL backend"),
    }
}

/// The armed trial's mitigation verdict, from the runner's splice-seam
/// flags plus the trial-level SDC logit detector. `None` for unstruck
/// trials and for `--hardening none` campaigns (the coverage metrics
/// count struck trials only).
fn mit_verdict(
    runner: &CrossLayerRunner<'_>,
    h: &HardeningConfig,
    sdc_detected: bool,
) -> Option<MitVerdict> {
    if h.is_none() || !runner.mit_struck {
        return None;
    }
    Some(if runner.mit_corrected {
        MitVerdict::Corrected
    } else if runner.mit_detected || sdc_detected {
        MitVerdict::Detected
    } else {
        MitVerdict::Escaped
    })
}

fn run_rtl_trial(
    model: &Model,
    plan: &InputPlan,
    runner: &mut CrossLayerRunner<'_>,
    engine: TrialEngine,
) -> (TrialOutcome, Option<MitVerdict>) {
    let h = runner.hardening;
    match engine {
        TrialEngine::FullForward => {
            let logits = model.forward(&plan.x, Some(&mut *runner));
            debug_assert!(runner.hit, "trial site not reached: [{}]", runner.trial);
            let sdc = h.detect && logits != plan.golden_logits;
            let outcome = classify(runner.exposed, argmax(&logits.data) != plan.golden_top1);
            (outcome, mit_verdict(runner, &h, sdc))
        }
        TrialEngine::SiteResume => {
            let li = runner.trial.site.layer;
            let ckpt = plan
                .ckpt
                .as_ref()
                .expect("site-resume plan carries checkpoints");
            // phase 1: replay only the faulty layer from its checkpoint
            let act =
                model.forward_layers(li, li + 1, ckpt.at(li).clone(), Some(&mut *runner));
            debug_assert!(runner.hit, "trial site not reached: [{}]", runner.trial);
            if !runner.exposed {
                // The splice change-flag says the fault never escaped
                // the array (or mitigation restored it): the layer
                // output is bit-identical to the golden pass, so the
                // downstream recompute is skipped entirely (logits :=
                // golden logits — the SDC detector has nothing to flag).
                return (TrialOutcome::Masked, mit_verdict(runner, &h, false));
            }
            // phase 2: only the downstream layers run, hook-free
            let logits = model.resume_logits(li + 1, act, None);
            let sdc = h.detect && logits != plan.golden_logits;
            let outcome = classify(true, argmax(&logits.data) != plan.golden_top1);
            (outcome, mit_verdict(runner, &h, sdc))
        }
    }
}

fn run_sw_trial(
    model: &Model,
    plan: &InputPlan,
    sw_plan: &SwPlan,
    engine: TrialEngine,
) -> TrialOutcome {
    let mut inj = SwInjector::new(sw_plan);
    let logits = match (engine, &plan.ckpt) {
        (TrialEngine::SiteResume, Some(ckpt)) => {
            // every target applies at or after the plan's earliest
            // target layer: resume there
            model.forward_from(sw_plan.resume_layer(), ckpt, Some(&mut inj))
        }
        _ => model.forward(&plan.x, Some(&mut inj)),
    };
    let corrupted = logits != plan.golden_logits;
    classify(corrupted, argmax(&logits.data) != plan.golden_top1)
}

/// Run the trials of a single input index with its own derived RNG
/// stream — the coarse unit of work the coordinator distributes (the
/// fine unit is one [`SiteBatch`] of an [`InputPlan`]). Worker-count
/// invariant: results depend only on `(seed, input_idx)`.
pub fn run_input(
    model: &Model,
    mesh_cfg: &MeshConfig,
    cfg: &CampaignConfig,
    input_idx: u64,
) -> Result<CampaignResult> {
    let mut one = cfg.clone();
    one.inputs = 1;
    one.seed = derived_input_seed(cfg.seed, input_idx);
    run_campaign(model, mesh_cfg, &one)
}

/// Reject backend/dataflow combinations the simulators cannot execute.
/// Since the SoC controller became schedule-indexable (ROADMAP
/// "Schedule-indexable SoC"), every backend runs both dataflows and
/// this accepts every combination — it is kept as the config-level seam
/// where a future backend would surface its gaps as a clear error
/// rather than a silent dataflow override.
pub fn validate_dataflow_support(_mesh_cfg: &MeshConfig, _cfg: &CampaignConfig) -> Result<()> {
    Ok(())
}

/// Run a full campaign for `model` with the given configuration.
pub fn run_campaign(
    model: &Model,
    mesh_cfg: &MeshConfig,
    cfg: &CampaignConfig,
) -> Result<CampaignResult> {
    validate_dataflow_support(mesh_cfg, cfg)?;
    let kinds = signal_kinds(cfg);
    // site list computed once per campaign and borrowed from here on
    let sites = campaign_sites(model);
    let mut rng = Rng::new(cfg.seed);
    let mut result =
        CampaignResult::empty(&model.name, cfg.backend, cfg.scenario, mesh_cfg.dataflow);
    let mut exec = TrialExecutor::new(mesh_cfg, cfg);

    let t0 = Instant::now();
    for _input in 0..cfg.inputs {
        let plan = plan_one(model, cfg, &sites, &kinds, mesh_cfg, &mut rng);
        for batch in &plan.batches {
            exec.run_batch(model, &plan, batch, &mut result);
        }
    }
    result.wall = t0.elapsed();
    Ok(result)
}

fn classify(exposed: bool, critical: bool) -> TrialOutcome {
    if critical {
        TrialOutcome::Critical
    } else if exposed {
        TrialOutcome::Exposed
    } else {
        TrialOutcome::Masked
    }
}

fn record(
    result: &mut CampaignResult,
    layer: usize,
    (outcome, verdict): (TrialOutcome, Option<MitVerdict>),
) {
    let critical = outcome == TrialOutcome::Critical;
    result.vuln.record(critical);
    result.per_layer.entry(layer).or_default().record(critical);
    match outcome {
        TrialOutcome::Masked => result.masked_trials += 1,
        TrialOutcome::Exposed => result.exposed_trials += 1,
        TrialOutcome::Critical => {}
    }
    match verdict {
        Some(MitVerdict::Detected) => result.detected_trials += 1,
        Some(MitVerdict::Corrected) => result.corrected_trials += 1,
        Some(MitVerdict::Escaped) => result.escaped_trials += 1,
        None => {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::models;

    fn small_cfg(backend: Backend) -> (MeshConfig, CampaignConfig) {
        (
            MeshConfig::default(),
            CampaignConfig {
                seed: 99,
                faults_per_layer: 4,
                inputs: 2,
                backend,
                offload_scope: OffloadScope::SingleTile,
                engine: TrialEngine::SiteResume,
                tile_engine: TileEngine::CycleResume,
                lanes: 8,
                signals: vec![],
                scenario: Scenario::Seu,
                hardening: HardeningConfig::default(),
                workers: 1,
            },
        )
    }

    #[test]
    fn enforsa_campaign_runs_and_counts() {
        let model = models::quicknet(5);
        let (mesh_cfg, cfg) = small_cfg(Backend::EnforSa);
        let r = run_campaign(&model, &mesh_cfg, &cfg).unwrap();
        // 5 GEMM sites x 4 faults x 2 inputs
        assert_eq!(r.vuln.trials, 40);
        assert_eq!(
            r.vuln.trials,
            r.masked_trials + r.exposed_trials + r.vuln.critical
        );
        assert_eq!(r.per_layer.len(), 5);
    }

    #[test]
    fn sw_campaign_runs() {
        let model = models::quicknet(5);
        let (mesh_cfg, cfg) = small_cfg(Backend::SwOnly);
        let r = run_campaign(&model, &mesh_cfg, &cfg).unwrap();
        assert_eq!(r.vuln.trials, 40);
    }

    #[test]
    fn campaign_is_reproducible_from_seed() {
        let model = models::quicknet(5);
        let (mesh_cfg, cfg) = small_cfg(Backend::EnforSa);
        let a = run_campaign(&model, &mesh_cfg, &cfg).unwrap();
        let b = run_campaign(&model, &mesh_cfg, &cfg).unwrap();
        assert_eq!(a.vuln.critical, b.vuln.critical);
        assert_eq!(a.exposed_trials, b.exposed_trials);
    }

    #[test]
    fn control_only_campaign_respects_filter() {
        let model = models::quicknet(5);
        let (mesh_cfg, mut cfg) = small_cfg(Backend::EnforSa);
        cfg.signals = vec!["propag".into(), "valid".into()];
        let r = run_campaign(&model, &mesh_cfg, &cfg).unwrap();
        assert_eq!(r.vuln.trials, 40);
    }

    #[test]
    fn site_resume_matches_full_forward_oracle() {
        // the acceptance invariant: both engines produce bit-identical
        // campaign results for a fixed seed
        let model = models::quicknet(5);
        for backend in [Backend::EnforSa, Backend::SwOnly] {
            let (mesh_cfg, mut cfg) = small_cfg(backend);
            cfg.engine = TrialEngine::SiteResume;
            let a = run_campaign(&model, &mesh_cfg, &cfg).unwrap();
            cfg.engine = TrialEngine::FullForward;
            let b = run_campaign(&model, &mesh_cfg, &cfg).unwrap();
            assert_eq!(a.vuln.trials, b.vuln.trials, "{backend}");
            assert_eq!(a.vuln.critical, b.vuln.critical, "{backend}");
            assert_eq!(a.exposed_trials, b.exposed_trials, "{backend}");
            assert_eq!(a.masked_trials, b.masked_trials, "{backend}");
        }
    }

    #[test]
    fn every_scenario_campaign_runs_and_partitions_outcomes() {
        let model = models::quicknet(5);
        for scenario in [
            Scenario::Seu,
            Scenario::Mbu { bits: 2 },
            Scenario::Burst { radius: 1 },
            Scenario::DoubleSeu,
            Scenario::StuckAt { value: true },
        ] {
            for backend in [Backend::EnforSa, Backend::SwOnly] {
                let (mesh_cfg, mut cfg) = small_cfg(backend);
                cfg.scenario = scenario;
                let r = run_campaign(&model, &mesh_cfg, &cfg).unwrap();
                assert_eq!(r.scenario, scenario);
                assert_eq!(r.vuln.trials, 40, "{scenario}/{backend}");
                assert_eq!(
                    r.vuln.trials,
                    r.masked_trials + r.exposed_trials + r.vuln.critical,
                    "{scenario}/{backend}: outcomes must partition trials"
                );
            }
        }
    }

    #[test]
    fn scenario_campaigns_agree_across_trial_engines() {
        // the engine-oracle invariant holds for every scenario, not
        // just the paper's single-SEU model
        let model = models::quicknet(5);
        for scenario in [
            Scenario::Mbu { bits: 2 },
            Scenario::Burst { radius: 1 },
            Scenario::DoubleSeu,
            Scenario::StuckAt { value: false },
        ] {
            let (mesh_cfg, mut cfg) = small_cfg(Backend::EnforSa);
            cfg.scenario = scenario;
            cfg.engine = TrialEngine::SiteResume;
            let a = run_campaign(&model, &mesh_cfg, &cfg).unwrap();
            cfg.engine = TrialEngine::FullForward;
            let b = run_campaign(&model, &mesh_cfg, &cfg).unwrap();
            assert_eq!(a.vuln.critical, b.vuln.critical, "{scenario}");
            assert_eq!(a.exposed_trials, b.exposed_trials, "{scenario}");
            assert_eq!(a.masked_trials, b.masked_trials, "{scenario}");
        }
    }

    #[test]
    fn tile_engines_agree_and_cycle_resume_steps_fewer() {
        // the cycle-resume acceptance pin: bit-identical counts, strictly
        // fewer RTL cycles. faults_per_layer=16 pigeonholes trials onto
        // shared tiles (the Linear site has a 1x2 tile grid), so the
        // golden-prefix saving is structural, not a seed accident.
        let model = models::quicknet(5);
        let (mesh_cfg, mut cfg) = small_cfg(Backend::EnforSa);
        cfg.faults_per_layer = 16;
        cfg.inputs = 1;
        cfg.tile_engine = TileEngine::CycleResume;
        let a = run_campaign(&model, &mesh_cfg, &cfg).unwrap();
        cfg.tile_engine = TileEngine::Full;
        let b = run_campaign(&model, &mesh_cfg, &cfg).unwrap();
        assert_eq!(a.vuln.trials, b.vuln.trials);
        assert_eq!(a.vuln.critical, b.vuln.critical);
        assert_eq!(a.exposed_trials, b.exposed_trials);
        assert_eq!(a.masked_trials, b.masked_trials);
        assert!(a.rtl_cycles_stepped > 0 && b.rtl_cycles_stepped > 0);
        assert!(
            a.rtl_cycles_stepped < b.rtl_cycles_stepped,
            "cycle-resume must step fewer RTL cycles: {} vs {}",
            a.rtl_cycles_stepped,
            b.rtl_cycles_stepped
        );
    }

    #[test]
    fn lane_lockstep_agrees_and_steps_fewer_than_cycle_resume() {
        // the lockstep acceptance pin: bit-identical counts for any lane
        // count, strictly fewer RTL cycles than cycle-resume (which is
        // itself strictly fewer than full). faults_per_layer=16 puts >= 2
        // trials on shared tiles, so every multi-trial chunk pays its
        // suffix once instead of once per trial.
        let model = models::quicknet(5);
        let (mesh_cfg, mut cfg) = small_cfg(Backend::EnforSa);
        cfg.faults_per_layer = 16;
        cfg.inputs = 1;
        cfg.tile_engine = TileEngine::Full;
        let full = run_campaign(&model, &mesh_cfg, &cfg).unwrap();
        cfg.tile_engine = TileEngine::CycleResume;
        let resume = run_campaign(&model, &mesh_cfg, &cfg).unwrap();
        cfg.tile_engine = TileEngine::LaneLockstep;
        let lock = run_campaign(&model, &mesh_cfg, &cfg).unwrap();
        for (r, label) in [(&resume, "cycle-resume"), (&lock, "lane-lockstep")] {
            assert_eq!(r.vuln.trials, full.vuln.trials, "{label}");
            assert_eq!(r.vuln.critical, full.vuln.critical, "{label}");
            assert_eq!(r.exposed_trials, full.exposed_trials, "{label}");
            assert_eq!(r.masked_trials, full.masked_trials, "{label}");
        }
        assert!(
            lock.rtl_cycles_stepped < resume.rtl_cycles_stepped
                && resume.rtl_cycles_stepped < full.rtl_cycles_stepped,
            "expected lockstep < cycle-resume < full: {} vs {} vs {}",
            lock.rtl_cycles_stepped,
            resume.rtl_cycles_stepped,
            full.rtl_cycles_stepped
        );
        // a single-lane lockstep campaign degenerates to cycle-resume
        // exactly, cycle counts included
        cfg.lanes = 1;
        let one = run_campaign(&model, &mesh_cfg, &cfg).unwrap();
        assert_eq!(one.vuln.critical, resume.vuln.critical);
        assert_eq!(one.exposed_trials, resume.exposed_trials);
        assert_eq!(one.rtl_cycles_stepped, resume.rtl_cycles_stepped);
    }

    #[test]
    fn packed_lockstep_agrees_and_steps_fewer_than_lane_lockstep() {
        // the packed acceptance pin: bit-identical counts, strictly
        // fewer RTL cycles than same-tile lockstep, and strictly better
        // lane occupancy. lanes=16 with faults_per_layer=16 lets the
        // packer merge every batch's runs into one chunk, so any batch
        // whose trials touch >= 2 tiles (the Linear site has a 1x2
        // grid) pays max(span) instead of sum(span).
        let model = models::quicknet(5);
        let (mesh_cfg, mut cfg) = small_cfg(Backend::EnforSa);
        cfg.faults_per_layer = 16;
        cfg.inputs = 1;
        cfg.lanes = 16;
        cfg.tile_engine = TileEngine::Full;
        let full = run_campaign(&model, &mesh_cfg, &cfg).unwrap();
        cfg.tile_engine = TileEngine::LaneLockstep;
        let lock = run_campaign(&model, &mesh_cfg, &cfg).unwrap();
        cfg.tile_engine = TileEngine::PackedLockstep;
        let packed = run_campaign(&model, &mesh_cfg, &cfg).unwrap();
        for (r, label) in [(&lock, "lane-lockstep"), (&packed, "packed-lockstep")] {
            assert_eq!(r.vuln.trials, full.vuln.trials, "{label}");
            assert_eq!(r.vuln.critical, full.vuln.critical, "{label}");
            assert_eq!(r.exposed_trials, full.exposed_trials, "{label}");
            assert_eq!(r.masked_trials, full.masked_trials, "{label}");
        }
        assert!(
            packed.rtl_cycles_stepped < lock.rtl_cycles_stepped,
            "packed must step fewer RTL cycles than lockstep: {} vs {}",
            packed.rtl_cycles_stepped,
            lock.rtl_cycles_stepped
        );
        assert!(
            packed.lane_occupancy() > lock.lane_occupancy(),
            "packed must fill lanes better than lockstep: {} vs {}",
            packed.lane_occupancy(),
            lock.lane_occupancy()
        );
        // a single-lane packed campaign degenerates to cycle-resume
        // exactly, cycle counts included
        cfg.tile_engine = TileEngine::CycleResume;
        let resume = run_campaign(&model, &mesh_cfg, &cfg).unwrap();
        cfg.tile_engine = TileEngine::PackedLockstep;
        cfg.lanes = 1;
        let one = run_campaign(&model, &mesh_cfg, &cfg).unwrap();
        assert_eq!(one.vuln.critical, resume.vuln.critical);
        assert_eq!(one.exposed_trials, resume.exposed_trials);
        assert_eq!(one.rtl_cycles_stepped, resume.rtl_cycles_stepped);
    }

    #[test]
    fn hdfit_lane_lockstep_falls_back_to_cycle_resume() {
        // HDFIT's instrumented kernels hook one mesh instance, so it
        // rejects lane batching; both lane-batched gates must degrade
        // to cycle-resume with identical counts AND identical cycle
        // accounting.
        let model = models::quicknet(5);
        for engine in [TileEngine::LaneLockstep, TileEngine::PackedLockstep] {
            let (mesh_cfg, mut cfg) = small_cfg(Backend::Hdfit);
            cfg.tile_engine = engine;
            let a = run_campaign(&model, &mesh_cfg, &cfg).unwrap();
            cfg.tile_engine = TileEngine::CycleResume;
            let b = run_campaign(&model, &mesh_cfg, &cfg).unwrap();
            assert_eq!(a.vuln.trials, b.vuln.trials, "{engine}");
            assert_eq!(a.vuln.critical, b.vuln.critical, "{engine}");
            assert_eq!(a.exposed_trials, b.exposed_trials, "{engine}");
            assert_eq!(a.masked_trials, b.masked_trials, "{engine}");
            assert_eq!(a.rtl_cycles_stepped, b.rtl_cycles_stepped, "{engine}");
        }
    }

    fn ws_mesh_cfg() -> MeshConfig {
        MeshConfig {
            dataflow: Dataflow::WeightStationary,
            ..Default::default()
        }
    }

    #[test]
    fn ws_campaign_runs_and_counts_on_mesh_backends() {
        let model = models::quicknet(5);
        for backend in [Backend::EnforSa, Backend::Hdfit] {
            let (_, cfg) = small_cfg(backend);
            let r = run_campaign(&model, &ws_mesh_cfg(), &cfg).unwrap();
            assert_eq!(r.dataflow, Dataflow::WeightStationary);
            assert_eq!(r.vuln.trials, 40, "{backend}");
            assert_eq!(
                r.vuln.trials,
                r.masked_trials + r.exposed_trials + r.vuln.critical,
                "{backend}: outcomes must partition trials"
            );
            assert_eq!(r.per_layer.len(), 5);
            assert!(r.rtl_cycles_stepped > 0);
        }
    }

    #[test]
    fn ws_site_resume_matches_full_forward_oracle() {
        let model = models::quicknet(5);
        let (_, mut cfg) = small_cfg(Backend::EnforSa);
        cfg.engine = TrialEngine::SiteResume;
        let a = run_campaign(&model, &ws_mesh_cfg(), &cfg).unwrap();
        cfg.engine = TrialEngine::FullForward;
        let b = run_campaign(&model, &ws_mesh_cfg(), &cfg).unwrap();
        assert_eq!(a.vuln.trials, b.vuln.trials);
        assert_eq!(a.vuln.critical, b.vuln.critical);
        assert_eq!(a.exposed_trials, b.exposed_trials);
        assert_eq!(a.masked_trials, b.masked_trials);
    }

    #[test]
    fn ws_tile_engines_agree_and_cycle_resume_steps_fewer() {
        // the WS mirror of the cycle-resume acceptance pin: bit-identical
        // counts, strictly fewer RTL cycles. faults_per_layer=16
        // pigeonholes conv1's (K=27, N=16) -> 4x2 = 8 weight tiles.
        let model = models::quicknet(5);
        let (_, mut cfg) = small_cfg(Backend::EnforSa);
        cfg.faults_per_layer = 16;
        cfg.inputs = 1;
        cfg.tile_engine = TileEngine::CycleResume;
        let a = run_campaign(&model, &ws_mesh_cfg(), &cfg).unwrap();
        cfg.tile_engine = TileEngine::Full;
        let b = run_campaign(&model, &ws_mesh_cfg(), &cfg).unwrap();
        assert_eq!(a.vuln.trials, b.vuln.trials);
        assert_eq!(a.vuln.critical, b.vuln.critical);
        assert_eq!(a.exposed_trials, b.exposed_trials);
        assert_eq!(a.masked_trials, b.masked_trials);
        assert!(a.rtl_cycles_stepped > 0 && b.rtl_cycles_stepped > 0);
        assert!(
            a.rtl_cycles_stepped < b.rtl_cycles_stepped,
            "WS cycle-resume must step fewer RTL cycles: {} vs {}",
            a.rtl_cycles_stepped,
            b.rtl_cycles_stepped
        );
    }

    #[test]
    fn ws_lane_lockstep_agrees_and_steps_fewer_than_cycle_resume() {
        // the WS mirror of the lockstep acceptance pin
        let model = models::quicknet(5);
        let (_, mut cfg) = small_cfg(Backend::EnforSa);
        cfg.faults_per_layer = 16;
        cfg.inputs = 1;
        cfg.tile_engine = TileEngine::CycleResume;
        let resume = run_campaign(&model, &ws_mesh_cfg(), &cfg).unwrap();
        cfg.tile_engine = TileEngine::LaneLockstep;
        let lock = run_campaign(&model, &ws_mesh_cfg(), &cfg).unwrap();
        assert_eq!(lock.vuln.trials, resume.vuln.trials);
        assert_eq!(lock.vuln.critical, resume.vuln.critical);
        assert_eq!(lock.exposed_trials, resume.exposed_trials);
        assert_eq!(lock.masked_trials, resume.masked_trials);
        assert!(
            lock.rtl_cycles_stepped < resume.rtl_cycles_stepped,
            "WS lockstep must step fewer RTL cycles: {} vs {}",
            lock.rtl_cycles_stepped,
            resume.rtl_cycles_stepped
        );
    }

    #[test]
    fn ws_packed_lockstep_agrees_and_steps_fewer_than_lane_lockstep() {
        // the WS mirror of the packed acceptance pin: per-group prefix
        // psums and pass goldens must reproduce lockstep's counts while
        // cross-tile chunks retire the shorter schedules early
        let model = models::quicknet(5);
        let (_, mut cfg) = small_cfg(Backend::EnforSa);
        cfg.faults_per_layer = 16;
        cfg.inputs = 1;
        cfg.lanes = 16;
        cfg.tile_engine = TileEngine::LaneLockstep;
        let lock = run_campaign(&model, &ws_mesh_cfg(), &cfg).unwrap();
        cfg.tile_engine = TileEngine::PackedLockstep;
        let packed = run_campaign(&model, &ws_mesh_cfg(), &cfg).unwrap();
        assert_eq!(packed.vuln.trials, lock.vuln.trials);
        assert_eq!(packed.vuln.critical, lock.vuln.critical);
        assert_eq!(packed.exposed_trials, lock.exposed_trials);
        assert_eq!(packed.masked_trials, lock.masked_trials);
        assert!(
            packed.rtl_cycles_stepped < lock.rtl_cycles_stepped,
            "WS packed must step fewer RTL cycles: {} vs {}",
            packed.rtl_cycles_stepped,
            lock.rtl_cycles_stepped
        );
        assert!(
            packed.lane_occupancy() > lock.lane_occupancy(),
            "WS packed must fill lanes better: {} vs {}",
            packed.lane_occupancy(),
            lock.lane_occupancy()
        );
    }

    #[test]
    fn ws_full_soc_campaign_runs_and_counts() {
        // WS + FullSoc used to be a config-level error; the
        // schedule-indexable controller executes it end-to-end now
        let model = models::quicknet(5);
        let (_, mut cfg) = small_cfg(Backend::FullSoc);
        let mesh_cfg = MeshConfig { dim: 4, dataflow: Dataflow::WeightStationary };
        cfg.faults_per_layer = 2;
        cfg.inputs = 1;
        let r = run_campaign(&model, &mesh_cfg, &cfg).unwrap();
        assert_eq!(r.dataflow, Dataflow::WeightStationary);
        assert_eq!(r.vuln.trials, 10);
        assert_eq!(
            r.vuln.trials,
            r.masked_trials + r.exposed_trials + r.vuln.critical,
            "outcomes must partition trials"
        );
        assert!(r.rtl_cycles_stepped > 0);
    }

    #[test]
    fn full_soc_tile_engines_agree_and_cycle_resume_steps_fewer() {
        // the FullSoc cycle-resume acceptance pin, both dataflows:
        // bit-identical counts, strictly fewer SoC cycles once trials
        // pigeonhole onto shared tiles (faults_per_layer=8 on dim=4)
        let model = models::quicknet(5);
        for dataflow in [Dataflow::OutputStationary, Dataflow::WeightStationary] {
            let (_, mut cfg) = small_cfg(Backend::FullSoc);
            let mesh_cfg = MeshConfig { dim: 4, dataflow };
            cfg.faults_per_layer = 8;
            cfg.inputs = 1;
            cfg.tile_engine = TileEngine::CycleResume;
            let a = run_campaign(&model, &mesh_cfg, &cfg).unwrap();
            cfg.tile_engine = TileEngine::Full;
            let b = run_campaign(&model, &mesh_cfg, &cfg).unwrap();
            assert_eq!(a.vuln.trials, b.vuln.trials, "{dataflow}");
            assert_eq!(a.vuln.critical, b.vuln.critical, "{dataflow}");
            assert_eq!(a.exposed_trials, b.exposed_trials, "{dataflow}");
            assert_eq!(a.masked_trials, b.masked_trials, "{dataflow}");
            assert!(a.rtl_cycles_stepped > 0 && b.rtl_cycles_stepped > 0);
            assert!(
                a.rtl_cycles_stepped < b.rtl_cycles_stepped,
                "{dataflow}: SoC cycle-resume must step fewer cycles: {} vs {}",
                a.rtl_cycles_stepped,
                b.rtl_cycles_stepped
            );
        }
    }

    #[test]
    fn full_soc_lane_lockstep_falls_back_to_cycle_resume() {
        // one persistent chip cannot carry N lanes; both lane-batched
        // gates must degrade to cycle-resume with identical counts AND
        // identical cycle accounting, both dataflows
        let model = models::quicknet(5);
        for dataflow in [Dataflow::OutputStationary, Dataflow::WeightStationary] {
            for engine in [TileEngine::LaneLockstep, TileEngine::PackedLockstep] {
                let (_, mut cfg) = small_cfg(Backend::FullSoc);
                let mesh_cfg = MeshConfig { dim: 4, dataflow };
                cfg.faults_per_layer = 2;
                cfg.inputs = 1;
                cfg.tile_engine = engine;
                let a = run_campaign(&model, &mesh_cfg, &cfg).unwrap();
                cfg.tile_engine = TileEngine::CycleResume;
                let b = run_campaign(&model, &mesh_cfg, &cfg).unwrap();
                assert_eq!(a.vuln.trials, b.vuln.trials, "{dataflow}/{engine}");
                assert_eq!(a.vuln.critical, b.vuln.critical, "{dataflow}/{engine}");
                assert_eq!(a.exposed_trials, b.exposed_trials, "{dataflow}/{engine}");
                assert_eq!(a.masked_trials, b.masked_trials, "{dataflow}/{engine}");
                assert_eq!(a.rtl_cycles_stepped, b.rtl_cycles_stepped, "{dataflow}/{engine}");
            }
        }
    }

    #[test]
    fn hardened_campaign_partitions_verdicts_against_the_none_baseline() {
        // verdicts are disjoint per struck trial and the struck count
        // equals the same seed's exposed+critical under `none` (the
        // pre-mitigation region compare is identical); corrected trials
        // re-classify as masked, one for one
        let model = models::quicknet(5);
        let (mesh_cfg, mut cfg) = small_cfg(Backend::EnforSa);
        let none = run_campaign(&model, &mesh_cfg, &cfg).unwrap();
        assert_eq!(none.struck_trials(), 0, "none-mode campaigns carry no verdicts");
        assert_eq!(none.detection_coverage(), 0.0);

        cfg.hardening = HardeningConfig::parse("abft+detect").expect("valid hardening");
        let hard = run_campaign(&model, &mesh_cfg, &cfg).unwrap();
        assert_eq!(hard.vuln.trials, none.vuln.trials);
        assert_eq!(
            hard.struck_trials(),
            none.exposed_trials + none.vuln.critical,
            "struck trials = the none-baseline's escaped-the-array trials"
        );
        assert!(hard.corrected_trials > 0, "ABFT corrects single-element SEUs");
        assert!(hard.detection_coverage() > 0.0);
        assert!(hard.correction_coverage() <= hard.detection_coverage());
        assert_eq!(
            hard.masked_trials,
            none.masked_trials + hard.corrected_trials,
            "every corrected trial re-classifies as masked"
        );
    }

    #[test]
    fn full_width_tmr_corrects_every_strike() {
        // protecting all dim columns triplicates the whole array: every
        // struck trial is voted back to golden, so the campaign reports
        // full correction coverage and zero criticals
        let model = models::quicknet(5);
        let (mesh_cfg, mut cfg) = small_cfg(Backend::EnforSa);
        cfg.hardening = HardeningConfig::parse("tmr:8").expect("valid hardening");
        let r = run_campaign(&model, &mesh_cfg, &cfg).unwrap();
        assert!(r.struck_trials() > 0, "some trials must strike");
        assert_eq!(r.corrected_trials, r.struck_trials());
        assert_eq!(r.correction_coverage(), 1.0);
        assert_eq!(r.vuln.critical, 0);
        assert_eq!(r.masked_trials, r.vuln.trials);
    }

    #[test]
    fn hardened_campaigns_agree_across_tile_engines() {
        // the engine-agreement invariant extends to the hardening axis:
        // verdict counters are bit-identical across all four engines
        let model = models::quicknet(5);
        let (mesh_cfg, mut cfg) = small_cfg(Backend::EnforSa);
        cfg.faults_per_layer = 16;
        cfg.inputs = 1;
        cfg.hardening = HardeningConfig::parse("clip:-65536,65535+abft+detect")
            .expect("valid hardening");
        cfg.tile_engine = TileEngine::Full;
        let full = run_campaign(&model, &mesh_cfg, &cfg).unwrap();
        for engine in [
            TileEngine::CycleResume,
            TileEngine::LaneLockstep,
            TileEngine::PackedLockstep,
        ] {
            cfg.tile_engine = engine;
            let r = run_campaign(&model, &mesh_cfg, &cfg).unwrap();
            assert_eq!(r.vuln.critical, full.vuln.critical, "{engine}");
            assert_eq!(r.exposed_trials, full.exposed_trials, "{engine}");
            assert_eq!(r.masked_trials, full.masked_trials, "{engine}");
            assert_eq!(r.detected_trials, full.detected_trials, "{engine}");
            assert_eq!(r.corrected_trials, full.corrected_trials, "{engine}");
            assert_eq!(r.escaped_trials, full.escaped_trials, "{engine}");
        }
    }

    #[test]
    fn control_fault_campaign_runs_and_lane_engines_fall_back() {
        // the control-path fault target: campaigns restricted to the
        // sequencer/drain-FSM kind execute on every engine, and the
        // lane-batched engines fall back to per-trial cycle-resume
        // (identical counts AND identical cycle accounting) because the
        // SoA lane meshes do not model schedule corruption
        let model = models::quicknet(5);
        let (mesh_cfg, mut cfg) = small_cfg(Backend::EnforSa);
        cfg.signals = vec!["control".into()];
        cfg.tile_engine = TileEngine::Full;
        let full = run_campaign(&model, &mesh_cfg, &cfg).unwrap();
        assert_eq!(full.vuln.trials, 40);
        assert_eq!(
            full.vuln.trials,
            full.masked_trials + full.exposed_trials + full.vuln.critical,
            "outcomes must partition trials"
        );
        cfg.tile_engine = TileEngine::CycleResume;
        let resume = run_campaign(&model, &mesh_cfg, &cfg).unwrap();
        assert_eq!(resume.vuln.critical, full.vuln.critical);
        assert_eq!(resume.exposed_trials, full.exposed_trials);
        assert_eq!(resume.masked_trials, full.masked_trials);
        for engine in [TileEngine::LaneLockstep, TileEngine::PackedLockstep] {
            cfg.tile_engine = engine;
            let r = run_campaign(&model, &mesh_cfg, &cfg).unwrap();
            assert_eq!(r.vuln.critical, full.vuln.critical, "{engine}");
            assert_eq!(r.exposed_trials, full.exposed_trials, "{engine}");
            assert_eq!(r.masked_trials, full.masked_trials, "{engine}");
            assert_eq!(
                r.rtl_cycles_stepped, resume.rtl_cycles_stepped,
                "{engine} must fall back to cycle-resume on control batches"
            );
        }
    }

    #[test]
    fn tmr_columns_is_deterministic_and_sized() {
        let mesh_cfg = MeshConfig::default();
        let a = tmr_columns(&mesh_cfg, 2);
        let b = tmr_columns(&mesh_cfg, 2);
        assert_eq!(a, b, "fixed-literal seed: the column set is reproducible");
        assert_eq!(a.len(), mesh_cfg.dim);
        assert_eq!(a.iter().filter(|&&p| p).count(), 2);
        let all = tmr_columns(&mesh_cfg, 64);
        assert!(all.iter().all(|&p| p), "cols clamps to dim");
    }

    #[test]
    fn plan_one_is_deterministic_and_covers_all_sites() {
        let model = models::quicknet(5);
        let (mesh_cfg, cfg) = small_cfg(Backend::EnforSa);
        let sites = campaign_sites(&model);
        let kinds = signal_kinds(&cfg);
        let mut r1 = Rng::new(cfg.seed);
        let mut r2 = Rng::new(cfg.seed);
        let p1 = plan_one(&model, &cfg, &sites, &kinds, &mesh_cfg, &mut r1);
        let p2 = plan_one(&model, &cfg, &sites, &kinds, &mesh_cfg, &mut r2);
        assert_eq!(p1.batches.len(), sites.len());
        assert_eq!(p1.golden_top1, p2.golden_top1);
        assert_eq!(p1.golden_logits, p2.golden_logits);
        for (b1, b2) in p1.batches.iter().zip(&p2.batches) {
            assert_eq!(b1.trials.len() as u64, cfg.faults_per_layer);
            for (t1, t2) in b1.trials.iter().zip(&b2.trials) {
                match (t1, t2) {
                    (PlannedTrial::Rtl(a), PlannedTrial::Rtl(b)) => assert_eq!(a, b),
                    (PlannedTrial::Sw(a), PlannedTrial::Sw(b)) => assert_eq!(a, b),
                    _ => panic!("plan arms diverged"),
                }
            }
        }
        let ckpt = p1.ckpt.expect("site-resume plans carry checkpoints");
        assert_eq!(ckpt.layers(), model.layers.len());
    }
}
