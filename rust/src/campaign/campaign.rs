//! Campaign orchestration: the paper's §IV experiments as a library.
//!
//! A campaign runs `faults_per_layer` trials per GEMM layer per input,
//! classifies each trial (masked / exposed / critical) and accumulates
//! AVF (RTL backends) or PVF (software-only backend) with wall-clock
//! accounting for the Table VI timing comparison.

use super::fault::{sample_trial, TrialFault};
use super::runner::{CrossLayerRunner, TileBackend};
use crate::config::{Backend, CampaignConfig, MeshConfig, OffloadScope};
use crate::dnn::engine::synthetic_input;
use crate::dnn::{argmax, GemmSiteInfo, Model};
use crate::mesh::hdfit::InstrumentedMesh;
use crate::mesh::{Mesh, SignalKind};
use crate::soc::Soc;
use crate::swfi::{sample_output_fault, SwInjector};
use crate::util::stats::VulnEstimate;
use crate::util::Rng;
use anyhow::Result;
use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// Result of one fault-injection trial.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TrialOutcome {
    /// Fault never reached the layer output (HW-masked).
    Masked,
    /// Layer output corrupted but Top-1 unchanged (SW-masked / SDC-safe).
    Exposed,
    /// Top-1 classification flipped vs the golden run.
    Critical,
}

/// Aggregated campaign result for one model on one backend.
#[derive(Clone, Debug)]
pub struct CampaignResult {
    pub model: String,
    pub backend: Backend,
    pub vuln: VulnEstimate,
    pub exposed_trials: u64,
    pub masked_trials: u64,
    pub wall: Duration,
    pub per_layer: BTreeMap<usize, VulnEstimate>,
}

impl CampaignResult {
    /// The vulnerability factor: AVF for RTL backends, PVF for SW-only.
    pub fn vf(&self) -> f64 {
        self.vuln.vf()
    }
}

impl CampaignResult {
    /// Merge a partial (per-input / per-worker) result into this one.
    pub fn merge(&mut self, other: &CampaignResult) {
        self.vuln.merge(&other.vuln);
        self.exposed_trials += other.exposed_trials;
        self.masked_trials += other.masked_trials;
        self.wall += other.wall;
        for (layer, v) in &other.per_layer {
            self.per_layer.entry(*layer).or_default().merge(v);
        }
    }

    pub fn empty(model: &str, backend: Backend) -> CampaignResult {
        CampaignResult {
            model: model.to_string(),
            backend,
            vuln: VulnEstimate::default(),
            exposed_trials: 0,
            masked_trials: 0,
            wall: Duration::ZERO,
            per_layer: BTreeMap::new(),
        }
    }
}

/// Run the trials of a single input index with its own derived RNG
/// stream — the unit of work the coordinator distributes to workers.
/// Worker-count invariant: results depend only on (seed, input_idx).
pub fn run_input(
    model: &Model,
    mesh_cfg: &MeshConfig,
    cfg: &CampaignConfig,
    input_idx: u64,
) -> Result<CampaignResult> {
    let mut one = cfg.clone();
    one.inputs = 1;
    one.seed = cfg.seed ^ (input_idx + 1).wrapping_mul(0x9E3779B97F4A7C15);
    run_campaign(model, mesh_cfg, &one)
}

/// Run a full campaign for `model` with the given configuration.
pub fn run_campaign(
    model: &Model,
    mesh_cfg: &MeshConfig,
    cfg: &CampaignConfig,
) -> Result<CampaignResult> {
    let kinds: Vec<SignalKind> = cfg
        .signals
        .iter()
        .filter_map(|s| SignalKind::parse(s))
        .collect();
    let mut rng = Rng::new(cfg.seed);
    let mut result = CampaignResult {
        model: model.name.clone(),
        backend: cfg.backend,
        vuln: VulnEstimate::default(),
        exposed_trials: 0,
        masked_trials: 0,
        wall: Duration::ZERO,
        per_layer: BTreeMap::new(),
    };
    // persistent backends (reset per matmul by the drivers)
    let mut mesh = Mesh::new(mesh_cfg.dim, mesh_cfg.dataflow);
    let mut hdfit = InstrumentedMesh::new(mesh_cfg.dim);

    let t0 = Instant::now();
    let mut sites: Option<Vec<GemmSiteInfo>> = None;
    for _input in 0..cfg.inputs {
        let x = synthetic_input(&model.input_shape, &mut rng);
        let golden_logits = model.forward(&x, None);
        let golden = argmax(&golden_logits.data);
        let sites =
            sites.get_or_insert_with(|| model.gemm_sites(&x)).clone();
        for info in &sites {
            for _ in 0..cfg.faults_per_layer {
                let outcome = match cfg.backend {
                    Backend::SwOnly => {
                        let target = sample_output_fault(model, &mut rng);
                        let mut inj = SwInjector::new(target);
                        let logits = model.forward(&x, Some(&mut inj));
                        let corrupted = logits != golden_logits;
                        classify(corrupted, argmax(&logits.data) != golden)
                    }
                    Backend::FullSoc => {
                        let trial = sample_trial(
                            info.site, info.m, info.k, info.n, mesh_cfg.dim, &mut rng,
                            &kinds,
                        );
                        // a fresh SoC per trial (the core re-runs its
                        // driver program from reset)
                        run_soc_trial(model, &x, golden, trial, mesh_cfg.dim)?
                    }
                    _ => {
                        let trial = sample_trial(
                            info.site, info.m, info.k, info.n, mesh_cfg.dim, &mut rng,
                            &kinds,
                        );
                        let backend = match cfg.backend {
                            Backend::EnforSa => TileBackend::Mesh(&mut mesh),
                            Backend::Hdfit => TileBackend::Hdfit(&mut hdfit),
                            _ => unreachable!(),
                        };
                        let mut runner =
                            CrossLayerRunner::new(trial, backend, cfg.offload_scope);
                        let logits = model.forward(&x, Some(&mut runner));
                        debug_assert!(runner.hit, "trial site must be reached");
                        classify(runner.exposed, argmax(&logits.data) != golden)
                    }
                };
                record(&mut result, info.site.layer, outcome);
            }
        }
    }
    result.wall = t0.elapsed();
    Ok(result)
}

// The FullSoc arm needs its own flow (the backend owns big state);
// factored out to keep the loop readable.
fn run_soc_trial(
    model: &Model,
    x: &crate::dnn::TensorI8,
    golden: usize,
    trial: TrialFault,
    dim: usize,
) -> Result<TrialOutcome> {
    let mut soc = Soc::new(dim);
    let mut runner = CrossLayerRunner::new(
        trial,
        TileBackend::Soc(&mut soc),
        OffloadScope::SingleTile,
    );
    let logits = model.forward(x, Some(&mut runner));
    Ok(classify(
        runner.exposed,
        argmax(&logits.data) != golden,
    ))
}

fn classify(exposed: bool, critical: bool) -> TrialOutcome {
    if critical {
        TrialOutcome::Critical
    } else if exposed {
        TrialOutcome::Exposed
    } else {
        TrialOutcome::Masked
    }
}

fn record(result: &mut CampaignResult, layer: usize, outcome: TrialOutcome) {
    let critical = outcome == TrialOutcome::Critical;
    result.vuln.record(critical);
    result.per_layer.entry(layer).or_default().record(critical);
    match outcome {
        TrialOutcome::Masked => result.masked_trials += 1,
        TrialOutcome::Exposed => result.exposed_trials += 1,
        TrialOutcome::Critical => {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::models;

    fn small_cfg(backend: Backend) -> (MeshConfig, CampaignConfig) {
        (
            MeshConfig::default(),
            CampaignConfig {
                seed: 99,
                faults_per_layer: 4,
                inputs: 2,
                backend,
                offload_scope: OffloadScope::SingleTile,
                signals: vec![],
                workers: 1,
            },
        )
    }

    #[test]
    fn enforsa_campaign_runs_and_counts() {
        let model = models::quicknet(5);
        let (mesh_cfg, cfg) = small_cfg(Backend::EnforSa);
        let r = run_campaign(&model, &mesh_cfg, &cfg).unwrap();
        // 5 GEMM sites x 4 faults x 2 inputs
        assert_eq!(r.vuln.trials, 40);
        assert_eq!(
            r.vuln.trials,
            r.masked_trials + r.exposed_trials + r.vuln.critical
        );
        assert_eq!(r.per_layer.len(), 5);
    }

    #[test]
    fn sw_campaign_runs() {
        let model = models::quicknet(5);
        let (mesh_cfg, cfg) = small_cfg(Backend::SwOnly);
        let r = run_campaign(&model, &mesh_cfg, &cfg).unwrap();
        assert_eq!(r.vuln.trials, 40);
    }

    #[test]
    fn campaign_is_reproducible_from_seed() {
        let model = models::quicknet(5);
        let (mesh_cfg, cfg) = small_cfg(Backend::EnforSa);
        let a = run_campaign(&model, &mesh_cfg, &cfg).unwrap();
        let b = run_campaign(&model, &mesh_cfg, &cfg).unwrap();
        assert_eq!(a.vuln.critical, b.vuln.critical);
        assert_eq!(a.exposed_trials, b.exposed_trials);
    }

    #[test]
    fn control_only_campaign_respects_filter() {
        let model = models::quicknet(5);
        let (mesh_cfg, mut cfg) = small_cfg(Backend::EnforSa);
        cfg.signals = vec!["propag".into(), "valid".into()];
        let r = run_campaign(&model, &mesh_cfg, &cfg).unwrap();
        assert_eq!(r.vuln.trials, 40);
    }
}
