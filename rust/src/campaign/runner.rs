//! The cross-layer trial runner: software inference with exactly one
//! tile offloaded to an RTL backend (paper Fig. 4).
//!
//! Implemented as a [`GemmHook`]: the forward pass runs on the native
//! software path until the target GEMM site is reached; there, the
//! runner hands the RTL backend a zero-copy, DIM-padded [`MatView`]
//! window into the layer's existing flat operand buffers, executes it
//! with the trial's [`FaultPlan`] armed, and splices the (possibly
//! corrupted) int32 tile back into the layer's accumulator with one
//! strided copy — the rest of the inference continues in software. No
//! per-trial allocation happens on this path (the hot path of the whole
//! Table VI comparison): the native result is computed directly into
//! the layer's reusable accumulator and the RTL tile drains into the
//! runner's persistent scratch.

use super::fault::TrialFault;
use crate::config::{Dataflow, HardeningConfig, OffloadScope, TileEngine};
use crate::dnn::gemm::gemm_i8;
use crate::dnn::layers::{GemmCall, GemmHook};
use crate::mat::{Mat, MatView, MatViewMut};
use crate::mesh::driver::{
    lockstep_resumed, os_matmul_cycles, packed_lockstep_resumed, tile_grid, tiled_matmul_os,
    tiled_matmul_ws_with, ws_matmul_cycles, LaneGroup, MatmulDriver,
};
use crate::mesh::hdfit::InstrumentedMesh;

use crate::mesh::{CycleCursor, DriverScratch, FaultPlan, Injectable, LaneMesh, Mesh, MeshSim};
use crate::soc::Soc;

/// Which simulator executes the offloaded tile.
pub enum TileBackend<'a> {
    /// ENFOR-SA mesh-only RTL.
    Mesh(&'a mut Mesh),
    /// HDFIT-style instrumented mesh-only RTL.
    Hdfit(&'a mut InstrumentedMesh),
    /// Whole-SoC RTL (core drives the matmul).
    Soc(&'a mut Soc),
}

impl<'a> TileBackend<'a> {
    pub fn dim(&self) -> usize {
        match self {
            TileBackend::Mesh(m) => m.dim(),
            TileBackend::Hdfit(m) => m.dim(),
            TileBackend::Soc(s) => s.dim(),
        }
    }

    /// The dataflow this backend's mesh executes — it decides the tile
    /// grid, the operand shapes and the cycle model of every offload
    /// (all three backends, the whole SoC included, run both dataflows).
    pub fn dataflow(&self) -> Dataflow {
        match self {
            TileBackend::Mesh(m) => m.dataflow(),
            TileBackend::Hdfit(m) => m.dataflow(),
            TileBackend::Soc(s) => s.dataflow(),
        }
    }

    /// Run one DIM x DIM-output tile matmul (full-K stream), injecting
    /// the scenario's fault plan (empty plan = golden). The public
    /// software↔RTL seam: operands are borrowed windows into the
    /// caller's flat buffers.
    pub fn run_tile(
        &mut self,
        a: MatView<i8>,
        b: MatView<i8>,
        d: MatView<i32>,
        plan: &FaultPlan,
    ) -> anyhow::Result<Mat<i32>> {
        let mut out = Mat::default();
        self.run_tile_into(a, b, d, plan, &mut out)?;
        Ok(out)
    }

    /// [`TileBackend::run_tile`] into a caller-provided result buffer
    /// (reshaped and zeroed in place). Returns the RTL cycles stepped.
    pub fn run_tile_into(
        &mut self,
        a: MatView<i8>,
        b: MatView<i8>,
        d: MatView<i32>,
        plan: &FaultPlan,
        out: &mut Mat<i32>,
    ) -> anyhow::Result<u64> {
        let mut scratch = DriverScratch::default();
        self.run_tile_with(a, b, d, plan, out, &mut scratch)
    }

    /// [`TileBackend::run_tile_into`] reusing a caller-owned
    /// [`DriverScratch`] as well: the campaign's per-site trial batches
    /// drain every RTL tile into the same scratch `Mat` and boundary
    /// buffers, so the hot path performs no per-trial allocation at all.
    pub fn run_tile_with(
        &mut self,
        a: MatView<i8>,
        b: MatView<i8>,
        d: MatView<i32>,
        plan: &FaultPlan,
        out: &mut Mat<i32>,
        scratch: &mut DriverScratch,
    ) -> anyhow::Result<u64> {
        Ok(match self {
            TileBackend::Mesh(m) => {
                MatmulDriver::new(*m).matmul_into_with(a, b, d, plan, out, scratch)
            }
            TileBackend::Hdfit(m) => {
                MatmulDriver::new(*m).matmul_into_with(a, b, d, plan, out, scratch)
            }
            TileBackend::Soc(s) => s.run_matmul_into(a, b, d, plan, out)?,
        })
    }

    /// Whether this backend supports the cycle-resume tile engine. All
    /// three do: the mesh backends index their `Schedule` directly, and
    /// the whole-SoC backend's controller is schedule-indexable too —
    /// its `SocSchedule` + `ControllerState` snapshot give the same
    /// advance-golden/replay shape through [`Soc::run_matmul_resumed`]
    /// (ROADMAP "Schedule-indexable SoC"; pinned by the oracle tests).
    pub fn supports_cycle_resume(&self) -> bool {
        true
    }

    /// Whether this backend supports the trial-lockstep lane engine.
    /// Mesh-only: the HDFIT backend arms its instrumentation hooks per
    /// mesh instance, so one instrumented mesh cannot carry N
    /// independent trials' hooks side by side, and the whole-SoC backend
    /// steps one persistent chip — both silently fall back to
    /// cycle-resume, the same fallback shape as
    /// [`TileBackend::supports_cycle_resume`] (ROADMAP "Trial-lockstep"
    /// contract; pinned by the oracle tests).
    pub fn supports_lane_lockstep(&self) -> bool {
        matches!(self, TileBackend::Mesh(_))
    }

    /// Earliest cycle this backend's execution of `plan` can diverge
    /// from the golden trajectory (the cycle-resume restore point; the
    /// HDFIT backend's storage hooks fire one cycle before the ENFOR-SA
    /// onset).
    pub fn first_effect_cycle(&self, plan: &FaultPlan) -> u64 {
        match self {
            TileBackend::Mesh(m) => m.first_effect_cycle(plan),
            TileBackend::Hdfit(m) => m.first_effect_cycle(plan),
            TileBackend::Soc(_) => plan.first_cycle(),
        }
    }

    /// Cycle-resume tile run: advance the shared golden cursor for tile
    /// `key` to the plan's first effect cycle, snapshot, and replay only
    /// the faulty suffix — bit-identical to [`TileBackend::run_tile_with`]
    /// (pinned by `prop_cycle_resume.rs`). Returns the RTL cycles
    /// stepped: golden advance + replay for the mesh backends; prefix
    /// staging (once per tile) + golden advance + replay SoC cycles for
    /// the whole-SoC backend, whose resume cursor lives inside the `Soc`
    /// itself ([`Soc::run_matmul_resumed`]) rather than in `cur`.
    /// Callers must gate on [`TileBackend::supports_cycle_resume`].
    #[allow(clippy::too_many_arguments)]
    pub fn run_tile_resumed(
        &mut self,
        a: MatView<i8>,
        b: MatView<i8>,
        d: MatView<i32>,
        plan: &FaultPlan,
        key: (usize, usize),
        cur: &mut CycleCursor,
        out: &mut Mat<i32>,
        scratch: &mut DriverScratch,
    ) -> anyhow::Result<u64> {
        let resume = self.first_effect_cycle(plan);
        Ok(match self {
            TileBackend::Mesh(m) => {
                let cycles =
                    MatmulDriver::new(*m).advance_golden(a, b, d, key, resume, cur, scratch);
                cycles + MatmulDriver::new(*m).matmul_resumed(a, b, d, plan, cur, out, scratch)
            }
            TileBackend::Hdfit(m) => {
                let cycles =
                    MatmulDriver::new(*m).advance_golden(a, b, d, key, resume, cur, scratch);
                cycles + MatmulDriver::new(*m).matmul_resumed(a, b, d, plan, cur, out, scratch)
            }
            TileBackend::Soc(s) => s.run_matmul_resumed(a, b, d, plan, key, resume, out)?,
        })
    }

    /// Prepare the backend for the next trial batch. The mesh drivers
    /// reset the array at the start of every matmul, so only the
    /// whole-SoC backend (persistent across a campaign since the
    /// fresh-`Soc`-per-trial path was retired) has work to do here.
    /// Note: the SoC's reset also invalidates its cycle-resume cursor,
    /// so under the resumed engine this is a once-per-batch operation,
    /// not a per-trial one.
    pub fn reset(&mut self) {
        if let TileBackend::Soc(s) = self {
            s.reset();
        }
    }

    /// Whole-layer offload (ablation D3): every tile through RTL, the
    /// fault plan armed only on the target tile ([`tile_grid`]
    /// coordinates of the backend's dataflow).
    pub fn run_layer(
        &mut self,
        a: MatView<i8>,
        b: MatView<i8>,
        d: MatView<i32>,
        plan: &FaultPlan,
        tile_i: usize,
        tile_j: usize,
    ) -> anyhow::Result<Mat<i32>> {
        // unsupported-backend check first: no tile work before the bail
        if matches!(self, TileBackend::Soc(_)) {
            anyhow::bail!("whole-layer offload through the SoC is not supported")
        }
        if self.dataflow() == Dataflow::WeightStationary {
            // WS: the layer is a chain of M-panel passes per output
            // column block; the plan arms only pass (tile_i, tile_j)
            // and the corrupted psum column flows through the RTL
            // suffix passes of the chain.
            return Ok(match self {
                TileBackend::Mesh(mesh) => {
                    tiled_matmul_ws_with(*mesh, a, b, d, plan, (tile_i, tile_j))
                }
                TileBackend::Hdfit(mesh) => {
                    tiled_matmul_ws_with(*mesh, a, b, d, plan, (tile_i, tile_j))
                }
                TileBackend::Soc(_) => unreachable!("checked above"),
            });
        }
        let mut c = match self {
            TileBackend::Mesh(mesh) => tiled_matmul_os(*mesh, a, b, d),
            TileBackend::Hdfit(mesh) => tiled_matmul_os(*mesh, a, b, d),
            TileBackend::Soc(_) => unreachable!("checked above"),
        };
        // redo the faulty tile with the plan and splice. The tile gets
        // the full-K stream, exactly like every tile of tiled_matmul_os.
        let dim = self.dim();
        let k = a.cols();
        let (ti, tj) = (tile_i * dim, tile_j * dim);
        let c_tile = self.run_tile(
            a.sub(ti, 0, dim, k),
            b.sub(0, tj, k, dim),
            d.sub(ti, tj, dim, dim),
            plan,
        )?;
        c.window_mut(ti, tj, dim, dim).splice_from(&c_tile);
        Ok(c)
    }
}

/// One lane group of a packed-lockstep chunk, as the campaign's packer
/// hands it to [`CrossLayerRunner::begin_packed_chunk`]: a maximal
/// same-tile run of trials (exactly the runs lane-lockstep's chunking
/// would form) carrying its **sampled** tile coordinates — the runner
/// clamps them to each call's actual tile grid, exactly as the
/// single-trial paths do.
pub struct PackedGroup<'a> {
    pub tile_i: usize,
    pub tile_j: usize,
    /// One fault plan per lane of the group, ascending first-effect
    /// cycle (the batch sort order).
    pub plans: Vec<&'a FaultPlan>,
}

/// GEMM hook that performs the cross-layer offload for one trial.
///
/// A runner is built once per **site batch** and re-armed per trial
/// ([`CrossLayerRunner::arm`]): the backend borrow, the borrowed trial
/// (plans live in the input's pre-sampled batch, so re-arming allocates
/// nothing), the scratch result tile, the driver scratch and the golden
/// [`CycleCursor`] persist across all `faults_per_layer` trials of a
/// site. Under [`TileEngine::CycleResume`] the cursor's snapshots stay
/// valid across trials because every trial of a batch replays the site
/// from the same checkpoint, so the tile operands are bit-identical.
pub struct CrossLayerRunner<'a> {
    pub trial: &'a TrialFault,
    pub backend: TileBackend<'a>,
    pub scope: OffloadScope,
    /// Tile execution engine (cycle-resume falls back to full on
    /// backends without [`TileBackend::supports_cycle_resume`]).
    pub engine: TileEngine,
    /// Set when the target site was reached.
    pub hit: bool,
    /// Set when the RTL tile differed from the fault-free tile (the
    /// fault was *exposed* to the software layer — paper Fig. 5b).
    pub exposed: bool,
    /// Armed mitigation stack (the campaign's `--hardening` axis).
    /// `HardeningConfig::is_none()` keeps every splice seam on the
    /// legacy compare-only path, bit-identical to pre-hardening runs.
    pub hardening: HardeningConfig,
    /// TMR-protected PE columns (`tmr_protected[c % dim]`), empty when
    /// selective TMR is not armed. The campaign precomputes this once
    /// per executor from the dataflow's exposure map.
    pub tmr_protected: Vec<bool>,
    /// Hardening only: the RTL region differed from its golden BEFORE
    /// mitigation ran (what `exposed` would read under `--hardening
    /// none`) — the denominator of the coverage metrics.
    pub mit_struck: bool,
    /// Hardening only: an armed detector (ABFT checksum) flagged the
    /// struck region.
    pub mit_detected: bool,
    /// Hardening only: mitigation restored the struck region to its
    /// golden bit-exactly (the splice then writes nothing, so the trial
    /// classifies as masked).
    pub mit_corrected: bool,
    /// Total RTL mesh cycles stepped by this runner: golden-cursor
    /// advances plus (full or resumed) tile runs — the campaign's
    /// `rtl_cycles_stepped` accounting.
    pub rtl_cycles: u64,
    /// Lane capacity the occupancy accounting measures against — the
    /// campaign's configured lane count (chunks are at most this wide).
    /// Direct callers may leave the default 1: the accounting clamps it
    /// up to the armed chunk's width.
    pub lane_capacity: usize,
    /// Lane-cycles carrying live trial work: for every RTL cycle
    /// stepped, the number of lanes with an unretired trial on them
    /// (scalar engine paths count as one fully-occupied lane).
    pub lane_cycles_filled: u64,
    /// Lane-cycles of capacity paid for those same steps: lockstep and
    /// packed passes charge `max(lane_capacity, chunk width)` per cycle,
    /// scalar paths one. `filled / stepped` is the campaign's
    /// lane-occupancy metric.
    pub lane_cycles_stepped: u64,
    /// Reusable result tile shared by every trial in a batch (DIM x DIM
    /// under OS; M x DIM under WS — reshaped in place).
    scratch: Mat<i32>,
    /// Reusable driver boundary buffers + drain counter.
    drv: DriverScratch,
    /// Golden trajectory snapshot shared by the batch's trials.
    cursor: CycleCursor,
    /// WS only: the psum column entering the offloaded pass (bias plus
    /// the chain prefix of k-tiles before the target), M x DIM.
    ws_d: Mat<i32>,
    /// WS only: the software golden output of the offloaded pass — the
    /// reference the delta-splice compares the RTL column against.
    ws_gold: Mat<i32>,
    /// Which tile `ws_d`/`ws_gold` are valid for. Within a runner's
    /// lifetime (one site batch) the tile operands are bit-identical
    /// across trials — the same invariant the golden [`CycleCursor`]
    /// rests on — so the software prefix/golden of a tile is computed
    /// once per tile, not once per trial.
    ws_key: Option<(usize, usize)>,
    /// Lane-lockstep only: the fault plans of the current trial chunk
    /// ([`CrossLayerRunner::begin_chunk`]); lane `l` steps plan `l`.
    chunk_plans: Vec<&'a FaultPlan>,
    /// Lane-lockstep only: which lane the armed trial occupies.
    lane: usize,
    /// Lane-lockstep only: set once the chunk's lockstep pass ran;
    /// later trials of the chunk reuse the computed lane results.
    lockstep_done: bool,
    /// Lane-lockstep only: the lane-batched SoA mesh (zero lanes until
    /// the first chunk reshapes it).
    lane_mesh: LaneMesh,
    /// Lane-lockstep only: per-lane result tiles of the current chunk.
    lane_outs: Vec<Mat<i32>>,
    /// Debug guard: which tile engine has driven this runner's golden
    /// cursor. The lockstep and per-trial resume paths prime drain
    /// state differently (per-lane `takens` vs the scratch counter), so
    /// one runner must never interleave them on the same cursor.
    cursor_engine: Option<TileEngine>,
    /// Packed-lockstep only: the lane groups of the current chunk
    /// ([`CrossLayerRunner::begin_packed_chunk`]) — whole same-tile
    /// runs packed side by side; global lane `l` belongs to group
    /// `lane_group[l]`.
    packed_groups: Vec<PackedGroup<'a>>,
    /// Packed-lockstep only: global lane -> group index of the chunk.
    lane_group: Vec<usize>,
    /// Packed-lockstep only: set once the chunk's packed pass ran;
    /// later trials of the chunk reuse the computed lane results.
    packed_done: bool,
    /// Packed-lockstep only: one golden cursor per group. Slots are
    /// recycled across chunks without resetting — `advance_golden`
    /// restarts a stale trajectory on key mismatch or rewind, so a
    /// leftover snapshot can cost cycles but never correctness.
    packed_cursors: Vec<CycleCursor>,
    /// Packed-lockstep WS only: per-group psum column entering the
    /// offloaded pass (the [`CrossLayerRunner::ws_d`] peer, one per
    /// group since a packed chunk spans weight tiles).
    packed_ws_d: Vec<Mat<i32>>,
    /// Packed-lockstep WS only: per-group software golden of the pass
    /// (the delta-splice reference for that group's trials).
    packed_ws_gold: Vec<Mat<i32>>,
}

impl<'a> CrossLayerRunner<'a> {
    /// Legacy-shaped constructor: the full tile engine (the oracle the
    /// pre-resume unit tests pin). Campaign code passes the configured
    /// engine via [`CrossLayerRunner::with_engine`].
    pub fn new(trial: &'a TrialFault, backend: TileBackend<'a>, scope: OffloadScope) -> Self {
        Self::with_engine(trial, backend, scope, TileEngine::Full)
    }

    pub fn with_engine(
        trial: &'a TrialFault,
        backend: TileBackend<'a>,
        scope: OffloadScope,
        engine: TileEngine,
    ) -> Self {
        let dim = backend.dim();
        let dataflow = backend.dataflow();
        CrossLayerRunner {
            trial,
            backend,
            scope,
            engine,
            hit: false,
            exposed: false,
            hardening: HardeningConfig::default(),
            tmr_protected: Vec::new(),
            mit_struck: false,
            mit_detected: false,
            mit_corrected: false,
            rtl_cycles: 0,
            lane_capacity: 1,
            lane_cycles_filled: 0,
            lane_cycles_stepped: 0,
            scratch: Mat::zeros(dim, dim),
            drv: DriverScratch::new(dim),
            cursor: CycleCursor::new(),
            ws_d: Mat::default(),
            ws_gold: Mat::default(),
            ws_key: None,
            chunk_plans: vec![&trial.plan],
            lane: 0,
            lockstep_done: false,
            lane_mesh: LaneMesh::new(dim, dataflow),
            lane_outs: Vec::new(),
            cursor_engine: None,
            packed_groups: vec![PackedGroup {
                tile_i: trial.tile_i,
                tile_j: trial.tile_j,
                plans: vec![&trial.plan],
            }],
            lane_group: vec![0],
            packed_done: false,
            packed_cursors: Vec::new(),
            packed_ws_d: Vec::new(),
            packed_ws_gold: Vec::new(),
        }
    }

    /// Re-arm for the next trial of a batch: fresh trial and flags, same
    /// backend borrow, same scratch buffers, same golden cursor. Under
    /// lane-lockstep this arms a fresh single-trial chunk — the
    /// per-trial shape unit tests and direct callers use; the campaign
    /// executor arms whole chunks via [`CrossLayerRunner::begin_chunk`]
    /// + [`CrossLayerRunner::arm_lane`] instead.
    pub fn arm(&mut self, trial: &'a TrialFault) {
        self.trial = trial;
        self.hit = false;
        self.exposed = false;
        self.mit_struck = false;
        self.mit_detected = false;
        self.mit_corrected = false;
        self.chunk_plans.clear();
        self.chunk_plans.push(&trial.plan);
        self.lane = 0;
        self.lockstep_done = false;
        // the packed peer of the single-trial chunk: one one-lane group
        self.packed_groups.clear();
        self.packed_groups.push(PackedGroup {
            tile_i: trial.tile_i,
            tile_j: trial.tile_j,
            plans: vec![&trial.plan],
        });
        self.lane_group.clear();
        self.lane_group.push(0);
        self.packed_done = false;
    }

    /// Start a packed-lockstep chunk: whole same-tile runs (each a
    /// [`PackedGroup`]) laid side by side, `Σ_g plans_g` lanes in total;
    /// global lane `l` of the next packed pass steps the `l`-th plan in
    /// group-then-lane order. Every plan must come from the same site
    /// batch (the executor's packer guarantees it; operands of different
    /// *tiles* may differ — that is the point). The pass itself runs
    /// lazily on the chunk's first armed trial.
    pub fn begin_packed_chunk(&mut self, groups: Vec<PackedGroup<'a>>) {
        debug_assert!(!groups.is_empty(), "a packed chunk needs at least one group");
        self.lane_group.clear();
        for (gi, g) in groups.iter().enumerate() {
            debug_assert!(!g.plans.is_empty(), "a packed group needs at least one trial");
            self.lane_group.extend(std::iter::repeat(gi).take(g.plans.len()));
        }
        self.packed_groups = groups;
        self.packed_done = false;
    }

    /// Start a lane-lockstep chunk: lane `l` of the next lockstep pass
    /// steps `plans[l]`. Every plan must come from the same site batch
    /// and target the same tile (the executor's grouping guarantees
    /// both); the pass itself runs lazily on the chunk's first armed
    /// trial.
    pub fn begin_chunk(&mut self, plans: Vec<&'a FaultPlan>) {
        debug_assert!(!plans.is_empty(), "a lockstep chunk needs at least one trial");
        self.chunk_plans = plans;
        self.lockstep_done = false;
    }

    /// Re-arm for trial `lane` of the current chunk (see
    /// [`CrossLayerRunner::begin_chunk`] /
    /// [`CrossLayerRunner::begin_packed_chunk`] — `lane` is global,
    /// group-then-lane order, for packed chunks): like
    /// [`CrossLayerRunner::arm`] but keeping the chunk's plans and its
    /// already-computed lane results.
    pub fn arm_lane(&mut self, trial: &'a TrialFault, lane: usize) {
        debug_assert!(
            lane < self.chunk_plans.len().max(self.lane_group.len()),
            "lane outside the armed chunk"
        );
        self.trial = trial;
        self.hit = false;
        self.exposed = false;
        self.mit_struck = false;
        self.mit_detected = false;
        self.mit_corrected = false;
        self.lane = lane;
    }

    /// Debug guard (see `cursor_engine`): called by both cursor-driven
    /// tile paths with their engine.
    fn note_cursor_engine(&mut self, engine: TileEngine) {
        debug_assert!(
            self.cursor_engine.is_none() || self.cursor_engine == Some(engine),
            "lockstep and cycle-resume must not interleave on one runner's cursor"
        );
        self.cursor_engine = Some(engine);
    }

    /// Account RTL cycles stepped on a single-lane (scalar) engine path:
    /// one lane of capacity, fully occupied.
    fn add_scalar_cycles(&mut self, cycles: u64) {
        self.rtl_cycles += cycles;
        self.lane_cycles_filled += cycles;
        self.lane_cycles_stepped += cycles;
    }

    /// Trial-lockstep tile run (PR 6 tentpole): on the chunk's first
    /// armed trial, advance the batch-shared golden cursor to the
    /// chunk's MINIMUM first-effect cycle and step the tile suffix once
    /// for all lanes ([`lockstep_resumed`]); later trials of the chunk
    /// reuse the computed lane results for free. The caller splices
    /// `lane_outs[self.lane]` through the unchanged exposure seam via
    /// `scratch`. Callers must gate on
    /// [`TileBackend::supports_lane_lockstep`].
    fn run_lockstep_tile(
        &mut self,
        a: MatView<i8>,
        b: MatView<i8>,
        d: MatView<i32>,
        key: (usize, usize),
    ) {
        self.note_cursor_engine(TileEngine::LaneLockstep);
        if !self.lockstep_done {
            let min_fe = self
                .chunk_plans
                .iter()
                .map(|p| self.backend.first_effect_cycle(p))
                .min()
                .expect("lockstep chunk must not be empty");
            let TileBackend::Mesh(m) = &mut self.backend else {
                unreachable!("lane-lockstep is mesh-only: gate on supports_lane_lockstep")
            };
            let adv =
                MatmulDriver::new(*m).advance_golden(a, b, d, key, min_fe, &mut self.cursor, &mut self.drv);
            let stepped = lockstep_resumed(
                &mut self.lane_mesh,
                a,
                b,
                d,
                &self.chunk_plans,
                &self.cursor,
                &mut self.lane_outs,
                &mut self.drv,
            );
            // the suffix is paid ONCE per chunk — the lockstep speedup
            self.rtl_cycles += adv + stepped;
            // occupancy: the golden advance is scalar (one full lane);
            // the lockstep span fills `width` of `capacity` lanes
            let width = self.chunk_plans.len() as u64;
            let cap = (self.lane_capacity as u64).max(width);
            self.lane_cycles_filled += adv + width * stepped;
            self.lane_cycles_stepped += adv + cap * stepped;
            self.lockstep_done = true;
        }
        self.scratch.clone_from(&self.lane_outs[self.lane]);
    }

    /// Cross-tile packed-lockstep pass (the PR 9 tentpole): on the
    /// chunk's first armed trial, advance each group's OWN golden cursor
    /// to that group's minimum first-effect cycle, then step ALL groups'
    /// tile suffixes side by side in one [`packed_lockstep_resumed`]
    /// pass; later trials of the chunk read their lane for free. Sampled
    /// tile coordinates are clamped to the call's actual grid here,
    /// exactly like the single-trial paths — two groups may clamp onto
    /// the same actual tile, which is why each group owns a cursor slot
    /// instead of sharing a tile-keyed one. Under WS each group also
    /// gets its own prefix psum + pass golden (`packed_ws_d` /
    /// `packed_ws_gold`), since a packed chunk spans weight tiles.
    /// Callers must gate on [`TileBackend::supports_lane_lockstep`].
    fn run_packed_pass(
        &mut self,
        a_full: MatView<i8>,
        b_full: MatView<i8>,
        d_full: MatView<i32>,
        (m, k, n): (usize, usize, usize),
    ) {
        self.note_cursor_engine(TileEngine::PackedLockstep);
        let dim = self.backend.dim();
        let dataflow = self.backend.dataflow();
        let (tiles_i, tiles_j) = tile_grid(dataflow, dim, m, k, n);
        let ngroups = self.packed_groups.len();
        if self.packed_cursors.len() < ngroups {
            self.packed_cursors.resize_with(ngroups, CycleCursor::new);
        }
        // clamp each group's sampled tile to this call's actual grid
        let keys: Vec<(usize, usize)> = self
            .packed_groups
            .iter()
            .map(|g| (g.tile_i.min(tiles_i - 1), g.tile_j.min(tiles_j - 1)))
            .collect();
        let min_fes: Vec<u64> = self
            .packed_groups
            .iter()
            .map(|g| {
                g.plans
                    .iter()
                    .map(|p| self.backend.first_effect_cycle(p))
                    .min()
                    .expect("a packed group must not be empty")
            })
            .collect();
        if dataflow == Dataflow::WeightStationary {
            if self.packed_ws_d.len() < ngroups {
                self.packed_ws_d.resize_with(ngroups, Mat::default);
                self.packed_ws_gold.resize_with(ngroups, Mat::default);
            }
            for gi in 0..ngroups {
                let (ti, tj) = keys[gi];
                let (ri, cj) = (ti * dim, tj * dim);
                let ncols = dim.min(n - cj);
                let a_t = a_full.sub(0, ri, m, dim);
                let w_t = b_full.sub(ri, cj, dim, dim);
                // the group's psum column entering the pass: bias +
                // every k-tile before the target (see run_ws_tile)
                self.packed_ws_d[gi].reset(m, dim);
                for r in 0..m {
                    let row = self.packed_ws_d[gi].row_mut(r);
                    for c in 0..ncols {
                        let mut acc = d_full.at(r, cj + c);
                        for kk in 0..ri {
                            acc = acc.wrapping_add(
                                a_full.at(r, kk) as i32 * b_full.at(kk, cj + c) as i32,
                            );
                        }
                        row[c] = acc;
                    }
                }
                // software golden of the group's pass
                self.packed_ws_gold[gi].reset(m, dim);
                for r in 0..m {
                    for c in 0..dim {
                        let mut acc = self.packed_ws_d[gi].at(r, c);
                        for x in 0..dim {
                            acc = acc.wrapping_add(a_t.at(r, x) as i32 * w_t.at(x, c) as i32);
                        }
                        self.packed_ws_gold[gi].set(r, c, acc);
                    }
                }
            }
        }
        let mut adv_total = 0u64;
        {
            let TileBackend::Mesh(mesh) = &mut self.backend else {
                unreachable!("packed-lockstep is mesh-only: gate on supports_lane_lockstep")
            };
            for gi in 0..ngroups {
                let (ti, tj) = keys[gi];
                let (ri, cj) = (ti * dim, tj * dim);
                let (a_t, b_t, d_t) = match dataflow {
                    Dataflow::OutputStationary => (
                        a_full.sub(ri, 0, dim, k),
                        b_full.sub(0, cj, k, dim),
                        d_full.sub(ri, cj, dim, dim),
                    ),
                    Dataflow::WeightStationary => (
                        a_full.sub(0, ri, m, dim),
                        b_full.sub(ri, cj, dim, dim),
                        self.packed_ws_d[gi].view(),
                    ),
                };
                adv_total += MatmulDriver::new(*mesh).advance_golden(
                    a_t,
                    b_t,
                    d_t,
                    (ti, tj),
                    min_fes[gi],
                    &mut self.packed_cursors[gi],
                    &mut self.drv,
                );
            }
        }
        let mut groups: Vec<LaneGroup<'_>> = Vec::with_capacity(ngroups);
        for gi in 0..ngroups {
            let (ti, tj) = keys[gi];
            let (ri, cj) = (ti * dim, tj * dim);
            let (a_t, b_t, d_t) = match dataflow {
                Dataflow::OutputStationary => (
                    a_full.sub(ri, 0, dim, k),
                    b_full.sub(0, cj, k, dim),
                    d_full.sub(ri, cj, dim, dim),
                ),
                Dataflow::WeightStationary => (
                    a_full.sub(0, ri, m, dim),
                    b_full.sub(ri, cj, dim, dim),
                    self.packed_ws_d[gi].view(),
                ),
            };
            groups.push(LaneGroup {
                a: a_t,
                b: b_t,
                d: d_t,
                plans: self.packed_groups[gi].plans.clone(),
                cur: &self.packed_cursors[gi],
            });
        }
        let (stepped, filled) = packed_lockstep_resumed(
            &mut self.lane_mesh,
            &groups,
            &mut self.lane_outs,
            &mut self.drv,
        );
        // every group's golden advance is scalar; the packed span is
        // paid ONCE — `max_g(span_g)`, never more than lane-lockstep's
        // `Σ_g span_g` over the same runs
        self.rtl_cycles += adv_total + stepped;
        let cap = (self.lane_capacity as u64).max(self.lane_group.len() as u64);
        self.lane_cycles_filled += adv_total + filled;
        self.lane_cycles_stepped += adv_total + cap * stepped;
        self.packed_done = true;
    }

    /// Apply the armed mitigation stack to one faulty RTL region before
    /// it splices back into the software layer. `rtl[0..rows][0..cols]`
    /// is the in-bounds faulty region (exactly what the splice can
    /// expose); `gold(r, c)` reads the fault-free value of the same
    /// element. Mechanisms run in hardware order — selective TMR votes
    /// protected PE columns back to golden first, the ABFT row/column
    /// checksums check (and single-error-correct) the post-vote region,
    /// and range clipping clamps whatever corruption remains — each
    /// mutating `rtl` in place, so the unchanged splice seam downstream
    /// sees the mitigated tile. Returns `(struck, detected, corrected)`:
    /// the region differed from golden before mitigation, a detector
    /// flagged it, and mitigation restored it bit-exactly (the splice
    /// then writes nothing and the trial classifies as masked).
    ///
    /// Modeling notes: the checksums compare against the golden region
    /// (an idealized ABFT whose encoded checksum row/column is itself
    /// fault-free); clipping clamps only diverged elements — a range
    /// check against the fault-free activation, not a blanket
    /// saturation that would also perturb golden out-of-range values.
    /// Output column `c` drains from PE column `c % dim`, so tile
    /// regions (`cols <= dim`) index the TMR map directly and
    /// whole-layer regions wrap per column block.
    fn mitigate_region(
        h: &HardeningConfig,
        tmr: &[bool],
        rtl: &mut Mat<i32>,
        gold: impl Fn(usize, usize) -> i32,
        rows: usize,
        cols: usize,
    ) -> (bool, bool, bool) {
        let diff =
            |rtl: &Mat<i32>| (0..rows).any(|r| (0..cols).any(|c| rtl.at(r, c) != gold(r, c)));
        if !diff(rtl) {
            return (false, false, false);
        }
        // selective TMR: the voter at each protected column's output
        // restores that column's elements; corruption that forwarded
        // into unprotected neighbours stays
        if !tmr.is_empty() {
            for r in 0..rows {
                for c in 0..cols {
                    if tmr[c % tmr.len()] && rtl.at(r, c) != gold(r, c) {
                        rtl.set(r, c, gold(r, c));
                    }
                }
            }
        }
        // ABFT: wrapping row/column checksums over the post-vote region;
        // a unique bad-row x bad-column pair with matching deltas is a
        // single-element error — subtract it out
        let mut detected = false;
        if h.abft {
            let delta = |r: usize, c: usize| rtl.at(r, c).wrapping_sub(gold(r, c));
            let mut bad_rows: Vec<(usize, i32)> = Vec::new();
            for r in 0..rows {
                let d = (0..cols).fold(0i32, |acc, c| acc.wrapping_add(delta(r, c)));
                if d != 0 {
                    bad_rows.push((r, d));
                }
            }
            let mut bad_cols: Vec<(usize, i32)> = Vec::new();
            for c in 0..cols {
                let d = (0..rows).fold(0i32, |acc, r| acc.wrapping_add(delta(r, c)));
                if d != 0 {
                    bad_cols.push((c, d));
                }
            }
            if !bad_rows.is_empty() || !bad_cols.is_empty() {
                detected = true;
                if bad_rows.len() == 1 && bad_cols.len() == 1 && bad_rows[0].1 == bad_cols[0].1 {
                    let ((r, d), (c, _)) = (bad_rows[0], bad_cols[0]);
                    rtl.set(r, c, rtl.at(r, c).wrapping_sub(d));
                }
            }
        }
        // range clipping on whatever corruption remains
        if let Some((lo, hi)) = h.clip {
            for r in 0..rows {
                for c in 0..cols {
                    if rtl.at(r, c) != gold(r, c) {
                        rtl.set(r, c, rtl.at(r, c).clamp(lo, hi));
                    }
                }
            }
        }
        (true, detected, !diff(rtl))
    }

    /// Fold one mitigated region's flags into the armed trial's verdict
    /// inputs (one region splices per trial — the hook hits one site).
    fn note_mitigation(&mut self, (struck, detected, corrected): (bool, bool, bool)) {
        self.mit_struck |= struck;
        self.mit_detected |= detected;
        self.mit_corrected |= corrected;
    }

    /// Delta-splice one WS pass back into the layer accumulator:
    /// `out += rtl - gold`, touching only elements where the RTL pass
    /// diverged from its software golden. Returns whether anything
    /// changed (the exposure signal).
    fn ws_delta_splice(
        rtl: &Mat<i32>,
        gold: &Mat<i32>,
        out: &mut [i32],
        (m, n): (usize, usize),
        cj: usize,
        ncols: usize,
    ) -> bool {
        let mut changed = false;
        for r in 0..m {
            let rtl = rtl.row(r);
            let gold = gold.row(r);
            let dst = &mut out[r * n + cj..r * n + cj + ncols];
            for c in 0..ncols {
                if rtl[c] != gold[c] {
                    changed = true;
                    dst[c] = dst[c].wrapping_add(rtl[c].wrapping_sub(gold[c]));
                }
            }
        }
        changed
    }

    /// ENFOR-SA OS single-tile offload: the DIM-padded output tile is a
    /// zero-copy window into the layer's buffers (full-K stream); the
    /// RTL result drains into the runner's scratch tile and splices
    /// back with the change-flag as the exposure signal.
    #[allow(clippy::too_many_arguments)]
    fn run_os_tile(
        &mut self,
        a_full: MatView<i8>,
        b_full: MatView<i8>,
        d_full: MatView<i32>,
        (m, k, n): (usize, usize, usize),
        ti: usize,
        tj: usize,
        out: &mut [i32],
    ) {
        let dim = self.backend.dim();
        let (ri, cj) = (ti * dim, tj * dim);
        let a_t = a_full.sub(ri, 0, dim, k);
        let b_t = b_full.sub(0, cj, k, dim);
        let d_t = d_full.sub(ri, cj, dim, dim);
        if self.engine == TileEngine::PackedLockstep && self.backend.supports_lane_lockstep() {
            // packed-lockstep: ALL groups' suffixes step side by side
            // once through the lane mesh; this trial reads its lane
            if !self.packed_done {
                self.run_packed_pass(a_full, b_full, d_full, (m, k, n));
            }
            self.scratch.clone_from(&self.lane_outs[self.lane]);
        } else if self.engine == TileEngine::LaneLockstep && self.backend.supports_lane_lockstep() {
            // trial-lockstep: the whole chunk's suffix steps once
            // through the lane mesh; this trial reads its lane
            self.run_lockstep_tile(a_t, b_t, d_t, (ti, tj));
        } else if matches!(
            self.engine,
            TileEngine::CycleResume | TileEngine::LaneLockstep | TileEngine::PackedLockstep
        ) && self.backend.supports_cycle_resume()
        {
            // cycle-resume: skip the golden prefix of the tile — the
            // batch-shared cursor advances it once per tile (also the
            // lockstep engines' fallback on the HDFIT/SoC backends)
            self.note_cursor_engine(TileEngine::CycleResume);
            match self.backend.run_tile_resumed(
                a_t,
                b_t,
                d_t,
                &self.trial.plan,
                (ti, tj),
                &mut self.cursor,
                &mut self.scratch,
                &mut self.drv,
            ) {
                Ok(cycles) => self.add_scalar_cycles(cycles),
                Err(e) => panic!("resumed tile offload failed for [{}]: {e:#}", self.trial),
            }
        } else {
            match self
                .backend
                .run_tile_with(a_t, b_t, d_t, &self.trial.plan, &mut self.scratch, &mut self.drv)
            {
                Ok(cycles) => self.add_scalar_cycles(cycles),
                Err(e) => panic!("tile offload failed for [{}]: {e:#}", self.trial),
            }
        }
        if !self.hardening.is_none() {
            // the accumulator window still holds the native golden tile
            // — mitigate the RTL region against it before the splice
            let (rows, cols) = (dim.min(m - ri), dim.min(n - cj));
            let gold: &[i32] = out;
            let flags = Self::mitigate_region(
                &self.hardening,
                &self.tmr_protected,
                &mut self.scratch,
                |r, c| gold[(ri + r) * n + (cj + c)],
                rows,
                cols,
            );
            self.note_mitigation(flags);
        }
        // splice the RTL tile back into the accumulator (one strided
        // copy; a changed element means the fault escaped the array)
        let mut target = MatViewMut::window(out, m, n, n, ri, cj, dim, dim);
        if target.splice_from(&self.scratch) {
            self.exposed = true;
        }
    }

    /// ENFOR-SA WS single-tile offload: one weight-stationary pass — the
    /// DIM x DIM weight tile `(ti, tj)` preloaded, the layer's full
    /// M-row activation panel streamed through it, and the psum column
    /// entering at the north edge equal to bias + the chain prefix
    /// (k-tiles before `ti`), exactly the D stream the chained hardware
    /// execution would feed this pass.
    ///
    /// The chain *suffix* (k-tiles after `ti`) is exactly linear in the
    /// psum (a fault-free WS pass computes `A.W + D` in wrapping i32),
    /// so the corrupted pass splices back as a delta against its
    /// software golden: `out += rtl - gold`, element-wise, touching only
    /// elements where the RTL pass diverged — the change-flag contract
    /// of the OS splice, with identical masking semantics (corruption
    /// confined to drain lanes beyond N is discarded, as the fixed drain
    /// window of the real frontend would).
    #[allow(clippy::too_many_arguments)]
    fn run_ws_tile(
        &mut self,
        a_full: MatView<i8>,
        b_full: MatView<i8>,
        d_full: MatView<i32>,
        (m, _k, n): (usize, usize, usize),
        ti: usize,
        tj: usize,
        out: &mut [i32],
    ) {
        let dim = self.backend.dim();
        let (ri, cj) = (ti * dim, tj * dim);
        // operand windows: M x DIM activation panel, DIM x DIM weights
        let a_t = a_full.sub(0, ri, m, dim);
        let w_t = b_full.sub(ri, cj, dim, dim);
        let ncols = dim.min(n - cj);
        if self.engine == TileEngine::PackedLockstep && self.backend.supports_lane_lockstep() {
            // packed-lockstep: the pass computed per-group prefix psums
            // and goldens (`packed_ws_d`/`packed_ws_gold`) — the
            // single-slot `ws_key` cache below never runs on this path
            if !self.packed_done {
                self.run_packed_pass(a_full, b_full, d_full, (m, _k, n));
            }
            self.scratch.clone_from(&self.lane_outs[self.lane]);
            if !self.hardening.is_none() {
                let gold = &self.packed_ws_gold[self.lane_group[self.lane]];
                let flags = Self::mitigate_region(
                    &self.hardening,
                    &self.tmr_protected,
                    &mut self.scratch,
                    |r, c| gold.at(r, c),
                    m,
                    ncols,
                );
                self.note_mitigation(flags);
            }
            let gold = &self.packed_ws_gold[self.lane_group[self.lane]];
            if Self::ws_delta_splice(&self.scratch, gold, out, (m, n), cj, ncols) {
                self.exposed = true;
            }
            return;
        }
        if self.ws_key != Some((ti, tj)) {
            // first trial of this batch on this tile: compute the
            // software prefix psum and pass golden once; later trials
            // reuse them (tile operands are batch-invariant)
            self.ws_key = Some((ti, tj));
            // psum entering the pass: bias + every k-tile before the
            // target — the D stream of the chained hardware execution
            self.ws_d.reset(m, dim);
            for r in 0..m {
                let row = self.ws_d.row_mut(r);
                for c in 0..ncols {
                    let mut acc = d_full.at(r, cj + c);
                    for kk in 0..ri {
                        acc = acc.wrapping_add(
                            a_full.at(r, kk) as i32 * b_full.at(kk, cj + c) as i32,
                        );
                    }
                    row[c] = acc;
                }
            }
            // software golden of THIS pass: prefix psum + tile MACs
            self.ws_gold.reset(m, dim);
            for r in 0..m {
                for c in 0..dim {
                    let mut acc = self.ws_d.at(r, c);
                    for x in 0..dim {
                        acc = acc.wrapping_add(a_t.at(r, x) as i32 * w_t.at(x, c) as i32);
                    }
                    self.ws_gold.set(r, c, acc);
                }
            }
        }
        if self.engine == TileEngine::LaneLockstep && self.backend.supports_lane_lockstep() {
            let ws_d = std::mem::take(&mut self.ws_d);
            self.run_lockstep_tile(a_t, w_t, ws_d.view(), (ti, tj));
            self.ws_d = ws_d;
        } else if matches!(
            self.engine,
            TileEngine::CycleResume | TileEngine::LaneLockstep | TileEngine::PackedLockstep
        ) && self.backend.supports_cycle_resume()
        {
            self.note_cursor_engine(TileEngine::CycleResume);
            match self.backend.run_tile_resumed(
                a_t,
                w_t,
                self.ws_d.view(),
                &self.trial.plan,
                (ti, tj),
                &mut self.cursor,
                &mut self.scratch,
                &mut self.drv,
            ) {
                Ok(cycles) => self.add_scalar_cycles(cycles),
                Err(e) => panic!("resumed tile offload failed for [{}]: {e:#}", self.trial),
            }
        } else {
            match self.backend.run_tile_with(
                a_t,
                w_t,
                self.ws_d.view(),
                &self.trial.plan,
                &mut self.scratch,
                &mut self.drv,
            ) {
                Ok(cycles) => self.add_scalar_cycles(cycles),
                Err(e) => panic!("tile offload failed for [{}]: {e:#}", self.trial),
            }
        }
        if !self.hardening.is_none() {
            let gold = &self.ws_gold;
            let flags = Self::mitigate_region(
                &self.hardening,
                &self.tmr_protected,
                &mut self.scratch,
                |r, c| gold.at(r, c),
                m,
                ncols,
            );
            self.note_mitigation(flags);
        }
        // delta-splice: native + (rtl - gold); untouched where equal
        if Self::ws_delta_splice(&self.scratch, &self.ws_gold, out, (m, n), cj, ncols) {
            self.exposed = true;
        }
    }
}

impl GemmHook for CrossLayerRunner<'_> {
    fn gemm(&mut self, call: &GemmCall<'_>, out: &mut Vec<i32>) -> bool {
        if call.site != self.trial.site || self.hit {
            return false;
        }
        self.hit = true;
        let dim = self.backend.dim();
        let dataflow = self.backend.dataflow();
        let (m, k, n) = (call.m, call.k, call.n);
        // clamp the sampled tile to this call's actual tile grid (shapes
        // can differ between the sampling pass and this input); the grid
        // is the dataflow's ((M, N) output tiles for OS, (K, N) weight
        // tiles for WS)
        let (tiles_i, tiles_j) = tile_grid(dataflow, dim, m, k, n);
        let ti = self.trial.tile_i.min(tiles_i - 1);
        let tj = self.trial.tile_j.min(tiles_j - 1);

        // the layer's operands, viewed in place (flat row-major buffers)
        let a_full = MatView::full(call.a, m, k);
        let b_full = MatView::full(call.b, k, n);
        let d_full = MatView::full(call.d, m, n);

        // native full result first, computed directly into the layer's
        // reusable accumulator — no per-trial allocation
        out.resize(m * n, 0);
        gemm_i8(m, k, n, call.a, call.b, call.d, out);

        if self.scope == OffloadScope::Layer {
            // ablation: run the ENTIRE layer through RTL. Cycle-resume
            // does not apply here — every trial pays the whole layer by
            // design, so the tile prefix is noise; the cycle accounting
            // is the analytic tile count (OS: each tile one full-K pass
            // plus the faulty tile's re-run; WS: one M-stream pass per
            // weight tile of the chain, the fault armed inline).
            let mut cf = self
                .backend
                .run_layer(a_full, b_full, d_full, &self.trial.plan, ti, tj)
                .unwrap_or_else(|e| panic!("layer offload failed for [{}]: {e:#}", self.trial));
            let tiles = (tiles_i * tiles_j) as u64;
            let cycles = match dataflow {
                Dataflow::OutputStationary => (tiles + 1) * os_matmul_cycles(dim, k),
                Dataflow::WeightStationary => tiles * ws_matmul_cycles(dim, m),
            };
            self.add_scalar_cycles(cycles);
            if !self.hardening.is_none() {
                // layer scope mitigates the whole layer as one region
                // (its splice granularity); `out` holds the native
                // golden until the copy below
                let gold: &[i32] = out;
                let flags = Self::mitigate_region(
                    &self.hardening,
                    &self.tmr_protected,
                    &mut cf,
                    |r, c| gold[r * n + c],
                    m,
                    n,
                );
                self.note_mitigation(flags);
            }
            self.exposed = cf.data() != &out[..];
            out.copy_from_slice(cf.data());
            return true;
        }

        match dataflow {
            Dataflow::OutputStationary => {
                self.run_os_tile(a_full, b_full, d_full, (m, k, n), ti, tj, out)
            }
            Dataflow::WeightStationary => {
                self.run_ws_tile(a_full, b_full, d_full, (m, k, n), ti, tj, out)
            }
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Dataflow;
    use crate::dnn::engine::synthetic_input;
    use crate::dnn::models;
    use crate::dnn::GemmSiteId;
    use crate::mesh::{Fault, SignalKind};
    use crate::util::Rng;

    fn a_trial(cycle: u64) -> TrialFault {
        TrialFault::single(
            GemmSiteId { layer: 1, ordinal: 0 },
            0,
            0,
            Fault::new(0, 0, SignalKind::Acc, 30, cycle),
        )
    }

    #[test]
    fn golden_tile_offload_is_transparent() {
        // Offloading a tile WITHOUT corruption must reproduce the native
        // forward pass bit-exactly (RTL accuracy of the mesh).
        let model = models::quicknet(5);
        let mut rng = Rng::new(71);
        let x = synthetic_input(&model.input_shape, &mut rng);
        let golden = model.forward(&x, None);
        // a valid-flip during an idle edge cycle: fully masked
        let mut mesh = Mesh::new(8, Dataflow::OutputStationary);
        let trial = TrialFault::single(
            GemmSiteId { layer: 1, ordinal: 0 },
            0,
            0,
            // valid-flip at the very last flush cycle: no effect
            Fault::new(7, 7, SignalKind::Valid, 0, 1),
        );
        let mut runner =
            CrossLayerRunner::new(&trial, TileBackend::Mesh(&mut mesh), OffloadScope::SingleTile);
        let out = model.forward(&x, Some(&mut runner));
        assert!(runner.hit);
        assert!(!runner.exposed);
        assert_eq!(out, golden);
    }

    #[test]
    fn acc_fault_high_bit_is_exposed() {
        let model = models::quicknet(5);
        let mut rng = Rng::new(72);
        let x = synthetic_input(&model.input_shape, &mut rng);
        let mut mesh = Mesh::new(8, Dataflow::OutputStationary);
        // bit 30 of an accumulator mid-compute: massive corruption
        let trial = a_trial(20);
        let mut runner =
            CrossLayerRunner::new(&trial, TileBackend::Mesh(&mut mesh), OffloadScope::SingleTile);
        let _ = model.forward(&x, Some(&mut runner));
        assert!(runner.hit);
        assert!(runner.exposed);
    }

    #[test]
    fn single_tile_and_layer_scope_agree_on_fault_effect() {
        let model = models::quicknet(5);
        let mut rng = Rng::new(73);
        let x = synthetic_input(&model.input_shape, &mut rng);
        let trial = a_trial(25);

        let mut mesh1 = Mesh::new(8, Dataflow::OutputStationary);
        let mut r1 = CrossLayerRunner::new(
            &trial,
            TileBackend::Mesh(&mut mesh1),
            OffloadScope::SingleTile,
        );
        let out1 = model.forward(&x, Some(&mut r1));

        let mut mesh2 = Mesh::new(8, Dataflow::OutputStationary);
        let mut r2 =
            CrossLayerRunner::new(&trial, TileBackend::Mesh(&mut mesh2), OffloadScope::Layer);
        let out2 = model.forward(&x, Some(&mut r2));

        assert_eq!(out1, out2, "both scopes yield identical faulty outputs");
    }

    #[test]
    fn rearmed_runner_reproduces_fresh_runners() {
        // One runner re-armed across a batch (persistent mesh + scratch
        // tile) must match a fresh mesh + runner per trial bit-exactly.
        let model = models::quicknet(5);
        let mut rng = Rng::new(76);
        let x = synthetic_input(&model.input_shape, &mut rng);
        let trials = [a_trial(20), a_trial(2), a_trial(33)];

        let mut fresh = Vec::new();
        for t in &trials {
            let mut mesh = Mesh::new(8, Dataflow::OutputStationary);
            let mut r = CrossLayerRunner::new(
                t,
                TileBackend::Mesh(&mut mesh),
                OffloadScope::SingleTile,
            );
            let out = model.forward(&x, Some(&mut r));
            fresh.push((out, r.exposed));
        }

        let mut mesh = Mesh::new(8, Dataflow::OutputStationary);
        let mut r = CrossLayerRunner::new(
            &trials[0],
            TileBackend::Mesh(&mut mesh),
            OffloadScope::SingleTile,
        );
        for (i, t) in trials.iter().enumerate() {
            if i > 0 {
                r.arm(t);
            }
            r.backend.reset();
            let out = model.forward(&x, Some(&mut r));
            assert_eq!(out, fresh[i].0, "trial {i} output");
            assert_eq!(r.exposed, fresh[i].1, "trial {i} exposure");
        }
    }

    #[test]
    fn hdfit_backend_reproduces_mesh_backend() {
        let model = models::quicknet(5);
        let mut rng = Rng::new(74);
        let x = synthetic_input(&model.input_shape, &mut rng);
        let trial = a_trial(33);

        let mut mesh = Mesh::new(8, Dataflow::OutputStationary);
        let mut r1 = CrossLayerRunner::new(
            &trial,
            TileBackend::Mesh(&mut mesh),
            OffloadScope::SingleTile,
        );
        let out_mesh = model.forward(&x, Some(&mut r1));

        let mut hm = InstrumentedMesh::new(8);
        let mut r2 = CrossLayerRunner::new(
            &trial,
            TileBackend::Hdfit(&mut hm),
            OffloadScope::SingleTile,
        );
        let out_hdfit = model.forward(&x, Some(&mut r2));
        assert_eq!(out_mesh, out_hdfit);
    }

    #[test]
    fn multi_fault_trial_runs_through_the_hook() {
        // an MBU-style plan (two adjacent Acc bits) must expose at least
        // as much as either single flip, and the hook must classify it
        let model = models::quicknet(5);
        let mut rng = Rng::new(77);
        let x = synthetic_input(&model.input_shape, &mut rng);
        let golden = model.forward(&x, None);
        let site = GemmSiteId { layer: 1, ordinal: 0 };
        let f1 = Fault::new(0, 0, SignalKind::Acc, 30, 20);
        let f2 = Fault::new(0, 0, SignalKind::Acc, 29, 20);
        let trial = TrialFault {
            site,
            tile_i: 0,
            tile_j: 0,
            plan: FaultPlan::new(vec![f1, f2]),
        };
        let mut mesh = Mesh::new(8, Dataflow::OutputStationary);
        let mut runner =
            CrossLayerRunner::new(&trial, TileBackend::Mesh(&mut mesh), OffloadScope::SingleTile);
        let out = model.forward(&x, Some(&mut runner));
        assert!(runner.hit);
        assert!(runner.exposed, "two high Acc bits mid-compute must escape");
        assert_ne!(out, golden);
    }

    #[test]
    fn cycle_resume_runner_matches_full_runners_and_steps_fewer_cycles() {
        // One cycle-resume runner re-armed across a (cycle-sorted) batch
        // must reproduce fresh full-engine runners bit-exactly while
        // stepping strictly fewer RTL cycles (the shared tile prefix is
        // paid once).
        let model = models::quicknet(5);
        let mut rng = Rng::new(78);
        let x = synthetic_input(&model.input_shape, &mut rng);
        // same tile, ascending fault cycles — the order the campaign's
        // batch sort guarantees
        let trials = [a_trial(2), a_trial(20), a_trial(33)];

        let mut full = Vec::new();
        let mut full_cycles = 0u64;
        for t in &trials {
            let mut mesh = Mesh::new(8, Dataflow::OutputStationary);
            let mut r = CrossLayerRunner::new(
                t,
                TileBackend::Mesh(&mut mesh),
                OffloadScope::SingleTile,
            );
            let out = model.forward(&x, Some(&mut r));
            full_cycles += r.rtl_cycles;
            full.push((out, r.exposed));
        }

        let mut mesh = Mesh::new(8, Dataflow::OutputStationary);
        let mut r = CrossLayerRunner::with_engine(
            &trials[0],
            TileBackend::Mesh(&mut mesh),
            OffloadScope::SingleTile,
            TileEngine::CycleResume,
        );
        for (i, t) in trials.iter().enumerate() {
            if i > 0 {
                r.arm(t);
            }
            r.backend.reset();
            let out = model.forward(&x, Some(&mut r));
            assert_eq!(out, full[i].0, "trial {i} output");
            assert_eq!(r.exposed, full[i].1, "trial {i} exposure");
        }
        assert!(
            r.rtl_cycles < full_cycles,
            "cycle-resume stepped {} cycles, full engine {}",
            r.rtl_cycles,
            full_cycles
        );
    }

    #[test]
    fn ws_golden_tile_offload_is_transparent() {
        // A masked WS pass must reproduce the native forward pass
        // bit-exactly: the delta-splice writes nothing when the RTL
        // column equals its software golden.
        let model = models::quicknet(5);
        let mut rng = Rng::new(81);
        let x = synthetic_input(&model.input_shape, &mut rng);
        let golden = model.forward(&x, None);
        let mut mesh = Mesh::new(8, Dataflow::WeightStationary);
        // valid-flip in the preload window of a PE with a zero weight:
        // the stray psum copy is discarded by the fixed drain window
        let trial = TrialFault::single(
            GemmSiteId { layer: 1, ordinal: 0 },
            0,
            0,
            Fault::new(7, 7, SignalKind::Valid, 0, 1),
        );
        let mut runner =
            CrossLayerRunner::new(&trial, TileBackend::Mesh(&mut mesh), OffloadScope::SingleTile);
        let out = model.forward(&x, Some(&mut runner));
        assert!(runner.hit);
        assert!(!runner.exposed);
        assert_eq!(out, golden);
        assert!(runner.rtl_cycles > 0, "the WS pass still ran in RTL");
    }

    #[test]
    fn ws_acc_fault_high_bit_is_exposed() {
        let model = models::quicknet(5);
        let mut rng = Rng::new(82);
        let x = synthetic_input(&model.input_shape, &mut rng);
        let mut mesh = Mesh::new(8, Dataflow::WeightStationary);
        // bit 30 of a psum register mid-stream: massive corruption
        let trial = a_trial(20);
        let mut runner =
            CrossLayerRunner::new(&trial, TileBackend::Mesh(&mut mesh), OffloadScope::SingleTile);
        let _ = model.forward(&x, Some(&mut runner));
        assert!(runner.hit);
        assert!(runner.exposed);
    }

    #[test]
    fn ws_single_tile_and_layer_scope_agree_on_fault_effect() {
        // the chain suffix is exactly linear in the psum, so splicing
        // the single corrupted pass equals chaining it through the
        // whole-layer RTL offload
        let model = models::quicknet(5);
        let mut rng = Rng::new(83);
        let x = synthetic_input(&model.input_shape, &mut rng);
        let trial = a_trial(25);

        let mut mesh1 = Mesh::new(8, Dataflow::WeightStationary);
        let mut r1 = CrossLayerRunner::new(
            &trial,
            TileBackend::Mesh(&mut mesh1),
            OffloadScope::SingleTile,
        );
        let out1 = model.forward(&x, Some(&mut r1));

        let mut mesh2 = Mesh::new(8, Dataflow::WeightStationary);
        let mut r2 =
            CrossLayerRunner::new(&trial, TileBackend::Mesh(&mut mesh2), OffloadScope::Layer);
        let out2 = model.forward(&x, Some(&mut r2));

        assert_eq!(r1.exposed, r2.exposed, "scopes agree on exposure");
        assert_eq!(out1, out2, "both scopes yield identical faulty outputs");
    }

    #[test]
    fn ws_hdfit_backend_reproduces_mesh_backend() {
        let model = models::quicknet(5);
        let mut rng = Rng::new(84);
        let x = synthetic_input(&model.input_shape, &mut rng);
        let trial = a_trial(33);

        let mut mesh = Mesh::new(8, Dataflow::WeightStationary);
        let mut r1 = CrossLayerRunner::new(
            &trial,
            TileBackend::Mesh(&mut mesh),
            OffloadScope::SingleTile,
        );
        let out_mesh = model.forward(&x, Some(&mut r1));

        let mut hm = InstrumentedMesh::with_dataflow(8, Dataflow::WeightStationary);
        let mut r2 = CrossLayerRunner::new(
            &trial,
            TileBackend::Hdfit(&mut hm),
            OffloadScope::SingleTile,
        );
        let out_hdfit = model.forward(&x, Some(&mut r2));
        assert_eq!(r1.exposed, r2.exposed);
        assert_eq!(out_mesh, out_hdfit);
    }

    #[test]
    fn ws_cycle_resume_runner_matches_full_runners_and_steps_fewer_cycles() {
        // the cycle-resume contract on the WS tile path: bit-identical
        // to fresh full-engine runners, strictly fewer RTL cycles once
        // trials share a weight tile
        let model = models::quicknet(5);
        let mut rng = Rng::new(85);
        let x = synthetic_input(&model.input_shape, &mut rng);
        let trials = [a_trial(2), a_trial(20), a_trial(33)];

        let mut full = Vec::new();
        let mut full_cycles = 0u64;
        for t in &trials {
            let mut mesh = Mesh::new(8, Dataflow::WeightStationary);
            let mut r = CrossLayerRunner::new(
                t,
                TileBackend::Mesh(&mut mesh),
                OffloadScope::SingleTile,
            );
            let out = model.forward(&x, Some(&mut r));
            full_cycles += r.rtl_cycles;
            full.push((out, r.exposed));
        }

        let mut mesh = Mesh::new(8, Dataflow::WeightStationary);
        let mut r = CrossLayerRunner::with_engine(
            &trials[0],
            TileBackend::Mesh(&mut mesh),
            OffloadScope::SingleTile,
            TileEngine::CycleResume,
        );
        for (i, t) in trials.iter().enumerate() {
            if i > 0 {
                r.arm(t);
            }
            r.backend.reset();
            let out = model.forward(&x, Some(&mut r));
            assert_eq!(out, full[i].0, "trial {i} output");
            assert_eq!(r.exposed, full[i].1, "trial {i} exposure");
        }
        assert!(
            r.rtl_cycles < full_cycles,
            "cycle-resume stepped {} cycles, full engine {}",
            r.rtl_cycles,
            full_cycles
        );
    }

    #[test]
    fn soc_backend_supports_cycle_resume_but_not_lockstep() {
        let mut soc = Soc::new(4);
        assert!(
            TileBackend::Soc(&mut soc).supports_cycle_resume(),
            "the schedule-indexable SoC controller supports cycle-resume"
        );
        assert!(
            !TileBackend::Soc(&mut soc).supports_lane_lockstep(),
            "the SoC steps one persistent chip: lockstep falls back to cycle-resume"
        );
        let mut mesh = Mesh::new(4, Dataflow::OutputStationary);
        assert!(TileBackend::Mesh(&mut mesh).supports_cycle_resume());
        assert!(TileBackend::Mesh(&mut mesh).supports_lane_lockstep());
        let mut hm = InstrumentedMesh::new(4);
        assert!(TileBackend::Hdfit(&mut hm).supports_cycle_resume());
        assert!(
            !TileBackend::Hdfit(&mut hm).supports_lane_lockstep(),
            "HDFIT hooks are armed per mesh instance: lockstep falls back"
        );
    }

    #[test]
    fn soc_cycle_resume_runner_matches_full_runners_and_steps_fewer_cycles() {
        // The FullSoc cycle-resume contract, both dataflows: one resumed
        // runner over a cycle-sorted same-tile batch reproduces fresh
        // full-engine SoCs bit-exactly while stepping strictly fewer SoC
        // cycles (staging prefix and fence-drain postfix paid once).
        for dataflow in [Dataflow::OutputStationary, Dataflow::WeightStationary] {
            let model = models::quicknet(5);
            let mut rng = Rng::new(87);
            let x = synthetic_input(&model.input_shape, &mut rng);
            let trials = [a_trial(2), a_trial(20), a_trial(33)];

            let mut full = Vec::new();
            let mut full_cycles = 0u64;
            for t in &trials {
                let mut soc = Soc::with_dataflow(4, dataflow);
                let mut r = CrossLayerRunner::new(
                    t,
                    TileBackend::Soc(&mut soc),
                    OffloadScope::SingleTile,
                );
                let out = model.forward(&x, Some(&mut r));
                full_cycles += r.rtl_cycles;
                full.push((out, r.exposed));
            }

            // one resumed runner; reset ONCE (per-batch, like the
            // campaign) — a per-trial reset would invalidate the SoC's
            // resume cursor
            let mut soc = Soc::with_dataflow(4, dataflow);
            let mut r = CrossLayerRunner::with_engine(
                &trials[0],
                TileBackend::Soc(&mut soc),
                OffloadScope::SingleTile,
                TileEngine::CycleResume,
            );
            r.backend.reset();
            for (i, t) in trials.iter().enumerate() {
                if i > 0 {
                    r.arm(t);
                }
                let out = model.forward(&x, Some(&mut r));
                assert_eq!(out, full[i].0, "{dataflow:?}: trial {i} output");
                assert_eq!(r.exposed, full[i].1, "{dataflow:?}: trial {i} exposure");
            }
            assert!(
                r.rtl_cycles < full_cycles,
                "{dataflow:?}: SoC cycle-resume stepped {} cycles, full engine {}",
                r.rtl_cycles,
                full_cycles
            );
        }
    }

    #[test]
    fn lockstep_chunk_matches_full_runners_and_steps_fewer_cycles() {
        // The trial-lockstep contract, both dataflows: a whole chunk
        // armed via begin_chunk/arm_lane must reproduce fresh
        // full-engine runners bit-exactly (output AND exposure), while
        // stepping strictly fewer RTL cycles than per-trial cycle-resume
        // — the chunk's tile suffix is paid once, not once per trial.
        for dataflow in [Dataflow::OutputStationary, Dataflow::WeightStationary] {
            let model = models::quicknet(5);
            let mut rng = Rng::new(86);
            let x = synthetic_input(&model.input_shape, &mut rng);
            let trials = [a_trial(2), a_trial(20), a_trial(33)];

            let mut full = Vec::new();
            for t in &trials {
                let mut mesh = Mesh::new(8, dataflow);
                let mut r = CrossLayerRunner::new(
                    t,
                    TileBackend::Mesh(&mut mesh),
                    OffloadScope::SingleTile,
                );
                let out = model.forward(&x, Some(&mut r));
                full.push((out, r.exposed));
            }

            // per-trial cycle-resume cycle count: the lockstep baseline
            let mut mesh = Mesh::new(8, dataflow);
            let mut r = CrossLayerRunner::with_engine(
                &trials[0],
                TileBackend::Mesh(&mut mesh),
                OffloadScope::SingleTile,
                TileEngine::CycleResume,
            );
            for (i, t) in trials.iter().enumerate() {
                if i > 0 {
                    r.arm(t);
                }
                r.backend.reset();
                let _ = model.forward(&x, Some(&mut r));
            }
            let resume_cycles = r.rtl_cycles;

            let mut mesh = Mesh::new(8, dataflow);
            let mut r = CrossLayerRunner::with_engine(
                &trials[0],
                TileBackend::Mesh(&mut mesh),
                OffloadScope::SingleTile,
                TileEngine::LaneLockstep,
            );
            r.begin_chunk(trials.iter().map(|t| &t.plan).collect());
            for (lane, t) in trials.iter().enumerate() {
                r.arm_lane(t, lane);
                r.backend.reset();
                let out = model.forward(&x, Some(&mut r));
                assert_eq!(out, full[lane].0, "{dataflow} trial {lane} output");
                assert_eq!(r.exposed, full[lane].1, "{dataflow} trial {lane} exposure");
            }
            assert!(
                r.rtl_cycles < resume_cycles,
                "{dataflow}: lockstep stepped {} cycles, cycle-resume {}",
                r.rtl_cycles,
                resume_cycles
            );
        }
    }

    #[test]
    fn lockstep_single_trial_arm_matches_cycle_resume_cycles() {
        // Legacy arm() under lane-lockstep OR packed-lockstep = a
        // one-lane chunk per trial: bit-identical results and EXACTLY
        // the cycle-resume cycle count (one lane pays the same advance +
        // suffix as a resumed trial).
        let model = models::quicknet(5);
        let mut rng = Rng::new(87);
        let x = synthetic_input(&model.input_shape, &mut rng);
        let trials = [a_trial(2), a_trial(20)];
        let mut outs = Vec::new();
        let mut cycles = Vec::new();
        for engine in [
            TileEngine::CycleResume,
            TileEngine::LaneLockstep,
            TileEngine::PackedLockstep,
        ] {
            let mut mesh = Mesh::new(8, Dataflow::OutputStationary);
            let mut r = CrossLayerRunner::with_engine(
                &trials[0],
                TileBackend::Mesh(&mut mesh),
                OffloadScope::SingleTile,
                engine,
            );
            let mut got = Vec::new();
            for (i, t) in trials.iter().enumerate() {
                if i > 0 {
                    r.arm(t);
                }
                r.backend.reset();
                got.push(model.forward(&x, Some(&mut r)));
            }
            outs.push(got);
            cycles.push(r.rtl_cycles);
        }
        assert_eq!(outs[0], outs[1], "one-lane lockstep != cycle-resume");
        assert_eq!(cycles[0], cycles[1], "one-lane lockstep cycle count");
        assert_eq!(outs[0], outs[2], "one-lane packed != cycle-resume");
        assert_eq!(cycles[0], cycles[2], "one-lane packed cycle count");
    }

    #[test]
    fn packed_chunk_matches_full_runners_and_beats_lane_lockstep() {
        // The packed-lockstep contract, both dataflows: whole same-tile
        // runs packed side by side in ONE chunk must reproduce fresh
        // full-engine runners bit-exactly (output AND exposure) while
        // stepping strictly fewer RTL cycles than lane-lockstep paying
        // each run's suffix separately — packed pays max over runs, not
        // sum — and at strictly better lane occupancy.
        fn tile_trial(tile_i: usize, tile_j: usize, cycle: u64) -> TrialFault {
            TrialFault::single(
                GemmSiteId { layer: 1, ordinal: 0 },
                tile_i,
                tile_j,
                Fault::new(0, 0, SignalKind::Acc, 30, cycle),
            )
        }
        for dataflow in [Dataflow::OutputStationary, Dataflow::WeightStationary] {
            let model = models::quicknet(5);
            let mut rng = Rng::new(88);
            let x = synthetic_input(&model.input_shape, &mut rng);
            // two maximal same-tile runs: [trial 0, trial 1] and [trial 2]
            let trials = [tile_trial(0, 0, 2), tile_trial(0, 0, 20), tile_trial(0, 1, 5)];
            let runs: [&[usize]; 2] = [&[0, 1], &[2]];

            let mut full = Vec::new();
            for t in &trials {
                let mut mesh = Mesh::new(8, dataflow);
                let mut r = CrossLayerRunner::new(
                    t,
                    TileBackend::Mesh(&mut mesh),
                    OffloadScope::SingleTile,
                );
                let out = model.forward(&x, Some(&mut r));
                full.push((out, r.exposed));
            }

            // lane-lockstep baseline: one chunk per same-tile run
            let mut mesh = Mesh::new(8, dataflow);
            let mut r = CrossLayerRunner::with_engine(
                &trials[0],
                TileBackend::Mesh(&mut mesh),
                OffloadScope::SingleTile,
                TileEngine::LaneLockstep,
            );
            r.lane_capacity = 3;
            for run in runs {
                r.begin_chunk(run.iter().map(|&i| &trials[i].plan).collect());
                for (lane, &i) in run.iter().enumerate() {
                    r.arm_lane(&trials[i], lane);
                    r.backend.reset();
                    let _ = model.forward(&x, Some(&mut r));
                }
            }
            let lockstep_cycles = r.rtl_cycles;
            let lockstep_occ = r.lane_cycles_filled as f64 / r.lane_cycles_stepped as f64;

            // packed: both runs side by side in ONE chunk
            let mut mesh = Mesh::new(8, dataflow);
            let mut r = CrossLayerRunner::with_engine(
                &trials[0],
                TileBackend::Mesh(&mut mesh),
                OffloadScope::SingleTile,
                TileEngine::PackedLockstep,
            );
            r.lane_capacity = 3;
            r.begin_packed_chunk(vec![
                PackedGroup {
                    tile_i: 0,
                    tile_j: 0,
                    plans: vec![&trials[0].plan, &trials[1].plan],
                },
                PackedGroup { tile_i: 0, tile_j: 1, plans: vec![&trials[2].plan] },
            ]);
            for (lane, t) in trials.iter().enumerate() {
                r.arm_lane(t, lane);
                r.backend.reset();
                let out = model.forward(&x, Some(&mut r));
                assert_eq!(out, full[lane].0, "{dataflow} trial {lane} output");
                assert_eq!(r.exposed, full[lane].1, "{dataflow} trial {lane} exposure");
            }
            assert!(
                r.rtl_cycles < lockstep_cycles,
                "{dataflow}: packed stepped {} cycles, lane-lockstep {}",
                r.rtl_cycles,
                lockstep_cycles
            );
            let packed_occ = r.lane_cycles_filled as f64 / r.lane_cycles_stepped as f64;
            assert!(
                packed_occ > lockstep_occ,
                "{dataflow}: packed occupancy {packed_occ} must beat lockstep {lockstep_occ}"
            );
        }
    }

    #[test]
    fn abft_hardening_corrects_a_single_element_and_masks_the_trial() {
        // Acc bit 30 of PE (0,0) mid-compute is a single-element error
        // under OS (wrapping adds keep the delta exactly +/-2^30), so
        // the idealized ABFT checksums must locate and subtract it: the
        // trial strikes, detects, corrects — and the corrected splice
        // writes nothing, reproducing golden logits bit-exactly.
        let model = models::quicknet(5);
        let mut rng = Rng::new(90);
        let x = synthetic_input(&model.input_shape, &mut rng);
        let golden = model.forward(&x, None);
        let trial = a_trial(20);
        let mut mesh = Mesh::new(8, Dataflow::OutputStationary);
        let mut r =
            CrossLayerRunner::new(&trial, TileBackend::Mesh(&mut mesh), OffloadScope::SingleTile);
        r.hardening = HardeningConfig::parse("abft").expect("valid hardening");
        let out = model.forward(&x, Some(&mut r));
        assert!(r.hit);
        assert!(r.mit_struck, "bit 30 of an accumulator mid-compute escapes the array");
        assert!(r.mit_detected, "the row/column checksums flag the struck tile");
        assert!(r.mit_corrected, "a single corrupted element single-error-corrects");
        assert!(!r.exposed, "the corrected splice writes nothing");
        assert_eq!(out, golden, "ABFT-corrected trial reproduces golden logits");
    }

    #[test]
    fn tmr_protected_column_outvotes_the_fault() {
        // the fault sits in PE (0,0); under OS its corruption drains
        // from PE column 0 — voting that column restores the tile
        let model = models::quicknet(5);
        let mut rng = Rng::new(91);
        let x = synthetic_input(&model.input_shape, &mut rng);
        let golden = model.forward(&x, None);
        let trial = a_trial(20);
        let mut mesh = Mesh::new(8, Dataflow::OutputStationary);
        let mut r =
            CrossLayerRunner::new(&trial, TileBackend::Mesh(&mut mesh), OffloadScope::SingleTile);
        r.hardening = HardeningConfig::parse("tmr:1").expect("valid hardening");
        let mut protected = vec![false; 8];
        protected[0] = true;
        r.tmr_protected = protected;
        let out = model.forward(&x, Some(&mut r));
        assert!(r.mit_struck);
        assert!(r.mit_corrected, "the protected column outvotes the flip");
        assert!(!r.mit_detected, "TMR corrects silently (no detector armed)");
        assert!(!r.exposed);
        assert_eq!(out, golden);
    }

    #[test]
    fn clip_hardening_clamps_without_detecting() {
        // range clipping bounds the corruption magnitude but raises no
        // detection signal; a 2^30 accumulator flip stays struck (and
        // exposed) with its spliced elements clamped into range
        let model = models::quicknet(5);
        let mut rng = Rng::new(92);
        let x = synthetic_input(&model.input_shape, &mut rng);
        let trial = a_trial(20);
        let mut mesh = Mesh::new(8, Dataflow::OutputStationary);
        let mut r =
            CrossLayerRunner::new(&trial, TileBackend::Mesh(&mut mesh), OffloadScope::SingleTile);
        r.hardening = HardeningConfig::parse("clip:-1000,1000").expect("valid hardening");
        let _ = model.forward(&x, Some(&mut r));
        assert!(r.mit_struck);
        assert!(!r.mit_detected, "clipping is a silent mitigation");
    }

    #[test]
    fn none_hardening_keeps_the_legacy_seam_flags() {
        // `--hardening none` (the default) must not touch the mitigation
        // flags at all — the byte-identity contract of the report
        let model = models::quicknet(5);
        let mut rng = Rng::new(93);
        let x = synthetic_input(&model.input_shape, &mut rng);
        let trial = a_trial(20);
        let mut mesh = Mesh::new(8, Dataflow::OutputStationary);
        let mut r =
            CrossLayerRunner::new(&trial, TileBackend::Mesh(&mut mesh), OffloadScope::SingleTile);
        let _ = model.forward(&x, Some(&mut r));
        assert!(r.exposed, "the unhardened trial is exposed");
        assert!(!r.mit_struck && !r.mit_detected && !r.mit_corrected);
    }

    #[test]
    fn soc_layer_offload_bails_before_any_work() {
        let dim = 4;
        let mut soc = Soc::new(dim);
        let mut backend = TileBackend::Soc(&mut soc);
        let mut rng = Rng::new(75);
        let a = rng.mat_i8(dim, dim);
        let b = rng.mat_i8(dim, dim);
        let d = rng.mat_i32(dim, dim, 10);
        let plan = FaultPlan::single(Fault::new(0, 0, SignalKind::Acc, 0, 0));
        let err = backend
            .run_layer(a.view(), b.view(), d.view(), &plan, 0, 0)
            .unwrap_err();
        assert!(format!("{err}").contains("not supported"));
    }
}
