//! The cross-layer trial runner: software inference with exactly one
//! tile offloaded to an RTL backend (paper Fig. 4).
//!
//! Implemented as a [`GemmHook`]: the forward pass runs on the native
//! software path until the target GEMM site is reached; there, the
//! runner extracts the one DIM-padded operand tile the sampled fault
//! lands in, executes it on the RTL backend with the fault armed, and
//! splices the (possibly corrupted) int32 tile back into the layer's
//! accumulator — the rest of the inference continues in software.

use super::fault::TrialFault;
use crate::config::OffloadScope;
use crate::dnn::gemm::gemm_i8;
use crate::dnn::layers::{GemmCall, GemmHook};
use crate::mesh::driver::{tiled_matmul_os, MatI32, MatI8, MatmulDriver};
use crate::mesh::hdfit::InstrumentedMesh;

use crate::mesh::{Fault, Mesh, MeshSim};
use crate::soc::Soc;

/// Which simulator executes the offloaded tile.
pub enum TileBackend<'a> {
    /// ENFOR-SA mesh-only RTL.
    Mesh(&'a mut Mesh),
    /// HDFIT-style instrumented mesh-only RTL.
    Hdfit(&'a mut InstrumentedMesh),
    /// Whole-SoC RTL (core drives the matmul).
    Soc(&'a mut Soc),
}

impl<'a> TileBackend<'a> {
    pub fn dim(&self) -> usize {
        match self {
            TileBackend::Mesh(m) => m.dim(),
            TileBackend::Hdfit(m) => m.dim(),
            TileBackend::Soc(s) => s.dim(),
        }
    }

    /// Run one DIM x DIM-output tile matmul (full-K stream), with an
    /// optional transient fault.
    pub fn run_tile(
        &mut self,
        a: &MatI8,
        b: &MatI8,
        d: &MatI32,
        fault: Option<&Fault>,
    ) -> anyhow::Result<MatI32> {
        Ok(match self {
            TileBackend::Mesh(m) => match fault {
                Some(f) => MatmulDriver::new(*m).matmul_with_fault(a, b, d, f),
                None => MatmulDriver::new(*m).matmul(a, b, d),
            },
            TileBackend::Hdfit(m) => match fault {
                Some(f) => MatmulDriver::new(*m).matmul_with_fault(a, b, d, f),
                None => MatmulDriver::new(*m).matmul(a, b, d),
            },
            TileBackend::Soc(s) => s.run_matmul(a, b, d, fault.copied())?,
        })
    }

    /// Whole-layer offload (ablation D3): every tile through RTL, the
    /// fault armed only on the target tile.
    #[allow(clippy::too_many_arguments)]
    pub fn run_layer(
        &mut self,
        a: &MatI8,
        b: &MatI8,
        d: &MatI32,
        fault: &Fault,
        tile_i: usize,
        tile_j: usize,
    ) -> anyhow::Result<MatI32> {
        let dim = self.dim();
        let m = a.len();
        let n = if b.is_empty() { 0 } else { b[0].len() };
        // fault tile computed with fault, all others fault-free
        let mut c = match self {
            TileBackend::Mesh(mesh) => tiled_matmul_os(*mesh, a, b, d),
            TileBackend::Hdfit(mesh) => tiled_matmul_os(*mesh, a, b, d),
            TileBackend::Soc(_) => {
                anyhow::bail!("whole-layer offload through the SoC is not supported")
            }
        };
        // redo the faulty tile with the fault and splice
        let (ti, tj) = (tile_i * dim, tile_j * dim);
        let k = if m == 0 { 0 } else { a[0].len() };
        let a_tile: MatI8 = (0..dim)
            .map(|r| if ti + r < m { a[ti + r].clone() } else { vec![0; k] })
            .collect();
        let b_tile: MatI8 = (0..k)
            .map(|r| {
                (0..dim)
                    .map(|cc| if tj + cc < n { b[r][tj + cc] } else { 0 })
                    .collect()
            })
            .collect();
        let d_tile: MatI32 = (0..dim)
            .map(|r| {
                (0..dim)
                    .map(|cc| {
                        if ti + r < m && tj + cc < n {
                            d[ti + r][tj + cc]
                        } else {
                            0
                        }
                    })
                    .collect()
            })
            .collect();
        let c_tile = self.run_tile(&a_tile, &b_tile, &d_tile, Some(fault))?;
        for r in 0..dim {
            for cc in 0..dim {
                if ti + r < m && tj + cc < n {
                    c[ti + r][tj + cc] = c_tile[r][cc];
                }
            }
        }
        Ok(c)
    }
}

/// GEMM hook that performs the cross-layer offload for one trial.
pub struct CrossLayerRunner<'a> {
    pub trial: TrialFault,
    pub backend: TileBackend<'a>,
    pub scope: OffloadScope,
    /// Set when the target site was reached.
    pub hit: bool,
    /// Set when the RTL tile differed from the fault-free tile (the
    /// fault was *exposed* to the software layer — paper Fig. 5b).
    pub exposed: bool,
}

impl<'a> CrossLayerRunner<'a> {
    pub fn new(trial: TrialFault, backend: TileBackend<'a>, scope: OffloadScope) -> Self {
        CrossLayerRunner {
            trial,
            backend,
            scope,
            hit: false,
            exposed: false,
        }
    }
}

impl GemmHook for CrossLayerRunner<'_> {
    fn gemm(&mut self, call: &GemmCall<'_>) -> Option<Vec<i32>> {
        if call.site != self.trial.site || self.hit {
            return None;
        }
        self.hit = true;
        let dim = self.backend.dim();
        let (m, k, n) = (call.m, call.k, call.n);
        // clamp the sampled tile to this call's actual tile grid (shapes
        // can differ between the sampling pass and this input)
        let ti = self.trial.tile_i.min(m.div_ceil(dim) - 1);
        let tj = self.trial.tile_j.min(n.div_ceil(dim) - 1);

        // native full result first
        let mut c = vec![0i32; m * n];
        gemm_i8(m, k, n, call.a, call.b, call.d, &mut c);

        if self.scope == OffloadScope::Layer {
            // ablation: run the ENTIRE layer through RTL
            let a2: MatI8 = (0..m).map(|r| call.a[r * k..(r + 1) * k].to_vec()).collect();
            let b2: MatI8 = (0..k).map(|r| call.b[r * n..(r + 1) * n].to_vec()).collect();
            let d2: MatI32 = (0..m).map(|r| call.d[r * n..(r + 1) * n].to_vec()).collect();
            let cf = self
                .backend
                .run_layer(&a2, &b2, &d2, &self.trial.fault, ti, tj)
                .expect("layer offload failed");
            let flat: Vec<i32> = cf.into_iter().flatten().collect();
            self.exposed = flat != c;
            return Some(flat);
        }

        // ENFOR-SA single-tile offload: extract the DIM-padded tile
        let (ri, cj) = (ti * dim, tj * dim);
        let a_tile: MatI8 = (0..dim)
            .map(|r| {
                if ri + r < m {
                    call.a[(ri + r) * k..(ri + r + 1) * k].to_vec()
                } else {
                    vec![0; k]
                }
            })
            .collect();
        let b_tile: MatI8 = (0..k)
            .map(|r| {
                (0..dim)
                    .map(|cc| if cj + cc < n { call.b[r * n + cj + cc] } else { 0 })
                    .collect()
            })
            .collect();
        let d_tile: MatI32 = (0..dim)
            .map(|r| {
                (0..dim)
                    .map(|cc| {
                        if ri + r < m && cj + cc < n {
                            call.d[(ri + r) * n + cj + cc]
                        } else {
                            0
                        }
                    })
                    .collect()
            })
            .collect();
        let c_tile = self
            .backend
            .run_tile(&a_tile, &b_tile, &d_tile, Some(&self.trial.fault))
            .expect("tile offload failed");
        // splice the RTL tile back into the accumulator
        for r in 0..dim {
            for cc in 0..dim {
                if ri + r < m && cj + cc < n {
                    let idx = (ri + r) * n + cj + cc;
                    if c[idx] != c_tile[r][cc] {
                        self.exposed = true;
                        c[idx] = c_tile[r][cc];
                    }
                }
            }
        }
        Some(c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Dataflow;
    use crate::dnn::engine::synthetic_input;
    use crate::dnn::models;
    use crate::dnn::GemmSiteId;
    use crate::mesh::SignalKind;
    use crate::util::Rng;

    fn a_trial(cycle: u64) -> TrialFault {
        TrialFault {
            site: GemmSiteId { layer: 1, ordinal: 0 },
            tile_i: 0,
            tile_j: 0,
            fault: Fault::new(0, 0, SignalKind::Acc, 30, cycle),
        }
    }

    #[test]
    fn golden_tile_offload_is_transparent() {
        // Offloading a tile WITHOUT corruption must reproduce the native
        // forward pass bit-exactly (RTL accuracy of the mesh).
        let model = models::quicknet(5);
        let mut rng = Rng::new(71);
        let x = synthetic_input(&model.input_shape, &mut rng);
        let golden = model.forward(&x, None);
        // a propag fault during an idle edge cycle: fully masked
        let mut mesh = Mesh::new(8, Dataflow::OutputStationary);
        let trial = TrialFault {
            site: GemmSiteId { layer: 1, ordinal: 0 },
            tile_i: 0,
            tile_j: 0,
            // valid-flip at the very last flush cycle: no effect
            fault: Fault::new(7, 7, SignalKind::Valid, 0, 1),
        };
        let mut runner =
            CrossLayerRunner::new(trial, TileBackend::Mesh(&mut mesh), OffloadScope::SingleTile);
        let out = model.forward(&x, Some(&mut runner));
        assert!(runner.hit);
        assert!(!runner.exposed);
        assert_eq!(out, golden);
    }

    #[test]
    fn acc_fault_high_bit_is_exposed() {
        let model = models::quicknet(5);
        let mut rng = Rng::new(72);
        let x = synthetic_input(&model.input_shape, &mut rng);
        let mut mesh = Mesh::new(8, Dataflow::OutputStationary);
        // bit 30 of an accumulator mid-compute: massive corruption
        let trial = a_trial(20);
        let mut runner =
            CrossLayerRunner::new(trial, TileBackend::Mesh(&mut mesh), OffloadScope::SingleTile);
        let _ = model.forward(&x, Some(&mut runner));
        assert!(runner.hit);
        assert!(runner.exposed);
    }

    #[test]
    fn single_tile_and_layer_scope_agree_on_fault_effect() {
        let model = models::quicknet(5);
        let mut rng = Rng::new(73);
        let x = synthetic_input(&model.input_shape, &mut rng);
        let trial = a_trial(25);

        let mut mesh1 = Mesh::new(8, Dataflow::OutputStationary);
        let mut r1 = CrossLayerRunner::new(
            trial,
            TileBackend::Mesh(&mut mesh1),
            OffloadScope::SingleTile,
        );
        let out1 = model.forward(&x, Some(&mut r1));

        let mut mesh2 = Mesh::new(8, Dataflow::OutputStationary);
        let mut r2 =
            CrossLayerRunner::new(trial, TileBackend::Mesh(&mut mesh2), OffloadScope::Layer);
        let out2 = model.forward(&x, Some(&mut r2));

        assert_eq!(out1, out2, "both scopes yield identical faulty outputs");
    }

    #[test]
    fn hdfit_backend_reproduces_mesh_backend() {
        let model = models::quicknet(5);
        let mut rng = Rng::new(74);
        let x = synthetic_input(&model.input_shape, &mut rng);
        let trial = a_trial(33);

        let mut mesh = Mesh::new(8, Dataflow::OutputStationary);
        let mut r1 = CrossLayerRunner::new(
            trial,
            TileBackend::Mesh(&mut mesh),
            OffloadScope::SingleTile,
        );
        let out_mesh = model.forward(&x, Some(&mut r1));

        let mut hm = InstrumentedMesh::new(8);
        let mut r2 = CrossLayerRunner::new(
            trial,
            TileBackend::Hdfit(&mut hm),
            OffloadScope::SingleTile,
        );
        let out_hdfit = model.forward(&x, Some(&mut r2));
        assert_eq!(out_mesh, out_hdfit);
    }
}
