//! Flat, stride-aware matrices — the data-layout contract across the
//! software ↔ RTL boundary.
//!
//! # Why this module exists
//!
//! ENFOR-SA's headline claim is that RTL-accurate injection costs only a
//! few percent over software-only injection. That margin dies if every
//! tile crossing the software↔RTL seam is marshalled through nested
//! `Vec<Vec<T>>` matrices: one heap allocation per row, row-by-row
//! clones on tile extraction, and pointer-chasing in the mesh streaming
//! loops. The DNN side already computes on flat row-major buffers
//! ([`crate::dnn::gemm::gemm_i8`]), so the nested representation was a
//! seam artifact, not a design choice.
//!
//! # The contract
//!
//! * [`Mat<T>`] — an owned, contiguous, row-major `rows x cols` matrix.
//!   Element `(r, c)` lives at `data[r * cols + c]`. This is exactly the
//!   layout of the DNN layer buffers (`GemmCall::a/b/d`), the Pallas
//!   kernels' operands, and the scratchpad rows of the SoC model.
//! * [`MatView<T>`] — a borrowed, stride-aware window into a flat
//!   buffer. Reads outside the in-bounds region of the parent return
//!   `T::default()` (zero): the view *is* the DIM-padded tile the mesh
//!   drivers need, with no copy and no allocation. Extracting the
//!   operand tile a sampled fault lands in is O(1).
//! * [`MatViewMut<T>`] — the mutable counterpart, used to splice a
//!   (possibly corrupted) result tile back into the layer's flat
//!   accumulator with one strided copy. Writes that fall in the
//!   zero-padding are dropped, mirroring how the real drain FSM discards
//!   out-of-bounds lanes.
//!
//! Every layer that crosses the boundary — `mesh/driver.rs`,
//! `mesh/adapters.rs`, `campaign/runner.rs`, `soc/soc.rs` — speaks these
//! types; `rust/tests/prop_mat.rs` pins the view semantics against a
//! nested-matrix extraction oracle.

use std::ops::{Index, IndexMut};

/// Owned, contiguous, row-major matrix.
#[derive(Debug, PartialEq, Eq)]
pub struct Mat<T> {
    rows: usize,
    cols: usize,
    data: Vec<T>,
}

impl<T: Clone> Clone for Mat<T> {
    fn clone(&self) -> Mat<T> {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.clone(),
        }
    }

    /// Reshape to `src`'s shape and copy its contents, reusing the
    /// existing allocation whenever capacity allows — the cycle-resume
    /// prime path (trial result := golden prefix) calls this per trial,
    /// so it must not allocate once warm.
    fn clone_from(&mut self, src: &Mat<T>) {
        self.rows = src.rows;
        self.cols = src.cols;
        self.data.clone_from(&src.data);
    }
}

impl<T> Default for Mat<T> {
    /// An empty `0 x 0` matrix — no allocation; the seed value for
    /// buffers that are later reshaped in place with [`Mat::reset`].
    fn default() -> Mat<T> {
        Mat {
            rows: 0,
            cols: 0,
            data: Vec::new(),
        }
    }
}

impl<T: Copy + Default> Mat<T> {
    /// A `rows x cols` matrix of `T::default()`.
    pub fn zeros(rows: usize, cols: usize) -> Mat<T> {
        Mat {
            rows,
            cols,
            data: vec![T::default(); rows * cols],
        }
    }

    /// A matrix filled with one value.
    pub fn filled(rows: usize, cols: usize, value: T) -> Mat<T> {
        Mat {
            rows,
            cols,
            data: vec![value; rows * cols],
        }
    }

    /// Wrap an existing flat row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<T>) -> Mat<T> {
        assert_eq!(data.len(), rows * cols, "flat buffer length mismatch");
        Mat { rows, cols, data }
    }

    /// Build element-wise in row-major order (row 0 first — the order
    /// matters for deterministic RNG-driven fills).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> T) -> Mat<T> {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Mat { rows, cols, data }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// The flat row-major buffer.
    pub fn data(&self) -> &[T] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [T] {
        &mut self.data
    }

    /// Consume into the flat row-major buffer.
    pub fn into_vec(self) -> Vec<T> {
        self.data
    }

    /// One row as a slice.
    #[inline]
    pub fn row(&self, r: usize) -> &[T] {
        &self.data[r * self.cols..r * self.cols + self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [T] {
        &mut self.data[r * self.cols..r * self.cols + self.cols]
    }

    /// Iterate rows as slices.
    pub fn row_iter(&self) -> impl Iterator<Item = &[T]> {
        (0..self.rows).map(move |r| self.row(r))
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> T {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: T) {
        self.data[r * self.cols + c] = v;
    }

    /// Reshape to `rows x cols` and zero-fill, reusing the existing
    /// allocation whenever the element count matches. This is the arena
    /// primitive behind per-trial result-buffer reuse: the campaign
    /// runner and the matmul drivers call it instead of allocating a
    /// fresh result [`Mat`] per trial.
    pub fn reset(&mut self, rows: usize, cols: usize) {
        self.rows = rows;
        self.cols = cols;
        if self.data.len() == rows * cols {
            self.data.fill(T::default());
        } else {
            self.data.clear();
            self.data.resize(rows * cols, T::default());
        }
    }

    /// Borrow the whole matrix as a view.
    #[inline]
    pub fn view(&self) -> MatView<'_, T> {
        MatView::full(&self.data, self.rows, self.cols)
    }

    /// A zero-padded `rows x cols` window starting at `(r0, c0)`.
    #[inline]
    pub fn window(&self, r0: usize, c0: usize, rows: usize, cols: usize) -> MatView<'_, T> {
        self.view().sub(r0, c0, rows, cols)
    }

    /// Mutable window (out-of-bounds writes are dropped).
    #[inline]
    pub fn window_mut(
        &mut self,
        r0: usize,
        c0: usize,
        rows: usize,
        cols: usize,
    ) -> MatViewMut<'_, T> {
        let (sr, sc) = (self.rows, self.cols);
        MatViewMut::window(&mut self.data, sr, sc, sc, r0, c0, rows, cols)
    }
}

impl<T> Index<(usize, usize)> for Mat<T> {
    type Output = T;

    #[inline]
    fn index(&self, (r, c): (usize, usize)) -> &T {
        debug_assert!(r < self.rows && c < self.cols);
        &self.data[r * self.cols + c]
    }
}

impl<T> IndexMut<(usize, usize)> for Mat<T> {
    #[inline]
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut T {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }
}

/// Clamp a `rows x cols` window at `(r0, c0)` against the in-bounds
/// `src_rows x src_cols` region of a strided buffer. Returns the
/// in-bounds extent and the backing element range (empty when the
/// window lies entirely in the padding). Single home of the window
/// bounds arithmetic shared by [`MatView::sub`] and
/// [`MatViewMut::window`].
#[allow(clippy::too_many_arguments)]
fn clamp_window(
    src_rows: usize,
    src_cols: usize,
    stride: usize,
    r0: usize,
    c0: usize,
    rows: usize,
    cols: usize,
) -> (usize, usize, std::ops::Range<usize>) {
    let in_rows = src_rows.saturating_sub(r0).min(rows);
    let in_cols = src_cols.saturating_sub(c0).min(cols);
    let range = if in_rows == 0 || in_cols == 0 {
        0..0
    } else {
        let start = r0 * stride + c0;
        start..start + (in_rows - 1) * stride + in_cols
    };
    (in_rows, in_cols, range)
}

/// Borrowed, stride-aware window with implicit zero padding outside the
/// parent's bounds. `Copy`, pointer-sized: passing one is free.
#[derive(Clone, Copy, Debug)]
pub struct MatView<'a, T> {
    /// Backing elements, starting at the window origin. Covers only the
    /// in-bounds region; the last in-bounds row extends `in_cols`, not
    /// `stride`.
    data: &'a [T],
    /// Parent row stride (elements between vertically adjacent cells).
    stride: usize,
    /// Logical window height (includes zero padding).
    rows: usize,
    /// Logical window width (includes zero padding).
    cols: usize,
    /// Rows actually backed by the parent (`<= rows`).
    in_rows: usize,
    /// Columns actually backed by the parent (`<= cols`).
    in_cols: usize,
}

impl<'a, T: Copy + Default> MatView<'a, T> {
    /// View an entire flat row-major `rows x cols` buffer.
    #[inline]
    pub fn full(data: &'a [T], rows: usize, cols: usize) -> MatView<'a, T> {
        assert_eq!(data.len(), rows * cols, "flat buffer length mismatch");
        MatView {
            data,
            stride: cols,
            rows,
            cols,
            in_rows: rows,
            in_cols: cols,
        }
    }

    /// Logical window height (padding included).
    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Logical window width (padding included).
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Read `(r, c)`; zero (`T::default()`) outside the parent's bounds.
    #[inline]
    pub fn at(&self, r: usize, c: usize) -> T {
        debug_assert!(r < self.rows && c < self.cols, "view read out of window");
        if r < self.in_rows && c < self.in_cols {
            self.data[r * self.stride + c]
        } else {
            T::default()
        }
    }

    /// A zero-padded sub-window (window coordinates). Padding composes:
    /// a sub-window of a padded region reads as zeros.
    pub fn sub(&self, r0: usize, c0: usize, rows: usize, cols: usize) -> MatView<'a, T> {
        let (in_rows, in_cols, range) =
            clamp_window(self.in_rows, self.in_cols, self.stride, r0, c0, rows, cols);
        MatView {
            data: &self.data[range],
            stride: self.stride,
            rows,
            cols,
            in_rows,
            in_cols,
        }
    }

    /// Copy row `r` (zero-padded) into `out` (`out.len() == cols`).
    /// Allocation-free staging for the SoC memory/scratchpad paths.
    pub fn copy_row_into(&self, r: usize, out: &mut [T]) {
        debug_assert_eq!(out.len(), self.cols);
        if r < self.in_rows {
            let src = &self.data[r * self.stride..r * self.stride + self.in_cols];
            out[..self.in_cols].copy_from_slice(src);
            out[self.in_cols..].fill(T::default());
        } else {
            out.fill(T::default());
        }
    }

    /// Materialize the (padded) window as an owned [`Mat`].
    pub fn to_mat(&self) -> Mat<T> {
        let mut m = Mat::zeros(self.rows, self.cols);
        for r in 0..self.in_rows {
            m.row_mut(r)[..self.in_cols]
                .copy_from_slice(&self.data[r * self.stride..r * self.stride + self.in_cols]);
        }
        m
    }
}

/// Mutable stride-aware window: the splice path back into a layer's flat
/// accumulator. Writes landing in the zero-padding are dropped.
#[derive(Debug)]
pub struct MatViewMut<'a, T> {
    data: &'a mut [T],
    stride: usize,
    rows: usize,
    cols: usize,
    in_rows: usize,
    in_cols: usize,
}

impl<'a, T: Copy + Default> MatViewMut<'a, T> {
    /// Mutable `rows x cols` window at `(r0, c0)` of a flat
    /// `src_rows x src_cols` buffer with row stride `stride`.
    #[allow(clippy::too_many_arguments)]
    pub fn window(
        data: &'a mut [T],
        src_rows: usize,
        src_cols: usize,
        stride: usize,
        r0: usize,
        c0: usize,
        rows: usize,
        cols: usize,
    ) -> MatViewMut<'a, T> {
        let (in_rows, in_cols, range) =
            clamp_window(src_rows, src_cols, stride, r0, c0, rows, cols);
        MatViewMut {
            data: &mut data[range],
            stride,
            rows,
            cols,
            in_rows,
            in_cols,
        }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }
}

impl<'a, T: Copy + Default + PartialEq> MatViewMut<'a, T> {
    /// Splice `src`'s top-left `rows x cols` region into the window's
    /// in-bounds cells (one strided copy; padding cells are dropped).
    /// Returns true iff any destination element changed — the campaign
    /// runner's fault-exposure signal.
    pub fn splice_from(&mut self, src: &Mat<T>) -> bool {
        debug_assert!(
            src.rows() >= self.in_rows && src.cols() >= self.in_cols,
            "splice source smaller than window"
        );
        let mut changed = false;
        for r in 0..self.in_rows {
            let dst = &mut self.data[r * self.stride..r * self.stride + self.in_cols];
            let s = &src.row(r)[..self.in_cols];
            if dst != s {
                changed = true;
                dst.copy_from_slice(s);
            }
        }
        changed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn numbered(rows: usize, cols: usize) -> Mat<i32> {
        Mat::from_fn(rows, cols, |r, c| (r * cols + c) as i32 + 1)
    }

    #[test]
    fn mat_layout_is_row_major() {
        let m = numbered(2, 3);
        assert_eq!(m.data(), &[1, 2, 3, 4, 5, 6]);
        assert_eq!(m.row(1), &[4, 5, 6]);
        assert_eq!(m[(1, 2)], 6);
        assert_eq!(m.at(0, 1), 2);
    }

    #[test]
    fn full_view_reads_every_cell() {
        let m = numbered(3, 4);
        let v = m.view();
        for r in 0..3 {
            for c in 0..4 {
                assert_eq!(v.at(r, c), m[(r, c)]);
            }
        }
    }

    #[test]
    fn window_zero_pads_overhang() {
        let m = numbered(3, 3);
        // 4x4 window at (1, 1): bottom/right overhang out of the parent.
        let v = m.window(1, 1, 4, 4);
        assert_eq!(v.at(0, 0), m[(1, 1)]);
        assert_eq!(v.at(1, 1), m[(2, 2)]);
        assert_eq!(v.at(2, 0), 0, "row overhang reads zero");
        assert_eq!(v.at(0, 2), 0, "col overhang reads zero");
        assert_eq!(v.at(3, 3), 0);
    }

    #[test]
    fn window_fully_outside_is_all_zeros() {
        let m = numbered(2, 2);
        let v = m.window(5, 7, 3, 3);
        for r in 0..3 {
            for c in 0..3 {
                assert_eq!(v.at(r, c), 0);
            }
        }
    }

    #[test]
    fn sub_composes_with_padding() {
        let m = numbered(4, 4);
        let outer = m.window(2, 2, 4, 4); // in-bounds 2x2
        let inner = outer.sub(1, 1, 3, 3); // in-bounds 1x1 at parent (3,3)
        assert_eq!(inner.at(0, 0), m[(3, 3)]);
        assert_eq!(inner.at(0, 1), 0);
        assert_eq!(inner.at(2, 2), 0);
    }

    #[test]
    fn to_mat_materializes_padding() {
        let m = numbered(2, 2);
        let t = m.window(1, 0, 2, 3).to_mat();
        assert_eq!(t, Mat::from_vec(2, 3, vec![3, 4, 0, 0, 0, 0]));
    }

    #[test]
    fn copy_row_into_pads() {
        let m = numbered(2, 2);
        let v = m.window(0, 1, 3, 3);
        let mut buf = [9i32; 3];
        v.copy_row_into(0, &mut buf);
        assert_eq!(buf, [2, 0, 0]);
        v.copy_row_into(2, &mut buf);
        assert_eq!(buf, [0, 0, 0]);
    }

    #[test]
    fn splice_writes_in_bounds_only_and_reports_change() {
        let mut m = Mat::zeros(3, 3);
        let tile = Mat::from_vec(2, 2, vec![1, 2, 3, 4]);
        // window overhangs right edge: only column 2 of the tile lands
        let changed = m.window_mut(1, 2, 2, 2).splice_from(&tile);
        assert!(changed);
        assert_eq!(m.data(), &[0, 0, 0, 0, 0, 1, 0, 0, 3]);
        // splicing identical data reports no change
        let changed = m.window_mut(1, 2, 2, 2).splice_from(&tile);
        assert!(!changed);
    }

    #[test]
    fn reset_reuses_allocation_and_zeroes() {
        let mut m = numbered(3, 4);
        let ptr = m.data().as_ptr();
        m.reset(4, 3); // same element count: allocation must survive
        assert_eq!((m.rows(), m.cols()), (4, 3));
        assert_eq!(m.data().as_ptr(), ptr);
        assert!(m.data().iter().all(|&v| v == 0));
        m.set(0, 0, 7);
        m.reset(2, 2); // shrink: still zeroed
        assert_eq!(m.data(), &[0, 0, 0, 0]);
        let empty: Mat<i32> = Mat::default();
        assert_eq!((empty.rows(), empty.cols()), (0, 0));
    }

    #[test]
    fn zero_sized_windows_are_safe() {
        let m: Mat<i8> = Mat::zeros(0, 0);
        let v = m.window(0, 0, 2, 2);
        assert_eq!(v.at(1, 1), 0);
        let m2 = numbered(2, 2);
        let v2 = m2.window(0, 0, 0, 0);
        assert_eq!(v2.rows(), 0);
    }

    #[test]
    fn view_matches_nested_extraction_small_case() {
        // the nested-matrix tile extraction this module replaces
        let m = numbered(5, 7);
        let (r0, c0, th, tw) = (3, 5, 4, 4);
        let nested: Vec<Vec<i32>> = (0..th)
            .map(|r| {
                (0..tw)
                    .map(|c| {
                        if r0 + r < 5 && c0 + c < 7 {
                            m[(r0 + r, c0 + c)]
                        } else {
                            0
                        }
                    })
                    .collect()
            })
            .collect();
        let v = m.window(r0, c0, th, tw);
        for r in 0..th {
            for c in 0..tw {
                assert_eq!(v.at(r, c), nested[r][c]);
            }
        }
    }

    #[test]
    fn clone_from_reshapes_and_reuses_the_allocation() {
        let src = numbered(3, 4);
        let mut dst: Mat<i32> = Mat::zeros(6, 2); // same element count
        let ptr = dst.data().as_ptr();
        dst.clone_from(&src);
        assert_eq!(dst, src);
        assert_eq!(dst.data().as_ptr(), ptr, "equal-size copy must not allocate");
        // shrinking copies also keep the buffer
        let small = numbered(2, 2);
        dst.clone_from(&small);
        assert_eq!(dst, small);
        assert_eq!(dst.data().as_ptr(), ptr);
    }
}
